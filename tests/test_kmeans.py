"""Core Algorithm-1 behaviour: faithfulness + solver invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.anderson import AAConfig
from repro.core.hamerly import hamerly_kmeans
from repro.core.init_schemes import (afkmc2_init, bf_init, clarans_init,
                                     kmeanspp_init, random_init)
from repro.core.kmeans import KMeansConfig, aa_kmeans, aa_kmeans_traced
from repro.core.lloyd import assign, energy, lloyd_kmeans, update
from repro.data.synthetic import make_blobs


def _data(n=2000, d=8, k=7, seed=0, spread=1.5):
    x = jnp.asarray(make_blobs(n, d, k, seed=seed, spread=spread))
    c0 = kmeanspp_init(jax.random.PRNGKey(seed), x, k)
    return x, c0


def test_aa_monotone_energy_and_convergence():
    x, c0 = _data()
    tr = aa_kmeans_traced(x, c0, KMeansConfig(k=7, max_iter=300))
    e = tr.energies
    assert all(e[i + 1] <= e[i] + 1e-3 for i in range(len(e) - 1)), \
        "safeguarded AA must decrease the energy monotonically"
    assert bool(tr.result.converged)


def test_jit_matches_traced_driver():
    x, c0 = _data(seed=3)
    cfg = KMeansConfig(k=7, max_iter=300)
    tr = aa_kmeans_traced(x, c0, cfg)
    res = jax.jit(lambda a, b: aa_kmeans(a, b, cfg))(x, c0)
    assert int(res.n_iter) == int(tr.result.n_iter)
    assert int(res.n_accepted) == int(tr.result.n_accepted)
    np.testing.assert_allclose(float(res.energy), float(tr.result.energy),
                               rtol=1e-6)


def test_aa_final_energy_close_to_lloyd():
    # same local-minimum quality (paper: identical MSE in nearly all cases)
    x, c0 = _data(n=4000, d=6, k=10, seed=1)
    _, _, e_l, _ = lloyd_kmeans(x, c0, 10, 500)
    res = aa_kmeans(x, c0, KMeansConfig(k=10, max_iter=500))
    mse_l, mse_a = float(e_l) / 4000, float(res.energy) / 4000
    assert mse_a <= mse_l * 1.02, (mse_a, mse_l)


def test_unaccelerated_driver_equals_lloyd():
    x, c0 = _data(seed=5)
    cfg = KMeansConfig(k=7, max_iter=300, accelerated=False)
    res = aa_kmeans(x, c0, cfg)
    c_l, lab_l, e_l, it_l = lloyd_kmeans(x, c0, 7, 300)
    np.testing.assert_allclose(float(res.energy), float(e_l), rtol=1e-6)
    assert (np.asarray(res.labels) == np.asarray(lab_l)).all()


def test_hamerly_equals_lloyd_separated():
    """On separated clusters the bound-based trajectory is identical to
    Lloyd's (on heavily-overlapping data borderline samples may flip under
    the two fp distance formulations — both still valid Lloyd runs)."""
    x, c0 = _data(n=1500, seed=7, spread=5.0)
    c_h, lab_h, e_h, it_h, frac = hamerly_kmeans(x, c0, 7, 300)
    c_l, lab_l, e_l, it_l = lloyd_kmeans(x, c0, 7, 300)
    assert (np.asarray(lab_h) == np.asarray(lab_l)).all()
    np.testing.assert_allclose(float(e_h), float(e_l), rtol=1e-5)
    # separated clusters: bounds should eliminate most full scans
    assert float(frac) < 0.7


def test_hamerly_energy_parity_overlapping():
    x, c0 = _data(n=1500, seed=7)          # hard, overlapping regime
    *_, e_h, it_h, frac = hamerly_kmeans(x, c0, 7, 500)
    *_, e_l, it_l = lloyd_kmeans(x, c0, 7, 500)
    assert abs(float(e_h) - float(e_l)) / float(e_l) < 0.02
    assert 0.0 <= float(frac) <= 1.0


def test_dynamic_m_stays_in_bounds():
    x, c0 = _data(n=3000, k=7, seed=2, spread=1.0)
    cfg = KMeansConfig(k=7, max_iter=300,
                       aa=AAConfig(m0=2, mbar=10))
    tr = aa_kmeans_traced(x, c0, cfg)
    assert all(0 <= m <= 10 for m in tr.m_values)
    assert len(set(tr.m_values)) > 1, "m should actually adapt"


def test_acceptance_counted():
    x, c0 = _data(seed=4)
    tr = aa_kmeans_traced(x, c0, KMeansConfig(k=7, max_iter=300))
    assert int(tr.result.n_accepted) == sum(tr.accepted)
    assert int(tr.result.n_accepted) <= int(tr.result.n_iter)


@pytest.mark.parametrize("init_fn", [random_init, kmeanspp_init, afkmc2_init])
def test_init_schemes_shapes(init_fn):
    x, _ = _data(n=500, d=5, k=6)
    c = init_fn(jax.random.PRNGKey(0), x, 6)
    assert c.shape == (6, 5)
    assert bool(jnp.isfinite(c).all())


def test_bf_and_clarans_init():
    x, _ = _data(n=400, d=4, k=5)
    c = bf_init(jax.random.PRNGKey(0), x, 5, n_subsets=3, max_iter=10)
    assert c.shape == (5, 4) and bool(jnp.isfinite(c).all())
    c2 = clarans_init(jax.random.PRNGKey(0), x, 5, num_local=1,
                      max_neighbor=8, sample_n=256)
    assert c2.shape == (5, 4) and bool(jnp.isfinite(c2).all())


@pytest.mark.parametrize("init_fn", [random_init, kmeanspp_init,
                                     afkmc2_init, bf_init, clarans_init])
def test_init_schemes_reject_k_greater_than_n(init_fn):
    """Degenerate request k > n must fail with a clear ValueError, not an
    opaque gather/choice error (or, for clarans, a silent None)."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 3)),
                    jnp.float32)
    with pytest.raises(ValueError, match="k <= n"):
        init_fn(jax.random.PRNGKey(0), x, 9)
    with pytest.raises(ValueError, match="at least one cluster"):
        init_fn(jax.random.PRNGKey(0), x, 0)


def test_clarans_rejects_zero_num_local():
    """clarans_init(num_local=0) used to fall through its restart loop
    and return None."""
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64, 3)),
                    jnp.float32)
    with pytest.raises(ValueError, match="num_local"):
        clarans_init(jax.random.PRNGKey(0), x, 4, num_local=0)


def test_traced_warmup_excludes_compile_time():
    """warmup=True must compile before the timer starts: the warm trace's
    wall time may not exceed the cold trace's (which includes jit) and
    the statistics must be unchanged."""
    x, c0 = _data(seed=9)
    cfg = KMeansConfig(k=7, max_iter=300)
    cold = aa_kmeans_traced(x, c0, cfg)
    warm = aa_kmeans_traced(x, c0, cfg, warmup=True)
    assert int(warm.result.n_iter) == int(cold.result.n_iter)
    assert warm.energies == pytest.approx(cold.energies, rel=1e-6)
    # compile time is orders of magnitude above a warm solve here; 2x
    # slack keeps the assertion robust on a noisy CI box
    assert warm.wall_time_s <= cold.wall_time_s * 2.0


@settings(max_examples=15, deadline=None)
@given(n=st.integers(50, 400), d=st.integers(1, 12), k=st.integers(2, 8),
       seed=st.integers(0, 10_000))
def test_property_solver_invariants(n, d, k, seed):
    """Property: for arbitrary data, AA-KMeans converges to a valid
    clustering with energy <= initial, labels in range, finite centroids."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    c0 = x[rng.choice(n, k, replace=False)]
    res = aa_kmeans(x, c0, KMeansConfig(k=k, max_iter=200))
    lab0, mind0 = assign(x, c0)
    assert float(res.energy) <= float(jnp.sum(mind0)) + 1e-4
    labs = np.asarray(res.labels)
    assert labs.min() >= 0 and labs.max() < k
    assert bool(jnp.isfinite(res.centroids).all())
    # labels consistent with returned centroids
    lab_re, _ = assign(x, res.centroids)
    assert (np.asarray(lab_re) == labs).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_update_is_argmin_of_surrogate(seed):
    """Update step minimises the surrogate (5): cluster means are optimal."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((300, 4)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((5, 4)), jnp.float32)
    lab, _ = assign(x, c)
    c_new = update(x, lab, 5, c)
    e_new = energy(x, c_new, lab)
    for _ in range(5):
        pert = c_new + jnp.asarray(
            rng.standard_normal(c_new.shape), jnp.float32) * 0.05
        assert float(energy(x, pert, lab)) >= float(e_new) - 1e-4
