"""Cross-backend conformance matrix (ISSUE 3 acceptance).

Every backend in the registry x every step slot x every precision policy
is checked against the pure-jnp oracles in `repro/kernels/ref.py` on one
shared fixture, field by StepResult field — so a new backend (or a new
step slot on an existing backend) cannot ship without parity.  The
backend list is *iterated from the registry*, never hand-written: adding
`register_backend("new", ...)` automatically adds its whole row.

Fixture note: the data is well-separated blobs so that bf16 distance
rounding cannot flip an argmin — labels must be exact in every cell of
the matrix; float tolerances apply only to distances/stats/energy.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as B
from repro.core.init_schemes import kmeanspp_init
from repro.kernels import ref
from repro.data.synthetic import make_blobs

K = 5
R = 3          # restart axis for the batched slot
# options forcing the interesting code path at this fixture size
BACKEND_OPTS = {"blocked": dict(block_n=128)}
PRECISIONS = {
    "f32": B.Precision(),
    "bf16": B.Precision(compute=jnp.bfloat16),
}
# f32 tolerances are reduction-order slack; bf16 tolerances cover the
# compute-dtype rounding of the distance math.  The atol is *scaled by the
# field's magnitude*: the |x|^2 - 2xc + |c|^2 expansion cancels, so a bf16
# distance's absolute error is proportional to the |x|^2-scale of the row,
# not to the (possibly tiny) distance itself — a plain rtol would demand
# more precision of near-zero distances than bf16 carries.
TOLS = {"f32": dict(rtol=1e-4, atol_scale=1e-5),
        "bf16": dict(rtol=3e-2, atol_scale=3e-2)}

pytestmark = pytest.mark.conformance


@pytest.fixture(scope="module")
def fixture():
    x = jnp.asarray(make_blobs(384, 8, K, seed=0, spread=6.0))
    c = kmeanspp_init(jax.random.PRNGKey(0), x, K)
    cs = jnp.stack([jnp.asarray(kmeanspp_init(jax.random.PRNGKey(r), x, K))
                    for r in range(R)])
    n_real = 300                      # trailing rows are masked padding
    w = jnp.concatenate([jnp.ones((n_real,), jnp.float32),
                         jnp.zeros((x.shape[0] - n_real,), jnp.float32)])
    return x, c, cs, w


def _make(name, prec_key):
    return B.get_backend(name, precision=PRECISIONS[prec_key],
                         **BACKEND_OPTS.get(name, {}))


def _allclose(got, want, tol, msg):
    want64 = np.asarray(want, np.float64)
    scale = max(float(np.max(np.abs(want64))), 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float64), want64,
                               rtol=tol["rtol"],
                               atol=tol["atol_scale"] * scale,
                               err_msg=msg)


def _check(x, c, res, tol, cell, w=None):
    """Two-part conformance contract per StepResult:

    1. The assignment is the oracle argmin — exactly, except that a cell
       may flip a row whose top-2 oracle distances are within the cell's
       tolerance of each other (bf16 rounding legitimately breaks exact
       ties; the f32 atol_scale is tight enough to forbid flips there).
    2. min_sqdist / sums / counts / energy are the exact weighted
       reductions OF THE ASSIGNMENT MADE (oracle recomputation from the
       returned labels), to the cell's tolerance — a backend cannot hide
       a broken stats pipeline behind a tie flip.
    """
    x64 = np.asarray(x, np.float64)
    c64 = np.asarray(c, np.float64)
    d2 = np.maximum(((x64[:, None, :] - c64[None, :, :]) ** 2).sum(-1), 0.0)
    scale = max(float(d2.max()), 1.0)
    labels = np.asarray(res.labels)
    ref_labels = np.asarray(ref.assignment_ref(x, c)[0])
    mism = np.nonzero(labels != ref_labels)[0]
    if mism.size:
        gap = d2[mism, labels[mism]] - d2[mism].min(-1)
        assert (gap <= tol["atol_scale"] * scale).all(), (
            f"{cell}: {mism.size} label rows diverge beyond a "
            f"compute-dtype tie (worst gap {gap.max():.4g})")
    n = labels.shape[0]
    want_mind = d2[np.arange(n), labels]
    ww = np.ones(n) if w is None else np.asarray(w, np.float64)
    want_sums = np.zeros((c64.shape[0], x64.shape[1]))
    np.add.at(want_sums, labels, x64 * ww[:, None])
    want_counts = np.bincount(labels, weights=ww,
                              minlength=c64.shape[0])
    _allclose(res.min_sqdist, want_mind, tol, f"{cell}: min_sqdist")
    _allclose(res.sums, want_sums, tol, f"{cell}: sums")
    np.testing.assert_allclose(np.asarray(res.counts), want_counts,
                               rtol=0, atol=1e-5,
                               err_msg=f"{cell}: counts")
    _allclose(res.energy, (want_mind * ww).sum(), tol, f"{cell}: energy")


@pytest.mark.parametrize("prec", sorted(PRECISIONS))
@pytest.mark.parametrize("mode", ["single", "batched", "minibatch"])
@pytest.mark.parametrize("name", B.backend_names())
def test_step_slot_conformance(name, mode, prec, fixture):
    x, c, cs, w = fixture
    backend = _make(name, prec)
    tol = TOLS[prec]
    cell = f"{name}/{mode}/{prec}"
    if mode == "single":
        res, _ = backend.step(x, c, K, backend.init_carry(x, c, K))
        _check(x, c, res, tol, cell)
    elif mode == "minibatch":
        res, _ = backend.minibatch_step(x, c, K, w,
                                        backend.init_carry(x, c, K))
        _check(x, c, res, tol, cell, w=w)
    else:
        carries = jax.vmap(lambda cc: backend.init_carry(x, cc, K))(cs)
        res, _ = backend.batched_step(x, cs, K, carries)
        for r in range(R):
            _check(x, cs[r],
                   jax.tree_util.tree_map(lambda a: a[r], res),
                   tol, f"{cell}[r={r}]")


def test_matrix_covers_whole_registry():
    """The parametrization above is generated from backend_names(); this
    guard documents (and enforces) that the registry is the source of
    truth — the known engines must all be present, and the matrix size
    follows the registry, not a hand-written list."""
    names = B.backend_names()
    assert set(names) >= {"dense", "blocked", "pallas", "fused", "hamerly"}
    assert len(names) == len(set(names))


def test_minibatch_zero_weight_rows_are_inert(fixture):
    """The chunk contract: w=0 rows must vanish from sums/counts/energy
    exactly — padding a chunk equals truncating it."""
    x, c, _, w = fixture
    n_real = int(np.asarray(w).sum())
    for name in B.backend_names():
        backend = _make(name, "f32")
        res_pad, _ = backend.minibatch_step(
            x, c, K, w, backend.init_carry(x, c, K))
        xt = x[:n_real]
        res_cut, _ = backend.minibatch_step(
            xt, c, K, jnp.ones((n_real,), jnp.float32),
            backend.init_carry(xt, c, K))
        np.testing.assert_allclose(res_pad.sums, res_cut.sums,
                                   rtol=1e-5, atol=1e-5, err_msg=name)
        np.testing.assert_allclose(res_pad.counts, res_cut.counts,
                                   rtol=0, atol=1e-6, err_msg=name)
        np.testing.assert_allclose(float(res_pad.energy),
                                   float(res_cut.energy),
                                   rtol=1e-5, err_msg=name)


def test_fused_has_no_vmem_fallback(fixture, monkeypatch):
    """Satellite regression (kernels v2): the VMEM budget now drives the
    tile chooser, not a gate — a budget far too small for the centroid
    block must still take the fused single-pass kernel (k-tiled), never
    the old two-kernel fallback, and the step must stay correct."""
    from repro.core.backends import pallas as P
    from repro.kernels import tiles
    x, c, _, _ = fixture
    fused_calls, split_calls = [], []
    real = P.fused_lloyd_pallas

    def spy(*a, **kw):
        fused_calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(P, "fused_lloyd_pallas", spy)
    monkeypatch.setattr(P, "assignment_pallas",
                        lambda *a, **kw: split_calls.append(1))
    # smaller than one (K, d) centroid block at f32 — v1 fell back here
    monkeypatch.setattr(tiles, "DEFAULT_VMEM_BUDGET", K * x.shape[1] * 4 - 1)
    res, _ = P.fused_backend(B.Precision()).step(x, c, K, ())
    assert fused_calls and not split_calls, (fused_calls, split_calls)
    _check(x, c, res, TOLS["f32"], "fused/tiny-vmem-budget")
    # and the chooser actually shrank the tiles under that budget
    tn, tk = tiles.choose_tiles(x.shape[0], K, x.shape[1], 4, kind="fused")
    assert (tn, tk) != tiles.choose_tiles(x.shape[0], K, x.shape[1], 4,
                                          kind="fused",
                                          vmem_bytes=tiles.MAX_TILE ** 3)
