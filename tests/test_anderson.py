"""Anderson-acceleration unit behaviour (independent of K-Means)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import anderson
from repro.core.anderson import AAConfig


def _aa_solve_linear(a, b, x0, m, iters):
    """Accelerate the fixed-point iteration x <- Ax + b."""
    cfg = AAConfig(m0=m, mbar=max(m, 2), dynamic_m=False)
    d = x0.shape[0]
    st = anderson.aa_init(d, cfg)
    x = x0
    g = a @ x + b
    st = anderson.aa_seed(st, g - x, g)
    x = g
    errs = []
    for _ in range(iters):
        g = a @ x + b
        f = g - x
        st, x, _, _ = anderson.aa_push_and_solve(st, f, g, cfg)
        errs.append(float(jnp.linalg.norm(f)))
    return x, errs


def test_aa_accelerates_linear_fixed_point():
    """On x <- Ax + b (contraction), AA-m should far outpace Picard."""
    rng = np.random.default_rng(0)
    d = 12
    q, _ = np.linalg.qr(rng.standard_normal((d, d)))
    a = jnp.asarray(q @ np.diag(rng.uniform(0.5, 0.95, d)) @ q.T,
                    jnp.float32)
    b = jnp.asarray(rng.standard_normal(d), jnp.float32)
    x0 = jnp.zeros(d)
    x_star = jnp.linalg.solve(jnp.eye(d) - a, b)

    x_aa, errs_aa = _aa_solve_linear(a, b, x0, m=d, iters=25)
    # plain Picard for the same budget
    x_p = x0
    for _ in range(26):
        x_p = a @ x_p + b
    err_aa = float(jnp.linalg.norm(x_aa - x_star))
    err_p = float(jnp.linalg.norm(x_p - x_star))
    assert err_aa < err_p * 1e-2, (err_aa, err_p)


def test_aa_window_m0_is_picard():
    """m = 0 must reduce to the unaccelerated iteration exactly."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(-0.2, 0.2, (6, 6)), jnp.float32)
    b = jnp.asarray(rng.standard_normal(6), jnp.float32)
    x0 = jnp.zeros(6)
    x_aa, _ = _aa_solve_linear(a, b, x0, m=0, iters=10)
    x_p = x0
    g = a @ x_p + b
    x_p = g
    for _ in range(10):
        x_p = a @ x_p + b
    np.testing.assert_allclose(np.asarray(x_aa), np.asarray(x_p),
                               rtol=1e-5, atol=1e-5)


def test_adjust_m_policy():
    cfg = AAConfig(m0=5, mbar=8, eps1=0.02, eps2=0.5)
    st = anderson.aa_init(4, cfg)
    one = jnp.array(1.0)
    # big relative decrease -> grow
    st2 = anderson.adjust_m(st, e_curr=one * 1.0, e_prev=one * 10.0,
                            e_prev2=one * 11.0, cfg=cfg)
    assert int(st2.m) == 6
    # tiny decrease -> shrink
    st3 = anderson.adjust_m(st, e_curr=one * 9.999, e_prev=one * 10.0,
                            e_prev2=one * 20.0, cfg=cfg)
    assert int(st3.m) == 4
    # energy increase (negative ratio) -> shrink
    st4 = anderson.adjust_m(st, e_curr=one * 11.0, e_prev=one * 10.0,
                            e_prev2=one * 20.0, cfg=cfg)
    assert int(st4.m) == 4
    # undefined history (inf) -> unchanged
    st5 = anderson.adjust_m(st, e_curr=one * 5.0, e_prev=one * 10.0,
                            e_prev2=one * jnp.inf, cfg=cfg)
    assert int(st5.m) == 5
    # clamping at mbar and 0
    st = st._replace(m=jnp.array(8, jnp.int32))
    st6 = anderson.adjust_m(st, one * 1.0, one * 10.0, one * 11.0, cfg)
    assert int(st6.m) == 8
    st = st._replace(m=jnp.array(0, jnp.int32))
    st7 = anderson.adjust_m(st, one * 9.999, one * 10.0, one * 20.0, cfg)
    assert int(st7.m) == 0


def test_circular_buffer_ages():
    cfg = AAConfig(m0=3, mbar=4)
    st = anderson.aa_init(2, cfg)
    st = anderson.aa_seed(st, jnp.zeros(2), jnp.zeros(2))
    for i in range(6):   # wrap the mbar=4 buffer
        f = jnp.full((2,), float(i + 1))
        g = jnp.full((2,), float(2 * i + 1))
        st, _, _, m_t = anderson.aa_push_and_solve(st, f, g, cfg)
    assert int(st.ncols) == 4
    # newest column holds f_6 - f_5 = 1 at head-1
    newest = (int(st.head) - 1) % 4
    np.testing.assert_allclose(np.asarray(st.dF[newest]), [1.0, 1.0])
