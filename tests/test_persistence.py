"""Persistence engine + bugfix-sweep regressions (DESIGN.md §Persistence).

The load-bearing guarantee under test: a solve checkpointed at iteration t
and resumed — same process, new process, same or different mesh — is
BIT-IDENTICAL to the uninterrupted run, because segmentation only
partitions the identical sequence of jit'd loop bodies.  Parity is
asserted against the stored golden trajectory (tests/golden/), so resume
correctness and numeric stability are pinned by the same artifact.

Also here: the satellite bug regressions this PR's sweep fixed —
bf16 count saturation in `lloyd.cluster_sums` (a bf16 count freezes at
256), NaN-blind `select_best` (argmin returns 0 on any NaN energy),
Hamerly's O(K log K) argsort full scan (now a top-2 min reduction), and
the eager/unchunked estimator serving path.
"""

import os
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent / "golden"))
import generate_golden as G  # noqa: E402

from repro.checkpoint import latest_snapshot, load_estimator, resume_point
from repro.core import serialize
from repro.core.api import AAKMeans, MiniBatchAAKMeans
from repro.core.backends import Precision, get_backend
from repro.core.backends.dense import dense_backend
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import (KMeansConfig, aa_kmeans, aa_kmeans_batched,
                               aa_kmeans_minibatch, select_best)
from repro.core.lloyd import assign, cluster_sums, weighted_cluster_sums
from repro.core.minibatch import MiniBatchConfig, minibatch_init
from repro.data.streaming import chunk_dataset, split_validation
from repro.data.synthetic import make_blobs

CPU = jax.default_backend() == "cpu"


def _bits_equal(a, b, err_msg=""):
    a, b = np.asarray(a), np.asarray(b)
    if CPU:
        np.testing.assert_array_equal(
            a.view(np.uint32) if a.dtype == np.float32 else a,
            b.view(np.uint32) if b.dtype == np.float32 else b,
            err_msg=err_msg)
    else:
        np.testing.assert_allclose(a, b, rtol=1e-5, err_msg=err_msg)


@pytest.fixture(scope="module")
def golden_problem():
    """The exact problem behind tests/golden/aa_dense_cpu.npz."""
    x = jnp.asarray(make_blobs(G.N, G.D, G.K, seed=G.SEED, spread=G.SPREAD))
    c0 = kmeanspp_init(jax.random.PRNGKey(G.SEED), x, G.K)
    return x, c0, KMeansConfig(k=G.K, max_iter=G.MAX_ITER)


@pytest.fixture(scope="module")
def golden():
    with np.load(G.GOLDEN_PATH) as z:
        return {k: z[k] for k in z.files}


# ---------------------------------------------------------------------------
# serialize.py — the artifact layer
# ---------------------------------------------------------------------------

def test_serialize_roundtrip_bit_exact(tmp_path):
    tree = {"c": jnp.arange(12, dtype=jnp.float32).reshape(3, 4) * np.pi,
            "w": {"m": jnp.ones((5,), jnp.bfloat16) * 1.5,
                  "t": jnp.array(7, jnp.int32)},
            "flag": jnp.array(True)}
    p = serialize.save(tmp_path / "s", tree, kind="unit", extra={"t": 3})
    assert p.suffix == ".npz" and p.exists()
    like = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    out, meta = serialize.restore(p, like, expect_kind="unit")
    assert meta["t"] == 3 and meta["schema"] == serialize.SCHEMA_VERSION
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert b.dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serialize_refuses_newer_schema_and_wrong_kind(tmp_path, monkeypatch):
    tree = {"a": jnp.zeros((2,))}
    p = serialize.save(tmp_path / "s", tree, kind="unit")
    with pytest.raises(ValueError, match="expected 'other'"):
        serialize.load(p, expect_kind="other")
    monkeypatch.setattr(serialize, "SCHEMA_VERSION", 0)
    with pytest.raises(ValueError, match="newer"):
        serialize.load(p)


def test_restore_shape_mismatch_is_loud(tmp_path):
    p = serialize.save(tmp_path / "s", {"a": jnp.zeros((4, 2))}, kind="unit")
    with pytest.raises(ValueError, match="shape mismatch"):
        serialize.restore(p, {"a": jax.ShapeDtypeStruct((3, 2), np.float32)})
    with pytest.raises(ValueError, match="missing leaves"):
        serialize.restore(p, {"b": jax.ShapeDtypeStruct((4, 2), np.float32)})


def test_serialize_migration_chain(tmp_path, monkeypatch):
    """Older-schema artifacts are upgraded through registered per-kind
    migrations; a missing migration fails loudly instead of guessing."""
    tree_old = {"c": jnp.arange(6, dtype=jnp.float32).reshape(3, 2),
                "e": jnp.asarray(4.5)}     # hypothetical old leaf name
    p = serialize.save(tmp_path / "s", tree_old, kind=serialize.KIND_LOOP,
                       extra={"t": 5})
    monkeypatch.setattr(serialize, "SCHEMA_VERSION",
                        serialize.SCHEMA_VERSION + 1)
    with pytest.raises(ValueError, match="no migration is registered"):
        serialize.load(p)

    def mig(meta, by_path):      # schema bump renamed 'e' -> 'energy'
        by_path["energy"] = by_path.pop("e")
        for leaf in meta["leaves"]:
            if leaf["path"] == "e":
                leaf["path"] = "energy"
        return meta, by_path     # schema bump applied by the chain

    serialize.register_migration(serialize.KIND_LOOP,
                                 serialize.SCHEMA_VERSION - 1, mig)
    try:
        like = {"c": jax.ShapeDtypeStruct((3, 2), np.float32),
                "energy": jax.ShapeDtypeStruct((), np.float32)}
        out, meta = serialize.restore(p, like,
                                      expect_kind=serialize.KIND_LOOP)
        assert meta["schema"] == serialize.SCHEMA_VERSION
        assert meta["t"] == 5
        np.testing.assert_array_equal(np.asarray(tree_old["c"]), out["c"])
        assert float(out["energy"]) == 4.5
    finally:
        serialize.unregister_migration(serialize.KIND_LOOP,
                                       serialize.SCHEMA_VERSION - 1)


def test_migrated_loop_state_resumes_bit_identical(tmp_path, monkeypatch):
    """End-to-end schema evolution drill on the real driver: snapshot a
    run, rewrite the artifact as if saved before a (simulated)
    `_LoopState` field rename, bump SCHEMA_VERSION, register the
    migration — the segmented driver resumes from the migrated artifact
    and reproduces the uninterrupted solve bit for bit."""
    x = jnp.asarray(make_blobs(400, 4, 5, seed=0, spread=1.0))
    c0 = kmeanspp_init(jax.random.PRNGKey(0), x, 5)
    cfg = KMeansConfig(k=5, max_iter=30)
    ref = aa_kmeans(x, c0, cfg)
    aa_kmeans(x, c0, cfg, checkpoint_every=5, checkpoint_dir=tmp_path)
    p = latest_snapshot(tmp_path)
    meta, by_path = serialize.load(p)    # current layout, current schema

    # forge the pre-rename artifact: leaf 'e_last' used to be 'e_final'
    old = dict(by_path)
    old["e_final"] = old.pop("e_last")
    extra = {k: v for k, v in meta.items()
             if k not in ("schema", "kind", "leaves")}
    p_old = serialize.save(tmp_path / "old_schema", old,
                           kind=serialize.KIND_LOOP, extra=extra)

    monkeypatch.setattr(serialize, "SCHEMA_VERSION",
                        serialize.SCHEMA_VERSION + 1)

    def mig(m, bp):
        bp["e_last"] = bp.pop("e_final")
        for leaf in m["leaves"]:
            if leaf["path"] == "e_final":
                leaf["path"] = "e_last"
        return m, bp

    serialize.register_migration(serialize.KIND_LOOP,
                                 serialize.SCHEMA_VERSION - 1, mig)
    try:
        res = aa_kmeans(x, c0, cfg, resume_from=p_old)
    finally:
        serialize.unregister_migration(serialize.KIND_LOOP,
                                       serialize.SCHEMA_VERSION - 1)
    assert float(res.energy) == float(ref.energy)
    np.testing.assert_array_equal(np.asarray(res.centroids),
                                  np.asarray(ref.centroids))


# ---------------------------------------------------------------------------
# Segmented drivers — resume parity against the golden trajectory
# ---------------------------------------------------------------------------

def test_segmented_trajectory_matches_golden(golden_problem, golden,
                                             tmp_path):
    """checkpoint_every=1 visits every post-iteration state; its e_last /
    labels must be the golden per-iteration trajectory bit for bit —
    segmentation may not change a single loop body."""
    x, c0, cfg = golden_problem
    states = []
    aa_kmeans(x, c0, cfg, checkpoint_every=1,
              checkpoint_cb=lambda st, t: states.append(st))
    live = [st for st in states if not bool(st.converged)]
    assert len(live) == golden["energies"].shape[0]
    _bits_equal(np.stack([np.asarray(st.e_last) for st in live]),
                golden["energies"], "per-iteration energies drifted")
    np.testing.assert_array_equal(
        np.stack([np.asarray(st.labels) for st in live]), golden["labels"])
    _bits_equal(states[-1].c, golden["centroids"], "final centroids")


@pytest.mark.parametrize("resume_at", [1, 2])
def test_resume_is_bit_identical(golden_problem, golden, tmp_path,
                                 resume_at):
    """Kill the solve at a segment boundary, restore the artifact in what
    is effectively a fresh process (path in, state out), finish: energies,
    labels and centroids match the uninterrupted run — and hence the
    golden file — exactly."""
    x, c0, cfg = golden_problem
    ref = aa_kmeans(x, c0, cfg)
    d = tmp_path / "run"
    res_ck = aa_kmeans(x, c0, cfg, checkpoint_every=5, checkpoint_dir=d)
    snaps = sorted(d.glob("it_*.npz"))
    assert latest_snapshot(d) == snaps[-1]
    path, meta = resume_point(d)
    assert path == snaps[-1]
    assert bool(ref.converged) and meta["t"] == int(ref.n_iter)
    assert meta["k"] == G.K and meta["backend"] == "dense"
    res_rs = aa_kmeans(x, c0, cfg, resume_from=snaps[resume_at])
    for r in (res_ck, res_rs):
        _bits_equal(r.energy, ref.energy)
        np.testing.assert_array_equal(np.asarray(r.labels),
                                      np.asarray(ref.labels))
        _bits_equal(r.centroids, golden["centroids"])
        assert int(r.n_iter) == int(ref.n_iter)
        assert int(r.n_accepted) == int(ref.n_accepted)


def test_resume_meta_guard(golden_problem, tmp_path):
    x, c0, cfg = golden_problem
    d = tmp_path / "run"
    aa_kmeans(x, c0, cfg, checkpoint_every=5, checkpoint_dir=d)
    snap = latest_snapshot(d)
    with pytest.raises(ValueError, match="shape mismatch"):
        aa_kmeans(x, c0[:-1], KMeansConfig(k=G.K - 1, max_iter=10),
                  resume_from=snap)
    with pytest.raises(ValueError, match="backend"):
        aa_kmeans(x, c0, cfg, backend="hamerly", resume_from=snap)


def test_checkpointed_call_refuses_jit(golden_problem):
    x, c0, cfg = golden_problem
    with pytest.raises(ValueError, match="host-side segment loop"):
        jax.jit(lambda a, b: aa_kmeans(a, b, cfg, checkpoint_every=2))(x, c0)


def test_batched_resume_is_bit_identical(rng, tmp_path):
    x = jnp.asarray(make_blobs(512, 6, 8, seed=1, spread=1.2))
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    c0s = jnp.stack([kmeanspp_init(k, x, 8) for k in keys])
    cfg = KMeansConfig(k=8, max_iter=60)
    ref = aa_kmeans_batched(x, c0s, cfg)
    d = tmp_path / "runb"
    aa_kmeans_batched(x, c0s, cfg, checkpoint_every=7, checkpoint_dir=d)
    snaps = sorted(d.glob("it_*.npz"))
    assert len(snaps) >= 2
    res = aa_kmeans_batched(x, c0s, cfg, resume_from=snaps[0])
    _bits_equal(res.energy, ref.energy)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(ref.labels))
    np.testing.assert_array_equal(np.asarray(res.n_iter),
                                  np.asarray(ref.n_iter))
    best = select_best(res)
    _bits_equal(best.energy, select_best(ref).energy)


def test_minibatch_resume_is_bit_identical(tmp_path):
    x = jnp.asarray(make_blobs(512, 6, 8, seed=2, spread=1.5))
    key = jax.random.PRNGKey(3)
    x_val, x_train = split_validation(x, 64, key)
    dc = chunk_dataset(x_train, 64)
    c0 = kmeanspp_init(jax.random.PRNGKey(4), x, 8)
    cfg = MiniBatchConfig(k=8, epochs=4)
    ref = aa_kmeans_minibatch(dc.chunks, dc.weights, x_val, c0, cfg, key=key)
    d = tmp_path / "runm"
    aa_kmeans_minibatch(dc.chunks, dc.weights, x_val, c0, cfg, key=key,
                        checkpoint_every=1, checkpoint_dir=d)
    snaps = sorted(d.glob("it_*.npz"))
    assert len(snaps) == cfg.epochs
    res = aa_kmeans_minibatch(dc.chunks, dc.weights, x_val, c0, cfg,
                              key=key, resume_from=snaps[1])
    _bits_equal(res.energy, ref.energy)
    _bits_equal(res.centroids, ref.centroids)


# ---------------------------------------------------------------------------
# Estimator persistence
# ---------------------------------------------------------------------------

def test_aakmeans_save_load_roundtrip(rng, tmp_path):
    x = make_blobs(400, 5, 6, seed=5, spread=2.0)
    m = AAKMeans(n_clusters=6, max_iter=50, n_init=2, seed=0).fit(x)
    p = m.save(tmp_path / "model")
    for m2 in (AAKMeans.load(p), load_estimator(p)):
        assert isinstance(m2, AAKMeans)
        assert m2.energy_ == m.energy_ and m2.n_iter_ == m.n_iter_
        np.testing.assert_array_equal(np.asarray(m2.centroids_),
                                      np.asarray(m.centroids_))
        np.testing.assert_array_equal(m2.predict(x), m.predict(x))
        np.testing.assert_allclose(m2.transform(x), m.transform(x),
                                   rtol=1e-6)
    with pytest.raises(ValueError, match="not an estimator artifact"):
        serialize.save(tmp_path / "junk", {"a": jnp.zeros(2)}, kind="unit")
        load_estimator(tmp_path / "junk.npz")


def test_minibatch_estimator_midstream_roundtrip(tmp_path):
    """A partial_fit stream killed mid-flight and reloaded in a 'new
    process' must finish exactly like the process that never died."""
    x = make_blobs(640, 5, 4, seed=6, spread=2.0)
    kw = dict(n_clusters=4, chunk_size=64, epochs=2, seed=0)
    m = MiniBatchAAKMeans(**kw)
    for i in range(0, 320, 64):
        m.partial_fit(x[i:i + 64])
    p = m.save(tmp_path / "mid")
    m2 = MiniBatchAAKMeans.load(p)
    assert m2.n_steps_ == m.n_steps_
    for mm in (m, m2):
        for i in range(320, 640, 64):
            mm.partial_fit(x[i:i + 64])
        mm.finalize()
    assert m2.energy_ == m.energy_
    np.testing.assert_array_equal(np.asarray(m2.centroids_),
                                  np.asarray(m.centroids_))
    # a FITTED artifact roundtrips too (and serves)
    p2 = m.save(tmp_path / "done")
    m3 = load_estimator(p2)
    np.testing.assert_array_equal(m3.predict(x), m.predict(x))


def test_estimator_backend_roundtrip(tmp_path):
    """A Backend-instance backend must rebuild equivalently on load:
    recording bare `bk.name` either failed to resolve ('blocked4096' is
    no registry key) or silently dropped a custom precision."""
    from repro.core.backends import blocked_backend, get_backend
    x = make_blobs(300, 4, 3, seed=12, spread=2.0)
    m = AAKMeans(n_clusters=3, max_iter=30, seed=0,
                 backend=blocked_backend(128)).fit(x)
    m2 = AAKMeans.load(m.save(tmp_path / "blk"))
    assert m2.backend.name == "blocked128"
    np.testing.assert_array_equal(m2.predict(x), m.predict(x))
    mb = AAKMeans(n_clusters=3, max_iter=30, seed=0,
                  backend=dense_backend(
                      Precision(compute=jnp.bfloat16))).fit(x)
    mb2 = AAKMeans.load(mb.save(tmp_path / "bf16"))
    assert mb2.backend.precision.compute == jnp.bfloat16
    reg = AAKMeans(n_clusters=3, max_iter=30, seed=0,
                   backend=get_backend("hamerly")).fit(x)
    assert AAKMeans.load(reg.save(tmp_path / "ham")).backend.name == \
        "hamerly"


def test_minibatch_cb_state_resumes_without_rerunning_epochs(tmp_path):
    """The checkpoint_cb payload carries the epoch counter, so feeding it
    back as resume_from continues the run instead of stacking cfg.epochs
    MORE epochs onto already-advanced state."""
    x = jnp.asarray(make_blobs(512, 6, 8, seed=13, spread=1.5))
    key = jax.random.PRNGKey(13)
    x_val, x_train = split_validation(x, 64, key)
    dc = chunk_dataset(x_train, 64)
    c0 = kmeanspp_init(jax.random.PRNGKey(14), x, 8)
    cfg = MiniBatchConfig(k=8, epochs=4)
    ref = aa_kmeans_minibatch(dc.chunks, dc.weights, x_val, c0, cfg,
                              key=key)
    snaps = []
    aa_kmeans_minibatch(dc.chunks, dc.weights, x_val, c0, cfg, key=key,
                        checkpoint_every=1,
                        checkpoint_cb=lambda tree, e: snaps.append(tree))
    assert snaps[1]["epoch"] == 2
    res = aa_kmeans_minibatch(dc.chunks, dc.weights, x_val, c0, cfg,
                              key=key, resume_from=snaps[1])
    assert int(res.n_steps) == int(ref.n_steps)
    _bits_equal(res.energy, ref.energy)
    _bits_equal(res.centroids, ref.centroids)


def test_batched_accum_policy_floors_at_f32():
    """Backend slots obey the >= f32 stat-accumulation floor even under
    an explicit accum=bf16 policy — the batched one-hot path used to
    accumulate counts in bf16 and saturate past 256 members."""
    bk = dense_backend(Precision(compute=jnp.bfloat16,
                                 accum=jnp.bfloat16))
    n = 1000
    x = jnp.ones((n, 4), jnp.bfloat16)
    cs = jnp.stack([jnp.zeros((2, 4), jnp.bfloat16)] * 2).at[:, 1].set(9.0)
    res, _ = bk.batched_step_fn(x, cs, 2, ((), ()))
    assert res.counts.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(res.counts),
                                  [[n, 0]] * 2)
    resw, _ = bk.minibatch_step_fn(x, cs[0], 2, jnp.ones((n,), jnp.bfloat16),
                                   ())
    np.testing.assert_array_equal(np.asarray(resw.counts), [n, 0])


def test_chunked_local_predict_transform(rng):
    """Local (no-mesh) predict/transform are chunked + host-resident: the
    output is numpy, chunk size does not change values, and the jitted
    runner is cached on the model (one entry per kind)."""
    x = make_blobs(500, 4, 3, seed=7, spread=2.0)
    m = AAKMeans(n_clusters=3, max_iter=30, seed=0).fit(x)
    lab = m.predict(x, chunk_size=128)
    dist = m.transform(x, chunk_size=96)
    assert isinstance(lab, np.ndarray) and isinstance(dist, np.ndarray)
    assert dist.shape == (500, 3)
    np.testing.assert_array_equal(lab, m.predict(x, chunk_size=499))
    np.testing.assert_allclose(dist, m.transform(x, chunk_size=500),
                               rtol=1e-6)
    assert len(m._local_runners) == 2   # predict + transform, cached
    np.testing.assert_array_equal(lab, np.argmin(dist, axis=1))


# ---------------------------------------------------------------------------
# Bugfix sweep regressions
# ---------------------------------------------------------------------------

def test_bf16_counts_do_not_saturate():
    """bf16 has 8 mantissa bits: pre-fix, a count accumulated in x.dtype
    froze at 256 (256 + 1 rounds to 256) and the cluster's centroid
    silently drifted.  Counts/sums must now accumulate >= f32."""
    n = 1000
    x = jnp.ones((n, 4), jnp.bfloat16)
    labels = jnp.zeros((n,), jnp.int32)
    sums, counts = cluster_sums(x, labels, 2)
    assert counts.dtype == jnp.float32 and sums.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(counts), [n, 0])
    _, wcounts = weighted_cluster_sums(x, labels,
                                       jnp.ones((n,), jnp.bfloat16), 2)
    np.testing.assert_array_equal(np.asarray(wcounts), [n, 0])


def test_bf16_dense_solve_counts_match_f32_oracle():
    """Acceptance criterion: a bf16 dense solve whose clusters exceed 256
    members keeps exact counts — equal to the integer histogram of the
    assignment it actually made (the f32 oracle)."""
    x = jnp.asarray(make_blobs(2000, 4, 4, seed=8, spread=6.0))
    c0 = kmeanspp_init(jax.random.PRNGKey(8), x, 4)
    bk = dense_backend(Precision(compute=jnp.bfloat16, accum=jnp.bfloat16))
    res = aa_kmeans(x.astype(jnp.bfloat16), c0.astype(jnp.bfloat16),
                    KMeansConfig(k=4, max_iter=50), backend=bk)
    step, _ = bk.step(x.astype(jnp.bfloat16), res.centroids, 4, ())
    oracle = np.bincount(np.asarray(step.labels), minlength=4)
    assert oracle.max() > 256, "fixture must exercise the saturation range"
    np.testing.assert_array_equal(np.asarray(step.counts, np.float64),
                                  oracle)
    # the streaming engine floors its long-horizon accumulators the same way
    st = minibatch_init(c0, MiniBatchConfig(k=4), bk)
    assert st.counts.dtype == jnp.float32


def test_select_best_skips_nan_energies():
    """argmin returns index 0 as soon as ANY energy is NaN — a degenerate
    restart must never beat finite ones."""
    x = jnp.asarray(make_blobs(256, 4, 4, seed=9, spread=2.0))
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    c0s = jnp.stack([kmeanspp_init(k, x, 4) for k in keys])
    res = aa_kmeans_batched(x, c0s, KMeansConfig(k=4, max_iter=40))
    e = np.asarray(res.energy).copy()
    e[0] = np.nan                      # restart 0 "wins" under bare argmin
    poisoned = res._replace(energy=jnp.asarray(e))
    best = select_best(poisoned)
    assert float(best.energy) == np.nanmin(e)
    # all-NaN surfaces instead of silently crowning restart 0
    all_nan = res._replace(energy=jnp.full_like(res.energy, np.nan))
    assert not np.isfinite(float(select_best(all_nan).energy))


def test_fit_surfaces_all_nan_restarts():
    x = np.full((64, 3), np.nan, np.float32)
    with pytest.raises(FloatingPointError, match="non-finite"):
        AAKMeans(n_clusters=2, max_iter=5, n_init=2, seed=0).fit(x)


def test_hamerly_full_scan_top2_parity(rng):
    """The argsort full scan became two O(K) min reductions; (argmin, min,
    second-min) and the tie convention (first index wins) are unchanged —
    including duplicated centroids, where d2 == d1."""
    from repro.core.backends.hamerly import _full_scan as scan_bk
    from repro.core.hamerly import _full_scan as scan_legacy
    x = jnp.asarray(rng.normal(size=(300, 5)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(16, 5)).astype(np.float32))
    c = c.at[7].set(c[3])              # exact duplicate: tie on d1/d2
    d = np.linalg.norm(np.asarray(x)[:, None] - np.asarray(c)[None], axis=2)
    order = np.argsort(d, axis=1, kind="stable")
    for scan in (scan_bk, scan_legacy):
        lab, d1, d2 = scan(x, c)
        np.testing.assert_array_equal(np.asarray(lab), order[:, 0])
        np.testing.assert_allclose(np.asarray(d1),
                                   np.take_along_axis(
                                       d, order[:, :1], 1)[:, 0], rtol=1e-5)
        np.testing.assert_allclose(np.asarray(d2),
                                   np.take_along_axis(
                                       d, order[:, 1:2], 1)[:, 0], rtol=1e-5)


def test_hamerly_solver_parity_with_lloyd():
    """Assignment parity end to end: the hamerly backend's solve labels
    equal the dense (plain Lloyd assignment) labels."""
    x = jnp.asarray(make_blobs(600, 6, 6, seed=10, spread=4.0))
    c0 = kmeanspp_init(jax.random.PRNGKey(10), x, 6)
    cfg = KMeansConfig(k=6, max_iter=60)
    res_h = aa_kmeans(x, c0, cfg, backend=get_backend("hamerly"))
    res_d = aa_kmeans(x, c0, cfg, backend="dense")
    np.testing.assert_array_equal(np.asarray(res_h.labels),
                                  np.asarray(res_d.labels))
    ref = assign(x, res_h.centroids)
    np.testing.assert_array_equal(np.asarray(res_h.labels),
                                  np.asarray(ref.labels))


# ---------------------------------------------------------------------------
# Distributed: elastic (re-mesh) resume — subprocess, 8 virtual devices
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_distributed_elastic_resume(tmp_path):
    from test_distributed import _run
    _run(f"""
import jax, jax.numpy as jnp, numpy as np, os
from repro.core.distributed import make_distributed_kmeans
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import KMeansConfig, aa_kmeans
from repro.data.synthetic import make_blobs

d = {str(tmp_path)!r}
x = jnp.asarray(make_blobs(512, 8, 8, seed=11, spread=5.0))
c0 = kmeanspp_init(jax.random.PRNGKey(11), x, 8)
cfg = KMeansConfig(k=8, max_iter=100)

mesh8 = jax.make_mesh((8,), ("data",),
                      axis_types=(jax.sharding.AxisType.Auto,))
fit8 = make_distributed_kmeans(mesh8, cfg, checkpoint_every=1,
                               checkpoint_dir=d)
ref8 = fit8(x, c0)                      # uninterrupted (segments, ckpts)
snaps = sorted(p for p in os.listdir(d) if p.endswith(".npz"))
assert len(snaps) >= 2, snaps

# 1. same-mesh resume: bit-identical to the uninterrupted segmented run
res = make_distributed_kmeans(mesh8, cfg)(
    x, c0, resume_from=os.path.join(d, snaps[0]))
np.testing.assert_array_equal(
    np.float32(res.energy).view(np.uint32),
    np.float32(ref8.energy).view(np.uint32))
np.testing.assert_array_equal(np.asarray(res.labels),
                              np.asarray(ref8.labels))
assert int(res.n_iter) == int(ref8.n_iter)

# 2. elastic: the SAME artifact restores onto a different mesh geometry
#    and axes layout (2x2 over ("pod","data")); trajectory agrees with
#    the local oracle up to psum reduction order.
mesh22 = jax.make_mesh((2, 2), ("pod", "data"),
                       axis_types=(jax.sharding.AxisType.Auto,)*2)
fit22 = make_distributed_kmeans(mesh22, cfg, data_axes=("pod", "data"))
res22 = fit22(x, c0, resume_from=os.path.join(d, snaps[0]))
ref = aa_kmeans(x, c0, cfg)
assert bool(res22.converged)
np.testing.assert_allclose(float(res22.energy), float(ref.energy),
                           rtol=1e-5)
assert (np.asarray(res22.labels) == np.asarray(ref.labels)).mean() > 0.999
print("elastic resume OK")
""")
