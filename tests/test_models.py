"""Per-arch smoke tests (deliverable f) + model-level correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.launch import steps as ST
from repro.models import params as pr
from repro.models.config import SHAPES, ShapeSpec
from repro.models.model import Model, RunFlags, make_constrain
from repro.optim import adamw

MESH = jax.make_mesh((1, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
TRAIN = ShapeSpec("t", 32, 2, "train")
PREFILL = ShapeSpec("p", 32, 2, "prefill")
FLAGS = RunFlags(block_q=16, block_kv=16)


def _setup(arch):
    cfg = reduced_config(arch)
    model = Model(cfg, FLAGS)
    rules = ST.rules_for(MESH, cfg, TRAIN)
    constrain = make_constrain(MESH, rules)
    specs = model.param_specs()
    params = pr.init_tree(specs, jax.random.PRNGKey(0))
    return cfg, model, constrain, params


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    """One reduced-config forward/train step on CPU: shapes + no NaNs."""
    cfg, model, constrain, params = _setup(arch)
    batch = ST.real_batch(cfg, TRAIN, jax.random.PRNGKey(1))
    opt_cfg = adamw.AdamWConfig(warmup_steps=1, decay_steps=10)
    opt = adamw.init_state(params, opt_cfg)
    step = jax.jit(ST.make_train_step(model, opt_cfg, constrain))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    h, _ = model.forward(params, batch, constrain)
    assert h.shape == (2, 32, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    # params actually moved
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_prefill_decode(arch):
    cfg, model, constrain, params = _setup(arch)
    batch = ST.real_batch(cfg, PREFILL, jax.random.PRNGKey(1))
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, constrain, max_len=40))(
            params, batch)
    assert logits.shape == (2, 1, cfg.vocab)
    dstep = jax.jit(ST.make_decode_step(model, constrain))
    db = ST.real_batch(cfg, ShapeSpec("d", 32, 2, "decode"),
                       jax.random.PRNGKey(2))
    for _ in range(3):
        logits, cache = dstep(params, db, cache)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(np.asarray(cache["len"])[0]) == 35


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-2.7b",
                                  "h2o-danube-1.8b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits == teacher-forced forward logits."""
    cfg = reduced_config(arch)
    model = Model(cfg, FLAGS)
    rules = ST.rules_for(MESH, cfg, TRAIN)
    constrain = make_constrain(MESH, rules)
    params = pr.init_tree(model.param_specs(), jax.random.PRNGKey(0))

    s = 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, s), 0, cfg.vocab)
    h, _ = model.forward(params, {"tokens": toks}, constrain)
    from repro.models.model import logits_fn
    full_logits = logits_fn(params["head"], cfg, h, constrain)

    pre = s // 2
    logits_p, cache = model.prefill(params, {"tokens": toks[:, :pre]},
                                    constrain, max_len=s)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(full_logits[:, pre - 1], np.float32), rtol=2e-2,
        atol=2e-2)
    for t in range(pre, s):
        logits_d, cache = model.decode_step(
            params, {"token": toks[:, t]}, cache, constrain)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32), rtol=3e-2, atol=3e-2)


def test_swa_ring_cache_bounded():
    """Sliding-window arch: cache tensor never exceeds the window."""
    cfg = reduced_config("h2o-danube-1.8b")   # window 32
    model = Model(cfg, FLAGS)
    rules = ST.rules_for(MESH, cfg, TRAIN)
    constrain = make_constrain(MESH, rules)
    params = pr.init_tree(model.param_specs(), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    _, cache = model.prefill(params, {"tokens": toks}, constrain,
                             max_len=128)
    assert cache["k"].shape[2] == cfg.sliding_window == 32
    logits, cache = model.decode_step(
        params, {"token": toks[:, 0]}, cache, constrain)
    assert cache["k"].shape[2] == 32
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_moe_capacity_and_aux():
    cfg = reduced_config("olmoe-1b-7b")
    model = Model(cfg, FLAGS)
    rules = ST.rules_for(MESH, cfg, TRAIN)
    constrain = make_constrain(MESH, rules)
    params = pr.init_tree(model.param_specs(), jax.random.PRNGKey(0))
    batch = ST.real_batch(cfg, TRAIN, jax.random.PRNGKey(1))
    loss, aux = model.loss(params, batch, constrain)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(aux["moe_dropped"]) / cfg.n_layers <= 1.0
    assert float(aux["moe_lb_loss"]) > 0.0


def test_full_configs_match_assignment():
    """The exact numbers from the assignment block."""
    q = get_config("qwen1.5-110b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab, q.qkv_bias) == (80, 8192, 64, 8, 49152, 152064, True)
    mx = get_config("mixtral-8x7b")
    assert (mx.n_experts, mx.top_k, mx.sliding_window) == (8, 2, 4096)
    ol = get_config("olmoe-1b-7b")
    assert (ol.n_experts, ol.top_k, ol.d_ff) == (64, 8, 1024)
    mb = get_config("mamba2-2.7b")
    assert (mb.n_layers, mb.d_model, mb.ssm_state, mb.vocab) == \
        (64, 2560, 128, 50280)
    za = get_config("zamba2-2.7b")
    assert (za.n_layers, za.shared_attn_every, za.ssm_state) == (54, 6, 64)
    vl = get_config("llama-3.2-vision-11b")
    assert (vl.n_layers, vl.cross_attn_every, vl.vocab) == (40, 5, 128256)
    # parameter-count sanity vs the arch names (order of magnitude)
    assert 90e9 < q.n_params() < 130e9
    assert 6e9 < get_config("minitron-8b").n_params() < 10e9
    assert 0.1e9 < get_config("smollm-135m").n_params() < 0.2e9
    assert 40e9 < mx.n_params() < 50e9
    assert mx.n_active_params() < 15e9
    assert 2e9 < mb.n_params() < 3.5e9


def test_long500k_eligibility():
    from repro.launch.dryrun import cell_supported
    eligible = {a: cell_supported(a, "long_500k")[0] for a in ARCHS}
    assert eligible == {
        "musicgen-medium": False, "minitron-8b": False,
        "qwen1.5-110b": False, "smollm-135m": False,
        "h2o-danube-1.8b": True, "olmoe-1b-7b": False,
        "mixtral-8x7b": True, "mamba2-2.7b": True, "zamba2-2.7b": True,
        "llama-3.2-vision-11b": False}


def test_swa_decode_crosses_window_boundary():
    """Decode logits from the ring cache must match teacher-forced forward
    once the context exceeds the sliding window (ring overwrite path)."""
    cfg = reduced_config("h2o-danube-1.8b")      # window 32
    model = Model(cfg, FLAGS)
    rules = ST.rules_for(MESH, cfg, TRAIN)
    constrain = make_constrain(MESH, rules)
    params = pr.init_tree(model.param_specs(), jax.random.PRNGKey(0))

    s = 96                                        # 3x the window
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, s), 0, cfg.vocab)
    h, _ = model.forward(params, {"tokens": toks}, constrain)
    from repro.models.model import logits_fn
    full_logits = logits_fn(params["head"], cfg, h, constrain)

    pre = 64                                      # prefill 2x window
    _, cache = model.prefill(params, {"tokens": toks[:, :pre]}, constrain,
                             max_len=s)
    assert cache["k"].shape[2] == 32              # ring = window slots
    for t in range(pre, s):
        logits_d, cache = model.decode_step(
            params, {"token": toks[:, t]}, cache, constrain)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32), rtol=4e-2, atol=4e-2)
