"""Bound-invariant tests for the distance-elimination engine
(DESIGN.md §Bounds).

The bound backends are exact BECAUSE two invariants hold on every carry
the step hands back, no matter how the centroids moved in between:

    upper:  u_i >= d(x_i, c_{labels_i})
    lower:  l_{i,g} <= min_{j in group g} d(x_i, c_j)   (group family)
            l_i <= second-closest distance              (hamerly)

These tests drive step sequences through exactly the moves the AA solver
makes — Lloyd refinements, a large accepted Anderson jump, and an exact
revert to the pre-jump centroids — and after EVERY step assert (a) the
invariants on the post-step carry against brute-force distances at the
NEXT centroids (i.e. post-drift, where they must hold for the next step
to be exact), and (b) labels/min_sqdist against the dense oracle.

The fused_bounds kernel additionally gets direct kernel-level checks:
with trivial bounds it must reproduce the plain fused kernel bit-for-bit
with zero skipped tiles, and with carry-tightened bounds it must still
match the oracle while actually skipping.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis_compat import given, settings, st
from repro.core.backends import get_backend
from repro.core.backends.bounds import (extract_stats, group_layout,
                                        resolve_group_size)
from repro.core.lloyd import pairwise_sqdist

jax.config.update("jax_enable_x64", False)

# carry slack for f32 sqrt/drift round-off in the invariant assertions
ATOL = 1e-3

BOUND_BACKENDS = [
    ("hamerly", {}),
    ("elkan", {"group_size": 4}),
    ("yinyang", {}),
    ("fused_bounds", {"group_size": 8}),
]


def _problem(seed=0, n=257, d=7, k=13):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) * 3.0)
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    return x, c


def _oracle(x, c):
    d2 = pairwise_sqdist(x, c)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def _group_size_of(name, opts, k):
    gs = resolve_group_size(k, opts.get("group_size"),
                            "yinyang" if name == "yinyang" else "tile")
    if name == "fused_bounds":   # kernel rounds to the f32 sublane
        gs = gs + (-gs) % 8
    return gs


def _check_carry_invariants(name, opts, carry, x, c, k):
    """The post-step carry's bounds must hold at the centroids of the
    step that PRODUCED it (drift to any future centroids preserves them
    by the triangle inequality, which is what the step applies)."""
    labels, upper, lower = carry[0], carry[1], carry[2]
    d = np.sqrt(np.asarray(pairwise_sqdist(x, c), np.float64))
    lab = np.asarray(labels)
    u = np.asarray(upper, np.float64)
    d_a = d[np.arange(d.shape[0]), lab]
    assert (u >= d_a - ATOL).all(), f"{name}: upper bound violated"

    low = np.asarray(lower, np.float64)
    if low.ndim == 1:            # hamerly: bound on the second-closest
        masked = d.copy()
        masked[np.arange(d.shape[0]), lab] = np.inf
        d2nd = masked.min(axis=1)
        assert (low <= d2nd + ATOL).all(), \
            f"{name}: second-closest bound violated"
    else:                        # group family: inclusive per-group mins
        gs = _group_size_of(name, opts, k)
        g, gs = group_layout(k, gs)
        assert low.shape[1] == g
        pad = np.full((d.shape[0], g * gs - k), np.inf)
        gmin = np.concatenate([d, pad], axis=1) \
            .reshape(d.shape[0], g, gs).min(axis=2)
        assert (low <= gmin + ATOL).all(), \
            f"{name}: group lower bound violated"


def _aa_like_moves(x, c0, k, backend, rng):
    """Yields (c_before_step, c_after_step) per step: two Lloyd updates,
    an accepted-AA-like jump, an exact revert, then Lloyd to the end."""
    c = c0
    c_prejump = None
    for step_i in range(7):
        yield c
        if step_i == 2:
            c_prejump = c
            c = c + jnp.asarray(
                rng.normal(size=c.shape).astype(np.float32))   # AA jump
        elif step_i == 3:
            c = c_prejump                                      # revert
        else:
            lab, _ = _oracle(x, c)
            sums, cnt = backend.stats_fn(x, lab, k)
            c = jnp.where(cnt[:, None] > 0, sums / jnp.maximum(cnt, 1)[:, None],
                          c.astype(sums.dtype)).astype(c.dtype)


@pytest.mark.parametrize("name,opts",
                         BOUND_BACKENDS, ids=[b[0] for b in BOUND_BACKENDS])
def test_bound_invariants_across_jumps_and_reverts(name, opts):
    x, c0 = _problem()
    k = c0.shape[0]
    rng = np.random.default_rng(42)
    bk = get_backend(name, **opts)
    carry = bk.init_carry(x, c0, k)
    for c in _aa_like_moves(x, c0, k, bk, rng):
        res, carry = bk.step(x, c, k, carry)
        lab_o, mind_o = _oracle(x, c)
        assert np.array_equal(np.asarray(res.labels), np.asarray(lab_o))
        np.testing.assert_allclose(np.asarray(res.min_sqdist),
                                   np.asarray(mind_o), rtol=3e-5, atol=3e-5)
        _check_carry_invariants(name, opts, carry, x, c, k)


@pytest.mark.parametrize("name,opts",
                         BOUND_BACKENDS, ids=[b[0] for b in BOUND_BACKENDS])
def test_bound_stats_populated(name, opts):
    x, c0 = _problem(seed=5)
    k = c0.shape[0]
    bk = get_backend(name, **opts)
    carry = bk.init_carry(x, c0, k)
    st0 = extract_stats(carry)
    assert st0 is not None and float(st0.eliminated_frac) == 0.0
    _, carry = bk.step(x, c0, k, carry)
    _, carry = bk.step(x, c0, k, carry)   # stationary C: bounds are tight
    stats = extract_stats(carry)
    assert 0.0 <= float(stats.skipped_frac) <= 1.0
    assert 0.0 <= float(stats.eliminated_frac) <= 1.0


@given(seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_group_bound_labels_match_oracle_property(seed):
    """Randomised shapes/inits: elkan labels equal the oracle's after a
    step sequence that includes a jump and a revert."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(16, 200))
    d = int(rng.integers(2, 12))
    k = int(rng.integers(2, 24))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) * 2.0)
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    bk = get_backend("elkan", group_size=max(1, k // 3))
    carry = bk.init_carry(x, c, k)
    c_pre = c
    for step_i in range(4):
        res, carry = bk.step(x, c, k, carry)
        lab_o, _ = _oracle(x, c)
        assert np.array_equal(np.asarray(res.labels), np.asarray(lab_o))
        if step_i == 0:
            c_pre = c
            c = c + 0.5 * jnp.asarray(rng.normal(size=c.shape)
                                      .astype(np.float32))
        elif step_i == 1:
            c = c_pre
        else:
            c = bk.centroids_from_step(x, res, k, c)


# ---------------------------------------------------------------------------
# Kernel-level checks (fused_bounds vs fused)
# ---------------------------------------------------------------------------

def _kernel_problem(seed=3, n=300, d=5, k=20):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    return x, c


def test_trivial_bounds_reproduce_fused_kernel():
    """lower = 0 / upper = +inf (the init carry) must compute every tile:
    identical outputs to the bound-free kernel and skip fraction 0."""
    from repro.kernels.fused_lloyd import fused_lloyd_pallas

    x, c = _kernel_problem()
    n, k = x.shape[0], c.shape[0]
    tk = 8
    g = -(-k // tk)
    lab0 = jnp.zeros((n,), jnp.int32)
    lb = jnp.zeros((n, g), jnp.float32)
    ub = jnp.full((n,), jnp.inf, jnp.float32)
    base = fused_lloyd_pallas(x, c, tn=128, tk=tk, interpret=True)
    out = fused_lloyd_pallas(x, c, tn=128, tk=tk, interpret=True,
                             bounds=(lab0, lb, ub))
    labels, mind, sums, counts, energy, gmin, skip = out
    assert float(skip) == 0.0
    assert np.array_equal(np.asarray(labels), np.asarray(base[0]))
    for got, want in zip((mind, sums, counts, energy), base[1:]):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
    # the emitted group mins are exact when every tile was computed
    d2 = np.asarray(pairwise_sqdist(x, c))
    pad = np.full((n, g * tk - k), np.finfo(np.float32).max)
    gmin_ref = np.concatenate([d2, pad], 1).reshape(n, g, tk).min(axis=2)
    np.testing.assert_allclose(np.asarray(gmin), gmin_ref,
                               rtol=1e-4, atol=1e-4)


def test_tight_bounds_skip_tiles_and_stay_exact():
    """Carry-tightened bounds at unchanged C: exact labels/min-dist with
    a strictly positive skipped-tile fraction on ordered data."""
    from repro.kernels.fused_lloyd import fused_lloyd_pallas

    rng = np.random.default_rng(11)
    k, d, per, tk = 16, 8, 32, 8                 # n=512, 4 tiles of 128
    centers = rng.normal(size=(k, d)).astype(np.float32) * 15.0
    x = jnp.asarray(np.concatenate(
        [centers[j] + rng.normal(size=(per, d)).astype(np.float32)
         for j in range(k)]))
    c = jnp.asarray(centers)
    n, g = x.shape[0], -(-k // tk)

    lab0, mind0 = _oracle(x, c)
    d2 = np.asarray(pairwise_sqdist(x, c))
    pad = np.full((n, g * tk - k), np.inf)
    gmin = np.concatenate([d2, pad], 1).reshape(n, g, tk).min(axis=2)
    out = fused_lloyd_pallas(
        x, c, tn=128, tk=tk, interpret=True,
        bounds=(lab0, jnp.asarray(gmin, jnp.float32), mind0))
    labels, mind, _, _, _, _, skip = out
    assert float(skip) > 0.0
    assert np.array_equal(np.asarray(labels), np.asarray(lab0))
    np.testing.assert_allclose(np.asarray(mind), np.asarray(mind0),
                               rtol=1e-5, atol=1e-5)


def test_traced_driver_reports_bound_stats():
    from repro.core.kmeans import KMeansConfig, aa_kmeans_traced

    x, c0 = _problem(seed=9, n=200, d=5, k=8)
    cfg = KMeansConfig(k=8, max_iter=12)
    tr = aa_kmeans_traced(x, c0, cfg, backend="hamerly")
    assert len(tr.bound_stats) == len(tr.energies)
    for rec in tr.bound_stats:
        assert set(rec) == {"eliminated_frac", "skipped_frac"}
        assert 0.0 <= rec["eliminated_frac"] <= 1.0
    # elimination must ramp: the converged tail beats the cold start
    assert tr.bound_stats[-1]["eliminated_frac"] >= \
        tr.bound_stats[0]["eliminated_frac"]
    tr_dense = aa_kmeans_traced(x, c0, cfg, backend="dense")
    assert list(tr_dense.bound_stats) == []
