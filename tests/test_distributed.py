"""Multi-device tests — each spawns a subprocess that sets XLA_FLAGS before
importing jax (the main pytest process keeps the default 1 device)."""

import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 8, timeout: int = 500):
    import os
    env = {"XLA_FLAGS":
           f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root",
           # keep the virtual-device runs on the host platform: without
           # this a container with libtpu installed probes the GCP
           # metadata service (30 HTTP retries per variable ≈ minutes of
           # stall) before falling back to CPU.
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_distributed_kmeans_parity():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import make_distributed_kmeans, shard_dataset
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import KMeansConfig, aa_kmeans
from repro.data.synthetic import make_blobs

# separated clusters: psum reduction-order fp noise cannot flip steady-state
# assignments, but near convergence consecutive energies are nearly equal,
# so the accept test E^t < E^{t-1} (and with it the exact stopping step) is
# reduction-order sensitive.  (The seed's exact-n_iter/rtol-1e-5 assertions
# predate jax 0.4.x support and never executed on this stack: shard_map was
# unimportable, and the measured distributed-vs-single deviation here is
# 1.3e-5.)  The invariant: deterministic convergence to the same optimum,
# within a couple of endgame iterations.
mesh = jax.make_mesh((2, 4), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
x_host = make_blobs(8000, 8, 10, seed=3, spread=5.0)
x, _ = shard_dataset(x_host, mesh, ("pod", "data"))
c0 = kmeanspp_init(jax.random.PRNGKey(1), jnp.asarray(x_host), 10)
cfg = KMeansConfig(k=10, max_iter=500)
fit = make_distributed_kmeans(mesh, cfg, ("pod", "data"))
res = fit(x, c0)
resb = fit(x, c0)
ref = jax.jit(lambda a, b: aa_kmeans(a, b, cfg))(jnp.asarray(x_host), c0)
assert bool(res.converged) and bool(ref.converged)
np.testing.assert_allclose(float(res.energy), float(resb.energy), rtol=0)
assert int(res.n_iter) == int(resb.n_iter)          # deterministic
assert abs(int(res.n_iter) - int(ref.n_iter)) <= 2, \
    (int(res.n_iter), int(ref.n_iter))
assert abs(int(res.n_accepted) - int(ref.n_accepted)) <= 2
np.testing.assert_allclose(float(res.energy), float(ref.energy), rtol=5e-5)

# overlapping clusters: fp reduction order through the AA solve can pick a
# different (equally valid) local minimum — see DESIGN.md.  The distributed
# run must be deterministic, converged, and of sane quality.
x_host = make_blobs(8000, 8, 10, seed=3, spread=1.5)
x, _ = shard_dataset(x_host, mesh, ("pod", "data"))
c0 = kmeanspp_init(jax.random.PRNGKey(1), jnp.asarray(x_host), 10)
fit = make_distributed_kmeans(mesh, cfg, ("pod", "data"))
res = fit(x, c0)
res2 = fit(x, c0)
ref = jax.jit(lambda a, b: aa_kmeans(a, b, cfg))(jnp.asarray(x_host), c0)
assert bool(res.converged) and bool(ref.converged)
np.testing.assert_allclose(float(res.energy), float(res2.energy), rtol=0)
assert int(res.n_iter) == int(res2.n_iter)          # deterministic
assert abs(float(res.energy) - float(ref.energy)) / float(ref.energy) < 0.15
print("PARITY_OK")
""")
    assert "PARITY_OK" in out


@pytest.mark.slow
def test_distributed_backend_composition():
    """Acceptance: get_backend("pallas"/"fused") composed with distribute()
    matches the dense single-device solver's energy to rtol 1e-5 — "fused
    Pallas + sharded mesh" as a configuration, not a code path."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import make_distributed_kmeans, shard_dataset
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import KMeansConfig, aa_kmeans
from repro.data.synthetic import make_blobs

mesh = jax.make_mesh((2, 4), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
x_host = make_blobs(8000, 8, 10, seed=3, spread=1.5)
x, _ = shard_dataset(x_host, mesh, ("pod", "data"))
c0 = kmeanspp_init(jax.random.PRNGKey(1), jnp.asarray(x_host), 10)
cfg = KMeansConfig(k=10, max_iter=500)
ref = jax.jit(lambda a, b: aa_kmeans(a, b, cfg))(jnp.asarray(x_host), c0)
for name in ("pallas", "fused"):
    fit = make_distributed_kmeans(mesh, cfg, ("pod", "data"), backend=name)
    res = fit(x, c0)
    assert bool(res.converged), name
    np.testing.assert_allclose(float(res.energy), float(ref.energy),
                               rtol=1e-5, err_msg=name)
print("COMPOSE_OK")
""")
    assert "COMPOSE_OK" in out


@pytest.mark.slow
def test_distributed_batched_restarts():
    """Batched multi-restart solver on a (2,4) mesh: one program for R
    restarts, per-restart parity with the sequential distributed solver
    on separated data, and on-device best-of-R selection."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import (make_distributed_kmeans,
                                    make_distributed_kmeans_batched,
                                    shard_dataset)
from repro.core.init_schemes import batched_init
from repro.core.kmeans import KMeansConfig
from repro.data.synthetic import make_blobs

mesh = jax.make_mesh((2, 4), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
x_host = make_blobs(8000, 8, 10, seed=3, spread=5.0)
x, _ = shard_dataset(x_host, mesh, ("pod", "data"))
keys = jax.random.split(jax.random.PRNGKey(1), 4)
c0s = batched_init("kmeans++", keys, jnp.asarray(x_host), 10)
cfg = KMeansConfig(k=10, max_iter=500)

fit_b = make_distributed_kmeans_batched(mesh, cfg, ("pod", "data"))
res = fit_b(x, c0s)
assert res.labels.shape == (4, 8000)
fit_1 = make_distributed_kmeans(mesh, cfg, ("pod", "data"))
for r in range(4):
    ref = fit_1(x, c0s[r])
    assert int(res.n_iter[r]) == int(ref.n_iter), r
    np.testing.assert_allclose(float(res.energy[r]), float(ref.energy),
                               rtol=1e-4)

best = make_distributed_kmeans_batched(mesh, cfg, ("pod", "data"),
                                       pick_best=True)(x, c0s)
assert float(best.energy) == float(jnp.min(res.energy))
assert best.labels.shape == (8000,)
print("BATCHED_DIST_OK")
""")
    assert "BATCHED_DIST_OK" in out


@pytest.mark.slow
def test_distributed_minibatch_streaming():
    """Streaming mini-batch solver on a (2,4) mesh: chunk rows sharded,
    one stat-psum per chunk, deterministic, and within psum-reduction
    tolerance of the single-device streaming run on the same chunk
    schedule (same key => same chunk order on every shard)."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import (make_distributed_kmeans_minibatch,
                                    shard_dataset)
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import aa_kmeans_minibatch
from repro.core.minibatch import MiniBatchConfig
from repro.data.streaming import chunk_dataset, split_validation
from repro.data.synthetic import make_blobs

mesh = jax.make_mesh((2, 4), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
k = 8
x = jnp.asarray(make_blobs(16000, 8, k, seed=3, spread=5.0))
xt, xv = split_validation(x, 1024, jax.random.PRNGKey(7))
c0 = kmeanspp_init(jax.random.PRNGKey(1), x[:4096], k)
cfg = MiniBatchConfig(k=k, chunk_size=2048, epochs=3)
key = jax.random.PRNGKey(5)

dc_local = chunk_dataset(xt, 2048)
ref = jax.jit(lambda a, b, v, c, kk: aa_kmeans_minibatch(
    a, b, v, c, cfg, key=kk))(dc_local.chunks, dc_local.weights, xv, c0, key)

dc = chunk_dataset(xt, 2048, mesh=mesh, data_axes=("pod", "data"))
fit = make_distributed_kmeans_minibatch(mesh, cfg, ("pod", "data"))
res = fit(dc.chunks, dc.weights, xv, c0, key)
res2 = fit(dc.chunks, dc.weights, xv, c0, key)
assert int(res.n_steps) == int(ref.n_steps)
np.testing.assert_allclose(float(res.energy), float(res2.energy), rtol=0)
np.testing.assert_array_equal(np.asarray(res.centroids),
                              np.asarray(res2.centroids))   # deterministic
np.testing.assert_allclose(float(res.energy), float(ref.energy), rtol=1e-4)
np.testing.assert_allclose(np.asarray(res.centroids),
                           np.asarray(ref.centroids), rtol=1e-3, atol=1e-3)
assert abs(int(res.n_accepted) - int(ref.n_accepted)) <= 1

# fused-kernel backend composes with the streaming driver + mesh too
fit_f = make_distributed_kmeans_minibatch(mesh, cfg, ("pod", "data"),
                                          backend="fused")
res_f = fit_f(dc.chunks, dc.weights, xv, c0, key)
np.testing.assert_allclose(float(res_f.energy), float(ref.energy), rtol=1e-4)
print("MINIBATCH_DIST_OK")
""")
    assert "MINIBATCH_DIST_OK" in out


@pytest.mark.slow
def test_sharded_train_step_runs():
    """Reduced smollm train step on a (2,2,2) pod/data/model mesh with real
    execution (not just lowering): loss finite, params update, grads agree
    with the single-device step."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import reduced_config
from repro.launch import steps as ST
from repro.models import params as pr
from repro.models.config import ShapeSpec
from repro.models.model import Model, RunFlags, make_constrain
from repro.optim import adamw

cfg = reduced_config("smollm-135m")
shape = ShapeSpec("t", 32, 4, "train")
flags = RunFlags(block_q=16, block_kv=16)
opt_cfg = adamw.AdamWConfig(warmup_steps=1, decay_steps=10)

def run(mesh):
    model = Model(cfg, flags)
    rules = ST.rules_for(mesh, cfg, shape)
    constrain = make_constrain(mesh, rules)
    specs = model.param_specs()
    params = pr.init_tree(specs, jax.random.PRNGKey(0))
    params = jax.device_put(params, pr.sharding_tree(specs, mesh, rules))
    opt = adamw.init_state(params, opt_cfg)
    batch = ST.real_batch(cfg, shape, jax.random.PRNGKey(1))
    step = jax.jit(ST.make_train_step(model, opt_cfg, constrain))
    p2, o2, m = step(params, opt, batch)
    return float(m["loss"]), float(m["grad_norm"])

mesh8 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*3)
mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*2)
l8, g8 = run(mesh8)
l1, g1 = run(mesh1)
assert np.isfinite(l8)
np.testing.assert_allclose(l8, l1, rtol=2e-3)
np.testing.assert_allclose(g8, g1, rtol=2e-2)
print("SHARDED_TRAIN_OK", l8, l1)
""")
    assert "SHARDED_TRAIN_OK" in out


@pytest.mark.slow
def test_elastic_reshard_roundtrip():
    """Save a checkpoint sharded on an 8-device mesh, restore it onto a
    4-device mesh via reshard_restore, and verify values."""
    out = _run("""
import tempfile
from pathlib import Path
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import checkpointer as ckpt
from repro.checkpoint.reshard import reshard_restore
from repro.configs.registry import reduced_config
from repro.models import params as pr
from repro.models.model import Model, RunFlags
from repro.launch import steps as ST
from repro.models.config import ShapeSpec
from repro.sharding.rules import make_rules

cfg = reduced_config("smollm-135m")
model = Model(cfg, RunFlags())
specs = model.param_specs()

mesh8 = jax.make_mesh((2, 4), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,)*2)
rules8 = make_rules(mesh8)
params = pr.init_tree(specs, jax.random.PRNGKey(0))
params8 = jax.device_put(params, pr.sharding_tree(specs, mesh8, rules8))

with tempfile.TemporaryDirectory() as d:
    ckpt.save(Path(d) / "step_00000007", params8, step=7,
              extra={"mesh": "2x4"})
    devs = jax.devices()[:4]
    mesh4 = jax.sharding.Mesh(
        np.array(devs).reshape(2, 2), ("data", "model"))
    rules4 = make_rules(mesh4)
    restored, meta = reshard_restore(Path(d) / "step_00000007", specs,
                                     mesh4, rules4)
    assert meta["step"] == 7
    a = jax.tree.leaves(params)[0]
    b = jax.tree.leaves(restored)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
print("RESHARD_OK")
""")
    assert "RESHARD_OK" in out
