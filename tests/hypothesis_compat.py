"""Optional-`hypothesis` shim for the property-based tests.

`hypothesis` is a dev-only dependency; a missing install must not kill
collection of the deterministic cases.  Import `given` / `settings` / `st`
from here instead of from `hypothesis`: when the real package is present
they are re-exported unchanged; when it is absent the decorators turn each
property test into a skip (via pytest.importorskip, so the skip reason
names the missing package) while everything else in the module still
collects and runs.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(_fn):
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = _fn.__name__
            skipper.__doc__ = _fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Accepts any strategy constructor call; the value is never used."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
