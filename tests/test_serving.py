"""Serving-tier tests (DESIGN.md §Serving): closure-index recall and
persistence, padded micro-batch parity, hot reload without dropped
requests, legacy-artifact fallback."""

import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.api import AAKMeans, MiniBatchAAKMeans, NotFittedError
from repro.data.synthetic import make_blobs
from repro.serving import (KMeansServer, ServingModel, build_closure_index,
                           candidate_table, closure_assign, closure_sqdist,
                           serve_manifest)


@pytest.fixture(scope="module")
def fitted():
    x = make_blobs(4000, 8, 32, seed=0, spread=6.0)
    model = AAKMeans(n_clusters=32, seed=1).fit(x)
    return np.asarray(x), model


# -- closure index ----------------------------------------------------------

def test_closure_index_recall_bounds(fitted):
    """Full candidate lists reproduce the exact labels exactly; truncated
    lists stay above a generous recall bar on blob data; recall is
    monotone in the candidate count by construction (prefix closures)."""
    x, model = fitted
    exact = model.predict(x)
    model.build_serving_index(n_candidates=32)   # C = K: no approximation
    assert np.array_equal(model.predict(x, approx=True), exact)
    idx = model.closure_index_
    recalls = []
    for c in (4, 8, 16, 32):
        small = idx.shrink(c)
        labels, _ = closure_assign(jnp.asarray(x), model.centroids_,
                                   small.routers, small.candidates)
        recalls.append(float(np.mean(np.asarray(labels) == exact)))
    assert recalls == sorted(recalls)        # prefix lists: monotone
    assert recalls[1] >= 0.9                 # C=8 of K=32 on blobs
    # candidate lists are valid centroid indices, nearest-first
    cand = np.asarray(idx.candidates)
    assert cand.min() >= 0 and cand.max() < 32


def test_closure_assign_distances_exact_for_hits(fitted):
    """Where the approximate label agrees, the min_sqdist is the exact
    one — candidate restriction never perturbs the scanned distances."""
    x, model = fitted
    model.build_serving_index(n_candidates=16)
    idx = model.closure_index_
    labels, d2 = closure_assign(jnp.asarray(x[:256]), model.centroids_,
                                idx.routers, idx.candidates)
    full = np.asarray(model.transform(x[:256])) ** 2
    hits = np.asarray(labels) == np.argmin(full, axis=1)
    assert hits.mean() > 0.8
    np.testing.assert_allclose(np.asarray(d2)[hits],
                               full.min(axis=1)[hits], rtol=1e-4,
                               atol=1e-3)


def test_closure_transform_inf_off_candidates(fitted):
    x, model = fitted
    model.build_serving_index(n_candidates=8)
    t = model.transform(x[:64], approx=True)
    assert t.shape == (64, 32)
    finite = np.isfinite(t)
    assert (finite.sum(axis=1) <= 8).all() and (finite.sum(axis=1) >= 1).all()
    # argmin over the approximate transform == approximate predict
    assert np.array_equal(np.argmin(t, axis=1),
                          model.predict(x[:64], approx=True))


def test_index_roundtrips_through_save_load(fitted, tmp_path):
    x, model = fitted
    model.build_serving_index(n_candidates=16)
    p = model.save(tmp_path / "m.npz")
    loaded = AAKMeans.load(p)
    assert np.array_equal(np.asarray(loaded.closure_routers_),
                          np.asarray(model.closure_routers_))
    assert np.array_equal(np.asarray(loaded.closure_candidates_),
                          np.asarray(model.closure_candidates_))
    assert loaded.closure_candidates_.dtype == jnp.int32
    assert np.array_equal(loaded.predict(x[:500], approx=True),
                          model.predict(x[:500], approx=True))


def test_fit_builds_and_refit_invalidates_index():
    x = make_blobs(1200, 6, 8, seed=3, spread=5.0)
    m = AAKMeans(n_clusters=8, seed=0, serving_index=4).fit(x)
    assert m.closure_index_ is not None
    assert m.closure_index_.n_candidates == 4
    first = np.asarray(m.closure_routers_)
    m.fit(np.asarray(x) + 10.0)              # refit: index rebuilt, not stale
    assert m.closure_index_ is not None
    assert not np.allclose(np.asarray(m.closure_routers_), first)


def test_adaptive_index_counts_and_label_validity(fitted):
    """adaptive=True sizes each router's live prefix by its radius:
    counts land in [1, C], and every served label comes from the nearest
    router's VALID prefix — a masked column can never win the argmin."""
    x, model = fitted
    idx = build_closure_index(model.centroids_, n_candidates=8, n_groups=4,
                              adaptive=True)
    n_valid = np.asarray(idx.n_valid)
    c_max = idx.candidates.shape[1]
    assert n_valid.shape == (4,)
    assert n_valid.min() >= 1 and n_valid.max() <= c_max
    labels, d2 = closure_assign(jnp.asarray(x), model.centroids_,
                                idx.routers, idx.candidates,
                                n_valid=idx.n_valid)
    g = np.argmin(((x[:, None, :] - np.asarray(idx.routers)) ** 2
                   ).sum(-1), axis=1)
    cand = np.asarray(idx.candidates)
    ok = [labels[i] in cand[g[i], :n_valid[g[i]]] for i in range(len(x))]
    assert all(ok)
    assert np.isfinite(np.asarray(d2)).all()


def test_adaptive_shrink_clamps_and_uniform_contract_unchanged(fitted):
    x, model = fitted
    idx = build_closure_index(model.centroids_, n_candidates=8, n_groups=4,
                              adaptive=True)
    small = idx.shrink(3)
    assert small.candidates.shape[1] == 3
    assert np.asarray(small.n_valid).max() <= 3
    assert np.asarray(small.n_valid).min() >= 1
    # the shrunken adaptive index still serves in-prefix labels
    labels, _ = closure_assign(jnp.asarray(x[:256]), model.centroids_,
                               small.routers, small.candidates,
                               n_valid=small.n_valid)
    assert np.asarray(labels).min() >= 0 and np.asarray(labels).max() < 32
    # uniform indexes are untouched by the new field
    uni = build_closure_index(model.centroids_, n_candidates=8, n_groups=4)
    assert uni.n_valid is None and uni.shrink(3).n_valid is None


def test_adaptive_recall_tracks_uniform(fitted):
    """Adaptive pricing reallocates candidates, it does not give up
    recall wholesale: stay within a few points of the uniform index at
    the same C on blob data."""
    x, model = fitted
    exact = model.predict(x)
    uni = build_closure_index(model.centroids_, n_candidates=12, n_groups=4)
    ada = build_closure_index(model.centroids_, n_candidates=12, n_groups=4,
                              adaptive=True)
    ru = np.mean(np.asarray(closure_assign(
        jnp.asarray(x), model.centroids_, uni.routers,
        uni.candidates)[0]) == exact)
    ra = np.mean(np.asarray(closure_assign(
        jnp.asarray(x), model.centroids_, ada.routers, ada.candidates,
        n_valid=ada.n_valid)[0]) == exact)
    assert ra >= ru - 0.1
    assert ra >= 0.7


def test_adaptive_sqdist_masked_columns_filled(fitted):
    x, model = fitted
    ada = build_closure_index(model.centroids_, n_candidates=8, n_groups=4,
                              adaptive=True)
    t = closure_sqdist(jnp.asarray(x[:64]), model.centroids_, ada.routers,
                       ada.candidates, n_valid=ada.n_valid)
    t = np.asarray(t)
    finite = np.isfinite(t)
    assert (finite.sum(axis=1) >= 1).all()
    assert (finite.sum(axis=1) <= ada.candidates.shape[1]).all()
    # argmin agreement with adaptive closure_assign
    labels, _ = closure_assign(jnp.asarray(x[:64]), model.centroids_,
                               ada.routers, ada.candidates,
                               n_valid=ada.n_valid)
    assert np.array_equal(np.argmin(t, axis=1), np.asarray(labels))


def test_legacy_artifact_without_index_falls_back(fitted, tmp_path):
    """approx=True on an index-less (legacy) artifact serves the exact
    full scan — no crash, no silent wrong answers."""
    x, model = fitted
    fresh = AAKMeans(n_clusters=32, seed=1).fit(x)   # no index built
    p = fresh.save(tmp_path / "legacy.npz")
    loaded = AAKMeans.load(p)
    assert loaded.closure_index_ is None
    assert np.array_equal(loaded.predict(x[:300], approx=True),
                          loaded.predict(x[:300]))


def test_minibatch_estimator_serving_index(tmp_path):
    x = make_blobs(3000, 6, 10, seed=5, spread=5.0)
    m = MiniBatchAAKMeans(n_clusters=10, chunk_size=512, epochs=2,
                          seed=0).fit(x)
    m.build_serving_index(n_candidates=10)
    exact = m.predict(x[:400])
    assert np.array_equal(m.predict(x[:400], approx=True), exact)
    loaded = MiniBatchAAKMeans.load(m.save(tmp_path / "mb.npz"))
    assert loaded.closure_index_ is not None
    assert np.array_equal(loaded.predict(x[:400], approx=True), exact)


# -- serving model / server -------------------------------------------------

def test_serving_model_requires_fitted():
    with pytest.raises(NotFittedError):
        ServingModel.from_estimator(AAKMeans(n_clusters=3))


def test_server_padded_microbatch_parity(fitted):
    """Every request size — including ones larger than the batch size and
    ones that land mid-batch — returns exactly the estimator's labels."""
    x, model = fitted
    model.build_serving_index(n_candidates=16)
    want = model.predict(x, approx=True)
    with KMeansServer(model, batch_size=64, flush_ms=1.0) as srv:
        sizes = [1, 7, 63, 64, 65, 200, 17]
        futs, off = [], 0
        for s in sizes:
            futs.append((off, s, srv.submit(x[off:off + s])))
            off += s
        for start, s, f in futs:
            got = f.result(timeout=30)
            assert got.dtype == np.int32 and got.shape == (s,)
            assert np.array_equal(got, want[start:start + s])
        assert srv.n_requests == len(sizes)
    # empty request resolves immediately (no queue round-trip)
    srv2 = KMeansServer(model, batch_size=8).start()
    try:
        assert srv2.submit(x[:0]).result(timeout=5).shape == (0,)
    finally:
        srv2.stop()


def test_server_exact_fallback_without_index(fitted):
    x, _ = fitted
    model = AAKMeans(n_clusters=32, seed=1).fit(x)   # no index
    with KMeansServer(model, batch_size=32) as srv:
        assert not srv._model.approx
        assert np.array_equal(srv.predict(x[:100]), model.predict(x[:100]))


def test_server_builds_index_for_legacy_source(fitted, tmp_path):
    """n_candidates= lets the server attach a closure index to an
    index-less artifact at load time."""
    x, model = fitted
    fresh = AAKMeans(n_clusters=32, seed=1).fit(x)
    p = fresh.save(tmp_path / "legacy.npz")
    with KMeansServer(p, batch_size=32, n_candidates=32) as srv:
        assert srv._model.approx
        assert np.array_equal(srv.predict(x[:100]), fresh.predict(x[:100]))


def test_server_hot_reload_no_dropped_requests(tmp_path):
    """Swap the artifact under continuous traffic: the watcher picks the
    new version up between batches, every request in flight is answered,
    and post-swap answers match the new model."""
    x = make_blobs(2000, 6, 8, seed=7, spread=6.0)
    m1 = AAKMeans(n_clusters=8, seed=0, serving_index=8).fit(x)
    p = tmp_path / "model.npz"
    m1.save(p)
    errors, results = [], []
    stop = threading.Event()
    with KMeansServer(p, batch_size=32, poll_s=0.02,
                      flush_ms=0.5) as srv:
        v1 = srv.version

        def traffic():
            i = 0
            while not stop.is_set():
                try:
                    results.append(srv.predict(np.asarray(
                        x[i % 1500:i % 1500 + 11]), timeout=30))
                except Exception as e:     # noqa: BLE001 — test records
                    errors.append(e)
                i += 17
        t = threading.Thread(target=traffic)
        t.start()
        try:
            time.sleep(0.1)
            m2 = AAKMeans(n_clusters=8, seed=3, init="random",
                          serving_index=8).fit(np.asarray(x) * -1.0 + 5.0)
            m2.save(p)
            deadline = time.time() + 10
            while srv.reload_count == 0 and time.time() < deadline:
                time.sleep(0.02)
        finally:
            stop.set()
            t.join()
        assert srv.reload_count >= 1 and srv.version != v1
        assert not errors
        assert all(r.shape == (11,) for r in results)
        # post-swap, the server answers with the NEW model
        got = srv.predict(np.asarray(x[:128]))
        assert np.array_equal(got, m2.predict(x[:128], approx=True))
        manifest = serve_manifest(srv)
        assert '"reload_count": 1' in manifest


def test_server_reload_from_manifest_dir(fitted, tmp_path):
    """Directory sources resolve through the PR-7 writer manifest: the
    server follows ``latest`` as new estimator artifacts land."""
    import json
    x, model = fitted
    d = tmp_path / "run"
    d.mkdir()
    model.build_serving_index(n_candidates=16)
    model.save(d / "v1.npz")
    (d / "manifest.json").write_text(json.dumps(
        {"schema": "ckpt_manifest/v1", "latest": "v1.npz",
         "snapshots": [{"file": "v1.npz", "step": 1}]}))
    with KMeansServer(d, batch_size=32, poll_s=0.02) as srv:
        want = model.predict(x[:64], approx=True)
        assert np.array_equal(srv.predict(x[:64]), want)
        m2 = AAKMeans(n_clusters=32, seed=9, init="random",
                      serving_index=16).fit(np.asarray(x) + 2.0)
        m2.save(d / "v2.npz")
        (d / "manifest.json").write_text(json.dumps(
            {"schema": "ckpt_manifest/v1", "latest": "v2.npz",
             "snapshots": [{"file": "v2.npz", "step": 2}]}))
        deadline = time.time() + 10
        while srv.reload_count == 0 and time.time() < deadline:
            time.sleep(0.02)
        assert srv.reload_count >= 1
        assert np.array_equal(srv.predict(x[:64]),
                              m2.predict(x[:64], approx=True))


def test_server_metrics_per_batch(fitted):
    from repro.runtime.metrics import CollectMetrics
    x, model = fitted
    sink = CollectMetrics()
    with KMeansServer(model, batch_size=16, metrics=sink) as srv:
        srv.predict(x[:40])     # 40 rows -> 16+16+8: one padded batch
    steps = dict(sink.records)
    assert steps, "no batch metrics emitted"
    rec = next(iter(steps.values()))
    assert {"serve_latency_s", "queue_depth", "batch_rows",
            "padded_rows"} <= set(rec)
    assert sum(r["batch_rows"] for _, r in sink.records) == 40
    assert sum(r["padded_rows"] for _, r in sink.records) == 8


# -- transform serving + bucketed closure (DESIGN.md §Locality) -------------

def test_closure_bucketed_parity(fitted):
    """Router-bucketed candidate scanning (rows counting-sorted by router
    id for contiguous table reads) is bit-identical to the plain path —
    all per-row math is row-local."""
    x, model = fitted
    idx = model.closure_index_
    c = model.centroids_
    tab = candidate_table(c, idx.candidates)
    xq = jnp.asarray(x[:512])
    l0, d0 = closure_assign(xq, c, idx.routers, idx.candidates, tab)
    l1, d1 = closure_assign(xq, c, idx.routers, idx.candidates, tab,
                            bucketed=True)
    assert np.array_equal(np.asarray(l0), np.asarray(l1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))
    s0 = closure_sqdist(xq, c, idx.routers, idx.candidates, tab)
    s1 = closure_sqdist(xq, c, idx.routers, idx.candidates, tab,
                        bucketed=True)
    assert np.array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("approx", [False, True])
def test_server_transform_micro_batched(fitted, approx):
    """`transform` (distance rows) rides the same padded micro-batch path
    as labels: block-exact vs the model's direct runner, argmin-consistent
    with predict, mixed-op batches served, empty requests short-circuit."""
    x, model = fitted
    with KMeansServer(model, batch_size=64, approx=approx,
                      flush_ms=1.0) as srv:
        q = x[:150]
        lab = srv.predict(q)
        dist = srv.transform(q)
        k = model.centroids_.shape[0]
        assert dist.shape == (150, k)
        # block-wise parity with the direct model runner (same padding)
        direct = np.empty_like(dist)
        for i in range(0, 150, 64):
            xb = q[i:i + 64]
            m = xb.shape[0]
            if m < 64:
                xb = np.concatenate([xb, np.repeat(xb[-1:], 64 - m,
                                                   axis=0)])
            direct[i:i + m] = srv._model.dists(xb)[:m]
        assert np.array_equal(dist, direct)
        # a transform row's argmin IS the served label (closure fills
        # non-candidate columns with +inf, so this holds on both paths)
        assert np.array_equal(np.argmin(dist, axis=1).astype(np.int32),
                              lab)
        # mixed ops inside one flush window
        f1 = srv.submit(q[:50], op="labels")
        f2 = srv.submit_transform(q[50:120])
        f3 = srv.submit(q[120:150])
        assert np.array_equal(f1.result(30), lab[:50])
        assert np.array_equal(f2.result(30), dist[50:120])
        assert np.array_equal(f3.result(30), lab[120:150])
        # empty requests resolve without a queue round-trip, op-shaped
        assert srv.submit(q[:0]).result(5).shape == (0,)
        assert srv.submit_transform(q[:0]).result(5).shape == (0, k)
        with pytest.raises(ValueError, match="op"):
            srv.submit(q[:4], op="energies")
