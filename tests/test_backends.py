"""Backend-engine tests (DESIGN.md §Backends).

1. step() parity: every registered backend agrees with the dense oracle on
   labels, min-dist, cluster stats, energy and the resulting G(C).
2. Solver parity: aa_kmeans driven by each backend reaches the dense
   solver's trajectory (same iterations, energy to tolerance).
3. Pass-count regression: the driver performs exactly ONE
   assignment-equivalent pass over X per accepted iteration (counted on an
   instrumented backend through jit/while_loop/cond), two per revert.
4. distribute() combinator: the psum wrapping is semantics-preserving for
   any local backend (single-device shard_map check; the multi-device
   version lives in test_distributed).
5. Legacy LloydOps injection still works through the deprecation shim.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import backends as B
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import KMeansConfig, aa_kmeans, aa_kmeans_traced
from repro.data.synthetic import make_blobs

K = 7
# options that force the interesting code path at this fixture size
BACKEND_OPTS = {"blocked": dict(block_n=300)}


def _make(name):
    return B.get_backend(name, **BACKEND_OPTS.get(name, {}))


@pytest.fixture(scope="module")
def fixture():
    x = jnp.asarray(make_blobs(1200, 8, K, seed=0, spread=1.5))
    c0 = kmeanspp_init(jax.random.PRNGKey(0), x, K)
    return x, c0


def _step(backend, x, c):
    res, _ = backend.step(x, c, K, backend.init_carry(x, c, K))
    return res


@pytest.mark.parametrize("name", B.backend_names())
def test_step_parity_with_dense(name, fixture):
    x, c = fixture
    ref = _step(_make("dense"), x, c)
    res = _step(_make(name), x, c)
    assert (np.asarray(res.labels) == np.asarray(ref.labels)).all()
    np.testing.assert_allclose(res.min_sqdist, ref.min_sqdist,
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(res.sums, ref.sums, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(res.counts, ref.counts, rtol=0, atol=1e-6)
    np.testing.assert_allclose(float(res.energy), float(ref.energy),
                               rtol=1e-4)
    # the derived fixed-point image G(c) agrees too
    g_ref = _make("dense").centroids_from_step(x, ref, K, c)
    g_res = _make(name).centroids_from_step(x, res, K, c)
    np.testing.assert_allclose(g_res, g_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", B.backend_names())
def test_solver_parity_with_dense(name, fixture):
    x, c0 = fixture
    cfg = KMeansConfig(k=K, max_iter=300)
    ref = aa_kmeans(x, c0, cfg)
    res = aa_kmeans(x, c0, cfg, backend=_make(name))
    assert bool(res.converged)
    assert int(res.n_iter) == int(ref.n_iter)
    np.testing.assert_allclose(float(res.energy), float(ref.energy),
                               rtol=1e-5)


@pytest.mark.parametrize("name", ["fused", "dense"])
def test_one_pass_per_accepted_iteration(name, fixture):
    """Regression for the Sec-2.1 cost model: counting *executed* steps
    (passes over X) through jit + lax.while_loop + lax.cond, the solver
    spends 1 pass on the init G(C^0), 1 per loop body, and 1 extra only
    when a body reverts — i.e. exactly one pass per accepted iteration."""
    x, c0 = fixture
    passes = []
    backend = B.instrument(_make(name), lambda: passes.append(1))
    cfg = KMeansConfig(k=K, max_iter=300)
    res = jax.jit(
        lambda a, b: aa_kmeans(a, b, cfg, backend=backend))(x, c0)
    jax.block_until_ready(res.centroids)
    jax.effects_barrier()
    assert bool(res.converged)
    t, n_acc = int(res.n_iter), int(res.n_accepted)
    # init (1) + full bodies (t-1) + one extra per reject (t-1-n_acc)
    # + the convergence-detect body (1)  ==  2t - n_acc
    assert len(passes) == 2 * t - n_acc, (len(passes), t, n_acc)


def test_pass_count_matches_acceptance_trace(fixture):
    """Cross-check against the instrumented python-loop driver: each
    recorded iteration costs 1 pass when accepted, 2 when reverted."""
    x, c0 = fixture
    passes = []
    backend = B.instrument(_make("dense"), lambda: passes.append(1))
    cfg = KMeansConfig(k=K, max_iter=300)
    tr = aa_kmeans_traced(x, c0, cfg, backend=backend)
    jax.effects_barrier()
    assert bool(tr.result.converged)
    expected = 1 + sum(1 if a else 2 for a in tr.accepted) + 1
    assert len(passes) == expected, (len(passes), tr.accepted)


@pytest.mark.parametrize("name", B.backend_names())
def test_distribute_combinator_single_device(name, fixture):
    """distribute(backend, axes) is semantics-preserving: under a 1-device
    shard_map the psum-wrapped step must equal the local step exactly."""
    x, c = fixture
    backend = _make(name)
    dist = B.distribute(backend, ("data",))
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def run(xx, cc):
        res, _ = dist.step(xx, cc, K, dist.init_carry(xx, cc, K))
        return res

    res = compat.shard_map(run, mesh=mesh, in_specs=(P("data"), P()),
                           out_specs=B.StepResult(
                               labels=P("data"), min_sqdist=P("data"),
                               sums=P(), counts=P(), energy=P()))(x, c)
    ref = _step(backend, x, c)
    assert (np.asarray(res.labels) == np.asarray(ref.labels)).all()
    np.testing.assert_allclose(res.sums, ref.sums, rtol=0, atol=0)
    np.testing.assert_allclose(float(res.energy), float(ref.energy), rtol=0)


def test_distributed_energy_op_reduces_once():
    """Regression: the derived energy() op of a distribute()-wrapped
    backend must psum exactly once — it previously composed a psum'd
    energy_fn with a psum reduce_scalar, inflating by the device count.
    A 1-device mesh cannot observe the inflation (psum is identity), so
    this only bites under test.sh's 8 virtual devices."""
    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >= 2 devices to observe a double reduction")
    x = jnp.asarray(make_blobs(400, 4, K, seed=1, spread=3.0))
    c = kmeanspp_init(jax.random.PRNGKey(0), x, K)
    dense = _make("dense")
    labels = dense.assign(x, c).labels
    e_ref = float(dense.energy(x, c, labels))
    dist = B.distribute(dense, ("data",))
    mesh = jax.make_mesh((n_dev,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    e = compat.shard_map(lambda xx, cc, ll: dist.energy(xx, cc, ll),
                         mesh=mesh, in_specs=(P("data"), P(), P("data")),
                         out_specs=P())(x, c, labels)
    np.testing.assert_allclose(float(e), e_ref, rtol=1e-5)


def test_lloyd_ops_adapter_is_memoised():
    from repro.core.lloyd import LloydOps
    ops = LloydOps()
    assert B.from_lloyd_ops(ops) is B.from_lloyd_ops(ops)


def test_resolve_backend_accepts_lloyd_ops_and_rejects_junk():
    from repro.core.kmeans import resolve_backend
    from repro.core.lloyd import LloydOps
    assert resolve_backend(LloydOps()).name == "lloyd-ops-shim"
    with pytest.raises(TypeError):
        resolve_backend(object())


def test_reregistering_backend_invalidates_cache():
    marker = _make("dense")
    B.register_backend("tmp-test-backend", lambda: marker)
    assert B.get_backend("tmp-test-backend") is marker
    other = _make("hamerly")
    B.register_backend("tmp-test-backend", lambda: other)
    try:
        assert B.get_backend("tmp-test-backend") is other
    finally:
        from repro.core.backends import base as _base
        _base._REGISTRY.pop("tmp-test-backend", None)
        _base._INSTANCES.pop(("tmp-test-backend", ()), None)


def test_legacy_lloyd_ops_shim(fixture):
    from repro.kernels.ops import pallas_lloyd_ops
    x, c0 = fixture
    cfg = KMeansConfig(k=K, max_iter=300)
    ref = aa_kmeans(x, c0, cfg)
    res = aa_kmeans(x, c0, cfg, ops=pallas_lloyd_ops())
    assert int(res.n_iter) == int(ref.n_iter)
    np.testing.assert_allclose(float(res.energy), float(ref.energy),
                               rtol=1e-5)


def test_precision_policy(fixture):
    """bf16 compute / f32 accumulate: runs end-to-end and lands on the
    same clustering quality (exactness is not expected at bf16)."""
    x, c0 = fixture
    prec = B.Precision(compute=jnp.bfloat16)
    cfg = KMeansConfig(k=K, max_iter=300)
    ref = aa_kmeans(x, c0, cfg)
    res = aa_kmeans(x, c0, cfg,
                    backend=B.get_backend("dense", precision=prec))
    assert bool(jnp.isfinite(res.energy))
    assert abs(float(res.energy) - float(ref.energy)) / float(ref.energy) \
        < 0.02


def test_get_backend_registry():
    assert set(B.backend_names()) >= {"dense", "blocked", "pallas", "fused",
                                      "hamerly"}
    assert B.get_backend("dense") is B.get_backend("dense")  # cached
    with pytest.raises(KeyError):
        B.get_backend("no-such-backend")


def test_blocked_backend_handles_non_divisible_n(fixture):
    """Regression: block_n not dividing N must still take the row-blocked
    path (padded), not silently materialise the full (N, K) matrix — and
    the padded rows must not perturb the results."""
    x, c = fixture                    # N = 1200, not divisible by 500
    ref = _step(_make("dense"), x, c)
    res = _step(B.get_backend("blocked", block_n=500), x, c)
    assert (np.asarray(res.labels) == np.asarray(ref.labels)).all()
    np.testing.assert_allclose(res.sums, ref.sums, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(res.energy), float(ref.energy),
                               rtol=1e-5)


def test_resolve_backend_honours_block_n():
    from repro.core.kmeans import resolve_backend
    cfg = KMeansConfig(k=K, block_n=300)
    assert resolve_backend("blocked", cfg=cfg).name == "blocked300"
    assert resolve_backend("dense", cfg=cfg).name == "blocked300"
    assert resolve_backend(None, cfg=cfg).name == "blocked300"
    assert resolve_backend(None, block_n=600).name == "blocked600"
    assert resolve_backend("fused", cfg=cfg).name == "fused"  # not promoted


def test_distribute_rejects_double_wrapping():
    dist = B.distribute(_make("dense"), ("data",))
    assert dist.axes == ("data",)
    with pytest.raises(ValueError):
        B.distribute(dist, ("data",))


def test_make_distributed_accepts_prewrapped_backend():
    """An already distribute()-wrapped backend is used as-is (no double
    psum); mismatched axes are rejected."""
    from repro.core.distributed import make_distributed_kmeans
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = KMeansConfig(k=K, max_iter=50)
    wrapped = B.distribute(_make("dense"), ("data",))
    x = jnp.asarray(make_blobs(400, 4, K, seed=1, spread=3.0))
    c0 = kmeanspp_init(jax.random.PRNGKey(0), x, K)
    res = make_distributed_kmeans(mesh, cfg, ("data",), backend=wrapped)(x, c0)
    ref = aa_kmeans(x, c0, cfg)
    np.testing.assert_allclose(float(res.energy), float(ref.energy), rtol=0)
    with pytest.raises(ValueError):
        make_distributed_kmeans(mesh, cfg, ("pod", "data"), backend=wrapped)
