"""Perf-harness smoke (slow tier): the kernel benchmark must run end to
end in interpret mode and emit a well-formed BENCH_kernels.json — the
machine-readable seed of the perf trajectory (ISSUE 4 acceptance)."""

import json
import sys
from pathlib import Path

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.perf]

BENCH_DIR = Path(__file__).resolve().parents[1] / "benchmarks"


def test_kernels_bench_emits_json(tmp_path):
    sys.path.insert(0, str(BENCH_DIR.parent))
    try:
        from benchmarks import kernels_bench
    finally:
        sys.path.pop(0)
    out = tmp_path / "BENCH_kernels.json"
    records = kernels_bench.main(["--smoke", "--json", str(out)])
    assert out.exists()
    payload = json.loads(out.read_text())
    assert payload["schema"] == "kernels_bench/v4"
    assert payload["records"] == records and records
    variants = {r["variant"] for r in records}
    # analytic roofline rows for every variant + the real Pallas kernels
    # driven in interpret mode
    assert {"split", "fused", "fused_v1", "pallas.fused",
            "pallas.assignment", "pallas.update",
            "pallas.fused_bounds", "solver.fused_bounds_traced"} <= variants
    for r in records:
        # v3 tile-skip + v4 layout columns exist on EVERY record (None
        # outside the bounds arms)
        assert "skipped_tile_frac" in r and "phase" in r and "layout" in r
        if r["variant"].startswith("solver."):
            continue                       # end-to-end rows: no roofline
        assert r["x_passes_per_iter"] >= 1.0
        assert r["bytes_per_iter"] > 0 and r["flops_per_iter"] > 0
    # the v2 fused kernel reads X once; the split path twice — and the
    # bounds engine never adds an X pass (skipping removes C re-streams)
    by_var = {}
    for r in records:
        by_var.setdefault(r["variant"], r)
    assert by_var["fused"]["x_passes_per_iter"] == 1.0
    assert by_var["split"]["x_passes_per_iter"] == 2.0
    assert by_var["pallas.fused_bounds"]["x_passes_per_iter"] == 1.0
    # v4 layout matrix: each bounds arm reports both phases; skip is 0 on
    # the bound-free first step everywhere, and converged skip depends on
    # the row layout — majority skip when rows are cluster-ordered (or
    # reordered on the fly by the locality engine), ~0 when interleaved
    cells = {(r["layout"], r["phase"]): r for r in records
             if r["variant"] == "pallas.fused_bounds"}
    layouts = ("ordered", "interleaved", "interleaved+reorder")
    assert set(cells) == {(lay, ph) for lay in layouts
                          for ph in ("early", "converged")}
    for lay in layouts:
        assert cells[(lay, "early")]["skipped_tile_frac"] == 0.0
    assert cells[("ordered", "converged")]["skipped_tile_frac"] > 0.5
    assert cells[("interleaved", "converged")]["skipped_tile_frac"] < 0.05
    assert cells[("interleaved+reorder", "converged")][
        "skipped_tile_frac"] >= 0.5
    # end-to-end traced rows: one per arm, wall time measured
    solver = [r for r in records
              if r["variant"] == "solver.fused_bounds_traced"]
    assert sorted(r["layout"] for r in solver) == \
        ["interleaved", "interleaved+reorder"]
    assert all(r["wall_us"] > 0 and r["n_iters"] > 0 for r in solver)
    # interpret-mode Pallas rows actually measured a wall time
    assert all(r["wall_us"] is not None for r in records
               if r["wall_path"] == "pallas_interpret")


def test_kernels_bench_records_deterministic(tmp_path):
    """Two --smoke runs agree on everything but wall clocks: fixed seeds,
    deterministic record order, sorted JSON keys (ISSUE 6 acceptance)."""
    sys.path.insert(0, str(BENCH_DIR.parent))
    try:
        from benchmarks import kernels_bench
    finally:
        sys.path.pop(0)
    runs = [kernels_bench.main(["--smoke", "--json",
                                str(tmp_path / f"b{i}.json")])
            for i in range(2)]

    def strip(recs):
        return [{k: v for k, v in r.items() if k != "wall_us"}
                for r in recs]

    assert strip(runs[0]) == strip(runs[1])
    texts = [(tmp_path / f"b{i}.json").read_text() for i in range(2)]
    keys = [list(json.loads(t)["records"][0]) for t in texts]
    assert keys[0] == sorted(keys[0])     # sort_keys=True in the emitter


def test_checkpoint_bench_emits_json(tmp_path):
    """`benchmarks/run.py --checkpoint-every` block: the segmentation-
    overhead benchmark runs (with a real snapshot + resume roundtrip
    inside) and reports the overheads in BENCH_checkpoint.json."""
    sys.path.insert(0, str(BENCH_DIR.parent))
    try:
        from benchmarks import checkpoint_bench
    finally:
        sys.path.pop(0)
    out = tmp_path / "BENCH_checkpoint.json"
    rec = checkpoint_bench.main(
        ["--smoke", "--checkpoint-every", "5", "--json", str(out)])
    payload = json.loads(out.read_text())
    assert payload["schema"] == "checkpoint_bench/v2"
    assert payload["record"] == rec
    assert rec["checkpoint_every"] == 5 and rec["snapshots"] >= 1
    for key in ("t_monolithic_s", "t_segmented_s", "t_checkpointed_s",
                "t_checkpointed_async_s"):
        assert rec[key] > 0
    # v2 reports the async writer's per-boundary cost next to sync's:
    # device_get + queue handoff must beat device_get + inline npz write
    assert rec["sync_boundary_us"] > 0 and rec["async_boundary_us"] > 0
    assert rec["async_to_sync_overhead_ratio"] < 1.0


def test_hierarchy_bench_emits_json(tmp_path):
    """`benchmarks/hierarchy_bench.py --smoke`: the flat-vs-hierarchical
    comparison runs end to end and BENCH_hierarchy.json is well formed
    (ISSUE 10 wires the full run into run.py)."""
    sys.path.insert(0, str(BENCH_DIR.parent))
    try:
        from benchmarks import hierarchy_bench
    finally:
        sys.path.pop(0)
    out = tmp_path / "BENCH_hierarchy.json"
    records = hierarchy_bench.main(["--smoke", "--json", str(out)])
    payload = json.loads(out.read_text())
    assert payload["schema"] == "hierarchy_bench/v1"
    assert payload["smoke"] is True and payload["records"] == records
    assert records
    for r in records:
        assert {"case", "k", "n", "d", "n_groups", "k_sub", "hier_wall_s",
                "hier_energy", "n_rounds", "flat_wall_s", "flat_energy",
                "wall_ratio", "energy_ratio"} <= set(r)
        assert r["hier_wall_s"] > 0 and r["hier_energy"] > 0
        assert r["k"] == r["n_groups"] * r["k_sub"]
        if r["flat_wall_s"] is not None:
            assert r["wall_ratio"] > 0 and r["energy_ratio"] > 0


def test_hierarchy_bench_committed_pin():
    """The committed BENCH_hierarchy.json pins the ISSUE 10 acceptance:
    at K=65536 the hierarchical engine beats the flat batched solve on
    wall clock without giving up energy."""
    path = BENCH_DIR.parent / "BENCH_hierarchy.json"
    payload = json.loads(path.read_text())
    assert payload["schema"] == "hierarchy_bench/v1"
    by_k = {r["k"]: r for r in payload["records"]}
    big = by_k[65536]
    assert big["wall_ratio"] < 1.0          # hier strictly faster
    assert big["energy_ratio"] <= 1.05      # <= 5% energy regression
    # the million-cluster arm exists and solved hierarchically
    assert any(r["k"] >= 2 ** 20 and r["hier_energy"] > 0
               for r in payload["records"])


def test_serving_bench_emits_json(tmp_path):
    """`benchmarks/serving_bench.py --smoke`: the recall-vs-latency sweep
    runs end to end and BENCH_serving.json is well formed (ISSUE 8
    acceptance names the schema; the full run adds the K=4096 case)."""
    sys.path.insert(0, str(BENCH_DIR.parent))
    try:
        from benchmarks import serving_bench
    finally:
        sys.path.pop(0)
    out = tmp_path / "BENCH_serving.json"
    records = serving_bench.main(["--smoke", "--json", str(out)])
    payload = json.loads(out.read_text())
    assert payload["schema"] == "serving_bench/v1"
    assert payload["smoke"] is True and payload["records"] == records
    assert records
    for r in records:
        assert {"k", "n_groups", "n_candidates", "recall",
                "exact_us_per_query", "approx_us_per_query",
                "speedup", "scan_frac"} <= set(r)
        assert 0.0 <= r["recall"] <= 1.0
        assert r["approx_us_per_query"] > 0
    # recall is monotone in the candidate sweep (prefix closures), and
    # full candidate coverage (C = K in the smoke case) is exact
    by_k = {}
    for r in records:
        by_k.setdefault(r["k"], []).append(r)
    for k, recs in by_k.items():
        recs.sort(key=lambda r: r["n_candidates"])
        recalls = [r["recall"] for r in recs]
        assert recalls == sorted(recalls)
        if recs[-1]["n_candidates"] == k:
            assert recalls[-1] == 1.0
