"""Estimator-API tests + emergency-checkpoint behaviour."""

from pathlib import Path

import numpy as np
import pytest

from repro.core.api import AAKMeans
from repro.data.synthetic import make_blobs


def test_estimator_fit_predict():
    x = make_blobs(2000, 6, 5, seed=0, spread=4.0)
    m = AAKMeans(n_clusters=5, n_init=2, seed=1).fit(x)
    assert m.centroids_.shape == (5, 6)
    assert m.labels_.shape == (2000,)
    assert m.energy_ > 0 and m.n_iter_ >= 1
    labs = np.asarray(m.predict(x[:100]))
    assert labs.min() >= 0 and labs.max() < 5
    assert m.transform(x[:10]).shape == (10, 5)


def test_estimator_restarts_pick_best():
    x = make_blobs(1500, 4, 6, seed=2, spread=1.2)
    e1 = AAKMeans(n_clusters=6, n_init=1, init="random", seed=0).fit(x).energy_
    e5 = AAKMeans(n_clusters=6, n_init=5, init="random", seed=0).fit(x).energy_
    assert e5 <= e1 + 1e-3


def test_estimator_plain_lloyd_mode():
    x = make_blobs(800, 4, 4, seed=3, spread=4.0)
    maa = AAKMeans(n_clusters=4, accelerated=True, seed=4).fit(x)
    mll = AAKMeans(n_clusters=4, accelerated=False, seed=4).fit(x)
    assert abs(maa.energy_ - mll.energy_) / mll.energy_ < 0.02


def test_estimator_threshold_params_reach_aa_config():
    """eps1/eps2/ridge must thread through to AAConfig — they were
    silently dropped, making Table-2-style threshold sweeps through the
    public API no-ops."""
    m = AAKMeans(n_clusters=3, eps1=0.07, eps2=0.9, ridge=1e-8,
                 m0=4, mbar=12, dynamic_m=False)
    aa = m._config().aa
    assert aa.eps1 == 0.07 and aa.eps2 == 0.9 and aa.ridge == 1e-8
    assert aa.m0 == 4 and aa.mbar == 12 and aa.dynamic_m is False
    # and they must change solver behaviour end-to-end: an eps2 of -inf
    # grows m on every defined ratio, an eps1 above any ratio shrinks it;
    # both must still converge to the same quality
    x = make_blobs(600, 4, 4, seed=1, spread=4.0)
    e_grow = AAKMeans(n_clusters=4, eps2=-1e9, seed=0).fit(x).energy_
    e_shrink = AAKMeans(n_clusters=4, eps1=1e9, seed=0).fit(x).energy_
    assert abs(e_grow - e_shrink) / e_shrink < 0.02


def test_estimator_predict_uses_fitted_mesh():
    """Regression: predict/transform on a mesh-fitted model must route
    through the mesh (sharded rows, replicated centroids), not silently
    run a bare single-device assign — and must agree with the local
    result.  A 1-device mesh exercises the exact code path in-process;
    the multi-device behaviour rides the same shard_map contract as
    fit (tests/test_distributed.py)."""
    import jax
    from repro import compat

    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = make_blobs(1000, 5, 4, seed=6, spread=3.0)
    mm = AAKMeans(n_clusters=4, n_init=2, seed=1, mesh=mesh).fit(x)
    ml = AAKMeans(n_clusters=4, n_init=2, seed=1).fit(x)
    np.testing.assert_allclose(float(mm.energy_), float(ml.energy_),
                               rtol=1e-5)
    # odd-length query exercises the padding-strip path too
    q = x[:333]
    np.testing.assert_array_equal(np.asarray(mm.predict(q)),
                                  np.asarray(ml.predict(q)))
    np.testing.assert_allclose(np.asarray(mm.transform(q)),
                               np.asarray(ml.transform(q)), rtol=1e-5)
    assert mm.predict(q).shape == (333,)
    assert mm.transform(q).shape == (333, 4)


def test_chunked_runner_traces_once_across_remainders():
    """Regression (ISSUE 8): `_chunked_rows_apply` used to retrace the
    jitted runner for every distinct final-chunk remainder shape — the
    exact varying-batch-size pattern a request queue produces.  The tail
    chunk is now padded to the fixed chunk size, so a serving loop over
    varying N compiles exactly once."""
    import jax.numpy as jnp
    from repro.core.api import _chunked_rows_apply
    from repro.core.lloyd import pairwise_sqdist

    x = make_blobs(700, 5, 4, seed=8, spread=4.0)
    m = AAKMeans(n_clusters=4, seed=0).fit(x)
    traced_shapes = []

    def spy(xl, c):
        traced_shapes.append(tuple(xl.shape))   # runs at TRACE time only
        return jnp.argmin(pairwise_sqdist(xl, c), axis=1).astype(jnp.int32)

    xh = np.asarray(x)
    for n in (257, 128, 300, 123, 512, 1):      # six distinct remainders
        out = _chunked_rows_apply(m, xh[:n], "spy", spy, np.int32,
                                  chunk_size=128)
        assert out.shape == (n,)
        # padding must not perturb the real rows' results
        np.testing.assert_array_equal(out, np.asarray(m.predict(xh[:n])))
    assert traced_shapes == [(128, 5)], \
        f"expected ONE trace at the padded chunk shape; got {traced_shapes}"


def test_unfitted_inference_raises_not_fitted_error():
    from repro.core.api import MiniBatchAAKMeans, NotFittedError
    q = np.zeros((4, 3), np.float32)
    for m in (AAKMeans(n_clusters=3), MiniBatchAAKMeans(n_clusters=3)):
        for call in (m.predict, m.transform):
            with pytest.raises(NotFittedError):
                call(q)
        with pytest.raises(NotFittedError):
            m.save("unfitted.npz")      # checked before any file I/O
        with pytest.raises(NotFittedError):
            m.build_serving_index()


def test_assert_fitted_survives_python_O(tmp_path):
    """Regression (ISSUE 8): the fitted check was a bare ``assert``,
    which `python -O` strips — turning "call fit() first" into an opaque
    None-attribute crash inside the first jitted call.  Run the check in
    an optimized subprocess and require the REAL exception."""
    import os
    import subprocess
    import sys

    code = (
        "import numpy as np\n"
        "from repro.core.api import AAKMeans, NotFittedError\n"
        "try:\n"
        "    AAKMeans(n_clusters=3).predict(np.zeros((4, 2), np.float32))\n"
        "except NotFittedError:\n"
        "    print('NOT_FITTED_RAISED')\n"
    )
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-O", "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "NOT_FITTED_RAISED" in out.stdout, \
        f"stdout={out.stdout!r} stderr={out.stderr[-500:]!r}"
