"""Estimator-API tests + emergency-checkpoint behaviour."""

import numpy as np
import pytest

from repro.core.api import AAKMeans
from repro.data.synthetic import make_blobs


def test_estimator_fit_predict():
    x = make_blobs(2000, 6, 5, seed=0, spread=4.0)
    m = AAKMeans(n_clusters=5, n_init=2, seed=1).fit(x)
    assert m.centroids_.shape == (5, 6)
    assert m.labels_.shape == (2000,)
    assert m.energy_ > 0 and m.n_iter_ >= 1
    labs = np.asarray(m.predict(x[:100]))
    assert labs.min() >= 0 and labs.max() < 5
    assert m.transform(x[:10]).shape == (10, 5)


def test_estimator_restarts_pick_best():
    x = make_blobs(1500, 4, 6, seed=2, spread=1.2)
    e1 = AAKMeans(n_clusters=6, n_init=1, init="random", seed=0).fit(x).energy_
    e5 = AAKMeans(n_clusters=6, n_init=5, init="random", seed=0).fit(x).energy_
    assert e5 <= e1 + 1e-3


def test_estimator_plain_lloyd_mode():
    x = make_blobs(800, 4, 4, seed=3, spread=4.0)
    maa = AAKMeans(n_clusters=4, accelerated=True, seed=4).fit(x)
    mll = AAKMeans(n_clusters=4, accelerated=False, seed=4).fit(x)
    assert abs(maa.energy_ - mll.energy_) / mll.energy_ < 0.02
