"""Estimator-API tests + emergency-checkpoint behaviour."""

import numpy as np
import pytest

from repro.core.api import AAKMeans
from repro.data.synthetic import make_blobs


def test_estimator_fit_predict():
    x = make_blobs(2000, 6, 5, seed=0, spread=4.0)
    m = AAKMeans(n_clusters=5, n_init=2, seed=1).fit(x)
    assert m.centroids_.shape == (5, 6)
    assert m.labels_.shape == (2000,)
    assert m.energy_ > 0 and m.n_iter_ >= 1
    labs = np.asarray(m.predict(x[:100]))
    assert labs.min() >= 0 and labs.max() < 5
    assert m.transform(x[:10]).shape == (10, 5)


def test_estimator_restarts_pick_best():
    x = make_blobs(1500, 4, 6, seed=2, spread=1.2)
    e1 = AAKMeans(n_clusters=6, n_init=1, init="random", seed=0).fit(x).energy_
    e5 = AAKMeans(n_clusters=6, n_init=5, init="random", seed=0).fit(x).energy_
    assert e5 <= e1 + 1e-3


def test_estimator_plain_lloyd_mode():
    x = make_blobs(800, 4, 4, seed=3, spread=4.0)
    maa = AAKMeans(n_clusters=4, accelerated=True, seed=4).fit(x)
    mll = AAKMeans(n_clusters=4, accelerated=False, seed=4).fit(x)
    assert abs(maa.energy_ - mll.energy_) / mll.energy_ < 0.02


def test_estimator_threshold_params_reach_aa_config():
    """eps1/eps2/ridge must thread through to AAConfig — they were
    silently dropped, making Table-2-style threshold sweeps through the
    public API no-ops."""
    m = AAKMeans(n_clusters=3, eps1=0.07, eps2=0.9, ridge=1e-8,
                 m0=4, mbar=12, dynamic_m=False)
    aa = m._config().aa
    assert aa.eps1 == 0.07 and aa.eps2 == 0.9 and aa.ridge == 1e-8
    assert aa.m0 == 4 and aa.mbar == 12 and aa.dynamic_m is False
    # and they must change solver behaviour end-to-end: an eps2 of -inf
    # grows m on every defined ratio, an eps1 above any ratio shrinks it;
    # both must still converge to the same quality
    x = make_blobs(600, 4, 4, seed=1, spread=4.0)
    e_grow = AAKMeans(n_clusters=4, eps2=-1e9, seed=0).fit(x).energy_
    e_shrink = AAKMeans(n_clusters=4, eps1=1e9, seed=0).fit(x).energy_
    assert abs(e_grow - e_shrink) / e_shrink < 0.02


def test_estimator_predict_uses_fitted_mesh():
    """Regression: predict/transform on a mesh-fitted model must route
    through the mesh (sharded rows, replicated centroids), not silently
    run a bare single-device assign — and must agree with the local
    result.  A 1-device mesh exercises the exact code path in-process;
    the multi-device behaviour rides the same shard_map contract as
    fit (tests/test_distributed.py)."""
    import jax
    from repro import compat

    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = make_blobs(1000, 5, 4, seed=6, spread=3.0)
    mm = AAKMeans(n_clusters=4, n_init=2, seed=1, mesh=mesh).fit(x)
    ml = AAKMeans(n_clusters=4, n_init=2, seed=1).fit(x)
    np.testing.assert_allclose(float(mm.energy_), float(ml.energy_),
                               rtol=1e-5)
    # odd-length query exercises the padding-strip path too
    q = x[:333]
    np.testing.assert_array_equal(np.asarray(mm.predict(q)),
                                  np.asarray(ml.predict(q)))
    np.testing.assert_allclose(np.asarray(mm.transform(q)),
                               np.asarray(ml.transform(q)), rtol=1e-5)
    assert mm.predict(q).shape == (333,)
    assert mm.transform(q).shape == (333, 4)
