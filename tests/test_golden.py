"""Golden-trajectory regression: bit-stable dense-backend reproduction.

A small fixed-seed full-batch AA run is serialized in tests/golden/
(per-iteration energies and labels, final centroids).  The dense backend
recomputing a *bitwise different* trajectory on the same platform means
the numerics drifted silently — a refactor changed reduction order, a
kernel swapped accumulation dtype, a driver reordered the guard — which
must be an explicit, reviewed decision (regenerate via
tests/golden/generate_golden.py), never an accident.

Bitwise equality is asserted on CPU (XLA CPU is run-to-run
deterministic); other platforms fall back to tight tolerances.
"""

import sys
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent / "golden"))
import generate_golden as G  # noqa: E402


@pytest.fixture(scope="module")
def golden():
    if not G.GOLDEN_PATH.exists():
        pytest.fail(f"golden file missing: {G.GOLDEN_PATH} — run "
                    f"tests/golden/generate_golden.py")
    with np.load(G.GOLDEN_PATH) as z:
        return {k: z[k] for k in z.files}


def test_dense_trajectory_is_bit_stable(golden):
    traj = G.compute_trajectory()
    assert traj["energies"].shape == golden["energies"].shape, (
        f"iteration count drifted: {traj['energies'].shape[0]} vs golden "
        f"{golden['energies'].shape[0]}")
    if jax.default_backend() == "cpu":
        # exact bits: energies, every per-iteration assignment, centroids
        np.testing.assert_array_equal(
            traj["energies"].view(np.uint32),
            golden["energies"].view(np.uint32),
            err_msg="per-iteration energies drifted (bitwise)")
        np.testing.assert_array_equal(traj["labels"], golden["labels"])
        np.testing.assert_array_equal(
            traj["centroids"].view(np.uint32),
            golden["centroids"].view(np.uint32),
            err_msg="final centroids drifted (bitwise)")
    else:   # accelerator reduction order differs from the stored CPU run
        np.testing.assert_allclose(traj["energies"], golden["energies"],
                                   rtol=1e-5)
        assert (traj["labels"][-1] == golden["labels"][-1]).mean() > 0.999
        np.testing.assert_allclose(traj["centroids"], golden["centroids"],
                                   rtol=1e-4, atol=1e-4)


def test_golden_metadata_matches_generator(golden):
    np.testing.assert_array_equal(
        golden["shape"], np.array([G.N, G.D, G.K, G.SEED], np.int64))
