# NOTE: deliberately does NOT set --xla_force_host_platform_device_count:
# smoke tests and benches must see 1 device (system prompt).  Multi-device
# tests spawn subprocesses that set XLA_FLAGS before importing jax.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
