"""repro.runtime: prefetching chunk pipeline, background checkpoint
writer + manifest/retention lifecycle, metrics sinks — and their wiring
through the segmented drivers (DESIGN.md §Runtime)."""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_snapshot, resume_point
from repro.core import serialize
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import KMeansConfig, aa_kmeans
from repro.data.streaming import chunk_dataset, stream_chunks
from repro.data.synthetic import make_blobs
from repro.runtime.metrics import (CollectMetrics, JsonlMetrics, NullMetrics,
                                   StdoutMetrics, TeeMetrics, as_metrics)
from repro.runtime.prefetch import (IngestMeter, prefetch_to_device,
                                    tree_nbytes)
from repro.runtime.writer import (CheckpointWriter, cleanup_orphans,
                                  read_manifest, snapshot_name,
                                  write_snapshot)


def _problem(n=400, d=4, k=5, max_iter=30, seed=0):
    x = jnp.asarray(make_blobs(n, d, k, seed=seed, spread=1.0))
    c0 = kmeanspp_init(jax.random.PRNGKey(seed), x, k)
    return x, c0, KMeansConfig(k=k, max_iter=max_iter)


# ---------------------------------------------------------------------------
# prefetch
# ---------------------------------------------------------------------------

def test_prefetch_preserves_order_and_values(rng):
    chunks = [rng.standard_normal((8, 3)).astype(np.float32)
              for _ in range(7)]
    for size in (1, 2, 4, 16):   # 16 > len: whole stream in flight
        out = list(prefetch_to_device(iter(chunks), size=size))
        assert len(out) == len(chunks)
        for a, b in zip(chunks, out):
            assert isinstance(b, jax.Array)
            np.testing.assert_array_equal(a, np.asarray(b))


def test_prefetch_rejects_size_zero():
    with pytest.raises(ValueError, match="size"):
        list(prefetch_to_device(iter([np.zeros(2)]), size=0))


def test_prefetch_meter_counts_bytes(rng):
    chunks = [rng.standard_normal((16, 4)).astype(np.float32)
              for _ in range(5)]
    meter = IngestMeter()
    list(prefetch_to_device(iter(chunks), size=2, meter=meter))
    assert meter.chunks == 5
    assert meter.bytes == 5 * 16 * 4 * 4 == sum(map(tree_nbytes, chunks))
    assert meter.gbps > 0
    s = meter.scalars()
    assert s["ingest_bytes"] == meter.bytes and s["ingest_chunks"] == 5


def test_stream_chunks_host_array_matches_host_chunk_stream(rng):
    from repro.data.streaming import host_chunk_stream
    x = rng.standard_normal((100, 3)).astype(np.float32)
    ref = list(host_chunk_stream(x, 32, epochs=2, seed=3))
    out = list(stream_chunks(x, 32, epochs=2, seed=3))
    assert len(out) == len(ref)
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_stream_chunks_device_chunks_passthrough(rng):
    x = rng.standard_normal((96, 3)).astype(np.float32)
    dc = chunk_dataset(x, 32)
    out = list(stream_chunks(dc))
    assert len(out) == dc.chunks.shape[0]
    for i, ch in enumerate(out):
        np.testing.assert_array_equal(np.asarray(dc.chunks[i]),
                                      np.asarray(ch))
    with pytest.raises(ValueError, match="storage order"):
        stream_chunks(dc, chunk_size=32)


def test_stream_chunks_requires_chunk_size_for_arrays(rng):
    with pytest.raises(ValueError, match="chunk_size"):
        stream_chunks(rng.standard_normal((10, 2)))


def test_stream_chunks_device_chunks_rejects_all_stream_params(rng):
    """Regression (ISSUE 8): ``seed=``/``drop_remainder=`` used to slip
    past the DeviceChunks guard and be silently ignored — a caller's
    "my shuffle seed works" was a no-op.  The documented contract (all
    stream params at defaults) is now enforced for every parameter."""
    dc = chunk_dataset(rng.standard_normal((96, 3)).astype(np.float32), 32)
    for bad in ({"seed": 7}, {"drop_remainder": True}, {"epochs": 2},
                {"start_chunk": 1}, {"chunk_size": 32}):
        with pytest.raises(ValueError, match="storage order"):
            stream_chunks(dc, **bad)
    assert len(list(stream_chunks(dc))) == dc.chunks.shape[0]


# ---------------------------------------------------------------------------
# metrics sinks
# ---------------------------------------------------------------------------

def test_as_metrics_normalisation():
    assert isinstance(as_metrics(None), NullMetrics)
    assert isinstance(as_metrics("null"), NullMetrics)
    assert isinstance(as_metrics("stdout"), StdoutMetrics)
    sink = CollectMetrics()
    assert as_metrics(sink) is sink
    with pytest.raises(ValueError, match="unknown metrics sink"):
        as_metrics("wandb")
    with pytest.raises(TypeError, match="log_scalars"):
        as_metrics(42)


def test_collect_and_tee_and_jsonl(tmp_path):
    c1, c2 = CollectMetrics(), CollectMetrics()
    jl = JsonlMetrics(tmp_path / "m.jsonl")
    tee = TeeMetrics(c1, c2, jl)
    tee.log_scalars(1, {"e": jnp.asarray(2.5), "n": 3})
    tee.log_scalars(2, {"e": 1.25})
    tee.close()
    assert c1.records == c2.records == [(1, {"e": 2.5, "n": 3.0}),
                                        (2, {"e": 1.25})]
    lines = [json.loads(ln) for ln in
             (tmp_path / "m.jsonl").read_text().splitlines()]
    assert lines == [{"step": 1, "e": 2.5, "n": 3.0},
                     {"step": 2, "e": 1.25}]


def test_early_stop_hook_trips_on_stall():
    from repro.runtime.metrics import EarlyStopHook, should_stop
    hook = EarlyStopHook(rel_tol=1e-3, patience=2, min_records=1)
    hook.log_scalars(0, {"energy": 100.0})
    hook.log_scalars(1, {"energy": 50.0})     # big improvement: no stall
    assert not hook.should_stop
    hook.log_scalars(2, {"energy": 49.999})   # stall 1
    assert not hook.should_stop
    hook.log_scalars(3, {"energy": 49.998})   # stall 2 -> trip
    assert hook.should_stop and hook.stopped_at == 3
    assert should_stop(hook)
    # monotone: later improvement does not un-trip
    hook.log_scalars(4, {"energy": 1.0})
    assert hook.should_stop
    # records kept for inspecting the decision (CollectMetrics base)
    assert len(hook.records) == 5


def test_early_stop_hook_metric_fallbacks_and_nonfinite():
    from repro.runtime.metrics import EarlyStopHook, should_stop
    hook = EarlyStopHook(rel_tol=1e-3, patience=1, min_records=1)
    hook.log_scalars(0, {"segment_s": 0.5})            # no watched metric
    hook.log_scalars(1, {"e_val": float("nan")})       # ignored
    hook.log_scalars(2, {"energy_best": 10.0})         # batched spelling
    assert not hook.should_stop
    hook.log_scalars(3, {"energy_best": 10.0})
    assert hook.should_stop
    # plain sinks never stop a driver; a Tee fan-out is searched
    assert not should_stop(CollectMetrics())
    assert should_stop(TeeMetrics(CollectMetrics(), hook))


def test_early_stop_hook_halts_segmented_driver():
    """Wired as the metrics= sink of the segmented single-solve driver:
    an impossible improvement bar stops the host loop before max_iter."""
    from repro.runtime.metrics import EarlyStopHook
    x, c0, cfg = _problem(max_iter=200)
    hook = EarlyStopHook(rel_tol=10.0, patience=1, min_records=1)
    res = aa_kmeans(x, c0, cfg, checkpoint_every=1, metrics=hook)
    assert hook.should_stop
    assert int(res.n_iter) < 200


def test_jsonl_is_thread_safe(tmp_path):
    jl = JsonlMetrics(tmp_path / "m.jsonl")

    def pump(tid):
        for i in range(50):
            jl.log_scalars(i, {"tid": tid})
    threads = [threading.Thread(target=pump, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    jl.close()
    lines = (tmp_path / "m.jsonl").read_text().splitlines()
    assert len(lines) == 200
    for ln in lines:
        json.loads(ln)     # every line intact (no interleaving)


# ---------------------------------------------------------------------------
# writer: manifest, retention, orphan cleanup
# ---------------------------------------------------------------------------

def _fake_state(step):
    return {"c": jnp.full((3, 2), float(step)), "t": jnp.asarray(step)}


def test_write_snapshot_builds_manifest(tmp_path):
    for t in (2, 4, 6):
        write_snapshot(tmp_path, _fake_state(t), kind="unit", step=t,
                       extra={"t": t})
    m = read_manifest(tmp_path)
    assert m is not None and m["kind"] == "unit"
    assert m["latest"] == snapshot_name(6)
    assert [e["step"] for e in m["snapshots"]] == [2, 4, 6]
    assert (tmp_path / m["latest"]).exists()


def test_retention_window_and_boundary_keep(tmp_path):
    # keep_last_n=2 with keep_every_m=10: a sliding window of 2 plus
    # every 10th boundary kept forever
    for t in range(5, 55, 5):
        write_snapshot(tmp_path, _fake_state(t), kind="unit", step=t,
                       keep_last_n=2, keep_every_m=10)
    kept = sorted(p.name for p in tmp_path.glob("it_*.npz"))
    want = sorted({snapshot_name(t) for t in (10, 20, 30, 40, 50, 45)})
    assert kept == want
    m = read_manifest(tmp_path)
    assert sorted(e["file"] for e in m["snapshots"]) == want
    # the manifest never references a deleted file
    for e in m["snapshots"]:
        assert (tmp_path / e["file"]).exists()


def test_retention_always_keeps_newest(tmp_path):
    # keep_every_m alone, newest step not on the boundary: still kept
    for t in (3, 6, 10, 13):
        write_snapshot(tmp_path, _fake_state(t), kind="unit", step=t,
                       keep_every_m=10)
    kept = {p.name for p in tmp_path.glob("it_*.npz")}
    assert kept == {snapshot_name(10), snapshot_name(13)}


def test_cleanup_orphans(tmp_path):
    (tmp_path / "it_00000001.npz.tmp").write_bytes(b"partial")
    (tmp_path / "manifest.json.tmp").write_bytes(b"{")
    keep = tmp_path / "it_00000002.npz"
    keep.write_bytes(b"complete")
    removed = cleanup_orphans(tmp_path)
    assert len(removed) == 2 and keep.exists()
    assert not list(tmp_path.glob("*.tmp"))


def test_latest_snapshot_uses_manifest_with_scan_fallback(tmp_path):
    for t in (1, 2):
        write_snapshot(tmp_path, _fake_state(t), kind="unit", step=t)
    assert latest_snapshot(tmp_path).name == snapshot_name(2)
    # corrupt manifest -> scan fallback still finds the newest artifact
    (tmp_path / "manifest.json").write_text("not json")
    assert latest_snapshot(tmp_path).name == snapshot_name(2)
    # manifest pointing at an externally deleted file -> fallback too
    write_snapshot(tmp_path, _fake_state(3), kind="unit", step=3)
    (tmp_path / snapshot_name(3)).unlink()
    assert latest_snapshot(tmp_path).name == snapshot_name(2)


def test_latest_snapshot_fallback_orders_by_step_not_name(tmp_path):
    """Regression (ISSUE 8): the manifest-less fallback sorted snapshot
    file NAMES, so lexicographic it_9.npz beat it_10.npz and a ``.tmp``
    filter aimed at ``*.npz.tmp`` never matched its own glob.  The
    fallback now parses the integer step and ignores orphans/garbage."""
    from repro.checkpoint import latest_snapshot as latest
    for step in (9, 10, 2):
        (tmp_path / f"it_{step}.npz").write_bytes(b"snap")
    (tmp_path / "it_11.npz.tmp").write_bytes(b"orphan")    # interrupted
    (tmp_path / "it_xx.npz").write_bytes(b"garbage")       # unparseable
    assert latest(tmp_path).name == "it_10.npz"
    # directory with ONLY orphans/garbage: no snapshot, not a crash
    empty = tmp_path / "empty"
    empty.mkdir()
    (empty / "it_1.npz.tmp").write_bytes(b"orphan")
    assert latest(empty) is None


# ---------------------------------------------------------------------------
# writer: async lifecycle
# ---------------------------------------------------------------------------

def test_writer_async_matches_sync_artifacts(tmp_path):
    sync_dir, async_dir = tmp_path / "sync", tmp_path / "async"
    states = {t: jax.device_get(_fake_state(t)) for t in (1, 2, 3)}
    for t, st in states.items():
        write_snapshot(sync_dir, st, kind="unit", step=t, extra={"t": t})
    with CheckpointWriter(async_dir, kind="unit") as w:
        for t, st in states.items():
            w.submit(st, t, {"t": t})
    assert w.n_written == 3
    for t in states:
        a, _ = serialize.load(sync_dir / snapshot_name(t))
        b, _ = serialize.load(async_dir / snapshot_name(t))
        assert a["t"] == b["t"] == t
        _, pa = serialize.load(sync_dir / snapshot_name(t))
        _, pb = serialize.load(async_dir / snapshot_name(t))
        for k in pa:
            np.testing.assert_array_equal(pa[k], pb[k])
    ma, mb = read_manifest(sync_dir), read_manifest(async_dir)
    assert ma["latest"] == mb["latest"]
    assert [e["step"] for e in ma["snapshots"]] == \
        [e["step"] for e in mb["snapshots"]]


def test_writer_propagates_write_errors(tmp_path, monkeypatch):
    import repro.runtime.writer as W
    w = CheckpointWriter(tmp_path, kind="unit")
    monkeypatch.setattr(W, "write_snapshot",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            OSError("disk full")))
    w.submit(jax.device_get(_fake_state(1)), 1)
    with pytest.raises(OSError, match="disk full"):
        w.drain()
    # close() after a surfaced error is clean (error already consumed)
    w.close()


def test_writer_emits_write_latency_metric(tmp_path):
    mx = CollectMetrics()
    with CheckpointWriter(tmp_path, kind="unit", metrics=mx) as w:
        w.submit(jax.device_get(_fake_state(7)), 7)
    assert any(step == 7 and "checkpoint_write_s" in rec
               for step, rec in mx.records)


def test_writer_refuses_submit_after_close(tmp_path):
    w = CheckpointWriter(tmp_path, kind="unit")
    w.close()
    with pytest.raises(RuntimeError, match="closed"):
        w.submit(jax.device_get(_fake_state(1)), 1)
    w.close()      # idempotent


# ---------------------------------------------------------------------------
# drivers: async checkpointing end-to-end
# ---------------------------------------------------------------------------

def test_driver_async_checkpoints_match_sync(tmp_path):
    x, c0, cfg = _problem()
    ref = aa_kmeans(x, c0, cfg)
    sync_dir, async_dir = tmp_path / "sync", tmp_path / "async"
    aa_kmeans(x, c0, cfg, checkpoint_every=7, checkpoint_dir=sync_dir,
              sync_writes=True)
    aa_kmeans(x, c0, cfg, checkpoint_every=7, checkpoint_dir=async_dir)
    names_s = sorted(p.name for p in sync_dir.glob("it_*.npz"))
    names_a = sorted(p.name for p in async_dir.glob("it_*.npz"))
    assert names_s == names_a and names_s
    for name in names_s:     # bit-identical artifacts either way
        _, pa = serialize.load(sync_dir / name)
        _, pb = serialize.load(async_dir / name)
        assert pa.keys() == pb.keys()
        for k in pa:
            np.testing.assert_array_equal(pa[k], pb[k])
    # resume from the async run's manifest-reported latest: bit-identical
    res = aa_kmeans(x, c0, cfg, resume_from=latest_snapshot(async_dir))
    assert float(res.energy) == float(ref.energy)
    np.testing.assert_array_equal(np.asarray(res.centroids),
                                  np.asarray(ref.centroids))


def test_driver_killed_midrun_resumes_from_manifest(tmp_path):
    """A run that dies mid-solve (exception at a boundary) still drains
    the writer on the way out, so the manifest names a durable snapshot
    and resuming from it reproduces the uninterrupted result bit for
    bit."""
    x, c0, cfg = _problem(max_iter=40)
    ref = aa_kmeans(x, c0, cfg)

    class Die(RuntimeError):
        pass

    boundaries = []

    def killer(state, t):
        boundaries.append(t)
        if len(boundaries) >= 2:       # die at the second boundary
            raise Die("simulated preemption")

    with pytest.raises(Die):
        aa_kmeans(x, c0, cfg, checkpoint_every=3, checkpoint_dir=tmp_path,
                  checkpoint_cb=killer)
    p, meta = resume_point(tmp_path)       # reads manifest.json
    assert p is not None and meta["t"] == boundaries[-1]
    assert read_manifest(tmp_path)["latest"] == p.name
    res = aa_kmeans(x, c0, cfg, resume_from=p)
    assert float(res.energy) == float(ref.energy)
    np.testing.assert_array_equal(np.asarray(res.centroids),
                                  np.asarray(ref.centroids))


def test_driver_failed_write_fails_run(tmp_path, monkeypatch):
    import repro.runtime.writer as W
    x, c0, cfg = _problem()
    monkeypatch.setattr(W, "write_snapshot",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            OSError("disk full")))
    with pytest.raises(OSError, match="disk full"):
        aa_kmeans(x, c0, cfg, checkpoint_every=5, checkpoint_dir=tmp_path)


def test_driver_retention_flows_through(tmp_path):
    x, c0, cfg = _problem(max_iter=40)
    aa_kmeans(x, c0, cfg, checkpoint_every=4, checkpoint_dir=tmp_path,
              keep_last_n=2)
    snaps = sorted(tmp_path.glob("it_*.npz"))
    assert len(snaps) == 2
    m = read_manifest(tmp_path)
    assert len(m["snapshots"]) == 2
    # resume from the retained window still reproduces the full solve
    res = aa_kmeans(x, c0, cfg, resume_from=snaps[-1])
    ref = aa_kmeans(x, c0, cfg)
    assert float(res.energy) == float(ref.energy)


def test_driver_metrics_emission(tmp_path):
    x, c0, cfg = _problem()
    mx = CollectMetrics()
    aa_kmeans(x, c0, cfg, checkpoint_every=7, checkpoint_dir=tmp_path,
              metrics=mx)
    seg_records = [(s, r) for s, r in mx.records if "energy" in r]
    assert seg_records
    for _, rec in seg_records:
        assert {"energy", "n_accepted", "segment_s"} <= set(rec)
    # the writer contributed its write-latency stream to the same sink
    assert any("checkpoint_write_s" in r for _, r in mx.records)
    # metrics alone (no checkpointing) also routes through the host loop
    mx2 = CollectMetrics()
    res = aa_kmeans(x, c0, cfg, metrics=mx2)
    ref = aa_kmeans(x, c0, cfg)
    assert mx2.records
    assert float(res.energy) == float(ref.energy)
