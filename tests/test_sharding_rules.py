"""Sharding-rule unit tests (pure metadata — no device execution)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import make_rules

MESH2 = jax.make_mesh((1, 1), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _mesh3():
    dev = np.array(jax.devices()[:1]).reshape(1, 1, 1)
    return jax.sharding.Mesh(dev, ("pod", "data", "model"))


def test_basic_table():
    r = make_rules(MESH2)
    assert r.spec(("batch", "seq", "act_embed")) == P("data", None, None)
    assert r.spec(("embed", "mlp")) == P("data", "model")
    assert r.spec(("vocab", "embed")) == P("model", "data")


def test_multi_pod_batch_axes():
    r = make_rules(_mesh3())
    assert r.spec(("batch",)) == P(("pod", "data"))
    assert r.spec(("embed",)) == P(("pod", "data"))
    assert r.spec(("fold_bh",)) == P(("pod", "data", "model"))


def test_divisibility_fallback():
    """A dim that does not divide its axis extent must fall back to
    replication (shape_spec), e.g. 9 heads on model=16."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = jax.sharding.Mesh(dev, ("data", "model"))
    # pretend-extent check happens against mesh.shape: with size-1 axes
    # everything divides, so craft the check through the rule API directly
    r = make_rules(MESH2)
    spec = r.shape_spec(MESH2, ("batch", "seq", "act_heads", None),
                        (4, 32, 9, 64))
    # model axis extent is 1 here -> divisible; the semantic test is in
    # test_dryrun-side artifacts; assert the API keeps rank and order
    assert len(spec) == 4


def test_seq_shard_modes():
    r_sp = make_rules(MESH2, seq_shard_acts=True)
    assert r_sp.spec(("batch", "seq_res", "act_embed")) == \
        P("data", "model", None)
    r_long = make_rules(MESH2, seq_sharded=True)
    assert r_long.spec(("batch",)) == P(None)
    assert r_long.spec(("cache_seq",)) == P("data")
    r_dec = make_rules(MESH2, cache_seq_model=True)
    assert r_dec.spec(("cache_seq",)) == P("model")


def test_moe_ep_rules():
    r_tp = make_rules(MESH2, moe_ep=False)
    assert r_tp.spec(("experts", "embed", "expert_mlp")) == \
        P(None, "data", "model")
    r_ep = make_rules(MESH2, moe_ep=True)
    assert r_ep.spec(("experts", "embed", "expert_mlp")) == \
        P("model", "data", None)


def test_unknown_logical_axis_raises():
    r = make_rules(MESH2)
    with pytest.raises(KeyError):
        r.spec(("not_an_axis",))


def test_artifacts_complete_and_coherent():
    """Deliverable-e integration check: 40 cells x 2 meshes accounted for
    (compiled or assignment-mandated skip), zero failures."""
    import json
    from pathlib import Path
    art = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated in this checkout")
    recs = [json.loads(p.read_text()) for p in art.glob("*.json")
            if not p.name.startswith("aa-kmeans") and "__" in p.name
            and p.name.count("__") == 2]     # baseline (untagged) cells
    if not recs:
        # a kmeans-only dry-run (e.g. the verify recipe) creates the
        # directory without the LM baseline sweep — that is still "not
        # generated", not a coherence failure
        pytest.skip("no baseline dry-run records in this checkout")
    cells = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
    assert len(cells) == 80, len(cells)
    bad = [r for r in recs if not (r.get("ok") or r.get("skipped"))]
    assert not bad, bad[:2]
    skips = [r for r in recs if r.get("skipped")]
    assert len(skips) == 12
    for r in recs:
        if r.get("skipped"):
            continue
        assert r.get("time_compile_s", 0) > 0
        assert "memory" in r
