"""Streaming mini-batch engine tests (DESIGN.md §Streaming).

1. minibatch_step contract: native (dense/blocked) and fallback paths
   agree with the weighted oracle; zero-weight padding is inert (the
   per-backend sweep lives in test_conformance).
2. Driver behaviour: convergence to full-batch quality from the same
   seeds, determinism, backend-independence of the guard decisions,
   plain-Lloyd mode, epoch/chunk trace shapes.
3. Data layer: chunk_dataset masking/reshaping, split_validation,
   host_chunk_stream reshuffling.
4. Estimator: fit / partial_fit / finalize / predict / transform.
5. Streaming sweep smoke (slow): the benchmark's headline criterion.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as B
from repro.core.api import MiniBatchAAKMeans
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import (KMeansConfig, aa_kmeans,
                               aa_kmeans_minibatch,
                               aa_kmeans_minibatch_streamed)
from repro.core.minibatch import (MiniBatchConfig, guard_pick,
                                  minibatch_init, minibatch_iteration)
from repro.data.streaming import (chunk_dataset, host_chunk_stream,
                                  split_validation, stream_chunks)
from repro.data.synthetic import make_blobs
from repro.kernels import ref

K = 8


@pytest.fixture(scope="module")
def problem():
    x = jnp.asarray(make_blobs(16000, 8, K, seed=0, spread=3.0))
    xt, xv = split_validation(x, 1024, jax.random.PRNGKey(7))
    c0 = kmeanspp_init(jax.random.PRNGKey(0), x[:4096], K)
    return x, xt, xv, c0


def _full_energy(x, c):
    res, _ = B.get_backend("dense").step(x, c, K, ())
    return float(res.energy)


# -- step contract ----------------------------------------------------------

def test_minibatch_step_native_matches_fallback_and_oracle(problem):
    x, _, _, c = (*problem[:3], problem[3])
    xc = x[:1000]
    w = jnp.concatenate([jnp.ones(800), jnp.zeros(200)])
    dense = B.get_backend("dense")
    assert dense.minibatch_step_fn is not None
    res_native, _ = dense.minibatch_step(xc, c, K, w, ())
    # strip the native slot to force the generic step_fn+reweight fallback
    import dataclasses
    fallback = dataclasses.replace(dense, minibatch_step_fn=None)
    res_fb, _ = fallback.minibatch_step(xc, c, K, w, ())
    want = ref.minibatch_ref(xc, c, w)
    for got in (res_native, res_fb):
        np.testing.assert_array_equal(got.labels, want[0])
        np.testing.assert_allclose(got.sums, want[2], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(got.counts, want[3], rtol=0, atol=1e-6)
        np.testing.assert_allclose(float(got.energy), float(want[4]),
                                   rtol=1e-5)


def test_distributed_minibatch_step_psums_once(problem):
    """A distribute()-wrapped minibatch step on a 1-device mesh must equal
    the local step exactly (psum = identity); the multi-device version
    lives in test_distributed."""
    from jax.sharding import PartitionSpec as P
    from repro import compat
    x, _, _, c = (*problem[:3], problem[3])
    xc, w = x[:1024], jnp.ones(1024)
    dense = B.get_backend("dense")
    dist = B.distribute(dense, ("data",))
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    res = compat.shard_map(
        lambda a, b, ww: dist.minibatch_step(a, b, K, ww, ())[0],
        mesh=mesh, in_specs=(P("data"), P(), P("data")),
        out_specs=B.StepResult(labels=P("data"), min_sqdist=P("data"),
                               sums=P(), counts=P(), energy=P()))(xc, c, w)
    want, _ = dense.minibatch_step(xc, c, K, w, ())
    np.testing.assert_allclose(res.sums, want.sums, rtol=0, atol=0)
    np.testing.assert_allclose(float(res.energy), float(want.energy),
                               rtol=0)


def test_instrumented_backend_counts_chunk_passes(problem):
    x, _, xv, c0 = problem
    passes = []
    backend = B.instrument(B.get_backend("dense"), lambda: passes.append(1))
    xc, w = x[:2048], jnp.ones(2048)
    cfg = MiniBatchConfig(k=K, chunk_size=2048)
    state = minibatch_init(c0, cfg, backend)
    state, _ = minibatch_iteration(xc, w, xv, state, cfg, backend)
    jax.block_until_ready(state.c)
    jax.effects_barrier()
    # one guard pass (batched, R=2 over the val chunk) + one chunk pass
    assert len(passes) == 2, passes


# -- driver -----------------------------------------------------------------

def test_minibatch_reaches_full_batch_quality(problem):
    """From identical seed centroids, 5 mini-batch epochs must land within
    2% of the full-batch AA optimum's energy on the full dataset."""
    x, xt, xv, c0 = problem
    full = aa_kmeans(x, c0, KMeansConfig(k=K, max_iter=500))
    dc = chunk_dataset(xt, 2048)
    cfg = MiniBatchConfig(k=K, chunk_size=2048, epochs=5)
    res = jax.jit(lambda a, b, v, c: aa_kmeans_minibatch(
        a, b, v, c, cfg))(dc.chunks, dc.weights, xv, c0)
    e_mb = _full_energy(x, res.centroids)
    assert e_mb <= float(full.energy) * 1.02, (e_mb, float(full.energy))
    assert int(res.n_steps) == 5 * dc.chunks.shape[0]
    assert 0 < int(res.n_accepted) <= int(res.n_steps)


def test_minibatch_is_deterministic_and_backend_invariant(problem):
    """Same key -> identical result; the guard decisions (accept counts)
    must not depend on which backend computed the identical math."""
    _, xt, xv, c0 = problem
    dc = chunk_dataset(xt, 2048)
    cfg = MiniBatchConfig(k=K, chunk_size=2048, epochs=2)
    key = jax.random.PRNGKey(3)
    runs = {}
    for name in ("dense", "hamerly"):
        r1 = aa_kmeans_minibatch(dc.chunks, dc.weights, xv, c0, cfg,
                                 backend=name, key=key)
        r2 = aa_kmeans_minibatch(dc.chunks, dc.weights, xv, c0, cfg,
                                 backend=name, key=key)
        assert float(r1.energy) == float(r2.energy), name
        np.testing.assert_array_equal(np.asarray(r1.centroids),
                                      np.asarray(r2.centroids))
        runs[name] = r1
    assert int(runs["dense"].n_accepted) == int(runs["hamerly"].n_accepted)
    np.testing.assert_allclose(float(runs["dense"].energy),
                               float(runs["hamerly"].energy), rtol=1e-5)


def test_minibatch_plain_lloyd_mode(problem):
    """accelerated=False is plain mini-batch Lloyd: no candidate is ever
    accepted (c == c_au throughout) and quality is still sane."""
    x, xt, xv, c0 = problem
    dc = chunk_dataset(xt, 2048)
    cfg = MiniBatchConfig(k=K, chunk_size=2048, epochs=5,
                          accelerated=False)
    res = aa_kmeans_minibatch(dc.chunks, dc.weights, xv, c0, cfg)
    assert int(res.n_accepted) == 0
    full = aa_kmeans(x, c0, KMeansConfig(k=K, max_iter=500))
    assert _full_energy(x, res.centroids) <= float(full.energy) * 1.10


def test_minibatch_trace_shapes_and_validation(problem):
    _, xt, xv, c0 = problem
    dc = chunk_dataset(xt, 4096)
    cfg = MiniBatchConfig(k=K, chunk_size=4096, epochs=3)
    res, trace = aa_kmeans_minibatch(dc.chunks, dc.weights, xv, c0, cfg,
                                     return_trace=True)
    assert trace.e_val.shape == (3, dc.chunks.shape[0])
    assert trace.accepted.dtype == jnp.bool_
    assert float(res.energy) > 0
    with pytest.raises(ValueError, match="n_chunks"):
        aa_kmeans_minibatch(xt, dc.weights, xv, c0, cfg)
    with pytest.raises(ValueError, match="weights"):
        aa_kmeans_minibatch(dc.chunks, dc.weights[:, :-1], xv, c0, cfg)


def test_decayed_stats_keep_unseen_clusters_fixed():
    """S/W is invariant under pure decay: a cluster that no chunk touches
    must hold its centroid exactly, not shrink toward the origin (the
    update_from_sums max(counts,1) safe-divide would corrupt decayed
    weights < 1 — regression for _centroids_from_running)."""
    k, d = 4, 3
    bk = B.get_backend("dense")
    cfg = MiniBatchConfig(k=k, chunk_size=32, decay=0.5)
    c0 = jnp.asarray(np.float32([[0, 0, 0], [10, 0, 0], [0, 10, 0],
                                 [50, 50, 50]]))   # cluster 3: never seen
    rng = np.random.default_rng(0)
    xv = jnp.asarray(rng.normal(0, 0.1, (16, d)).astype(np.float32))
    state = minibatch_init(c0, cfg, bk)
    for step in range(8):
        xc = jnp.asarray(
            np.concatenate([rng.normal(0, .1, (10, d)),
                            rng.normal([10, 0, 0], .1, (11, d)),
                            rng.normal([0, 10, 0], .1, (11, d))])
            .astype(np.float32))
        state, _ = minibatch_iteration(xc, jnp.ones(32), xv, state, cfg, bk)
        # after 8 steps of decay 0.5, cluster-3 weight would be 0.5^8 if it
        # had ever been counted; it must still sit exactly at its seed
        np.testing.assert_array_equal(np.asarray(state.c_au[3]),
                                      np.float32([50, 50, 50]))


# -- data layer -------------------------------------------------------------

def test_chunk_dataset_masks_remainder():
    x = jnp.arange(10 * 3, dtype=jnp.float32).reshape(10, 3)
    dc = chunk_dataset(x, 4)
    assert dc.chunks.shape == (3, 4, 3) and dc.n == 10
    np.testing.assert_array_equal(
        np.asarray(dc.weights),
        [[1, 1, 1, 1], [1, 1, 1, 1], [1, 1, 0, 0]])
    # padding rows replicate the last sample
    np.testing.assert_array_equal(np.asarray(dc.chunks[2, 2]),
                                  np.asarray(x[-1]))
    with pytest.raises(ValueError, match="chunk_size"):
        chunk_dataset(x, 0)


def test_split_validation_partitions():
    x = jnp.arange(100 * 2, dtype=jnp.float32).reshape(100, 2)
    xt, xv = split_validation(x, 25, jax.random.PRNGKey(0))
    assert xt.shape == (75, 2) and xv.shape == (25, 2)
    merged = np.sort(np.concatenate([np.asarray(xt), np.asarray(xv)]),
                     axis=0)
    np.testing.assert_array_equal(merged, np.asarray(x))
    with pytest.raises(ValueError, match="val_size"):
        split_validation(x, 100, jax.random.PRNGKey(0))


def test_host_chunk_stream_reshuffles_per_epoch():
    x = np.arange(100, dtype=np.float32).reshape(100, 1)
    chunks = list(host_chunk_stream(x, 32, epochs=2, seed=0))
    assert len(chunks) == 8                      # 4 per epoch (tail = 4)
    assert [c.shape[0] for c in chunks[:4]] == [32, 32, 32, 4]
    e1 = np.concatenate([c.ravel() for c in chunks[:4]])
    e2 = np.concatenate([c.ravel() for c in chunks[4:]])
    np.testing.assert_array_equal(np.sort(e1), x.ravel())  # full coverage
    np.testing.assert_array_equal(np.sort(e2), x.ravel())
    assert not (e1 == e2).all()                  # reshuffled
    short = list(host_chunk_stream(x, 32, epochs=1, drop_remainder=True))
    assert [c.shape[0] for c in short] == [32, 32, 32]


# -- estimator --------------------------------------------------------------

def test_estimator_fit(problem):
    x = problem[0]
    m = MiniBatchAAKMeans(n_clusters=K, chunk_size=2048, epochs=4,
                          seed=0).fit(x)
    assert m.centroids_.shape == (K, 8)
    assert m.labels_.shape == (x.shape[0],)
    assert m.energy_ == m.inertia_ and m.energy_ > 0
    assert m.n_steps_ > 0
    # labels_ match a fresh predict, chunked at a different size
    np.testing.assert_array_equal(np.asarray(m.labels_),
                                  np.asarray(m.predict(x, chunk_size=1111)))
    assert m.transform(x[:100]).shape == (100, K)


def test_estimator_partial_fit_streams_host_chunks(problem):
    x = np.asarray(problem[0])
    m = MiniBatchAAKMeans(n_clusters=K, chunk_size=2048, seed=0)
    with pytest.raises(ValueError, match="partial_fit chunk"):
        m.partial_fit(x[:4])
    # documented held-out pattern: feed the first chunk once (it carves
    # the val rows), epoch only over the remainder
    m.partial_fit(x[:2048])
    for chunk in host_chunk_stream(x[2048:], 2048, epochs=3, seed=1,
                                   drop_remainder=True):
        m.partial_fit(chunk)
    assert m.n_steps_ == 1 + 3 * ((x.shape[0] - 2048) // 2048)
    e_fallback = m.energy_
    m.finalize()
    assert m.energy_ <= e_fallback * 1.001   # guard pick can only help
    # quality vs full-batch FROM THE SAME SEED CENTROIDS (reconstructed
    # the way partial_fit derives them) — single-restart k-means quality
    # under independent inits is luck, not a solver property
    from repro.data.streaming import split_validation
    k_val, k_init = jax.random.split(jax.random.PRNGKey(0))
    x0, _ = split_validation(jnp.asarray(x[:2048]), m._val_rows(2048),
                             k_val)
    c0 = kmeanspp_init(k_init, x0, K)
    full = aa_kmeans(jnp.asarray(x), c0, KMeansConfig(k=K, max_iter=500))
    e_stream = _full_energy(jnp.asarray(x), jnp.asarray(m.centroids_))
    assert e_stream <= float(full.energy) * 1.10
    assert m.predict(x[:100]).shape == (100,)


def test_estimator_fit_deterministic(problem):
    x = problem[0]
    a = MiniBatchAAKMeans(n_clusters=K, chunk_size=4096, epochs=2,
                          seed=5, compute_labels=False).fit(x)
    b = MiniBatchAAKMeans(n_clusters=K, chunk_size=4096, epochs=2,
                          seed=5, compute_labels=False).fit(x)
    assert a.energy_ == b.energy_
    np.testing.assert_array_equal(np.asarray(a.centroids_),
                                  np.asarray(b.centroids_))


def test_estimator_fit_supersedes_partial_fit_stream(problem):
    """fit() after partial_fit discards the stream: a later partial_fit
    starts fresh instead of advancing the abandoned stream over the
    fitted results, and finalize() refuses until a new stream exists."""
    x = np.asarray(problem[0])
    m = MiniBatchAAKMeans(n_clusters=K, chunk_size=2048, epochs=2, seed=0,
                          compute_labels=False)
    m.partial_fit(x[:2048])
    m.fit(x)
    with pytest.raises(ValueError, match="streaming state"):
        m.finalize()
    m.partial_fit(x[:2048])          # fresh stream, step count restarts
    assert int(m.n_steps_) == 1


def test_estimator_input_validation():
    with pytest.raises(ValueError, match="rows"):
        MiniBatchAAKMeans(n_clusters=8).fit(np.zeros((4, 2), np.float32))
    m = MiniBatchAAKMeans(n_clusters=2)
    # a REAL exception, not a bare assert: survives `python -O` (ISSUE 8)
    from repro.core.api import NotFittedError
    with pytest.raises(NotFittedError, match="fit"):
        m.predict(np.zeros((4, 2), np.float32))
    with pytest.raises(ValueError, match="streaming state"):
        m.finalize()


# -- streamed epoch driver + chunk locality ---------------------------------

def test_stream_chunks_sort_by_orders_rows(problem):
    """``sort_by`` re-orders each chunk's rows by nearest centroid without
    changing WHICH rows a chunk holds (the locality engine's streaming
    analogue: ordering shapes tile-skipping, never the numbers)."""
    x, xt, xv, c0 = problem
    xt_np = np.asarray(xt)[:4096]
    c_np = np.asarray(c0)
    plain = list(stream_chunks(xt_np, 1024, epochs=1, seed=5))
    srt = list(stream_chunks(xt_np, 1024, epochs=1, seed=5, sort_by=c_np))
    assert len(plain) == len(srt) == 4
    for p, s in zip(plain, srt):
        p, s = np.asarray(p), np.asarray(s)
        # same rows, re-ordered
        assert np.array_equal(np.sort(p, axis=0), np.sort(s, axis=0))
        d2 = (np.square(s).sum(-1)[:, None] - 2.0 * s @ c_np.T
              + np.square(c_np).sum(-1)[None, :])
        labels = np.argmin(d2, axis=1)
        assert np.all(np.diff(labels) >= 0)     # cluster-sorted
    # a callable provider is re-read per chunk (the streamed driver
    # passes its live centroids)
    reads = []

    def provider():
        reads.append(1)
        return c_np
    list(stream_chunks(xt_np, 1024, epochs=1, sort_by=provider))
    assert len(reads) == 4


def test_stream_chunks_device_source_rejects_sort_by(problem):
    x, xt, xv, c0 = problem
    dc = chunk_dataset(xt, 2048)
    with pytest.raises(ValueError, match="sort_by"):
        stream_chunks(dc, sort_by=np.asarray(c0))


def test_streamed_driver_matches_quality_and_counts(problem):
    """`aa_kmeans_minibatch_streamed` runs the same per-chunk state
    machine as the device-resident driver over a prefetched host stream;
    with ``sort_chunks`` it must still land within the quality bar, and
    the trace must cover every chunk of every epoch."""
    x, xt, xv, c0 = problem
    full = aa_kmeans(x, c0, KMeansConfig(k=K, max_iter=500))
    xt_np = np.asarray(xt)
    cfg = MiniBatchConfig(k=K, chunk_size=2048, epochs=3)
    n_chunks = -(-xt_np.shape[0] // 2048)
    for sort_chunks in (False, True):
        res, tr = aa_kmeans_minibatch_streamed(
            xt_np, xv, c0, cfg, sort_chunks=sort_chunks,
            return_trace=True)
        assert int(res.n_steps) == 3 * n_chunks
        assert tr.e_val.shape == (3 * n_chunks,)
        e = _full_energy(x, res.centroids)
        assert e <= float(full.energy) * 1.02, (sort_chunks, e)


def test_streamed_driver_iterator_source_and_meter(problem):
    """An explicit chunk-iterator source streams as-is, and the ingest
    meter observes the host→device transfers."""
    from repro.runtime.prefetch import IngestMeter
    x, xt, xv, c0 = problem
    xt_np = np.asarray(xt)[:6144]
    cfg = MiniBatchConfig(k=K, chunk_size=2048, epochs=1)
    meter = IngestMeter()
    it = host_chunk_stream(xt_np, 2048, epochs=1, seed=3)
    res = aa_kmeans_minibatch_streamed(it, xv, c0, cfg, meter=meter)
    assert int(res.n_steps) == 3
    assert meter.chunks == 3 and meter.bytes > 0


# -- benchmark smoke --------------------------------------------------------

@pytest.mark.slow
def test_streaming_sweep_smoke():
    """The benchmark's headline criterion at smoke scale: mini-batch AA
    reaches within 2% of the full-batch final energy reading <= 50% of
    the samples full-batch AA reads."""
    from benchmarks import streaming_sweep
    out = streaming_sweep.main(smoke=True, verbose=False)
    aa = out["quality"]["minibatch-aa"]
    assert aa["reached"], aa
    assert aa["ratio"] <= 0.5, aa
