"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


SHAPES = [(64, 4, 3), (513, 7, 3), (1000, 16, 10), (300, 2, 37),
          (777, 130, 100), (256, 561, 10)]


@pytest.mark.parametrize("n,d,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_assignment_kernel(n, d, k, dtype, rng):
    x = jnp.asarray(rng.standard_normal((n, d)), dtype)
    c = jnp.asarray(rng.standard_normal((k, d)), dtype)
    la, ma = ops.assignment(x, c)
    lr, mr = ref.assignment_ref(x, c)
    # labels must agree exactly (identical arithmetic per (i,k) entry)
    assert (np.asarray(la) == np.asarray(lr)).all()
    np.testing.assert_allclose(ma, mr, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,d,k", SHAPES)
def test_update_kernel(n, d, k, rng):
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    sa, ca = ops.cluster_update(x, labels, k)
    sr, cr = ref.update_ref(x, labels, k)
    np.testing.assert_allclose(sa, sr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ca, cr, rtol=0, atol=0)


@pytest.mark.parametrize("n,d,k", SHAPES)
def test_fused_kernel(n, d, k, rng):
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    lf, mf, sf, cf, ef = ops.fused_lloyd_step(x, c)
    lr, mr, sr, cr, er = ref.fused_lloyd_ref(x, c)
    assert (np.asarray(lf) == np.asarray(lr)).all()
    np.testing.assert_allclose(mf, mr, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(sf, sr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(cf, cr, rtol=0, atol=1e-6)
    np.testing.assert_allclose(ef, er, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 600), d=st.integers(1, 80), k=st.integers(1, 64),
       seed=st.integers(0, 99999))
def test_property_kernels_match_oracle(n, d, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    la, _ = ops.assignment(x, c)
    lr, _ = ref.assignment_ref(x, c)
    assert (np.asarray(la) == np.asarray(lr)).all()
    lf, _, sf, cf, ef = ops.fused_lloyd_step(x, c)
    _, _, sr, cr, er = ref.fused_lloyd_ref(x, c)
    np.testing.assert_allclose(sf, sr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(ef, er, rtol=2e-4)


def test_fused_step_runs_algorithm(rng):
    """fused_step drives a full Lloyd iteration identical to the ref path."""
    from repro.kernels.ops import fused_step
    from repro.core.lloyd import lloyd_iteration
    x = jnp.asarray(rng.standard_normal((500, 12)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((9, 12)), jnp.float32)
    c1, lab1, e1 = fused_step(x, c)
    c2, lab2, e2 = lloyd_iteration(x, c, 9)
    assert (np.asarray(lab1) == np.asarray(lab2)).all()
    np.testing.assert_allclose(c1, c2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)
