"""Locality-engine invariants (DESIGN.md §Locality).

The reordering wrapper's whole contract is "the kernel sees sorted rows,
the caller sees nothing": every test here is some flavour of
*bit-identical outputs* around a permutation that verifiably happened
(non-identity perm, n_sorts > 0).  Three exactness tiers, matching the
engine's documented guarantees:

  * CPU bound backends (hamerly/elkan/yinyang): reorder=True vs the raw
    backend is strictly bitwise on every KMeansResult leaf — the wrapper
    recomputes sums/counts/energy in original row order with the exact
    expressions those backends use.
  * fused_bounds: labels are exact vs raw, but the raw kernel accumulates
    sums/energy in-pass while the wrapper recomputes them — ulp-level
    drift.  The strict bitwise claim is SAME-ENGINE sorted vs
    never-sorted (churn_threshold 0 vs >= 1: identical programs, only the
    sort predicate's data differs).
  * batched: materialising the per-restart permuted (R, N, d) X changes
    the matmul lowering vs the raw path's broadcast shared X, so the
    bitwise claim is again same-program sorted vs never-sorted, plus
    exact labels vs raw.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.backends import distribute, get_backend
from repro.core.kmeans import (KMeansConfig, aa_kmeans, aa_kmeans_batched,
                               split_bound_phases)
from repro.core.locality import (ReorderConfig, counting_sort_perm,
                                 inner_carry, permutation, reorder_backend,
                                 sort_count)
from repro.data.synthetic import make_blobs

jax.config.update("jax_enable_x64", False)

NEVER = ReorderConfig(warmup=2, churn_threshold=1.5)   # sort never fires
ALWAYS = ReorderConfig(warmup=2, churn_threshold=0.0)  # sort on any drift


def _problem(seed=3, n=512, d=8, k=8):
    x = jnp.asarray(make_blobs(n, d, k, seed=seed))
    c0 = jnp.asarray(np.asarray(x)[
        np.random.default_rng(0).permutation(n)[:k]])
    return x, c0, KMeansConfig(k=k, max_iter=40)


def _leaves_equal(a, b):
    fa, fb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(fa) == len(fb) and all(
        bool(jnp.array_equal(u, v)) for u, v in zip(fa, fb))


# ---------------------------------------------------------------------------
# counting sort
# ---------------------------------------------------------------------------


def test_counting_sort_matches_stable_argsort():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(1, 200))
        k = int(rng.integers(1, 16))
        labels = rng.integers(0, k, size=n).astype(np.int32)
        perm, inv = counting_sort_perm(jnp.asarray(labels), k)
        expect = np.argsort(labels, kind="stable")
        assert np.array_equal(np.asarray(perm), expect)
        assert np.array_equal(np.asarray(perm)[np.asarray(inv)],
                              np.arange(n))


def test_counting_sort_empty_clusters_and_tiles():
    # labels concentrated in few of many clusters; tiny tile forces the
    # rank pass through many tile iterations, most over empty labels
    labels = jnp.asarray([5, 5, 0, 9, 5, 0], jnp.int32)
    perm, inv = counting_sort_perm(labels, 12, sort_tile=1)
    expect = np.argsort(np.asarray(labels), kind="stable")
    assert np.array_equal(np.asarray(perm), expect)
    assert np.array_equal(np.asarray(inv),
                          np.argsort(expect, kind="stable"))


def test_counting_sort_segmented_matches_tight_pack():
    """With offsets = the exclusive cumsum of the counts (the tight
    packing), the segmented variant IS counting_sort_perm plus sentinel-
    free slots."""
    from repro.core.locality import counting_sort_perm_segmented
    rng = np.random.default_rng(1)
    for _ in range(10):
        n = int(rng.integers(1, 150))
        k = int(rng.integers(1, 12))
        labels = rng.integers(0, k, size=n).astype(np.int32)
        counts = np.bincount(labels, minlength=k)
        offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
        perm, inv, cnt = counting_sort_perm_segmented(
            jnp.asarray(labels), k, jnp.asarray(offsets, np.int32), n)
        tight, tight_inv = counting_sort_perm(jnp.asarray(labels), k)
        assert np.array_equal(np.asarray(perm), np.asarray(tight))
        assert np.array_equal(np.asarray(inv), np.asarray(tight_inv))
        assert np.array_equal(np.asarray(cnt), counts)


def test_counting_sort_segmented_padded_stripes():
    """The hierarchy layout: offsets = arange(k)*stride lays label-l rows
    into stripe l (stable within the stripe), unfilled slots carry the
    sentinel N, and inv points each row at its stripe slot."""
    from repro.core.locality import counting_sort_perm_segmented
    labels = jnp.asarray([2, 0, 2, 1, 0, 2], jnp.int32)
    stride, k, n = 4, 3, 6
    perm, inv, cnt = counting_sort_perm_segmented(
        labels, k, jnp.arange(k, dtype=jnp.int32) * stride, k * stride,
        sort_tile=2)
    p = np.asarray(perm)
    assert np.array_equal(cnt, [2, 1, 3])
    assert np.array_equal(p[0:2], [1, 4]) and (p[2:4] == n).all()
    assert np.array_equal(p[4:5], [3]) and (p[5:8] == n).all()
    assert np.array_equal(p[8:11], [0, 2, 5]) and (p[11:] == n).all()
    assert np.array_equal(np.asarray(inv), [8, 0, 9, 4, 1, 10])


# ---------------------------------------------------------------------------
# driver-level bitwise equality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["hamerly", "elkan", "yinyang"])
def test_solve_bitwise_vs_raw(name):
    x, c0, cfg = _problem()
    res_raw = aa_kmeans(x, c0, cfg, backend=name)
    res_ro = aa_kmeans(x, c0, cfg, backend=name, reorder=True)
    assert _leaves_equal(res_raw, res_ro)


def test_fused_bounds_labels_exact_and_same_engine_bitwise():
    x, c0, cfg = _problem()
    res_raw = aa_kmeans(x, c0, cfg, backend="fused_bounds")
    res_never = aa_kmeans(x, c0, cfg, backend="fused_bounds", reorder=NEVER)
    res_sorted = aa_kmeans(x, c0, cfg, backend="fused_bounds",
                           reorder=ALWAYS)
    assert bool(jnp.array_equal(res_raw.labels, res_sorted.labels))
    assert _leaves_equal(res_never, res_sorted)


def test_batched_same_program_bitwise_and_labels_exact():
    x, c0, cfg = _problem()
    c0s = jnp.stack([c0, jnp.flip(c0, axis=0)])
    raw = aa_kmeans_batched(x, c0s, cfg, backend="fused_bounds")
    never = aa_kmeans_batched(x, c0s, cfg, backend="fused_bounds",
                              reorder=NEVER)
    srt = aa_kmeans_batched(x, c0s, cfg, backend="fused_bounds",
                            reorder=ALWAYS)
    assert _leaves_equal(never, srt)
    assert bool(jnp.array_equal(raw.labels, srt.labels))


# ---------------------------------------------------------------------------
# the sort actually happens / the churn trigger gates it
# ---------------------------------------------------------------------------


def _carry_probe(name, config, steps=6, seed=3):
    """Drive raw steps and return the final wrapper carry."""
    x, c0, _ = _problem(seed=seed)
    k = c0.shape[0]
    bk = reorder_backend(get_backend(name), config)
    carry = bk.init_carry(x, c0, k)
    c = c0
    step = jax.jit(lambda a, b, cr: bk.step(a, b, k, cr))
    for _ in range(steps):
        (res, carry) = step(x, c, carry)
        c = bk.centroids_from_step(x, res, k, c)
    return carry


def test_churn_trigger_fires():
    carry = _carry_probe("elkan", ALWAYS)
    assert int(sort_count(carry)) > 0
    assert not np.array_equal(np.asarray(permutation(carry)),
                              np.arange(512))


@pytest.mark.parametrize("config", [NEVER, ReorderConfig(warmup=10 ** 6)])
def test_churn_trigger_held_off(config):
    carry = _carry_probe("elkan", config)
    assert int(sort_count(carry)) == 0
    assert np.array_equal(np.asarray(permutation(carry)), np.arange(512))


# ---------------------------------------------------------------------------
# checkpoint / resume with a live permutation
# ---------------------------------------------------------------------------


def test_resume_mid_sort_bitwise(tmp_path):
    x, c0, cfg = _problem()
    snaps = {}
    res_full = aa_kmeans(x, c0, cfg, backend="elkan", reorder=True,
                         checkpoint_every=3,
                         checkpoint_cb=lambda st, t: snaps.setdefault(t, st))
    t0 = min(snaps)
    carry = snaps[t0].carry
    # the snapshot really holds a mid-solve permutation, not identity
    assert int(sort_count(carry)) > 0
    assert not np.array_equal(np.asarray(permutation(carry)),
                              np.arange(512))
    res_resumed = aa_kmeans(x, c0, cfg, backend="elkan", reorder=True,
                            checkpoint_every=3, resume_from=snaps[t0])
    assert _leaves_equal(res_full, res_resumed)


def test_resume_rejects_reorder_mismatch(tmp_path):
    x, c0, cfg = _problem()
    aa_kmeans(x, c0, cfg, backend="elkan", reorder=True,
              checkpoint_every=3, checkpoint_dir=tmp_path)
    ckpts = sorted(tmp_path.glob("*.npz"))
    assert ckpts
    with pytest.raises(ValueError, match="backend"):
        aa_kmeans(x, c0, cfg, backend="elkan",
                  checkpoint_every=3, resume_from=ckpts[-1])


# ---------------------------------------------------------------------------
# composition and guard rails
# ---------------------------------------------------------------------------


def test_wrapper_rejects_boundless_inner():
    x, c0, _ = _problem()
    bk = reorder_backend(get_backend("dense"))
    with pytest.raises(TypeError, match="bound-carrying"):
        bk.init_carry(x, c0, c0.shape[0])


def test_distribute_composition_order():
    inner = get_backend("hamerly")
    dist = distribute(reorder_backend(inner), ("data",))
    assert dist.axes == ("data",)
    with pytest.raises(ValueError, match="shard-local"):
        reorder_backend(distribute(inner, ("data",)))


def test_registry_variants_resolve():
    bk = get_backend("elkan_reorder", warmup=5, churn_threshold=0.5)
    assert bk.name == "elkan+reorder"
    assert bk is not get_backend("elkan_reorder")   # different config
    assert get_backend("elkan_reorder") is get_backend("elkan_reorder")


# ---------------------------------------------------------------------------
# bound-stats phase split (the PR-9 dilution bugfix)
# ---------------------------------------------------------------------------


def test_split_bound_phases_pins_split():
    stats = [{"skipped_frac": s} for s in (0.0, 0.0, 0.6, 0.8)]
    accepted = [False, False, True, True]
    phases = split_bound_phases(accepted, stats)
    assert phases["pre_accept"]["n_iters"] == 2
    assert phases["pre_accept"]["skipped_frac"] == 0.0
    assert phases["post_accept"]["n_iters"] == 2
    assert phases["post_accept"]["skipped_frac"] == pytest.approx(0.7)
    # a flat mean would have reported 0.35 — the dilution this fixes
    assert phases["post_accept"]["skipped_frac"] > 0.5


def test_split_bound_phases_edge_cases():
    assert split_bound_phases([True], []) == {}
    phases = split_bound_phases([False, False],
                                [{"skipped_frac": 0.1}] * 2)
    assert phases["post_accept"]["n_iters"] == 0
    assert phases["post_accept"]["skipped_frac"] is None
    assert phases["pre_accept"]["n_iters"] == 2
