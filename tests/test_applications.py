"""K-Means <-> LM integration (applications.py) and dry-run helpers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.applications import (compress_kv_cache, embedding_codebook,
                                     kv_codebook)


def test_kv_codebook_error_decreases_with_k(rng):
    v = jnp.asarray(rng.standard_normal((2000, 16)), jnp.float32)
    errs = []
    for k in (2, 8, 32):
        cb, codes, res = kv_codebook(v, k)
        rec = cb[codes]
        errs.append(float(jnp.linalg.norm(rec - v)))
        assert cb.shape == (k, 16)
        assert int(res.n_iter) >= 1
    assert errs[0] > errs[1] > errs[2]


def test_compress_kv_cache_shapes(rng):
    cache = {"k": jnp.asarray(rng.standard_normal((2, 3, 10, 2, 8)),
                              jnp.float32),
             "v": jnp.asarray(rng.standard_normal((2, 3, 10, 2, 8)),
                              jnp.float32),
             "len": jnp.full((3,), 8, jnp.int32)}
    out, err = compress_kv_cache(dict(cache), k=4, valid_len=8)
    assert out["k"].shape == cache["k"].shape
    assert 0.0 <= err <= 1.5
    # beyond valid_len untouched
    np.testing.assert_array_equal(np.asarray(out["k"][..., 8:, :, :]),
                                  np.asarray(cache["k"][..., 8:, :, :]))


def test_embedding_codebook(rng):
    table = jnp.asarray(rng.standard_normal((256, 32)), jnp.float32)
    cbs, codes, err = embedding_codebook(table, k=16, n_subspaces=4)
    assert cbs.shape == (4, 16, 8)
    assert codes.shape == (256, 4)
    assert err < 1.0


# ----------------------------------------------------------- dryrun helpers

def test_parse_collectives_toy_hlo():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %ag = f32[128,64]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}
  %ar = bf16[32,32]{1,0} all-reduce(%y), replica_groups=[8,32]<=[256], to_apply=%sum
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %fusion.1 = f32[2,2]{1,0} fusion(%a), kind=kLoop
"""
    operand, wire, counts = parse_collectives(hlo)
    assert counts == {"all-gather": 1, "all-reduce": 1,
                      "reduce-scatter": 0, "all-to-all": 0,
                      "collective-permute": 1}
    assert operand["all-gather"] == 128 * 64 * 4 // 16
    assert operand["all-reduce"] == 32 * 32 * 2
    assert wire["all-reduce"] == pytest.approx(2 * 32 * 32 * 2 * 31 / 32)
    assert wire["collective-permute"] == 8 * 4


def test_model_flops_conventions():
    from repro.configs.registry import get_config
    from repro.launch.dryrun import model_flops
    from repro.models.config import SHAPES
    cfg = get_config("qwen1.5-110b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    assert f_train == pytest.approx(6 * cfg.n_params() * 256 * 4096,
                                    rel=1e-6)
    moe = get_config("mixtral-8x7b")
    f_moe = model_flops(moe, SHAPES["train_4k"])
    assert f_moe == pytest.approx(6 * moe.n_active_params() * 256 * 4096,
                                  rel=1e-6)
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert f_dec == pytest.approx(2 * cfg.n_params() * 128, rel=1e-6)


def test_calibration_units():
    from repro.configs.registry import get_config
    from repro.launch.dryrun import n_units, with_units
    assert n_units(get_config("qwen1.5-110b")) == 80
    assert n_units(get_config("zamba2-2.7b")) == 9
    assert n_units(get_config("llama-3.2-vision-11b")) == 8
    c2 = with_units(get_config("zamba2-2.7b"), 2)
    assert c2.n_layers == 12
