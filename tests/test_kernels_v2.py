"""Kernel-engine v2 tests (ISSUE 4): k-tiled single-pass fused kernel,
native weights, leading-R batching, VMEM-aware tile chooser.

Everything runs in interpret mode on this host; parity is against the
pure-jnp oracles in kernels/ref.py.  The pass-count tests count *kernel
executions* (a host callback stitched into the traced program fires per
run, through jit / lax.while_loop / lax.cond) — the physical-X-read
analogue of test_backends' step counting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backends as B
from repro.core.backends import pallas as P
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import KMeansConfig, aa_kmeans
from repro.data.synthetic import make_blobs
from repro.kernels import ref, tiles
from repro.kernels.assignment import assignment_pallas
from repro.kernels.fused_lloyd import fused_lloyd_pallas
from repro.kernels.update import update_pallas

# non-tile-multiple N/K/d; tiles forced small so every shape exercises a
# multi-tile (n_tiles, k_tiles) grid in interpret mode
SHAPES = [(97, 5, 33), (130, 17, 9), (64, 3, 70)]
TILES = dict(tn=16, tk=8)


def _mk(n, d, k, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, d)), dtype)
    c = jnp.asarray(rng.standard_normal((k, d)), dtype)
    return x, c


def _spy(monkeypatch, module, name):
    """Wrap module.name so executions (not traces) are counted: the
    callback is stitched into the traced program and fires per run."""
    calls = []
    real = getattr(module, name)

    def wrapper(*a, **kw):
        jax.debug.callback(lambda: calls.append(1))
        return real(*a, **kw)

    monkeypatch.setattr(module, name, wrapper)
    return calls


# -- parity ----------------------------------------------------------------

@pytest.mark.parametrize("n,d,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ktiled_parity(n, d, k, dtype):
    x, c = _mk(n, d, k, dtype)
    lf, mf, sf, cf, ef = fused_lloyd_pallas(x, c, interpret=True, **TILES)
    lr, mr, sr, cr, er = ref.fused_lloyd_ref(x, c)
    assert (np.asarray(lf) == np.asarray(lr)).all()
    tol = dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 \
        else dict(rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(mf, mr, **tol)
    # stats are exact for the assignment made, at the compute dtype
    sr2, cr2 = ref.update_ref(x, lf, k)
    np.testing.assert_allclose(sf, sr2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(cf, cr2, rtol=0, atol=1e-6)
    np.testing.assert_allclose(float(ef), float(np.asarray(mf).sum()),
                               rtol=1e-4)


@pytest.mark.parametrize("n,d,k", SHAPES)
def test_fused_weighted_parity(n, d, k):
    x, c = _mk(n, d, k)
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.uniform(0.0, 2.0, n), jnp.float32).at[n // 2:].set(0)
    got = fused_lloyd_pallas(x, c, w, interpret=True, **TILES)
    want = ref.minibatch_ref(x, c, w)
    assert (np.asarray(got[0]) == np.asarray(want[0])).all()
    for g, wnt, tol in [(got[2], want[2], 1e-4), (got[3], want[3], 1e-5)]:
        np.testing.assert_allclose(g, wnt, rtol=tol, atol=tol)
    np.testing.assert_allclose(float(got[4]), float(want[4]), rtol=1e-4)


@pytest.mark.parametrize("x_batched", [False, True])
def test_fused_batched_parity(x_batched):
    n, d, k, r = 97, 5, 33, 3
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(
        (r, n, d) if x_batched else (n, d)), jnp.float32)
    cs = jnp.asarray(rng.standard_normal((r, k, d)), jnp.float32)
    lf, mf, sf, cf, ef = fused_lloyd_pallas(x, cs, interpret=True, **TILES)
    assert lf.shape == (r, n) and sf.shape == (r, k, d)
    for rr in range(r):
        xr = x[rr] if x_batched else x
        lr, mr, sr, cr, er = ref.fused_lloyd_ref(xr, cs[rr])
        assert (np.asarray(lf[rr]) == np.asarray(lr)).all(), rr
        np.testing.assert_allclose(sf[rr], sr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(float(ef[rr]), float(er), rtol=1e-4)


def test_assignment_batched_parity():
    n, d, k, r = 130, 17, 9, 2
    x, _ = _mk(n, d, k, seed=5)
    cs = jnp.stack([_mk(n, d, k, seed=s)[1] for s in (1, 2)])
    la, ma = assignment_pallas(x, cs, interpret=True, **TILES)
    for rr in range(r):
        lr, mr = ref.assignment_ref(x, cs[rr])
        assert (np.asarray(la[rr]) == np.asarray(lr)).all()
        np.testing.assert_allclose(ma[rr], mr, rtol=2e-5, atol=2e-5)


def test_update_weighted_and_batched_parity():
    n, d, k = 97, 5, 33
    x, _ = _mk(n, d, k)
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.uniform(0.0, 1.0, n), jnp.float32)
    labels = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    sa, ca = update_pallas(x, labels, k, w=w, interpret=True, **TILES)
    sr, cr = ref.update_ref(x, labels, k, w=w)
    np.testing.assert_allclose(sa, sr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ca, cr, rtol=1e-5, atol=1e-5)
    lb = jnp.stack([labels, (labels + 1) % k])
    sb, cb = update_pallas(x, lb, k, interpret=True, **TILES)
    for rr in range(2):
        sr, cr = ref.update_ref(x, lb[rr], k)
        np.testing.assert_allclose(sb[rr], sr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(cb[rr], cr, rtol=0, atol=0)


def test_k_straddling_old_gate_stays_fused(monkeypatch):
    """A K*d block bigger than the (monkeypatched) budget k-tiles via the
    chooser and stays correct — v1 would have refused this shape."""
    n, d, k = 120, 6, 40
    x, c = _mk(n, d, k, seed=9)
    monkeypatch.setattr(tiles, "DEFAULT_VMEM_BUDGET", k * d * 4 - 1)
    tn, tk = tiles.choose_tiles(n, k, d, 4, kind="fused")
    assert tk < tiles.round_up(k, 8), "budget must force k-tiling"
    got = fused_lloyd_pallas(x, c, interpret=True)
    want = ref.fused_lloyd_ref(x, c)
    assert (np.asarray(got[0]) == np.asarray(want[0])).all()
    np.testing.assert_allclose(got[2], want[2], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(got[4]), float(want[4]), rtol=1e-4)


# -- tile chooser ----------------------------------------------------------

def test_tile_chooser_fits_budget_and_floors():
    budget = 256 * 1024
    for kind in ("fused", "assignment", "update"):
        tn, tk = tiles.choose_tiles(100_000, 1000, 64, 4, kind=kind,
                                    vmem_bytes=budget)
        assert tn % 8 == 0 and tk % 8 == 0
        kp = tiles.round_up(1000, tk)
        # tile-dependent cost fits what the (resident-capped) budget
        # leaves; the fused accumulator may irreducibly exceed its half
        charged = min(tiles._resident(kind, kp, 128), budget // 2)
        assert tiles._tile_cost(kind, tn, tk, 128, 4) + charged <= budget \
            or (tn == 8 and tk == 8)
    # ample budget: full 512 tiles
    assert tiles.choose_tiles(100_000, 1000, 8, 4, kind="assignment",
                              vmem_bytes=64 << 20) == (512, 512)
    # tiny problems never exceed their own (padded) extent
    tn, tk = tiles.choose_tiles(3, 2, 2, 4)
    assert tn == 8 and tk == 8


def test_tile_chooser_respects_dtype():
    # bf16 halves the streamed bytes -> same budget affords wider tiles
    # (and the sublane floor doubles)
    args = dict(kind="assignment", vmem_bytes=600 * 1024)
    tn32, tk32 = tiles.choose_tiles(65_536, 4096, 256, 4, **args)
    tn16, tk16 = tiles.choose_tiles(65_536, 4096, 256, 2, **args)
    assert tn16 * tk16 >= tn32 * tk32
    assert tiles.sublane(2) == 16 and tiles.sublane(4) == 8


# -- pass counts (physical X reads) ----------------------------------------

@pytest.fixture()
def blobs():
    k = 24
    x = jnp.asarray(make_blobs(600, 6, k, seed=2, spread=3.0))
    c0 = kmeanspp_init(jax.random.PRNGKey(1), x, k)
    return x, c0, k


def test_large_k_fused_solver_is_single_pass(blobs, monkeypatch):
    """With K*d over the (monkeypatched) VMEM budget, the fused solver
    still executes exactly 2t - a fused-kernel runs — one physical X read
    per step, no two-kernel fallback (v1 split every step here: 2 reads).
    """
    x, c0, k = blobs
    monkeypatch.setattr(tiles, "DEFAULT_VMEM_BUDGET", k * x.shape[1] * 4 - 1)
    kernel_runs = _spy(monkeypatch, P, "fused_lloyd_pallas")
    split_runs = _spy(monkeypatch, P, "assignment_pallas")
    steps = []
    backend = B.instrument(B.get_backend("fused"),
                           lambda: steps.append(1))
    cfg = KMeansConfig(k=k, max_iter=300)
    res = jax.jit(lambda a, b: aa_kmeans(a, b, cfg, backend=backend))(x, c0)
    jax.block_until_ready(res.centroids)
    jax.effects_barrier()
    assert bool(res.converged)
    t, n_acc = int(res.n_iter), int(res.n_accepted)
    assert len(steps) == 2 * t - n_acc, (len(steps), t, n_acc)
    assert len(kernel_runs) == len(steps), "each step must be ONE fused run"
    assert not split_runs, "no fallback to the two-kernel path"


def test_native_minibatch_drops_segment_sum_pass(monkeypatch):
    """pallas/fused minibatch steps are native: the generic fallback's
    extra weighted segment-sum pass over the chunk must not run, and the
    fused chunk step must be ONE kernel execution."""
    from repro.core import lloyd as L
    x, c = _mk(257, 6, 11, seed=4)
    w = jnp.ones((257,), jnp.float32).at[200:].set(0.0)
    segsum_runs = _spy(monkeypatch, L, "weighted_cluster_sums")
    fused_runs = _spy(monkeypatch, P, "fused_lloyd_pallas")
    want = ref.minibatch_ref(x, c, w)
    for name in ("pallas", "fused"):
        backend = B.get_backend(name)
        assert backend.minibatch_step_fn is not None
        res, _ = backend.minibatch_step(x, c, 11, w, ())
        jax.block_until_ready(res.sums)
        jax.effects_barrier()
        np.testing.assert_allclose(res.sums, want[2], rtol=1e-4, atol=1e-4,
                                   err_msg=name)
        np.testing.assert_allclose(float(res.energy), float(want[4]),
                                   rtol=1e-4, err_msg=name)
    assert not segsum_runs, "native weighted kernels skip the extra pass"
    assert len(fused_runs) == 1, "fused chunk step is one kernel run"


def test_instrument_counts_native_slots_once():
    """instrument() must count a native batched/minibatch step as exactly
    one pass (the fallback path used to route through the counted step_fn
    — a native slot must not be double- or un-counted)."""
    x, c = _mk(64, 4, 5, seed=6)
    w = jnp.ones((64,), jnp.float32)
    cs = jnp.stack([c, c + 0.5])
    for name in ("pallas", "fused"):
        passes = []
        bk = B.instrument(B.get_backend(name), lambda: passes.append(1))
        bk.minibatch_step(x, c, 5, w, ())
        jax.effects_barrier()
        assert len(passes) == 1, (name, passes)
        bk.batched_step(x, cs, 5, ((), ()))
        jax.effects_barrier()
        assert len(passes) == 2, (name, passes)


def test_minibatch_guard_runs_native_batched_kernel(monkeypatch):
    """Wiring: one streaming iteration on the fused backend = the R=2
    validation guard plus the weighted chunk pass, BOTH as native fused
    kernel runs (v1 vmapped pl.pallas_call for the guard and paid the
    fallback's segment-sum for the chunk)."""
    from repro.core.minibatch import (MiniBatchConfig, minibatch_init,
                                      minibatch_iteration)
    k = 5
    x = jnp.asarray(make_blobs(512, 4, k, seed=3, spread=4.0))
    xc, xv = x[:384], x[384:]
    w = jnp.ones((384,), jnp.float32)
    c0 = kmeanspp_init(jax.random.PRNGKey(0), x, k)
    fused_runs = _spy(monkeypatch, P, "fused_lloyd_pallas")
    backend = B.get_backend("fused")
    cfg = MiniBatchConfig(k=k, chunk_size=384)
    state = minibatch_init(c0, cfg, backend)
    state, _ = minibatch_iteration(xc, w, xv, state, cfg, backend)
    jax.block_until_ready(state.c)
    jax.effects_barrier()
    assert len(fused_runs) == 2, fused_runs
