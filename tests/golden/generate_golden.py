"""Golden-trajectory generator + single source of truth for the
regression in tests/test_golden.py.

`compute_trajectory()` runs a small fixed-seed full-batch AA solve on the
dense backend through the jitted `_iteration` body and records the
per-iteration post-revert energies and labels plus the final centroids —
exactly the quantities whose silent drift the golden test guards.

Regenerating the file is an *intentional numerics change* and belongs in
its own reviewed commit:

    PYTHONPATH=src JAX_PLATFORMS=cpu python tests/golden/generate_golden.py

The stored trajectory is CPU-XLA specific; the test compares bitwise on
CPU and falls back to tolerances elsewhere.
"""

from pathlib import Path

import numpy as np

GOLDEN_PATH = Path(__file__).parent / "aa_dense_cpu.npz"
# overlapping clusters (spread 0.9): long enough a trajectory (~25
# iterations, mixed accepts and reverts) to pin the guard dynamics, small
# enough to rerun in milliseconds
N, D, K, SEED, SPREAD, MAX_ITER = 400, 6, 6, 0, 0.9, 200


def compute_trajectory():
    import jax
    import jax.numpy as jnp
    from repro.core import kmeans as KM
    from repro.core.init_schemes import kmeanspp_init
    from repro.core.kmeans import KMeansConfig
    from repro.core.backends import get_backend
    from repro.data.synthetic import make_blobs

    x = jnp.asarray(make_blobs(N, D, K, seed=SEED, spread=SPREAD))
    c0 = kmeanspp_init(jax.random.PRNGKey(SEED), x, K)
    cfg = KMeansConfig(k=K, max_iter=MAX_ITER)
    backend = get_backend("dense")

    init_fn = jax.jit(KM._init_state, static_argnames=("cfg", "backend"))
    iter_fn = jax.jit(KM._iteration, static_argnames=("cfg", "backend"))

    state = init_fn(x, c0, cfg, backend)
    energies, labels = [], []
    for _ in range(MAX_ITER):
        state, conv, _, e_t = iter_fn(x, state, cfg, backend)
        if bool(conv):
            break
        energies.append(np.asarray(e_t))
        labels.append(np.asarray(state.labels))
    return {
        "energies": np.stack(energies),              # (T,) f32, exact bits
        "labels": np.stack(labels).astype(np.int32),  # (T, N)
        "centroids": np.asarray(state.c, np.float32),  # (K, d)
        "shape": np.array([N, D, K, SEED], np.int64),
    }


if __name__ == "__main__":
    traj = compute_trajectory()
    np.savez_compressed(GOLDEN_PATH, **traj)
    print(f"wrote {GOLDEN_PATH}: T={traj['energies'].shape[0]} iterations, "
          f"final E={traj['energies'][-1]:.6f}")
