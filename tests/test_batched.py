"""Batched multi-restart engine: parity, masking, selection, integration.

Numerics note (mirrors the distributed tests' psum caveat): the batched
dense step computes cross-terms and cluster stats with batched matmuls
whose reduction order differs from the sequential scatter path in the
last ulp.  On separated data the trajectories are decision-identical
(exact label/iteration equality below); near-degenerate endgames can
legitimately flip one accept test and converge to an equally-good
optimum a few iterations earlier or later, so the harder-data checks
assert energy quality, not step equality.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import AAKMeans
from repro.core.init_schemes import batched_init, kmeanspp_init
from repro.core.kmeans import (KMeansConfig, aa_kmeans, aa_kmeans_batched,
                               select_best)
from repro.data.synthetic import make_blobs


def _problem(n=2000, d=6, k=5, seed=0, spread=4.0, restarts=4):
    x = jnp.asarray(make_blobs(n, d, k, seed=seed, spread=spread))
    keys = jax.random.split(jax.random.PRNGKey(seed), restarts)
    c0s = batched_init("kmeans++", keys, x, k)
    return x, c0s, KMeansConfig(k=k, max_iter=300)


def test_batched_matches_sequential_trajectories():
    """Per-restart decision parity on the dense backend: identical
    iteration/acceptance counts and final labels, energies to f32
    reduction-order tolerance."""
    x, c0s, cfg = _problem()
    bat = jax.jit(lambda a, b: aa_kmeans_batched(a, b, cfg))(x, c0s)
    for r in range(c0s.shape[0]):
        seq = aa_kmeans(x, c0s[r], cfg)
        assert int(bat.n_iter[r]) == int(seq.n_iter)
        assert int(bat.n_accepted[r]) == int(seq.n_accepted)
        assert bool(bat.converged[r]) == bool(seq.converged)
        np.testing.assert_array_equal(np.asarray(bat.labels[r]),
                                      np.asarray(seq.labels))
        np.testing.assert_allclose(float(bat.energy[r]), float(seq.energy),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(bat.centroids[r]),
                                   np.asarray(seq.centroids),
                                   rtol=1e-4, atol=1e-4)


def test_batched_single_restart_is_bitwise_sequential():
    """R=1 through the vmap(step) fallback has no batched-matmul
    reduction reordering: results must be bit-identical to aa_kmeans.
    (The dense backend's *native* batched step swaps segment-sum stats
    for a one-hot matmul, so it is decision-identical but not bitwise —
    covered by the trajectory test above.)"""
    x, c0s, cfg = _problem(seed=3, spread=1.5)
    seq = jax.jit(
        lambda a, b: aa_kmeans(a, b, cfg, backend="blocked"))(x, c0s[0])
    bat = jax.jit(
        lambda a, b: aa_kmeans_batched(a, b, cfg, backend="blocked"))(
            x, c0s[:1])
    assert int(bat.n_iter[0]) == int(seq.n_iter)
    assert int(bat.n_accepted[0]) == int(seq.n_accepted)
    assert float(bat.energy[0]) == float(seq.energy)
    np.testing.assert_array_equal(np.asarray(bat.centroids[0]),
                                  np.asarray(seq.centroids))


def test_masked_convergence_freezes_finished_restarts():
    """Restarts converge at different iterations; each batched restart
    must stop exactly where its sequential counterpart does — the shared
    loop running longer for slow restarts must not perturb finished ones."""
    x, c0s, cfg = _problem(n=1500, k=6, seed=2, spread=4.0, restarts=6)
    bat = aa_kmeans_batched(x, c0s, cfg)
    iters = [int(v) for v in bat.n_iter]
    assert len(set(iters)) > 1, "test needs heterogeneous convergence"
    for r in range(6):
        seq = aa_kmeans(x, c0s[r], cfg)
        assert iters[r] == int(seq.n_iter)
        assert bool(bat.converged[r])


def test_select_best_matches_python_loop():
    x, c0s, cfg = _problem(seed=5, spread=4.0, restarts=8)
    bat = select_best(aa_kmeans_batched(x, c0s, cfg))
    seq_best = min((aa_kmeans(x, c0s[r], cfg) for r in range(8)),
                   key=lambda res: float(res.energy))
    np.testing.assert_allclose(float(bat.energy), float(seq_best.energy),
                               rtol=1e-5)
    assert bat.centroids.shape == seq_best.centroids.shape
    assert bat.labels.ndim == 1


def test_batched_problem_axis():
    """(R, N, d) mode: independent datasets solved in one program."""
    k = 5
    xs = jnp.stack([jnp.asarray(make_blobs(800, 6, k, seed=s, spread=3.0))
                    for s in range(3)])
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    c0s = batched_init("kmeans++", keys, xs, k)
    cfg = KMeansConfig(k=k, max_iter=200)
    bat = aa_kmeans_batched(xs, c0s, cfg)
    for g in range(3):
        seq = aa_kmeans(xs[g], c0s[g], cfg)
        assert int(bat.n_iter[g]) == int(seq.n_iter)
        np.testing.assert_allclose(float(bat.energy[g]), float(seq.energy),
                                   rtol=1e-5)


@pytest.mark.parametrize("backend", ["blocked", "hamerly"])
def test_batched_vmap_fallback_backends(backend):
    """Backends without a native batched step run through vmap(step) —
    including a stateful carry (hamerly bounds)."""
    x, c0s, cfg = _problem(n=1024, seed=7)
    bat = aa_kmeans_batched(x, c0s, cfg, backend=backend)
    for r in range(c0s.shape[0]):
        seq = aa_kmeans(x, c0s[r], cfg, backend=backend)
        assert int(bat.n_iter[r]) == int(seq.n_iter)
        np.testing.assert_allclose(float(bat.energy[r]), float(seq.energy),
                                   rtol=1e-5)


def test_batched_shape_validation():
    x, c0s, cfg = _problem()
    with pytest.raises(ValueError, match=r"\(R, K, d\)"):
        aa_kmeans_batched(x, c0s[0], cfg)
    with pytest.raises(ValueError, match="problems"):
        aa_kmeans_batched(jnp.stack([x, x]), c0s[:3], cfg)


def test_batched_quality_on_overlapping_data():
    """Harder, overlapping clusters: every batched restart must reach an
    energy within 1% of its sequential twin's (decision flips near
    convergence may land on a neighbouring optimum — either driver's —
    but never degrade solution quality materially; cf. the repo's
    Lloyd-vs-AA MSE-parity bound)."""
    x = jnp.asarray(make_blobs(3000, 8, 10, seed=11, spread=1.0))
    keys = jax.random.split(jax.random.PRNGKey(1), 6)
    c0s = batched_init("kmeans++", keys, x, 10)
    cfg = KMeansConfig(k=10, max_iter=500)
    bat = aa_kmeans_batched(x, c0s, cfg)
    for r in range(6):
        seq = aa_kmeans(x, c0s[r], cfg)
        assert float(bat.energy[r]) <= float(seq.energy) * 1.01
        assert bool(bat.converged[r])


def test_batched_init_shapes_and_vmap_parity():
    x = jnp.asarray(make_blobs(600, 5, 4, seed=0))
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    c0s = batched_init("kmeans++", keys, x, 4)
    assert c0s.shape == (3, 4, 5)
    # vmapped seeding must equal per-key seeding
    for r in range(3):
        np.testing.assert_allclose(np.asarray(c0s[r]),
                                   np.asarray(kmeanspp_init(keys[r], x, 4)),
                                   rtol=1e-6)
    # host-loop fallback schemes stack the same shape
    c0s_bf = batched_init("bf", keys, x, 4)
    assert c0s_bf.shape == (3, 4, 5)


def test_estimator_batched_fit_matches_loop_best():
    """AAKMeans(n_init=8).fit: one jit'd batched solve whose winner
    matches the sequential restart loop's best energy."""
    x = make_blobs(2000, 6, 5, seed=0, spread=4.0)
    m = AAKMeans(n_clusters=5, n_init=8, init="kmeans++", seed=0).fit(x)
    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    c0s = batched_init("kmeans++", keys, jnp.asarray(x), 5)
    cfg = m._config()
    seq_best = min((float(aa_kmeans(jnp.asarray(x), c0s[r], cfg).energy)
                    for r in range(8)))
    np.testing.assert_allclose(m.energy_, seq_best, rtol=1e-5)
    assert m.labels_.shape == (2000,)
