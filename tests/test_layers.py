"""Layer-level references: blockwise attention vs dense, SSD vs recurrence,
MoE dispatch vs dense expert evaluation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models import layers as L
from repro.models import params as pr
from repro.models.config import ModelConfig
from repro.models.moe import moe_ffn, moe_spec
from repro.models.ssm import _ssd_chunked, ssd_reference


def dense_attention_ref(q, k, v, causal=True, window=None):
    b, s, h, hd = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bqkgs", qg,
                        k.astype(jnp.float32)) / np.sqrt(hd)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    scores = jnp.where(mask[None, :, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd)


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (6, 2)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 8),
                                           (False, None)])
@pytest.mark.parametrize("skip", [False, True])
def test_blockwise_attention_matches_dense(h, hkv, causal, window, skip,
                                           rng):
    b, s, hd = 2, 32, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    cfg = L.AttnBlockCfg(block_q=8, block_kv=8, skip_blocks=skip)
    out = L.blockwise_attention(q, k, v, causal=causal, window=window,
                                cfg=cfg)
    ref = dense_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_attention_unroll_matches_scan(rng):
    b, s, h, hd = 2, 32, 4, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    a = L.blockwise_attention(q, k, v, cfg=L.AttnBlockCfg(8, 8, False,
                                                          False))
    bb = L.blockwise_attention(q, k, v, cfg=L.AttnBlockCfg(8, 8, False,
                                                           True))
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(bb, np.float32), rtol=1e-5,
                               atol=1e-5)


def test_decode_attention_matches_dense(rng):
    b, t, h, hkv, hd = 3, 24, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, t, hkv, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, t, hkv, hd)), jnp.float32)
    lens = jnp.asarray([5, 24, 17], jnp.int32)
    out = L.decode_attention(q, kc, vc, lens)
    for i, ln in enumerate([5, 24, 17]):
        ref = dense_attention_ref(q[i:i + 1], kc[i:i + 1, :ln],
                                  vc[i:i + 1, :ln], causal=False)
        np.testing.assert_allclose(np.asarray(out[i:i + 1], np.float32),
                                   np.asarray(ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 32, 64]), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 1000))
def test_property_ssd_chunk_invariance(s, chunk, seed):
    """SSD output must not depend on the chunk size."""
    rng = np.random.default_rng(seed)
    b, h, p, g, n = 2, 2, 4, 1, 8
    xh = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, g, n)) * .5, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, g, n)) * .5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.1, 1.0, h), jnp.float32)
    y_ref, st_ref = ssd_reference(xh, bm, cm, dt, a)
    y, stt = _ssd_chunked(xh, bm, cm, dt, a, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(stt), np.asarray(st_ref),
                               rtol=3e-4, atol=3e-4)


def _moe_cfg(e=8, k=2, cap=64.0):
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                       vocab=32, n_heads=2, n_kv_heads=2, head_dim=8,
                       d_ff=32, n_experts=e, top_k=k, capacity_factor=cap)


def test_moe_matches_dense_when_no_drops(rng):
    """With huge capacity, dispatch == dense weighted expert evaluation."""
    cfg = _moe_cfg(cap=1000.0)
    specs = moe_spec(cfg)
    p = pr.init_tree(specs, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert float(aux["moe_dropped"]) == 0.0

    # dense reference: every expert on every token, weighted combine
    xt = x.reshape(-1, 16)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    y_all = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        y_all.append(h @ p["w_down"][e])
    y_all = jnp.stack(y_all, 1)                     # (n, E, d)
    ref = jnp.zeros_like(xt)
    for j in range(cfg.top_k):
        ref = ref + top_p[:, j:j + 1] * jnp.take_along_axis(
            y_all, top_e[:, j][:, None, None].repeat(16, -1), 1)[:, 0]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 16)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_moe_drops_under_tight_capacity(rng):
    cfg = _moe_cfg(cap=0.1)
    specs = moe_spec(cfg)
    p = pr.init_tree(specs, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    out, aux = moe_ffn(p, x, cfg)
    assert float(aux["moe_dropped"]) > 0.0
    assert bool(jnp.isfinite(out).all())


def test_rotary_relative_property(rng):
    """RoPE: <q_i, k_j> depends only on i - j."""
    hd = 8
    q = jnp.asarray(rng.standard_normal((1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, hd)), jnp.float32)
    def dot_at(i, j):
        qi = L.rotary(q, jnp.array([[i]]))
        kj = L.rotary(k, jnp.array([[j]]))
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(5, 5) - dot_at(0, 0)) < 1e-4
