"""End-to-end launcher tests (CLI surface, CPU-sized)."""

import tempfile

import pytest

from repro.launch import serve as S
from repro.launch import train as T


@pytest.mark.slow
def test_train_cli_loss_decreases_and_resumes():
    with tempfile.TemporaryDirectory() as d:
        args = T.parse_args([
            "--arch", "smollm-135m", "--smoke", "--steps", "30",
            "--seq-len", "64", "--global-batch", "4",
            "--ckpt-dir", d, "--ckpt-every", "10", "--log-every", "10"])
        out = T.run(args)
        assert out["final_loss"] < out["first_loss"]
        # resume from step 30 checkpoint and do 10 more
        args2 = T.parse_args([
            "--arch", "smollm-135m", "--smoke", "--steps", "40",
            "--seq-len", "64", "--global-batch", "4",
            "--ckpt-dir", d, "--resume", "--log-every", "10"])
        out2 = T.run(args2)
        assert out2["steps"] == 10          # 30 -> 40 only
        assert out2["final_loss"] < out["first_loss"]


@pytest.mark.slow
def test_serve_cli_with_kv_codebook():
    args = S.parse_args([
        "--arch", "h2o-danube-1.8b", "--smoke", "--prompt-len", "32",
        "--new-tokens", "8", "--batch", "2", "--kv-codebook", "8"])
    out = S.run(args)
    assert out["tokens"] == (2, 8)
    assert out["prefill_s"] > 0 and out["decode_s"] > 0


@pytest.mark.slow
def test_train_cli_with_compression():
    args = T.parse_args([
        "--arch", "smollm-135m", "--smoke", "--steps", "15",
        "--seq-len", "64", "--global-batch", "4",
        "--compression", "int8_ef", "--log-every", "5"])
    out = T.run(args)
    assert out["final_loss"] < out["first_loss"] + 0.1
