"""Substrate tests: optimizer, compression, data pipeline, checkpointing,
straggler/elastic policies."""

import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.data.tokens import DataConfig, TokenStream, global_batch_at
from repro.optim import adamw
from repro.optim.compression import int8_error_feedback, quantize_int8
from repro.runtime.elastic import (LADDER, ElasticController, MeshPlan,
                                   global_batch_plan, plan_for)
from repro.runtime.straggler import StragglerConfig, StragglerMonitor


# ---------------------------------------------------------------------- optim

def _quad_problem():
    a = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                    jnp.float32)
    q = a @ a.T + 0.5 * jnp.eye(8)
    b = jnp.ones(8)

    def loss(p):
        return 0.5 * p["x"] @ q @ p["x"] - b @ p["x"]
    return loss


def test_adamw_decreases_quadratic():
    loss = _quad_problem()
    cfg = adamw.AdamWConfig(lr=5e-2, warmup_steps=5, decay_steps=200,
                            weight_decay=0.0)
    params = {"x": jnp.zeros(8)}
    state = adamw.init_state(params, cfg)
    l0 = float(loss(params))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < l0 - 0.5
    assert int(state.step) == 150


def test_int8_ef_compression_converges_like_fp32():
    loss = _quad_problem()
    outs = {}
    for comp in ("none", "int8_ef"):
        cfg = adamw.AdamWConfig(lr=5e-2, warmup_steps=5, decay_steps=300,
                                weight_decay=0.0, compression=comp)
        params = {"x": jnp.zeros(8)}
        state = adamw.init_state(params, cfg)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, _ = adamw.apply_updates(params, g, state, cfg)
        outs[comp] = float(loss(params))
    # error feedback keeps the quantised run within a small margin
    assert outs["int8_ef"] < outs["none"] + 0.05, outs


def test_quantize_int8_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize_int8(x)
    err = float(jnp.max(jnp.abs(q.astype(jnp.float32) * s - x)))
    assert err <= float(s) * 0.5 + 1e-6


def test_error_feedback_carries_residual():
    g = {"w": jnp.asarray([0.001, 1.0], jnp.float32)}
    ef = {"w": jnp.zeros(2)}
    out, ef2 = int8_error_feedback(g, ef)
    # small component is quantised away but preserved in the residual
    np.testing.assert_allclose(np.asarray(out["w"] + ef2["w"]),
                               np.asarray(g["w"]), rtol=1e-6)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) > 100.0


# ----------------------------------------------------------------------- data

def test_data_deterministic_and_shard_invariant():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=7)
    b1 = global_batch_at(cfg, step=3, shard_count=1)
    b2 = global_batch_at(cfg, step=3, shard_count=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # resume: stream at start_step t yields batch_at(t)
    s = TokenStream(cfg, start_step=3)
    np.testing.assert_array_equal(next(s)["tokens"],
                                  TokenStream(cfg).batch_at(3)["tokens"])
    # different steps differ
    assert not np.array_equal(b1["tokens"],
                              global_batch_at(cfg, 4)["tokens"])
    # labels are next-token shifted
    row = TokenStream(cfg)._row(0, 0)
    b = TokenStream(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][0], row[:-1])
    np.testing.assert_array_equal(b["labels"][0], row[1:])


def test_data_tokens_in_range():
    cfg = DataConfig(vocab=50, seq_len=32, global_batch=4)
    b = TokenStream(cfg).batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 50


# ----------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_latest():
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "opt": {"m": jnp.ones(4)}}
    with tempfile.TemporaryDirectory() as d:
        root = Path(d)
        ckpt.save(root / "step_00000005", tree, step=5,
                  extra={"data": {"step": 5}})
        ckpt.save(root / "step_00000009", tree, step=9)
        assert ckpt.latest_step_dir(root).name == "step_00000009"
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            tree)
        restored, meta = ckpt.restore(root / "step_00000005", like)
        assert meta["step"] == 5 and meta["data"]["step"] == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


def test_checkpoint_shape_mismatch_raises():
    tree = {"w": jnp.ones((2, 3))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(Path(d) / "step_00000001", tree, step=1)
        bad = {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)}
        with pytest.raises(ValueError, match="shape mismatch"):
            ckpt.restore(Path(d) / "step_00000001", bad)


def test_async_checkpointer_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ac = ckpt.AsyncCheckpointer(Path(d), keep=2)
        for s in (1, 2, 3, 4):
            ac.save({"w": jnp.full((3,), float(s))}, step=s)
        ac.wait()
        kept = sorted(p.name for p in Path(d).glob("step_*"))
        assert kept == ["step_00000003", "step_00000004"]
        restored, meta = ac.restore_latest(
            {"w": jax.ShapeDtypeStruct((3,), jnp.float32)})
        assert meta["step"] == 4
        assert float(restored["w"][0]) == 4.0


def test_checkpoint_atomicity_tmp_ignored():
    with tempfile.TemporaryDirectory() as d:
        root = Path(d)
        ckpt.save(root / "step_00000001", {"w": jnp.ones(2)}, step=1)
        # a crashed half-write leaves only a .tmp dir — must be ignored
        (root / "step_00000002.tmp").mkdir()
        assert ckpt.latest_step_dir(root).name == "step_00000001"


# -------------------------------------------------------------------- runtime

def test_straggler_policy_flags_slow_host():
    clock = [0.0]
    mon = StragglerMonitor([f"h{i}" for i in range(4)],
                           StragglerConfig(patience=2),
                           clock=lambda: clock[0])
    actions = []
    for step in range(6):
        clock[0] += 10
        for i in range(4):
            mon.report(f"h{i}", 1.0 if i else 3.0)   # h0 is slow
        actions += mon.evaluate()
    assert any(a["host"] == "h0" and a["action"] == "REBALANCE"
               for a in actions)
    assert all(a["host"] == "h0" for a in actions)


def test_straggler_dead_host_evicted():
    clock = [0.0]
    mon = StragglerMonitor(["h0", "h1"],
                           StragglerConfig(dead_after_s=50),
                           clock=lambda: clock[0])
    mon.report("h0", 1.0)
    mon.report("h1", 1.0)
    clock[0] = 100.0
    mon.report("h1", 1.0)       # h0 silent
    actions = mon.evaluate()
    assert [a for a in actions if a["host"] == "h0"][0]["action"] == "EVICT"
    assert mon.healthy_hosts() == ["h1"]


def test_elastic_ladder():
    c = ElasticController()
    assert c.on_membership_change(512).kind == "NOOP"
    ev = c.on_membership_change(300)       # lost most of a pod
    assert ev.kind == "SHRINK" and ev.plan.shape == (16, 16)
    ev = c.on_membership_change(100)
    assert ev.plan.shape == (4, 16)
    ev = c.on_membership_change(512)
    assert ev.kind == "GROW" and ev.plan.shape == (2, 16, 16)
    assert c.on_membership_change(10).kind == "NOOP"


def test_elastic_batch_replan():
    assert global_batch_plan(256, MeshPlan((2, 16, 16),
                                           ("pod", "data", "model"))) == 8
    assert global_batch_plan(256, MeshPlan((16, 16), ("data", "model"))) == 16
