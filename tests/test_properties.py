"""Property tests for the Anderson window solve and the energy guard.

Two properties, each with a deterministic sweep (always runs) and a
hypothesis-widened version (runs when `hypothesis` is installed; the
shim in hypothesis_compat turns it into a skip otherwise):

1. `anderson._spd_solve` — the unrolled pure-XLA Gauss-Jordan — matches
   `jnp.linalg.solve` on exactly the masked SPD systems the window solve
   builds, for every active window size m in 0..mbar.
2. The guard path never keeps an energy-increasing iterate: on the
   full-batch driver an accepted iteration strictly decreases E (and the
   whole post-revert energy trace is non-increasing, Lloyd monotonicity
   covering the reverted steps); on the mini-batch driver an accepted
   chunk step's candidate beats the fallback on the validation chunk.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import anderson
from repro.core.anderson import AAConfig
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import (KMeansConfig, aa_kmeans_minibatch,
                               aa_kmeans_traced)
from repro.core.minibatch import MiniBatchConfig
from repro.data.streaming import chunk_dataset, split_validation
from repro.data.synthetic import make_blobs

MBAR = 12


def _masked_spd_system(seed: int, m_active: int, d_flat: int = 24,
                       mbar: int = MBAR):
    """Build (gram, rhs) exactly as `aa_push_and_solve` does: active
    columns' normal equations plus relative ridge, identity rows/cols for
    the inactive remainder."""
    rng = np.random.default_rng(seed)
    d_f = jnp.asarray(rng.standard_normal((mbar, d_flat)), jnp.float32)
    f = jnp.asarray(rng.standard_normal((d_flat,)), jnp.float32)
    active = jnp.arange(mbar) < m_active
    a_mask = jnp.where(active[:, None], d_f, 0.0)
    gram = a_mask @ a_mask.T
    rhs = a_mask @ f
    lam = 1e-12 * (jnp.trace(gram) + 1.0)
    eye = jnp.eye(mbar, dtype=f.dtype)
    gram = jnp.where(active[:, None] & active[None, :], gram, 0.0) + \
        eye * jnp.where(active, lam, 1.0)
    return gram, rhs


def _assert_solve_matches(gram, rhs):
    got = np.asarray(anderson._spd_solve(gram, rhs))
    want = np.asarray(jnp.linalg.solve(gram, rhs))
    scale = max(float(np.max(np.abs(want))), 1.0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale)


@pytest.mark.parametrize("m_active", range(0, MBAR + 1))
def test_spd_solve_matches_linalg_all_window_sizes(m_active):
    for seed in (0, 1, 2):
        gram, rhs = _masked_spd_system(seed * 1000 + m_active, m_active)
        _assert_solve_matches(gram, rhs)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m_active=st.integers(0, MBAR),
       d_flat=st.integers(1, 64))
def test_spd_solve_matches_linalg_hypothesis(seed, m_active, d_flat):
    gram, rhs = _masked_spd_system(seed, m_active, d_flat=d_flat)
    _assert_solve_matches(gram, rhs)


def _guard_trace(seed: int, spread: float):
    k = 6
    x = jnp.asarray(make_blobs(1500, 6, k, seed=seed, spread=spread))
    c0 = kmeanspp_init(jax.random.PRNGKey(seed), x, k)
    return aa_kmeans_traced(x, c0, KMeansConfig(k=k, max_iter=300),
                            backend="dense")


def _assert_guard_monotone(tr):
    energies = [float(e) for e in tr.energies]
    for i, accepted in enumerate(tr.accepted):
        prev = np.inf if i == 0 else energies[i - 1]
        if accepted:
            assert energies[i] < prev, \
                f"accepted iteration {i} increased E: {prev} -> {energies[i]}"
        else:
            # reverted -> the fallback G-iterate; Lloyd monotonicity
            # bounds it by the previous post-revert energy (fp slack for
            # an exactly-converged endgame step)
            assert energies[i] <= prev * (1 + 1e-6), (i, prev, energies[i])


@pytest.mark.parametrize("seed,spread", [(0, 4.0), (1, 1.5), (2, 1.0),
                                         (3, 0.8)])
def test_accepted_iterates_never_increase_energy(seed, spread):
    tr = _guard_trace(seed, spread)
    assert any(tr.accepted), "fixture should accept at least one AA step"
    _assert_guard_monotone(tr)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), spread=st.floats(0.6, 5.0))
def test_accepted_iterates_never_increase_energy_hypothesis(seed, spread):
    _assert_guard_monotone(_guard_trace(seed, spread))


@pytest.mark.parametrize("seed", [0, 1])
def test_minibatch_guard_accepts_only_val_improvements(seed):
    """Streaming guard property: whenever a chunk step keeps the
    accelerated candidate, that candidate was strictly better than the
    running-stats fallback on the held-out validation chunk."""
    k = 6
    x = jnp.asarray(make_blobs(12000, 6, k, seed=seed, spread=2.0))
    xt, xv = split_validation(x, 1024, jax.random.PRNGKey(seed))
    c0 = kmeanspp_init(jax.random.PRNGKey(seed + 1), xv, k)
    dc = chunk_dataset(xt, 2048)
    cfg = MiniBatchConfig(k=k, chunk_size=2048, epochs=4)
    _, trace = aa_kmeans_minibatch(dc.chunks, dc.weights, xv, c0, cfg,
                                   key=jax.random.PRNGKey(seed),
                                   return_trace=True)
    acc = np.asarray(trace.accepted).reshape(-1)
    e_cand = np.asarray(trace.e_cand).reshape(-1)
    e_fall = np.asarray(trace.e_fallback).reshape(-1)
    assert acc.any(), "fixture should accept at least one AA chunk step"
    assert (e_cand[acc] < e_fall[acc]).all()
    # and the kept energy is the min of the two candidates, always
    e_val = np.asarray(trace.e_val).reshape(-1)
    np.testing.assert_allclose(e_val, np.minimum(e_cand, e_fall), rtol=0)
