"""Hierarchy-engine conformance (DESIGN.md §Hierarchy).

The divide-and-conquer engine's contract is tested at its seams:

  * G = 1 IS the flat batched solve — bitwise, not approximately;
  * the per-problem weight machinery it rides on is exact: weight-1 rows
    match the unweighted solve bitwise, weight-0 padding rows change
    nothing;
  * reassignment rounds never increase the RETURNED energy (the
    best-snapshot guard), and labels come back in original row order;
  * the two-level structure survives estimator save/load and the round
    loop survives checkpoint/resume bit-exactly.
"""

from __future__ import annotations

import glob
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.api import AAKMeans
from repro.core.hierarchy import (HierarchyResult, aa_kmeans_hierarchical,
                                  default_n_groups, hierarchy_state_like)
from repro.core.init_schemes import batched_init
from repro.core.kmeans import (KMeansConfig, aa_kmeans_batched, select_best)
from repro.runtime.metrics import CollectMetrics, EarlyStopHook
from repro.serving.closure import closure_assign, hierarchy_closure_index

jax.config.update("jax_enable_x64", False)


def _smooth(n=2048, d=8, seed=1):
    """Smooth-density manifold — the k²-means operating regime (see
    benchmarks/hierarchy_bench.py for why not well-separated blobs)."""
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(n, 3))
    basis = rng.normal(size=(3, d)) / np.sqrt(3)
    return jnp.asarray((np.tanh(z @ basis)
                        + 0.05 * rng.normal(size=(n, d))).astype(np.float32))


# ---------------------------------------------------------------------------
# degenerate exactness
# ---------------------------------------------------------------------------

def test_default_n_groups_divisor_near_root():
    assert default_n_groups(4096) == 64
    assert default_n_groups(65536) == 256
    assert default_n_groups(2 ** 20) == 1024
    assert default_n_groups(12) in (3, 4)
    assert default_n_groups(7) == 1          # prime: no useful divisor


def test_g1_bitwise_matches_flat_batched():
    """The ISSUE acceptance: G=1 is the flat batched solve bit for bit —
    same seeds, same driver, same leaves."""
    x = _smooth(512, 5)
    cfg = KMeansConfig(k=8, max_iter=40)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    c0s = batched_init("kmeans++", keys, x, 8)
    flat = select_best(aa_kmeans_batched(x, c0s, cfg, backend="dense"))
    hier = aa_kmeans_hierarchical(x, 8, cfg, backend="dense",
                                  n_groups=1, n_init=2, c0s=c0s)
    assert bool(jnp.array_equal(hier.centroids, flat.centroids))
    assert bool(jnp.array_equal(hier.labels, flat.labels.astype(jnp.int32)))
    assert bool(jnp.array_equal(hier.energy,
                                flat.energy.astype(jnp.float32)))
    assert hier.n_rounds == 0
    assert np.array_equal(np.asarray(hier.group_offsets), [0, 8])


def test_weight_one_rows_bitwise_and_padding_exact():
    """The weights refactor the engine rides on: dense weights=1 is the
    unweighted solve bitwise, and appended weight-0 rows perturb
    nothing."""
    x = _smooth(256, 4)
    cfg = KMeansConfig(k=6, max_iter=30)
    c0s = batched_init("kmeans++",
                       jax.random.split(jax.random.PRNGKey(1), 1), x, 6)
    plain = aa_kmeans_batched(x, c0s, cfg, backend="dense")
    ones = aa_kmeans_batched(x, c0s, cfg, backend="dense",
                             weights=jnp.ones((1, 256), x.dtype))
    for a, b in zip(plain, ones):
        assert bool(jnp.array_equal(a, b))
    xp = jnp.concatenate([x, jnp.full((32, 4), 7.7, x.dtype)])
    wp = jnp.concatenate([jnp.ones(256), jnp.zeros(32)]).astype(x.dtype)
    padded = aa_kmeans_batched(xp[None][0], c0s, cfg, backend="dense",
                               weights=wp[None])
    assert bool(jnp.array_equal(padded.centroids, plain.centroids))
    assert bool(jnp.array_equal(padded.energy, plain.energy))
    assert bool(jnp.array_equal(padded.labels[:, :256], plain.labels))


# ---------------------------------------------------------------------------
# round loop invariants
# ---------------------------------------------------------------------------

def test_reassignment_never_increases_energy():
    """energy_best is monotone non-increasing across rounds, and the
    returned energy equals the best logged one — a crude super-solve
    (super_max_iter=1) forces rows to actually move."""
    x = _smooth(2048, 8, seed=2)
    cfg = KMeansConfig(k=64, max_iter=25)
    mx = CollectMetrics()
    res = aa_kmeans_hierarchical(x, 64, cfg, backend="dense", n_groups=8,
                                 n_reassign=3, super_max_iter=1,
                                 metrics=mx, seed=0)
    eb = [r["energy_best"] for _, r in mx.records]
    assert len(eb) >= 2          # at least one reassignment round ran
    assert all(a >= b - 1e-6 * abs(a) for a, b in zip(eb, eb[1:]))
    assert float(res.energy) == pytest.approx(eb[-1], rel=1e-6)


def test_labels_original_row_order_and_consistent():
    """Labels index the flattened group-major codebook in ORIGINAL row
    order: recomputing the energy from (labels, centroids) reproduces the
    reported energy, and every row's label lands inside its super-
    cluster's codebook slice."""
    x = _smooth(1024, 6, seed=3)
    res = aa_kmeans_hierarchical(x, 32, KMeansConfig(k=32, max_iter=25),
                                 backend="dense", n_groups=4,
                                 n_reassign=1, seed=4)
    e2 = float(jnp.sum(jnp.sum((x - res.centroids[res.labels]) ** 2,
                               axis=1)))
    assert float(res.energy) == pytest.approx(e2, rel=1e-4)
    off = np.asarray(res.group_offsets)
    grp = np.asarray(res.labels_super)
    lab = np.asarray(res.labels)
    assert ((lab >= off[grp]) & (lab < off[grp + 1])).all()


def test_sub_energies_sum_to_total():
    x = _smooth(512, 4, seed=5)
    res = aa_kmeans_hierarchical(x, 16, KMeansConfig(k=16, max_iter=20),
                                 backend="dense", n_groups=4, seed=5)
    assert float(res.energy) == pytest.approx(
        float(jnp.sum(res.sub_energies)), rel=1e-6)


def test_early_stop_hook_halts_rounds():
    """An EarlyStopHook with an impossible improvement bar stops the
    round loop at its patience, not at n_reassign."""
    x = _smooth(1024, 6, seed=6)
    hook = EarlyStopHook(rel_tol=10.0, patience=1, min_records=1)
    res = aa_kmeans_hierarchical(x, 32, KMeansConfig(k=32, max_iter=20),
                                 backend="dense", n_groups=4,
                                 n_reassign=5, super_max_iter=1,
                                 metrics=hook, seed=6)
    assert hook.should_stop
    assert res.n_rounds < 5


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

def test_checkpoint_resume_bitwise(tmp_path):
    """Round-granular KIND_HIERARCHY snapshots: resuming from a mid-run
    artifact replays the remaining rounds bit-identically."""
    x = _smooth(1024, 6, seed=7)
    cfg = KMeansConfig(k=32, max_iter=20)
    kw = dict(backend="dense", n_groups=4, n_reassign=3,
              super_max_iter=1, seed=7)
    full = aa_kmeans_hierarchical(x, 32, cfg, checkpoint_dir=tmp_path, **kw)
    snaps = sorted(glob.glob(os.path.join(tmp_path, "it_*.npz")))
    assert len(snaps) >= 2       # round 0 + at least one reassignment
    resumed = aa_kmeans_hierarchical(x, 32, cfg, resume_from=snaps[0],
                                     **kw)
    assert bool(jnp.array_equal(full.centroids, resumed.centroids))
    assert bool(jnp.array_equal(full.labels, resumed.labels))
    assert bool(jnp.array_equal(full.energy, resumed.energy))
    assert bool(jnp.array_equal(full.labels_super, resumed.labels_super))


def test_resume_rejects_config_mismatch(tmp_path):
    x = _smooth(512, 4, seed=8)
    aa_kmeans_hierarchical(x, 16, KMeansConfig(k=16, max_iter=10),
                           backend="dense", n_groups=4, n_reassign=1,
                           checkpoint_dir=tmp_path, seed=8)
    snap = sorted(glob.glob(os.path.join(tmp_path, "it_*.npz")))[0]
    # either guard is a loud refusal: the per-leaf shape check (different
    # G changes every group-axis leaf) or the meta n_groups check
    with pytest.raises(ValueError, match="n_groups|shape mismatch"):
        aa_kmeans_hierarchical(x, 16, KMeansConfig(k=16, max_iter=10),
                               backend="dense", n_groups=2,
                               resume_from=snap, seed=8)


def test_state_like_matches_snapshot(tmp_path):
    x = _smooth(512, 4, seed=9)
    aa_kmeans_hierarchical(x, 16, KMeansConfig(k=16, max_iter=10),
                           backend="dense", n_groups=4, n_reassign=1,
                           checkpoint_dir=tmp_path, seed=9)
    from repro.core import serialize
    snap = sorted(glob.glob(os.path.join(tmp_path, "it_*.npz")))[-1]
    state, meta = serialize.restore(snap, hierarchy_state_like(x, 16, 4),
                                    expect_kind=serialize.KIND_HIERARCHY)
    assert state["best_centroids"].shape == (16, 4)
    assert int(meta["n_groups"]) == 4


def test_estimator_roundtrip_and_free_index(tmp_path):
    """AAKMeans(hierarchical=...) fit -> save -> load keeps the labels in
    original row order and the two-level structure; the serving index is
    the solve's own routing (agreement with fit labels)."""
    x = np.asarray(_smooth(2048, 8, seed=10))
    m = AAKMeans(n_clusters=64, max_iter=25, seed=2, serving_index=True,
                 hierarchical={"n_groups": 8, "n_reassign": 1}).fit(x)
    assert m.hier_routers_.shape == (8, 8)
    assert np.array_equal(np.asarray(m.hier_offsets_),
                          np.arange(9) * 8)
    p = m.save(os.path.join(tmp_path, "model"))
    m2 = AAKMeans.load(p)
    assert bool(jnp.array_equal(m2.centroids_, m.centroids_))
    assert bool(jnp.array_equal(m2.labels_, m.labels_))
    assert bool(jnp.array_equal(m2.hier_routers_, m.hier_routers_))
    assert bool(jnp.array_equal(m2.hier_offsets_, m.hier_offsets_))
    # the persisted closure index is the hierarchy's free one: candidate
    # lists partition the codebook group by group
    cands = np.sort(np.asarray(m2.closure_candidates_), axis=1)
    assert np.array_equal(cands.reshape(-1), np.arange(64))
    la = m2.predict(x, approx=True)
    assert float((la == np.asarray(m.labels_)).mean()) > 0.95


def test_hierarchy_closure_index_prefix_contract():
    x = _smooth(1024, 6, seed=11)
    res = aa_kmeans_hierarchical(x, 32, KMeansConfig(k=32, max_iter=20),
                                 backend="dense", n_groups=4, seed=11)
    idx = hierarchy_closure_index(res.centroids, res.routers,
                                  res.group_offsets)
    assert idx.candidates.shape == (4, 8)
    labels, _ = closure_assign(x, res.centroids, idx.routers,
                               idx.candidates)
    assert float((labels == res.labels).mean()) > 0.9
    small = idx.shrink(3)
    assert bool(jnp.array_equal(small.candidates, idx.candidates[:, :3]))


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_rejects_non_divisor_groups():
    x = _smooth(256, 4)
    with pytest.raises(ValueError, match="divisor"):
        aa_kmeans_hierarchical(x, 16, KMeansConfig(k=16), n_groups=5)


def test_rejects_g1_checkpointing(tmp_path):
    x = _smooth(256, 4)
    with pytest.raises(ValueError, match="aa_kmeans_batched"):
        aa_kmeans_hierarchical(x, 16, KMeansConfig(k=16), n_groups=1,
                               checkpoint_dir=tmp_path)


def test_result_is_named_tuple_with_expected_fields():
    assert set(HierarchyResult._fields) == {
        "centroids", "labels", "energy", "routers", "group_offsets",
        "labels_super", "sub_energies", "n_rounds"}
