#!/usr/bin/env bash
# Tier-1 verification — the single entry point local runs and CI share, so
# the two stop diverging on environment setup.
#
#   ./test.sh              # full tier-1 suite
#   ./test.sh -m 'not slow'  # skip the multi-device / launcher tests
#
# Notes:
#   * PYTHONPATH=src — the package is not installed in the container.
#   * XLA_FLAGS forces 8 virtual host devices so mesh-shaped code paths are
#     exercised; tests that need a specific device count (test_distributed)
#     spawn subprocesses that override XLA_FLAGS themselves.
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
# Containers with libtpu installed stall for minutes probing GCP instance
# metadata unless the platform is pinned; override for real-TPU runs.
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

exec python -m pytest -x -q "$@"
