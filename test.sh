#!/usr/bin/env bash
# Tiered verification — one entry point, one environment setup, two tiers.
# CI's gate runs the FULL suite (PYTHONPATH=src python -m pytest -x -q);
# locally run the fast tier while iterating and the slow tier before
# shipping — together they are exactly CI's coverage.
#
#   ./test.sh              # fast tier: slow marker excluded; includes
#                          #   the checkpoint/resume roundtrip suite
#                          #   (tests/test_persistence.py: golden resume
#                          #   parity, estimator save/load)
#   ./test.sh --slow       # slow tier: multi-device subprocesses
#                          #   (incl. elastic re-mesh resume), launchers,
#                          #   streaming smoke, and the perf smokes
#                          #   (kernels_bench/checkpoint_bench --smoke,
#                          #   emitting BENCH_*.json)
#   ./test.sh --interpret  # interpret tier: the kernel-facing suites
#                          #   (kernels v1/v2, conformance, bounds,
#                          #   locality) with
#                          #   REPRO_PALLAS_INTERPRET=1, forcing every
#                          #   pallas_call through interpret mode even
#                          #   where a compiled path would be picked —
#                          #   the off-TPU check of the kernel sources
#   ./test.sh -m 'conformance'   # any extra pytest args pass through
#   ./test.sh -m 'perf'          # just the benchmark-harness smokes
#   ./test.sh tests/test_persistence.py   # just the persistence suite
#
# Notes:
#   * PYTHONPATH=src — the package is not installed in the container.
#   * XLA_FLAGS forces 8 virtual host devices so mesh-shaped code paths are
#     exercised; tests that need a specific device count (test_distributed)
#     spawn subprocesses that override XLA_FLAGS themselves.
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
# Containers with libtpu installed stall for minutes probing GCP instance
# metadata unless the platform is pinned; override for real-TPU runs.
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if [[ "${1:-}" == "--interpret" ]]; then
    shift
    export REPRO_PALLAS_INTERPRET=1
    exec python -m pytest -x -q -m 'not slow' \
        tests/test_kernels.py tests/test_kernels_v2.py \
        tests/test_conformance.py tests/test_bounds.py \
        tests/test_locality.py tests/test_hierarchy.py "$@"
fi
if [[ "${1:-}" == "--slow" ]]; then
    shift
    exec python -m pytest -x -q -m slow "$@"
fi
exec python -m pytest -x -q -m 'not slow' "$@"
