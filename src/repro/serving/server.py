"""Async online-serving tier: micro-batched assignment + hot reload
(DESIGN.md §Serving).

The "millions of users" workload (ROADMAP) is a request queue, and a
request queue produces exactly the shape pattern jit punishes: every
distinct row count is a fresh trace.  This server makes the compiled
surface ONE shape:

  * **bounded queue micro-batching** — callers `submit` (n_i, d) row
    blocks and get a Future; a single worker thread coalesces waiting
    requests (up to ``batch_size`` rows or ``flush_ms``, whichever first)
    and runs them as fixed-size ``(batch_size, d)`` padded batches through
    a module-level jitted runner.  Padding rows replicate the last real
    row and their outputs are sliced off, so results are exactly the
    per-request labels.  The queue bound is back-pressure: a producer
    outrunning the device blocks in ``submit`` instead of buffering
    unboundedly (same policy as the PR-7 checkpoint writer).
  * **closure-index fast path** — when the model carries a cluster
    closure index (`repro.serving.closure`), batches are labelled by the
    sublinear candidate scan; without one the server falls back to the
    exact full-K scan.  Both runners take centroids/index as *arguments*,
    so a reload that only moves values never recompiles.
  * **hot reload** — a watcher thread polls the artifact source (an
    estimator ``.npz``, or a directory whose ``manifest.json`` — the PR-7
    writer's — names the latest artifact) every ``poll_s``; on a changed
    fingerprint it loads and *warms* the replacement off the serving
    path, then swaps the model reference atomically.  The worker reads
    that reference once per micro-batch, so every batch is served
    entirely by one model version and no request is ever dropped or
    mixed across versions.
  * **metrics** — per-batch ``serve_latency_s`` / ``queue_depth`` /
    ``batch_rows`` / ``padded_rows`` (and ``reload_s`` per swap) through
    the PR-7 `log_scalars` protocol; any sink object works.
"""

from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
from concurrent.futures import Future
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import NotFittedError
from repro.runtime.metrics import as_metrics
from repro.serving.closure import (ClosureIndex, build_closure_index,
                                   candidate_table, closure_assign,
                                   closure_sqdist)

_STOP = object()

_OPS = ("labels", "transform")


# -- jitted runners ----------------------------------------------------------
# Module level (not per-model): the jit cache survives hot reloads, so a
# swap that keeps (batch_size, d, K) recompiles nothing.  The closure
# runners serve bucketed: the micro-batch is counting-sorted by nearest
# router before the candidate-table gather, so rows sharing a router read
# the same contiguous (C, d) block (bit-identical outputs; DESIGN.md
# §Locality).

@jax.jit
def _labels_exact(xb, centroids):
    from repro.core.lloyd import pairwise_sqdist
    return jnp.argmin(pairwise_sqdist(xb, centroids), axis=1
                      ).astype(jnp.int32)


@jax.jit
def _labels_closure(xb, centroids, routers, candidates, table):
    return closure_assign(xb, centroids, routers, candidates, table,
                          bucketed=True)[0]


@jax.jit
def _dists_exact(xb, centroids):
    from repro.core.lloyd import pairwise_sqdist
    return pairwise_sqdist(xb, centroids)


@jax.jit
def _dists_closure(xb, centroids, routers, candidates, table):
    return closure_sqdist(xb, centroids, routers, candidates, table,
                          bucketed=True)


class ServingModel:
    """Immutable servable snapshot: centroids + optional closure index.

    ``version`` is whatever fingerprint the loader attached (file name +
    mtime for artifact sources); it is how tests and operators observe
    which model a server is answering with."""

    def __init__(self, centroids, index: Optional[ClosureIndex] = None,
                 *, version=None, approx: bool = True):
        self.centroids = jnp.asarray(centroids)
        self.index = index
        self.version = version
        self.approx = bool(approx) and index is not None
        # the (G, C, d) candidate table is the hot-path scan operand;
        # built ONCE per model version so batches never pay the gather
        self.table = candidate_table(self.centroids, index.candidates) \
            if self.approx else None

    @classmethod
    def from_estimator(cls, model, *, version=None, approx: bool = True,
                       n_candidates: Optional[int] = None
                       ) -> "ServingModel":
        """Snapshot a fitted estimator.  ``n_candidates`` builds an index
        on the spot when the artifact carries none (legacy models) —
        left None, an index-less model simply serves the exact path."""
        if getattr(model, "centroids_", None) is None:
            raise NotFittedError(
                "cannot serve an unfitted estimator; call fit() or load "
                "a fitted artifact first")
        index = getattr(model, "closure_index_", None)
        if index is None and n_candidates is not None:
            index = build_closure_index(model.centroids_,
                                        n_candidates=n_candidates)
        return cls(model.centroids_, index, version=version, approx=approx)

    def labels(self, xb) -> np.ndarray:
        """Labels for one device-shaped batch (host numpy out)."""
        xb = jnp.asarray(xb)
        if self.approx:
            out = _labels_closure(xb, self.centroids, self.index.routers,
                                  self.index.candidates, self.table)
        else:
            out = _labels_exact(xb, self.centroids)
        return np.asarray(out)

    def dists(self, xb) -> np.ndarray:
        """(b, K) squared-distance rows for one device-shaped batch — the
        transform-serving payload.  On the closure path non-candidate
        columns are +inf (`closure_sqdist`), so argmin over a row always
        reproduces `labels`."""
        xb = jnp.asarray(xb)
        if self.approx:
            out = _dists_closure(xb, self.centroids, self.index.routers,
                                 self.index.candidates, self.table)
        else:
            out = _dists_exact(xb, self.centroids)
        return np.asarray(out)

    def warmup(self, batch_size: int, d: Optional[int] = None) -> None:
        """Compile (or hit the cache for) the fixed serving shape off the
        serving path — reload swaps never pay a trace mid-traffic.  Warms
        both ops: a batch mixing predict and transform requests must not
        trace either runner mid-traffic."""
        d = self.centroids.shape[1] if d is None else d
        zeros = jnp.zeros((batch_size, d), self.centroids.dtype)
        self.labels(zeros)
        self.dists(zeros)


# -- artifact source resolution ---------------------------------------------

def _resolve_artifact(source: Path) -> Optional[Path]:
    """The artifact a source path currently designates: the file itself,
    or — for a directory — the file its ``manifest.json`` names as
    ``latest`` (falling back to the newest ``*.npz`` by mtime when there
    is no usable manifest)."""
    if source.is_dir():
        from repro.runtime.writer import read_manifest
        m = read_manifest(source)
        if m is not None and m.get("latest"):
            p = source / m["latest"]
            if p.exists():
                return p
        snaps = [p for p in source.glob("*.npz")]
        return max(snaps, key=lambda p: p.stat().st_mtime_ns, default=None)
    return source if source.exists() else None


def _fingerprint(path: Optional[Path]):
    if path is None:
        return None
    st = path.stat()
    return (str(path), st.st_mtime_ns, st.st_size)


@dataclasses.dataclass
class _Request:
    rows: np.ndarray
    future: Future
    op: str = "labels"


class KMeansServer:
    """Micro-batching assignment server over one servable model.

    ``source`` is a fitted estimator instance (static serving), or a path
    — an estimator artifact ``.npz`` or a directory with a writer
    ``manifest.json`` — which is watched for hot reload when ``poll_s``
    is set.  Use as a context manager::

        with KMeansServer("model.npz", batch_size=256, poll_s=2.0) as srv:
            labels = srv.predict(rows)          # sync convenience
            fut = srv.submit(more_rows)         # async
    """

    def __init__(self, source, *, batch_size: int = 256,
                 approx: bool = True, n_candidates: Optional[int] = None,
                 flush_ms: float = 2.0, max_queue: int = 1024,
                 poll_s: Optional[float] = None, metrics=None):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1; got {batch_size}")
        self.batch_size = int(batch_size)
        self.approx = bool(approx)
        self.n_candidates = n_candidates
        self.flush_s = max(float(flush_ms), 0.0) / 1e3
        self.metrics = as_metrics(metrics)
        self.poll_s = poll_s
        self.n_batches = 0
        self.n_requests = 0
        self.reload_count = 0
        self.last_reload_error: Optional[BaseException] = None
        self._q: queue.Queue = queue.Queue(maxsize=max(1, int(max_queue)))
        self._stop = threading.Event()
        self._worker_thread: Optional[threading.Thread] = None
        self._watcher_thread: Optional[threading.Thread] = None

        if isinstance(source, (str, Path)):
            self._source: Optional[Path] = Path(source)
            path = _resolve_artifact(self._source)
            if path is None:
                raise FileNotFoundError(
                    f"{self._source}: no servable artifact found")
            self._fp = _fingerprint(path)
            self._model = self._load(path)
        else:
            self._source = None
            self._fp = None
            self._model = ServingModel.from_estimator(
                source, version="estimator", approx=self.approx,
                n_candidates=self.n_candidates)
        self._model.warmup(self.batch_size)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "KMeansServer":
        if self._worker_thread is not None:
            return self
        self._stop.clear()
        self._worker_thread = threading.Thread(
            target=self._worker, daemon=True, name="repro-serve-worker")
        self._worker_thread.start()
        if self._source is not None and self.poll_s:
            self._watcher_thread = threading.Thread(
                target=self._watcher, daemon=True,
                name="repro-serve-watcher")
            self._watcher_thread.start()
        return self

    def stop(self) -> None:
        """Drain: every accepted request is answered before the worker
        exits.  Idempotent."""
        if self._worker_thread is None:
            return
        self._stop.set()
        self._q.put(_STOP)
        self._worker_thread.join()
        self._worker_thread = None
        if self._watcher_thread is not None:
            self._watcher_thread.join()
            self._watcher_thread = None

    def __enter__(self) -> "KMeansServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    # -- request API -------------------------------------------------------

    @property
    def version(self):
        return self._model.version

    def submit(self, rows, op: str = "labels") -> Future:
        """Queue (n, d) rows; the Future resolves to their (n,) int32
        labels (``op="labels"``) or (n, K) squared-distance rows
        (``op="transform"``).  Requests of both ops coalesce into the
        same micro-batches — one compiled padded shape per op, shared by
        every request.  Blocks (back-pressure) when ``max_queue``
        requests are already waiting."""
        if op not in _OPS:
            raise ValueError(f"op must be one of {_OPS}; got {op!r}")
        if self._worker_thread is None:
            raise RuntimeError("server is not running; call start() or "
                               "use it as a context manager")
        rows = np.asarray(rows)
        if rows.ndim != 2:
            raise ValueError(f"submit expects (n, d) rows; got shape "
                             f"{rows.shape}")
        if rows.shape[0] == 0:
            f: Future = Future()
            k = self._model.centroids.shape[0]
            f.set_result(np.empty((0,), np.int32) if op == "labels"
                         else np.empty((0, k), np.float32))
            return f
        req = _Request(rows, Future(), op)
        self._q.put(req)
        return req.future

    def submit_transform(self, rows) -> Future:
        """`submit` with ``op="transform"``."""
        return self.submit(rows, op="transform")

    def predict(self, rows, timeout: Optional[float] = None) -> np.ndarray:
        """Synchronous convenience: submit + wait."""
        return self.submit(rows).result(timeout=timeout)

    def transform(self, rows, timeout: Optional[float] = None) -> np.ndarray:
        """Synchronous transform: (n, K) squared-distance rows through the
        same micro-batch path (closure models fill non-candidate columns
        with +inf, exactly like the estimator's ``approx`` transform)."""
        return self.submit_transform(rows).result(timeout=timeout)

    # -- worker ------------------------------------------------------------

    def _collect(self, first) -> list:
        """One micro-batch: the triggering request plus whatever arrives
        before ``batch_size`` rows are gathered or ``flush_s`` elapses."""
        batch, rows = [first], first.rows.shape[0]
        deadline = time.perf_counter() + self.flush_s
        while rows < self.batch_size:
            wait = deadline - time.perf_counter()
            if wait <= 0:
                break
            try:
                nxt = self._q.get(timeout=wait)
            except queue.Empty:
                break
            if nxt is _STOP:
                self._stop.set()     # drain what we have, then exit
                break
            batch.append(nxt)
            rows += nxt.rows.shape[0]
        return batch

    def _worker(self) -> None:
        while True:
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                if self._stop.is_set() and self._q.empty():
                    return
                continue
            if item is _STOP:
                if self._q.empty():
                    return
                continue    # stop already set; keep draining
            self._serve_batch(self._collect(item))

    def _serve_batch(self, batch: list) -> None:
        # one reference read per micro-batch: a concurrent hot reload
        # swaps the model BETWEEN batches, never inside one
        model = self._model
        depth = self._q.qsize()
        t0 = time.perf_counter()
        try:
            rows = np.concatenate([r.rows for r in batch]) \
                if len(batch) > 1 else batch[0].rows
            n, b = rows.shape[0], self.batch_size
            # ops can mix within a micro-batch; each padded block runs
            # only the runners some waiting request actually needs
            need_labels = any(r.op == "labels" for r in batch)
            need_dists = any(r.op == "transform" for r in batch)
            k = model.centroids.shape[0]
            labels = np.empty((n,), np.int32) if need_labels else None
            dists = np.empty((n, k), model.centroids.dtype) \
                if need_dists else None
            padded = (-n) % b
            for i in range(0, n, b):
                xb = rows[i:i + b]
                m = xb.shape[0]
                if m < b:   # fixed compiled shape: pad, slice the output
                    xb = np.concatenate(
                        [xb, np.repeat(xb[-1:], b - m, axis=0)])
                if need_labels:
                    labels[i:i + m] = model.labels(xb)[:m]
                if need_dists:
                    dists[i:i + m] = model.dists(xb)[:m]
            off = 0
            for r in batch:
                m = r.rows.shape[0]
                out = labels[off:off + m] if r.op == "labels" \
                    else dists[off:off + m]
                r.future.set_result(out.copy())
                off += m
        except BaseException as e:   # noqa: BLE001 — delivered per request
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(e)
        finally:
            self.n_batches += 1
            self.n_requests += len(batch)
            try:
                self.metrics.log_scalars(self.n_batches, {
                    "serve_latency_s": time.perf_counter() - t0,
                    "queue_depth": float(depth),
                    "batch_rows": float(sum(r.rows.shape[0]
                                            for r in batch)),
                    "batch_requests": float(len(batch)),
                    "padded_rows": float(padded),
                })
            except Exception:
                pass    # a broken sink must not fail requests

    # -- hot reload --------------------------------------------------------

    def _load(self, path: Path) -> ServingModel:
        # lazy: repro.checkpoint.kmeans imports repro.core.api — keep the
        # serving package importable without closing that cycle at import
        from repro.checkpoint.kmeans import load_estimator
        est = load_estimator(path)
        return ServingModel.from_estimator(
            est, version=_fingerprint(path), approx=self.approx,
            n_candidates=self.n_candidates)

    def check_reload(self) -> bool:
        """Poll the source once; swap in a changed artifact.  Returns
        True when a swap happened.  The watcher thread calls this on its
        ``poll_s`` cadence; tests and single-threaded callers may call it
        directly."""
        if self._source is None:
            return False
        path = _resolve_artifact(self._source)
        fp = _fingerprint(path)
        if fp is None or fp == self._fp:
            return False
        t0 = time.perf_counter()
        model = self._load(path)
        model.warmup(self.batch_size)   # compile off the serving path
        self._model = model             # atomic ref swap: between batches
        self._fp = fp
        self.reload_count += 1
        self.last_reload_error = None
        try:
            self.metrics.log_scalars(self.n_batches, {
                "reload_s": time.perf_counter() - t0,
                "reload_count": float(self.reload_count)})
        except Exception:
            pass
        return True

    def _watcher(self) -> None:
        while not self._stop.wait(self.poll_s):
            try:
                self.check_reload()
            except Exception as e:   # keep serving the old model
                self.last_reload_error = e


def serve_manifest(server: KMeansServer) -> str:
    """One-line JSON status blob for operators/health checks."""
    return json.dumps({
        "version": list(server.version)
        if isinstance(server.version, tuple) else server.version,
        "batch_size": server.batch_size,
        "approx": server._model.approx,
        "n_batches": server.n_batches,
        "n_requests": server.n_requests,
        "reload_count": server.reload_count,
    }, sort_keys=True)
