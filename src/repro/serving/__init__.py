"""Online serving tier (DESIGN.md §Serving): cluster-closure candidate
index for sublinear-in-K assignment, and the micro-batching request
server with hot reload."""

from repro.serving.closure import (ClosureIndex, build_closure_index,
                                   candidate_table, closure_assign,
                                   closure_sqdist, default_n_candidates,
                                   default_n_groups)
from repro.serving.server import KMeansServer, ServingModel, serve_manifest

__all__ = [
    "ClosureIndex", "build_closure_index", "candidate_table",
    "closure_assign", "closure_sqdist", "default_n_candidates",
    "default_n_groups", "KMeansServer", "ServingModel", "serve_manifest",
]
