"""Cluster-closure candidate index for sublinear-in-K assignment
(DESIGN.md §Serving).

Anderson acceleration (PAPER.md) only speeds up *fit*; at serving time
every query still paid a full K-centroid scan.  Following Wang et al.
(*Fast Approximate K-Means via Cluster Closures*, PAPERS.md), the fitted
centroids themselves are cheap to organise: cluster the K centroids into
G groups, keep each group's mean as a **router**, and precompute each
router's **closure** — the candidate list of the ``C`` centroids nearest
to it.  A query then prices G routers, follows the nearest one, and takes
the *exact* argmin over that router's C candidates:

    cost per row:  O(G·d + C·d)   instead of   O(K·d)

With the defaults (G ≈ 4√K routers, C sized like the PR-6 bound groups —
one fused-kernel k-tile of centroids) the scan shrinks by ~K/(G+C) while
recall stays near 1: a query only mislabels when its true centroid is
absent from its router's closure, i.e. when the row sits far outside its
cluster's neighbourhood.  Routers are cheap (one small GEMM), candidates
are not (a per-row gather), so the default spends G ≫ √K on routing to
buy recall at small C.  ``benchmarks/serving_bench.py`` measures the
recall-vs-latency curve over the candidate-count sweep.

Everything here is pure jnp on (K, d)-sized operands — index *build* is a
one-off at fit time (a few Lloyd iterations over the centroids), and the
*query* functions take the index as flat array arguments so the serving
tier's jitted runners recompile only when shapes change, never on a
hot-reload that merely swaps values.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import lloyd
from repro.core.backends import bounds
from repro.core.lloyd import pairwise_sqdist


class ClosureIndex(NamedTuple):
    """The servable candidate index.

    routers    : (G, d) float — group-mean entry points.
    candidates : (G, C) int32 — for each router, the indices of the C
                 centroids nearest to it, nearest first (so a prefix
                 ``candidates[:, :c]`` is itself a valid, smaller index).
    n_valid    : optional (G,) int32 — ADAPTIVE per-router candidate
                 counts (`build_closure_index(adaptive=True)`): router g
                 scans only ``candidates[g, :n_valid[g]]``; columns past
                 it are masked to +inf at query time.  None (the default,
                 and what every uniform build produces) means all C
                 columns are live — the uniform index's behaviour is
                 unchanged bit for bit.
    """
    routers: jax.Array
    candidates: jax.Array
    n_valid: Optional[jax.Array] = None

    @property
    def n_groups(self) -> int:
        return self.routers.shape[0]

    @property
    def n_candidates(self) -> int:
        return self.candidates.shape[1]

    def shrink(self, n_candidates: int) -> "ClosureIndex":
        """A cheaper index over the same routers: candidate lists are
        sorted nearest-first, so truncation IS the smaller closure.  An
        adaptive index clamps its per-router counts to the new width, so
        the prefix contract survives ``adaptive=True``."""
        n_valid = None if self.n_valid is None \
            else jnp.minimum(self.n_valid, n_candidates)
        return ClosureIndex(self.routers,
                            self.candidates[:, :n_candidates], n_valid)


def default_n_groups(k: int) -> int:
    """4√K routers — still sublinear in K, but deliberately router-heavy:
    routing is one (N, G)·GEMM while candidate scanning pays a per-row
    gather, so trading a bigger G for a smaller C at equal recall is a
    straight win on every backend we measured."""
    return max(1, min(4 * int(math.isqrt(max(k, 1))), k))


def default_n_candidates(k: int) -> int:
    """Candidate lists sized like the PR-6 bound groups (one fused-kernel
    k-tile of centroids, `bounds.resolve_group_size`): the same "how many
    centroids form a neighbourhood" constant the distance-elimination
    engine already uses."""
    return min(k, bounds.resolve_group_size(k, None, policy="tile"))


def build_closure_index(centroids, n_candidates: Optional[int] = None,
                        n_groups: Optional[int] = None, *,
                        n_iter: int = 10, seed: int = 0,
                        adaptive: bool = False) -> ClosureIndex:
    """Build the index from the fitted centroids alone.

    Routers come from ``n_iter`` plain Lloyd iterations clustering the K
    centroids into ``n_groups`` groups (k-means on the codebook — K rows,
    so this is trivia next to the fit that produced them); each router's
    closure is the ``n_candidates`` centroids nearest to it by
    centroid-centroid distance, nearest first.  Deterministic in
    ``seed``.

    ``adaptive=True`` sizes each router's LIVE candidate count by its
    radius (the distance to its farthest member centroid): a router in a
    dense codebook region needs few candidates for full recall while a
    sparse-region router needs many, so ``n_candidates`` becomes the
    *mean* count and each router gets a share proportional to its radius
    (clamped to [1, C_max]).  The candidate matrix stays rectangular —
    width = the largest live count — with per-router validity in
    ``n_valid``; a uniform build (``adaptive=False``) returns
    ``n_valid=None`` and is untouched."""
    c = jnp.asarray(centroids)
    k = c.shape[0]
    g = n_groups if n_groups is not None else default_n_groups(k)
    g = max(1, min(int(g), k))
    n_cand = n_candidates if n_candidates is not None \
        else default_n_candidates(k)
    n_cand = max(1, min(int(n_cand), k))
    key = jax.random.PRNGKey(seed)
    routers = c[jax.random.choice(key, k, (g,), replace=False)]
    for _ in range(max(int(n_iter), 0)):
        labels = jnp.argmin(pairwise_sqdist(c, routers), axis=1)
        sums, counts = lloyd.cluster_sums(c, labels, g)
        routers = lloyd.update_from_sums(sums, counts,
                                         routers.astype(sums.dtype)
                                         ).astype(c.dtype)
    d2 = pairwise_sqdist(routers, c)                           # (G, K)
    if not adaptive:
        _, candidates = jax.lax.top_k(-d2, n_cand)
        return ClosureIndex(routers, candidates.astype(jnp.int32))
    # Radius of router g = distance to its farthest OWNED centroid; an
    # ownerless router scans the mean count (radius -> mean radius).
    owner = jnp.argmin(d2, axis=0)                             # (K,)
    mine = owner[None, :] == jnp.arange(g)[:, None]            # (G, K)
    radius = jnp.sqrt(jnp.max(jnp.where(mine, d2, 0.0), axis=1))
    has = jnp.any(mine, axis=1)
    mean_r = jnp.sum(jnp.where(has, radius, 0.0)) \
        / jnp.maximum(jnp.sum(has), 1)
    radius = jnp.where(has, radius, mean_r)
    share = radius / jnp.maximum(mean_r, 1e-30)
    n_valid = jnp.clip(jnp.round(n_cand * share), 1, k).astype(jnp.int32)
    c_max = int(jax.device_get(jnp.max(n_valid)))
    _, candidates = jax.lax.top_k(-d2, c_max)
    return ClosureIndex(routers, candidates.astype(jnp.int32), n_valid)


def hierarchy_closure_index(centroids, routers, group_offsets
                            ) -> ClosureIndex:
    """The hierarchical solve's FREE serving index (DESIGN.md §Hierarchy).

    `repro.core.hierarchy.aa_kmeans_hierarchical` already produced the
    two-level structure a closure index is built from: the super-centroid
    routers and a group-major codebook where group g owns the rows
    [offsets[g], offsets[g+1]).  No clustering happens here — each
    router's candidate list is exactly its own group's codebook rows,
    reordered nearest-first so the `shrink` prefix contract holds.  A
    query routed and scanned through this index replays the solve's own
    two-level assignment rule."""
    c = jnp.asarray(centroids)
    routers = jnp.asarray(routers)
    off = jnp.asarray(group_offsets, jnp.int32)
    g = routers.shape[0]
    sizes = off[1:] - off[:-1]
    if bool(jax.device_get(jnp.any(sizes != sizes[0]))):
        raise ValueError(
            "hierarchy_closure_index needs uniform group sizes (the "
            "hierarchy engine emits them); got offsets with mixed strides")
    k_sub = int(jax.device_get(sizes[0]))
    ids = off[:-1, None] + jnp.arange(k_sub, dtype=jnp.int32)[None, :]
    table = jnp.take(c, ids.reshape(-1), axis=0).reshape(g, k_sub, -1)
    d2 = jnp.sum((table - routers[:, None, :]) ** 2, axis=-1)  # (G, k_sub)
    order = jnp.argsort(d2, axis=1)
    return ClosureIndex(routers,
                        jnp.take_along_axis(ids, order, axis=1
                                            ).astype(jnp.int32))


# -- query-time kernels (flat array args: jit-cache-friendly across
#    hot reloads — same shapes, new values, zero retraces) ------------------
#
# The centroid gather is the whole query-time cost story.  Gathering
# ``centroids[candidates[g]]`` with (N, C) scattered row indices is
# catastrophically slow on CPU XLA (scalar-loop gather, ~10x the full-K
# GEMM at C=512).  Instead the candidate *table* (G, C, d) is materialised
# once per call — a fixed G·C-row gather amortised over all N queries —
# and each row then gathers ONE contiguous (C, d) block by its router id.


def candidate_table(centroids, candidates):
    """(G, C, d) centroid rows of every router's closure — the operand
    the query kernels actually scan.  O(G·C·d) to build; callers holding
    an index between calls (the serving tier) should build it once per
    model version rather than per batch."""
    g, c = candidates.shape
    return jnp.take(jnp.asarray(centroids), candidates.reshape(-1),
                    axis=0).reshape(g, c, -1)


def _routed_sqdist(x, g, table, n_valid=None):
    """Exact distances from each row to its router's candidate block.
    ``n_valid`` (G,) masks each row's columns past its router's live
    count to +inf (adaptive indices); None scans the full width."""
    cc = table[g]                                  # (N, C, d) block rows
    x_sq = jnp.sum(x * x, axis=-1, keepdims=True)               # (N, 1)
    c_sq = jnp.sum(table * table, axis=-1)[g]                   # (N, C)
    cross = jnp.einsum("nd,ncd->nc", x, cc)                     # (N, C)
    d2 = jnp.maximum(x_sq - 2.0 * cross + c_sq, 0.0)
    if n_valid is None:
        return d2
    cols = jnp.arange(table.shape[1], dtype=jnp.int32)[None, :]  # (1, C)
    return jnp.where(cols < n_valid[g][:, None], d2, jnp.inf)


def _candidate_sqdist(x, routers, candidates, table, bucketed=False,
                      n_valid=None):
    """Shared core: route, block-gather, exact distances to candidates.
    Returns (g (N,), d2 (N, C)).

    ``bucketed=True`` counting-sorts the rows by router id before the
    block gather and inverts the permutation on the way out (DESIGN.md
    §Locality): rows sharing a router then read the SAME contiguous
    (C, d) table block back to back instead of hopping between blocks —
    the serving-tier analogue of the solver's cluster-sorted reordering.
    All per-row math is row-local, so the outputs are bit-identical to
    the unbucketed path."""
    x = jnp.asarray(x)
    g = jnp.argmin(pairwise_sqdist(x, routers), axis=1)        # (N,)
    if bucketed:
        from repro.core.locality import counting_sort_perm
        perm, inv = counting_sort_perm(g, routers.shape[0])
        d2s = _routed_sqdist(jnp.take(x, perm, axis=0),
                             jnp.take(g, perm, axis=0), table,
                             n_valid=n_valid)
        return g, jnp.take(d2s, inv, axis=0)
    return g, _routed_sqdist(x, g, table, n_valid=n_valid)


def closure_assign(x, centroids, routers, candidates, table=None,
                   bucketed=False, n_valid=None):
    """Approximate assignment: exact argmin over the nearest router's
    candidate list.  Returns (labels (N,) int32, min_sqdist (N,)).

    The only approximation is the candidate restriction — distances to
    the scanned centroids are exact, so a row whose true centroid is in
    its router's closure gets exactly the full-scan label.  ``table`` is
    the `candidate_table`; pass a precomputed one to skip the per-call
    build (hot serving path).  ``bucketed=True`` sorts the batch by
    router id for contiguous table reads (bit-identical outputs; see
    `_candidate_sqdist`).  ``n_valid`` is the adaptive index's per-router
    live count (`ClosureIndex.n_valid`): masked columns price +inf, so a
    masked candidate can never win the argmin."""
    if table is None:
        table = candidate_table(centroids, candidates)
    g, d2 = _candidate_sqdist(x, routers, candidates, table,
                              bucketed=bucketed, n_valid=n_valid)
    j = jnp.argmin(d2, axis=1)
    take = lambda a: jnp.take_along_axis(a, j[:, None], axis=1)[:, 0]
    return take(candidates[g]).astype(jnp.int32), take(d2)


def closure_sqdist(x, centroids, routers, candidates, table=None,
                   fill=jnp.inf, bucketed=False, n_valid=None):
    """Approximate transform support: (N, K) squared distances, computed
    exactly for each row's candidate centroids and ``fill`` (+inf by
    default) everywhere else — +inf keeps any downstream argmin/softmin
    consistent with `closure_assign`, at the cost that non-candidate
    columns carry no information (that is the point of not pricing
    them).  ``bucketed`` / ``n_valid`` as in `closure_assign` — a masked
    adaptive column stays at ``fill``, exactly like a non-candidate."""
    k = jnp.asarray(centroids).shape[0]
    if table is None:
        table = candidate_table(centroids, candidates)
    g, d2 = _candidate_sqdist(x, routers, candidates, table,
                              bucketed=bucketed, n_valid=n_valid)
    if n_valid is not None:
        d2 = jnp.where(jnp.isinf(d2), jnp.asarray(fill, d2.dtype), d2)
    out = jnp.full((d2.shape[0], k), fill, dtype=d2.dtype)
    rows = jnp.arange(d2.shape[0])[:, None]
    return out.at[rows, candidates[g]].set(d2)
