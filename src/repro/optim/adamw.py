"""AdamW implemented from scratch (no optax in this container).

State is a pytree mirroring params (m, v in fp32) + a step counter.
Supports global-norm gradient clipping, decoupled weight decay, linear
warmup + cosine decay, and an optional gradient-compression hook
(repro.optim.compression) applied before the update — the compression
operates on the *sharded* gradients, modelling compressed cross-device
reduction with error feedback.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    compression: str = "none"       # none | int8_ef


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree
    ef: Optional[PyTree]            # error-feedback residual (compression)


def init_state(params: PyTree, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    ef = None
    if cfg.compression != "none":
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros), ef)


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: PyTree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params: PyTree, grads: PyTree, state: AdamWState,
                  cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    from repro.optim import compression as comp

    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    ef = state.ef
    if cfg.compression == "int8_ef":
        grads, ef = comp.int8_error_feedback(grads, ef)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    step = state.step + 1
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        pf = p.astype(jnp.float32)
        pnew = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * pf)
        return pnew.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v, ef), metrics
