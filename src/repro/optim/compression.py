"""Gradient compression with error feedback (distributed-optimization trick).

int8 quantisation with per-tensor scale + error-feedback residual
(Seide et al. 2014 / Karimireddy et al. 2019 style): the quantisation error
of step t is added back into the gradient at step t+1, preserving
convergence.  On the production mesh this models compressing the cross-pod
gradient all-reduce 4x (int8 vs f32); the quantise/dequantise pair here is
the numerics — the wire format on real hardware is the int8 tensor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def int8_error_feedback(grads, ef):
    """Quantise (grad + residual), carry the new residual."""
    def one(g, e):
        corrected = g + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_g, new_e
