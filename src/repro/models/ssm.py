"""Mamba2 (state-space duality) block: chunked parallel scan + O(1) decode.

Implements the SSD algorithm of Dao & Gu 2024 (arXiv:2405.21060): the
sequence is split into chunks of length Q; within a chunk the recurrence is
computed in its quadratic "attention-like" dual form (MXU-friendly matmuls),
while a lax.scan over chunk boundaries carries the (P x N) per-head state.

Semantics per head (headdim P, state N, scalar A < 0):

    h_t = exp(dt_t * A) h_{t-1} + dt_t * (x_t outer B_t)        h: (P, N)
    y_t = h_t @ C_t + D * x_t

Decode is the recurrence applied once — O(1) in context length, which is why
the ssm/hybrid families run the long_500k shape (DESIGN.md §Shapes).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.params import spec


def mamba2_spec(cfg):
    d = cfg.d_model
    di = cfg.ssm_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * g * n
    return {
        # order of in_proj outputs: [z, x, B, C, dt]
        "in_proj": spec((d, 2 * di + 2 * g * n + h), ("embed", "ssm_inner")),
        "conv_w": spec((cfg.ssm_conv, conv_dim), ("conv", "ssm_inner")),
        "conv_b": spec((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": spec((h,), ("ssm_heads",), init="zeros"),
        "d_skip": spec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": spec((h,), ("ssm_heads",), init="zeros"),
        "norm_scale": spec((di,), ("ssm_inner",), init="ones"),
        "out_proj": spec((di, d), ("ssm_inner", "embed")),
    }


class MambaState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, conv_dim) ring of last inputs
    ssm: jax.Array    # (B, H, P, N) recurrent state


def mamba2_state_spec(cfg, batch):
    di, g, n = cfg.ssm_inner, cfg.ssm_groups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    return {
        "conv": spec((batch, cfg.ssm_conv - 1, conv_dim),
                     ("cache_batch", None, "ssm_inner")),
        "ssm": spec((batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                    ("cache_batch", "ssm_heads_act", None, None)),
    }


def _split_proj(cfg, proj):
    di, g, n, h = (cfg.ssm_inner, cfg.ssm_groups, cfg.ssm_state,
                   cfg.ssm_heads)
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * g * n]
    dt = proj[..., di + di + 2 * g * n:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, init_state=None):
    """Depthwise causal conv over time.  xbc (B,S,C); w (W,C); b (C,).
    init_state (B,W-1,C) prepended (decode continuity)."""
    bsz, s, c = xbc.shape
    w_width = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((bsz, w_width - 1, c), xbc.dtype)
    else:
        pad = init_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)          # (B, S+W-1, C)
    # depthwise conv as a sum of W shifted scalings — W is tiny (4)
    out = jnp.zeros_like(xbc)
    for i in range(w_width):
        out = out + xp[:, i:i + s, :] * w[i][None, None, :].astype(xbc.dtype)
    out = out + b[None, None, :].astype(xbc.dtype)
    return jax.nn.silu(out), xp[:, s:, :]             # new conv tail


def _ssd_chunked(xh, bmat, cmat, dt, a, chunk):
    """Chunked SSD.  xh (B,S,H,P); bmat/cmat (B,S,G,N); dt (B,S,H) > 0;
    a (H,) < 0.  Returns y (B,S,H,P) and final state (B,H,P,N)."""
    bsz, s, h, p = xh.shape
    g, n = bmat.shape[2], bmat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    def r(t):  # (B,S,...) -> (B,nc,Q,...)
        return t.reshape(bsz, nc, chunk, *t.shape[2:])

    xh_, b_, c_, dt_ = r(xh.astype(jnp.float32)), r(bmat.astype(jnp.float32)), \
        r(cmat.astype(jnp.float32)), r(dt.astype(jnp.float32))
    bh = jnp.repeat(b_, rep, axis=3)      # (B,nc,Q,H,N)
    ch = jnp.repeat(c_, rep, axis=3)

    aa = dt_ * a[None, None, None, :]                 # (B,nc,Q,H) log-decay
    cum = jnp.cumsum(aa, axis=2)                      # inclusive
    # intra-chunk: L[t,s] = exp(cum_t - cum_s) * dt_s   for s <= t
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Qt,Qs,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    l_mat = l_mat * dt_[:, :, None, :, :]             # weight by dt_s
    cb = jnp.einsum("bqthn,bqshn->bqtsh", ch, bh)      # C_t . B_s
    y_intra = jnp.einsum("bqtsh,bqtsh,bqshp->bqthp", cb, l_mat, xh_)

    # chunk state contribution: S_q = sum_s exp(cum_Q - cum_s) dt_s x_s B_s^T
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dt_    # (B,nc,Q,H)
    state_c = jnp.einsum("bqsh,bqshp,bqshn->bqhpn", w_end, xh_, bh)
    decay_c = jnp.exp(jnp.sum(aa, axis=2))            # (B,nc,H)

    def scan_body(carry, inp):
        st_prev = carry                               # (B,H,P,N)
        st_c, dec = inp                               # (B,H,P,N), (B,H)
        st = dec[:, :, None, None] * st_prev + st_c
        return st, st_prev

    st0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    stc_t = state_c.swapaxes(0, 1)                    # (nc,B,H,P,N)
    dec_t = decay_c.swapaxes(0, 1)                    # (nc,B,H)
    st_final, st_prevs = jax.lax.scan(scan_body, st0, (stc_t, dec_t))
    st_prevs = st_prevs.swapaxes(0, 1)                # (B,nc,H,P,N)

    # inter-chunk: y_t += exp(cum_t) * C_t . S_{prev}
    w_in = jnp.exp(cum)                               # (B,nc,Q,H)
    y_inter = jnp.einsum("bqth,bqthn,bqhpn->bqthp", w_in, ch, st_prevs)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, st_final


def mamba2_forward(p, x, cfg, conv_init=None, ssm_init=None):
    """Full Mamba2 block.  x (B,S,d_model) -> (y (B,S,d_model), MambaState)."""
    di, g, n, h_heads = (cfg.ssm_inner, cfg.ssm_groups, cfg.ssm_state,
                         cfg.ssm_heads)
    phd = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_init)
    xs = xbc[..., :di]
    bmat = xbc[..., di:di + g * n].reshape(*xbc.shape[:2], g, n)
    cmat = xbc[..., di + g * n:].reshape(*xbc.shape[:2], g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(*xs.shape[:2], h_heads, phd)
    y, st = _ssd_chunked(xh, bmat, cmat, dt, a, min(cfg.ssm_chunk, x.shape[1]))
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(*xs.shape[:2], di).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    yz = y * jax.nn.silu(z)
    yf = yz.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = (yf * jax.lax.rsqrt(var + cfg.norm_eps)
          * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", yn, p["out_proj"].astype(x.dtype))
    return out, MambaState(conv_tail, st)


def mamba2_decode_step(p, x, cfg, state: MambaState):
    """One-token decode.  x (B,1,d_model) -> (y (B,1,d_model), new state)."""
    di, g, n, h_heads = (cfg.ssm_inner, cfg.ssm_groups, cfg.ssm_state,
                         cfg.ssm_heads)
    phd = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], state.conv)
    xs = xbc[..., :di]
    bmat = xbc[..., di:di + g * n].reshape(xbc.shape[0], g, n)   # S=1 squeezed
    cmat = xbc[..., di + g * n:].reshape(xbc.shape[0], g, n)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(xs.shape[0], h_heads, phd).astype(jnp.float32)
    rep = h_heads // g
    bh = jnp.repeat(bmat, rep, axis=1).astype(jnp.float32)       # (B,H,N)
    ch = jnp.repeat(cmat, rep, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])                             # (B,H)
    st = state.ssm.astype(jnp.float32)
    st = decay[:, :, None, None] * st \
        + (dt[:, :, None] * xh)[..., None] * bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", st, ch) \
        + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(xs.shape[0], 1, di).astype(x.dtype)
    yz = y * jax.nn.silu(z)
    yf = yz.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yn = (yf * jax.lax.rsqrt(var + cfg.norm_eps)
          * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", yn, p["out_proj"].astype(x.dtype))
    return out, MambaState(conv_tail, st.astype(state.ssm.dtype))


# ---------------------------------------------------------------------------
# Naive recurrent reference (tests only)
# ---------------------------------------------------------------------------

def ssd_reference(xh, bmat, cmat, dt, a):
    """Literal recurrence; xh (B,S,H,P), bmat/cmat (B,S,G,N), dt (B,S,H)."""
    bsz, s, h, p = xh.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    bh = jnp.repeat(bmat, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(cmat, rep, axis=2).astype(jnp.float32)
    xf = xh.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(carry, t):
        st = carry
        decay = jnp.exp(dtf[:, t] * a[None, :])       # (B,H)
        st = decay[:, :, None, None] * st \
            + (dtf[:, t][:, :, None] * xf[:, t])[..., None] \
            * bh[:, t][:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", st, ch[:, t])
        return st, y

    st0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    st, ys = jax.lax.scan(step, st0, jnp.arange(s))
    return ys.swapaxes(0, 1), st                      # (B,S,H,P), (B,H,P,N)
