"""Mixture-of-Experts FFN with grouped, sort-based capacity dispatch.

Dispatch strategy (pjit/GSPMD-friendly — no data-dependent shapes, no
(tokens x experts) one-hot materialisation):

  1. router logits -> top_k experts + normalised weights per token,
  2. tokens are processed in GROUPS (one group = one batch row), the group
     axis sharded over the data mesh axes — dispatch state never crosses
     shards, so every buffer below is data-parallel,
  3. position-in-expert via SORT within the group: argsort the flat expert
     ids, rank within each equal-id run (searchsorted on the sorted ids),
     scatter ranks back — O(T log T) and O(T) memory instead of the
     O(T x E) cumsum tensor,
  4. tokens beyond an expert's per-group capacity are dropped (standard
     capacity-factor semantics, cf. Switch/GShard/MaxText),
  5. scatter into an (E, cap_g, d) per-group buffer; batched expert
     einsums; gather back and combine with routing weights.

Sharding: with `moe_ep` rules the expert axis additionally shards over
"model" (olmoe: 64 experts / 16 = 4 per chip) so the dispatch buffer is
(groups/data, E/model, cap_g, d) — fully distributed.  With <16 experts
(mixtral) the expert weights shard their d_ff over "model" instead.

Aux: Switch load-balancing loss + router z-loss + dropped-token fraction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import spec


def moe_spec(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": spec((d, e), ("embed", "experts")),
        "w_gate": spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": spec((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def _group_capacity(group_tokens: int, cfg) -> int:
    cap = int(group_tokens * cfg.top_k * cfg.capacity_factor
              / cfg.n_experts)
    return max(cap, cfg.top_k)


def _dispatch_group(xg, top_e, top_p, e: int, cap: int):
    """One group's dispatch.  xg (T, d); top_e/top_p (T, k).
    Returns (buf (e, cap, d), combine metadata)."""
    t, d = xg.shape
    k = top_e.shape[1]
    flat_e = top_e.reshape(-1)                      # (T*k,)

    # position-in-expert via sort: rank within each expert's run
    order = jnp.argsort(flat_e, stable=True)        # (T*k,)
    sorted_e = flat_e[order]
    run_start = jnp.searchsorted(sorted_e, jnp.arange(e))   # (e,)
    pos_sorted = jnp.arange(t * k) - run_start[sorted_e]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))

    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)     # drop bucket

    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e * cap + 1, d), xg.dtype)
    buf = buf.at[slot].set(xg[tok_idx], mode="drop")
    return buf[:e * cap].reshape(e, cap, d), (slot, keep, tok_idx)


def _combine_group(y, meta, top_p, t: int, e: int, cap: int):
    """Gather expert outputs back to token order, weighted."""
    slot, keep, tok_idx = meta
    d = y.shape[-1]
    y_flat = y.reshape(e * cap, d)
    gathered = y_flat.at[jnp.minimum(slot, e * cap - 1)].get(mode="clip")
    w = (top_p.reshape(-1) * keep).astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype)
    return out.at[tok_idx].add(gathered * w[:, None])


def moe_ffn(p, x, cfg, act="silu", constrain=None):
    """x (B,S,d) -> (out (B,S,d), aux).  Groups = batch rows."""
    from repro.models.layers import act_fn
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _group_capacity(s, cfg)

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    logits_f = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits_f, axis=-1)                # (b,s,e)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (b,s,k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    top_p = top_p.astype(x.dtype)

    bufs, metas = jax.vmap(
        lambda xg, te, tp: _dispatch_group(xg, te, tp, e, cap)
    )(x, top_e, top_p)                                       # (b,e,cap,d)
    if constrain is not None:
        bufs = constrain(bufs, ("batch", "act_experts", "act_cap", None))

    g = jnp.einsum("becd,edf->becf", bufs, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", bufs, p["w_up"].astype(x.dtype))
    h = act_fn(act)(g) * u
    y = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    if constrain is not None:
        y = constrain(y, ("batch", "act_experts", "act_cap", None))

    out = jax.vmap(
        lambda yy, meta, tp: _combine_group(yy, meta, tp, s, e, cap)
    )(y, metas, top_p)
    out = out.reshape(b, s, d)

    # aux losses (fp32): Switch load-balance + z-loss
    pm = probs.reshape(-1, e)
    me = jnp.mean(pm, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e.reshape(-1)[::k], e,
                                 dtype=jnp.float32), axis=0)
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits_f, axis=-1) ** 2)
    keep_frac = jnp.mean(jnp.stack(
        [m.astype(jnp.float32) for m in metas[1]]) if isinstance(
            metas[1], (list, tuple)) else metas[1].astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_dropped": 1.0 - keep_frac}
    return out, aux
