"""Parameter specification trees.

A model is described once as a pytree of `ParamSpec`s (shape + logical axes +
initialiser).  From that single description we derive:

  * real initialised parameters (smoke tests, examples) — `init_tree`,
  * ShapeDtypeStructs with shardings, **no allocation** (dry-run) —
    `abstract_tree`,
  * PartitionSpec / NamedSharding trees — via repro.sharding.rules.

This keeps init, sharding and abstract lowering impossible to de-sync.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.sharding.rules import ShardingRules


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical axis names, len == ndim
    init: str = "normal"                # normal | zeros | ones | normal_out
    scale: Optional[float] = None       # override fan-in scale

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, init="normal", scale=None) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def _fan_in(shape) -> int:
    if len(shape) == 1:
        return shape[0]
    return math.prod(shape[:-1])


def init_leaf(key, s: ParamSpec, dtype) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, dtype)
    scale = s.scale if s.scale is not None else 1.0 / math.sqrt(
        max(_fan_in(s.shape), 1))
    return (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(dtype)


def init_tree(specs, key: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def abstract_tree(specs, mesh: Mesh, rules: ShardingRules,
                  dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins with shardings attached — dry-run inputs."""
    def mk(s: ParamSpec):
        return jax.ShapeDtypeStruct(
            s.shape, dtype,
            sharding=rules.shape_sharding(mesh, s.axes, s.shape))
    return jax.tree.map(mk, specs, is_leaf=_is_spec)


def sharding_tree(specs, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda s: rules.shape_sharding(mesh, s.axes, s.shape), specs,
        is_leaf=_is_spec)


def spec_tree(specs, rules: ShardingRules):
    return jax.tree.map(lambda s: rules.spec(s.axes), specs, is_leaf=_is_spec)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return sum(math.prod(s.shape) for s in leaves)
