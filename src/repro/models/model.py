"""Model assembly: family dispatch, scan-over-layers, caches, entry points.

Every architecture exposes the same three jit-able entry points:

    forward(params, batch)              -> (per-token logits, aux)   [train]
    prefill(params, batch)              -> (last-token logits, cache)
    decode_step(params, batch, cache)   -> (logits, cache)           [serve]

Layers are stacked with `jax.lax.scan` (params carry a leading "layers"
axis) and rematerialised with a configurable policy, keeping the HLO small
enough to compile 80-layer models and the activation memory bounded.

Caches:
  * attention — (L, B, T, Hkv, hd) K/V ring buffers; sliding-window archs
    allocate only the window (the SWA memory win; seq lens here are
    multiples of the window so ring slots align, asserted below),
  * ssm — MambaState stacked per layer: O(1) in context length,
  * vlm — cross-attention K/V computed once from the image embeddings.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec, spec

PyTree = Any


@dataclasses.dataclass(frozen=True)
class RunFlags:
    """Per-run (not per-arch) knobs — the §Perf hillclimb levers."""
    remat: str = "full"            # none | dots | full
    block_q: int = 512
    block_kv: int = 1024
    skip_blocks: bool = False      # causal prefix-only attention chunks
    loss_chunk: int = 0            # 0 = unchunked CE
    scan_layers: bool = True       # False: python-unroll the layer stack
    attn_unroll: bool = False      # unroll attention block loops (cost calib)
    fold_heads: bool = False       # shard attention over folded batch x
    #                                kv-heads (fixes non-divisible head counts)
    cache_seq_model: bool = False  # decode: shard the KV cache sequence dim
    #                                over "model" (flash-decode layout)
    seq_shard_acts: bool = False   # Megatron-SP: residual-stream activations
    #                                sequence-sharded over "model"


def _remat(fn, flags: RunFlags):
    if flags.remat == "none":
        return fn
    if flags.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def scan_or_loop(body, carry, xs, scan: bool):
    """lax.scan, or an equivalent python unroll (XLA cost_analysis counts a
    while body once regardless of trip count, so the dry-run calibration
    builds unroll — see launch/dryrun.py)."""
    if scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
    else:
        ys = ys[0] if ys else None
    return carry, ys


def _stack(specs: PyTree, n: int) -> PyTree:
    """Prepend a scanned 'layers' axis to every spec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                            s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _attn_cfg(flags: RunFlags) -> L.AttnBlockCfg:
    return L.AttnBlockCfg(flags.block_q, flags.block_kv, flags.skip_blocks,
                          flags.attn_unroll)


# ---------------------------------------------------------------------------
# Transformer block (dense / moe / audio backbones)
# ---------------------------------------------------------------------------

def block_spec(cfg: ModelConfig) -> PyTree:
    p = {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = MOE.moe_spec(cfg)
    else:
        p["mlp"] = L.mlp_spec(cfg)
    return p


def _folded_attention(q, k, v, cfg, flags, constrain, causal=True):
    """Attention sharded over the folded (batch x kv-heads) axis.

    Head counts that do not divide the model axis (9, 24, or GQA kv=8 vs
    model=16) force head replication under plain head sharding; folding
    batch into kv-heads gives a leading axis (B * Hkv) that divides the
    full mesh for every assigned arch (B >= 128).  The GQA group dimension
    rides along as the per-fold head dim.  §Perf lever."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv

    def fold_q(t):   # (B,S,Hkv*g,hd) -> (B*Hkv, S, g, hd)
        t = t.reshape(b, s, hkv, g, hd).transpose(0, 2, 1, 3, 4)
        return t.reshape(b * hkv, s, g, hd)

    def fold_kv(t):  # (B,S,Hkv,hd) -> (B*Hkv, S, 1, hd)
        return t.transpose(0, 2, 1, 3).reshape(b * hkv, s, 1, hd)

    qf = constrain(fold_q(q), ("fold_bh", "seq", None, None))
    kf = constrain(fold_kv(k), ("fold_bh", "seq", None, None))
    vf = constrain(fold_kv(v), ("fold_bh", "seq", None, None))
    attn = L.blockwise_attention(qf, kf, vf, causal=causal,
                                 window=cfg.sliding_window,
                                 cfg=_attn_cfg(flags))
    attn = attn.reshape(b, hkv, s, g, hd).transpose(0, 2, 1, 3, 4)
    return attn.reshape(b, s, hq, hd)


def block_apply(p, h, cfg: ModelConfig, flags: RunFlags, positions,
                constrain):
    """Training/prefill block.  Returns (h, (k, v), aux)."""
    x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
    q, k, v = L.qkv_proj(p["attn"], x, cfg, positions)
    if flags.fold_heads:
        attn = _folded_attention(q, k, v, cfg, flags, constrain)
    else:
        q = constrain(q, ("batch", "seq", "act_heads", None))
        k = constrain(k, ("batch", "seq", "act_kv_heads", None))
        v = constrain(v, ("batch", "seq", "act_kv_heads", None))
        attn = L.blockwise_attention(q, k, v, causal=True,
                                     window=cfg.sliding_window,
                                     cfg=_attn_cfg(flags))
    h = h + L.out_proj(p["attn"], attn)
    h = constrain(h, ("batch", "seq_res", "act_embed"))
    x2 = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = MOE.moe_ffn(p["moe"], x2, cfg, cfg.act, constrain)
    else:
        y, aux = L.mlp(p["mlp"], x2, cfg.act), {}
    h = h + y
    h = constrain(h, ("batch", "seq_res", "act_embed"))
    return h, (k, v), aux


def block_decode(p, h, cfg: ModelConfig, k_cache, v_cache, cache_len,
                 positions, constrain):
    """One-token block step against a cache.  Returns (h, k_cache, v_cache)."""
    bsz = h.shape[0]
    x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
    q, k, v = L.qkv_proj(p["attn"], x, cfg, positions)
    t = k_cache.shape[1]
    widx = cache_len % t if cfg.sliding_window else jnp.minimum(
        cache_len, t - 1)
    k_cache = k_cache.at[jnp.arange(bsz), widx].set(k[:, 0])
    v_cache = v_cache.at[jnp.arange(bsz), widx].set(v[:, 0])
    new_len = cache_len + 1
    attn = L.decode_attention(q, k_cache, v_cache, new_len, window=None)
    h = h + L.out_proj(p["attn"], attn)
    x2 = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = MOE.moe_ffn(p["moe"], x2, cfg, cfg.act, constrain)
    else:
        y = L.mlp(p["mlp"], x2, cfg.act)
    h = h + y
    h = constrain(h, ("batch", None, "act_embed"))
    return h, k_cache, v_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_spec(cfg: ModelConfig) -> PyTree:
    p = {"ln_f": L.rmsnorm_spec(cfg.d_model),
         "unembed": spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))}
    if not cfg.embed_stub:
        p["embed"] = spec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                          scale=1.0)
    return p


def embed_tokens(p, cfg, batch, constrain):
    if cfg.embed_stub:
        h = batch["frames"]                    # (B, S, d) precomputed stub
    else:
        h = jnp.take(p["embed"], batch["tokens"], axis=0)
    h = h.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    return constrain(h, ("batch", "seq_res", "act_embed"))


def logits_fn(p, cfg, h, constrain):
    logits = jnp.einsum("bsd,dv->bsv", h, p["unembed"].astype(h.dtype))
    return constrain(logits, ("batch", "seq", "act_vocab"))


def ce_loss(p, cfg, h, labels, constrain, flags: RunFlags):
    """Cross-entropy; optionally chunked over the sequence so the (B,Sc,V)
    logits block bounds peak memory (§Perf lever)."""
    def chunk_loss(hc, yc):
        logits = logits_fn(p, cfg, hc, constrain).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(yc, cfg.vocab, dtype=logits.dtype)
        # keep the (B,S,V) one-hot sharded like the logits — unsharded it
        # is the single biggest buffer in the whole step
        onehot = constrain(onehot, ("batch", "seq", "act_vocab"))
        correct = jnp.sum(logits * onehot, axis=-1)
        return jnp.sum(lse - correct)

    b, s, _ = h.shape
    n_tok = b * s
    if flags.loss_chunk and s % flags.loss_chunk == 0 and s > flags.loss_chunk:
        nc = s // flags.loss_chunk
        hc = h.reshape(b, nc, flags.loss_chunk, -1).swapaxes(0, 1)
        yc = labels.reshape(b, nc, flags.loss_chunk).swapaxes(0, 1)
        tot = jax.lax.map(lambda t: chunk_loss(t[0], t[1]), (hc, yc))
        return jnp.sum(tot) / n_tok
    return chunk_loss(h, labels) / n_tok


# ---------------------------------------------------------------------------
# Family: dense / moe / audio (shared skeleton)
# ---------------------------------------------------------------------------

def _tf_specs(cfg: ModelConfig) -> PyTree:
    return {"blocks": _stack(block_spec(cfg), cfg.n_layers),
            "head": embed_spec(cfg)}


def _tf_forward(params, batch, cfg, flags, constrain):
    h = embed_tokens(params["head"], cfg, batch, constrain)
    positions = jnp.arange(h.shape[1])[None, :]
    aux_acc = {}

    def body(hh, lp):
        hh, _, aux = block_apply(lp, hh, cfg, flags, positions, constrain)
        return hh, aux

    body_r = _remat(body, flags)
    h, auxs = scan_or_loop(body_r, h, params["blocks"], flags.scan_layers)
    if auxs:
        aux_acc = {k: jnp.sum(v) for k, v in auxs.items()}
    h = L.rmsnorm(params["head"]["ln_f"], h, cfg.norm_eps)
    return h, aux_acc


def _tf_prefill(params, batch, cfg, flags, constrain, cache_t):
    h = embed_tokens(params["head"], cfg, batch, constrain)
    s = h.shape[1]
    positions = jnp.arange(s)[None, :]

    def body(hh, lp):
        hh, (k, v), _ = block_apply(lp, hh, cfg, flags, positions, constrain)
        if cache_t < s:      # SWA: keep the last window only (ring-aligned)
            assert s % cache_t == 0
            k, v = k[:, -cache_t:], v[:, -cache_t:]
        elif cache_t > s:
            pad = cache_t - s
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = constrain(k, ("cache_batch", "cache_seq", "act_kv_heads", None))
        v = constrain(v, ("cache_batch", "cache_seq", "act_kv_heads", None))
        return hh, (k, v)

    body_r = _remat(body, flags)
    h, (k_all, v_all) = scan_or_loop(body_r, h, params["blocks"],
                                     flags.scan_layers)
    h = L.rmsnorm(params["head"]["ln_f"], h[:, -1:], cfg.norm_eps)
    logits = logits_fn(params["head"], cfg, h, constrain)
    cache = {"k": k_all, "v": v_all,
             "len": jnp.full((h.shape[0],), s, jnp.int32)}
    return logits, cache


def _tf_decode(params, batch, cache, cfg, flags, constrain):
    h = embed_tokens(params["head"], cfg,
                     {"tokens": batch["token"][:, None]} if not cfg.embed_stub
                     else {"frames": batch["frame"][:, None, :]}, constrain)
    cache_len = cache["len"]
    positions = cache_len[:, None]

    def body(hh, xs):
        lp, kc, vc = xs
        hh, kc, vc = block_decode(lp, hh, cfg, kc, vc, cache_len,
                                  positions, constrain)
        return hh, (kc, vc)

    h, (k_new, v_new) = scan_or_loop(body, h, (params["blocks"],
                                               cache["k"], cache["v"]),
                                     flags.scan_layers)
    h = L.rmsnorm(params["head"]["ln_f"], h, cfg.norm_eps)
    logits = logits_fn(params["head"], cfg, h, constrain)
    return logits, {"k": k_new, "v": v_new, "len": cache_len + 1}


def _tf_cache_specs(cfg: ModelConfig, batch: int, cache_t: int) -> PyTree:
    kv = {"k": spec((cfg.n_layers, batch, cache_t, cfg.n_kv_heads,
                     cfg.head_dim),
                    ("layers", "cache_batch", "cache_seq", "act_kv_heads",
                     None)),
          "v": spec((cfg.n_layers, batch, cache_t, cfg.n_kv_heads,
                     cfg.head_dim),
                    ("layers", "cache_batch", "cache_seq", "act_kv_heads",
                     None)),
          "len": spec((batch,), ("cache_batch",), init="zeros")}
    return kv


# ---------------------------------------------------------------------------
# Family: ssm (mamba2)
# ---------------------------------------------------------------------------

def _ssm_specs(cfg: ModelConfig) -> PyTree:
    blk = {"ln": L.rmsnorm_spec(cfg.d_model), "mamba": SSM.mamba2_spec(cfg)}
    return {"blocks": _stack(blk, cfg.n_layers), "head": embed_spec(cfg)}


def _ssm_forward(params, batch, cfg, flags, constrain):
    h = embed_tokens(params["head"], cfg, batch, constrain)

    def body(hh, lp):
        x = L.rmsnorm(lp["ln"], hh, cfg.norm_eps)
        y, _ = SSM.mamba2_forward(lp["mamba"], x, cfg)
        hh = constrain(hh + y, ("batch", "seq_res", "act_embed"))
        return hh, None

    body_r = _remat(body, flags)
    h, _ = scan_or_loop(body_r, h, params["blocks"], flags.scan_layers)
    h = L.rmsnorm(params["head"]["ln_f"], h, cfg.norm_eps)
    return h, {}


def _ssm_prefill(params, batch, cfg, flags, constrain, cache_t):
    h = embed_tokens(params["head"], cfg, batch, constrain)

    def body(hh, lp):
        x = L.rmsnorm(lp["ln"], hh, cfg.norm_eps)
        y, st = SSM.mamba2_forward(lp["mamba"], x, cfg)
        hh = constrain(hh + y, ("batch", "seq_res", "act_embed"))
        return hh, st

    body_r = _remat(body, flags)
    h, states = scan_or_loop(body_r, h, params["blocks"], flags.scan_layers)
    h = L.rmsnorm(params["head"]["ln_f"], h[:, -1:], cfg.norm_eps)
    logits = logits_fn(params["head"], cfg, h, constrain)
    cache = {"conv": states.conv, "ssm": states.ssm,
             "len": jnp.full((h.shape[0],), batch_len(batch), jnp.int32)}
    return logits, cache


def batch_len(batch) -> int:
    if "tokens" in batch:
        return batch["tokens"].shape[1]
    return batch["frames"].shape[1]


def _ssm_decode(params, batch, cache, cfg, flags, constrain):
    h = embed_tokens(params["head"], cfg,
                     {"tokens": batch["token"][:, None]}, constrain)

    def body(hh, xs):
        lp, conv, st = xs
        x = L.rmsnorm(lp["ln"], hh, cfg.norm_eps)
        y, new_state = SSM.mamba2_decode_step(
            lp["mamba"], x, cfg, SSM.MambaState(conv, st))
        hh = hh + y
        return hh, (new_state.conv, new_state.ssm)

    h, (conv_new, ssm_new) = scan_or_loop(
        body, h, (params["blocks"], cache["conv"], cache["ssm"]),
        flags.scan_layers)
    h = L.rmsnorm(params["head"]["ln_f"], h, cfg.norm_eps)
    logits = logits_fn(params["head"], cfg, h, constrain)
    return logits, {"conv": conv_new, "ssm": ssm_new,
                    "len": cache["len"] + 1}


def _ssm_cache_specs(cfg: ModelConfig, batch: int, cache_t: int) -> PyTree:
    st = SSM.mamba2_state_spec(cfg, batch)
    return {"conv": _stack(st["conv"], cfg.n_layers),
            "ssm": _stack(st["ssm"], cfg.n_layers),
            "len": spec((batch,), ("cache_batch",), init="zeros")}


# ---------------------------------------------------------------------------
# Family: hybrid (zamba2: mamba2 + weight-shared attention block w/ LoRA)
# ---------------------------------------------------------------------------

def _hybrid_groups(cfg: ModelConfig):
    every = cfg.shared_attn_every
    assert cfg.n_layers % every == 0, (cfg.n_layers, every)
    return cfg.n_layers // every, every


def _shared_block_spec(cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    return {
        "ln": L.rmsnorm_spec(2 * d),
        "attn": L.attention_spec(cfg, d_in=2 * d),
        "ln2": L.rmsnorm_spec(d),
        "mlp": L.mlp_spec(cfg),
    }


def _lora_spec(cfg: ModelConfig) -> PyTree:
    d, r = 2 * cfg.d_model, cfg.shared_lora_rank
    out = {}
    for nm, heads in (("q", cfg.n_heads), ("k", cfg.n_kv_heads),
                      ("v", cfg.n_kv_heads)):
        out[f"{nm}_a"] = spec((d, r), ("embed", "lora"))
        out[f"{nm}_b"] = spec((r, heads, cfg.head_dim),
                              ("lora", "kv_heads", None), init="zeros")
    return out


def _hybrid_specs(cfg: ModelConfig) -> PyTree:
    ng, every = _hybrid_groups(cfg)
    blk = {"ln": L.rmsnorm_spec(cfg.d_model), "mamba": SSM.mamba2_spec(cfg)}
    return {
        "mamba_blocks": _stack(_stack(blk, every), ng),
        "shared": _shared_block_spec(cfg),
        "lora": _stack(_lora_spec(cfg), ng),
        "head": embed_spec(cfg),
    }


def _shared_attn_apply(params, h, h0, lora, cfg, flags, positions, constrain,
                       kv_out=False):
    """Shared attention block on concat(h, h0) (zamba2)."""
    hcat = jnp.concatenate([h, h0], axis=-1)
    x = L.rmsnorm(params["ln"], hcat, cfg.norm_eps)
    q, k, v = L.qkv_proj(params["attn"], x, cfg, positions, lora=lora)
    q = constrain(q, ("batch", "seq", "act_heads", None))
    attn = L.blockwise_attention(q, k, v, causal=True, cfg=_attn_cfg(flags))
    h = h + L.out_proj(params["attn"], attn)
    x2 = L.rmsnorm(params["ln2"], h, cfg.norm_eps)
    h = h + L.mlp(params["mlp"], x2, cfg.act)
    h = constrain(h, ("batch", "seq_res", "act_embed"))
    return (h, (k, v)) if kv_out else (h, None)


def _hybrid_forward_impl(params, batch, cfg, flags, constrain, collect_kv,
                         cache_t=None):
    h = embed_tokens(params["head"], cfg, batch, constrain)
    h0 = h
    s = h.shape[1]
    positions = jnp.arange(s)[None, :]

    def group(hh, xs):
        gp, lora = xs
        hh, kv = _shared_attn_apply(params["shared"], hh, h0, lora, cfg,
                                    flags, positions, constrain,
                                    kv_out=collect_kv)

        def inner(hh2, lp):
            x = L.rmsnorm(lp["ln"], hh2, cfg.norm_eps)
            y, st = SSM.mamba2_forward(lp["mamba"], x, cfg)
            return constrain(hh2 + y, ("batch", "seq_res", "act_embed")), st

        hh, states = scan_or_loop(inner, hh, gp, flags.scan_layers)
        return hh, (kv, states)

    group_r = _remat(group, flags)
    h, (kvs, states) = scan_or_loop(group_r, h,
                                    (params["mamba_blocks"], params["lora"]),
                                    flags.scan_layers)
    return h, kvs, states


def _hybrid_forward(params, batch, cfg, flags, constrain):
    h, _, _ = _hybrid_forward_impl(params, batch, cfg, flags, constrain,
                                   collect_kv=False)
    h = L.rmsnorm(params["head"]["ln_f"], h, cfg.norm_eps)
    return h, {}


def _hybrid_prefill(params, batch, cfg, flags, constrain, cache_t):
    h, kvs, states = _hybrid_forward_impl(params, batch, cfg, flags,
                                          constrain, collect_kv=True,
                                          cache_t=cache_t)
    k_all, v_all = kvs
    k_all = constrain(k_all, (None, "cache_batch", "cache_seq",
                              "act_kv_heads", None))
    v_all = constrain(v_all, (None, "cache_batch", "cache_seq",
                              "act_kv_heads", None))
    s = batch_len(batch)
    h = L.rmsnorm(params["head"]["ln_f"], h[:, -1:], cfg.norm_eps)
    logits = logits_fn(params["head"], cfg, h, constrain)
    if cache_t > s:
        pad = cache_t - s
        k_all = jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v_all = jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k_all, "v": v_all, "conv": states.conv, "ssm": states.ssm,
             "len": jnp.full((logits.shape[0],), s, jnp.int32)}
    return logits, cache


def _hybrid_decode(params, batch, cache, cfg, flags, constrain):
    h = embed_tokens(params["head"], cfg,
                     {"tokens": batch["token"][:, None]}, constrain)
    h0 = h
    cache_len = cache["len"]
    positions = cache_len[:, None]
    bsz = h.shape[0]

    def group(hh, xs):
        lora, kc, vc, conv, st, gp = xs
        # shared attention against this group's cache slice
        hcat = jnp.concatenate([hh, h0], axis=-1)
        x = L.rmsnorm(params["shared"]["ln"], hcat, cfg.norm_eps)
        q, k, v = L.qkv_proj(params["shared"]["attn"], x, cfg, positions,
                             lora=lora)
        widx = jnp.minimum(cache_len, kc.shape[1] - 1)
        kc = kc.at[jnp.arange(bsz), widx].set(k[:, 0])
        vc = vc.at[jnp.arange(bsz), widx].set(v[:, 0])
        attn = L.decode_attention(q, kc, vc, cache_len + 1)
        hh = hh + L.out_proj(params["shared"]["attn"], attn)
        x2 = L.rmsnorm(params["shared"]["ln2"], hh, cfg.norm_eps)
        hh = hh + L.mlp(params["shared"]["mlp"], x2, cfg.act)

        def inner(hh2, xs2):
            lp, conv_l, st_l = xs2
            x3 = L.rmsnorm(lp["ln"], hh2, cfg.norm_eps)
            y, ns = SSM.mamba2_decode_step(lp["mamba"], x3, cfg,
                                           SSM.MambaState(conv_l, st_l))
            return hh2 + y, (ns.conv, ns.ssm)

        hh, (conv_n, ssm_n) = scan_or_loop(inner, hh, (gp, conv, st),
                                           flags.scan_layers)
        return hh, (kc, vc, conv_n, ssm_n)

    h, (k_n, v_n, conv_n, ssm_n) = scan_or_loop(
        group, h, (params["lora"], cache["k"], cache["v"], cache["conv"],
                   cache["ssm"], params["mamba_blocks"]), flags.scan_layers)
    h = L.rmsnorm(params["head"]["ln_f"], h, cfg.norm_eps)
    logits = logits_fn(params["head"], cfg, h, constrain)
    return logits, {"k": k_n, "v": v_n, "conv": conv_n, "ssm": ssm_n,
                    "len": cache_len + 1}


def _hybrid_cache_specs(cfg: ModelConfig, batch: int, cache_t: int) -> PyTree:
    ng, every = _hybrid_groups(cfg)
    st = SSM.mamba2_state_spec(cfg, batch)
    return {
        "k": spec((ng, batch, cache_t, cfg.n_kv_heads, cfg.head_dim),
                  (None, "cache_batch", "cache_seq", "act_kv_heads", None)),
        "v": spec((ng, batch, cache_t, cfg.n_kv_heads, cfg.head_dim),
                  (None, "cache_batch", "cache_seq", "act_kv_heads", None)),
        "conv": _stack(_stack(st["conv"], every), ng),
        "ssm": _stack(_stack(st["ssm"], every), ng),
        "len": spec((batch,), ("cache_batch",), init="zeros"),
    }


# ---------------------------------------------------------------------------
# Family: vlm (llama + gated cross-attention over stub image embeddings)
# ---------------------------------------------------------------------------

def _vlm_groups(cfg: ModelConfig):
    every = cfg.cross_attn_every
    assert cfg.n_layers % every == 0
    return cfg.n_layers // every, every - 1   # (groups, self layers/group)


def _cross_spec(cfg: ModelConfig) -> PyTree:
    return {
        "ln": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_spec(cfg),
        "gate": spec((1,), (None,), init="zeros"),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
        "gate_mlp": spec((1,), (None,), init="zeros"),
    }


def _vlm_specs(cfg: ModelConfig) -> PyTree:
    ng, n_self = _vlm_groups(cfg)
    return {
        "self_blocks": _stack(_stack(block_spec(cfg), n_self), ng),
        "cross_blocks": _stack(_cross_spec(cfg), ng),
        "head": embed_spec(cfg),
    }


def _cross_apply(cp, h, img_kv, cfg, flags, constrain):
    """Gated cross-attention (llama-3.2-vision style)."""
    k_img, v_img = img_kv
    x = L.rmsnorm(cp["ln"], h, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", x, cp["attn"]["wq"].astype(x.dtype))
    attn = L.blockwise_attention(q, k_img, v_img, causal=False,
                                 cfg=_attn_cfg(flags))
    gate = jnp.tanh(cp["gate"].astype(h.dtype))
    h = h + gate * L.out_proj(cp["attn"], attn)
    x2 = L.rmsnorm(cp["ln2"], h, cfg.norm_eps)
    gate2 = jnp.tanh(cp["gate_mlp"].astype(h.dtype))
    h = h + gate2 * L.mlp(cp["mlp"], x2, cfg.act)
    return constrain(h, ("batch", "seq_res", "act_embed"))


def _vlm_img_kv(cp, img, cfg):
    """Image-side K/V for one cross block (no RoPE on image tokens)."""
    k = jnp.einsum("bsd,dhk->bshk", img, cp["attn"]["wk"].astype(img.dtype))
    v = jnp.einsum("bsd,dhk->bshk", img, cp["attn"]["wv"].astype(img.dtype))
    return k, v


def _vlm_forward_impl(params, batch, cfg, flags, constrain, collect_kv,
                      cache_t=None):
    h = embed_tokens(params["head"], cfg, batch, constrain)
    img = batch["img_embeds"].astype(h.dtype)
    s = h.shape[1]
    positions = jnp.arange(s)[None, :]

    def group(hh, xs):
        sp, cp = xs
        # 3 self layers, cross at slot 3, final self layer (cross_every=5)
        def self_body(hh2, lp):
            hh2, kv, _ = block_apply(lp, hh2, cfg, flags, positions,
                                     constrain)
            return hh2, kv

        n_self = jax.tree.leaves(sp)[0].shape[0]
        first = jax.tree.map(lambda t: t[:n_self - 1], sp)
        last = jax.tree.map(lambda t: t[n_self - 1], sp)
        hh, kv_first = scan_or_loop(self_body, hh, first,
                                    flags.scan_layers)
        img_kv = _vlm_img_kv(cp, img, cfg)
        hh = _cross_apply(cp, hh, img_kv, cfg, flags, constrain)
        hh, kv_last = self_body(hh, last)
        kvs = None
        if collect_kv:
            kvs = (jnp.concatenate([kv_first[0], kv_last[0][None]], 0),
                   jnp.concatenate([kv_first[1], kv_last[1][None]], 0),
                   img_kv[0], img_kv[1])
        return hh, kvs

    group_r = _remat(group, flags)
    h, kvs = scan_or_loop(group_r, h, (params["self_blocks"],
                                       params["cross_blocks"]),
                          flags.scan_layers)
    return h, kvs


def _vlm_forward(params, batch, cfg, flags, constrain):
    h, _ = _vlm_forward_impl(params, batch, cfg, flags, constrain, False)
    h = L.rmsnorm(params["head"]["ln_f"], h, cfg.norm_eps)
    return h, {}


def _vlm_prefill(params, batch, cfg, flags, constrain, cache_t):
    h, kvs = _vlm_forward_impl(params, batch, cfg, flags, constrain, True)
    k_self, v_self, k_img, v_img = kvs
    s = batch_len(batch)
    if cache_t > s:
        pad = ((0, 0), (0, 0), (0, 0), (0, cache_t - s), (0, 0), (0, 0))
        k_self = jnp.pad(k_self, pad)
        v_self = jnp.pad(v_self, pad)
    h = L.rmsnorm(params["head"]["ln_f"], h[:, -1:], cfg.norm_eps)
    logits = logits_fn(params["head"], cfg, h, constrain)
    cache = {"k": k_self, "v": v_self, "k_img": k_img, "v_img": v_img,
             "len": jnp.full((logits.shape[0],), s, jnp.int32)}
    return logits, cache


def _vlm_decode(params, batch, cache, cfg, flags, constrain):
    h = embed_tokens(params["head"], cfg,
                     {"tokens": batch["token"][:, None]}, constrain)
    cache_len = cache["len"]
    positions = cache_len[:, None]
    bsz = h.shape[0]

    def group(hh, xs):
        sp, cp, kc, vc, k_img, v_img = xs
        n_self = jax.tree.leaves(sp)[0].shape[0]

        def self_body(hh2, xs2):
            lp, kc_l, vc_l = xs2
            hh2, kc_l, vc_l = block_decode(lp, hh2, cfg, kc_l, vc_l,
                                           cache_len, positions, constrain)
            return hh2, (kc_l, vc_l)

        first = jax.tree.map(lambda t: t[:n_self - 1], sp)
        last = jax.tree.map(lambda t: t[n_self - 1], sp)
        hh, (kc1, vc1) = scan_or_loop(self_body, hh,
                                      (first, kc[:n_self - 1],
                                       vc[:n_self - 1]), flags.scan_layers)
        # cross attention against the static image cache
        x = L.rmsnorm(cp["ln"], hh, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", x, cp["attn"]["wq"].astype(x.dtype))
        img_len = jnp.full((bsz,), k_img.shape[1], jnp.int32)
        attn = L.decode_attention(q, k_img, v_img, img_len)
        gate = jnp.tanh(cp["gate"].astype(hh.dtype))
        hh = hh + gate * L.out_proj(cp["attn"], attn)
        x2 = L.rmsnorm(cp["ln2"], hh, cfg.norm_eps)
        gate2 = jnp.tanh(cp["gate_mlp"].astype(hh.dtype))
        hh = hh + gate2 * L.mlp(cp["mlp"], x2, cfg.act)
        hh, (kc2, vc2) = self_body(hh, (last, kc[n_self - 1], vc[n_self - 1]))
        k_new = jnp.concatenate([kc1, kc2[None]], 0)
        v_new = jnp.concatenate([vc1, vc2[None]], 0)
        return hh, (k_new, v_new)

    h, (k_n, v_n) = scan_or_loop(group, h,
                                 (params["self_blocks"],
                                  params["cross_blocks"], cache["k"],
                                  cache["v"], cache["k_img"],
                                  cache["v_img"]), flags.scan_layers)
    h = L.rmsnorm(params["head"]["ln_f"], h, cfg.norm_eps)
    logits = logits_fn(params["head"], cfg, h, constrain)
    return logits, {"k": k_n, "v": v_n, "k_img": cache["k_img"],
                    "v_img": cache["v_img"], "len": cache_len + 1}


def _vlm_cache_specs(cfg: ModelConfig, batch: int, cache_t: int) -> PyTree:
    ng, n_self = _vlm_groups(cfg)
    kv_shape = (ng, n_self, batch, cache_t, cfg.n_kv_heads, cfg.head_dim)
    kv_axes = (None, "layers", "cache_batch", "cache_seq", "act_kv_heads",
               None)
    return {
        "k": spec(kv_shape, kv_axes),
        "v": spec(kv_shape, kv_axes),
        "k_img": spec((ng, batch, cfg.n_img_tokens, cfg.n_kv_heads,
                       cfg.head_dim),
                      (None, "cache_batch", None, "act_kv_heads", None)),
        "v_img": spec((ng, batch, cfg.n_img_tokens, cfg.n_kv_heads,
                       cfg.head_dim),
                      (None, "cache_batch", None, "act_kv_heads", None)),
        "len": spec((batch,), ("cache_batch",), init="zeros"),
    }


# ---------------------------------------------------------------------------
# Public dispatch
# ---------------------------------------------------------------------------

_FAMILY = {
    "dense": (_tf_specs, _tf_forward, _tf_prefill, _tf_decode,
              _tf_cache_specs),
    "moe": (_tf_specs, _tf_forward, _tf_prefill, _tf_decode,
            _tf_cache_specs),
    "audio": (_tf_specs, _tf_forward, _tf_prefill, _tf_decode,
              _tf_cache_specs),
    "ssm": (_ssm_specs, _ssm_forward, _ssm_prefill, _ssm_decode,
            _ssm_cache_specs),
    "hybrid": (_hybrid_specs, _hybrid_forward, _hybrid_prefill,
               _hybrid_decode, _hybrid_cache_specs),
    "vlm": (_vlm_specs, _vlm_forward, _vlm_prefill, _vlm_decode,
            _vlm_cache_specs),
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    flags: RunFlags

    def param_specs(self) -> PyTree:
        return _FAMILY[self.cfg.family][0](self.cfg)

    def cache_specs(self, batch: int, seq: int) -> PyTree:
        cache_t = seq
        if self.cfg.sliding_window is not None:
            cache_t = min(seq, self.cfg.sliding_window)
        return _FAMILY[self.cfg.family][4](self.cfg, batch, cache_t)

    def cache_len_for(self, seq: int) -> int:
        if self.cfg.sliding_window is not None:
            return min(seq, self.cfg.sliding_window)
        return seq

    def forward(self, params, batch, constrain):
        """Train-mode forward: returns (hidden (B,S,d), aux)."""
        return _FAMILY[self.cfg.family][1](params, batch, self.cfg,
                                           self.flags, constrain)

    def loss(self, params, batch, constrain):
        h, aux = self.forward(params, batch, constrain)
        loss = ce_loss(params["head"], self.cfg, h, batch["labels"],
                       constrain, self.flags)
        if "moe_lb_loss" in aux:
            loss = loss + self.cfg.router_aux_coef * aux["moe_lb_loss"] \
                + 1e-3 * aux["moe_z_loss"]
        return loss, aux

    def prefill(self, params, batch, constrain, max_len: int = 0):
        """max_len > seq reserves decode headroom in the attention caches."""
        cache_t = self.cache_len_for(max(batch_len(batch), max_len))
        return _FAMILY[self.cfg.family][2](params, batch, self.cfg,
                                           self.flags, constrain, cache_t)

    def decode_step(self, params, batch, cache, constrain):
        return _FAMILY[self.cfg.family][3](params, batch, cache, self.cfg,
                                           self.flags, constrain)


def no_constrain(x, axes=None):
    return x


def make_constrain(mesh, rules):
    def constrain(x, axes):
        return jax.lax.with_sharding_constraint(
            x, rules.shape_sharding(mesh, axes, x.shape))
    return constrain
