"""Shared neural-net layers: norms, rotary embeddings, attention, MLP.

Attention is implemented blockwise (flash-attention-style online softmax via
lax.scan over KV blocks, with the query axis chunked by an outer scan) so the
(S x S) score matrix never materialises — required for the 32k-prefill and
500k-context shapes.  Causal and sliding-window masks are applied per block.

The `skip_blocks` option (beyond-paper perf lever, see EXPERIMENTS.md §Perf)
unrolls the query chunks in Python so each chunk only scans the KV prefix it
can actually attend to — removing the ~2x masked-flops waste of the scanned
version at the price of a larger (but still layer-scanned) HLO.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.params import spec


# ---------------------------------------------------------------------------
# Norms / activations / rotary
# ---------------------------------------------------------------------------

def rmsnorm_spec(d):
    return {"scale": spec((d,), ("norm",), init="ones")}


def rmsnorm(params, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def rotary(x, positions, theta=10000.0):
    """Apply RoPE.  x (..., S, H, hd); positions (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention
# ---------------------------------------------------------------------------

class AttnBlockCfg(NamedTuple):
    block_q: int = 512
    block_kv: int = 1024
    skip_blocks: bool = False    # unroll q chunks, scan only the live prefix
    unroll: bool = False         # python-unroll ALL block loops (cost calib)


def _pick_block(total: int, want: int) -> int:
    """Largest divisor of `total` that is <= want (block sizes must tile)."""
    want = min(want, total)
    for b in range(want, 0, -1):
        if total % b == 0:
            return b
    return total


def _attend_block(q, k, v, mask, scale):
    """q (B,bq,H,hd), k/v (B,bk,Hkv,hd), mask (bq,bk) or None.
    Returns (scores_exp_sum, new_max, weighted_v) pieces for online softmax.
    GQA: H = Hkv * group."""
    b, bq, h, hd = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, bq, hkv, group, hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale    # (B,bq,Hkv,g,bk)
    if mask is not None:
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    return s


def _block_mask(q_pos, k_pos, causal, window):
    """(bq, bk) boolean mask; True = attend."""
    m = None
    if causal:
        m = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        w = q_pos[:, None] - k_pos[None, :] < window
        m = w if m is None else (m & w)
    return m


def blockwise_attention(q, k, v, *, causal=True,
                        window: Optional[int] = None,
                        cfg: AttnBlockCfg = AttnBlockCfg(),
                        q_offset: int = 0):
    """Flash-style attention.  q (B,S,H,hd); k,v (B,T,Hkv,hd).

    q_offset: absolute position of q[0] relative to k[0] (prefill: 0;
    decode-with-cache uses the dense path below instead).
    """
    b, sq, h, hd = q.shape
    t = k.shape[1]
    scale = 1.0 / (hd ** 0.5)
    bq = _pick_block(sq, cfg.block_q)
    bk = _pick_block(t, cfg.block_kv)
    nq, nk = sq // bq, t // bk
    hkv = k.shape[2]
    group = h // hkv

    k_blocks = k.reshape(b, nk, bk, hkv, hd)
    v_blocks = v.reshape(b, nk, bk, hkv, hd)

    def q_chunk(qc, iq, nk_live):
        """Online softmax over the first nk_live kv blocks (static).

        Both the per-block body and the whole chunk are checkpointed: the
        backward pass then recomputes score blocks instead of storing every
        (bq x bk) block of the linearised scan — the flash-attention memory
        property.  Without this, the scan backward stores O(S^2/bk) f32
        scores per layer (measured: ~30 GiB/device on a 135M model)."""
        q_pos = q_offset + iq * bq + jnp.arange(bq)

        @jax.checkpoint
        def body(carry, blk):
            acc, mx, den = carry
            kb, vb, jk = blk
            k_pos = jk * bk + jnp.arange(bk)
            mask = _block_mask(q_pos, k_pos, causal, window)
            s = _attend_block(qc, kb, vb, mask, scale)   # (B,bq,Hkv,g,bk)
            new_mx = jnp.maximum(mx, jnp.max(s, axis=-1))
            # guard all-masked rows (new_mx = -inf)
            safe_mx = jnp.where(jnp.isfinite(new_mx), new_mx, 0.0)
            p = jnp.exp(s - safe_mx[..., None])
            corr = jnp.exp(jnp.where(jnp.isfinite(mx), mx - safe_mx,
                                     -jnp.inf))
            den = den * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqkgs,bskd->bqkgd", p,
                            vb.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (acc, new_mx, den), None

        acc0 = jnp.zeros((b, bq, hkv, group, hd), jnp.float32)
        mx0 = jnp.full((b, bq, hkv, group), -jnp.inf, jnp.float32)
        den0 = jnp.zeros((b, bq, hkv, group), jnp.float32)
        if cfg.unroll:
            carry = (acc0, mx0, den0)
            for jk in range(nk_live):
                carry, _ = body(carry, (k_blocks[:, jk], v_blocks[:, jk],
                                        jnp.int32(jk)))
            acc, mx, den = carry
        else:
            kb = k_blocks[:, :nk_live].swapaxes(0, 1)
            vb = v_blocks[:, :nk_live].swapaxes(0, 1)
            (acc, mx, den), _ = jax.lax.scan(
                body, (acc0, mx0, den0), (kb, vb, jnp.arange(nk_live)))
        out = acc / jnp.maximum(den[..., None], 1e-30)
        return out.reshape(b, bq, h, hd).astype(q.dtype)

    q_chunk_ck = jax.checkpoint(q_chunk, static_argnums=(2,))

    if (cfg.skip_blocks and causal and nq > 1) or cfg.unroll:
        # Python-unrolled q chunks.  skip_blocks: each chunk processes only
        # the prefix of KV blocks it can see.  unroll (cost-calibration
        # builds): every block loop is unrolled so cost_analysis counts all
        # block bodies.
        outs = []
        for iq in range(nq):
            if cfg.skip_blocks and causal and t == sq:
                # kv blocks covering positions [0, (iq+1)*bq) — bq != bk safe
                hi = min(nk, -(-((iq + 1) * bq) // bk))
            else:
                hi = nk
            qc = q[:, iq * bq:(iq + 1) * bq]
            outs.append(q_chunk_ck(qc, iq, hi))
        return jnp.concatenate(outs, axis=1)

    def outer(qc_iq):
        qc, iq = qc_iq
        return q_chunk_ck(qc, iq, nk)

    q_chunks = q.reshape(b, nq, bq, h, hd).swapaxes(0, 1)
    out = jax.lax.map(outer, (q_chunks, jnp.arange(nq)))
    return out.swapaxes(0, 1).reshape(b, sq, h, hd)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None):
    """Single-token attention against a cache.

    q (B,1,H,hd); k_cache/v_cache (B,T,Hkv,hd); cache_len (B,) int32 —
    number of valid cache entries (new token's kv already written).
    """
    b, _, h, hd = q.shape
    t = k_cache.shape[1]
    hkv = k_cache.shape[2]
    group = h // hkv
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, hkv, group, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale     # (B,Hkv,g,T)
    pos = jnp.arange(t)[None, :]                            # (1,T)
    valid = pos < cache_len[:, None]
    if window is not None:
        valid = valid & (pos >= (cache_len[:, None] - window))
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block parameter specs / application
# ---------------------------------------------------------------------------

def attention_spec(cfg, d_in=None, *, prefix_axes=()):
    """Projection specs for one attention block.  d_in defaults to d_model
    (zamba2's shared block passes 2*d_model)."""
    d = d_in if d_in is not None else cfg.d_model
    pa = tuple(prefix_axes)
    px = tuple(None for _ in pa)  # leading dims (e.g. layers) — handled by caller

    def sp(shape, axes, **kw):
        return spec(shape, axes, **kw)

    p = {
        "wq": sp((d, cfg.n_heads, cfg.head_dim), ("embed", "heads", None)),
        "wk": sp((d, cfg.n_kv_heads, cfg.head_dim), ("embed", "kv_heads", None)),
        "wv": sp((d, cfg.n_kv_heads, cfg.head_dim), ("embed", "kv_heads", None)),
        "wo": sp((cfg.n_heads, cfg.head_dim, cfg.d_model),
                 ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = sp((cfg.n_heads, cfg.head_dim), ("heads", None), init="zeros")
        p["bk"] = sp((cfg.n_kv_heads, cfg.head_dim), ("kv_heads", None),
                     init="zeros")
        p["bv"] = sp((cfg.n_kv_heads, cfg.head_dim), ("kv_heads", None),
                     init="zeros")
    return p


def qkv_proj(p, x, cfg, positions, *, rope=True, lora=None):
    """x (B,S,d_in) -> q (B,S,H,hd), k, v with RoPE at `positions`."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if lora is not None:
        # per-slot LoRA on q/k/v (zamba2 shared block)
        for nm, tgt in (("q", "q"), ("k", "k"), ("v", "v")):
            a, bmat = lora[f"{nm}_a"].astype(x.dtype), lora[f"{nm}_b"].astype(x.dtype)
            delta = jnp.einsum("bsd,dr,rhk->bshk", x, a, bmat)
            if tgt == "q":
                q = q + delta
            elif tgt == "k":
                k = k + delta
            else:
                v = v + delta
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if rope:
        q = rotary(q, positions, cfg.rope_theta)
        k = rotary(k, positions, cfg.rope_theta)
    return q, k, v


def out_proj(p, attn_out):
    return jnp.einsum("bshk,hkd->bsd", attn_out,
                      p["wo"].astype(attn_out.dtype))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_spec(cfg, d_ff=None):
    f = d_ff if d_ff is not None else cfg.d_ff
    d = cfg.d_model
    return {
        "w_gate": spec((d, f), ("embed", "mlp")),
        "w_up": spec((d, f), ("embed", "mlp")),
        "w_down": spec((f, d), ("mlp", "embed")),
    }


def mlp(p, x, act="silu"):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = act_fn(act)(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
