"""Unified model configuration covering all assigned architecture families.

Families:
  dense   — llama-style decoder (GQA, optional QKV bias, optional SWA)
  moe     — dense skeleton with MoE FFN (top-k routing, capacity dispatch)
  ssm     — Mamba2 (SSD) stack, attention-free
  hybrid  — Zamba2: Mamba2 blocks + a weight-shared attention block applied
            every `shared_attn_every` layers (with per-slot LoRA)
  vlm     — llama + gated cross-attention layers over stub image embeddings
  audio   — musicgen: decoder over EnCodec-token *embeddings* (stub
            frontend); logits over the codec vocabulary
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    sliding_window: Optional[int] = None    # SWA width; None = full attention
    # ffn
    d_ff: int = 0
    act: str = "silu"
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2)
    shared_attn_every: int = 0        # one shared attn block per this many
    shared_lora_rank: int = 0
    # vlm
    cross_attn_every: int = 0         # cross-attn layer each N layers
    n_img_tokens: int = 0
    # audio / embed stub
    embed_stub: bool = False          # inputs are embeddings, not token ids
    # numerics / structure
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"      # master parameter dtype
    tie_embeddings: bool = False
    # notes for DESIGN.md / dry-run bookkeeping
    source: str = ""

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM/hybrid/sliding-window)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def n_params(self) -> int:
        """Approximate parameter count (dense matmul weights + embeddings)."""
        d, f, L, v = self.d_model, self.d_ff, self.n_layers, self.vocab
        p = 0
        if not self.embed_stub:
            p += v * d
        p += v * d if not self.tie_embeddings else 0     # lm head
        if self.family in ("dense", "moe", "vlm", "audio"):
            attn = d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d
            if self.family == "moe":
                ffn = self.n_experts * 3 * d * f
            else:
                ffn = 3 * d * f
            p += L * (attn + ffn)
            if self.family == "vlm" and self.cross_attn_every:
                n_cross = L // self.cross_attn_every
                p += n_cross * (d * self.attn_dim + 2 * d * self.kv_dim
                                + self.attn_dim * d)
        elif self.family == "ssm":
            di, ns, nh = self.ssm_inner, self.ssm_state, self.ssm_heads
            in_proj = d * (2 * di + 2 * self.ssm_groups * ns + nh)
            p += L * (in_proj + di * d)
        elif self.family == "hybrid":
            di, ns, nh = self.ssm_inner, self.ssm_state, self.ssm_heads
            in_proj = d * (2 * di + 2 * self.ssm_groups * ns + nh)
            p += L * (in_proj + di * d)
            # one shared attn+mlp block (+ tiny per-slot LoRA)
            p += (2 * d) * self.attn_dim + 2 * (2 * d) * self.kv_dim \
                + self.attn_dim * d + 3 * d * f
        return p

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        total = self.n_params()
        return total - L * (self.n_experts - self.top_k) * 3 * d * f


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}
