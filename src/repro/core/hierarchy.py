"""Million-cluster hierarchical engine: k²-means divide-and-conquer as
ONE batched Anderson-accelerated program (DESIGN.md §Hierarchy).

Flat Algorithm 1 at K clusters pays O(N·K·d) per X-pass; past ~10^4
clusters the (N, K) distance work dominates everything else.  The k²-means
observation (Agustsson & Timofte; PAPERS.md) is that a codebook of K
centroids factors: cluster X into G ≈ √K super-clusters, then solve an
independent K/G-cluster problem *inside* each super-cluster.  Each
sub-problem sees only its own rows, so total assignment work drops from
N·K to roughly N·(G + K/G) — at K = 2^16 that is a ~128x arithmetic
reduction before any bound or kernel tricks.

What makes this module an *engine* rather than a loop over `aa_kmeans` is
that all G sub-problems run as ONE `aa_kmeans_batched` call:

  * the partition step lays every super-cluster's rows into its own
    padded stripe of a (G, N_max, d) tensor with NO host argsort —
    `counting_sort_perm_segmented` against the offset table
    ``arange(G) * N_max`` (core/locality.py);
  * padding rows carry weight 0 through the drivers' first-class
    per-problem row weights, so they vanish exactly from cluster stats,
    energy AND the per-problem masked convergence check;
  * seeding is segment-aware: `batched_init(..., weights=...)` never
    seeds a padding row;
  * best-of-n_init selection is per-problem: `select_best(groups=...)`.

Reassignment rounds then repair the one thing the decomposition got
wrong — rows whose nearest router (super-centroid) changed after the
sub-solves: rows move between sub-problems, the partition is rebuilt,
and all G sub-problems re-solve warm from their previous centroids.  A
best-snapshot energy guard makes the returned result monotone: a round
that increases total energy is never returned.

The result flattens to a (K, d) codebook (group-major: group g owns rows
[g·k_sub, (g+1)·k_sub)) plus labels in ORIGINAL row order, and the
(routers, group offsets) pair is a free two-level routing index —
`repro.serving.closure.hierarchy_closure_index` turns it into a serving
`ClosureIndex` with zero extra clustering work.

Persistence: the round loop is a pure state -> state function, so a
round-granular snapshot (`KIND_HIERARCHY`) restores a run bit-exactly —
`resume_from` a snapshot and the remaining rounds replay what the
uninterrupted run would have done.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import serialize
from repro.core.init_schemes import batched_init, kmeanspp_init
from repro.core.kmeans import (
    BackendLike,
    KMeansConfig,
    aa_kmeans,
    aa_kmeans_batched,
    resolve_backend,
    select_best,
)
from repro.core.locality import counting_sort_perm_segmented
from repro.runtime.metrics import as_metrics
from repro.runtime.metrics import should_stop as _metrics_stop
from repro.runtime.writer import write_snapshot

KIND_HIERARCHY = serialize.KIND_HIERARCHY


class HierarchyResult(NamedTuple):
    """Flattened two-level solve: codebook + original-row-order labels
    plus the routing structure that produced them."""

    centroids: jax.Array      # (K, d) codebook, group-major
    labels: jax.Array         # (N,) int32 global labels, ORIGINAL row order
    energy: jax.Array         # scalar total energy (sum of sub_energies)
    routers: jax.Array        # (G, d) super-centroids (level-1 routers)
    group_offsets: jax.Array  # (G+1,) int32; group g owns [off[g], off[g+1])
    labels_super: jax.Array   # (N,) int32 super-cluster per row
    sub_energies: jax.Array   # (G,) per-group masked energies
    n_rounds: int             # reassignment rounds executed


def default_n_groups(k: int) -> int:
    """The divisor of ``k`` nearest √k — the k²-means balance point where
    per-row routing work G + K/G is minimised.  A prime ``k`` has no
    useful divisor and degenerates to G = 1 (the flat solve)."""
    if k <= 0:
        raise ValueError(f"k must be positive; got {k}")
    root = math.sqrt(k)
    best = 1
    for g in range(1, int(root) + 1):
        if k % g == 0:
            for cand in (g, k // g):
                if abs(cand - root) < abs(best - root):
                    best = cand
    return best


def _ceil_to(v: int, m: int) -> int:
    return -(-v // m) * m


def _partition(x, labels_super, g: int, k_sub: int, pad_multiple: int,
               sort_tile):
    """Stripe rows by super-cluster label into (G, N_max, d) + weights.

    N_max is the max group population rounded up to ``pad_multiple``
    (bucketing the compiled shapes so reassignment rounds rarely
    recompile), floored at k_sub (every sub-problem must offer at least
    k_sub candidate seed rows) and capped at N.  Returns
    ``(xg, wg, perm, n_max)`` where ``wg`` is 1 for live rows, 0 for
    padding — the drivers' native per-problem weight column."""
    n, d = x.shape
    counts = jnp.bincount(labels_super, length=g)
    counts_max = int(jax.device_get(jnp.max(counts)))
    n_max = min(max(_ceil_to(counts_max, pad_multiple), k_sub), n)
    n_max = max(n_max, counts_max)   # the cap at N never loses a row
    offsets = jnp.arange(g, dtype=jnp.int32) * n_max
    perm, _, _ = counting_sort_perm_segmented(
        labels_super, g, offsets, g * n_max, sort_tile=sort_tile)
    # Sentinel perm slots (== N, the unfilled padding) gather the zero row.
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xg = jnp.take(x_pad, perm, axis=0).reshape(g, n_max, d)
    wg = (perm < n).astype(x.dtype).reshape(g, n_max)
    return xg, wg, perm, n_max


def _flatten(best, perm, g: int, k_sub: int, n: int, n_max: int):
    """(G,...) winners -> global codebook / labels / energies.

    Global label = g·k_sub + local label.  The inverse scatter sends
    every sentinel perm slot to index N of an (N+1,) buffer — the one
    collision point — and slices it off, recovering ORIGINAL row order
    without a second sort."""
    d = best.centroids.shape[-1]
    codebook = best.centroids.reshape(g * k_sub, d)
    gid = jnp.repeat(jnp.arange(g, dtype=jnp.int32), n_max)
    codes = gid * k_sub + best.labels.reshape(-1).astype(jnp.int32)
    labels = jnp.zeros((n + 1,), jnp.int32).at[perm].set(codes)[:n]
    sub_e = best.energy.astype(jnp.float32)
    return codebook, labels, sub_e, jnp.sum(sub_e)


def _routers_of(x, labels_super, g: int, prev):
    """Per-super-cluster row means; an emptied group keeps its previous
    router instead of collapsing to the origin (which would vacuum up
    rows on the next reassignment)."""
    acc = jnp.promote_types(x.dtype, jnp.float32)
    sums = jnp.zeros((g, x.shape[1]), acc).at[labels_super].add(x.astype(acc))
    cnt = jnp.zeros((g,), acc).at[labels_super].add(
        jnp.ones((x.shape[0],), acc))
    mean = (sums / jnp.maximum(cnt, 1.0)[:, None]).astype(x.dtype)
    return jnp.where((cnt > 0)[:, None], mean, prev)


def hierarchy_state_like(x, k: int, n_groups: int):
    """ShapeDtypeStruct tree matching the round-granular snapshot —
    derived from the problem shape so `serialize.restore` can never
    drift from the engine (DESIGN.md §Persistence)."""
    n, d = x.shape
    g = int(n_groups)
    k_sub = k // g
    sds = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    return {
        "labels_super": sds((n,), i32),
        "c_subs": sds((g, k_sub, d), x.dtype),
        "routers": sds((g, d), x.dtype),
        "best_centroids": sds((k, d), x.dtype),
        "best_labels": sds((n,), i32),
        "best_labels_super": sds((n,), i32),
        "best_routers": sds((g, d), x.dtype),
        "best_sub_e": sds((g,), f32),
        "best_energy": sds((), f32),
    }


def _solve_groups(xg, wg, c0s, sub_cfg, bk, g: int, n_init: int):
    """All G sub-problems (x n_init seeds) as ONE batched AA program,
    reduced to per-group winners."""
    if n_init > 1:
        xg = jnp.repeat(xg, n_init, axis=0)
        wg = jnp.repeat(wg, n_init, axis=0)
    res = aa_kmeans_batched(xg, c0s, sub_cfg, backend=bk, weights=wg)
    groups = jnp.repeat(jnp.arange(g, dtype=jnp.int32), n_init)
    return select_best(res, groups=groups, n_groups=g)


def _check_hier_meta(meta: dict, k: int, g: int, what: str):
    for name, want in (("k", k), ("n_groups", g)):
        got = meta.get(name)
        if got is not None and int(got) != int(want):
            raise ValueError(
                f"{what}: snapshot was taken at {name}={got}, this run "
                f"uses {name}={want} — resume must target the identical "
                f"hierarchy configuration")


def aa_kmeans_hierarchical(x: jax.Array, k: int,
                           cfg: Optional[KMeansConfig] = None,
                           backend: BackendLike = None, *,
                           n_groups: Optional[int] = None,
                           n_init: int = 1,
                           init: str = "kmeans++",
                           seed: int = 0,
                           n_reassign: int = 2,
                           super_max_iter: int = 50,
                           pad_multiple: int = 256,
                           sort_tile=None,
                           c0s: Optional[jax.Array] = None,
                           metrics=None,
                           checkpoint_dir=None,
                           resume_from=None,
                           keep_last_n: int = 0,
                           keep_every_m: int = 0) -> HierarchyResult:
    """Two-level Anderson-accelerated K-Means (module docstring).

    ``cfg`` configures the SUB-problems (its ``k`` must equal the global
    ``k``; the engine derives the k/G sub-config); ``backend`` is any
    solver backend and is shared by the super-solve, the batched
    sub-solves and the reassignment step.  ``n_groups`` defaults to the
    divisor of k nearest √k; ``n_init`` seeds per sub-problem compete
    through per-group `select_best` (warm reassignment rounds keep a
    single warm seed).  ``c0s`` overrides the cold seeds — (n_init, K, d)
    when G = 1, else (G·n_init, K/G, d) — for conformance tests that pin
    the seeding.

    G = 1 degenerates to the flat batched solve: shared X, no weights, no
    reassignment — bitwise-identical to
    ``select_best(aa_kmeans_batched(x, c0s, cfg))`` by construction.

    ``n_reassign`` nearest-router repair rounds run after the initial
    solve; each recomputes routers as super-cluster row means, moves rows
    to their nearest router, rebuilds the partition and re-solves all G
    sub-problems warm.  The returned result is the best round under total
    energy (monotone by snapshot), and the loop exits early when no row
    moves or a ``metrics=`` sink (e.g. `EarlyStopHook`) trips.

    ``checkpoint_dir`` snapshots the round state (``KIND_HIERARCHY``)
    after every round; ``resume_from`` (a path or a restored state dict
    plus its ``round`` in meta) replays the remaining rounds
    bit-identically to the uninterrupted run.
    """
    x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"x must be (N, d); got shape {x.shape}")
    n, d = x.shape
    if cfg is None:
        cfg = KMeansConfig(k=k)
    if cfg.k != k:
        raise ValueError(f"cfg.k={cfg.k} disagrees with k={k}")
    if not (0 < k <= n):
        raise ValueError(f"need 0 < k <= N; got k={k}, N={n}")
    g = int(n_groups) if n_groups else default_n_groups(k)
    if g < 1 or k % g != 0:
        raise ValueError(
            f"n_groups={g} must be a positive divisor of k={k} "
            f"(uniform k_sub keeps the batched solve one program); "
            f"default_n_groups(k) picks the divisor nearest √k")
    k_sub = k // g
    bk = resolve_backend(backend, cfg=cfg)
    mx = as_metrics(metrics)
    sub_cfg = dataclasses.replace(cfg, k=k_sub)
    root = jax.random.PRNGKey(seed)
    i32 = jnp.int32

    # -- G = 1: literally the flat batched solve ---------------------------
    if g == 1:
        if checkpoint_dir is not None or resume_from is not None:
            raise ValueError(
                "G=1 degenerates to the flat batched solve, which has its "
                "own checkpoint kind — call aa_kmeans_batched with "
                "checkpoint_dir/resume_from directly")
        if c0s is None:
            keys = jax.random.split(jax.random.fold_in(root, 1), n_init)
            c0s = batched_init(init, keys, x, k)
        best = select_best(aa_kmeans_batched(x, c0s, cfg, backend=bk,
                                             metrics=metrics))
        labels = best.labels.astype(i32)
        return HierarchyResult(
            centroids=best.centroids, labels=labels,
            energy=best.energy.astype(jnp.float32),
            routers=jnp.mean(x, axis=0, dtype=jnp.float32
                             ).astype(x.dtype)[None],
            group_offsets=jnp.asarray([0, k], i32),
            labels_super=jnp.zeros((n,), i32),
            sub_energies=best.energy.astype(jnp.float32)[None],
            n_rounds=0)

    # -- resume or cold round 0 --------------------------------------------
    state = None
    start_round = 0
    if resume_from is not None:
        like = hierarchy_state_like(x, k, g)
        if isinstance(resume_from, (str, bytes)) or hasattr(
                resume_from, "__fspath__"):
            state, meta = serialize.restore(resume_from, like,
                                            expect_kind=KIND_HIERARCHY)
            _check_hier_meta(meta, k, g, str(resume_from))
            start_round = int(meta.get("round", meta.get("t", 0))) + 1
        else:
            state, meta = resume_from
            _check_hier_meta(meta, k, g, "resume_from")
            start_round = int(meta["round"]) + 1
        state = {name: jnp.asarray(a) for name, a in state.items()}

    def _snapshot(state, r):
        if checkpoint_dir is None:
            return
        write_snapshot(checkpoint_dir, state, kind=KIND_HIERARCHY, step=r,
                       extra={"round": r, "k": k, "n_groups": g,
                              "k_sub": k_sub, "backend": bk.name},
                       keep_last_n=keep_last_n, keep_every_m=keep_every_m)

    last_round = start_round - 1
    if state is None:
        t0 = time.perf_counter()
        super_cfg = dataclasses.replace(cfg, k=g, max_iter=super_max_iter)
        c0_super = kmeanspp_init(jax.random.fold_in(root, 0), x, g)
        sup = aa_kmeans(x, c0_super, super_cfg, backend=bk)
        labels_super = sup.labels.astype(i32)
        routers = sup.centroids

        xg, wg, perm, n_max = _partition(x, labels_super, g, k_sub,
                                         pad_multiple, sort_tile)
        if c0s is None:
            keys = jax.random.split(jax.random.fold_in(root, 1),
                                    g * n_init)
            w_rep = wg if n_init == 1 else jnp.repeat(wg, n_init, axis=0)
            x_rep = xg if n_init == 1 else jnp.repeat(xg, n_init, axis=0)
            c0s = batched_init(init, keys, x_rep, k_sub, weights=w_rep)
        elif c0s.shape != (g * n_init, k_sub, d):
            raise ValueError(
                f"c0s must be (G*n_init, K/G, d) = "
                f"({g * n_init}, {k_sub}, {d}); got {c0s.shape}")
        best = _solve_groups(xg, wg, c0s, sub_cfg, bk, g, n_init)
        codebook, labels, sub_e, total = _flatten(best, perm, g, k_sub,
                                                  n, n_max)
        state = {
            "labels_super": labels_super,
            "c_subs": best.centroids,
            "routers": routers,
            "best_centroids": codebook,
            "best_labels": labels,
            "best_labels_super": labels_super,
            "best_routers": routers,
            "best_sub_e": sub_e,
            "best_energy": total.astype(jnp.float32),
        }
        last_round = 0
        mx.log_scalars(0, {"energy": total,
                           "energy_best": state["best_energy"],
                           "moved_frac": 1.0, "n_max": n_max,
                           "round_s": time.perf_counter() - t0})
        _snapshot(state, 0)
        start_round = 1
        if _metrics_stop(mx):
            n_reassign = 0

    # -- nearest-router reassignment rounds --------------------------------
    for r in range(start_round, n_reassign + 1):
        t0 = time.perf_counter()
        routers = _routers_of(x, state["labels_super"], g, state["routers"])
        ls_new = bk.assign(x, routers).labels.astype(i32)
        moved = int(jax.device_get(
            jnp.sum(ls_new != state["labels_super"])))
        if moved == 0:
            break
        xg, wg, perm, n_max = _partition(x, ls_new, g, k_sub,
                                         pad_multiple, sort_tile)
        best = _solve_groups(xg, wg, state["c_subs"], sub_cfg, bk, g,
                             n_init=1)
        codebook, labels, sub_e, total = _flatten(best, perm, g, k_sub,
                                                  n, n_max)
        total32 = total.astype(jnp.float32)
        improved = bool(jax.device_get(total32 <= state["best_energy"]))
        state = dict(state, labels_super=ls_new, c_subs=best.centroids,
                     routers=routers)
        if improved:
            state.update(best_centroids=codebook, best_labels=labels,
                         best_labels_super=ls_new, best_routers=routers,
                         best_sub_e=sub_e, best_energy=total32)
        last_round = r
        mx.log_scalars(r, {"energy": total,
                           "energy_best": state["best_energy"],
                           "moved_frac": moved / n, "n_max": n_max,
                           "round_s": time.perf_counter() - t0})
        _snapshot(state, r)
        if _metrics_stop(mx):
            break

    return HierarchyResult(
        centroids=state["best_centroids"],
        labels=state["best_labels"],
        energy=state["best_energy"],
        routers=state["best_routers"],
        group_offsets=jnp.arange(g + 1, dtype=i32) * k_sub,
        labels_super=state["best_labels_super"],
        sub_energies=state["best_sub_e"],
        n_rounds=max(last_round, 0))
