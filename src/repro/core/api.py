"""Top-level estimator API for the paper's solver.

    from repro.core.api import AAKMeans, MiniBatchAAKMeans
    model = AAKMeans(n_clusters=10, init="kmeans++", n_init=3).fit(x)
    labels = model.predict(x_new)

    stream = MiniBatchAAKMeans(n_clusters=10, chunk_size=8192)
    stream.fit(x)                       # X on device, chunked epochs
    stream2 = MiniBatchAAKMeans(n_clusters=10, chunk_size=8192)
    stream2.partial_fit(x_big[:8192])   # seeds centroids + carves val rows
    for chunk in host_chunk_stream(x_big[8192:], 8192, epochs=3):
        stream2.partial_fit(chunk)      # X never fully on device
    stream2.finalize()
    # (streaming the FULL x_big for several epochs would re-feed the
    #  carved validation rows as training data from epoch 2 on — feed the
    #  first chunk once and epoch only over the remainder, as above)

Thin, sklearn-shaped wrappers: `AAKMeans` over the batched multi-restart
full-batch solver, `MiniBatchAAKMeans` over the streaming chunked solver
(DESIGN.md §Streaming).  All heavy work stays in the jit'd solvers, and a
mesh-fitted model keeps using its mesh for predict/transform.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import serialize
from repro.core.anderson import AAConfig
from repro.core.distributed import (make_distributed_kmeans_batched,
                                    make_distributed_kmeans_minibatch,
                                    shard_dataset)
from repro.core.init_schemes import batched_init, make_init
from repro.core.kmeans import (KMeansConfig, KMeansResult,
                               aa_kmeans_batched, aa_kmeans_minibatch,
                               minibatch_stream_like, resolve_backend,
                               select_best)
from repro.core.minibatch import (MiniBatchConfig, MiniBatchState,
                                  guard_pick, minibatch_init,
                                  minibatch_iteration)
from repro.data.streaming import (chunk_dataset, shard_count,
                                  split_validation)


class NotFittedError(RuntimeError):
    """Inference was requested on an estimator with no fitted state.

    A real exception, not a bare ``assert``: under ``python -O`` asserts
    are compiled away, which used to turn "call fit() first" into an
    opaque None-attribute crash inside the first jitted call."""


def _mesh_rows_apply(model, x, kind, fn, extras=()):
    """Run ``fn(x_local, centroids, *extras) -> per-row output`` under a
    fitted model's mesh: rows sharded over its data axes, centroids (and
    any extra operands, e.g. the closure index arrays) replicated,
    padding rows (added to match the shard count) stripped from the
    result.  The jitted shard_map program is cached on the model per
    (kind, mesh, axes, backend), so a serving loop pays compilation once
    and refitting with a different composition cannot reuse a stale
    program."""
    axes = tuple(model.data_axes)
    x_sh, _ = shard_dataset(x, model.mesh, model.data_axes)
    cache = model.__dict__.setdefault("_mesh_runners", {})
    cache_key = (kind, model.mesh, axes, model.backend)
    run = cache.get(cache_key)
    if run is None:
        run = cache[cache_key] = jax.jit(compat.shard_map(
            fn, mesh=model.mesh,
            in_specs=(P(axes), P()) + (P(),) * len(extras),
            out_specs=P(axes)))
    out = run(x_sh, jnp.asarray(model.centroids_), *extras)
    return out[:x.shape[0]]


def _chunked_rows_apply(model, x, kind, fn, out_dtype, out_cols=None,
                        chunk_size=None, extras=()):
    """Run ``fn(x_chunk, centroids, *extras) -> per-row output`` jitted,
    chunk by chunk, into a HOST (numpy) array — the single-device serving
    path shared by both estimators.  The chunking bounds the device
    footprint for host-sized X (an (N, K) transform of such an X would
    not fit back on device either, hence the numpy result), and the
    jitted fn is cached on the model per (kind, backend) so a serving
    loop pays dispatch/tracing once instead of eager per-call overhead.

    Every chunk fed to the jitted fn has EXACTLY ``step`` rows: the tail
    chunk is padded with copies of its last row and the padding sliced
    off the output.  One compiled shape total — a serving loop over
    varying N used to retrace per distinct remainder (N % step), which
    is precisely the varying-batch-size pattern a request queue
    produces.  Extras are passed through to the fn unchanged, so index
    arrays can be swapped (same shapes) without invalidating the cache."""
    cache = model.__dict__.setdefault("_local_runners", {})
    run = cache.get((kind, model.backend))
    if run is None:
        run = cache[(kind, model.backend)] = jax.jit(fn)
    step = chunk_size or getattr(model, "chunk_size", 0) or 16384
    n = x.shape[0]
    c = jnp.asarray(model.centroids_)
    shape = (n,) if out_cols is None else (n, out_cols)
    out = np.empty(shape, out_dtype)
    for i in range(0, n, step):
        xc = jnp.asarray(x[i:i + step])
        m = xc.shape[0]
        if m < step:
            xc = jnp.concatenate(
                [xc, jnp.repeat(xc[-1:], step - m, axis=0)])
        out[i:i + m] = np.asarray(run(xc, c, *extras))[:m]
    return out


# -- shared inference paths (both estimators) --------------------------------

def _closure_extras(model):
    """(routers, candidates, candidate_table) when the model carries a
    serving index.  The (G, C, d) table is built once per inference call
    and threaded through as an operand so every chunk scans contiguous
    block rows instead of paying a scattered per-row centroid gather."""
    if getattr(model, "closure_routers_", None) is None:
        return None
    from repro.serving.closure import candidate_table
    candidates = jnp.asarray(model.closure_candidates_)
    return (jnp.asarray(model.closure_routers_), candidates,
            candidate_table(model.centroids_, candidates))


def _predict_rows(model, x, chunk_size=None, approx=False):
    """Nearest-centroid labels; ``approx=True`` routes through the
    cluster-closure candidate index (`repro.serving.closure`) when the
    model carries one — exact argmin over each row's candidate list,
    sublinear in K — and falls back to the full-K scan when it does not
    (legacy/index-less artifacts serve unchanged, just slower)."""
    model._assert_fitted()
    extras = _closure_extras(model) if approx else None
    if extras is not None:
        from repro.serving.closure import closure_assign
        fn = lambda xl, c, r, cd, t: closure_assign(  # noqa: E731
            xl, c, r, cd, t)[0]
        if model.mesh is not None:
            return _mesh_rows_apply(model, jnp.asarray(x),
                                    "predict_closure", fn, extras=extras)
        return _chunked_rows_apply(model, x, "predict_closure", fn,
                                   np.int32, chunk_size=chunk_size,
                                   extras=extras)
    bk = resolve_backend(model.backend)
    label_fn = lambda xl, c: bk.assign(xl, c).labels  # noqa: E731
    if model.mesh is not None:
        return _mesh_rows_apply(model, jnp.asarray(x), "predict", label_fn)
    return _chunked_rows_apply(model, x, "predict", label_fn, np.int32,
                               chunk_size=chunk_size)


def _transform_rows(model, x, chunk_size=None, approx=False):
    """Distances to each centroid (N, K).  ``approx=True`` with a fitted
    closure index prices only each row's candidate centroids — the other
    columns come back +inf (consistent with `closure_assign`'s argmin,
    and honest about not having been computed)."""
    from repro.core.lloyd import pairwise_sqdist
    model._assert_fitted()
    extras = _closure_extras(model) if approx else None
    if extras is not None:
        from repro.serving.closure import closure_sqdist
        fn = lambda xl, c, r, cd, t: jnp.sqrt(  # noqa: E731
            closure_sqdist(xl, c, r, cd, t))
        if model.mesh is not None:
            return _mesh_rows_apply(model, jnp.asarray(x),
                                    "transform_closure", fn, extras=extras)
        return _chunked_rows_apply(model, x, "transform_closure", fn,
                                   np.float32, out_cols=model.n_clusters,
                                   chunk_size=chunk_size, extras=extras)
    dist_fn = lambda xl, c: jnp.sqrt(pairwise_sqdist(xl, c))  # noqa: E731
    if model.mesh is not None:
        return _mesh_rows_apply(model, jnp.asarray(x), "transform",
                                dist_fn)
    return _chunked_rows_apply(model, x, "transform", dist_fn,
                               np.float32, out_cols=model.n_clusters,
                               chunk_size=chunk_size)


def _build_serving_index(model, n_candidates=None, n_groups=None, seed=0):
    """Build + attach the cluster-closure index (DESIGN.md §Serving) to a
    fitted model; persisted by ``save`` and restored by ``load``.

    A hierarchically-fitted model (`AAKMeans(hierarchical=True)`) gets
    its index FOR FREE: the solve's super-centroids are the routers and
    each group's codebook rows are its candidate list
    (`repro.serving.closure.hierarchy_closure_index`) — no codebook
    re-clustering.  Passing explicit ``n_candidates``/``n_groups`` opts
    back into the built-from-scratch index."""
    model._assert_fitted()
    if n_candidates is None and n_groups is None \
            and getattr(model, "hier_routers_", None) is not None:
        from repro.serving.closure import hierarchy_closure_index
        idx = hierarchy_closure_index(jnp.asarray(model.centroids_),
                                      jnp.asarray(model.hier_routers_),
                                      jnp.asarray(model.hier_offsets_))
    else:
        from repro.serving.closure import build_closure_index
        idx = build_closure_index(jnp.asarray(model.centroids_),
                                  n_candidates=n_candidates,
                                  n_groups=n_groups, seed=seed)
    model.closure_routers_ = idx.routers
    model.closure_candidates_ = idx.candidates
    return model


def _closure_index(model):
    """The model's `ClosureIndex`, or None when none was built."""
    if getattr(model, "closure_routers_", None) is None:
        return None
    from repro.serving.closure import ClosureIndex
    return ClosureIndex(jnp.asarray(model.closure_routers_),
                        jnp.asarray(model.closure_candidates_))


# -- estimator persistence (DESIGN.md §Persistence) -------------------------

def _encode_backend(bk):
    """Registry names pass through; a Backend instance is recorded by
    registry identity + precision policy so `load` can rebuild an
    EQUIVALENT engine — recording only `bk.name` would either fail to
    resolve ('blocked4096' is not a registry key) or silently drop a
    custom precision, serving at a different dtype than the fit."""
    if isinstance(bk, str):
        return bk
    enc = {"name": bk.name}
    prec = bk.precision
    if prec.compute is not None:
        enc["compute"] = np.dtype(prec.compute).name
    if prec.accum is not None:
        enc["accum"] = np.dtype(prec.accum).name
    return enc


def _decode_backend(enc, path):
    if isinstance(enc, str):
        return enc
    from repro.core.backends import Precision, backend_names, get_backend
    name = enc["name"].split("@")[0]   # the mesh wrap belongs to a process
    opts = {}
    m = re.fullmatch(r"blocked(\d+)", name)
    if m:
        name, opts["block_n"] = "blocked", int(m.group(1))
    if "compute" in enc or "accum" in enc:
        opts["precision"] = Precision(
            compute=np.dtype(enc["compute"]) if "compute" in enc else None,
            accum=np.dtype(enc["accum"]) if "accum" in enc else None)
    if name not in backend_names():
        raise ValueError(
            f"{path}: model was fitted with backend {enc['name']!r}, which "
            f"cannot be rebuilt from the registry "
            f"({sorted(backend_names())}); construct the engine yourself "
            f"and set model.backend on the loaded model before serving")
    return get_backend(name, **opts)


def _save_estimator(model, path, kind, arrays: dict, stream: dict,
                    scalars: dict):
    """One serialize.py artifact: fitted arrays + (optionally) streaming
    state as the tree, constructor params and scalar fitted stats in the
    meta block.  The mesh is deliberately NOT persisted — a mesh is a
    property of the process, not of the model; a loaded model is local
    until the caller assigns one."""
    params = {}
    for f in dataclasses.fields(model):
        if f.name.endswith("_") or f.name.startswith("_"):
            continue
        v = getattr(model, f.name)
        if f.name in ("mesh", "metrics"):
            # both are process properties, not model parameters: a mesh
            # belongs to the device topology, a metrics sink to whatever
            # log file/stream this process opened
            continue
        if f.name == "backend":
            v = _encode_backend(v)
        if f.name == "data_axes":
            v = list(v)
        params[f.name] = v
    tree = {"arrays": arrays}
    if stream:
        tree["stream"] = stream
    return serialize.save(
        path, tree, kind=kind,
        extra={"params": params, "scalars": scalars,
               "has": sorted(arrays), "has_stream": sorted(stream)})


def _load_estimator(cls, path, kind):
    meta, by_path = serialize.load(path, expect_kind=kind)
    params = dict(meta["params"])
    params["data_axes"] = tuple(params.get("data_axes", ("data",)))
    params["backend"] = _decode_backend(params.get("backend", "dense"), path)
    model = cls(**params)
    for name in meta["has"]:
        setattr(model, name, jnp.asarray(by_path[f"arrays/{name}"]))
    for name, val in meta["scalars"].items():
        setattr(model, name, val)
    return model, meta, by_path


@dataclasses.dataclass
class AAKMeans:
    n_clusters: int
    init: str = "kmeans++"
    n_init: int = 1
    max_iter: int = 500
    accelerated: bool = True
    m0: int = 2
    mbar: int = 30
    dynamic_m: bool = True
    # Paper's Algorithm-1 thresholds / stabilisation — exposed so Table-2
    # style eps sweeps run through the public estimator.
    eps1: float = 0.02
    eps2: float = 0.5
    ridge: float = 1e-12
    seed: int = 0
    mesh: Optional[jax.sharding.Mesh] = None      # distributed when set
    data_axes: tuple = ("data",)
    # local-compute engine: "dense" | "blocked" | "pallas" | "fused" |
    # "hamerly" or a Backend instance; composed with the mesh when set.
    backend: object = "dense"
    # runtime metrics sink (`repro.runtime.metrics`): None | "stdout" |
    # anything with log_scalars(step, dict).  Setting one routes the fit
    # through the segmented driver (per-segment host boundaries are where
    # the scalars materialise).  Not persisted by save().
    metrics: object = None
    # cluster-closure serving index (DESIGN.md §Serving): None = don't
    # build at fit time; True = build with default sizing; an int = build
    # with that candidate count.  `build_serving_index()` attaches one to
    # an already-fitted model either way.
    serving_index: object = None
    # two-level divide-and-conquer fit (DESIGN.md §Hierarchy): False =
    # flat batched solve; True = `aa_kmeans_hierarchical` with defaults
    # (G = divisor of K nearest √K); a dict = keyword overrides for the
    # hierarchy driver (n_groups=, n_reassign=, super_max_iter=, ...).
    # The million-cluster regime — flat assignment work is O(N·K·d),
    # hierarchical roughly O(N·(G + K/G)·d).
    hierarchical: object = False

    # fitted state
    centroids_: Optional[jax.Array] = None
    labels_: Optional[jax.Array] = None
    energy_: Optional[float] = None
    n_iter_: Optional[int] = None
    n_accepted_: Optional[int] = None
    closure_routers_: Optional[jax.Array] = None
    closure_candidates_: Optional[jax.Array] = None
    # hierarchical fit extras: level-1 routers + group-major codebook
    # offsets (the free serving index; see `_build_serving_index`)
    hier_routers_: Optional[jax.Array] = None
    hier_offsets_: Optional[jax.Array] = None

    def _config(self) -> KMeansConfig:
        return KMeansConfig(
            k=self.n_clusters, max_iter=self.max_iter,
            accelerated=self.accelerated,
            aa=AAConfig(m0=self.m0, mbar=self.mbar,
                        dynamic_m=self.dynamic_m,
                        eps1=self.eps1, eps2=self.eps2, ridge=self.ridge))

    def fit(self, x) -> "AAKMeans":
        x = jnp.asarray(x)
        n = x.shape[0]
        cfg = self._config()
        n_init = max(self.n_init, 1)
        if self.hierarchical:
            return self._fit_hierarchical(x, cfg, n_init)
        keys = jax.random.split(jax.random.PRNGKey(self.seed), n_init)
        c0s = jnp.asarray(batched_init(self.init, keys, x, self.n_clusters))
        if self.mesh is not None:
            fit_fn = make_distributed_kmeans_batched(
                self.mesh, cfg, self.data_axes, backend=self.backend,
                pick_best=True)
            x_in, _ = shard_dataset(x, self.mesh, self.data_axes)
        elif self.metrics is not None:
            # segmented (host-loop) driver: metrics need host boundaries
            fit_fn = lambda a, b: select_best(  # noqa: E731
                aa_kmeans_batched(a, b, cfg, backend=self.backend,
                                  metrics=self.metrics))
            x_in = x
        else:
            fit_fn = jax.jit(lambda a, b: select_best(
                aa_kmeans_batched(a, b, cfg, backend=self.backend)))
            x_in = x
        # ONE device program: R restarts solved in a batch, winner picked
        # on device — n_init no longer multiplies dispatch/transfer cost.
        best: KMeansResult = fit_fn(x_in, c0s)
        energy = float(best.energy)
        if not math.isfinite(energy):
            # select_best skips non-finite restarts, so reaching here means
            # EVERY restart degenerated (NaN rows in X, exploded iterate).
            # Surfacing beats returning restart 0 with a NaN inertia that
            # every downstream comparison silently treats as "best".
            raise FloatingPointError(
                f"all {n_init} restarts produced non-finite energies "
                f"(E={energy}); check X for NaN/inf rows")
        self.centroids_ = best.centroids
        self.labels_ = best.labels[:n]
        self.energy_ = energy
        self.n_iter_ = int(best.n_iter)
        self.n_accepted_ = int(best.n_accepted)
        # fresh centroids invalidate any previous closure index (and any
        # previous hierarchical structure); rebuild when requested, never
        # serve a stale one
        self.closure_routers_ = self.closure_candidates_ = None
        self.hier_routers_ = self.hier_offsets_ = None
        if self.serving_index:
            self.build_serving_index(
                n_candidates=self.serving_index
                if isinstance(self.serving_index, int)
                and not isinstance(self.serving_index, bool) else None)
        return self

    def _fit_hierarchical(self, x, cfg, n_init) -> "AAKMeans":
        """k²-means divide-and-conquer fit (`repro.core.hierarchy`): the
        million-cluster path.  Keeps the flat fit's contract (centroids_,
        original-row-order labels_, finite-energy check) and additionally
        records the two-level routing structure, which ``save`` persists
        and the serving index reuses for free."""
        if self.mesh is not None:
            raise NotImplementedError(
                "hierarchical=True is a host-driven round loop; a "
                "mesh-distributed hierarchy is a ROADMAP follow-up — fit "
                "flat under the mesh or hierarchical on one device")
        from repro.core.hierarchy import aa_kmeans_hierarchical
        opts = dict(self.hierarchical) \
            if isinstance(self.hierarchical, dict) else {}
        res = aa_kmeans_hierarchical(
            x, self.n_clusters, cfg, backend=self.backend,
            n_init=n_init, init=self.init, seed=self.seed,
            metrics=self.metrics, **opts)
        energy = float(res.energy)
        if not math.isfinite(energy):
            raise FloatingPointError(
                f"hierarchical fit produced a non-finite energy "
                f"(E={energy}); check X for NaN/inf rows")
        self.centroids_ = res.centroids
        self.labels_ = res.labels
        self.energy_ = energy
        self.n_iter_ = int(res.n_rounds)
        self.n_accepted_ = None
        self.hier_routers_ = res.routers
        self.hier_offsets_ = res.group_offsets
        self.closure_routers_ = self.closure_candidates_ = None
        if self.serving_index:
            self.build_serving_index(
                n_candidates=self.serving_index
                if isinstance(self.serving_index, int)
                and not isinstance(self.serving_index, bool) else None)
        return self

    # -- inference --------------------------------------------------------

    def _assert_fitted(self):
        if self.centroids_ is None:
            raise NotFittedError(
                "this AAKMeans instance has no fitted centroids; call "
                "fit() (or load() a fitted artifact) first")

    def _mesh_apply(self, x, kind, fn):
        return _mesh_rows_apply(self, x, kind, fn)

    def build_serving_index(self, n_candidates: Optional[int] = None,
                            n_groups: Optional[int] = None,
                            seed: int = 0) -> "AAKMeans":
        """Attach a cluster-closure candidate index to the fitted
        centroids (`repro.serving.closure`); ``save`` persists it and
        ``load`` restores it, so the serving process never rebuilds."""
        return _build_serving_index(self, n_candidates=n_candidates,
                                    n_groups=n_groups, seed=seed)

    @property
    def closure_index_(self):
        """The fitted `ClosureIndex`, or None when none was built."""
        return _closure_index(self)

    def predict(self, x, chunk_size: Optional[int] = None,
                approx: bool = False):
        """Nearest-centroid labels.  A mesh-fitted model assigns under the
        same mesh/backend composition as ``fit`` — rows sharded over the
        data axes, centroids replicated — instead of silently falling back
        to a single-device pass over the full X (which defeats the point
        of a distributed fit and breaks once N exceeds one device).  The
        local path runs jitted and chunked into a host array
        (`_chunked_rows_apply`): a serving loop previously paid eager
        dispatch per call, and a host-sized X materialised (N, K) at once.
        ``approx=True`` scores only the closure index's candidate
        centroids per row (sublinear in K); without a fitted index it
        falls back to the exact full scan."""
        return _predict_rows(self, x, chunk_size=chunk_size, approx=approx)

    def transform(self, x, chunk_size: Optional[int] = None,
                  approx: bool = False):
        """Distances to each centroid (N, K); mesh-fitted models compute
        the row block on each shard's local rows (K is replicated), the
        local path is jitted + chunked like ``predict``.  ``approx=True``
        prices only the candidate centroids (+inf elsewhere)."""
        return _transform_rows(self, x, chunk_size=chunk_size,
                               approx=approx)

    @property
    def inertia_(self) -> float:
        return self.energy_

    # -- persistence ------------------------------------------------------

    def save(self, path):
        """Persist params + fitted state to one npz artifact (no pickle;
        `repro.core.serialize` schema) so a fitted model ships to a
        serving process.  A Backend instance is recorded by registry
        identity + precision and rebuilt on ``load``; the mesh is NOT
        persisted — assign one after ``load`` when distributed serving is
        wanted."""
        self._assert_fitted()
        arrays = {"centroids_": jnp.asarray(self.centroids_)}
        if self.labels_ is not None:
            arrays["labels_"] = jnp.asarray(self.labels_)
        if self.closure_routers_ is not None:
            arrays["closure_routers_"] = jnp.asarray(self.closure_routers_)
            arrays["closure_candidates_"] = \
                jnp.asarray(self.closure_candidates_)
        if self.hier_routers_ is not None:
            # the two-level structure rides the same npz schema: load()
            # restores every array named in meta["has"] generically
            arrays["hier_routers_"] = jnp.asarray(self.hier_routers_)
            arrays["hier_offsets_"] = jnp.asarray(self.hier_offsets_)
        scalars = {"energy_": self.energy_, "n_iter_": self.n_iter_,
                   "n_accepted_": self.n_accepted_}
        return _save_estimator(self, path, serialize.KIND_ESTIMATOR_AA,
                               arrays, {}, scalars)

    @classmethod
    def load(cls, path) -> "AAKMeans":
        """Rebuild a fitted estimator from ``save``'s artifact."""
        model, _, _ = _load_estimator(cls, path,
                                      serialize.KIND_ESTIMATOR_AA)
        return model


@dataclasses.dataclass
class MiniBatchAAKMeans:
    """Streaming mini-batch AA K-Means estimator (DESIGN.md §Streaming).

    Two consumption modes over the same chunk-step state machine:

      * ``fit(x, chunk_size=...)`` — X fits on device (or on the mesh):
        a random ``val_size`` validation chunk is held out for the energy
        guard, the rest is chunked, and one jit'd program runs every
        epoch (`kmeans.aa_kmeans_minibatch`; the distributed driver when
        ``mesh`` is set).
      * ``partial_fit(chunk)`` — X never fits on device: feed host chunks
        one at a time (`repro.data.streaming.host_chunk_stream`); the
        first call carves its leading rows into the validation chunk and
        seeds the centroids, each later call is one jit'd chunk step.
        Keep chunk lengths uniform to avoid re-jitting, and when making
        multiple epochs, re-stream only the rows AFTER the first chunk
        (see the module docstring) so the carved validation rows stay
        held out — re-feeding them would bias the guard energies
        optimistic.

    After ``fit``, ``centroids_`` is the final validation-guard-picked
    iterate and ``energy_`` its total *validation-chunk* energy (full-X
    energy is deliberately never computed — that is the point of the
    streaming solver).  During a ``partial_fit`` sequence, ``centroids_``
    tracks the running-stats fallback iterate (always safe) while
    ``energy_`` is the guard's most recent pricing — of the iterate that
    *entered* the last chunk step, i.e. one step behind ``centroids_``
    (the guard is the only val pass per step; pricing the exit iterate
    would cost a second).  ``finalize()`` reprices the current iterates
    and applies the guard pick, making the pair consistent.
    """
    n_clusters: int
    chunk_size: int = 4096
    epochs: int = 5
    decay: float = 0.9
    val_size: int = 1024
    init: str = "kmeans++"
    accelerated: bool = True
    m0: int = 2
    mbar: int = 30
    dynamic_m: bool = True
    eps1: float = 0.02
    eps2: float = 0.5
    ridge: float = 1e-12
    seed: int = 0
    compute_labels: bool = True      # fit() labels the input like sklearn
    mesh: Optional[jax.sharding.Mesh] = None
    data_axes: tuple = ("data",)
    backend: object = "dense"
    # runtime metrics sink (`repro.runtime.metrics`); fit() logs per
    # epoch, partial_fit per chunk.  Per-chunk logging float()s device
    # scalars — a host sync the stream otherwise avoids — so attach a
    # sink only when the diagnostics are worth it.  Not persisted.
    metrics: object = None

    # fitted state
    centroids_: Optional[jax.Array] = None
    labels_: Optional[jax.Array] = None
    energy_: Optional[float] = None
    n_steps_: Optional[int] = None
    n_accepted_: Optional[int] = None
    closure_routers_: Optional[jax.Array] = None
    closure_candidates_: Optional[jax.Array] = None

    # streaming state (partial_fit)
    _state: object = dataclasses.field(default=None, repr=False)
    _x_val: object = dataclasses.field(default=None, repr=False)
    _step_fn: object = dataclasses.field(default=None, repr=False)

    def _config(self, chunk_size: Optional[int] = None) -> MiniBatchConfig:
        return MiniBatchConfig(
            k=self.n_clusters,
            chunk_size=chunk_size or self.chunk_size,
            epochs=self.epochs, decay=self.decay,
            accelerated=self.accelerated,
            aa=AAConfig(m0=self.m0, mbar=self.mbar,
                        dynamic_m=self.dynamic_m,
                        eps1=self.eps1, eps2=self.eps2, ridge=self.ridge))

    def _val_rows(self, n: int) -> int:
        v = min(self.val_size, max(n // 4, self.n_clusters))
        if self.mesh is not None:
            v -= v % shard_count(self.mesh, self.data_axes)
        if v < 1:
            raise ValueError(
                f"cannot carve a validation chunk from N={n} rows "
                f"(val_size={self.val_size})")
        return v

    def fit(self, x, chunk_size: Optional[int] = None) -> "MiniBatchAAKMeans":
        x = jnp.asarray(x)
        cfg = self._config(chunk_size)
        if x.shape[0] < 2 * self.n_clusters:
            raise ValueError(f"need at least {2 * self.n_clusters} rows to "
                             f"fit k={self.n_clusters}; got {x.shape[0]}")
        # a fit supersedes any partial_fit stream in progress — otherwise a
        # later partial_fit/finalize would advance the abandoned stream and
        # silently overwrite this fit's results
        self._state = self._x_val = None
        k_val, k_init, k_run = jax.random.split(
            jax.random.PRNGKey(self.seed), 3)
        x_train, x_val = split_validation(x, self._val_rows(x.shape[0]),
                                          k_val)
        # split_validation permutes rows, so the head is a uniform sample.
        n_seed = min(x_train.shape[0], max(cfg.chunk_size, 4096))
        c0 = make_init(self.init)(k_init, x_train[:n_seed], self.n_clusters)
        dc = chunk_dataset(x_train, cfg.chunk_size, mesh=self.mesh,
                           data_axes=self.data_axes)
        if self.mesh is not None:
            fit_fn = make_distributed_kmeans_minibatch(
                self.mesh, cfg, self.data_axes, backend=self.backend)
            x_val, _ = shard_dataset(x_val, self.mesh, self.data_axes)
            res = fit_fn(dc.chunks, dc.weights, x_val, c0, k_run)
        elif self.metrics is not None:
            # epoch-segmented driver (host loop) so per-epoch scalars
            # have a host boundary to materialise at
            res = aa_kmeans_minibatch(dc.chunks, dc.weights, x_val, c0,
                                      cfg, backend=self.backend, key=k_run,
                                      metrics=self.metrics)
        else:
            run = jax.jit(lambda ch, w, xv, c, key: aa_kmeans_minibatch(
                ch, w, xv, c, cfg, backend=self.backend, key=key))
            res = run(dc.chunks, dc.weights, x_val, c0, k_run)
        self.centroids_ = res.centroids
        self.energy_ = float(res.energy)
        self.n_steps_ = int(res.n_steps)
        self.n_accepted_ = int(res.n_accepted)
        # new centroids: any previously built closure index is stale
        self.closure_routers_ = self.closure_candidates_ = None
        self.labels_ = self.predict(x) if self.compute_labels else None
        return self

    # -- streaming ---------------------------------------------------------

    def partial_fit(self, chunk) -> "MiniBatchAAKMeans":
        """One chunk step; device memory never holds more than this chunk
        plus the validation chunk.  Updates ``centroids_`` to the fresh
        running-stats iterate and ``energy_`` to the guard's pricing of
        the previous one (see the class docstring; ``finalize()`` makes
        them consistent)."""
        if self.mesh is not None:
            raise NotImplementedError(
                "partial_fit streams from one host; for mesh execution "
                "use fit() / make_distributed_kmeans_minibatch")
        x = jnp.asarray(chunk)
        cfg = self._config()
        bk = resolve_backend(self.backend)
        if self._state is None:
            if x.shape[0] < 2 * self.n_clusters:
                raise ValueError(
                    f"the first partial_fit chunk seeds the solver and "
                    f"must have >= {2 * self.n_clusters} rows; got "
                    f"{x.shape[0]}")
            # uniform carve (like fit's split_validation), not the raw
            # head: datasets are often stored sorted, and a val chunk
            # covering only the leading cluster would bias every guard
            # decision
            k_val, k_init = jax.random.split(jax.random.PRNGKey(self.seed))
            x, self._x_val = split_validation(
                x, self._val_rows(x.shape[0]), k_val)
            c0 = make_init(self.init)(k_init, x, self.n_clusters)
            self._state = minibatch_init(c0, cfg, bk)
        if self._step_fn is None:
            self._step_fn = jax.jit(minibatch_iteration,
                                    static_argnames=("cfg", "backend"))
        w = jnp.ones((x.shape[0],), jnp.float32)
        self._state, trace = self._step_fn(x, w, self._x_val, self._state,
                                           cfg=cfg, backend=bk)
        # device scalars, deliberately not float()/int()-converted: a host
        # sync per chunk would serialise the streaming loop (the next
        # chunk's H2D transfer could no longer overlap this step's
        # compute).  fit()/finalize() store Python floats.
        self.centroids_ = self._state.c_au
        self.energy_ = trace.e_val
        self.n_steps_ = self._state.t
        self.n_accepted_ = self._state.n_acc
        # centroids moved: a previously built closure index is stale
        self.closure_routers_ = self.closure_candidates_ = None
        if self.metrics is not None:
            # attaching a sink opts into the per-chunk host sync
            from repro.runtime.metrics import as_metrics
            as_metrics(self.metrics).log_scalars(
                int(self._state.t),
                {"e_val": float(trace.e_val),
                 "accepted": float(trace.accepted),
                 "n_accepted": float(self._state.n_acc),
                 "chunk_rows": float(x.shape[0])})
        return self

    def partial_fit_stream(self, chunks, prefetch: int = 2
                           ) -> "MiniBatchAAKMeans":
        """Consume an iterator of host chunks with overlapped
        host→device ingestion: chunk t+1's transfer is issued while
        chunk t's step computes (`repro.data.streaming.stream_chunks`
        over `repro.runtime.prefetch`).  Numerically identical to
        calling ``partial_fit`` per chunk — only transfer timing
        changes.  With a ``metrics`` sink attached, the final achieved
        ingest bytes/bandwidth are logged as ``ingest_*`` scalars."""
        from repro.data.streaming import stream_chunks
        from repro.runtime.metrics import as_metrics
        from repro.runtime.prefetch import IngestMeter
        meter = IngestMeter()
        for chunk in stream_chunks(iter(chunks), prefetch=prefetch,
                                   meter=meter):
            self.partial_fit(chunk)
        if self.metrics is not None and meter.chunks:
            as_metrics(self.metrics).log_scalars(int(self._state.t),
                                                 meter.scalars())
        return self

    def finalize(self) -> "MiniBatchAAKMeans":
        """Validation-guard pick between the accelerated candidate and the
        running-stats fallback after a partial_fit sequence (fit() applies
        it automatically)."""
        if self._state is None:
            raise ValueError("no streaming state; call partial_fit first")
        cfg = self._config()
        bk = resolve_backend(self.backend)
        c_fin, e_fin, _, _ = guard_pick(self._x_val, self._state, cfg, bk)
        self.centroids_ = c_fin
        self.energy_ = float(e_fin)
        self.closure_routers_ = self.closure_candidates_ = None
        return self

    # -- inference ---------------------------------------------------------

    def _assert_fitted(self):
        if self.centroids_ is None:
            raise NotFittedError(
                "this MiniBatchAAKMeans instance has no fitted centroids; "
                "call fit() or partial_fit() (or load() a fitted "
                "artifact) first")

    def _chunked_apply(self, x, kind, fn, out_dtype, out_cols=None,
                       chunk_size=None):
        """Jitted chunk-by-chunk apply into a host array — shared with
        AAKMeans via the module-level `_chunked_rows_apply`."""
        return _chunked_rows_apply(self, x, kind, fn, out_dtype,
                                   out_cols=out_cols, chunk_size=chunk_size)

    def build_serving_index(self, n_candidates: Optional[int] = None,
                            n_groups: Optional[int] = None,
                            seed: int = 0) -> "MiniBatchAAKMeans":
        """Attach a cluster-closure candidate index (`repro.serving`) to
        the current centroids.  For a ``partial_fit`` stream, call after
        ``finalize()`` — the index describes the centroids it was built
        from, and further chunks invalidate it."""
        return _build_serving_index(self, n_candidates=n_candidates,
                                    n_groups=n_groups, seed=seed)

    @property
    def closure_index_(self):
        """The fitted `ClosureIndex`, or None when none was built."""
        return _closure_index(self)

    # -- persistence ------------------------------------------------------

    def save(self, path):
        """Persist params + fitted state — INCLUDING an in-progress
        ``partial_fit`` stream (running S/W stats, Anderson window, guard
        energies, the carved validation chunk) — to one npz artifact.
        A loaded mid-stream model continues ``partial_fit`` exactly where
        this process stopped: the stream state is the whole trajectory
        state, so feeding the same remaining chunks reproduces the
        uninterrupted run bit for bit."""
        self._assert_fitted()
        arrays = {"centroids_": jnp.asarray(self.centroids_)}
        if self.labels_ is not None:
            arrays["labels_"] = jnp.asarray(self.labels_)
        if self.closure_routers_ is not None:
            arrays["closure_routers_"] = jnp.asarray(self.closure_routers_)
            arrays["closure_candidates_"] = \
                jnp.asarray(self.closure_candidates_)
        stream = {}
        if self._state is not None:
            stream = {"state": self._state,
                      "x_val": jnp.asarray(self._x_val)}
        # device scalars mid-stream (see partial_fit) -> host floats here
        scalars = {
            "energy_": None if self.energy_ is None else float(self.energy_),
            "n_steps_": None if self.n_steps_ is None else int(self.n_steps_),
            "n_accepted_": None if self.n_accepted_ is None
            else int(self.n_accepted_)}
        return _save_estimator(self, path, serialize.KIND_ESTIMATOR_MB,
                               arrays, stream, scalars)

    @classmethod
    def load(cls, path) -> "MiniBatchAAKMeans":
        """Rebuild from ``save``'s artifact; a saved mid-stream state is
        restored so the next ``partial_fit``/``finalize`` continues the
        stream."""
        model, meta, by_path = _load_estimator(
            cls, path, serialize.KIND_ESTIMATOR_MB)
        if meta["has_stream"]:
            like = minibatch_stream_like(
                by_path["stream/state/c"], model._config(), model.backend)
            state_paths, state_leaves, treedef = serialize.flatten_with_paths(
                like["state"])
            leaves = [jnp.asarray(np.asarray(by_path[f"stream/state/{p}"],
                                             dtype=l.dtype))
                      for p, l in zip(state_paths, state_leaves)]
            model._state = jax.tree_util.tree_unflatten(treedef, leaves)
            model._x_val = jnp.asarray(by_path["stream/x_val"])
        return model

    def predict(self, x, chunk_size: Optional[int] = None,
                approx: bool = False):
        """Nearest-centroid labels, computed chunk by chunk into a host
        array (bounded device footprint); mesh-fitted models assign under
        the fitted mesh instead.  ``approx=True`` uses the closure index
        when one is built, the exact full scan otherwise."""
        return _predict_rows(self, x, chunk_size=chunk_size, approx=approx)

    def transform(self, x, chunk_size: Optional[int] = None,
                  approx: bool = False):
        """Distances to each centroid (N, K), chunked like predict into
        a host array; ``approx=True`` prices only the candidate
        centroids (+inf elsewhere)."""
        return _transform_rows(self, x, chunk_size=chunk_size,
                               approx=approx)

    @property
    def inertia_(self) -> float:
        return self.energy_
