"""Top-level estimator API for the paper's solver.

    from repro.core.api import AAKMeans
    model = AAKMeans(n_clusters=10, init="kmeans++", n_init=3).fit(x)
    labels = model.predict(x_new)

Thin, sklearn-shaped wrapper over Algorithm 1: multiple restarts (best
energy wins), any seeding scheme from init_schemes, optional plain-Lloyd
mode, optional mesh for the distributed solver.  All heavy work stays in
the jit'd solvers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.anderson import AAConfig
from repro.core.distributed import make_distributed_kmeans, shard_dataset
from repro.core.init_schemes import make_init
from repro.core.kmeans import (KMeansConfig, KMeansResult, aa_kmeans,
                               resolve_backend)


@dataclasses.dataclass
class AAKMeans:
    n_clusters: int
    init: str = "kmeans++"
    n_init: int = 1
    max_iter: int = 500
    accelerated: bool = True
    m0: int = 2
    mbar: int = 30
    dynamic_m: bool = True
    seed: int = 0
    mesh: Optional[jax.sharding.Mesh] = None      # distributed when set
    data_axes: tuple = ("data",)
    # local-compute engine: "dense" | "blocked" | "pallas" | "fused" |
    # "hamerly" or a Backend instance; composed with the mesh when set.
    backend: object = "dense"

    # fitted state
    centroids_: Optional[jax.Array] = None
    labels_: Optional[jax.Array] = None
    energy_: Optional[float] = None
    n_iter_: Optional[int] = None
    n_accepted_: Optional[int] = None

    def _config(self) -> KMeansConfig:
        return KMeansConfig(
            k=self.n_clusters, max_iter=self.max_iter,
            accelerated=self.accelerated,
            aa=AAConfig(m0=self.m0, mbar=self.mbar,
                        dynamic_m=self.dynamic_m))

    def fit(self, x) -> "AAKMeans":
        x = jnp.asarray(x)
        cfg = self._config()
        init_fn = make_init(self.init)
        if self.mesh is not None:
            fit_fn = make_distributed_kmeans(self.mesh, cfg, self.data_axes,
                                             backend=self.backend)
            x_sharded, _ = shard_dataset(x, self.mesh, self.data_axes)
        else:
            fit_fn = jax.jit(
                lambda a, b: aa_kmeans(a, b, cfg, backend=self.backend))
            x_sharded = x

        best: Optional[KMeansResult] = None
        key = jax.random.PRNGKey(self.seed)
        for _ in range(max(self.n_init, 1)):
            key, sub = jax.random.split(key)
            c0 = jnp.asarray(init_fn(sub, x, self.n_clusters))
            res = fit_fn(x_sharded, c0)
            if best is None or float(res.energy) < float(best.energy):
                best = res
        self.centroids_ = best.centroids
        self.labels_ = best.labels[:x.shape[0]]
        self.energy_ = float(best.energy)
        self.n_iter_ = int(best.n_iter)
        self.n_accepted_ = int(best.n_accepted)
        return self

    def predict(self, x) -> jax.Array:
        assert self.centroids_ is not None, "call fit() first"
        bk = resolve_backend(self.backend)
        return bk.assign(jnp.asarray(x), self.centroids_).labels

    def transform(self, x) -> jax.Array:
        """Distances to each centroid (N, K)."""
        from repro.core.lloyd import pairwise_sqdist
        assert self.centroids_ is not None, "call fit() first"
        return jnp.sqrt(pairwise_sqdist(jnp.asarray(x), self.centroids_))

    @property
    def inertia_(self) -> float:
        return self.energy_
