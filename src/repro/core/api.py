"""Top-level estimator API for the paper's solver.

    from repro.core.api import AAKMeans
    model = AAKMeans(n_clusters=10, init="kmeans++", n_init=3).fit(x)
    labels = model.predict(x_new)

Thin, sklearn-shaped wrapper over Algorithm 1: multiple restarts (best
energy wins), any seeding scheme from init_schemes, optional plain-Lloyd
mode, optional mesh for the distributed solver.  All heavy work stays in
the jit'd solvers — ``fit`` runs every restart in ONE batched device
program (kmeans.aa_kmeans_batched) with on-device best-of-R selection,
and a mesh-fitted model keeps using its mesh for predict/transform.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.anderson import AAConfig
from repro.core.distributed import (make_distributed_kmeans_batched,
                                    shard_dataset)
from repro.core.init_schemes import batched_init
from repro.core.kmeans import (KMeansConfig, KMeansResult, aa_kmeans_batched,
                               resolve_backend, select_best)


@dataclasses.dataclass
class AAKMeans:
    n_clusters: int
    init: str = "kmeans++"
    n_init: int = 1
    max_iter: int = 500
    accelerated: bool = True
    m0: int = 2
    mbar: int = 30
    dynamic_m: bool = True
    # Paper's Algorithm-1 thresholds / stabilisation — exposed so Table-2
    # style eps sweeps run through the public estimator.
    eps1: float = 0.02
    eps2: float = 0.5
    ridge: float = 1e-12
    seed: int = 0
    mesh: Optional[jax.sharding.Mesh] = None      # distributed when set
    data_axes: tuple = ("data",)
    # local-compute engine: "dense" | "blocked" | "pallas" | "fused" |
    # "hamerly" or a Backend instance; composed with the mesh when set.
    backend: object = "dense"

    # fitted state
    centroids_: Optional[jax.Array] = None
    labels_: Optional[jax.Array] = None
    energy_: Optional[float] = None
    n_iter_: Optional[int] = None
    n_accepted_: Optional[int] = None

    def _config(self) -> KMeansConfig:
        return KMeansConfig(
            k=self.n_clusters, max_iter=self.max_iter,
            accelerated=self.accelerated,
            aa=AAConfig(m0=self.m0, mbar=self.mbar,
                        dynamic_m=self.dynamic_m,
                        eps1=self.eps1, eps2=self.eps2, ridge=self.ridge))

    def fit(self, x) -> "AAKMeans":
        x = jnp.asarray(x)
        n = x.shape[0]
        cfg = self._config()
        n_init = max(self.n_init, 1)
        keys = jax.random.split(jax.random.PRNGKey(self.seed), n_init)
        c0s = jnp.asarray(batched_init(self.init, keys, x, self.n_clusters))
        if self.mesh is not None:
            fit_fn = make_distributed_kmeans_batched(
                self.mesh, cfg, self.data_axes, backend=self.backend,
                pick_best=True)
            x_in, _ = shard_dataset(x, self.mesh, self.data_axes)
        else:
            fit_fn = jax.jit(lambda a, b: select_best(
                aa_kmeans_batched(a, b, cfg, backend=self.backend)))
            x_in = x
        # ONE device program: R restarts solved in a batch, winner picked
        # on device — n_init no longer multiplies dispatch/transfer cost.
        best: KMeansResult = fit_fn(x_in, c0s)
        self.centroids_ = best.centroids
        self.labels_ = best.labels[:n]
        self.energy_ = float(best.energy)
        self.n_iter_ = int(best.n_iter)
        self.n_accepted_ = int(best.n_accepted)
        return self

    # -- inference --------------------------------------------------------

    def _assert_fitted(self):
        assert self.centroids_ is not None, "call fit() first"

    def _mesh_apply(self, x, kind, fn):
        """Run ``fn(x_local, centroids) -> per-row output`` under the fitted
        mesh: rows sharded over data_axes, centroids replicated, padding
        rows (added to match the shard count) stripped from the result.
        The jitted shard_map program is cached per (model, kind) so a
        serving loop pays compilation once."""
        axes = tuple(self.data_axes)
        x_sh, _ = shard_dataset(x, self.mesh, self.data_axes)
        cache = self.__dict__.setdefault("_mesh_runners", {})
        # keyed by everything the runner closes over, so refitting with a
        # different mesh/backend/axes cannot reuse a stale program
        cache_key = (kind, self.mesh, axes, self.backend)
        run = cache.get(cache_key)
        if run is None:
            run = cache[cache_key] = jax.jit(compat.shard_map(
                fn, mesh=self.mesh, in_specs=(P(axes), P()),
                out_specs=P(axes)))
        out = run(x_sh, jnp.asarray(self.centroids_))
        return out[:x.shape[0]]

    def predict(self, x) -> jax.Array:
        """Nearest-centroid labels.  A mesh-fitted model assigns under the
        same mesh/backend composition as ``fit`` — rows sharded over the
        data axes, centroids replicated — instead of silently falling back
        to a single-device pass over the full X (which defeats the point
        of a distributed fit and breaks once N exceeds one device)."""
        self._assert_fitted()
        x = jnp.asarray(x)
        bk = resolve_backend(self.backend)
        if self.mesh is not None:
            return self._mesh_apply(
                x, "predict", lambda xl, c: bk.assign(xl, c).labels)
        return bk.assign(x, self.centroids_).labels

    def transform(self, x) -> jax.Array:
        """Distances to each centroid (N, K); mesh-fitted models compute
        the row block on each shard's local rows (K is replicated)."""
        from repro.core.lloyd import pairwise_sqdist
        self._assert_fitted()
        x = jnp.asarray(x)
        if self.mesh is not None:
            return self._mesh_apply(
                x, "transform", lambda xl, c: jnp.sqrt(pairwise_sqdist(xl, c)))
        return jnp.sqrt(pairwise_sqdist(x, self.centroids_))

    @property
    def inertia_(self) -> float:
        return self.energy_
