"""Algorithm 1 of the paper: Anderson acceleration for the K-Means algorithm.

Two drivers over the same primitives:

  * ``aa_kmeans``        — fully jit-able ``lax.while_loop`` implementation
                           (production path; runs unchanged under shard_map
                           distribution and with Pallas kernel ops).
  * ``aa_kmeans_traced`` — Python-loop driver that records the per-iteration
                           statistics the paper reports (accepted / total
                           iterations, energy trace, m trace, wall time);
                           used by the Table 2 / Table 3 benchmarks.

Faithfulness notes (vs. the pseudo-code in the paper):

  * Convergence criterion: identical assignment between two consecutive
    iterations (line 4).  Because an accelerated iterate is only kept when it
    strictly decreases the energy, this is reached exactly when a fallback
    Lloyd iterate repeats the previous assignment — the classical criterion.
  * The energy check (lines 12-14) compares E(C^t) with E(C^{t-1}) and
    reverts to the *previous* un-accelerated iterate C_AU^t = G(C^{t-1})
    computed at line 16 of the previous iteration.
  * m-adjustment (lines 7-11) happens *before* the revert check, so a
    rejected iterate (negative decrease -> ratio < eps1) also shrinks m.
  * E^0 = +inf, and the ratio test only activates once E^{t-2} is finite.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import anderson
from repro.core.anderson import AAConfig, AAState
from repro.core.lloyd import (DENSE_OPS, LloydOps, energy_from_mindist)


@dataclasses.dataclass(frozen=True)
class KMeansConfig:
    k: int
    max_iter: int = 500
    aa: AAConfig = dataclasses.field(default_factory=AAConfig)
    accelerated: bool = True     # False -> plain Lloyd through the same driver
    block_n: int = 0             # row blocking for the assignment step


class KMeansResult(NamedTuple):
    centroids: jax.Array   # (K, d)
    labels: jax.Array      # (N,)
    energy: jax.Array      # scalar, final E
    n_iter: jax.Array      # total iterations (paper's "b" in a/b)
    n_accepted: jax.Array  # iterations whose accelerated iterate was kept
    converged: jax.Array   # bool


class _LoopState(NamedTuple):
    c: jax.Array           # C^t               (K, d)
    c_au: jax.Array        # C_AU^t = G(C^{t-1})  fallback iterate
    p_prev: jax.Array      # P^{t-1}           (N,)
    e_prev: jax.Array      # E^{t-1}
    e_prev2: jax.Array     # E^{t-2}
    aa: AAState
    t: jax.Array
    n_acc: jax.Array
    converged: jax.Array
    labels: jax.Array      # last P^t (valid on exit)
    e_last: jax.Array


def _init_state(x, c0, cfg: KMeansConfig, ops: LloydOps) -> _LoopState:
    k = cfg.k
    inf = jnp.array(jnp.inf, x.dtype)
    # Line 1:  C^1 = C_AU^1 = G(C^0);  F^0 = C^1 - C^0;  E^0 = +inf
    c1, res0 = ops.g_map(x, c0, k)
    aa_state = anderson.aa_init(k * x.shape[1], cfg.aa, x.dtype)
    aa_state = anderson.aa_seed(aa_state, (c1 - c0).reshape(-1),
                                c1.reshape(-1))
    return _LoopState(
        c=c1, c_au=c1, p_prev=res0.labels,
        e_prev=inf, e_prev2=inf,
        aa=aa_state,
        t=jnp.array(0, jnp.int32), n_acc=jnp.array(0, jnp.int32),
        converged=jnp.array(False),
        labels=res0.labels,
        # E(C^0) as the placeholder "last energy" — overwritten by the first
        # loop body; min_sqdist is reused (no gather), reduced across shards.
        e_last=ops.reduce_scalar(energy_from_mindist(res0.min_sqdist)))


def _iteration(x, state: _LoopState, cfg: KMeansConfig,
               ops: LloydOps):
    """One body of Algorithm 1's for-loop (lines 3-19)."""
    k = cfg.k

    # Line 3: P^t = Assign(X, C^t)
    res = ops.assign_fn(x, state.c)
    p_t, c_t = res.labels, state.c

    # Line 4: convergence <=> identical assignment.  Algorithm 1 returns
    # (P^t, C^t) at line 5 *before* doing any further work.
    converged = ops.all_equal_fn(p_t, state.p_prev)

    # E(P^t, C^t) with P^t the fresh assignment of C^t is exactly the sum
    # of min squared distances — reuse them instead of re-gathering
    # (the paper's Sec-2.1 low-overhead argument; measured 25.6 ms vs the
    # 16.2 ms assignment itself on Covtype before this reuse).
    e_assign = ops.reduce_scalar(energy_from_mindist(res.min_sqdist))

    def _finish(_):
        new_state = state._replace(converged=jnp.array(True), labels=p_t,
                                   e_last=e_assign, t=state.t + 1)
        return new_state, jnp.array(False), e_assign

    def _full(_):
        # Line 7: E^t = E(P^t, C^t)
        e_t = e_assign

        # Lines 7-11: dynamic adjustment of m
        aa_state = anderson.adjust_m(state.aa, e_t, state.e_prev,
                                     state.e_prev2, cfg.aa)

        # Lines 12-14: keep the accelerated iterate only if it decreases E;
        # otherwise revert to the fallback iterate C_AU^t = G(C^{t-1}).
        accepted = e_t < state.e_prev

        def _revert(_):
            res_f = ops.assign_fn(x, state.c_au)
            e_f = ops.reduce_scalar(energy_from_mindist(res_f.min_sqdist))
            return state.c_au, res_f.labels, e_f

        def _keep(_):
            return c_t, p_t, e_t

        c_cur, p_cur, e_cur = jax.lax.cond(accepted, _keep, _revert,
                                           operand=None)

        # Line 16: C_AU^{t+1} = Update(X, P^t) — also the next fallback.
        c_au_next = ops.update_fn(x, p_cur, k, c_cur)

        # Lines 17-19: Anderson acceleration.
        g_flat = c_au_next.reshape(-1)
        f_flat = g_flat - c_cur.reshape(-1)
        if cfg.accelerated:
            aa_state, c_next_flat, _, _ = anderson.aa_push_and_solve(
                aa_state, f_flat, g_flat, cfg.aa)
            c_next = c_next_flat.reshape(c_cur.shape)
        else:
            c_next = c_au_next

        new_state = _LoopState(
            c=c_next, c_au=c_au_next, p_prev=p_cur,
            e_prev=e_cur, e_prev2=state.e_prev,
            aa=aa_state,
            t=state.t + 1,
            n_acc=state.n_acc + jnp.where(accepted, 1, 0).astype(jnp.int32),
            converged=jnp.array(False),
            labels=p_cur, e_last=e_cur)
        return new_state, accepted, e_cur

    new_state, accepted, e_cur = jax.lax.cond(converged, _finish, _full,
                                              operand=None)
    return new_state, converged, accepted, e_cur


def aa_kmeans(x: jax.Array, c0: jax.Array, cfg: KMeansConfig,
              ops: LloydOps = DENSE_OPS) -> KMeansResult:
    """Jit-able Algorithm 1.  ``cfg`` is static; x (N,d); c0 (K,d)."""

    def cond(state: _LoopState):
        return jnp.logical_and(~state.converged, state.t < cfg.max_iter)

    def body(state: _LoopState):
        new_state, _, _, _ = _iteration(x, state, cfg, ops)
        return new_state

    state = _init_state(x, c0, cfg, ops)
    state = jax.lax.while_loop(cond, body, state)
    # Iteration count convention of the paper's "a/b": b counts the initial
    # C^1 = G(C^0) plus every fully-executed loop body; the body that merely
    # *detects* convergence (line 4-5 early return) is not counted.
    n_iter = state.t + jnp.where(state.converged, 0, 1)
    return KMeansResult(state.c, state.labels, state.e_last,
                        n_iter, state.n_acc, state.converged)


def aa_kmeans_jit(x, c0, cfg: KMeansConfig, ops: LloydOps = DENSE_OPS):
    fn = jax.jit(lambda xx, cc: aa_kmeans(xx, cc, cfg, ops))
    return fn(x, c0)


# ---------------------------------------------------------------------------
# Instrumented Python driver (benchmark parity with the paper's tables)
# ---------------------------------------------------------------------------

class KMeansTrace(NamedTuple):
    result: KMeansResult
    energies: list          # E^t per iteration (post-revert)
    m_values: list          # m after adjustment, per iteration
    accepted: list          # bool per iteration
    wall_time_s: float
    mse: float              # final E / N — the paper's reported MSE


def aa_kmeans_traced(x: jax.Array, c0: jax.Array, cfg: KMeansConfig,
                     ops: LloydOps = DENSE_OPS,
                     jit_iteration: bool = True) -> KMeansTrace:
    """Python-loop driver recording the statistics of Tables 2 and 3."""
    iter_fn = _iteration
    if jit_iteration:
        iter_fn = jax.jit(_iteration, static_argnames=("cfg", "ops"))
    init_fn = jax.jit(_init_state, static_argnames=("cfg", "ops")) \
        if jit_iteration else _init_state

    t0 = time.perf_counter()
    state = init_fn(x, c0, cfg, ops)
    energies, m_vals, acc = [], [], []
    converged = False
    while not converged and int(state.t) < cfg.max_iter:
        state, conv, accepted, e_t = iter_fn(x, state, cfg, ops)
        converged = bool(conv)
        if converged:
            break
        energies.append(float(e_t))
        m_vals.append(int(state.aa.m))
        acc.append(bool(accepted))
    jax.block_until_ready(state.c)
    wall = time.perf_counter() - t0

    n_iter = len(energies) + 1          # +1 for the initial G(C^0)
    n_accepted = sum(acc)
    result = KMeansResult(state.c, state.labels, state.e_last,
                          jnp.array(n_iter), jnp.array(n_accepted),
                          jnp.array(converged))
    mse = float(state.e_last) / x.shape[0]
    return KMeansTrace(result, energies, m_vals, acc, wall, mse)
