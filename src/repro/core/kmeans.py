"""Algorithm 1 of the paper: Anderson acceleration for the K-Means algorithm.

Two drivers over the same primitives:

  * ``aa_kmeans``        — fully jit-able ``lax.while_loop`` implementation
                           (production path; runs unchanged under shard_map
                           distribution and with Pallas kernel backends).
  * ``aa_kmeans_traced`` — Python-loop driver that records the per-iteration
                           statistics the paper reports (accepted / total
                           iterations, energy trace, m trace, wall time);
                           used by the Table 2 / Table 3 benchmarks.
  * ``aa_kmeans_minibatch`` — streaming epoch driver over chunked data
                           (state machine in repro.core.minibatch;
                           DESIGN.md §Streaming).
  * ``aa_kmeans_batched`` — R restarts / problems in one device program.

Both consume a `Backend` (repro.core.backends) whose core op is the
single-pass ``step(x, c) -> StepResult``, so one *accepted* Algorithm-1
iteration costs exactly one pass over X (the paper's Sec-2.1 cost model):
the step's assignment doubles as the energy evaluation AND as the cluster
statistics from which the next fallback iterate C_AU follows without
re-reading X.  A *rejected* iteration takes one extra step — the fallback
must be re-assigned — and that second step's stats are reused the same way
(the legacy driver paid a third pass here).

Faithfulness notes (vs. the pseudo-code in the paper):

  * Convergence criterion: identical assignment between two consecutive
    iterations (line 4).  Because an accelerated iterate is only kept when it
    strictly decreases the energy, this is reached exactly when a fallback
    Lloyd iterate repeats the previous assignment — the classical criterion.
  * The energy check (lines 12-14) compares E(C^t) with E(C^{t-1}) and
    reverts to the *previous* un-accelerated iterate C_AU^t = G(C^{t-1})
    computed at line 16 of the previous iteration.
  * m-adjustment (lines 7-11) happens *before* the revert check, so a
    rejected iterate (negative decrease -> ratio < eps1) also shrinks m.
  * E^0 = +inf, and the ratio test only activates once E^{t-2} is finite.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import anderson, serialize
from repro.core.anderson import AAConfig, AAState
from repro.core.backends import Backend, from_lloyd_ops, get_backend
from repro.core.lloyd import DENSE_OPS, LloydOps
from repro.core.locality import maybe_reorder
from repro.core.minibatch import (MiniBatchConfig, MiniBatchResult,
                                  guard_pick, minibatch_init,
                                  minibatch_iteration, run_epoch)
from repro.runtime.metrics import as_metrics, should_stop as _metrics_stop
from repro.runtime.writer import CheckpointWriter, write_snapshot


@dataclasses.dataclass(frozen=True)
class KMeansConfig:
    k: int
    max_iter: int = 500
    aa: AAConfig = dataclasses.field(default_factory=AAConfig)
    accelerated: bool = True     # False -> plain Lloyd through the same driver
    block_n: int = 0             # row blocking for the assignment step


class KMeansResult(NamedTuple):
    centroids: jax.Array   # (K, d)
    labels: jax.Array      # (N,)
    energy: jax.Array      # scalar, final E
    n_iter: jax.Array      # total iterations (paper's "b" in a/b)
    n_accepted: jax.Array  # iterations whose accelerated iterate was kept
    converged: jax.Array   # bool


class _LoopState(NamedTuple):
    c: jax.Array           # C^t               (K, d)
    c_au: jax.Array        # C_AU^t = G(C^{t-1})  fallback iterate
    p_prev: jax.Array      # P^{t-1}           (N,)
    e_prev: jax.Array      # E^{t-1}
    e_prev2: jax.Array     # E^{t-2}
    aa: AAState
    t: jax.Array
    n_acc: jax.Array
    converged: jax.Array
    labels: jax.Array      # last P^t (valid on exit)
    e_last: jax.Array
    carry: Any             # opaque backend carry (e.g. Hamerly bounds)


BackendLike = Union[str, Backend, None]


def resolve_backend(backend: BackendLike, ops: Optional[LloydOps] = None,
                    cfg: Optional[KMeansConfig] = None,
                    block_n: int = 0) -> Backend:
    """Resolve the (backend=, ops=) pair the solver entry points accept —
    the single backend-selection policy for both the local and the
    distributed drivers.

    Priority: an explicit Backend instance wins; a registry name is looked
    up (with "dense"/"blocked" promoted to the row-blocked engine when a
    block size is configured — via ``block_n`` or ``cfg.block_n``); a
    non-default legacy LloydOps is adapted through the deprecation shim;
    otherwise the dense engine."""
    block_n = block_n or (cfg.block_n if cfg is not None else 0)
    if isinstance(backend, Backend):
        return backend
    if isinstance(backend, str):
        if backend in ("dense", "blocked") and block_n:
            return get_backend("blocked", block_n=block_n)
        return get_backend(backend)
    if isinstance(backend, LloydOps):   # migration path off the ops= param
        return from_lloyd_ops(backend)
    if backend is not None:
        raise TypeError(
            f"backend= expects a registry name, a Backend, or a legacy "
            f"LloydOps; got {type(backend).__name__}")
    if ops is not None and ops is not DENSE_OPS:
        return from_lloyd_ops(ops)
    if block_n:
        return get_backend("blocked", block_n=block_n)
    return get_backend("dense")


def _init_state(x, c0, cfg: KMeansConfig, backend: Backend,
                w=None) -> _LoopState:
    k = cfg.k
    # Line 1:  C^1 = C_AU^1 = G(C^0);  F^0 = C^1 - C^0;  E^0 = +inf
    # — one step: the same pass yields E(C^0), P^0 and the stats of G(C^0).
    # ``w`` (N,) routes the init through the weighted slot — the hierarchy
    # driver's padded rows (w = 0) must vanish from the seed stats too.
    carry = backend.init_carry(x, c0, k)
    if w is None:
        res0, carry = backend.step(x, c0, k, carry)
    else:
        res0, carry = backend.minibatch_step(x, c0, k, w, carry)
    c1 = backend.centroids_from_step(x, res0, k, c0)
    aa_state = anderson.aa_init(k * x.shape[1], cfg.aa, x.dtype)
    aa_state = anderson.aa_seed(aa_state, (c1 - c0).reshape(-1),
                                c1.reshape(-1))
    inf = jnp.array(jnp.inf, res0.energy.dtype)
    return _LoopState(
        c=c1, c_au=c1, p_prev=res0.labels,
        e_prev=inf, e_prev2=inf,
        aa=aa_state,
        t=jnp.array(0, jnp.int32), n_acc=jnp.array(0, jnp.int32),
        converged=jnp.array(False),
        labels=res0.labels,
        # E(C^0) as the placeholder "last energy" — overwritten by the first
        # loop body; already reduced across shards by the backend.
        e_last=res0.energy,
        carry=carry)


def _iteration(x, state: _LoopState, cfg: KMeansConfig, backend: Backend):
    """One body of Algorithm 1's for-loop (lines 3-19) — ONE pass over X
    when the accelerated iterate is accepted, two when it reverts."""
    k = cfg.k

    # Lines 3 + 7 + 16 fused: P^t = Assign(X, C^t), E^t = E(P^t, C^t) and
    # the cluster stats of Update(X, P^t), all from a single step.
    res, carry = backend.step(x, state.c, k, state.carry)
    p_t, c_t, e_assign = res.labels, state.c, res.energy

    # Line 4: convergence <=> identical assignment.  Algorithm 1 returns
    # (P^t, C^t) at line 5 *before* doing any further work.
    converged = backend.all_equal(p_t, state.p_prev)

    def _finish(carry):
        new_state = state._replace(converged=jnp.array(True), labels=p_t,
                                   e_last=e_assign, t=state.t + 1,
                                   carry=carry)
        return new_state, jnp.array(False), e_assign

    def _full(carry):
        # Line 7: E^t = E(P^t, C^t) — the step's min-dist sum (the paper's
        # Sec-2.1 low-overhead argument; no re-gather).
        e_t = e_assign

        # Lines 7-11: dynamic adjustment of m
        aa_state = anderson.adjust_m(state.aa, e_t, state.e_prev,
                                     state.e_prev2, cfg.aa)

        # Lines 12-14: keep the accelerated iterate only if it decreases E;
        # otherwise revert to the fallback iterate C_AU^t = G(C^{t-1}).
        # The revert's single step supplies labels, energy AND the stats of
        # the next fallback — the legacy driver re-assigned and then paid a
        # separate update pass on top.
        accepted = e_t < state.e_prev

        def _keep(carry):
            return c_t, res, e_t, carry

        def _revert(carry):
            res_f, carry = backend.step(x, state.c_au, k, carry)
            return state.c_au, res_f, res_f.energy, carry

        c_cur, res_cur, e_cur, carry = jax.lax.cond(accepted, _keep, _revert,
                                                    carry)
        p_cur = res_cur.labels

        # Line 16: C_AU^{t+1} = Update(X, P^t) — from the already-computed
        # stats; no further pass over X.
        c_au_next = backend.centroids_from_step(x, res_cur, k, c_cur)

        # Lines 17-19: Anderson acceleration.
        g_flat = c_au_next.reshape(-1)
        f_flat = g_flat - c_cur.reshape(-1)
        if cfg.accelerated:
            aa_state, c_next_flat, _, _ = anderson.aa_push_and_solve(
                aa_state, f_flat, g_flat, cfg.aa)
            c_next = c_next_flat.reshape(c_cur.shape)
        else:
            c_next = c_au_next

        new_state = _LoopState(
            c=c_next, c_au=c_au_next, p_prev=p_cur,
            e_prev=e_cur, e_prev2=state.e_prev,
            aa=aa_state,
            t=state.t + 1,
            n_acc=state.n_acc + jnp.where(accepted, 1, 0).astype(jnp.int32),
            converged=jnp.array(False),
            labels=p_cur, e_last=e_cur, carry=carry)
        return new_state, accepted, e_cur

    new_state, accepted, e_cur = jax.lax.cond(converged, _finish, _full,
                                              carry)
    return new_state, converged, accepted, e_cur


# ---------------------------------------------------------------------------
# Segmented execution & persistence (DESIGN.md §Persistence)
# ---------------------------------------------------------------------------
#
# A checkpointable solve runs as a HOST loop over jit'd `lax.while_loop`
# segments: each segment executes the identical `_iteration` body until a
# traced boundary (`state.t < seg_end`), so pausing never enters the jit
# trace and the sequence of executed loop bodies — hence every bit of the
# trajectory — is exactly that of the uninterrupted single-while_loop run.
# Snapshots are the raw loop-state pytree via `repro.core.serialize`; the
# "like" trees below derive the expected structure from the init functions
# themselves (eval_shape), so the snapshot schema cannot drift from the
# code.  tests/test_persistence.py proves resume parity against the golden
# trajectory.

@functools.partial(jax.jit, static_argnames=("cfg", "backend"))
def _run_segment(x, state: _LoopState, seg_end, cfg: KMeansConfig,
                 backend: Backend) -> _LoopState:
    """Run Algorithm-1 iterations until convergence or t == seg_end.
    ``seg_end`` is a traced scalar, so every segment of a solve reuses one
    compiled program."""
    def cond(st: _LoopState):
        return jnp.logical_and(~st.converged, st.t < seg_end)

    def body(st: _LoopState):
        new_state, _, _, _ = _iteration(x, st, cfg, backend)
        return new_state

    return jax.lax.while_loop(cond, body, state)


_init_state_jit = jax.jit(_init_state, static_argnames=("cfg", "backend"))


def loop_state_like(x, c0, cfg: KMeansConfig, backend: BackendLike = None):
    """ShapeDtypeStruct tree of `_LoopState` for this problem/backend —
    the restore target for `serialize.restore` (no compute, no copies)."""
    bk = resolve_backend(backend, cfg=cfg)
    return jax.eval_shape(lambda xx, cc: _init_state(xx, cc, cfg, bk),
                          jax.ShapeDtypeStruct(x.shape, x.dtype),
                          jax.ShapeDtypeStruct(c0.shape, c0.dtype))


def _backend_base(name: str) -> str:
    """Mesh-layout-free backend identity: `distribute()` suffixes the name
    with '@axes', which must not block an elastic (re-mesh) restore."""
    return name.split("@")[0]


def _check_resume_meta(meta: dict, cfg, backend: Backend, what: str):
    if meta.get("k") is not None and meta["k"] != cfg.k:
        raise ValueError(f"{what}: snapshot was taken at k={meta['k']}, "
                         f"resuming with k={cfg.k}")
    snap_bk = meta.get("backend")
    if snap_bk and _backend_base(snap_bk) != _backend_base(backend.name):
        raise ValueError(
            f"{what}: snapshot was taken on backend {snap_bk!r} but the "
            f"resume uses {backend.name!r}; the per-backend carry (and on "
            f"some backends the reduction order) differs, so the resumed "
            f"trajectory would not match — resume on the same engine")


def _resolve_resume(resume_from, like, kind: str, cfg, backend: Backend):
    """Accept a state pytree (used as-is) or an artifact path (restored
    into ``like``); returns host/device state ready to enter a segment."""
    if resume_from is None:
        return None
    if isinstance(resume_from, (str, os.PathLike)):
        state, meta = serialize.restore(resume_from, like, expect_kind=kind)
        _check_resume_meta(meta, cfg, backend, str(resume_from))
        return state
    return resume_from


def _snapshot_meta(step: int, cfg, backend: Backend,
                   extra: Optional[dict] = None) -> dict:
    return {"t": step, "k": cfg.k, "backend": backend.name,
            **(extra or {})}


def _snapshot(checkpoint_dir, state, kind: str, step: int, cfg,
              backend: Backend, extra: Optional[dict] = None,
              keep_last_n: int = 0, keep_every_m: int = 0):
    """Synchronous boundary snapshot: atomic artifact + manifest +
    retention (`repro.runtime.writer.write_snapshot`).  The segmented
    drivers route the same call through a `CheckpointWriter` thread; the
    distributed driver and the sync-write benchmark arm call this
    directly."""
    return write_snapshot(checkpoint_dir, state, kind=kind, step=step,
                          extra=_snapshot_meta(step, cfg, backend, extra),
                          keep_last_n=keep_last_n, keep_every_m=keep_every_m)


def _make_writer(checkpoint_dir, kind: str, keep_last_n: int,
                 keep_every_m: int, metrics, sync_writes: bool):
    if checkpoint_dir is None or sync_writes:
        return None
    return CheckpointWriter(checkpoint_dir, kind=kind,
                            keep_last_n=keep_last_n,
                            keep_every_m=keep_every_m, metrics=metrics)


def _bound_scalars(carry) -> dict:
    from repro.core.backends.bounds import extract_stats
    bs = extract_stats(carry)
    if bs is None:
        return {}
    return {"eliminated_frac": float(bs.eliminated_frac),
            "skipped_frac": float(bs.skipped_frac)}


def _no_trace(x, who: str):
    if isinstance(x, jax.core.Tracer):
        raise ValueError(
            f"{who} with checkpoint_every/resume_from runs a host-side "
            f"segment loop and cannot itself be jit-traced; jit only the "
            f"plain (checkpoint-free) call, or let the driver's internal "
            f"per-segment jit do the compiling")


def _result_from_state(state: _LoopState) -> KMeansResult:
    # Iteration count convention of the paper's "a/b": b counts the initial
    # C^1 = G(C^0) plus every fully-executed loop body; the body that merely
    # *detects* convergence (line 4-5 early return) is not counted.
    n_iter = state.t + jnp.where(state.converged, 0, 1)
    return KMeansResult(state.c, state.labels, state.e_last,
                        n_iter, state.n_acc, state.converged)


def _aa_kmeans_segmented(x, c0, cfg: KMeansConfig, bk: Backend,
                         checkpoint_every: int, checkpoint_dir,
                         resume_from, checkpoint_cb,
                         keep_last_n: int = 0, keep_every_m: int = 0,
                         metrics=None,
                         sync_writes: bool = False) -> KMeansResult:
    _no_trace(x, "aa_kmeans")
    mx = as_metrics(metrics)
    every = int(checkpoint_every) if checkpoint_every else cfg.max_iter
    like = loop_state_like(x, c0, cfg, bk)
    state = _resolve_resume(resume_from, like, serialize.KIND_LOOP, cfg, bk)
    if state is None:
        state = _init_state_jit(x, c0, cfg, bk)
    t = int(state.t)
    writer = _make_writer(checkpoint_dir, serialize.KIND_LOOP, keep_last_n,
                          keep_every_m, mx, sync_writes)
    try:
        while not bool(state.converged) and t < cfg.max_iter:
            seg_end = min(t + every, cfg.max_iter)
            t0 = time.perf_counter()
            state = _run_segment(x, state, jnp.asarray(seg_end, jnp.int32),
                                 cfg, bk)
            t = int(state.t)   # host sync: the segment is fully computed
            seg_s = time.perf_counter() - t0
            if writer is not None:
                # the device_get here IS the snapshot point — taken
                # synchronously at the boundary, so the artifact content
                # is exactly the sync path's; only the write is deferred
                writer.submit(jax.device_get(state), t,
                              _snapshot_meta(t, cfg, bk))
            elif checkpoint_dir is not None:
                _snapshot(checkpoint_dir, state, serialize.KIND_LOOP, t,
                          cfg, bk, keep_last_n=keep_last_n,
                          keep_every_m=keep_every_m)
            if checkpoint_cb is not None:
                checkpoint_cb(state, t)
            mx.log_scalars(t, {
                "energy": float(state.e_last),
                "n_accepted": float(int(state.n_acc)),
                "converged": float(bool(state.converged)),
                "segment_s": seg_s, **_bound_scalars(state.carry)})
            if _metrics_stop(mx):
                break   # EarlyStopHook: improvement per segment stalled
    finally:
        if writer is not None:
            writer.close()   # drain + join; a failed write fails the run
    return _result_from_state(state)


def aa_kmeans(x: jax.Array, c0: jax.Array, cfg: KMeansConfig,
              ops: Optional[LloydOps] = None,
              backend: BackendLike = None, *,
              checkpoint_every: int = 0,
              checkpoint_dir=None,
              resume_from=None,
              checkpoint_cb: Optional[Callable] = None,
              keep_last_n: int = 0,
              keep_every_m: int = 0,
              metrics=None,
              sync_writes: bool = False,
              reorder=False) -> KMeansResult:
    """Jit-able Algorithm 1.  ``cfg`` is static; x (N,d); c0 (K,d).

    ``backend`` selects the engine ("dense" | "blocked" | "pallas" |
    "fused" | "hamerly" | "elkan" | "yinyang" | "fused_bounds", a Backend
    instance, or a distribute()-wrapped one).  ``ops`` is the deprecated
    LloydOps injection point, adapted via the shim when passed.  The
    bound family (the last four) threads triangle-inequality bounds
    through the loop carry — valid across accepted AA jumps and reverts
    (DESIGN.md §Bounds).

    Persistence (DESIGN.md §Persistence): ``checkpoint_every=s`` runs the
    solve as a host loop over jit'd s-iteration segments, snapshotting the
    loop state after each segment — to ``checkpoint_dir`` (one
    ``it_<t>.npz`` artifact per boundary, `repro.core.serialize` format)
    and/or a ``checkpoint_cb(state, t)`` callback.  ``resume_from`` (a
    snapshot path or a restored ``_LoopState``) continues a previous solve;
    the resumed trajectory is bit-identical to the uninterrupted one
    because segment boundaries only partition the identical sequence of
    loop bodies.  The checkpoint parameters require host execution — do
    not wrap the call itself in jit (each segment is jitted internally).

    Runtime (DESIGN.md §Runtime): artifact writes run on a background
    `CheckpointWriter` thread (the state snapshot itself is taken
    synchronously at the boundary, so resume stays bit-identical; set
    ``sync_writes=True`` to force in-line writes), with
    ``keep_last_n``/``keep_every_m`` retention and a ``manifest.json``
    per run directory.  ``metrics`` is any ``log_scalars(step, dict)``
    sink (`repro.runtime.metrics`); each segment boundary emits energy,
    accept counts, bound-skip fractions and wall time, and the writer
    emits per-snapshot write latency.

    Locality (DESIGN.md §Locality): ``reorder=True`` (or a
    `repro.core.locality.ReorderConfig`) wraps a bound backend in the
    churn-triggered row-reordering engine — the kernel sees cluster-sorted
    rows once assignments stabilise, while emitted labels/energies stay
    bit-identical to the unpermuted solve.  The permutation rides the
    backend carry, so checkpoint/resume persists it automatically."""
    bk = maybe_reorder(resolve_backend(backend, ops, cfg), reorder)
    if checkpoint_every or checkpoint_dir is not None \
            or resume_from is not None or checkpoint_cb is not None \
            or metrics is not None:
        return _aa_kmeans_segmented(x, c0, cfg, bk, checkpoint_every,
                                    checkpoint_dir, resume_from,
                                    checkpoint_cb, keep_last_n,
                                    keep_every_m, metrics, sync_writes)

    def cond(state: _LoopState):
        return jnp.logical_and(~state.converged, state.t < cfg.max_iter)

    def body(state: _LoopState):
        new_state, _, _, _ = _iteration(x, state, cfg, bk)
        return new_state

    state = _init_state(x, c0, cfg, bk)
    state = jax.lax.while_loop(cond, body, state)
    return _result_from_state(state)


def aa_kmeans_jit(x, c0, cfg: KMeansConfig, ops: Optional[LloydOps] = None,
                  backend: BackendLike = None):
    fn = jax.jit(lambda xx, cc: aa_kmeans(xx, cc, cfg, ops, backend))
    return fn(x, c0)


# ---------------------------------------------------------------------------
# Batched driver (many restarts / problems in ONE device program)
# ---------------------------------------------------------------------------

class _BatchedState(NamedTuple):
    inner: _LoopState
    # True while an Algorithm-1 iteration is half-done: the accelerated
    # iterate was rejected and the fallback step has not run yet.
    pending: jax.Array


def _tree_where(flag, on_true, on_false):
    """Leaf-wise select on a scalar flag (broadcasts over any leaf shape)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(flag, a, b), on_true, on_false)


def _tree_select_rows(mask, on_true, on_false):
    """Leaf-wise per-row select: mask (R,) against leaves of shape (R, ...)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(mask.reshape(mask.shape + (1,) * (a.ndim - 1)),
                               a, b), on_true, on_false)


def _is_active(state: _LoopState, max_iter: int):
    return jnp.logical_and(~state.converged, state.t < max_iter)


def _complete_batched_iteration(x, res, carry, bst: _BatchedState,
                                cfg: KMeansConfig,
                                backend: Backend, w=None) -> _BatchedState:
    """Per-restart completion logic of the split-phase batched body:
    everything in Algorithm 1's loop body *after* the backend step.
    Operates on one restart's (unbatched) state — the driver vmaps it."""
    st, pending = bst.inner, bst.pending
    k = cfg.k
    c_eval = jnp.where(pending, st.c_au, st.c)

    # Line 4 (phase A only): the revert step never checks convergence.
    # Under per-problem weights the check is MASKED: a padding row (w = 0)
    # never holds up convergence — its label chases centroids it does not
    # influence, so it may flip forever on ties while the real rows are
    # long settled (DESIGN.md §Hierarchy).
    if w is None:
        lab_now, lab_prev = res.labels, st.p_prev
    else:
        live = w > 0
        lab_now = jnp.where(live, res.labels, 0)
        lab_prev = jnp.where(live, st.p_prev, 0)
    conv_now = jnp.logical_and(~pending,
                               backend.all_equal(lab_now, lab_prev))
    # Lines 7-11 (phase A only): m adjusts before the revert decision.
    aa_adj = anderson.adjust_m(st.aa, res.energy, st.e_prev, st.e_prev2,
                               cfg.aa)
    accepted = jnp.logical_and(~pending, res.energy < st.e_prev)
    complete = jnp.logical_or(pending, accepted)

    # Iteration completion (phase-A-accepted or phase-B): lines 16-19 from
    # the step's stats.  In phase B the window was already adjusted when
    # the iterate was rejected, so push into the stored state.
    aa_for_push = _tree_where(pending, st.aa, aa_adj)
    c_au_next = backend.centroids_from_step(x, res, k, c_eval)
    g_flat = c_au_next.reshape(-1)
    f_flat = g_flat - c_eval.reshape(-1)
    if cfg.accelerated:
        aa_pushed, c_next_flat, _, _ = anderson.aa_push_and_solve(
            aa_for_push, f_flat, g_flat, cfg.aa)
        c_next = c_next_flat.reshape(st.c.shape)
    else:
        aa_pushed, c_next = aa_for_push, c_au_next

    st_complete = _LoopState(
        c=c_next, c_au=c_au_next, p_prev=res.labels,
        e_prev=res.energy, e_prev2=st.e_prev, aa=aa_pushed,
        t=st.t + 1,
        n_acc=st.n_acc + accepted.astype(jnp.int32),
        converged=jnp.array(False), labels=res.labels, e_last=res.energy,
        carry=carry)
    st_pending = st._replace(aa=aa_adj, carry=carry)
    st_conv = st._replace(converged=jnp.array(True), labels=res.labels,
                          e_last=res.energy, t=st.t + 1, carry=carry)

    new_inner = _tree_where(conv_now, st_conv,
                            _tree_where(complete, st_complete, st_pending))
    new_pending = jnp.logical_and(~conv_now, ~complete)
    return _BatchedState(new_inner, new_pending)


def _batched_body(x, bst: _BatchedState, cfg: KMeansConfig,
                  backend: Backend, x_batched: bool,
                  w=None) -> _BatchedState:
    """One *backend step* of Algorithm 1 for the whole batch.

    Under vmap, ``lax.cond`` lowers to a select that executes both
    branches, so the sequential ``_iteration`` — whose revert branch
    contains a second backend step — would cost two passes over X per
    loop body for *every* restart, accepted or not.  This body instead
    performs exactly one step and carries an explicit per-restart
    ``pending`` flag:

      phase A (pending=False): step at C^t.  Converged -> finish.
        Accepted (E^t < E^{t-1}) -> the same step's stats complete the
        iteration.  Rejected -> record the adjusted window and flip to
        pending; the iteration completes next body.
      phase B (pending=True): step at C_AU^t (the fallback), completing
        the rejected iteration exactly as ``_iteration``'s revert branch.

    The sequence of backend steps, window pushes and m-adjustments per
    restart is identical to the sequential driver's, so trajectories
    match step-for-step; a rejected iteration merely spans two bodies.
    The step itself runs through ``backend.batched_step`` — natively
    batched when the backend provides it (one shared-X einsum + matmul
    stats for dense), vmapped otherwise; only the cheap completion logic
    is always vmapped.
    """
    st = bst.inner
    c_eval = jnp.where(bst.pending[:, None, None], st.c_au, st.c)
    res, carry = backend.batched_step(x, c_eval, cfg.k, st.carry,
                                      x_batched=x_batched, w=w)
    if w is None:
        return jax.vmap(
            lambda xx, r, cr, ob: _complete_batched_iteration(
                xx, r, cr, ob, cfg, backend),
            in_axes=(0 if x_batched else None, 0, 0, 0))(x, res, carry, bst)
    return jax.vmap(
        lambda xx, r, cr, ob, ww: _complete_batched_iteration(
            xx, r, cr, ob, cfg, backend, w=ww),
        in_axes=(0 if x_batched else None, 0, 0, 0, 0))(x, res, carry, bst,
                                                        w)


@functools.partial(jax.jit, static_argnames=("cfg", "backend", "x_batched"))
def _run_batched_segment(x, bst: _BatchedState, max_trips, cfg: KMeansConfig,
                         backend: Backend, x_batched: bool,
                         w=None) -> _BatchedState:
    """Run up to ``max_trips`` batched loop trips (one backend step each).

    Restarts' iteration counters drift apart (a rejected iteration spans
    two trips), so segments are bounded by the TRIP count, which is the
    unit the shared while_loop actually executes: pausing at a trip
    boundary partitions the uninterrupted trip sequence exactly, which is
    what makes a resumed batched solve bit-identical."""
    def cond(carry):
        b, i = carry
        return jnp.logical_and(jnp.any(_is_active(b.inner, cfg.max_iter)),
                               i < max_trips)

    def body(carry):
        b, i = carry
        new_b = _batched_body(x, b, cfg, backend, x_batched=x_batched, w=w)
        new_b = _tree_select_rows(_is_active(b.inner, cfg.max_iter),
                                  new_b, b)
        return new_b, i + 1

    bst, _ = jax.lax.while_loop(cond, body,
                                (bst, jnp.array(0, jnp.int32)))
    return bst


def batched_state_like(x, c0s, cfg: KMeansConfig,
                       backend: BackendLike = None):
    """ShapeDtypeStruct tree of `_BatchedState` for this problem — the
    restore target for a batched-solver snapshot."""
    bk = resolve_backend(backend, cfg=cfg)
    x_axis = 0 if x.ndim == 3 else None

    def build(xx, cc):
        inner = jax.vmap(lambda xr, cr: _init_state(xr, cr, cfg, bk),
                         in_axes=(x_axis, 0))(xx, cc)
        return _BatchedState(inner, jnp.zeros((cc.shape[0],), bool))

    return jax.eval_shape(build, jax.ShapeDtypeStruct(x.shape, x.dtype),
                          jax.ShapeDtypeStruct(c0s.shape, c0s.dtype))


def aa_kmeans_batched(x: jax.Array, c0s: jax.Array, cfg: KMeansConfig,
                      ops: Optional[LloydOps] = None,
                      backend: BackendLike = None, *,
                      checkpoint_every: int = 0,
                      checkpoint_dir=None,
                      resume_from=None,
                      checkpoint_cb: Optional[Callable] = None,
                      keep_last_n: int = 0,
                      keep_every_m: int = 0,
                      metrics=None,
                      sync_writes: bool = False,
                      reorder=False,
                      weights=None) -> KMeansResult:
    """Batched Algorithm 1: R independent solves in one device program.

    ``c0s`` is (R, K, d) — one seed set per restart/problem.  ``x`` is
    either (N, d), shared by every restart (the multi-restart case), or
    (R, N, d), one dataset per problem (the grid / per-layer-codebook
    case; all problems must share N, d and K).

    ``weights`` (R, N) >= 0, when given, scales each row's contribution
    to the per-problem cluster stats and energy — the hierarchy engine
    passes its padding mask here (w = 0 rows vanish exactly from stats,
    energy AND the convergence check; DESIGN.md §Hierarchy).  Labels are
    still emitted for every row, weighted or not.

    The loop body is ``_batched_body``: one (natively batched or vmapped)
    backend step plus the vmapped completion logic — every backend's
    step, its carry, and the Anderson window batch cleanly because all
    loop state lives in fixed-shape arrays (DESIGN.md §Batching).
    Per-restart convergence is handled by *masking*, not by stopping: the
    shared ``lax.while_loop`` runs until every restart is done, and a
    restart that has converged (or hit max_iter) keeps its frozen state
    while the others continue — its trajectory is therefore identical to
    what the sequential driver would have produced.

    Returns a ``KMeansResult`` whose every leaf carries a leading R axis.
    Use ``select_best`` for on-device best-of-R selection.

    ``checkpoint_every=s`` segments the solve every s loop TRIPS (one
    batched backend step each; a rejected iteration spans two trips) and
    snapshots the whole per-restart state — see ``aa_kmeans`` for the
    checkpoint/resume contract and the runtime parameters
    (``keep_last_n``/``keep_every_m``/``metrics``/``sync_writes``), which
    carry over verbatim.  ``reorder=`` wraps a bound backend in the
    locality engine with per-restart permutations (DESIGN.md §Locality;
    each restart's rows sort by its own labels, gathered as (R, N, d)).
    """
    if c0s.ndim != 3:
        raise ValueError(f"c0s must be (R, K, d); got shape {c0s.shape}")
    if x.ndim not in (2, 3):
        raise ValueError(f"x must be (N, d) or (R, N, d); got {x.shape}")
    if x.ndim == 3 and x.shape[0] != c0s.shape[0]:
        raise ValueError(
            f"batched x has {x.shape[0]} problems but c0s has "
            f"{c0s.shape[0]} seed sets")
    if weights is not None and weights.shape != \
            (c0s.shape[0], x.shape[-2]):
        raise ValueError(
            f"weights must be (R, N) = ({c0s.shape[0]}, {x.shape[-2]}); "
            f"got {weights.shape}")
    bk = maybe_reorder(resolve_backend(backend, ops, cfg), reorder)
    x_axis = 0 if x.ndim == 3 else None

    if checkpoint_every or checkpoint_dir is not None \
            or resume_from is not None or checkpoint_cb is not None \
            or metrics is not None:
        return _aa_kmeans_batched_segmented(
            x, c0s, cfg, bk, x_axis, checkpoint_every, checkpoint_dir,
            resume_from, checkpoint_cb, keep_last_n, keep_every_m,
            metrics, sync_writes, weights=weights)

    states = _init_batched_state(x, c0s, cfg, bk, x_axis, w=weights)

    def active(bst: _BatchedState):
        # A pending restart never has t == max_iter (completion is what
        # advances t), so the sequential loop guard carries over as-is.
        return _is_active(bst.inner, cfg.max_iter)

    def cond(bst):
        return jnp.any(active(bst))

    def body(bst):
        new_bst = _batched_body(x, bst, cfg, bk, x_batched=(x_axis == 0),
                                w=weights)
        # Masked iteration: a finished restart is a no-op — its state is
        # frozen row-wise, so the shared loop cannot perturb it.
        return _tree_select_rows(active(bst), new_bst, bst)

    states = jax.lax.while_loop(cond, body, states).inner
    return _result_from_state(states)


@functools.partial(jax.jit, static_argnames=("cfg", "backend", "x_axis"))
def _init_batched_state(x, c0s, cfg: KMeansConfig, backend: Backend,
                        x_axis, w=None) -> _BatchedState:
    if w is None:
        inner0 = jax.vmap(lambda xx, cc: _init_state(xx, cc, cfg, backend),
                          in_axes=(x_axis, 0))(x, c0s)
    else:
        inner0 = jax.vmap(
            lambda xx, cc, ww: _init_state(xx, cc, cfg, backend, w=ww),
            in_axes=(x_axis, 0, 0))(x, c0s, w)
    return _BatchedState(inner0, jnp.zeros((c0s.shape[0],), bool))


def _aa_kmeans_batched_segmented(x, c0s, cfg: KMeansConfig, bk: Backend,
                                 x_axis, checkpoint_every, checkpoint_dir,
                                 resume_from, checkpoint_cb,
                                 keep_last_n: int = 0, keep_every_m: int = 0,
                                 metrics=None,
                                 sync_writes: bool = False,
                                 weights=None) -> KMeansResult:
    _no_trace(x, "aa_kmeans_batched")
    mx = as_metrics(metrics)
    # Worst case every Algorithm-1 iteration rejects, costing two trips.
    every = int(checkpoint_every) if checkpoint_every \
        else 2 * cfg.max_iter + 1
    like = batched_state_like(x, c0s, cfg, bk)
    trips = 0
    if isinstance(resume_from, (str, os.PathLike)):
        bst, meta = serialize.restore(resume_from, like,
                                      expect_kind=serialize.KIND_BATCHED)
        _check_resume_meta(meta, cfg, bk, str(resume_from))
        trips = int(meta.get("t", 0))
    elif resume_from is not None:
        bst = resume_from
        trips = int(jnp.max(resume_from.inner.t))   # snapshot naming only
    else:
        bst = _init_batched_state(x, c0s, cfg, bk, x_axis, w=weights)
    writer = _make_writer(checkpoint_dir, serialize.KIND_BATCHED,
                          keep_last_n, keep_every_m, mx, sync_writes)
    try:
        while bool(jnp.any(_is_active(bst.inner, cfg.max_iter))):
            t0 = time.perf_counter()
            bst = _run_batched_segment(x, bst, jnp.asarray(every, jnp.int32),
                                       cfg, bk, x_batched=(x_axis == 0),
                                       w=weights)
            trips += every   # upper bound on the final segment; monotone
            n_active = int(jnp.sum(_is_active(bst.inner, cfg.max_iter)))
            seg_s = time.perf_counter() - t0
            if writer is not None:
                writer.submit(jax.device_get(bst), trips,
                              _snapshot_meta(trips, cfg, bk))
            elif checkpoint_dir is not None:
                _snapshot(checkpoint_dir, bst, serialize.KIND_BATCHED,
                          trips, cfg, bk, keep_last_n=keep_last_n,
                          keep_every_m=keep_every_m)
            if checkpoint_cb is not None:
                checkpoint_cb(bst, trips)
            e = bst.inner.e_last
            e_best = jnp.min(jnp.where(jnp.isfinite(e), e, jnp.inf))
            mx.log_scalars(trips, {
                "energy_best": float(e_best),
                "n_active": float(n_active),
                "n_accepted_total": float(int(jnp.sum(bst.inner.n_acc))),
                "segment_s": seg_s})
            if _metrics_stop(mx):
                break   # EarlyStopHook: improvement per segment stalled
    finally:
        if writer is not None:
            writer.close()
    return _result_from_state(bst.inner)


def select_best(results: KMeansResult, groups=None,
                n_groups: Optional[int] = None) -> KMeansResult:
    """On-device best-of-R selection: the restart with the lowest final
    energy, as an unbatched KMeansResult.  Ties break toward the lower
    index — the same winner the sequential strict-< loop keeps.

    A NaN final energy (degenerate restart: NaN rows in X, numerically
    exploded iterate) never wins: `argmin` alone returns index 0 as soon
    as ANY energy is NaN, silently crowning a broken restart.  Non-finite
    energies are excluded from the comparison; if every restart is
    non-finite, the returned result keeps its NaN energy so the failure
    surfaces at the caller (the estimator raises on it) instead of being
    masked by a plausible-looking winner.

    ``groups`` (R,) int32 generalises the selection to PER-PROBLEM masked
    energies: restart r competes only within problem groups[r] (the
    hierarchy driver runs G sub-problems x n_init seeds as one batch),
    and the result keeps a leading axis of ``n_groups`` — row g is group
    g's winner.  Per-group masking uses the same finite-energy rule; a
    group whose every restart is non-finite surfaces its energy at row g.
    """
    e = results.energy
    masked = jnp.where(jnp.isfinite(e), e, jnp.inf)
    if groups is None:
        best = jnp.argmin(masked)
        return jax.tree_util.tree_map(lambda a: a[best], results)
    if n_groups is None:
        raise ValueError("select_best(groups=...) needs a static n_groups")
    gid = jnp.arange(n_groups, dtype=jnp.int32)
    emat = jnp.where(groups.astype(jnp.int32)[None, :] == gid[:, None],
                     masked[None, :], jnp.inf)               # (G, R)
    best = jnp.argmin(emat, axis=1)                          # (G,)
    return jax.tree_util.tree_map(lambda a: a[best], results)


# ---------------------------------------------------------------------------
# Streaming mini-batch driver (chunked X; DESIGN.md §Streaming)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "backend"))
def _run_minibatch_epoch(chunks, weights, x_val, state, key,
                         cfg: MiniBatchConfig, backend: Backend):
    """One epoch as a standalone program: the exact body of the scan-path
    ``epoch_step`` (same key-split order), so epoch-granular segmentation
    partitions the scan's computation without changing a bit of it."""
    key, sub = jax.random.split(key)
    state, trace = run_epoch(chunks, weights, x_val, state, cfg, backend,
                             sub)
    return state, key, trace


def minibatch_stream_like(c0, cfg: MiniBatchConfig,
                          backend: BackendLike = None, key=None):
    """ShapeDtypeStruct tree of a streaming-solver snapshot: the
    `MiniBatchState` plus the epoch-shuffle key (trajectory state the
    `lax.scan` carry holds alongside the solver state)."""
    bk = resolve_backend(backend)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32) if key is None else \
        jax.ShapeDtypeStruct(key.shape, key.dtype)
    state_sds = jax.eval_shape(
        lambda cc: minibatch_init(cc, cfg, bk),
        jax.ShapeDtypeStruct(c0.shape, c0.dtype))
    return {"state": state_sds, "key": key_sds}


def _aa_kmeans_minibatch_segmented(chunks, weights, x_val, c0,
                                   cfg: MiniBatchConfig, bk: Backend, key,
                                   checkpoint_every, checkpoint_dir,
                                   resume_from, checkpoint_cb,
                                   return_trace: bool,
                                   keep_last_n: int = 0,
                                   keep_every_m: int = 0,
                                   metrics=None,
                                   sync_writes: bool = False):
    _no_trace(chunks, "aa_kmeans_minibatch")
    mx = as_metrics(metrics)
    every = max(1, int(checkpoint_every)) if checkpoint_every else 1
    like = minibatch_stream_like(c0, cfg, bk, key)
    epoch = 0
    if isinstance(resume_from, (str, os.PathLike)):
        tree, meta = serialize.restore(resume_from, like,
                                       expect_kind=serialize.KIND_MINIBATCH)
        _check_resume_meta(meta, cfg, bk, str(resume_from))
        state, key = tree["state"], jnp.asarray(tree["key"])
        epoch = int(meta.get("epoch", 0))
    elif resume_from is not None:
        state, key = resume_from["state"], resume_from["key"]
        epoch = int(resume_from.get("epoch", 0))
    else:
        state = minibatch_init(c0, cfg, bk)
    traces = []
    writer = _make_writer(checkpoint_dir, serialize.KIND_MINIBATCH,
                          keep_last_n, keep_every_m, mx, sync_writes)
    try:
        while epoch < cfg.epochs:
            t0 = time.perf_counter()
            state, key, trace = _run_minibatch_epoch(chunks, weights, x_val,
                                                     state, key, cfg, bk)
            epoch += 1
            n_acc_epoch = int(jnp.sum(trace.accepted))   # host sync
            epoch_s = time.perf_counter() - t0
            if return_trace:
                traces.append(trace)
            if checkpoint_dir is not None and \
                    (epoch % every == 0 or epoch == cfg.epochs):
                meta = _snapshot_meta(epoch, cfg, bk,
                                      extra={"epoch": epoch})
                if writer is not None:
                    writer.submit(
                        jax.device_get({"state": state, "key": key}),
                        epoch, meta)
                else:
                    _snapshot(checkpoint_dir, {"state": state, "key": key},
                              serialize.KIND_MINIBATCH, epoch, cfg, bk,
                              extra={"epoch": epoch},
                              keep_last_n=keep_last_n,
                              keep_every_m=keep_every_m)
            if checkpoint_cb is not None:
                # "epoch" rides in the payload so the dict round-trips
                # through resume_from= without losing the counter (a
                # path-based resume reads it from the artifact's meta)
                checkpoint_cb({"state": state, "key": key, "epoch": epoch},
                              epoch)
            mx.log_scalars(epoch, {
                "e_val": float(trace.e_val[-1]),
                "e_cand": float(trace.e_cand[-1]),
                "e_fallback": float(trace.e_fallback[-1]),
                "n_accepted_epoch": float(n_acc_epoch),
                "epoch_s": epoch_s})
            if _metrics_stop(mx):
                break   # EarlyStopHook: improvement per epoch stalled
    finally:
        if writer is not None:
            writer.close()
    c_fin, e_fin, _, _ = guard_pick(x_val, state, cfg, bk)
    result = MiniBatchResult(c_fin, e_fin, state.t, state.n_acc)
    if not return_trace:
        return result
    # epochs run in THIS process only — a resumed run's trace covers the
    # epochs since the snapshot, like any log that restarts with a process
    trace = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *traces) \
        if traces else None
    return result, trace


def aa_kmeans_minibatch(chunks: jax.Array, weights: jax.Array,
                        x_val: jax.Array, c0: jax.Array,
                        cfg: MiniBatchConfig,
                        backend: BackendLike = None,
                        key: Optional[jax.Array] = None,
                        return_trace: bool = False, *,
                        checkpoint_every: int = 0,
                        checkpoint_dir=None,
                        resume_from=None,
                        checkpoint_cb: Optional[Callable] = None,
                        keep_last_n: int = 0,
                        keep_every_m: int = 0,
                        metrics=None,
                        sync_writes: bool = False):
    """Streaming Algorithm 1 over chunked data — fully jit-able.

    ``chunks`` is (n_chunks, B, d) with row-weight mask ``weights``
    (n_chunks, B) (`repro.data.streaming.chunk_dataset` builds both),
    ``x_val`` (V, d) is the held-out validation chunk the energy guard
    runs on, and ``c0`` (K, d) the seed centroids.  Runs ``cfg.epochs``
    epochs; the chunk order is reshuffled per epoch from ``key``.

    Each chunk step shares Algorithm 1's accept/revert skeleton with the
    full-batch driver — guard, dynamic-m, one weighted backend pass,
    Anderson push/solve (`minibatch.minibatch_iteration`) — and the whole
    run is a `lax.scan` over epochs of a `lax.scan` over chunks, so the
    program dispatches once regardless of epochs x chunks.  Runs
    unchanged under shard_map with a `distribute()`-wrapped backend: one
    stat-psum per chunk (`make_distributed_kmeans_minibatch`).

    Returns a `MiniBatchResult` whose centroids are the final
    guard-picked iterate; with ``return_trace=True`` also returns a
    `MiniBatchTrace` with leaves of shape (epochs, n_chunks).

    ``checkpoint_every=e`` segments the run at EPOCH granularity (a host
    loop over the jit'd epoch program, snapshotting state + shuffle key
    every e epochs); see ``aa_kmeans`` for the checkpoint/resume contract.
    The runtime knobs (``keep_last_n=`` / ``keep_every_m=`` retention,
    ``metrics=`` sink, ``sync_writes=``) carry over verbatim; metrics are
    emitted once per epoch.
    """
    if chunks.ndim != 3:
        raise ValueError(f"chunks must be (n_chunks, B, d); got "
                         f"{chunks.shape}")
    if weights.shape != chunks.shape[:2]:
        raise ValueError(f"weights {weights.shape} must match chunks' "
                         f"leading dims {chunks.shape[:2]}")
    bk = resolve_backend(backend)
    if key is None:
        key = jax.random.PRNGKey(0)
    if checkpoint_every or checkpoint_dir is not None \
            or resume_from is not None or checkpoint_cb is not None \
            or metrics is not None:
        return _aa_kmeans_minibatch_segmented(
            chunks, weights, x_val, c0, cfg, bk, key, checkpoint_every,
            checkpoint_dir, resume_from, checkpoint_cb, return_trace,
            keep_last_n=keep_last_n, keep_every_m=keep_every_m,
            metrics=metrics, sync_writes=sync_writes)
    state = minibatch_init(c0, cfg, bk)

    def epoch_step(carry, _):
        st, k2 = carry
        k2, sub = jax.random.split(k2)
        st, trace = run_epoch(chunks, weights, x_val, st, cfg, bk, sub)
        return (st, k2), trace

    (state, _), trace = jax.lax.scan(epoch_step, (state, key), None,
                                     length=cfg.epochs)
    c_fin, e_fin, _, _ = guard_pick(x_val, state, cfg, bk)
    result = MiniBatchResult(c_fin, e_fin, state.t, state.n_acc)
    return (result, trace) if return_trace else result


def aa_kmeans_minibatch_streamed(source, x_val: jax.Array, c0: jax.Array,
                                 cfg: MiniBatchConfig,
                                 backend: BackendLike = None, *,
                                 chunk_size: Optional[int] = None,
                                 seed: int = 0,
                                 prefetch: int = 2,
                                 drop_remainder: bool = False,
                                 sort_chunks: bool = False,
                                 mesh=None, data_axes=("data",),
                                 meter=None, metrics=None,
                                 return_trace: bool = False):
    """Streaming Algorithm 1 over a host-resident source, with transfer
    overlap: the `stream_chunks` prefetcher threaded under the epoch
    driver (DESIGN.md §Runtime — previously only `partial_fit_stream` and
    the ``--big`` benchmark overlapped host→device copies).

    ``source`` is a host array (chunked/shuffled per epoch by
    `host_chunk_stream`; ``chunk_size`` defaults to ``cfg.chunk_size``) or
    any iterator of host chunks (``chunk_size``/``seed`` ignored; the
    caller owns ordering and ``cfg.epochs`` must be baked into the
    iterator).  Each chunk runs one jitted `minibatch_iteration` — the
    same per-chunk state machine as `aa_kmeans_minibatch` — while chunk
    t+1's copy is in flight, so the device never waits on ingest.  For a
    device-resident `DeviceChunks` use `aa_kmeans_minibatch`, whose
    scan-over-gathers needs no transfers at all.

    ``sort_chunks=True`` assembles each chunk cluster-sorted
    (`stream_chunks(sort_by=...)` with the driver's current centroids —
    stale by the prefetch depth, which affects locality only, never the
    numbers) so the weighted backend pass sees locality-ordered rows.
    ``meter`` (an `IngestMeter`) and ``metrics`` observe ingest bandwidth
    and per-chunk guard decisions; note a ``metrics`` sink synchronises on
    every chunk, serialising the very overlap this driver exists for —
    leave it None on the hot path.  Uniform chunk lengths avoid re-jitting
    (``drop_remainder=True`` guarantees them for an array source).

    Returns a `MiniBatchResult` (with ``return_trace=True``, also a
    `MiniBatchTrace` stacked over all chunk steps).
    """
    from repro.data.streaming import stream_chunks

    bk = resolve_backend(backend)
    state = minibatch_init(c0, cfg, bk)
    holder = [state]
    sort_by = (lambda: jax.device_get(holder[0].c)) if sort_chunks else None
    step = jax.jit(minibatch_iteration, static_argnames=("cfg", "backend"))
    mx = as_metrics(metrics)
    traces = []
    chunk_iter = stream_chunks(
        source, None if hasattr(source, "__next__") else
        (chunk_size or cfg.chunk_size),
        epochs=cfg.epochs, seed=seed, drop_remainder=drop_remainder,
        prefetch=prefetch, mesh=mesh, data_axes=tuple(data_axes),
        meter=meter, sort_by=sort_by)
    for xc in chunk_iter:
        w = jnp.ones((xc.shape[0],), jnp.float32)
        holder[0], tr = step(xc, w, x_val, holder[0], cfg, bk)
        if return_trace:
            traces.append(tr)
        if metrics is not None:
            mx.log_scalars(int(holder[0].t),
                           {"e_val": float(tr.e_val),
                            "accepted": float(tr.accepted)})
    state = holder[0]
    c_fin, e_fin, _, _ = guard_pick(x_val, state, cfg, bk)
    result = MiniBatchResult(c_fin, e_fin, state.t, state.n_acc)
    if not return_trace:
        return result
    trace = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *traces) \
        if traces else None
    return result, trace


# ---------------------------------------------------------------------------
# Instrumented Python driver (benchmark parity with the paper's tables)
# ---------------------------------------------------------------------------

class KMeansTrace(NamedTuple):
    result: KMeansResult
    energies: list          # E^t per iteration (post-revert)
    m_values: list          # m after adjustment, per iteration
    accepted: list          # bool per iteration
    wall_time_s: float
    mse: float              # final E / N — the paper's reported MSE
    # per-iteration {"eliminated_frac", "skipped_frac"} dicts for bound
    # backends (hamerly/elkan/yinyang/fused_bounds), read off the carry's
    # BoundStats; [] for stateless backends.  Shows how the elimination
    # ramps from 0 (first full scan) toward the converged-phase plateau.
    bound_stats: tuple = ()
    # per-phase means of bound_stats, split at the FIRST ACCEPTED AA
    # iteration: {"pre_accept": {...}, "post_accept": {...}}, each with
    # n_iters + the mean fracs (None when the phase is empty).  The flat
    # bound_stats average mixes the warm-up iterations — where skipping is
    # structurally ~0 because bounds have not tightened — into the
    # converged plateau, understating the engine by 2-3x on short runs;
    # BENCH consumers must read post_accept (see split_bound_phases).
    bound_phases: Optional[dict] = None


def split_bound_phases(accepted, bound_stats):
    """Split per-iteration bound stats at the first accepted iteration.

    The early iterations run on slack bounds (first scan has upper = +inf;
    drift updates need a few steps to tighten), so their elimination/skip
    fractions sit near 0 regardless of the engine's quality — averaging
    them into the converged tail dilutes every reported fraction.  The
    first *accepted* AA iteration is the natural phase boundary: the energy
    has started decreasing monotonically and the bounds are live.

    Returns {} when there are no bound stats; otherwise a dict with
    "pre_accept" / "post_accept" entries of {n_iters, <mean of each stat
    key>} — empty phases report n_iters = 0 and None means.
    """
    bound_stats = list(bound_stats)
    if not bound_stats:
        return {}
    accepted = list(accepted)[:len(bound_stats)]
    first = next((i for i, a in enumerate(accepted) if a), len(bound_stats))
    keys = sorted(bound_stats[0])

    def phase(rows):
        out = {"n_iters": len(rows)}
        for key in keys:
            out[key] = (sum(r[key] for r in rows) / len(rows)) if rows \
                else None
        return out

    return {"pre_accept": phase(bound_stats[:first]),
            "post_accept": phase(bound_stats[first:])}


def aa_kmeans_traced(x: jax.Array, c0: jax.Array, cfg: KMeansConfig,
                     ops: Optional[LloydOps] = None,
                     jit_iteration: bool = True,
                     backend: BackendLike = None,
                     warmup: bool = False,
                     metrics=None,
                     reorder=False) -> KMeansTrace:
    """Python-loop driver recording the statistics of Tables 2 and 3.

    ``metrics=`` accepts any `repro.runtime.metrics` sink; each iteration
    emits {energy, m, accepted} plus bound-elimination fractions for
    bound backends — the same numbers the returned trace accumulates,
    streamed live instead of collected at the end.

    ``warmup=True`` compiles the init/iteration computations on a throwaway
    run before the timer starts, so ``wall_time_s`` measures steady-state
    execution rather than jit compilation — the quantity the paper's
    Table 3 wall-times report.  (Both jitted functions are keyed on static
    (cfg, backend) and the argument shapes, so the warm-up populates
    exactly the cache the timed loop hits.)

    ``reorder=`` enables the locality engine exactly as in ``aa_kmeans``;
    the trace's ``bound_phases`` then shows the converged-phase skip the
    reordering unlocked (the flat average would dilute it — see
    `split_bound_phases`).
    """
    bk = maybe_reorder(resolve_backend(backend, ops, cfg), reorder)
    iter_fn = _iteration
    if jit_iteration:
        iter_fn = jax.jit(_iteration, static_argnames=("cfg", "backend"))
    init_fn = jax.jit(_init_state, static_argnames=("cfg", "backend")) \
        if jit_iteration else _init_state

    if warmup:
        ws = init_fn(x, c0, cfg, bk)
        ws, _, _, _ = iter_fn(x, ws, cfg, bk)
        jax.block_until_ready(ws.c)

    from repro.core.backends.bounds import extract_stats

    mx = as_metrics(metrics)
    t0 = time.perf_counter()
    state = init_fn(x, c0, cfg, bk)
    energies, m_vals, acc, bstats = [], [], [], []
    converged = False
    while not converged and int(state.t) < cfg.max_iter:
        state, conv, accepted, e_t = iter_fn(x, state, cfg, bk)
        converged = bool(conv)
        if converged:
            break
        energies.append(float(e_t))
        m_vals.append(int(state.aa.m))
        acc.append(bool(accepted))
        scalars = {"energy": energies[-1], "m": float(m_vals[-1]),
                   "accepted": float(acc[-1])}
        bs = extract_stats(state.carry)
        if bs is not None:
            bstats.append({"eliminated_frac": float(bs.eliminated_frac),
                           "skipped_frac": float(bs.skipped_frac)})
            scalars.update(bstats[-1])
        mx.log_scalars(len(energies), scalars)
    jax.block_until_ready(state.c)
    wall = time.perf_counter() - t0

    n_iter = len(energies) + 1          # +1 for the initial G(C^0)
    n_accepted = sum(acc)
    result = KMeansResult(state.c, state.labels, state.e_last,
                          jnp.array(n_iter), jnp.array(n_accepted),
                          jnp.array(converged))
    mse = float(state.e_last) / x.shape[0]
    return KMeansTrace(result, energies, m_vals, acc, wall, mse, bstats,
                       split_bound_phases(acc, bstats))
