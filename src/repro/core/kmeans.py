"""Algorithm 1 of the paper: Anderson acceleration for the K-Means algorithm.

Two drivers over the same primitives:

  * ``aa_kmeans``        — fully jit-able ``lax.while_loop`` implementation
                           (production path; runs unchanged under shard_map
                           distribution and with Pallas kernel backends).
  * ``aa_kmeans_traced`` — Python-loop driver that records the per-iteration
                           statistics the paper reports (accepted / total
                           iterations, energy trace, m trace, wall time);
                           used by the Table 2 / Table 3 benchmarks.
  * ``aa_kmeans_minibatch`` — streaming epoch driver over chunked data
                           (state machine in repro.core.minibatch;
                           DESIGN.md §Streaming).
  * ``aa_kmeans_batched`` — R restarts / problems in one device program.

Both consume a `Backend` (repro.core.backends) whose core op is the
single-pass ``step(x, c) -> StepResult``, so one *accepted* Algorithm-1
iteration costs exactly one pass over X (the paper's Sec-2.1 cost model):
the step's assignment doubles as the energy evaluation AND as the cluster
statistics from which the next fallback iterate C_AU follows without
re-reading X.  A *rejected* iteration takes one extra step — the fallback
must be re-assigned — and that second step's stats are reused the same way
(the legacy driver paid a third pass here).

Faithfulness notes (vs. the pseudo-code in the paper):

  * Convergence criterion: identical assignment between two consecutive
    iterations (line 4).  Because an accelerated iterate is only kept when it
    strictly decreases the energy, this is reached exactly when a fallback
    Lloyd iterate repeats the previous assignment — the classical criterion.
  * The energy check (lines 12-14) compares E(C^t) with E(C^{t-1}) and
    reverts to the *previous* un-accelerated iterate C_AU^t = G(C^{t-1})
    computed at line 16 of the previous iteration.
  * m-adjustment (lines 7-11) happens *before* the revert check, so a
    rejected iterate (negative decrease -> ratio < eps1) also shrinks m.
  * E^0 = +inf, and the ratio test only activates once E^{t-2} is finite.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import anderson
from repro.core.anderson import AAConfig, AAState
from repro.core.backends import Backend, from_lloyd_ops, get_backend
from repro.core.lloyd import DENSE_OPS, LloydOps
from repro.core.minibatch import (MiniBatchConfig, MiniBatchResult,
                                  guard_pick, minibatch_init, run_epoch)


@dataclasses.dataclass(frozen=True)
class KMeansConfig:
    k: int
    max_iter: int = 500
    aa: AAConfig = dataclasses.field(default_factory=AAConfig)
    accelerated: bool = True     # False -> plain Lloyd through the same driver
    block_n: int = 0             # row blocking for the assignment step


class KMeansResult(NamedTuple):
    centroids: jax.Array   # (K, d)
    labels: jax.Array      # (N,)
    energy: jax.Array      # scalar, final E
    n_iter: jax.Array      # total iterations (paper's "b" in a/b)
    n_accepted: jax.Array  # iterations whose accelerated iterate was kept
    converged: jax.Array   # bool


class _LoopState(NamedTuple):
    c: jax.Array           # C^t               (K, d)
    c_au: jax.Array        # C_AU^t = G(C^{t-1})  fallback iterate
    p_prev: jax.Array      # P^{t-1}           (N,)
    e_prev: jax.Array      # E^{t-1}
    e_prev2: jax.Array     # E^{t-2}
    aa: AAState
    t: jax.Array
    n_acc: jax.Array
    converged: jax.Array
    labels: jax.Array      # last P^t (valid on exit)
    e_last: jax.Array
    carry: Any             # opaque backend carry (e.g. Hamerly bounds)


BackendLike = Union[str, Backend, None]


def resolve_backend(backend: BackendLike, ops: Optional[LloydOps] = None,
                    cfg: Optional[KMeansConfig] = None,
                    block_n: int = 0) -> Backend:
    """Resolve the (backend=, ops=) pair the solver entry points accept —
    the single backend-selection policy for both the local and the
    distributed drivers.

    Priority: an explicit Backend instance wins; a registry name is looked
    up (with "dense"/"blocked" promoted to the row-blocked engine when a
    block size is configured — via ``block_n`` or ``cfg.block_n``); a
    non-default legacy LloydOps is adapted through the deprecation shim;
    otherwise the dense engine."""
    block_n = block_n or (cfg.block_n if cfg is not None else 0)
    if isinstance(backend, Backend):
        return backend
    if isinstance(backend, str):
        if backend in ("dense", "blocked") and block_n:
            return get_backend("blocked", block_n=block_n)
        return get_backend(backend)
    if isinstance(backend, LloydOps):   # migration path off the ops= param
        return from_lloyd_ops(backend)
    if backend is not None:
        raise TypeError(
            f"backend= expects a registry name, a Backend, or a legacy "
            f"LloydOps; got {type(backend).__name__}")
    if ops is not None and ops is not DENSE_OPS:
        return from_lloyd_ops(ops)
    if block_n:
        return get_backend("blocked", block_n=block_n)
    return get_backend("dense")


def _init_state(x, c0, cfg: KMeansConfig, backend: Backend) -> _LoopState:
    k = cfg.k
    # Line 1:  C^1 = C_AU^1 = G(C^0);  F^0 = C^1 - C^0;  E^0 = +inf
    # — one step: the same pass yields E(C^0), P^0 and the stats of G(C^0).
    carry = backend.init_carry(x, c0, k)
    res0, carry = backend.step(x, c0, k, carry)
    c1 = backend.centroids_from_step(x, res0, k, c0)
    aa_state = anderson.aa_init(k * x.shape[1], cfg.aa, x.dtype)
    aa_state = anderson.aa_seed(aa_state, (c1 - c0).reshape(-1),
                                c1.reshape(-1))
    inf = jnp.array(jnp.inf, res0.energy.dtype)
    return _LoopState(
        c=c1, c_au=c1, p_prev=res0.labels,
        e_prev=inf, e_prev2=inf,
        aa=aa_state,
        t=jnp.array(0, jnp.int32), n_acc=jnp.array(0, jnp.int32),
        converged=jnp.array(False),
        labels=res0.labels,
        # E(C^0) as the placeholder "last energy" — overwritten by the first
        # loop body; already reduced across shards by the backend.
        e_last=res0.energy,
        carry=carry)


def _iteration(x, state: _LoopState, cfg: KMeansConfig, backend: Backend):
    """One body of Algorithm 1's for-loop (lines 3-19) — ONE pass over X
    when the accelerated iterate is accepted, two when it reverts."""
    k = cfg.k

    # Lines 3 + 7 + 16 fused: P^t = Assign(X, C^t), E^t = E(P^t, C^t) and
    # the cluster stats of Update(X, P^t), all from a single step.
    res, carry = backend.step(x, state.c, k, state.carry)
    p_t, c_t, e_assign = res.labels, state.c, res.energy

    # Line 4: convergence <=> identical assignment.  Algorithm 1 returns
    # (P^t, C^t) at line 5 *before* doing any further work.
    converged = backend.all_equal(p_t, state.p_prev)

    def _finish(carry):
        new_state = state._replace(converged=jnp.array(True), labels=p_t,
                                   e_last=e_assign, t=state.t + 1,
                                   carry=carry)
        return new_state, jnp.array(False), e_assign

    def _full(carry):
        # Line 7: E^t = E(P^t, C^t) — the step's min-dist sum (the paper's
        # Sec-2.1 low-overhead argument; no re-gather).
        e_t = e_assign

        # Lines 7-11: dynamic adjustment of m
        aa_state = anderson.adjust_m(state.aa, e_t, state.e_prev,
                                     state.e_prev2, cfg.aa)

        # Lines 12-14: keep the accelerated iterate only if it decreases E;
        # otherwise revert to the fallback iterate C_AU^t = G(C^{t-1}).
        # The revert's single step supplies labels, energy AND the stats of
        # the next fallback — the legacy driver re-assigned and then paid a
        # separate update pass on top.
        accepted = e_t < state.e_prev

        def _keep(carry):
            return c_t, res, e_t, carry

        def _revert(carry):
            res_f, carry = backend.step(x, state.c_au, k, carry)
            return state.c_au, res_f, res_f.energy, carry

        c_cur, res_cur, e_cur, carry = jax.lax.cond(accepted, _keep, _revert,
                                                    carry)
        p_cur = res_cur.labels

        # Line 16: C_AU^{t+1} = Update(X, P^t) — from the already-computed
        # stats; no further pass over X.
        c_au_next = backend.centroids_from_step(x, res_cur, k, c_cur)

        # Lines 17-19: Anderson acceleration.
        g_flat = c_au_next.reshape(-1)
        f_flat = g_flat - c_cur.reshape(-1)
        if cfg.accelerated:
            aa_state, c_next_flat, _, _ = anderson.aa_push_and_solve(
                aa_state, f_flat, g_flat, cfg.aa)
            c_next = c_next_flat.reshape(c_cur.shape)
        else:
            c_next = c_au_next

        new_state = _LoopState(
            c=c_next, c_au=c_au_next, p_prev=p_cur,
            e_prev=e_cur, e_prev2=state.e_prev,
            aa=aa_state,
            t=state.t + 1,
            n_acc=state.n_acc + jnp.where(accepted, 1, 0).astype(jnp.int32),
            converged=jnp.array(False),
            labels=p_cur, e_last=e_cur, carry=carry)
        return new_state, accepted, e_cur

    new_state, accepted, e_cur = jax.lax.cond(converged, _finish, _full,
                                              carry)
    return new_state, converged, accepted, e_cur


def aa_kmeans(x: jax.Array, c0: jax.Array, cfg: KMeansConfig,
              ops: Optional[LloydOps] = None,
              backend: BackendLike = None) -> KMeansResult:
    """Jit-able Algorithm 1.  ``cfg`` is static; x (N,d); c0 (K,d).

    ``backend`` selects the engine ("dense" | "blocked" | "pallas" |
    "fused" | "hamerly", a Backend instance, or a distribute()-wrapped
    one).  ``ops`` is the deprecated LloydOps injection point, adapted via
    the shim when passed."""
    bk = resolve_backend(backend, ops, cfg)

    def cond(state: _LoopState):
        return jnp.logical_and(~state.converged, state.t < cfg.max_iter)

    def body(state: _LoopState):
        new_state, _, _, _ = _iteration(x, state, cfg, bk)
        return new_state

    state = _init_state(x, c0, cfg, bk)
    state = jax.lax.while_loop(cond, body, state)
    # Iteration count convention of the paper's "a/b": b counts the initial
    # C^1 = G(C^0) plus every fully-executed loop body; the body that merely
    # *detects* convergence (line 4-5 early return) is not counted.
    n_iter = state.t + jnp.where(state.converged, 0, 1)
    return KMeansResult(state.c, state.labels, state.e_last,
                        n_iter, state.n_acc, state.converged)


def aa_kmeans_jit(x, c0, cfg: KMeansConfig, ops: Optional[LloydOps] = None,
                  backend: BackendLike = None):
    fn = jax.jit(lambda xx, cc: aa_kmeans(xx, cc, cfg, ops, backend))
    return fn(x, c0)


# ---------------------------------------------------------------------------
# Batched driver (many restarts / problems in ONE device program)
# ---------------------------------------------------------------------------

class _BatchedState(NamedTuple):
    inner: _LoopState
    # True while an Algorithm-1 iteration is half-done: the accelerated
    # iterate was rejected and the fallback step has not run yet.
    pending: jax.Array


def _tree_where(flag, on_true, on_false):
    """Leaf-wise select on a scalar flag (broadcasts over any leaf shape)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(flag, a, b), on_true, on_false)


def _tree_select_rows(mask, on_true, on_false):
    """Leaf-wise per-row select: mask (R,) against leaves of shape (R, ...)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(mask.reshape(mask.shape + (1,) * (a.ndim - 1)),
                               a, b), on_true, on_false)


def _is_active(state: _LoopState, max_iter: int):
    return jnp.logical_and(~state.converged, state.t < max_iter)


def _complete_batched_iteration(x, res, carry, bst: _BatchedState,
                                cfg: KMeansConfig,
                                backend: Backend) -> _BatchedState:
    """Per-restart completion logic of the split-phase batched body:
    everything in Algorithm 1's loop body *after* the backend step.
    Operates on one restart's (unbatched) state — the driver vmaps it."""
    st, pending = bst.inner, bst.pending
    k = cfg.k
    c_eval = jnp.where(pending, st.c_au, st.c)

    # Line 4 (phase A only): the revert step never checks convergence.
    conv_now = jnp.logical_and(~pending,
                               backend.all_equal(res.labels, st.p_prev))
    # Lines 7-11 (phase A only): m adjusts before the revert decision.
    aa_adj = anderson.adjust_m(st.aa, res.energy, st.e_prev, st.e_prev2,
                               cfg.aa)
    accepted = jnp.logical_and(~pending, res.energy < st.e_prev)
    complete = jnp.logical_or(pending, accepted)

    # Iteration completion (phase-A-accepted or phase-B): lines 16-19 from
    # the step's stats.  In phase B the window was already adjusted when
    # the iterate was rejected, so push into the stored state.
    aa_for_push = _tree_where(pending, st.aa, aa_adj)
    c_au_next = backend.centroids_from_step(x, res, k, c_eval)
    g_flat = c_au_next.reshape(-1)
    f_flat = g_flat - c_eval.reshape(-1)
    if cfg.accelerated:
        aa_pushed, c_next_flat, _, _ = anderson.aa_push_and_solve(
            aa_for_push, f_flat, g_flat, cfg.aa)
        c_next = c_next_flat.reshape(st.c.shape)
    else:
        aa_pushed, c_next = aa_for_push, c_au_next

    st_complete = _LoopState(
        c=c_next, c_au=c_au_next, p_prev=res.labels,
        e_prev=res.energy, e_prev2=st.e_prev, aa=aa_pushed,
        t=st.t + 1,
        n_acc=st.n_acc + accepted.astype(jnp.int32),
        converged=jnp.array(False), labels=res.labels, e_last=res.energy,
        carry=carry)
    st_pending = st._replace(aa=aa_adj, carry=carry)
    st_conv = st._replace(converged=jnp.array(True), labels=res.labels,
                          e_last=res.energy, t=st.t + 1, carry=carry)

    new_inner = _tree_where(conv_now, st_conv,
                            _tree_where(complete, st_complete, st_pending))
    new_pending = jnp.logical_and(~conv_now, ~complete)
    return _BatchedState(new_inner, new_pending)


def _batched_body(x, bst: _BatchedState, cfg: KMeansConfig,
                  backend: Backend, x_batched: bool) -> _BatchedState:
    """One *backend step* of Algorithm 1 for the whole batch.

    Under vmap, ``lax.cond`` lowers to a select that executes both
    branches, so the sequential ``_iteration`` — whose revert branch
    contains a second backend step — would cost two passes over X per
    loop body for *every* restart, accepted or not.  This body instead
    performs exactly one step and carries an explicit per-restart
    ``pending`` flag:

      phase A (pending=False): step at C^t.  Converged -> finish.
        Accepted (E^t < E^{t-1}) -> the same step's stats complete the
        iteration.  Rejected -> record the adjusted window and flip to
        pending; the iteration completes next body.
      phase B (pending=True): step at C_AU^t (the fallback), completing
        the rejected iteration exactly as ``_iteration``'s revert branch.

    The sequence of backend steps, window pushes and m-adjustments per
    restart is identical to the sequential driver's, so trajectories
    match step-for-step; a rejected iteration merely spans two bodies.
    The step itself runs through ``backend.batched_step`` — natively
    batched when the backend provides it (one shared-X einsum + matmul
    stats for dense), vmapped otherwise; only the cheap completion logic
    is always vmapped.
    """
    st = bst.inner
    c_eval = jnp.where(bst.pending[:, None, None], st.c_au, st.c)
    res, carry = backend.batched_step(x, c_eval, cfg.k, st.carry,
                                      x_batched=x_batched)
    return jax.vmap(
        lambda xx, r, cr, ob: _complete_batched_iteration(
            xx, r, cr, ob, cfg, backend),
        in_axes=(0 if x_batched else None, 0, 0, 0))(x, res, carry, bst)


def aa_kmeans_batched(x: jax.Array, c0s: jax.Array, cfg: KMeansConfig,
                      ops: Optional[LloydOps] = None,
                      backend: BackendLike = None) -> KMeansResult:
    """Batched Algorithm 1: R independent solves in one device program.

    ``c0s`` is (R, K, d) — one seed set per restart/problem.  ``x`` is
    either (N, d), shared by every restart (the multi-restart case), or
    (R, N, d), one dataset per problem (the grid / per-layer-codebook
    case; all problems must share N, d and K).

    The loop body is ``_batched_body``: one (natively batched or vmapped)
    backend step plus the vmapped completion logic — every backend's
    step, its carry, and the Anderson window batch cleanly because all
    loop state lives in fixed-shape arrays (DESIGN.md §Batching).
    Per-restart convergence is handled by *masking*, not by stopping: the
    shared ``lax.while_loop`` runs until every restart is done, and a
    restart that has converged (or hit max_iter) keeps its frozen state
    while the others continue — its trajectory is therefore identical to
    what the sequential driver would have produced.

    Returns a ``KMeansResult`` whose every leaf carries a leading R axis.
    Use ``select_best`` for on-device best-of-R selection.
    """
    if c0s.ndim != 3:
        raise ValueError(f"c0s must be (R, K, d); got shape {c0s.shape}")
    if x.ndim not in (2, 3):
        raise ValueError(f"x must be (N, d) or (R, N, d); got {x.shape}")
    if x.ndim == 3 and x.shape[0] != c0s.shape[0]:
        raise ValueError(
            f"batched x has {x.shape[0]} problems but c0s has "
            f"{c0s.shape[0]} seed sets")
    bk = resolve_backend(backend, ops, cfg)
    x_axis = 0 if x.ndim == 3 else None

    inner0 = jax.vmap(lambda xx, cc: _init_state(xx, cc, cfg, bk),
                      in_axes=(x_axis, 0))(x, c0s)
    r = c0s.shape[0]
    states = _BatchedState(inner0, jnp.zeros((r,), bool))

    def active(bst: _BatchedState):
        # A pending restart never has t == max_iter (completion is what
        # advances t), so the sequential loop guard carries over as-is.
        return _is_active(bst.inner, cfg.max_iter)

    def cond(bst):
        return jnp.any(active(bst))

    def body(bst):
        new_bst = _batched_body(x, bst, cfg, bk, x_batched=(x_axis == 0))
        # Masked iteration: a finished restart is a no-op — its state is
        # frozen row-wise, so the shared loop cannot perturb it.
        return _tree_select_rows(active(bst), new_bst, bst)

    states = jax.lax.while_loop(cond, body, states).inner
    n_iter = states.t + jnp.where(states.converged, 0, 1)
    return KMeansResult(states.c, states.labels, states.e_last,
                        n_iter, states.n_acc, states.converged)


def select_best(results: KMeansResult) -> KMeansResult:
    """On-device best-of-R selection: the restart with the lowest final
    energy, as an unbatched KMeansResult.  Ties break toward the lower
    index — the same winner the sequential strict-< loop keeps."""
    best = jnp.argmin(results.energy)
    return jax.tree_util.tree_map(lambda a: a[best], results)


# ---------------------------------------------------------------------------
# Streaming mini-batch driver (chunked X; DESIGN.md §Streaming)
# ---------------------------------------------------------------------------

def aa_kmeans_minibatch(chunks: jax.Array, weights: jax.Array,
                        x_val: jax.Array, c0: jax.Array,
                        cfg: MiniBatchConfig,
                        backend: BackendLike = None,
                        key: Optional[jax.Array] = None,
                        return_trace: bool = False):
    """Streaming Algorithm 1 over chunked data — fully jit-able.

    ``chunks`` is (n_chunks, B, d) with row-weight mask ``weights``
    (n_chunks, B) (`repro.data.streaming.chunk_dataset` builds both),
    ``x_val`` (V, d) is the held-out validation chunk the energy guard
    runs on, and ``c0`` (K, d) the seed centroids.  Runs ``cfg.epochs``
    epochs; the chunk order is reshuffled per epoch from ``key``.

    Each chunk step shares Algorithm 1's accept/revert skeleton with the
    full-batch driver — guard, dynamic-m, one weighted backend pass,
    Anderson push/solve (`minibatch.minibatch_iteration`) — and the whole
    run is a `lax.scan` over epochs of a `lax.scan` over chunks, so the
    program dispatches once regardless of epochs x chunks.  Runs
    unchanged under shard_map with a `distribute()`-wrapped backend: one
    stat-psum per chunk (`make_distributed_kmeans_minibatch`).

    Returns a `MiniBatchResult` whose centroids are the final
    guard-picked iterate; with ``return_trace=True`` also returns a
    `MiniBatchTrace` with leaves of shape (epochs, n_chunks).
    """
    if chunks.ndim != 3:
        raise ValueError(f"chunks must be (n_chunks, B, d); got "
                         f"{chunks.shape}")
    if weights.shape != chunks.shape[:2]:
        raise ValueError(f"weights {weights.shape} must match chunks' "
                         f"leading dims {chunks.shape[:2]}")
    bk = resolve_backend(backend)
    if key is None:
        key = jax.random.PRNGKey(0)
    state = minibatch_init(c0, cfg, bk)

    def epoch_step(carry, _):
        st, k2 = carry
        k2, sub = jax.random.split(k2)
        st, trace = run_epoch(chunks, weights, x_val, st, cfg, bk, sub)
        return (st, k2), trace

    (state, _), trace = jax.lax.scan(epoch_step, (state, key), None,
                                     length=cfg.epochs)
    c_fin, e_fin, _, _ = guard_pick(x_val, state, cfg, bk)
    result = MiniBatchResult(c_fin, e_fin, state.t, state.n_acc)
    return (result, trace) if return_trace else result


# ---------------------------------------------------------------------------
# Instrumented Python driver (benchmark parity with the paper's tables)
# ---------------------------------------------------------------------------

class KMeansTrace(NamedTuple):
    result: KMeansResult
    energies: list          # E^t per iteration (post-revert)
    m_values: list          # m after adjustment, per iteration
    accepted: list          # bool per iteration
    wall_time_s: float
    mse: float              # final E / N — the paper's reported MSE


def aa_kmeans_traced(x: jax.Array, c0: jax.Array, cfg: KMeansConfig,
                     ops: Optional[LloydOps] = None,
                     jit_iteration: bool = True,
                     backend: BackendLike = None,
                     warmup: bool = False) -> KMeansTrace:
    """Python-loop driver recording the statistics of Tables 2 and 3.

    ``warmup=True`` compiles the init/iteration computations on a throwaway
    run before the timer starts, so ``wall_time_s`` measures steady-state
    execution rather than jit compilation — the quantity the paper's
    Table 3 wall-times report.  (Both jitted functions are keyed on static
    (cfg, backend) and the argument shapes, so the warm-up populates
    exactly the cache the timed loop hits.)
    """
    bk = resolve_backend(backend, ops, cfg)
    iter_fn = _iteration
    if jit_iteration:
        iter_fn = jax.jit(_iteration, static_argnames=("cfg", "backend"))
    init_fn = jax.jit(_init_state, static_argnames=("cfg", "backend")) \
        if jit_iteration else _init_state

    if warmup:
        ws = init_fn(x, c0, cfg, bk)
        ws, _, _, _ = iter_fn(x, ws, cfg, bk)
        jax.block_until_ready(ws.c)

    t0 = time.perf_counter()
    state = init_fn(x, c0, cfg, bk)
    energies, m_vals, acc = [], [], []
    converged = False
    while not converged and int(state.t) < cfg.max_iter:
        state, conv, accepted, e_t = iter_fn(x, state, cfg, bk)
        converged = bool(conv)
        if converged:
            break
        energies.append(float(e_t))
        m_vals.append(int(state.aa.m))
        acc.append(bool(accepted))
    jax.block_until_ready(state.c)
    wall = time.perf_counter() - t0

    n_iter = len(energies) + 1          # +1 for the initial G(C^0)
    n_accepted = sum(acc)
    result = KMeansResult(state.c, state.labels, state.e_last,
                          jnp.array(n_iter), jnp.array(n_accepted),
                          jnp.array(converged))
    mse = float(state.e_last) / x.shape[0]
    return KMeansTrace(result, energies, m_vals, acc, wall, mse)
