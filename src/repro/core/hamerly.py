"""Hamerly-bound Lloyd baseline (Hamerly 2010), vectorised for JAX.

The paper's experiments implement the Assignment-Step with Hamerly's
algorithm: per sample keep an upper bound u_i on the distance to the
assigned centroid and a lower bound l_i on the second-closest; after the
centroids move, bounds are updated by the centroid drift and most samples
skip the O(K) distance scan.

TPU adaptation (DESIGN.md §Hardware-adaptation): bound checks are
data-dependent branches, so a literal port would idle the MXU.  This
implementation is *vectorised-masked*: bounds are maintained exactly and
the full distance row is computed only logically for the failing mask (on
CPU this is where the win lives; on TPU the dense Pallas path is faster and
is the production choice).  We report `scan_fraction` — the fraction of
samples that needed a full scan — which reproduces the paper's premise that
bounds eliminate most distance work, independent of backend.

Equivalence to plain Lloyd is exact (same assignments every iteration);
tests/test_kmeans.py asserts it.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lloyd import pairwise_sqdist, update


class HamerlyState(NamedTuple):
    labels: jax.Array     # (N,)
    upper: jax.Array      # (N,)  upper bound on dist(x, c_label)
    lower: jax.Array      # (N,)  lower bound on dist(x, second closest)
    c: jax.Array          # (K, d)


def _full_scan(x, c):
    """(argmin, min, second-min) of each distance row via two O(K) masked
    min reductions — a full argsort is O(K log K) plus an (N, K) index
    materialisation for three columns of output (same tie convention:
    first index wins, exactly like argmin)."""
    d = jnp.sqrt(pairwise_sqdist(x, c))
    lab = jnp.argmin(d, axis=1).astype(jnp.int32)
    u = jnp.min(d, axis=1)
    k = c.shape[0]
    others = jnp.where(jnp.arange(k)[None, :] == lab[:, None], jnp.inf, d)
    l2 = jnp.min(others, axis=1)
    return lab, u, l2


def hamerly_init(x, c0) -> HamerlyState:
    lab, u, l2 = _full_scan(x, c0)
    return HamerlyState(lab, u, l2, c0)


def hamerly_step(x, state: HamerlyState, k: int):
    """One Lloyd iteration with Hamerly bounds.

    Returns (new_state, changed, scan_fraction)."""
    # s(j): half distance from centroid j to its nearest other centroid
    cc = jnp.sqrt(pairwise_sqdist(state.c, state.c))
    cc = jnp.where(jnp.eye(k, dtype=bool), jnp.inf, cc)
    s_half = 0.5 * jnp.min(cc, axis=1)                       # (K,)

    m = jnp.maximum(s_half[state.labels], state.lower)       # (N,)
    needs1 = state.upper > m
    # tighten u for the candidates: exact distance to assigned centroid
    d_assigned = jnp.sqrt(jnp.sum(
        (x - state.c[state.labels]) ** 2, axis=-1))
    upper_t = jnp.where(needs1, d_assigned, state.upper)
    needs2 = upper_t > m                                     # full scan mask

    lab_f, u_f, l_f = _full_scan(x, state.c)                 # masked result
    labels = jnp.where(needs2, lab_f, state.labels)
    upper = jnp.where(needs2, u_f, upper_t)
    lower = jnp.where(needs2, l_f, state.lower)

    changed = jnp.sum((labels != state.labels).astype(jnp.int32))
    scan_fraction = jnp.mean(needs2.astype(jnp.float32))

    c_new = update(x, labels, k, state.c)
    drift = jnp.sqrt(jnp.sum((c_new - state.c) ** 2, axis=-1))  # (K,)
    upper = upper + drift[labels]
    lower = lower - jnp.max(drift)
    return HamerlyState(labels, upper, lower, c_new), changed, scan_fraction


@partial(jax.jit, static_argnames=("k", "max_iter"))
def hamerly_kmeans(x, c0, k: int, max_iter: int = 500):
    """Lloyd-with-Hamerly-bounds run to convergence.

    Returns (c, labels, energy, n_iter, mean_scan_fraction)."""
    state0 = hamerly_init(x, c0)

    def cond(carry):
        _, changed, t, _ = carry
        # the first step re-derives labels(C0) (always changed == 0); real
        # convergence is "assignment unchanged after a centroid update"
        return jnp.logical_and(jnp.logical_or(changed > 0, t < 2),
                               t < max_iter)

    def body(carry):
        st, _, t, fsum = carry
        st, changed, frac = hamerly_step(x, st, k)
        return st, changed, t + 1, fsum + frac

    st, _, t, fsum = jax.lax.while_loop(
        cond, body, (state0, jnp.array(1, jnp.int32),
                     jnp.array(0, jnp.int32), jnp.array(0.0)))
    diff = x - st.c[st.labels]
    energy = jnp.sum(diff * diff)
    return st.c, st.labels, energy, t, fsum / jnp.maximum(t, 1)
