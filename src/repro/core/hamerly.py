"""Hamerly-bound Lloyd baseline (Hamerly 2010) — thin legacy driver over
the `backends/hamerly.py` bound implementation.

This module predates the backend protocol and used to carry its own copy
of the full-scan/step logic; the two copies drifted once (the PR-5 argsort
fix had to land twice), so the bound math now lives in ONE place —
`repro.core.backends.hamerly` (scan) and `repro.core.backends.bounds`
(drift algebra) — and this file only keeps the historical standalone API:
``hamerly_init`` / ``hamerly_step`` / ``hamerly_kmeans`` returning the
per-iteration ``scan_fraction`` the paper's premise is quoted on.

Equivalence notes:

  * ``hamerly_step`` delegates to the backend's step with a zero-drift
    carry (c_last = the current centroids: this driver applies the drift
    update itself, post-update, exactly as Hamerly's original loop does),
    then updates the centroids and re-drifts the bounds via the shared
    `hamerly_drift` helper.
  * The backend's single-stage scan mask (exact d(x, c_a) > max(s(a), l))
    is exactly the legacy two-stage needs1/needs2 mask: d_a <= u always,
    so "u > m and then the d_a-tightened u > m" collapses to "d_a > m".
    Labels, scan fractions and trajectories are unchanged.

Equivalence to plain Lloyd is exact (same assignments every iteration);
tests/test_kmeans.py asserts it.  For the composable engine — AA driver,
distribution, batching — use ``backend="hamerly"`` (or the group-bound
``elkan``/``yinyang``/``fused_bounds`` engines) instead of this driver.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.backends.bounds import BoundStats
from repro.core.backends.hamerly import (_full_scan, hamerly_backend,
                                         hamerly_drift)
from repro.core.lloyd import update

_BACKEND = hamerly_backend()


class HamerlyState(NamedTuple):
    labels: jax.Array     # (N,)
    upper: jax.Array      # (N,)  upper bound on dist(x, c_label)
    lower: jax.Array      # (N,)  lower bound on dist(x, second closest)
    c: jax.Array          # (K, d)


def hamerly_init(x, c0) -> HamerlyState:
    lab, u, l2 = _full_scan(x, c0)
    return HamerlyState(lab, u, l2, c0)


def hamerly_step(x, state: HamerlyState, k: int):
    """One Lloyd iteration with Hamerly bounds.

    Returns (new_state, changed, scan_fraction)."""
    # The state's bounds are already post-drift (this driver drifts after
    # the update below), so hand the backend a zero-drift carry.
    carry = (state.labels, state.upper, state.lower,
             state.c.astype(jnp.float32), BoundStats.zeros())
    _, carry = _BACKEND.step(x, state.c, k, carry)
    labels, upper, lower, _, stats = carry

    changed = jnp.sum((labels != state.labels).astype(jnp.int32))
    scan_fraction = 1.0 - stats.eliminated_frac

    c_new = update(x, labels, k, state.c)
    upper, lower = hamerly_drift(labels, upper, lower, c_new, state.c)
    return HamerlyState(labels, upper, lower, c_new), changed, scan_fraction


@partial(jax.jit, static_argnames=("k", "max_iter"))
def hamerly_kmeans(x, c0, k: int, max_iter: int = 500):
    """Lloyd-with-Hamerly-bounds run to convergence.

    Returns (c, labels, energy, n_iter, mean_scan_fraction)."""
    state0 = hamerly_init(x, c0)

    def cond(carry):
        _, changed, t, _ = carry
        # the first step re-derives labels(C0) (always changed == 0); real
        # convergence is "assignment unchanged after a centroid update"
        return jnp.logical_and(jnp.logical_or(changed > 0, t < 2),
                               t < max_iter)

    def body(carry):
        st, _, t, fsum = carry
        st, changed, frac = hamerly_step(x, st, k)
        return st, changed, t + 1, fsum + frac

    st, _, t, fsum = jax.lax.while_loop(
        cond, body, (state0, jnp.array(1, jnp.int32),
                     jnp.array(0, jnp.int32), jnp.array(0.0)))
    diff = x - st.c[st.labels]
    energy = jnp.sum(diff * diff)
    return st.c, st.labels, energy, t, fsum / jnp.maximum(t, 1)
