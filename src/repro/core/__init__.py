"""The paper's contribution: Anderson-accelerated K-Means (Algorithm 1).

Public surface:
    AAKMeans              — sklearn-shaped estimator (multi-restart)
    aa_kmeans             — jit-able Algorithm 1 (lax.while_loop)
    aa_kmeans_traced      — instrumented driver (per-iteration stats)
    lloyd_kmeans          — classical Lloyd baseline
    hamerly_kmeans        — Hamerly-bound Lloyd baseline
    KMeansConfig/AAConfig — solver configuration
    make_distributed_kmeans — shard_map multi-pod solver
    get_backend/distribute/Precision — composable step-primitive engine
                            (DESIGN.md §Backends)
"""

from repro.core.anderson import AAConfig                       # noqa: F401
from repro.core.api import AAKMeans                            # noqa: F401
from repro.core.backends import (Backend, Precision,           # noqa: F401
                                 StepResult, distribute, get_backend)
from repro.core.distributed import make_distributed_kmeans    # noqa: F401
from repro.core.hamerly import hamerly_kmeans                  # noqa: F401
from repro.core.kmeans import (KMeansConfig, aa_kmeans,        # noqa: F401
                               aa_kmeans_traced)
from repro.core.lloyd import lloyd_kmeans                      # noqa: F401
