"""The paper's contribution: Anderson-accelerated K-Means (Algorithm 1).

Public surface:
    AAKMeans              — sklearn-shaped estimator (batched multi-restart)
    MiniBatchAAKMeans     — streaming estimator (partial_fit / chunked fit)
    aa_kmeans             — jit-able Algorithm 1 (lax.while_loop)
    aa_kmeans_batched     — R restarts/problems in one device program
    aa_kmeans_minibatch   — streaming chunked driver (DESIGN.md §Streaming)
    aa_kmeans_minibatch_streamed — host-source epoch driver with prefetch
    ReorderConfig/reorder_backend — locality engine (DESIGN.md §Locality)
    select_best           — on-device best-of-R selection
    aa_kmeans_traced      — instrumented driver (per-iteration stats)
    lloyd_kmeans          — classical Lloyd baseline
    hamerly_kmeans        — Hamerly-bound Lloyd baseline
    KMeansConfig/AAConfig/MiniBatchConfig — solver configuration
    make_distributed_kmeans / make_distributed_kmeans_batched /
    make_distributed_kmeans_minibatch
                          — shard_map multi-pod solvers
    get_backend/distribute/Precision — composable step-primitive engine
                            (DESIGN.md §Backends)
"""

from repro.core.anderson import AAConfig                       # noqa: F401
from repro.core.api import AAKMeans, MiniBatchAAKMeans         # noqa: F401
from repro.core.backends import (Backend, Precision,           # noqa: F401
                                 StepResult, distribute, get_backend)
from repro.core.distributed import (make_distributed_kmeans,   # noqa: F401
                                    make_distributed_kmeans_batched,
                                    make_distributed_kmeans_minibatch)
from repro.core.hamerly import hamerly_kmeans                  # noqa: F401
from repro.core.kmeans import (KMeansConfig, aa_kmeans,        # noqa: F401
                               aa_kmeans_batched, aa_kmeans_minibatch,
                               aa_kmeans_minibatch_streamed,
                               aa_kmeans_traced, select_best)
from repro.core.locality import (ReorderConfig,                # noqa: F401
                                 reorder_backend)
from repro.core.lloyd import lloyd_kmeans                      # noqa: F401
from repro.core.minibatch import (MiniBatchConfig,             # noqa: F401
                                  MiniBatchResult)
