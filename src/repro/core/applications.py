"""LM-stack applications of the paper's solver (DESIGN.md §Arch-applicability).

1. `kv_codebook` / `compress_kv_cache` — per-layer K-Means codebooks over
   cached K/V vectors: serving-time cache compression (store int codes +
   (K, hd) codebooks instead of raw vectors).  The clustering problem is
   exactly Eq. (1) over N = B*T*Hkv vectors in R^{hd}, solved with
   Algorithm 1.
2. `embedding_codebook` — product-quantisation of embedding tables: split
   the d dims into sub-blocks, AA-KMeans per sub-block.
3. Both report the quantities the paper's tables track (iterations,
   acceptance rate, MSE) so the LM-side usage doubles as an evaluation of
   the solver on realistic non-synthetic inputs.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import KMeansConfig, aa_kmeans
from repro.core.init_schemes import kmeanspp_init


def kv_codebook(vectors: jax.Array, k: int, *, key=None,
                max_iter: int = 60):
    """Cluster (N, d) vectors; returns (codebook (k,d), codes (N,), res)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    v32 = vectors.astype(jnp.float32)
    c0 = kmeanspp_init(key, v32, k)
    res = aa_kmeans(v32, c0, KMeansConfig(k=k, max_iter=max_iter))
    return res.centroids, res.labels, res


def compress_kv_cache(cache: dict, k: int, valid_len: int) -> Tuple[dict, float]:
    """Replace the K/V caches with their codebook reconstruction.

    Returns the reconstructed cache (same pytree) and the relative L2
    reconstruction error over the valid prefix — the serving-quality
    proxy.  A production path would store (codes, codebook) and gather at
    attention time; here we materialise the reconstruction so the decode
    step is unchanged."""
    def one(x):
        # x: (..., T, Hkv, hd) — cluster the valid prefix vectors per tensor
        lead = x.shape[:-3]
        t, hkv, hd = x.shape[-3:]
        v = x[..., :valid_len, :, :].reshape(-1, hd)
        cb, codes, _ = kv_codebook(v, k)
        rec = cb[codes].reshape(*lead, valid_len, hkv, hd).astype(x.dtype)
        err = (jnp.linalg.norm((rec - x[..., :valid_len, :, :])
                               .astype(jnp.float32))
               / jnp.maximum(jnp.linalg.norm(
                   x[..., :valid_len, :, :].astype(jnp.float32)), 1e-9))
        out = x.at[..., :valid_len, :, :].set(rec)
        return out, err

    new_cache = dict(cache)
    errs = []
    for key_name in ("k", "v"):
        if key_name in cache:
            new_cache[key_name], e = one(cache[key_name])
            errs.append(e)
    err = float(jnp.mean(jnp.stack(errs))) if errs else 0.0
    return new_cache, err


def embedding_codebook(table: jax.Array, k: int, n_subspaces: int = 4,
                       key=None, max_iter: int = 60):
    """Product quantisation of an embedding table (V, d).

    Returns (codebooks (n_sub, k, d/n_sub), codes (V, n_sub), rel_err)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    v, d = table.shape
    assert d % n_subspaces == 0
    sub = d // n_subspaces
    t32 = table.astype(jnp.float32).reshape(v, n_subspaces, sub)
    cbs, codes = [], []
    for j in range(n_subspaces):
        key, k1 = jax.random.split(key)
        block = t32[:, j, :]
        c0 = kmeanspp_init(k1, block, k)
        res = aa_kmeans(block, c0, KMeansConfig(k=k, max_iter=max_iter))
        cbs.append(res.centroids)
        codes.append(res.labels)
    cbs = jnp.stack(cbs)                      # (n_sub, k, sub)
    codes = jnp.stack(codes, axis=1)          # (V, n_sub)
    rec = jnp.stack([cbs[j][codes[:, j]] for j in range(n_subspaces)], 1)
    err = float(jnp.linalg.norm(rec - t32)
                / jnp.maximum(jnp.linalg.norm(t32), 1e-9))
    return cbs, codes, err
