"""LM-stack applications of the paper's solver (DESIGN.md §Arch-applicability).

1. `kv_codebook` / `kv_codebooks_batched` / `compress_kv_cache` — per-layer
   K-Means codebooks over cached K/V vectors: serving-time cache compression
   (store int codes + (K, hd) codebooks instead of raw vectors).  The
   clustering problem is exactly Eq. (1) over N = B*T*Hkv vectors in R^{hd},
   solved with Algorithm 1.  Every same-shape group of tensors (the K and V
   caches, or many layers' caches) is solved as ONE batched device program
   (kmeans.aa_kmeans_batched) instead of a Python loop of solves — the
   serving path's concurrency lever.
2. `embedding_codebook` — product-quantisation of embedding tables: split
   the d dims into sub-blocks, AA-KMeans over all sub-blocks in one batch.
3. All report the quantities the paper's tables track (iterations,
   acceptance rate, MSE) so the LM-side usage doubles as an evaluation of
   the solver on realistic non-synthetic inputs.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import (KMeansConfig, aa_kmeans, aa_kmeans_batched)
from repro.core.init_schemes import kmeanspp_init


def kv_codebook(vectors: jax.Array, k: int, *, key=None,
                max_iter: int = 60):
    """Cluster (N, d) vectors; returns (codebook (k,d), codes (N,), res)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    v32 = vectors.astype(jnp.float32)
    c0 = kmeanspp_init(key, v32, k)
    res = aa_kmeans(v32, c0, KMeansConfig(k=k, max_iter=max_iter))
    return res.centroids, res.labels, res


# Module-level so the jit cache persists across calls: a serving loop
# compressing cache after cache pays trace+compile once per (shape, k,
# max_iter, backend), not once per request.
@partial(jax.jit, static_argnames=("k", "max_iter", "backend"))
def _codebooks_solve(vectors, key, k, max_iter, backend):
    v32 = vectors.astype(jnp.float32)
    keys = jax.random.split(key, v32.shape[0])
    c0s = jax.vmap(lambda kk, vv: kmeanspp_init(kk, vv, k))(keys, v32)
    return aa_kmeans_batched(v32, c0s, KMeansConfig(k=k, max_iter=max_iter),
                             backend=backend)


def kv_codebooks_batched(vectors: jax.Array, k: int, *, key=None,
                         max_iter: int = 60, backend=None):
    """Cluster B same-shape vector sets (B, N, d) in ONE device program.

    Seeding (vmapped K-Means++ over a keys axis) and the B solves all run
    inside a single jit call; per-problem convergence is masked, so early
    finishers do not stall the batch.  Returns (codebooks (B,k,d),
    codes (B,N), res) with a leading problem axis on every leaf."""
    if vectors.ndim != 3:
        raise ValueError(
            f"kv_codebooks_batched expects (B, N, d); got {vectors.shape}")
    key = key if key is not None else jax.random.PRNGKey(0)
    res = _codebooks_solve(vectors, key, k, max_iter, backend)
    return res.centroids, res.labels, res


def kv_codebook_hierarchical(vectors: jax.Array, k: int, *, seed: int = 0,
                             max_iter: int = 60, n_groups=None,
                             n_reassign: int = 1, backend=None):
    """`kv_codebook` for codebooks too large to solve flat — the
    65k-and-beyond PQ/cache regime (DESIGN.md §Hierarchy).

    Flat `kv_codebook` materialises O(N·K) distance work per pass; at
    K = 2^16 a serving-side codebook refresh stops being "trivia next to
    the forward pass".  This variant routes through
    `repro.core.hierarchy.aa_kmeans_hierarchical` (G ≈ √K super-clusters,
    all sub-problems one batched AA program), returning the same
    ``(codebook (k, d), codes (N,), res)`` triple — ``codes`` are global
    codebook rows in original vector order, so reconstruction is still
    ``codebook[codes]`` — plus the two-level routing structure on ``res``
    for a free serving index (`serving.closure.hierarchy_closure_index`).
    """
    from repro.core.hierarchy import aa_kmeans_hierarchical
    v32 = vectors.astype(jnp.float32)
    res = aa_kmeans_hierarchical(
        v32, k, KMeansConfig(k=k, max_iter=max_iter), backend=backend,
        n_groups=n_groups, n_reassign=n_reassign, seed=seed)
    return res.centroids, res.labels, res


def compress_kv_cache(cache: dict, k: int, valid_len: int) -> Tuple[dict, float]:
    """Replace the K/V caches with their codebook reconstruction.

    Returns the reconstructed cache (same pytree) and the relative L2
    reconstruction error over the valid prefix — the serving-quality
    proxy.  A production path would store (codes, codebook) and gather at
    attention time; here we materialise the reconstruction so the decode
    step is unchanged.  The K and V tensors (same shape by construction)
    are clustered as one batched solve rather than two sequential ones."""
    names = [n for n in ("k", "v") if n in cache]
    new_cache = dict(cache)
    if not names:
        return new_cache, 0.0

    def flatten(x):
        # x: (..., T, Hkv, hd) — the valid prefix vectors of one tensor
        hd = x.shape[-1]
        return x[..., :valid_len, :, :].reshape(-1, hd)

    if len({cache[n].shape for n in names}) == 1:
        # the common (MHA/GQA) layout: K and V share a shape, so both
        # clustering problems solve as one batched program
        stacked = jnp.stack([flatten(cache[n]) for n in names])  # (B,N,hd)
        cbs, codes, _ = kv_codebooks_batched(stacked, k)
        solved = {n: (cbs[i], codes[i]) for i, n in enumerate(names)}
    else:
        # asymmetric caches (e.g. MLA-style differing head dims) cannot
        # share a batch; cluster each tensor independently as before
        solved = {}
        for n in names:
            cb, cd, _ = kv_codebook(flatten(cache[n]), k)
            solved[n] = (cb, cd)

    errs = []
    for n in names:
        x = cache[n]
        cb, cd = solved[n]
        lead = x.shape[:-3]
        hkv, hd = x.shape[-2], x.shape[-1]
        rec = cb[cd].reshape(*lead, valid_len, hkv, hd).astype(x.dtype)
        err = (jnp.linalg.norm((rec - x[..., :valid_len, :, :])
                               .astype(jnp.float32))
               / jnp.maximum(jnp.linalg.norm(
                   x[..., :valid_len, :, :].astype(jnp.float32)), 1e-9))
        new_cache[n] = x.at[..., :valid_len, :, :].set(rec)
        errs.append(err)
    return new_cache, float(jnp.mean(jnp.stack(errs)))


def embedding_codebook(table: jax.Array, k: int, n_subspaces: int = 4,
                       key=None, max_iter: int = 60):
    """Product quantisation of an embedding table (V, d).

    All ``n_subspaces`` sub-block clusterings solve as one batched program
    (the sub-blocks share (V, d/n_subspaces) and K — the (R, N, d)
    problem-axis case of the batched engine).

    Returns (codebooks (n_sub, k, d/n_sub), codes (V, n_sub), rel_err)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    v, d = table.shape
    assert d % n_subspaces == 0
    sub = d // n_subspaces
    # (n_sub, V, sub): one clustering problem per subspace
    blocks = table.astype(jnp.float32).reshape(v, n_subspaces, sub) \
        .transpose(1, 0, 2)
    cbs, codes_b, _ = kv_codebooks_batched(blocks, k, key=key,
                                           max_iter=max_iter)
    codes = codes_b.T                          # (V, n_sub)
    rec = jnp.stack([cbs[j][codes[:, j]] for j in range(n_subspaces)], 1)
    t32 = blocks.transpose(1, 0, 2)
    err = float(jnp.linalg.norm(rec - t32)
                / jnp.maximum(jnp.linalg.norm(t32), 1e-9))
    return cbs, codes, err
