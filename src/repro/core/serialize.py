"""Versioned solver-state serialisation (DESIGN.md §Persistence).

Every solver in this repo keeps its whole trajectory-defining state in a
pytree of fixed-shape arrays (`_LoopState`/`AAState`, `_BatchedState`,
`MiniBatchState`) precisely so it can live inside `lax.while_loop`/`scan`.
This module is the other payoff of that discipline: any such state tree
snapshots to ONE host-side artifact and restores bit-exactly, so a solve
can outlive a device lease.

Artifact format — a single ``.npz`` file, no pickle anywhere:

  * each leaf is stored as an ``npy`` member ``a<i>`` (ml_dtypes leaves
    such as bfloat16 round-trip through a same-width view; the true dtype
    is recorded in the metadata and re-viewed on load);
  * member ``__meta__`` is a msgpack blob: ``schema`` (format version),
    ``kind`` (which state tree this is), per-leaf ``path/shape/dtype``,
    plus caller metadata (iteration count, k, backend name, ...).

Restores go *into* a caller-provided "like" tree (normally built with
``jax.eval_shape`` over the solver's own init function, so the structure
can never drift from the code), with shape checking per leaf.  Arrays are
stored UNSHARDED — ``jax.device_get`` gathers across any mesh — so a
checkpoint taken under one mesh layout restores onto any other: elastic
resume is a ``device_put`` with the new shardings (core/distributed.py).

Schema evolution contract: ``SCHEMA_VERSION`` bumps whenever a state
tree's meaning changes (not merely its nesting — structure is checked
against the like tree anyway); ``load`` refuses artifacts from a NEWER
schema, and OLDER artifacts are upgraded in-memory by per-kind migration
functions (`register_migration`) applied schema-by-schema until the
artifact matches ``SCHEMA_VERSION`` — an old snapshot either restores
correctly or fails loudly naming the missing migration; it never
restores wrong.
"""

from __future__ import annotations

import os
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

import msgpack

SCHEMA_VERSION = 1

# Registered state kinds (informational; `load` checks the caller's
# expectation, not membership, so downstream layers can add kinds).
KIND_LOOP = "loop_state"             # kmeans._LoopState
KIND_BATCHED = "batched_state"       # kmeans._BatchedState
KIND_MINIBATCH = "minibatch_stream"  # {"state": MiniBatchState, "key",...}
KIND_HIERARCHY = "hierarchy_state"   # hierarchy round state (core/hierarchy)
KIND_ESTIMATOR_AA = "estimator/aa_kmeans"
KIND_ESTIMATOR_MB = "estimator/minibatch_aa_kmeans"

PyTree = Any

# -- schema migrations (DESIGN.md §Persistence) ------------------------------
#
# {(kind, from_schema): migrate} where ``migrate(meta, by_path)`` returns
# the (meta, by_path) pair upgraded to ``from_schema + 1`` — rename/add/
# drop leaf paths in ``by_path`` and adjust ``meta`` accordingly.  `load`
# chains these until the artifact reaches SCHEMA_VERSION, so each bump
# needs exactly one migration per affected kind, written once, at the
# bump.  Unaffected kinds need none: the identity chain is implied only
# when a migration IS registered for the (kind, schema) step; a gap means
# the artifact cannot be interpreted and `load` fails loudly.
_MIGRATIONS: dict = {}


def register_migration(kind: str, from_schema: int, fn) -> None:
    """Register ``fn(meta, by_path) -> (meta, by_path)`` upgrading
    ``kind`` artifacts from ``from_schema`` to ``from_schema + 1``."""
    _MIGRATIONS[(kind, int(from_schema))] = fn


def unregister_migration(kind: str, from_schema: int) -> None:
    _MIGRATIONS.pop((kind, int(from_schema)), None)


def _migrate(path, meta: dict, by_path: dict):
    """Chain registered migrations until ``meta['schema']`` reaches
    SCHEMA_VERSION; loud failure when a step has no migration."""
    while meta["schema"] < SCHEMA_VERSION:
        step = (meta.get("kind"), meta["schema"])
        fn = _MIGRATIONS.get(step)
        if fn is None:
            raise ValueError(
                f"{path}: artifact schema {meta['schema']} predates this "
                f"code's {SCHEMA_VERSION} and no migration is registered "
                f"for kind {meta.get('kind')!r} at schema "
                f"{meta['schema']} — refusing to guess at the old layout")
        meta, by_path = fn(dict(meta), dict(by_path))
        if meta["schema"] <= step[1]:
            meta["schema"] = step[1] + 1    # migrations may omit the bump
    return meta, by_path


def _key_name(k) -> str:
    for attr in ("key", "name", "idx"):
        v = getattr(k, attr, None)
        if v is not None:
            return str(v)
    return str(k)


def flatten_with_paths(tree: PyTree):
    """Flatten a pytree to (slash-joined path strings, leaves, treedef)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(_key_name(k) for k in path) for path, _ in flat]
    return paths, [leaf for _, leaf in flat], treedef


def _to_storable(a: np.ndarray) -> Tuple[np.ndarray, str]:
    """(array numpy can round-trip without pickle, true dtype string).

    npy files preserve standard dtypes; extension dtypes (bfloat16 &
    friends from ml_dtypes) come back as void — store them as-is (the
    bytes survive) and record the dtype string so `load` can re-view."""
    if a.dtype.hasobject:
        raise TypeError(
            f"refusing to serialise object-dtype leaf (shape {a.shape}); "
            f"snapshot trees must contain only numeric arrays")
    return a, str(a.dtype)


def _from_storable(a: np.ndarray, dtype_str: str) -> np.ndarray:
    want = np.dtype(dtype_str)
    if a.dtype == want:
        return a
    # extension dtype stored as void of the same width: re-view the bits
    if a.dtype.kind == "V" and a.dtype.itemsize == want.itemsize:
        return a.view(want)
    return a.astype(want)


def save(path: str | os.PathLike, tree: PyTree, *, kind: str,
         extra: Optional[dict] = None) -> Path:
    """Atomically write ``tree`` to ``path`` as a version-tagged npz.

    Leaves are gathered to host (`jax.device_get` — works for sharded
    arrays on any mesh).  ``extra`` is msgpack-serialisable caller
    metadata merged into the artifact's meta block.  Returns the final
    path (``.npz`` appended if missing)."""
    path = Path(path)
    if path.suffix != ".npz":
        path = path.with_suffix(path.suffix + ".npz")
    paths, leaves, _ = flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    stored, meta_leaves = [], []
    for p, a in zip(paths, host):
        s, dt = _to_storable(a)
        stored.append(s)
        meta_leaves.append({"path": p, "shape": list(a.shape), "dtype": dt})
    meta = {"schema": SCHEMA_VERSION, "kind": kind,
            "leaves": meta_leaves, **(extra or {})}
    blob = np.frombuffer(msgpack.packb(meta), dtype=np.uint8)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez(f, __meta__=blob,
                 **{f"a{i}": a for i, a in enumerate(stored)})
    os.replace(tmp, path)   # a crash mid-write never corrupts an artifact
    return path


def load(path: str | os.PathLike, *, expect_kind: Optional[str] = None):
    """Read an artifact -> (meta dict, {leaf path: host array}).

    Validates the schema version (a NEWER schema than this code knows is
    refused — forward compatibility is never silent; an OLDER one is
    upgraded through registered migrations, failing loudly when a step
    is unregistered) and, when ``expect_kind`` is given, that the
    artifact holds that state kind."""
    path = Path(path)
    with np.load(path, allow_pickle=False) as z:
        meta = msgpack.unpackb(bytes(z["__meta__"].tobytes()))
        arrays = [z[f"a{i}"] for i in range(len(meta["leaves"]))]
    schema = meta.get("schema")
    if not isinstance(schema, int) or schema > SCHEMA_VERSION:
        raise ValueError(
            f"{path}: artifact schema {schema!r} is newer than this "
            f"code's {SCHEMA_VERSION}; upgrade before restoring")
    if expect_kind is not None and meta.get("kind") != expect_kind:
        raise ValueError(
            f"{path}: artifact holds {meta.get('kind')!r} state, "
            f"expected {expect_kind!r}")
    by_path = {m["path"]: _from_storable(a, m["dtype"])
               for m, a in zip(meta["leaves"], arrays)}
    if schema < SCHEMA_VERSION:
        meta, by_path = _migrate(path, meta, by_path)
    return meta, by_path


def restore(path: str | os.PathLike, like: PyTree, *,
            expect_kind: Optional[str] = None):
    """Restore an artifact into the structure of ``like``.

    ``like`` is a pytree of arrays or ShapeDtypeStructs — build it with
    ``jax.eval_shape`` over the solver's init so the expected structure
    is derived from the code, never hand-maintained.  Every leaf is
    shape-checked and cast to the like leaf's dtype (a no-op on a
    faithful round-trip).  Returns (tree of host numpy arrays, meta)."""
    meta, by_path = load(path, expect_kind=expect_kind)
    want_paths, want_leaves, treedef = flatten_with_paths(like)
    missing = [p for p in want_paths if p not in by_path]
    if missing:
        raise ValueError(
            f"{path}: artifact is missing leaves {missing[:5]} "
            f"({len(missing)} of {len(want_paths)}) — was it saved from a "
            f"different backend or solver configuration?")
    out = []
    for p, w in zip(want_paths, want_leaves):
        a = by_path[p]
        if tuple(a.shape) != tuple(w.shape):
            raise ValueError(
                f"{path}: shape mismatch at {p}: artifact {a.shape} vs "
                f"expected {tuple(w.shape)} — restore must target the "
                f"same (N, K, d) problem the snapshot came from")
        out.append(np.asarray(a, dtype=w.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), meta
