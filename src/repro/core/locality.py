"""Cluster-sorted row reordering — the locality engine (DESIGN.md §Locality).

Tile-granular work elimination (the fused_bounds kernel's skip predicate,
Elkan/Yinyang group bounds) only pays when neighbouring rows share owners:
on cluster-ordered rows the converged-phase skip is ~0.75, on interleaved
`make_blobs` rows it is ~0.  This module closes that gap by *sorting rows
by their current label* once assignments stabilise, running the bound
backend on the permuted X, and inverting the permutation on exit so the
emitted labels/energies are bit-identical to the unpermuted solve.

The permutation lives INSIDE the backend carry, as a wrapper Backend:

    carry = (perm, inv, labels_sort, t, n_sorts, inner_carry)

    perm        (N,) i32  row at sorted slot j came from original row perm[j]
    inv         (N,) i32  original row i now lives at sorted slot inv[i]
    labels_sort (N,) i32  original-order labels at the time of the last sort
                          (zeros before the first sort — any real labelling
                          churns ~1 against it, so the first eligible step
                          always sorts)
    t           ()   i32  steps taken (warm-up gate)
    n_sorts     ()   i32  sorts performed (churn-trigger observability)
    inner_carry           the wrapped backend's bound carry — the shared
                          (labels, upper, lower, c_last, stats) contract of
                          backends/bounds.py, all per-row arrays in
                          *permuted* order

Because the carry rides the drivers' loop state, checkpoint persistence,
bit-identical resume, and the batched driver all come for free — the
PR-5 artifact serialises the permutation like any other carry leaf.

Exactness: every per-row quantity the bound backends compute (labels,
upper/lower bounds, min_sqdist) is row-local, so permuting rows permutes
the outputs bitwise.  The wrapper re-gathers labels/min_sqdist back to
original order and RECOMPUTES sums/counts/energy from the original-order
arrays — the exact expressions the unwrapped CPU bound backends use — so
reordering never perturbs the AA accept/revert trajectory.  The price is
one (N, d) gather of X per step; the win is the converged tail where the
kernel skips the majority of centroid tiles.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import lloyd
from .backends.base import Backend, StepResult


@dataclasses.dataclass(frozen=True)
class ReorderConfig:
    """Churn-triggered re-sort policy (hashable: rides jit static args).

    warmup          — steps before the first sort may fire.  The early
                      iterations churn heavily (sorting would thrash) and
                      bound upkeep has not tightened yet; the default skips
                      the init step plus one full scan.
    churn_threshold — re-sort when the fraction of rows whose label changed
                      since the LAST sort exceeds this.  0 re-sorts on any
                      drift; >= 1 never re-sorts after the first.
    sort_tile       — static label-tile width of the counting sort's rank
                      pass (None: sized so the transient one-hot stays
                      ~16 MB; see `counting_sort_perm`).
    """
    warmup: int = 2
    churn_threshold: float = 0.15
    sort_tile: Optional[int] = None


DEFAULT_REORDER = ReorderConfig()


# ---------------------------------------------------------------------------
# Stable counting sort (no argsort on the hot path)
# ---------------------------------------------------------------------------


def _rank_tile(n: int, k: int, sort_tile) -> int:
    if sort_tile is not None:
        return max(1, min(k, int(sort_tile)))
    return max(1, min(k, (1 << 22) // max(n, 1)))


def counting_sort_perm(labels: jax.Array, k: int, *, sort_tile=None):
    """Stable counting sort of rows by label via segment offsets.

    Returns ``(perm, inv)``: sorted slot j holds original row ``perm[j]``;
    original row i lands at sorted slot ``inv[i]``.  Rows sharing a label
    keep their original relative order (stability), so the result matches
    ``np.argsort(labels, kind="stable")``.

    O(N·K) work but NO O(N log N) argsort and no data-dependent control
    flow: counts by scatter-add, segment offsets by exclusive cumsum, and
    within-label ranks by a label-tiled one-hot column cumsum whose
    transient (N, sort_tile) buffer is bounded by the static tile width.
    """
    n = labels.shape[0]
    labels = labels.astype(jnp.int32)
    counts = jnp.zeros((k,), jnp.int32).at[labels].add(1)
    offsets = jnp.cumsum(counts) - counts          # exclusive segment starts
    rank = label_ranks(labels, k, sort_tile=sort_tile)
    inv = offsets[labels] + rank
    # inv is a permutation of arange(n), so the scatter-set is exact
    perm = jnp.zeros((n,), jnp.int32).at[inv].set(
        jnp.arange(n, dtype=jnp.int32))
    return perm, inv


def label_ranks(labels: jax.Array, k: int, *, sort_tile=None) -> jax.Array:
    """Within-label stable ranks: rank[i] = #{j < i : labels[j] == labels[i]}.

    The counting sorts' shared inner pass, exposed for segmented callers:
    a label-tiled one-hot column cumsum whose transient (N, sort_tile)
    buffer is bounded by the static tile width — no argsort, no
    data-dependent control flow.
    """
    n = labels.shape[0]
    labels = labels.astype(jnp.int32)
    t = _rank_tile(n, k, sort_tile)

    def body(i, rank):
        ids = i * t + jnp.arange(t, dtype=jnp.int32)
        hit = labels[:, None] == ids[None, :]       # (N, t) one-hot slice
        before = jnp.cumsum(hit.astype(jnp.int32), axis=0) - hit
        return rank + jnp.sum(jnp.where(hit, before, 0), axis=1)

    return lax.fori_loop(0, -(-k // t), body, jnp.zeros((n,), jnp.int32))


def counting_sort_perm_segmented(labels: jax.Array, k: int,
                                 offsets: jax.Array, out_size: int, *,
                                 sort_tile=None):
    """Stable counting sort against a CALLER-SUPPLIED segment-offset table.

    Where `counting_sort_perm` packs segments tightly (offsets = exclusive
    cumsum of the counts), this variant scatters label-l rows to
    consecutive slots starting at ``offsets[l]`` in an output of static
    length ``out_size`` — the primitive behind (a) the hierarchy engine's
    partition step, where offsets = arange(G) * N_max lays every
    super-cluster's rows into its own padded stripe, and (b) distribute()
    shards sorting against a SHARED centroid order so tiles align across
    shards (each shard passes the same offset table; DESIGN.md §Locality).

    Returns ``(perm, inv, counts)``:

        perm   (out_size,) i32 — slot j holds original row perm[j], or the
               sentinel N (= labels.shape[0]) for unfilled slots, so a
               gather from X padded with one trailing sentinel row yields
               the padding rows directly;
        inv    (N,) i32 — original row i lands at slot inv[i];
        counts (k,)  i32 — per-label row counts (segment fill levels).

    The caller guarantees capacity: segment l must have room for
    counts[l] rows before the next offset (overflowing rows are silently
    DROPPED by JAX's out-of-bounds scatter rule — check counts when the
    offsets are not derived from the data).  Rows sharing a label keep
    their original relative order (stability), like `counting_sort_perm`.
    """
    n = labels.shape[0]
    labels = labels.astype(jnp.int32)
    counts = jnp.zeros((k,), jnp.int32).at[labels].add(1)
    rank = label_ranks(labels, k, sort_tile=sort_tile)
    inv = offsets.astype(jnp.int32)[labels] + rank
    perm = jnp.full((out_size,), n, jnp.int32).at[inv].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    return perm, inv, counts


def churn_frac(labels_new: jax.Array, labels_ref: jax.Array) -> jax.Array:
    """Fraction of rows whose label differs between two assignments."""
    return jnp.mean((labels_new != labels_ref).astype(jnp.float32))


def permute_bound_carry(carry, idx: jax.Array):
    """Re-gather the per-row leaves of a bounds.py carry by ``idx``.

    ``idx[j]`` is the OLD position whose state lands at new position j —
    labels/upper/lower move in lockstep with the rows; c_last and the
    BoundStats are row-free and pass through untouched.
    """
    labels, upper, lower, c_last, stats = carry
    return (jnp.take(labels, idx, axis=0),
            jnp.take(upper, idx, axis=0),
            jnp.take(lower, idx, axis=0),
            c_last, stats)


# ---------------------------------------------------------------------------
# Reorder carry accessors (tests / drivers peek without tuple-index magic)
# ---------------------------------------------------------------------------


def permutation(carry) -> jax.Array:
    return carry[0]


def sort_count(carry) -> jax.Array:
    return carry[4]


def inner_carry(carry):
    return carry[5]


# ---------------------------------------------------------------------------
# The wrapper backend
# ---------------------------------------------------------------------------


def _require_bound_carry(carry, n: int) -> None:
    ok = isinstance(carry, tuple) and len(carry) == 5
    if ok:
        ok = all(getattr(carry[i], "shape", (None,))[:1] == (n,)
                 for i in range(3))
    if not ok:
        raise TypeError(
            "reorder_backend wraps bound-carrying backends only: the inner "
            "carry must be the (labels, upper, lower, c_last, stats) "
            "contract of backends/bounds.py with leading-N per-row arrays "
            f"(got {type(carry).__name__})")


@functools.lru_cache(maxsize=None)
def reorder_backend(inner: Backend,
                    config: ReorderConfig = DEFAULT_REORDER) -> Backend:
    """Wrap a bound-carrying backend with churn-triggered row reordering.

    The wrapped backend is a drop-in Backend: same step contract, same
    original-order outputs, conformance-matrix exact.  Compose INSIDE
    `distribute` — ``distribute(reorder_backend(b), axes)`` — so the sort
    stays shard-local (no collective) and the wrapper's shard-local stats
    are the ones psum-reduced.

    Cached per (inner, config): repeated resolution returns the identical
    instance, keeping jit static-argument caching effective.
    """
    if inner.axes:
        raise ValueError(
            f"{inner.name} is already distributed; wrap the local backend "
            "first — distribute(reorder_backend(b), axes) — so the "
            "permutation stays shard-local")
    warmup = int(config.warmup)
    threshold = float(config.churn_threshold)
    acc = inner.precision.accum_dtype

    def init_carry_fn(x, c, k):
        ic = inner.init_carry_fn(x, c, k)
        n = x.shape[-2]
        _require_bound_carry(ic, n)
        ar = jnp.arange(n, dtype=jnp.int32)
        return (ar, ar, jnp.zeros((n,), jnp.int32),
                jnp.int32(0), jnp.int32(0), ic)

    def _pre(x, k, carry):
        """Maybe re-sort, then gather X into permuted order."""
        perm, inv, labels_sort, t, n_sorts, ic = carry
        labels_prev = jnp.take(ic[0], inv, axis=0)      # original order
        do_sort = jnp.logical_and(
            t >= warmup, churn_frac(labels_prev, labels_sort) > threshold)

        def resort(args):
            _, inv_old, _, ic_old = args
            perm_new, inv_new = counting_sort_perm(
                labels_prev, k, sort_tile=config.sort_tile)
            # new slot j holds original row perm_new[j], whose carry state
            # currently sits at old slot inv_old[perm_new[j]]
            idx = jnp.take(inv_old, perm_new, axis=0)
            return (perm_new, inv_new, labels_prev,
                    permute_bound_carry(ic_old, idx))

        perm, inv, labels_sort, ic = lax.cond(
            do_sort, resort, lambda args: args, (perm, inv, labels_sort, ic))
        n_sorts = n_sorts + do_sort.astype(jnp.int32)
        xp = jnp.take(x, perm, axis=0)      # the one X gather per step
        return xp, (perm, inv, labels_sort, t, n_sorts, ic)

    def _post(x, k, carry, res_p, ic_new):
        """Invert the permutation and recompute order-invariant stats."""
        perm, inv, labels_sort, t, n_sorts, _ = carry
        labels = jnp.take(res_p.labels, inv, axis=0)
        mind = jnp.take(res_p.min_sqdist, inv, axis=0)
        # original-order recomputation: bitwise-equal to the unwrapped CPU
        # bound backends' own expressions, and independent of the current
        # permutation (DESIGN.md §Locality)
        sums, counts = lloyd.cluster_sums(x.astype(acc), labels, k)
        energy = jnp.sum(mind)
        return (StepResult(labels, mind, sums, counts, energy),
                (perm, inv, labels_sort, t + 1, n_sorts, ic_new))

    def step_fn(x, c, k, carry):
        xp, carry = _pre(x, k, carry)
        res_p, ic = inner.step_fn(xp, c, k, carry[5])
        return _post(x, k, carry, res_p, ic)

    def batched_step_fn(x, cs, k, carries, w=None):
        # per-restart permutations; x may be shared (N, d) or per-problem
        # (R, N, d).  The sort/gather bookkeeping vmaps (lax.cond lowers to
        # a select under vmap, so batched restarts pay the sort every step
        # once warm — the correctness path; see DESIGN.md §Locality), while
        # the inner step keeps its native batched kernel on the gathered
        # (R, N, d) X.
        if w is not None:
            raise TypeError(
                "reorder_backend has no weighted batched path: _post "
                "recomputes unweighted stats in original row order.  Use "
                "an unwrapped backend for weighted/hierarchical batched "
                "solves — the hierarchy engine's padded segments are "
                "already contiguous by construction, so reordering would "
                "buy nothing there anyway")
        xb = x.ndim == 3
        xp, carries = jax.vmap(
            lambda xx, cr: _pre(xx, k, cr),
            in_axes=(0 if xb else None, 0))(x, carries)
        res_p, ics = inner.batched_step(xp, cs, k, carries[5],
                                        x_batched=True)
        return jax.vmap(
            lambda xx, cr, rp, icn: _post(xx, k, cr, rp, icn),
            in_axes=(0 if xb else None, 0, 0, 0))(x, carries, res_p, ics)

    return Backend(name=f"{inner.name}+reorder",
                   step_fn=step_fn,
                   batched_step_fn=batched_step_fn,
                   # no minibatch_step_fn: carries re-init per chunk, so the
                   # warm-up gate never opens — chunk locality comes from
                   # stream_chunks(sort_by=...) instead.  The generic
                   # weighted fallback (original-order x + labels) is exact.
                   stats_fn=inner.stats_fn,
                   assign_fn=inner.assign_fn,
                   energy_fn=inner.energy_fn,
                   all_equal_fn=inner.all_equal_fn,
                   init_carry_fn=init_carry_fn,
                   finalize_fn=inner.finalize_fn,
                   precision=inner.precision)


def maybe_reorder(backend: Backend, reorder) -> Backend:
    """Driver-facing switch: False → untouched; True → default policy;
    a ReorderConfig → that policy."""
    if not reorder:
        return backend
    cfg = reorder if isinstance(reorder, ReorderConfig) else DEFAULT_REORDER
    return reorder_backend(backend, cfg)
