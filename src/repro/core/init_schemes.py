"""Centroid initialization schemes used in the paper's Table 3.

The paper evaluates robustness of AA-KMeans under four seedings:
K-Means++ (Arthur & Vassilvitskii 2007), afk-mc^2 (Bachem et al. 2016),
bf (Bradley & Fayyad 1998) and CLARANS (Newling & Fleuret 2017).  The paper
uses external code to generate seeds; here each scheme is implemented from
scratch in JAX so the whole pipeline is self-contained (system prompt:
"If the paper compares against a baseline, implement the baseline too").

All schemes are deterministic given a PRNG key and jit-able except CLARANS
(whose swap-acceptance loop is inherently sequential; it runs as a Python
loop over jitted cost evaluations).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.lloyd import assign, pairwise_sqdist


def _validate_seeding(x: jax.Array, k: int, scheme: str) -> None:
    """Reject degenerate requests with a clear error instead of the opaque
    gather/concatenate failures the schemes otherwise die with.  Shape-only,
    so it is safe at trace time (inside jit and under vmap)."""
    if x.ndim < 2:
        raise ValueError(
            f"{scheme}: x must be (N, d); got shape {tuple(x.shape)}")
    n = x.shape[0]
    if k < 1:
        raise ValueError(f"{scheme}: need at least one cluster; got k={k}")
    if k > n:
        raise ValueError(
            f"{scheme}: cannot seed k={k} centroids from only n={n} "
            f"samples; need k <= n")


def random_init(key: jax.Array, x: jax.Array, k: int,
                w=None) -> jax.Array:
    """Uniformly sample K distinct rows of X.  ``w`` (N,) >= 0 biases the
    draw (p ∝ w) — a zero-weight (padding) row is never picked."""
    _validate_seeding(x, k, "random_init")
    p = None if w is None else w / jnp.maximum(jnp.sum(w), 1e-30)
    idx = jax.random.choice(key, x.shape[0], (k,), replace=False, p=p)
    return x[idx]


@partial(jax.jit, static_argnames=("k",))
def kmeanspp_init(key: jax.Array, x: jax.Array, k: int,
                  w=None) -> jax.Array:
    """K-Means++: D^2-weighted sequential sampling.

    ``w`` (N,) >= 0 makes the sampling SEGMENT-AWARE: the first pick
    draws p ∝ w and every D^2 round draws p ∝ w·D^2, so a zero-weight
    row — the hierarchy engine's segment padding — is never seeded
    (DESIGN.md §Hierarchy).  ``w=None`` keeps the classic scheme
    bit-for-bit (the unweighted draws use different PRNG primitives, so
    ``w=ones`` is distributionally equal but not bitwise)."""
    _validate_seeding(x, k, "kmeanspp_init")
    n = x.shape[0]
    key, sub = jax.random.split(key)
    if w is None:
        first = jax.random.randint(sub, (), 0, n)
    else:
        w = w.astype(jnp.float32)
        first = jax.random.categorical(
            sub, jnp.log(jnp.maximum(w / jnp.maximum(jnp.sum(w), 1e-30),
                                     1e-38)))
    c0 = x[first]
    mind = jnp.sum((x - c0) ** 2, axis=-1)

    def body(carry, key_t):
        mind, _ = carry
        # Sample proportional to (w ·) D^2.  Weighted all-zero corner
        # (every live row already a centroid): fall back to w itself so
        # padding rows stay unseedable; unweighted keeps the classic
        # uniform fallback via the clamp below.
        if w is None:
            score = mind
        else:
            s = mind * w
            score = jnp.where(jnp.sum(s) > 0, s, w)
        p = score / jnp.maximum(jnp.sum(score), 1e-30)
        idx = jax.random.categorical(key_t, jnp.log(jnp.maximum(p, 1e-38)))
        c_new = x[idx]
        d_new = jnp.sum((x - c_new) ** 2, axis=-1)
        mind = jnp.minimum(mind, d_new)
        return (mind, idx), c_new

    keys = jax.random.split(key, k - 1)
    (_, _), rest = jax.lax.scan(body, (mind, first), keys)
    return jnp.concatenate([c0[None], rest], axis=0)


@partial(jax.jit, static_argnames=("k", "chain_length"))
def afkmc2_init(key: jax.Array, x: jax.Array, k: int,
                chain_length: int = 100) -> jax.Array:
    """Assumption-free K-MC^2 (Bachem et al. 2016): MCMC approximation of
    K-Means++ using a D^2+uniform proposal distribution."""
    _validate_seeding(x, k, "afkmc2_init")
    n = x.shape[0]
    key, sub = jax.random.split(key)
    first = jax.random.randint(sub, (), 0, n)
    c0 = x[first]
    # Proposal q(x) = 0.5 * d(x, c0)^2 / sum + 0.5 / n
    d0 = jnp.sum((x - c0) ** 2, axis=-1)
    q = 0.5 * d0 / jnp.maximum(jnp.sum(d0), 1e-30) + 0.5 / n
    logq = jnp.log(jnp.maximum(q, 1e-38))

    def sample_center(carry, key_t):
        centers, n_c = carry               # centers: (k, d) buffer; n_c valid
        k1, k2, k3 = jax.random.split(key_t, 3)
        # Candidate chain: chain_length proposals from q.
        cand = jax.random.categorical(k1, logq, shape=(chain_length,))
        us = jax.random.uniform(k2, (chain_length,))

        def mind_to_centers(i):
            d = jnp.sum((x[i][None, :] - centers) ** 2, axis=-1)
            masked = jnp.where(jnp.arange(centers.shape[0]) < n_c, d, jnp.inf)
            return jnp.min(masked)

        def chain_step(state, t):
            cur, cur_val = state
            nxt = cand[t]
            nxt_val = mind_to_centers(nxt) / q[nxt]
            accept = us[t] < nxt_val / jnp.maximum(cur_val, 1e-30)
            cur = jnp.where(accept, nxt, cur)
            cur_val = jnp.where(accept, nxt_val, cur_val)
            return (cur, cur_val), None

        start = cand[0]
        start_val = mind_to_centers(start) / q[start]
        (chosen, _), _ = jax.lax.scan(chain_step, (start, start_val),
                                      jnp.arange(1, chain_length))
        centers = centers.at[n_c].set(x[chosen])
        return (centers, n_c + 1), None

    centers = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(c0)
    keys = jax.random.split(key, k - 1)
    (centers, _), _ = jax.lax.scan(sample_center, (centers, 1), keys)
    return centers


def bf_init(key: jax.Array, x: jax.Array, k: int, n_subsets: int = 10,
            subset_frac: float = 0.1, max_iter: int = 20) -> jax.Array:
    """Bradley & Fayyad 1998 refinement: run K-Means on J random subsamples,
    then cluster the union of the J solutions and return the best seed set."""
    from repro.core.kmeans import KMeansConfig, aa_kmeans
    _validate_seeding(x, k, "bf_init")
    n = x.shape[0]
    subset = max(k * 2, int(n * subset_frac))
    subset = min(subset, n)
    cfg = KMeansConfig(k=k, max_iter=max_iter, accelerated=False)

    def solve_subset(key_j):
        k1, k2 = jax.random.split(key_j)
        idx = jax.random.choice(k1, n, (subset,), replace=False)
        xs = x[idx]
        c0 = random_init(k2, xs, k)
        res = aa_kmeans(xs, c0, cfg)
        return res.centroids

    keys = jax.random.split(key, n_subsets + 1)
    cms = jax.lax.map(solve_subset, keys[:n_subsets])   # (J, K, d)
    cm_all = cms.reshape(n_subsets * k, -1)

    # Cluster the union of subset solutions, seeding from each solution in
    # turn; keep the seed set with the lowest distortion over CM (as in BF98).
    def refine(cj):
        res = aa_kmeans(cm_all, cj, cfg)
        return res.centroids, res.energy

    fms, costs = jax.lax.map(refine, cms)
    best = jnp.argmin(costs)
    return fms[best]


def clarans_init(key: jax.Array, x: jax.Array, k: int,
                 num_local: int = 2, max_neighbor: int = 32,
                 sample_n: int = 2048) -> jax.Array:
    """Simplified CLARANS (Ng & Han 1994) k-medoids seeding as used for
    K-Means initialisation by Newling & Fleuret 2017.

    Randomized medoid-swap local search on a subsample (CLARANS evaluates
    swaps on a sample for scalability).  Python loop over jitted swap
    evaluations — initialisation cost, not part of the timed solver.
    """
    _validate_seeding(x, k, "clarans_init")
    if num_local < 1:
        raise ValueError(
            f"clarans_init: num_local must be >= 1 (got {num_local}); "
            f"zero local searches would yield no medoid set at all")
    n = x.shape[0]
    key, sub = jax.random.split(key)
    if n > sample_n:
        sidx = jax.random.choice(sub, n, (sample_n,), replace=False)
        xs = x[sidx]
    else:
        xs = x

    @jax.jit
    def cost_of(medoids):
        d = pairwise_sqdist(xs, medoids)
        return jnp.sum(jnp.min(d, axis=-1))

    @jax.jit
    def swap(medoids, slot, cand):
        return medoids.at[slot].set(xs[cand])

    best_medoids, best_cost = None, jnp.inf
    for restart in range(num_local):
        key, k1 = jax.random.split(key)
        medoids = random_init(k1, xs, k)
        cost = cost_of(medoids)
        stall = 0
        while stall < max_neighbor:
            key, k2, k3 = jax.random.split(key, 3)
            slot = int(jax.random.randint(k2, (), 0, k))
            cand = int(jax.random.randint(k3, (), 0, xs.shape[0]))
            trial = swap(medoids, slot, cand)
            tcost = cost_of(trial)
            if float(tcost) < float(cost):
                medoids, cost, stall = trial, tcost, 0
            else:
                stall += 1
        if float(cost) < float(best_cost):
            best_medoids, best_cost = medoids, cost
    return best_medoids


INIT_SCHEMES = {
    "random": random_init,
    "kmeans++": kmeanspp_init,
    "afk-mc2": afkmc2_init,
    "bf": bf_init,
    "clarans": clarans_init,
}

# Schemes whose whole computation is jit-able, hence vmap-safe over a keys
# axis; bf's subset solves and clarans's swap-acceptance loop run host-side
# Python, so batched_init falls back to stacking per-key results for them.
VMAP_SAFE_INITS = frozenset({"random", "kmeans++", "afk-mc2"})


def make_init(name: str):
    if name not in INIT_SCHEMES:
        raise ValueError(f"unknown init scheme {name!r}; "
                         f"choose from {sorted(INIT_SCHEMES)}")
    return INIT_SCHEMES[name]


def batched_init(name: str, keys: jax.Array, x: jax.Array,
                 k: int, weights=None) -> jax.Array:
    """Seed R restarts at once: (R, 2) keys -> (R, K, d) centroid stacks.

    ``x`` is (N, d) shared across restarts, or (R, N, d) one dataset per
    problem.  Vmap-safe schemes produce the whole stack in one traced
    computation (feeding the batched solver without a host round-trip);
    the host-loop schemes (bf, clarans) are looped and stacked, which is
    semantically identical — seeding cost only, never solver cost.

    ``weights`` (R, N) >= 0 makes the seeding segment-aware (the
    hierarchy engine's padded sub-problems: padding rows weigh 0 and are
    never seeded) — supported for the weighted schemes random/kmeans++
    only."""
    fn = make_init(name)
    x_axis = 0 if x.ndim == 3 else None
    if x_axis == 0 and x.shape[0] != keys.shape[0]:
        raise ValueError(
            f"batched x has {x.shape[0]} problems but got "
            f"{keys.shape[0]} keys")
    if weights is not None:
        if name not in ("random", "kmeans++"):
            raise ValueError(
                f"batched_init(weights=...) supports the weighted schemes "
                f"'random' and 'kmeans++' only; got {name!r}")
        return jax.vmap(lambda kk, xx, ww: fn(kk, xx, k, w=ww),
                        in_axes=(0, x_axis, 0))(keys, x, weights)
    if name in VMAP_SAFE_INITS:
        return jax.vmap(lambda kk, xx: fn(kk, xx, k),
                        in_axes=(0, x_axis))(keys, x)
    seeds = [fn(keys[i], x if x_axis is None else x[i], k)
             for i in range(keys.shape[0])]
    return jnp.stack([jnp.asarray(s) for s in seeds])
