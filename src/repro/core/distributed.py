"""Distributed AA-KMeans: the paper's Algorithm 1 on a multi-pod TPU mesh.

Parallelisation layout (see DESIGN.md §Distribution):

  * Samples X (N, d) are sharded over the data axes — on the production
    meshes that is ("data",) for a single pod and ("pod", "data") across
    pods — so each of the 256/512 chips owns an N/devices slice.
  * Centroids C (K, d) are replicated: K*d is tiny (<= a few MB) next to X.
  * The assignment half of the step is embarrassingly parallel (local
    distances); the step's cluster stats are psum-reduced over the data
    axes — one (K*(d+1))-sized all-reduce per iteration, the *only*
    communication of the solver.
  * The energy check and the convergence test reduce one scalar each.
  * Anderson acceleration operates on the replicated centroids; every
    device solves the identical tiny (mbar x mbar) system, so no extra
    communication is introduced by the acceleration — the paper's overhead
    argument (Sec. 2.1) carries over unchanged to the distributed setting.

Distribution is the `distribute(backend, axes)` combinator over *any*
local backend (`repro.core.backends`): dense, blocked, the Pallas kernels,
the fused single-pass kernel, or Hamerly bounds all run under the same
shard_map wrapping — "fused Pallas + sharded mesh + mixed precision" is a
configuration, not a code path.  The *same* Algorithm-1 driver
(repro.core.kmeans.aa_kmeans) runs unchanged here.
"""

from __future__ import annotations

import functools
from typing import Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import lloyd
from repro.core.backends import Backend, distribute
from repro.core.kmeans import (KMeansConfig, KMeansResult, aa_kmeans,
                               aa_kmeans_batched, aa_kmeans_minibatch,
                               resolve_backend, select_best)
from repro.core.lloyd import LloydOps
from repro.core.minibatch import MiniBatchConfig, MiniBatchResult


def distributed_lloyd_ops(data_axes: Sequence[str],
                          block_n: int = 0) -> LloydOps:
    """DEPRECATED: LloydOps whose update/energy/convergence reduce over
    ``data_axes``.  Superseded by ``distribute(backend, axes)``; kept so
    legacy injection sites keep working.  Must be called *inside* shard_map
    with x as the local shard and c replicated."""
    axes = tuple(data_axes)

    def assign_fn(x, c):
        return lloyd.assign(x, c, block_n=block_n)

    def update_fn(x, labels, k, c_prev):
        sums, counts = lloyd.cluster_sums(x, labels, k)
        sums = jax.lax.psum(sums, axes)
        counts = jax.lax.psum(counts, axes)
        return lloyd.update_from_sums(sums, counts, c_prev)

    def energy_fn(x, c, labels):
        return jax.lax.psum(lloyd.energy(x, c, labels), axes)

    def all_equal_fn(a, b):
        neq = jnp.sum((a != b).astype(jnp.int32))
        return jax.lax.psum(neq, axes) == 0

    return LloydOps(assign_fn=assign_fn, update_fn=update_fn,
                    energy_fn=energy_fn, all_equal_fn=all_equal_fn,
                    reduce_scalar=lambda s: jax.lax.psum(s, axes))


def make_distributed_kmeans(mesh: jax.sharding.Mesh, cfg: KMeansConfig,
                            data_axes: Sequence[str] = ("data",),
                            block_n: int = 0,
                            backend: Union[str, Backend, None] = None):
    """Build the jitted multi-device solver.

    Returns ``fit(x, c0) -> KMeansResult`` where x is (N, d) sharded (or
    shardable) over ``data_axes`` and c0 is (K, d) replicated.  N must be
    divisible by the product of the data-axis sizes.  ``backend`` picks the
    per-shard engine (any registry name or local Backend instance, wrapped
    here by ``distribute``); an already distribute()-wrapped backend is
    used as-is provided its axes match ``data_axes``.
    """
    axes = tuple(data_axes)
    ops = _resolve_distributed(backend, cfg, block_n, axes)
    x_spec = P(axes)           # shard rows over all data axes
    rep = P()

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(x_spec, rep),
        out_specs=KMeansResult(centroids=rep, labels=x_spec, energy=rep,
                               n_iter=rep, n_accepted=rep, converged=rep))
    def _run(x_local, c0):
        return aa_kmeans(x_local, c0, cfg, backend=ops)

    x_sharding = NamedSharding(mesh, x_spec)
    rep_sharding = NamedSharding(mesh, rep)

    @jax.jit
    def fit(x, c0):
        x = jax.lax.with_sharding_constraint(x, x_sharding)
        c0 = jax.lax.with_sharding_constraint(c0, rep_sharding)
        return _run(x, c0)

    return fit


def _resolve_distributed(backend, cfg, block_n, axes):
    local = resolve_backend(backend, cfg=cfg, block_n=block_n)
    if local.axes:
        if local.axes != axes:
            raise ValueError(
                f"backend {local.name!r} is distributed over {local.axes} "
                f"but the solver reduces over {axes}")
        return local
    return distribute(local, axes)


def make_distributed_kmeans_batched(mesh: jax.sharding.Mesh,
                                    cfg: KMeansConfig,
                                    data_axes: Sequence[str] = ("data",),
                                    block_n: int = 0,
                                    backend: Union[str, Backend,
                                                   None] = None,
                                    pick_best: bool = False):
    """Batched multi-restart solver on a mesh: one program, R restarts.

    Returns ``fit(x, c0s) -> KMeansResult`` where x is (N, d) sharded over
    ``data_axes``, c0s is (R, K, d) replicated, and the result carries a
    leading R axis (labels: (R, N), rows sharded).  Inside shard_map the
    *batched* driver vmaps the distributed backend, so each loop body does
    one psum of (R, K, d+1)-sized stats — R restarts cost one collective,
    not R.  ``pick_best=True`` adds on-device best-of-R selection, making
    the whole multi-restart fit a single device program.
    """
    axes = tuple(data_axes)
    ops = _resolve_distributed(backend, cfg, block_n, axes)
    x_spec = P(axes)
    rep = P()
    lab_spec = P(None, axes)      # (R, N): restart axis replicated

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(x_spec, rep),
        out_specs=KMeansResult(centroids=rep, labels=lab_spec, energy=rep,
                               n_iter=rep, n_accepted=rep, converged=rep))
    def _run(x_local, c0s):
        return aa_kmeans_batched(x_local, c0s, cfg, backend=ops)

    x_sharding = NamedSharding(mesh, x_spec)
    rep_sharding = NamedSharding(mesh, rep)

    @jax.jit
    def fit(x, c0s):
        x = jax.lax.with_sharding_constraint(x, x_sharding)
        c0s = jax.lax.with_sharding_constraint(c0s, rep_sharding)
        res = _run(x, c0s)
        return select_best(res) if pick_best else res

    return fit


def make_distributed_kmeans_minibatch(mesh: jax.sharding.Mesh,
                                      cfg: MiniBatchConfig,
                                      data_axes: Sequence[str] = ("data",),
                                      backend: Union[str, Backend,
                                                     None] = None):
    """Streaming mini-batch solver on a mesh: every host streams its shard.

    Returns ``fit(chunks, weights, x_val, c0, key=None) ->
    MiniBatchResult`` where ``chunks`` (n_chunks, B, d) and ``weights``
    (n_chunks, B) have their *row* dimension sharded over ``data_axes``
    (`repro.data.streaming.chunk_dataset(mesh=...)` lays them out) and
    ``x_val`` (V, d) is sharded likewise; centroids stay replicated.
    Inside shard_map each chunk step costs ONE (K,(d+1))-stat psum plus
    the guard's scalar energies — per-chunk communication is independent
    of both the chunk size and N (DESIGN.md §Streaming).  V and B must be
    divisible by the shard count of ``data_axes``.
    """
    axes = tuple(data_axes)
    ops = _resolve_distributed(backend, None, 0, axes)
    chunk_spec = P(None, axes)     # (n_chunks, B): chunk rows sharded
    val_spec = P(axes)
    rep = P()

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(chunk_spec, chunk_spec, val_spec, rep, rep),
        out_specs=MiniBatchResult(centroids=rep, energy=rep, n_steps=rep,
                                  n_accepted=rep))
    def _run(chunks, weights, x_val, c0, key):
        return aa_kmeans_minibatch(chunks, weights, x_val, c0, cfg,
                                   backend=ops, key=key)

    chunk_sharding = NamedSharding(mesh, chunk_spec)
    val_sharding = NamedSharding(mesh, val_spec)
    rep_sharding = NamedSharding(mesh, rep)

    @jax.jit
    def _fit(chunks, weights, x_val, c0, key):
        chunks = jax.lax.with_sharding_constraint(chunks, chunk_sharding)
        weights = jax.lax.with_sharding_constraint(weights, chunk_sharding)
        x_val = jax.lax.with_sharding_constraint(x_val, val_sharding)
        c0 = jax.lax.with_sharding_constraint(c0, rep_sharding)
        return _run(chunks, weights, x_val, c0, key)

    def fit(chunks, weights, x_val, c0, key=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        return _fit(chunks, weights, x_val, c0, key)

    return fit


def shard_dataset(x, mesh: jax.sharding.Mesh,
                  data_axes: Sequence[str] = ("data",)):
    """Place a host array on the mesh, padding N to the shard count.

    Padding rows replicate the final sample: duplicated points only bias the
    padded copy's cluster weighting, and callers that need exactness should
    pre-size N; the launcher reports when padding is applied."""
    import numpy as np
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    n = x.shape[0]
    pad = (-n) % n_shards
    if pad:
        x = np.concatenate([x, np.repeat(x[-1:], pad, axis=0)], axis=0)
    sharding = NamedSharding(mesh, P(tuple(data_axes)))
    return jax.device_put(x, sharding), pad
