"""Distributed AA-KMeans: the paper's Algorithm 1 on a multi-pod TPU mesh.

Parallelisation layout (see DESIGN.md §Distribution):

  * Samples X (N, d) are sharded over the data axes — on the production
    meshes that is ("data",) for a single pod and ("pod", "data") across
    pods — so each of the 256/512 chips owns an N/devices slice.
  * Centroids C (K, d) are replicated: K*d is tiny (<= a few MB) next to X.
  * The assignment half of the step is embarrassingly parallel (local
    distances); the step's cluster stats are psum-reduced over the data
    axes — one (K*(d+1))-sized all-reduce per iteration, the *only*
    communication of the solver.
  * The energy check and the convergence test reduce one scalar each.
  * Anderson acceleration operates on the replicated centroids; every
    device solves the identical tiny (mbar x mbar) system, so no extra
    communication is introduced by the acceleration — the paper's overhead
    argument (Sec. 2.1) carries over unchanged to the distributed setting.

Distribution is the `distribute(backend, axes)` combinator over *any*
local backend (`repro.core.backends`): dense, blocked, the Pallas kernels,
the fused single-pass kernel, or Hamerly bounds all run under the same
shard_map wrapping — "fused Pallas + sharded mesh + mixed precision" is a
configuration, not a code path.  The *same* Algorithm-1 driver
(repro.core.kmeans.aa_kmeans) runs unchanged here.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import kmeans as KM
from repro.core import lloyd, serialize
from repro.core.backends import Backend, distribute
from repro.core.kmeans import (KMeansConfig, KMeansResult, aa_kmeans,
                               aa_kmeans_batched, aa_kmeans_minibatch,
                               resolve_backend, select_best)
from repro.core.lloyd import LloydOps
from repro.core.minibatch import MiniBatchConfig, MiniBatchResult


def distributed_lloyd_ops(data_axes: Sequence[str],
                          block_n: int = 0) -> LloydOps:
    """DEPRECATED: LloydOps whose update/energy/convergence reduce over
    ``data_axes``.  Superseded by ``distribute(backend, axes)``; kept so
    legacy injection sites keep working.  Must be called *inside* shard_map
    with x as the local shard and c replicated."""
    axes = tuple(data_axes)

    def assign_fn(x, c):
        return lloyd.assign(x, c, block_n=block_n)

    def update_fn(x, labels, k, c_prev):
        sums, counts = lloyd.cluster_sums(x, labels, k)
        sums = jax.lax.psum(sums, axes)
        counts = jax.lax.psum(counts, axes)
        return lloyd.update_from_sums(sums, counts, c_prev)

    def energy_fn(x, c, labels):
        return jax.lax.psum(lloyd.energy(x, c, labels), axes)

    def all_equal_fn(a, b):
        neq = jnp.sum((a != b).astype(jnp.int32))
        return jax.lax.psum(neq, axes) == 0

    return LloydOps(assign_fn=assign_fn, update_fn=update_fn,
                    energy_fn=energy_fn, all_equal_fn=all_equal_fn,
                    reduce_scalar=lambda s: jax.lax.psum(s, axes))


def _mesh_shards(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _is_spec(s) -> bool:
    # PartitionSpec subclasses tuple, so tree_map would descend into it
    # without an explicit is_leaf.
    return isinstance(s, P)


def loop_state_specs(local_backend: Backend, cfg: KMeansConfig,
                     x_local, c0, axes: Sequence[str]):
    """PartitionSpec tree for a `_LoopState` under row sharding.

    Per-row leaves (labels, the previous assignment, and any per-row
    backend carry, recognised by a leading dim equal to the local row
    count) shard over ``axes``; centroids, energies, the Anderson window
    and the counters are replicated — exactly the layout the solver's
    shard_map maintains, reused here as both shard_map in/out specs and
    the device_put shardings of an elastic restore."""
    axes = tuple(axes)

    def shape_at(n_rows):
        return jax.eval_shape(
            lambda xx, cc: KM._init_state(xx, cc, cfg, local_backend),
            jax.ShapeDtypeStruct((n_rows, x_local.shape[1]), x_local.dtype),
            jax.ShapeDtypeStruct(c0.shape, c0.dtype))

    # Classify carry leaves by whether their leading dim tracks the row
    # count — probed by eval_shape at a second N, NOT by comparing shapes
    # against n_local (a centroid-shaped carry leaf, e.g. hamerly's
    # c_last (K, d), would collide whenever K == n_local and get sharded).
    like = shape_at(x_local.shape[0])
    probe = shape_at(x_local.shape[0] + 1)
    row, rep = P(axes), P()

    def carry_spec(leaf, probe_leaf):
        per_row = getattr(leaf, "ndim", 0) >= 1 and \
            leaf.shape[:1] != probe_leaf.shape[:1]
        return row if per_row else rep

    return KM._LoopState(
        c=rep, c_au=rep, p_prev=row, e_prev=rep, e_prev2=rep,
        aa=jax.tree_util.tree_map(lambda _: rep, like.aa),
        t=rep, n_acc=rep, converged=rep, labels=row, e_last=rep,
        carry=jax.tree_util.tree_map(carry_spec, like.carry, probe.carry))


def restore_distributed_loop_state(path, x, c0, cfg: KMeansConfig,
                                   local_backend: Backend,
                                   mesh: jax.sharding.Mesh,
                                   data_axes: Sequence[str] = ("data",)):
    """Elastic restore: place a solver snapshot onto ``mesh``.

    Snapshots store UNSHARDED host arrays (serialize.py), so restoring
    onto a different mesh or data-axes layout than the one the checkpoint
    was taken under is a `device_put` with the new shardings — the mesh
    geometry appears nowhere in the artifact.  ``x``/``c0`` supply the
    problem shapes (the like tree); the snapshot's backend identity is
    checked up to the '@axes' distribution suffix."""
    axes = tuple(data_axes)
    n_shards = _mesh_shards(mesh, axes)
    if x.shape[0] % n_shards:
        raise ValueError(
            f"N={x.shape[0]} must divide over the {n_shards} shards of "
            f"mesh axes {axes} to restore onto this mesh "
            f"(pad via shard_dataset first)")
    like = KM.loop_state_like(x, c0, cfg, local_backend)
    host_state, meta = serialize.restore(path, like,
                                         expect_kind=serialize.KIND_LOOP)
    KM._check_resume_meta(meta, cfg, local_backend, str(path))
    x_local = jax.ShapeDtypeStruct((x.shape[0] // n_shards, x.shape[1]),
                                   x.dtype)
    specs = loop_state_specs(local_backend, cfg, x_local, c0, axes)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=_is_spec)
    return jax.device_put(host_state, shardings), meta


def make_distributed_kmeans(mesh: jax.sharding.Mesh, cfg: KMeansConfig,
                            data_axes: Sequence[str] = ("data",),
                            block_n: int = 0,
                            backend: Union[str, Backend, None] = None,
                            checkpoint_every: int = 0,
                            checkpoint_dir=None):
    """Build the jitted multi-device solver.

    Returns ``fit(x, c0, resume_from=None) -> KMeansResult`` where x is
    (N, d) sharded (or shardable) over ``data_axes`` and c0 is (K, d)
    replicated.  N must be divisible by the product of the data-axis
    sizes.  ``backend`` picks the per-shard engine (any registry name or
    local Backend instance, wrapped here by ``distribute``); an already
    distribute()-wrapped backend is used as-is provided its axes match
    ``data_axes``.

    Persistence (DESIGN.md §Persistence): with ``checkpoint_every`` set
    (or ``resume_from`` passed to fit), the solve runs as a host loop over
    shard_map'd segments; snapshots gather to host via `jax.device_get`
    and are therefore mesh-free — a checkpoint taken here restores onto a
    DIFFERENT mesh or axes layout by building the new fit with that mesh
    and passing the same path (`restore_distributed_loop_state` reshards
    on device_put).  A resumed run is bit-identical to an uninterrupted
    run on the same mesh; across meshes the trajectory agrees up to psum
    reduction order.
    """
    axes = tuple(data_axes)
    ops = _resolve_distributed(backend, cfg, block_n, axes)
    x_spec = P(axes)           # shard rows over all data axes
    rep = P()

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(x_spec, rep),
        out_specs=KMeansResult(centroids=rep, labels=x_spec, energy=rep,
                               n_iter=rep, n_accepted=rep, converged=rep))
    def _run(x_local, c0):
        return aa_kmeans(x_local, c0, cfg, backend=ops)

    x_sharding = NamedSharding(mesh, x_spec)
    rep_sharding = NamedSharding(mesh, rep)

    @jax.jit
    def _fit_whole(x, c0):
        x = jax.lax.with_sharding_constraint(x, x_sharding)
        c0 = jax.lax.with_sharding_constraint(c0, rep_sharding)
        return _run(x, c0)

    # -- segmented path (host loop over shard_map'd while_loop segments) --
    local = resolve_backend(backend, cfg=cfg, block_n=block_n) \
        if not isinstance(backend, Backend) or not backend.axes else None
    programs = {}   # (x shape/dtype, c0 shape/dtype) -> (init, seg, specs)

    def _segment_programs(x, c0):
        key = (x.shape, str(x.dtype), c0.shape, str(c0.dtype))
        built = programs.get(key)
        if built is not None:
            return built
        if local is None:
            raise ValueError(
                "checkpointed distributed solves need a local backend "
                "(registry name or un-distributed instance) so the state "
                "layout can be derived; got a pre-distributed backend")
        n_shards = _mesh_shards(mesh, axes)
        if x.shape[0] % n_shards:
            raise ValueError(f"N={x.shape[0]} must be divisible by the "
                             f"{n_shards} shards of {axes}")
        x_local = jax.ShapeDtypeStruct((x.shape[0] // n_shards, x.shape[1]),
                                       x.dtype)
        specs = loop_state_specs(local, cfg, x_local, c0, axes)
        init = jax.jit(compat.shard_map(
            lambda xl, cc: KM._init_state(xl, cc, cfg, ops),
            mesh=mesh, in_specs=(x_spec, rep), out_specs=specs))
        seg = jax.jit(compat.shard_map(
            lambda xl, st, end: KM._run_segment(xl, st, end, cfg=cfg,
                                                backend=ops),
            mesh=mesh, in_specs=(x_spec, specs, rep), out_specs=specs))
        built = programs[key] = (init, seg, specs)
        return built

    def _fit_segmented(x, c0, resume_from):
        KM._no_trace(x, "make_distributed_kmeans fit")
        every = int(checkpoint_every) if checkpoint_every else cfg.max_iter
        init, seg, _ = _segment_programs(x, c0)
        x = jax.device_put(x, x_sharding)
        c0 = jax.device_put(c0, rep_sharding)
        if resume_from is None:
            state = init(x, c0)
        elif isinstance(resume_from, (str, os.PathLike)):
            state, _ = restore_distributed_loop_state(
                resume_from, x, c0, cfg, local, mesh, axes)
        else:
            state = resume_from
        t = int(state.t)
        while not bool(state.converged) and t < cfg.max_iter:
            seg_end = min(t + every, cfg.max_iter)
            state = seg(x, state, jnp.asarray(seg_end, jnp.int32))
            t = int(state.t)
            if checkpoint_dir is not None:
                KM._snapshot(checkpoint_dir, state, serialize.KIND_LOOP,
                             t, cfg, ops,
                             extra={"mesh": dict(mesh.shape),
                                    "data_axes": list(axes)})
        return KM._result_from_state(state)

    def fit(x, c0, resume_from=None):
        if not checkpoint_every and checkpoint_dir is None \
                and resume_from is None:
            return _fit_whole(x, c0)
        return _fit_segmented(x, c0, resume_from)

    return fit


def _resolve_distributed(backend, cfg, block_n, axes):
    local = resolve_backend(backend, cfg=cfg, block_n=block_n)
    if local.axes:
        if local.axes != axes:
            raise ValueError(
                f"backend {local.name!r} is distributed over {local.axes} "
                f"but the solver reduces over {axes}")
        return local
    return distribute(local, axes)


def make_distributed_kmeans_batched(mesh: jax.sharding.Mesh,
                                    cfg: KMeansConfig,
                                    data_axes: Sequence[str] = ("data",),
                                    block_n: int = 0,
                                    backend: Union[str, Backend,
                                                   None] = None,
                                    pick_best: bool = False):
    """Batched multi-restart solver on a mesh: one program, R restarts.

    Returns ``fit(x, c0s) -> KMeansResult`` where x is (N, d) sharded over
    ``data_axes``, c0s is (R, K, d) replicated, and the result carries a
    leading R axis (labels: (R, N), rows sharded).  Inside shard_map the
    *batched* driver vmaps the distributed backend, so each loop body does
    one psum of (R, K, d+1)-sized stats — R restarts cost one collective,
    not R.  ``pick_best=True`` adds on-device best-of-R selection, making
    the whole multi-restart fit a single device program.
    """
    axes = tuple(data_axes)
    ops = _resolve_distributed(backend, cfg, block_n, axes)
    x_spec = P(axes)
    rep = P()
    lab_spec = P(None, axes)      # (R, N): restart axis replicated

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(x_spec, rep),
        out_specs=KMeansResult(centroids=rep, labels=lab_spec, energy=rep,
                               n_iter=rep, n_accepted=rep, converged=rep))
    def _run(x_local, c0s):
        return aa_kmeans_batched(x_local, c0s, cfg, backend=ops)

    x_sharding = NamedSharding(mesh, x_spec)
    rep_sharding = NamedSharding(mesh, rep)

    @jax.jit
    def fit(x, c0s):
        x = jax.lax.with_sharding_constraint(x, x_sharding)
        c0s = jax.lax.with_sharding_constraint(c0s, rep_sharding)
        res = _run(x, c0s)
        return select_best(res) if pick_best else res

    return fit


def make_distributed_kmeans_minibatch(mesh: jax.sharding.Mesh,
                                      cfg: MiniBatchConfig,
                                      data_axes: Sequence[str] = ("data",),
                                      backend: Union[str, Backend,
                                                     None] = None):
    """Streaming mini-batch solver on a mesh: every host streams its shard.

    Returns ``fit(chunks, weights, x_val, c0, key=None) ->
    MiniBatchResult`` where ``chunks`` (n_chunks, B, d) and ``weights``
    (n_chunks, B) have their *row* dimension sharded over ``data_axes``
    (`repro.data.streaming.chunk_dataset(mesh=...)` lays them out) and
    ``x_val`` (V, d) is sharded likewise; centroids stay replicated.
    Inside shard_map each chunk step costs ONE (K,(d+1))-stat psum plus
    the guard's scalar energies — per-chunk communication is independent
    of both the chunk size and N (DESIGN.md §Streaming).  V and B must be
    divisible by the shard count of ``data_axes``.
    """
    axes = tuple(data_axes)
    ops = _resolve_distributed(backend, None, 0, axes)
    chunk_spec = P(None, axes)     # (n_chunks, B): chunk rows sharded
    val_spec = P(axes)
    rep = P()

    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(chunk_spec, chunk_spec, val_spec, rep, rep),
        out_specs=MiniBatchResult(centroids=rep, energy=rep, n_steps=rep,
                                  n_accepted=rep))
    def _run(chunks, weights, x_val, c0, key):
        return aa_kmeans_minibatch(chunks, weights, x_val, c0, cfg,
                                   backend=ops, key=key)

    chunk_sharding = NamedSharding(mesh, chunk_spec)
    val_sharding = NamedSharding(mesh, val_spec)
    rep_sharding = NamedSharding(mesh, rep)

    @jax.jit
    def _fit(chunks, weights, x_val, c0, key):
        chunks = jax.lax.with_sharding_constraint(chunks, chunk_sharding)
        weights = jax.lax.with_sharding_constraint(weights, chunk_sharding)
        x_val = jax.lax.with_sharding_constraint(x_val, val_sharding)
        c0 = jax.lax.with_sharding_constraint(c0, rep_sharding)
        return _run(chunks, weights, x_val, c0, key)

    def fit(chunks, weights, x_val, c0, key=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        return _fit(chunks, weights, x_val, c0, key)

    return fit


def shard_dataset(x, mesh: jax.sharding.Mesh,
                  data_axes: Sequence[str] = ("data",)):
    """Place a host array on the mesh, padding N to the shard count.

    Padding rows replicate the final sample: duplicated points only bias the
    padded copy's cluster weighting, and callers that need exactness should
    pre-size N; the launcher reports when padding is applied."""
    import numpy as np
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    n = x.shape[0]
    pad = (-n) % n_shards
    if pad:
        x = np.concatenate([x, np.repeat(x[-1:], pad, axis=0)], axis=0)
    sharding = NamedSharding(mesh, P(tuple(data_axes)))
    return jax.device_put(x, sharding), pad
