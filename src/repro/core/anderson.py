"""Anderson acceleration window with the paper's dynamic-m adjustment.

Implements the accelerated-iterate computation of Algorithm 1 (lines 16-19):

    theta* = argmin || F^t - sum_j theta_j (F^{t-j+1} - F^{t-j}) ||^2      (7)
    C^{t+1} = G^t  -  sum_j theta_j* (G^{t-j+1} - G^{t-j})                 (19)

NOTE on sign: Eq. (8) of the paper prints a "+" while Algorithm 1 line 19
prints a "-".  The "-" is the correct classical type-II Anderson update (the
affine-combination weights alpha_j of {G^{t-j}} with sum alpha = 1 transform
to backward-difference coefficients theta with a minus sign; see Walker & Ni
2011, Eq. 2.2).  We implement the minus sign; DESIGN.md records the typo.

All state lives in fixed-shape circular buffers so the whole accelerated
solver can run inside jax.lax.while_loop.  The least-squares problem (7) is
solved via normal equations with a tiny relative Tikhonov term (the
stabilisation used by Peng et al. 2018's reference implementation); columns
beyond the active window m_t are masked out with an identity block so the
solve is well-posed at any m_t <= mbar.

Dynamic adjustment of m (Algorithm 1 lines 7-11): with the energy-decrease
ratio r = (E^{t-1} - E^t) / (E^{t-2} - E^{t-1}),

    r < eps1  ->  m = max(m - 1, 0)       # step ineffective, shrink window
    r > eps2  ->  m = min(m + 1, mbar)    # step effective, grow window

with paper defaults eps1 = 0.02, eps2 = 0.5, mbar = 30, m0 = 2.

Batching contract (DESIGN.md §Batching): every function here is vmap-safe
over a leading problem axis — AAState leaves are fixed-shape arrays, the
window solve is already a *masked* dense (mbar x mbar) system (no
data-dependent shapes), and `_spd_solve`'s unrolled elimination batches
as fused elementwise ops (unlike LAPACK-backed `jnp.linalg.solve`, which
it replaced).  The batched driver (kmeans.aa_kmeans_batched) relies on
this to run R independent Anderson windows inside one `lax.while_loop`;
do not introduce value-dependent Python control flow here.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AAConfig:
    m0: int = 2            # initial window size
    mbar: int = 30         # maximum window size (paper: 30)
    eps1: float = 0.02     # shrink threshold (paper: 0.02)
    eps2: float = 0.5      # grow threshold (paper: 0.5)
    dynamic_m: bool = True  # False -> fixed m = m0 (Table 2 "Fixed" columns)
    ridge: float = 1e-12   # relative Tikhonov regularisation for (7)


class AAState(NamedTuple):
    """Fixed-shape Anderson window.

    dF, dG : (mbar, D) circular buffers of residual / iterate differences,
             column ``head - 1 - j (mod mbar)`` holds (F^{t-j} - F^{t-j-1}).
    f_prev, g_prev : (D,) last residual / last fixed-point image.
    ncols  : number of valid history columns (= min(t, mbar)).
    head   : next write position in the circular buffers.
    m      : current window size (dynamically adjusted).

    Persistence contract (DESIGN.md §Persistence): this tuple IS the
    acceleration's whole memory — there is no hidden host state — and
    every leaf is a fixed-shape array, so snapshotting it (inside the
    solver's `_LoopState`, via `repro.core.serialize`) and restoring it
    bit-exactly resumes the accelerated trajectory the paper's energy
    guard depends on.  A restart from bare centroids instead discards the
    window (ncols/head/m reset), which changes every subsequent AA step.
    Adding a field here is a snapshot-schema change: bump
    `serialize.SCHEMA_VERSION` and provide a migration.
    """
    dF: jax.Array
    dG: jax.Array
    f_prev: jax.Array
    g_prev: jax.Array
    ncols: jax.Array
    head: jax.Array
    m: jax.Array


def aa_init(d_flat: int, cfg: AAConfig, dtype=jnp.float32) -> AAState:
    return AAState(
        dF=jnp.zeros((cfg.mbar, d_flat), dtype),
        dG=jnp.zeros((cfg.mbar, d_flat), dtype),
        f_prev=jnp.zeros((d_flat,), dtype),
        g_prev=jnp.zeros((d_flat,), dtype),
        ncols=jnp.array(0, jnp.int32),
        head=jnp.array(0, jnp.int32),
        m=jnp.array(cfg.m0, jnp.int32),
    )


def aa_seed(state: AAState, f0: jax.Array, g0: jax.Array) -> AAState:
    """Record (F^0, G^0) before the first accelerated iteration."""
    return state._replace(f_prev=f0, g_prev=g0)


def adjust_m(state: AAState, e_curr: jax.Array, e_prev: jax.Array,
             e_prev2: jax.Array, cfg: AAConfig) -> AAState:
    """Algorithm 1 lines 7-11.  Guarded for t < 2 (e_prev2 = +inf) and for a
    zero previous decrease (ratio -> +inf -> grow, matching the limit)."""
    if not cfg.dynamic_m:
        return state
    num = e_prev - e_curr
    den = e_prev2 - e_prev
    # den == +inf (first two iterations): ratio 0/inf -> leave m unchanged by
    # construction of the guards below; den == 0: treat as ratio = +inf.
    ratio = jnp.where(den > 0, num / jnp.maximum(den, jnp.finfo(num.dtype).tiny),
                      jnp.where(num > 0, jnp.inf, -jnp.inf))
    defined = jnp.isfinite(e_prev2)  # only adjust once E^{t-2} exists
    shrink = jnp.logical_and(defined, ratio < cfg.eps1)
    grow = jnp.logical_and(defined, ratio > cfg.eps2)
    m = jnp.where(shrink, jnp.maximum(state.m - 1, 0),
                  jnp.where(grow, jnp.minimum(state.m + 1, cfg.mbar), state.m))
    return state._replace(m=m.astype(jnp.int32))


def _spd_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve a (n, n) SPD system with pure-XLA Gauss–Jordan elimination.

    The window gram is symmetric positive definite by construction (A Aᵀ
    over the active columns + relative ridge, identity rows elsewhere), so
    elimination without pivoting is stable here.  A hand-rolled fori_loop
    beats `jnp.linalg.solve` for this shape because the LAPACK custom
    call costs ~200us of dispatch per (mbar, mbar) solve on CPU — per
    *solver iteration* — and lowers to a per-matrix host loop when the
    batched driver vmaps it; this formulation is a handful of fused
    elementwise ops that batch for free."""
    n = a.shape[-1]
    aug = jnp.concatenate([a, b[:, None]], axis=-1)       # (n, n+1)
    # n (= mbar) is static and small, so unroll: one fused kernel instead
    # of an XLA while loop whose per-step dispatch would dominate.
    for i in range(n):
        pivot_row = aug[i] / aug[i, i]                    # (n+1,)
        factors = aug[:, i]                               # (n,)
        aug = aug - factors[:, None] * pivot_row[None, :]
        aug = aug.at[i].set(pivot_row)
    return aug[:, n]


def _column_ages(state: AAState, mbar: int) -> jax.Array:
    """age[i] = how many steps ago buffer column i was written (1 = newest).
    Invalid columns get age > mbar."""
    idx = jnp.arange(mbar, dtype=jnp.int32)
    age = (state.head - 1 - idx) % mbar + 1          # 1 .. mbar
    return jnp.where(age <= state.ncols, age, mbar + 1)


def aa_push_and_solve(state: AAState, f: jax.Array, g: jax.Array,
                      cfg: AAConfig):
    """Push (F^t, G^t), solve (7) over the active window, return C^{t+1}.

    Returns (new_state, c_next_flat, theta, m_t)."""
    mbar = cfg.mbar
    df = f - state.f_prev
    dg = g - state.g_prev
    dF = state.dF.at[state.head].set(df)
    dG = state.dG.at[state.head].set(dg)
    head = (state.head + 1) % mbar
    ncols = jnp.minimum(state.ncols + 1, mbar)
    state = state._replace(dF=dF, dG=dG, f_prev=f, g_prev=g,
                           ncols=ncols, head=head)

    m_t = jnp.minimum(state.m, ncols)                 # Algorithm 1 line 17
    age = _column_ages(state, mbar)                   # (mbar,)
    active = (age <= m_t)                             # newest m_t columns

    # Normal equations over masked columns:  (A A^T + lam I) theta = A f
    a_mask = jnp.where(active[:, None], dF, 0.0)
    gram = a_mask @ a_mask.T                          # (mbar, mbar)
    rhs = a_mask @ f                                  # (mbar,)
    lam = cfg.ridge * (jnp.trace(gram) + 1.0)
    eye = jnp.eye(mbar, dtype=f.dtype)
    # Identity rows/cols for inactive entries keep the solve well-posed.
    gram = jnp.where(active[:, None] & active[None, :], gram, 0.0) + \
        eye * jnp.where(active, lam, 1.0)
    theta = _spd_solve(gram, rhs)
    theta = jnp.where(active, theta, 0.0)

    dg_mask = jnp.where(active[:, None], dG, 0.0)
    c_next = g - theta @ dg_mask                      # Algorithm 1 line 19
    # m_t == 0 -> plain Lloyd iterate (theta is all zero already, but be
    # explicit so a zero window is exactly un-accelerated).
    c_next = jnp.where(m_t > 0, c_next, g)
    return state, c_next, theta, m_t
