"""Streaming mini-batch Anderson-accelerated K-Means (DESIGN.md §Streaming).

Every solver before this one assumes the whole dataset X sits in device
memory.  This module runs Algorithm 1 over *chunked* data: each step reads
one chunk, folds its weighted cluster statistics into exponentially-decayed
running sums, and treats the running mean as the fixed-point image G(C) —
the mini-batch analogue of the Lloyd update (Sculley 2010, with decay in
place of per-centre learning rates so the map stays a fixed-shape
fixed-point iteration AA can accelerate).

Three adaptations of Algorithm 1, all local to this module:

  * **G is the decayed running mean.**  With chunk stats (s, n) at C^t,

        S_t = γ·S_{t-1} + s,   W_t = γ·W_{t-1} + n,   G(C^t) = S_t / W_t

    (clusters with W = 0 keep their previous centroid).  S/W is invariant
    under pure decay, so a cluster unseen for many chunks holds its last
    mean rather than drifting.

  * **The energy guard runs on a held-out validation chunk.**  The paper's
    accept test compares full-X energies, which are unavailable online.
    Instead each step evaluates the accelerated candidate C^t and the
    fallback C_AU^t on one fixed validation chunk (a single batched step —
    R = 2 centroid sets, one pass over the val rows; the dense backend's
    shared-X einsum, or ONE leading-R-grid kernel launch on the
    pallas/fused engines) and keeps the candidate only if it is strictly
    better there.  The same validation energies drive the paper's
    dynamic-m adjustment.

  * **Seeding happens on the first chunk.**  The window is seeded with
    (G(C^0) − C^0, G(C^0)) computed from chunk 0's stats; the first step
    is therefore plain mini-batch Lloyd, exactly as the full-batch driver's
    init step is plain Lloyd.

The per-chunk communication under `distribute()` is one (K,(d+1))-stat
psum for the chunk step plus the scalar validation energies — independent
of the chunk size (DESIGN.md §Streaming).

The epoch driver lives in `kmeans.aa_kmeans_minibatch`; this module holds
the per-chunk state machine so the estimator's `partial_fit` and the
benchmarks can drive single steps / single epochs directly.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import anderson, lloyd
from repro.core.anderson import AAConfig, AAState
from repro.core.backends import Backend


@dataclasses.dataclass(frozen=True)
class MiniBatchConfig:
    """Static configuration of the streaming solver (jit-static)."""
    k: int
    chunk_size: int = 4096     # rows per chunk (data layer pads the tail)
    epochs: int = 5            # passes over the chunked data (fit path)
    decay: float = 0.9         # running-stat decay per chunk step
    aa: AAConfig = dataclasses.field(default_factory=AAConfig)
    accelerated: bool = True   # False -> plain mini-batch Lloyd


class MiniBatchState(NamedTuple):
    """Loop state carried across chunk steps (all fixed-shape arrays)."""
    c: jax.Array        # C^t — current (possibly accelerated) candidate
    c_au: jax.Array     # C_AU^t — fallback from the running stats
    sums: jax.Array     # decayed running cluster sums (K, d)
    counts: jax.Array   # decayed running cluster weights (K,)
    e_prev: jax.Array   # validation energy of the previous kept iterate
    e_prev2: jax.Array  # ... and the one before (dynamic-m ratio)
    aa: AAState
    t: jax.Array        # chunk steps taken
    n_acc: jax.Array    # steps whose accelerated candidate was kept


class MiniBatchTrace(NamedTuple):
    """Per-chunk-step diagnostics (scan-stacked by the epoch driver)."""
    e_val: jax.Array      # validation energy of the kept iterate
    e_cand: jax.Array     # ... of the accelerated candidate
    e_fallback: jax.Array  # ... of the running-stats fallback
    accepted: jax.Array   # guard decision


class MiniBatchResult(NamedTuple):
    centroids: jax.Array   # (K, d) — guard-picked final iterate
    energy: jax.Array      # total validation-chunk energy of `centroids`
    n_steps: jax.Array     # chunk steps executed
    n_accepted: jax.Array  # accelerated candidates kept


def minibatch_init(c0: jax.Array, cfg: MiniBatchConfig,
                   backend: Backend) -> MiniBatchState:
    k, d = c0.shape
    # accum_dtype is floored at f32 by the Precision policy (a bf16
    # running count freezes at 256 — see lloyd._accum_dtype)
    acc = backend.precision.accum_dtype
    inf = jnp.array(jnp.inf, acc)
    return MiniBatchState(
        c=c0, c_au=c0,
        sums=jnp.zeros((k, d), acc), counts=jnp.zeros((k,), acc),
        e_prev=inf, e_prev2=inf,
        aa=anderson.aa_init(k * d, cfg.aa, c0.dtype),
        t=jnp.array(0, jnp.int32), n_acc=jnp.array(0, jnp.int32))


def _centroids_from_running(sums, counts, c_prev, eps: float = 1e-6):
    """G(C) from the decayed running stats.  Unlike `lloyd.update_from_sums`
    (whose max(counts, 1) safe-divide assumes integer-ish counts), decayed
    weights legitimately sit below 1 and must still divide exactly."""
    safe = jnp.maximum(counts, eps)[:, None]
    mean = (sums / safe).astype(c_prev.dtype)
    return jnp.where(counts[:, None] > eps, mean, c_prev)


def guard_pick(x_val, state: MiniBatchState, cfg: MiniBatchConfig,
               backend: Backend):
    """Validation-chunk energy guard (Algorithm 1 lines 12-14, adapted).

    One batched step (R = 2 centroid sets, one pass over the val rows —
    shared-X einsum on the dense backend, the native leading-R fused
    kernel on pallas/fused) prices both the accelerated candidate and the
    fallback; the candidate is kept only if strictly better.  Returns
    (kept_c, kept_energy, accepted, (e_cand, e_fallback)).
    """
    cands = jnp.stack([state.c, state.c_au])
    carries = jax.vmap(lambda cc: backend.init_carry(x_val, cc, cfg.k))(cands)
    vres, _ = backend.batched_step(x_val, cands, cfg.k, carries)
    e_c, e_au = vres.energy[0], vres.energy[1]
    accepted = e_c < e_au
    c_t = jnp.where(accepted, state.c, state.c_au)
    e_t = jnp.where(accepted, e_c, e_au)
    return c_t, e_t, accepted, (e_c, e_au)


def minibatch_iteration(x_chunk, w, x_val, state: MiniBatchState,
                        cfg: MiniBatchConfig, backend: Backend):
    """One chunk step of streaming Algorithm 1.

    Structure mirrors `kmeans._iteration` line for line, with E replaced
    by the validation-chunk energy and G by the decayed-running-stats map:
    guard (accept/revert) -> m-adjustment -> one weighted pass over the
    chunk -> running-stat update -> Anderson push/solve.

    Returns (new_state, MiniBatchTrace).
    """
    k = cfg.k

    if cfg.accelerated:
        # Lines 7-14: m-adjustment then accept/revert, on val energies.
        c_t, e_t, accepted, (e_c, _e_au) = guard_pick(x_val, state, cfg,
                                                      backend)
        aa_adj = anderson.adjust_m(state.aa, e_c, state.e_prev,
                                   state.e_prev2, cfg.aa)
    else:
        # Plain mini-batch Lloyd: c == c_au always, so price the single
        # iterate (R=1) — an R=2 guard would double the val-row compute
        # to compare two identical candidates.
        vres, _ = backend.step(x_val, state.c_au, k,
                               backend.init_carry(x_val, state.c_au, k))
        c_t, e_t = state.c_au, vres.energy
        e_c = _e_au = vres.energy
        accepted = jnp.array(False)
        aa_adj = state.aa

    # Line 16 (mini-batch form): one weighted pass over the chunk at the
    # kept iterate; its stats decay into the running sums.  The carry is
    # chunk-local state, re-initialised because the rows are fresh.
    res, _ = backend.minibatch_step(x_chunk, c_t, k, w,
                                    backend.init_carry(x_chunk, c_t, k))
    sums = cfg.decay * state.sums + res.sums
    counts = cfg.decay * state.counts + res.counts
    c_au_next = _centroids_from_running(sums, counts, c_t)

    # Lines 17-19: Anderson acceleration across chunks.  The first step
    # seeds the window (the full-batch driver seeds in _init_state) and
    # emits the plain mini-batch iterate.
    g_flat = c_au_next.reshape(-1)
    f_flat = g_flat - c_t.reshape(-1)
    is_first = state.t == 0
    if cfg.accelerated:
        # lax.cond, not a select: the seed branch fires exactly once, and
        # a whole-AAState select would pay two (mbar, D)-buffer copies
        # plus a wasted window solve on every chunk of every epoch
        def _seed(args):
            aa, f, g = args
            return anderson.aa_seed(aa, f, g), g

        def _push(args):
            aa, f, g = args
            aa2, c2, _, _ = anderson.aa_push_and_solve(aa, f, g, cfg.aa)
            return aa2, c2

        aa_next, c_next_flat = jax.lax.cond(is_first, _seed, _push,
                                            (aa_adj, f_flat, g_flat))
        c_next = c_next_flat.reshape(c_t.shape)
    else:
        aa_next, c_next = aa_adj, c_au_next

    new_state = MiniBatchState(
        c=c_next, c_au=c_au_next, sums=sums, counts=counts,
        e_prev=e_t, e_prev2=state.e_prev, aa=aa_next,
        t=state.t + 1,
        n_acc=state.n_acc + accepted.astype(jnp.int32))
    trace = MiniBatchTrace(e_val=e_t, e_cand=e_c, e_fallback=_e_au,
                           accepted=accepted)
    return new_state, trace


def run_epoch(chunks, weights, x_val, state: MiniBatchState,
              cfg: MiniBatchConfig, backend: Backend, key):
    """One pass over every chunk in a fresh random order.

    ``chunks`` is (n_chunks, B, d) and ``weights`` (n_chunks, B) — the
    device-resident layout from `repro.data.streaming.chunk_dataset`.
    The scan gathers one chunk per step (dynamic index, no permuted copy
    of X).  Under shard_map the key is replicated, so every shard walks
    the same chunk order.  Returns (state, MiniBatchTrace with a leading
    n_chunks axis).
    """
    n_chunks = chunks.shape[0]
    perm = jax.random.permutation(key, n_chunks)

    def body(st, idx):
        xc = jnp.take(chunks, idx, axis=0)
        w = jnp.take(weights, idx, axis=0)
        return minibatch_iteration(xc, w, x_val, st, cfg, backend)

    return jax.lax.scan(body, state, perm)
