"""Lloyd's algorithm primitives: the fixed-point map G of the paper.

The paper (Zhang et al., 2018) treats one Lloyd iteration — assignment step
(Eq. 3) followed by the centroid-update step (Eq. 4) — as a fixed-point map

    C_{t+1} = G(C_t),   G = Update o Assign,

whose residual F(C) = G(C) - C vanishes at a local minimum of the K-Means
energy (Eq. 1).  This module provides the three primitives (assign / update /
energy) as pure, jit-able JAX functions plus an `Ops` container so that the
same Algorithm-1 driver (kmeans.py) can run with

  * the dense single-device ops below,
  * the Pallas TPU kernels (repro.kernels.ops), or
  * the shard_map distributed ops (repro.core.distributed)

without any change to the acceleration logic.

Hardware adaptation note (see DESIGN.md): the paper's CPU implementation uses
Hamerly's bound-based assignment to skip distance computations.  Bound
checking is data-dependent branching — hostile to the TPU's SIMD/MXU model —
so the TPU-native formulation is a dense blocked matmul
``dist^2 = |x|^2 - 2 x.c + |c|^2`` that runs on the MXU, optionally fused with
the update pass (repro/kernels/fused_lloyd.py).  A masked Hamerly variant is
provided in `hamerly.py` for completeness and CPU benchmarking.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AssignResult(NamedTuple):
    labels: jax.Array      # (N,) int32 — index of the closest centroid
    min_sqdist: jax.Array  # (N,) float — squared distance to that centroid


# ---------------------------------------------------------------------------
# Distance computation
# ---------------------------------------------------------------------------

def pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """Squared Euclidean distances between rows of x (N,d) and c (K,d).

    Uses the MXU-friendly expansion |x|^2 - 2 x.c + |c|^2 and clamps tiny
    negative values produced by cancellation.
    """
    x_sq = jnp.sum(x * x, axis=-1, keepdims=True)          # (N,1)
    c_sq = jnp.sum(c * c, axis=-1)                         # (K,)
    cross = x @ c.T                                        # (N,K) — MXU
    return jnp.maximum(x_sq - 2.0 * cross + c_sq[None, :], 0.0)


def assign(x: jax.Array, c: jax.Array, *, block_n: int = 0,
           block_unroll: bool = False) -> AssignResult:
    """Assignment step (Eq. 3): nearest centroid for every sample.

    ``block_n > 0`` evaluates distances in blocks of rows to bound the (N,K)
    intermediate — the pure-JAX analogue of the Pallas kernel's N-tiling.
    ``block_unroll`` uses a python loop instead of lax.map (the dry-run uses
    it so cost_analysis sees every block body; see launch/dryrun.py)."""
    n = x.shape[0]
    if block_n and n > block_n and n % block_n == 0:
        def body(xb):
            d = pairwise_sqdist(xb, c)
            return (jnp.argmin(d, axis=-1).astype(jnp.int32),
                    jnp.min(d, axis=-1))

        xs = x.reshape(n // block_n, block_n, x.shape[1])
        if block_unroll:
            outs = [body(xs[i]) for i in range(n // block_n)]
            labels = jnp.stack([o[0] for o in outs])
            dists = jnp.stack([o[1] for o in outs])
        else:
            labels, dists = jax.lax.map(body, xs)
        return AssignResult(labels.reshape(n), dists.reshape(n))
    d = pairwise_sqdist(x, c)
    return AssignResult(jnp.argmin(d, axis=-1).astype(jnp.int32),
                        jnp.min(d, axis=-1))


# ---------------------------------------------------------------------------
# Update step
# ---------------------------------------------------------------------------

def _accum_dtype(*dtypes):
    """Statistics accumulate in AT LEAST f32 (§Kernels-v2 precision
    policy: compute-dtype distances, f32 accumulation).  Accumulating in
    the compute dtype is a correctness bug, not a precision trade-off: a
    bf16 count (8 mantissa bits) stops incrementing at 256 — `256 + 1`
    rounds back to 256 — so any cluster beyond 256 members silently
    freezes its count and drifts its centroid.  f64 inputs keep f64."""
    return jnp.promote_types(jnp.result_type(*dtypes), jnp.float32)


def cluster_sums(x: jax.Array, labels: jax.Array, k: int):
    """Per-cluster sums (K,d) and counts (K,) via segment-sum.

    Accumulates in `_accum_dtype(x.dtype)` (>= f32) regardless of the
    compute dtype; cast at the boundary if a narrower dtype is needed."""
    acc = _accum_dtype(x.dtype)
    sums = jax.ops.segment_sum(x.astype(acc), labels, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), acc), labels,
                                 num_segments=k)
    return sums, counts


def weighted_cluster_sums(x: jax.Array, labels: jax.Array, w: jax.Array,
                          k: int):
    """Weighted per-cluster sums (K,d) and weight totals (K,).

    The masked/mini-batch generalisation of `cluster_sums`: each row
    contributes `w` times (w = 0 drops a padding row entirely; w = 1 for
    every row recovers `cluster_sums` exactly).  Accumulates >= f32 like
    `cluster_sums` — decayed streaming counts hit the same bf16 ceiling."""
    acc = _accum_dtype(x.dtype, w.dtype)
    wa = w.astype(acc)
    sums = jax.ops.segment_sum(x.astype(acc) * wa[:, None], labels,
                               num_segments=k)
    counts = jax.ops.segment_sum(wa, labels, num_segments=k)
    return sums, counts


def update_from_sums(sums: jax.Array, counts: jax.Array,
                     c_prev: jax.Array) -> jax.Array:
    """Update step (Eq. 4) given partial sums.  Empty clusters keep their
    previous centroid (the standard Lloyd convention; the paper does not
    treat empty clusters specially)."""
    safe = jnp.maximum(counts, 1.0)[:, None]
    mean = sums / safe
    return jnp.where(counts[:, None] > 0, mean, c_prev)


def update(x: jax.Array, labels: jax.Array, k: int,
           c_prev: jax.Array) -> jax.Array:
    """Update step (Eq. 4): each centroid becomes the mean of its samples.
    The mean is formed in the >= f32 accumulation dtype and cast back to
    the centroid dtype at the boundary."""
    sums, counts = cluster_sums(x, labels, k)
    return update_from_sums(sums, counts,
                            c_prev.astype(sums.dtype)).astype(c_prev.dtype)


# ---------------------------------------------------------------------------
# Energy
# ---------------------------------------------------------------------------

def energy(x: jax.Array, c: jax.Array, labels: jax.Array) -> jax.Array:
    """K-Means energy (Eq. 1) E(P, C) with a pre-computed assignment P.

    O(N d) — this is the cheap re-evaluation the paper uses to test whether
    an accelerated iterate decreases the energy (Sec. 2.1, overhead part ii).
    """
    diff = x - c[labels]
    return jnp.sum(diff * diff)


def energy_from_mindist(min_sqdist: jax.Array) -> jax.Array:
    return jnp.sum(min_sqdist)


# ---------------------------------------------------------------------------
# Ops container — dependency injection point for kernels / distribution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LloydOps:
    """DEPRECATED dependency-injection container (see DESIGN.md §Backends).

    Superseded by `repro.core.backends.Backend`, whose single-pass
    ``step()`` primitive lets the driver run one pass over X per accepted
    iteration; separate assign/update call sites cannot express that.
    Passing a LloydOps to the solvers still works — it is adapted through
    `repro.core.backends.from_lloyd_ops` with the legacy two-pass cost.

    assign_fn(x, c)            -> AssignResult
    update_fn(x, labels, k, c) -> new centroids (K,d)
    energy_fn(x, c, labels)    -> scalar energy
    all_equal_fn(a, b)         -> scalar bool (assignments identical;
                                  distributed backends psum-reduce this)
    """
    assign_fn: Callable = assign
    update_fn: Callable = update
    energy_fn: Callable = energy
    all_equal_fn: Callable = lambda a, b: jnp.all(a == b)
    # scalar cross-shard reduction (distributed backends psum); the solver
    # computes E(P^t, C^t) as sum(min_sqdist) reusing the assignment — the
    # paper's O(N) overhead argument (Sec 2.1 part ii) — then reduces it.
    reduce_scalar: Callable = lambda x: x

    def g_map(self, x: jax.Array, c: jax.Array, k: int):
        """One application of the fixed-point map G = Update o Assign.

        Returns (G(c), labels, min_sqdist)."""
        res = self.assign_fn(x, c)
        c_new = self.update_fn(x, res.labels, k, c)
        return c_new, res


DENSE_OPS = LloydOps()


def lloyd_iteration(x: jax.Array, c: jax.Array, k: int,
                    ops: LloydOps = DENSE_OPS):
    """One classical Lloyd iteration; returns (C', labels, energy(P, C))."""
    c_new, res = ops.g_map(x, c, k)
    return c_new, res.labels, energy_from_mindist(res.min_sqdist)


@partial(jax.jit, static_argnames=("k", "max_iter"))
def lloyd_kmeans(x: jax.Array, c0: jax.Array, k: int, max_iter: int = 500):
    """Baseline: plain Lloyd's algorithm run to assignment convergence.

    This is the unaccelerated reference the paper compares against
    (Table 3, "Lloyd" columns).  Returns (C, labels, energy, n_iter).
    """
    res0 = assign(x, c0)

    def cond(state):
        _, _, _, converged, t = state
        return jnp.logical_and(~converged, t < max_iter)

    def body(state):
        c, labels, _, _, t = state
        c_new = update(x, labels, k, c)
        res = assign(x, c_new)
        converged = jnp.all(res.labels == labels)
        return (c_new, res.labels, energy_from_mindist(res.min_sqdist),
                converged, t + 1)

    state = (c0, res0.labels, energy_from_mindist(res0.min_sqdist),
             jnp.array(False), jnp.array(0, jnp.int32))
    c, labels, e, _, t = jax.lax.while_loop(cond, body, state)
    return c, labels, e, t
