"""The step-primitive backend protocol for Algorithm 1 (DESIGN.md §Backends).

The paper's Sec-2.1 overhead argument prices one Algorithm-1 iteration at
one application of the fixed-point map G = Update ∘ Assign — i.e. one pass
over X — plus O(m·K·d) for the Anderson solve.  The legacy `LloydOps`
container exposed assign/update/energy as separate call sites, which forced
the driver into two to three X passes per iteration and made the fused
single-pass Pallas kernel unusable.  A `Backend`'s core op is instead

    step(x, c, k, carry) -> (StepResult(labels, min_sqdist, sums, counts,
                                        energy), carry)

one logical pass over X that returns everything an iteration needs: the
fresh assignment, the energy E(P, C) (= sum of min squared distances), and
the partial cluster statistics from which G(C) follows without touching X
again (`centroids_from_step`).  assign/update/energy remain available as
derived ops for callers that need a single piece.

``carry`` is an opaque per-backend pytree threaded through the solver loop
(default: the empty tuple).  Stateless backends ignore it; the Hamerly
backend keeps its distance bounds there so bound-based skipping survives
across iterations — including non-Lloyd centroid moves (AA steps, reverts),
whose bound update only needs the centroid drift since the previous step.

Carry vmap contract (DESIGN.md §Batching): the batched driver
(kmeans.aa_kmeans_batched) maps ``step`` over a leading restart/problem
axis, so a carry must be a pytree of fixed-shape arrays (or empty
containers) whose shapes depend only on (N, K, d) — never on data values —
and ``init_carry``/``step`` must be traceable under ``jax.vmap``.  The
driver freezes a converged restart's carry with a leaf-wise select, so a
carry must also tolerate being held constant while other restarts advance
(true for anything that is pure state, e.g. the Hamerly bounds).

Orthogonal axes, composable by construction:

    local compute — which backend (dense / blocked / pallas / fused /
                    hamerly), selected via `get_backend(name)`;
    precision     — `Precision(compute, accum)` policy applied inside the
                    backend (bf16 distance math, f32 accumulation);
    distribution  — `distribute(backend, axes)` wraps *any* local backend
                    with the psum reductions for a shard_map mesh.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import lloyd
from repro.core.lloyd import AssignResult, LloydOps, energy_from_mindist


class StepResult(NamedTuple):
    """Everything one pass over X yields for one Algorithm-1 iteration.

    labels     : (N,) int32 — fresh assignment P = Assign(X, C)
    min_sqdist : (N,) float — squared distance to the assigned centroid
                 (local rows under distribution)
    sums       : (K, d) accum-dtype per-cluster sums (reduced across shards
                 for distributed backends)
    counts     : (K,) accum-dtype per-cluster counts (reduced likewise)
    energy     : scalar E(P, C) = sum(min_sqdist) (reduced likewise)
    """
    labels: jax.Array
    min_sqdist: jax.Array
    sums: jax.Array
    counts: jax.Array
    energy: jax.Array


@dataclasses.dataclass(frozen=True)
class Precision:
    """Compute-vs-accumulate dtype policy applied inside a backend.

    compute — dtype for the distance computation (None: the input dtype;
              bf16 halves the X stream on TPU, distances still accumulate
              in f32 via preferred_element_type on the MXU paths).
    accum   — dtype for cluster sums/counts and the energy (None: f32,
              matching the Pallas kernels' accumulators).  ``accum_dtype``
              floors the request at f32 (and `lloyd.cluster_sums`
              promotes internally for direct callers): a sub-f32 count
              saturates — bf16 stops counting at 256 members — which is a
              correctness bug, not a precision trade-off, so every step
              slot (single, batched one-hot, weighted minibatch)
              accumulates at >= f32.
    """
    compute: Optional[Any] = None
    accum: Optional[Any] = None

    def compute_cast(self, a: jax.Array) -> jax.Array:
        return a if self.compute is None else a.astype(self.compute)

    @property
    def accum_dtype(self):
        if self.accum is None:
            return jnp.float32
        return jnp.promote_types(self.accum, jnp.float32)


DEFAULT_PRECISION = Precision()


def _default_init_carry(x, c, k):
    return ()


def _default_finalize(x, res: StepResult, k: int, c_prev: jax.Array):
    """G(C) from the step's partial stats — no further pass over X."""
    c_new = lloyd.update_from_sums(res.sums, res.counts,
                                   c_prev.astype(res.sums.dtype))
    return c_new.astype(c_prev.dtype)


def _default_all_equal(a, b):
    return jnp.all(a == b)


def _identity(s):
    return s


@dataclasses.dataclass(frozen=True)
class Backend:
    """A local-compute engine for Algorithm 1, keyed by the step primitive.

    Instances are immutable and hashable, so a Backend can be a static jit
    argument exactly like the legacy LloydOps container.  Use the
    module-level factories / `get_backend` rather than constructing
    directly; `distribute` wraps any instance for a shard_map mesh.
    """
    name: str
    # (x, c, k, carry) -> (StepResult, carry): ONE logical pass over X.
    step_fn: Callable = None
    # Optional natively-batched step: (x, cs, k, carries, w=None) ->
    # (StepResult with a leading R axis, carries), where cs is (R, K, d)
    # and x is (N, d) shared or (R, N, d) per-problem.  The batched driver
    # prefers this over jax.vmap(step_fn) when set — a hand-batched
    # formulation can share the X stream across restarts and use matmul
    # cluster stats where the vmapped scatter would serialise; the
    # pallas/fused engines run all R restarts as the leading grid axis of
    # ONE kernel launch instead of vmapping pl.pallas_call.  Must match
    # step_fn's semantics per row (same labels/energy up to reduction
    # order).  ``w`` (R, N) >= 0, when given, scales each row's
    # contribution to sums/counts/energy per problem — the hierarchy
    # engine's padding mask (w = 0 rows vanish exactly, DESIGN.md
    # §Hierarchy); labels/min_sqdist stay per-row and unweighted, exactly
    # the minibatch contract lifted to the restart axis.
    batched_step_fn: Optional[Callable] = None
    # Optional weighted step for streaming chunks (DESIGN.md §Streaming):
    # (x, c, k, w, carry) -> (StepResult, carry), where w (N,) >= 0 scales
    # each row's contribution to sums/counts/energy (w = 0 marks a padding
    # row).  labels and min_sqdist stay per-row and unweighted.  When None,
    # ``minibatch_step`` falls back to step_fn for the assignment plus one
    # weighted segment-sum over the chunk to reweight the stats; the
    # dense/blocked/pallas/fused engines all weight natively in-pass.
    minibatch_step_fn: Optional[Callable] = None
    # (x, labels, k) -> (sums, counts): partial stats of a known assignment
    # (the update half of G; used by the derived update op and by
    # distribute's psum wrapping).
    stats_fn: Callable = None
    # (x, c) -> AssignResult: standalone assignment (predict / legacy).
    assign_fn: Callable = None
    # (x, c, labels) -> scalar: FULLY-REDUCED energy of a fixed assignment
    # (distributed backends psum inside; do not compose with reduce_scalar).
    energy_fn: Callable = lloyd.energy
    all_equal_fn: Callable = _default_all_equal
    reduce_scalar: Callable = _identity
    init_carry_fn: Callable = _default_init_carry
    # (x, res, k, c_prev) -> next centroids; default consumes res.sums.
    finalize_fn: Callable = _default_finalize
    precision: Precision = DEFAULT_PRECISION
    # mesh axes this backend's step already psum-reduces over; set by
    # `distribute` — empty for local backends.
    axes: Tuple[str, ...] = ()

    # -- core op ----------------------------------------------------------

    def step(self, x, c, k, carry=()):
        return self.step_fn(x, c, k, carry)

    def batched_step(self, x, cs, k, carries, x_batched: bool = False,
                     w=None):
        """R restarts' steps at once; falls back to vmapping ``step``.
        ``x_batched`` marks x as (R, N, d) rather than shared (N, d);
        ``w`` (R, N) adds per-problem row weights (see batched_step_fn)."""
        if self.batched_step_fn is not None:
            return self.batched_step_fn(x, cs, k, carries, w=w)
        xa = 0 if x_batched else None
        if w is None:
            return jax.vmap(lambda xx, cc, cr: self.step_fn(xx, cc, k, cr),
                            in_axes=(xa, 0, 0))(x, cs, carries)
        # weighted fallback: the minibatch slot per problem.  Valid as the
        # batched slot because the hierarchy driver's per-problem rows are
        # FIXED across steps (unlike streaming chunks), so a data-dependent
        # carry keeps meaning between calls.
        return jax.vmap(
            lambda xx, cc, ww, cr: self.minibatch_step(xx, cc, k, ww, cr),
            in_axes=(xa, 0, 0, 0))(x, cs, w, carries)

    def minibatch_step(self, x, c, k, w, carry=()):
        """Weighted single pass over a chunk (DESIGN.md §Streaming).

        Row weights ``w`` scale each row's contribution to the cluster
        stats and the energy — the remainder-padded rows of a streaming
        chunk carry w = 0 and vanish from every reduction.  Chunk contents
        change between calls, so a data-dependent carry (e.g. Hamerly
        bounds, which are per-row state of *this* chunk's rows) must be
        re-initialised per chunk by the caller; the returned carry is only
        meaningful while the same chunk is re-stepped."""
        if self.minibatch_step_fn is not None:
            return self.minibatch_step_fn(x, c, k, w, carry)
        res, carry = self.step_fn(x, c, k, carry)
        wa = w.astype(res.sums.dtype)
        sums, counts = lloyd.weighted_cluster_sums(
            x.astype(res.sums.dtype), res.labels, wa, k)
        energy = jnp.sum(res.min_sqdist.astype(res.energy.dtype) * wa)
        return StepResult(res.labels, res.min_sqdist, sums, counts,
                          energy), carry

    def init_carry(self, x, c, k):
        return self.init_carry_fn(x, c, k)

    def centroids_from_step(self, x, res: StepResult, k: int, c_prev):
        return self.finalize_fn(x, res, k, c_prev)

    # -- derived ops ------------------------------------------------------

    def assign(self, x, c) -> AssignResult:
        return self.assign_fn(x, c)

    def update(self, x, labels, k, c_prev):
        sums, counts = self.stats_fn(x, labels, k)
        c_new = lloyd.update_from_sums(sums, counts,
                                       c_prev.astype(sums.dtype))
        return c_new.astype(c_prev.dtype)

    def energy(self, x, c, labels):
        return self.energy_fn(x, c, labels)

    def all_equal(self, a, b):
        return self.all_equal_fn(a, b)

    def g_map(self, x, c, k):
        """One fixed-point map application; returns (G(c), StepResult)."""
        res, _ = self.step(x, c, k, self.init_carry(x, c, k))
        return self.centroids_from_step(x, res, k, c), res


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}
_INSTANCES: dict = {}


def register_backend(name: str, factory: Callable[..., Backend]) -> None:
    """Register a backend factory under a string key.  Re-registering a
    name replaces the factory and drops any cached instances built by the
    previous one."""
    _REGISTRY[name] = factory
    for key in [k for k in _INSTANCES if k[0] == name]:
        del _INSTANCES[key]


def backend_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(name: str, **opts) -> Backend:
    """Construct (and cache) a backend by name: "dense" | "blocked" |
    "pallas" | "fused" | "hamerly".  Caching keeps the returned object
    identity stable so jit'd solvers keyed on the backend do not recompile
    per call site."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{', '.join(backend_names())}")
    try:
        key = (name, tuple(sorted(opts.items())))
        cached = _INSTANCES.get(key)
    except TypeError:  # unhashable option (e.g. a callable): build fresh
        return _REGISTRY[name](**opts)
    if cached is None:
        cached = _INSTANCES[key] = _REGISTRY[name](**opts)
    return cached


# ---------------------------------------------------------------------------
# Distribution combinator
# ---------------------------------------------------------------------------

def distribute(backend: Backend, axes: Sequence[str]) -> Backend:
    """Wrap *any* local backend for execution inside shard_map.

    The returned backend's step runs the local step on the shard-local rows
    and psum-reduces the (K,(d+1))-sized stats plus the scalar energy over
    ``axes`` — the only communication of the solver.  labels/min_sqdist
    (and any carry, e.g. Hamerly bounds) stay shard-local.  Convergence
    checks and standalone energies reduce likewise.
    """
    if backend.axes:
        raise ValueError(
            f"backend {backend.name!r} is already distributed over "
            f"{backend.axes}; wrapping it again would double-psum the "
            f"stats and inflate the reported energy")
    axes = tuple(axes)

    def reduce_carry(carry):
        """Per-row bounds stay shard-local, but the BoundStats scalars a
        bound backend reports are per-shard fractions — pmean them so
        every shard carries the GLOBAL elimination fractions (and so the
        carry leaves really are replicated where `loop_state_specs`
        classifies them as such).  The group drift itself needs no
        collective: C is replicated, so every shard derives identical
        drifts."""
        from repro.core.backends.bounds import BoundStats

        def fix(node):
            if isinstance(node, BoundStats):
                return jax.tree_util.tree_map(
                    lambda a: jax.lax.pmean(a, axes), node)
            return node

        return jax.tree_util.tree_map(
            fix, carry, is_leaf=lambda n: isinstance(n, BoundStats))

    def step_fn(x, c, k, carry):
        res, carry = backend.step_fn(x, c, k, carry)
        return StepResult(
            labels=res.labels,
            min_sqdist=res.min_sqdist,
            sums=jax.lax.psum(res.sums, axes),
            counts=jax.lax.psum(res.counts, axes),
            energy=jax.lax.psum(res.energy, axes)), reduce_carry(carry)

    # The local batched step (when present) must be re-wrapped so its
    # (R, K, d+1)-stats psum too — one collective covers all R restarts.
    # Leaving the inherited local batched_step_fn in place would silently
    # skip the reduction; when the local backend has none, None makes the
    # batched driver fall back to vmapping the psum-wrapped step above.
    if backend.batched_step_fn is not None:
        def batched_step_fn(x, cs, k, carries, w=None):
            res, carries = backend.batched_step_fn(x, cs, k, carries, w=w)
            return StepResult(
                labels=res.labels,
                min_sqdist=res.min_sqdist,
                sums=jax.lax.psum(res.sums, axes),
                counts=jax.lax.psum(res.counts, axes),
                energy=jax.lax.psum(res.energy, axes)), reduce_carry(carries)
    else:
        batched_step_fn = None

    # The streaming chunk step reduces exactly like the full step: one
    # (K,(d+1))-stat psum plus the scalar chunk energy per chunk — the
    # only communication of the streaming solver (DESIGN.md §Streaming).
    # Wrapping the *method* (not the field) keeps the generic weighted
    # fallback local-then-reduced even for backends without a native
    # minibatch_step_fn.
    def minibatch_step_fn(x, c, k, w, carry):
        res, carry = backend.minibatch_step(x, c, k, w, carry)
        return StepResult(
            labels=res.labels,
            min_sqdist=res.min_sqdist,
            sums=jax.lax.psum(res.sums, axes),
            counts=jax.lax.psum(res.counts, axes),
            energy=jax.lax.psum(res.energy, axes)), reduce_carry(carry)

    def stats_fn(x, labels, k):
        sums, counts = backend.stats_fn(x, labels, k)
        return jax.lax.psum(sums, axes), jax.lax.psum(counts, axes)

    def energy_fn(x, c, labels):
        return jax.lax.psum(backend.energy_fn(x, c, labels), axes)

    def all_equal_fn(a, b):
        neq = jnp.sum((a != b).astype(jnp.int32))
        return jax.lax.psum(neq, axes) == 0

    return dataclasses.replace(
        backend,
        name=f"{backend.name}@{'x'.join(axes)}",
        step_fn=step_fn, batched_step_fn=batched_step_fn,
        minibatch_step_fn=minibatch_step_fn,
        stats_fn=stats_fn, energy_fn=energy_fn,
        all_equal_fn=all_equal_fn,
        reduce_scalar=lambda s: jax.lax.psum(s, axes),
        axes=axes)


# ---------------------------------------------------------------------------
# Legacy LloydOps adapter (deprecation shim)
# ---------------------------------------------------------------------------

_OPS_ADAPTERS: "weakref.WeakKeyDictionary[LloydOps, Backend]" = \
    weakref.WeakKeyDictionary()


def from_lloyd_ops(ops: LloydOps) -> Backend:
    """Adapt a legacy LloydOps container to the Backend protocol.

    The legacy update_fn may hide reductions (the old distributed ops psum
    inside it), so the step's sums/counts are the *local* cluster stats and
    `centroids_from_step` routes through ops.update_fn — preserving the old
    container's exact semantics and cost (the stats are dead code under jit
    on this path).  New code should use `get_backend` / `distribute`.

    Adapters are memoised per LloydOps instance (weakly, so factories that
    build a fresh container per call do not accumulate entries) to keep the
    returned object identity stable for jit's static-argument cache.
    """
    cached = _OPS_ADAPTERS.get(ops)
    if cached is not None:
        return cached

    def step_fn(x, c, k, carry):
        res = ops.assign_fn(x, c)
        sums, counts = lloyd.cluster_sums(x.astype(jnp.float32), res.labels,
                                          k)
        e = ops.reduce_scalar(energy_from_mindist(res.min_sqdist))
        return StepResult(res.labels, res.min_sqdist, sums, counts, e), carry

    def finalize_fn(x, res, k, c_prev):
        return ops.update_fn(x, res.labels, k, c_prev)

    def stats_fn(x, labels, k):
        return lloyd.cluster_sums(x.astype(jnp.float32), labels, k)

    backend = Backend(name="lloyd-ops-shim", step_fn=step_fn,
                      stats_fn=stats_fn, assign_fn=ops.assign_fn,
                      energy_fn=ops.energy_fn,
                      all_equal_fn=ops.all_equal_fn,
                      reduce_scalar=ops.reduce_scalar,
                      finalize_fn=finalize_fn)
    _OPS_ADAPTERS[ops] = backend
    return backend


# ---------------------------------------------------------------------------
# Instrumentation (pass counting — tests/test_backends.py)
# ---------------------------------------------------------------------------

def instrument(backend: Backend, on_step: Callable[[], None]) -> Backend:
    """Wrap a backend so ``on_step`` fires (host-side) once per *executed*
    step — i.e. per pass over X — including inside jit / lax.cond /
    lax.while_loop, where only the taken branch triggers the callback."""

    def step_fn(x, c, k, carry):
        jax.debug.callback(lambda: on_step())
        return backend.step_fn(x, c, k, carry)

    if backend.batched_step_fn is not None:
        def batched_step_fn(x, cs, k, carries, w=None):
            jax.debug.callback(lambda: on_step())
            return backend.batched_step_fn(x, cs, k, carries, w=w)
    else:
        batched_step_fn = None

    # A native minibatch step is a pass over the chunk; without one the
    # fallback routes through the counted step_fn above, so chunk passes
    # are counted either way.
    if backend.minibatch_step_fn is not None:
        def minibatch_step_fn(x, c, k, w, carry):
            jax.debug.callback(lambda: on_step())
            return backend.minibatch_step_fn(x, c, k, w, carry)
    else:
        minibatch_step_fn = None

    return dataclasses.replace(backend, name=f"{backend.name}+count",
                               step_fn=step_fn,
                               batched_step_fn=batched_step_fn,
                               minibatch_step_fn=minibatch_step_fn)
