"""Hamerly-bound backend: the paper's CPU assignment strategy as a Backend.

The paper implements the Assignment-Step with Hamerly's bounds (in the
spirit of Newling & Fleuret 2016's accurate-bound family): an upper bound
u_i on the distance to the assigned centroid and a lower bound l_i on the
second-closest let most samples skip the O(K) scan after a centroid move.
`core/hamerly.py` keeps the legacy island driver as a thin delegate; the
bounds themselves live in the backend's ``carry``, so Hamerly assignment
composes with the Anderson-accelerated driver, the distribute combinator,
and every other orthogonal axis of the engine.

The carry follows the shared contract of `backends/bounds.py` — with the
hamerly-specific twist that ``lower`` is (N,), a single bound on the
SECOND-closest centroid (exclusive of the assigned one), rather than the
group family's (N, G) inclusive bounds.  The drift maintenance is the
same module's and only needs the per-centroid move between *consecutive
step calls* — not a Lloyd move — so it remains valid when the driver
jumps to an accelerated iterate or reverts to the fallback:

    u_i += |c_new[a_i] - c_old[a_i]|,   l_i -= max_j |c_new[j] - c_old[j]|

(triangle inequality, independent of how C moved).  The exact distance to
the assigned centroid is recomputed every step (O(N d), needed anyway for
the energy the accept test consumes), so u is always tight and min_sqdist
is exact for every row.

As in `core/hamerly.py`, this is a *vectorised-masked* formulation: the
full scan is computed densely and applied under the mask.  The mask is
where the skip-work win lives on CPU/sparse executors; on TPU the same
elimination is realised for real by the ``fused_bounds`` engine, whose
kernel skips whole centroid *tiles* on the group-bound variant of this
carry (`kernels/fused_lloyd.py`) — the per-step ``BoundStats`` in the
carry report the eliminated fraction either way.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lloyd
from repro.core.backends.base import (Backend, Precision, StepResult,
                                      DEFAULT_PRECISION)
from repro.core.backends.bounds import BoundStats, centroid_drift
from repro.core.lloyd import pairwise_sqdist


def _full_scan(x, c):
    """Closest two centroids per row via a top-2 min reduction.

    Hamerly's bounds only ever need (argmin, min, second-min) of each
    distance row; a full `argsort` is O(K log K) work and an (N, K)
    permutation materialisation for three columns of output.  Two masked
    min-reductions are O(K) and keep the argmin/argsort tie convention
    (first index wins) so assignments are unchanged."""
    d = jnp.sqrt(pairwise_sqdist(x, c))
    lab = jnp.argmin(d, axis=1).astype(jnp.int32)
    d1 = jnp.min(d, axis=1)
    k = c.shape[0]
    others = jnp.where(jnp.arange(k)[None, :] == lab[:, None], jnp.inf, d)
    d2 = jnp.min(others, axis=1)
    return lab, d1, d2


def hamerly_drift(labels, upper, lower, c_new, c_old):
    """Post-move bound update (u += |dc_a|, l -= max|dc|), shared with the
    legacy `core/hamerly.py` driver so there is one drift implementation."""
    drift = centroid_drift(c_new, c_old)
    return upper + drift[labels], lower - jnp.max(drift)


def hamerly_backend(precision: Precision = DEFAULT_PRECISION) -> Backend:
    def init_carry_fn(x, c, k):
        n = x.shape[0]
        inf = jnp.full((n,), jnp.inf, jnp.float32)
        # upper = +inf forces a full scan on the first step (no valid bounds
        # yet); drift against c_last = c is zero so the bounds stay +inf/0.
        return (jnp.zeros((n,), jnp.int32), inf,
                jnp.zeros((n,), jnp.float32), c.astype(jnp.float32),
                BoundStats.zeros())

    def step_fn(x, c, k, carry):
        labels0, upper, lower, c_last, _ = carry
        # Honour the compute policy by rounding the inputs to the compute
        # dtype first; the bound/distance arithmetic itself then runs in
        # f32 — bounds must stay monotone under the drift updates, which
        # low-precision accumulation would not guarantee.
        xf = precision.compute_cast(x).astype(jnp.float32)
        cf = precision.compute_cast(c).astype(jnp.float32)

        upper, lower = hamerly_drift(labels0, upper, lower, cf, c_last)

        cc = jnp.sqrt(pairwise_sqdist(cf, cf))
        cc = jnp.where(jnp.eye(k, dtype=bool), jnp.inf, cc)
        s_half = 0.5 * jnp.min(cc, axis=1)                         # (K,)

        # Exact distance to the assigned centroid: tightens u and supplies
        # the exact per-row energy term when the assignment is kept.
        d_assigned = jnp.sqrt(jnp.sum((xf - cf[labels0]) ** 2, axis=-1))
        m = jnp.maximum(s_half[labels0], lower)
        needs = d_assigned > m                                     # scan mask

        lab_f, u_f, l_f = _full_scan(xf, cf)
        labels = jnp.where(needs, lab_f, labels0)
        upper_n = jnp.where(needs, u_f, d_assigned)
        lower_n = jnp.where(needs, l_f, lower)

        elim = 1.0 - jnp.mean(needs.astype(jnp.float32))
        stats = BoundStats(elim, elim)   # one group: row == scan unit

        mind = (upper_n * upper_n).astype(precision.accum_dtype)
        sums, counts = lloyd.cluster_sums(x.astype(precision.accum_dtype),
                                          labels, k)
        res = StepResult(labels, mind, sums, counts, jnp.sum(mind))
        return res, (labels, upper_n, lower_n, cf, stats)

    def stats_fn(x, labels, k):
        return lloyd.cluster_sums(x.astype(precision.accum_dtype), labels, k)

    return Backend(name="hamerly",
                   step_fn=step_fn,
                   stats_fn=stats_fn,
                   assign_fn=lloyd.assign,
                   init_carry_fn=init_carry_fn,
                   precision=precision)
