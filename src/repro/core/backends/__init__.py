"""Composable backend engine for Algorithm 1 (DESIGN.md §Backends).

    from repro.core.backends import get_backend, distribute, Precision

    backend = get_backend("fused")                       # local compute
    backend = get_backend("dense",
                          precision=Precision(jnp.bfloat16))  # precision
    ops = distribute(backend, ("pod", "data"))           # any mesh

Step slots per backend: ``step`` (one pass over X), ``batched_step``
(R restarts at once), ``minibatch_step`` (weighted chunk pass for the
streaming solver; DESIGN.md §Streaming).  tests/test_conformance.py pins
every registered backend x slot x precision against the kernels/ref.py
oracle.

Registered backends:

    dense        — jnp reference semantics (the oracle; legacy DENSE_OPS)
    blocked      — row-blocked distances, bounded (block_n, K) intermediate
    pallas       — separate tiled assignment/update kernels (decomposed)
    fused        — single-pass Pallas kernel: one X read per accepted
                   iteration at arbitrary K (k-tiled; DESIGN.md §Kernels-v2)
    hamerly      — scalar second-closest bound carried across iterations
    elkan        — per-(row, k-group) lower bounds + centre-centre gate
                   (groups sized like the fused kernel's k-tiles)
    yinyang      — pure group filtering, no K x K term (t = K/10 groups)
    fused_bounds — the fused kernel consuming the group bounds to SKIP
                   whole centroid tiles via a tile-level predicate
                   (DESIGN.md §Bounds)

All three Pallas engines fill every step slot natively: batched steps
run R restarts as the kernels' leading grid axis, minibatch steps fold
row weights into the stats in-pass.  The bound family threads its carry
— (labels, upper, lower, c_last, BoundStats) — through the solver loop;
`distribute()` keeps the bounds shard-local and pmean's the stats.

Every bound backend also registers a ``<name>_reorder`` variant wrapping
it in the locality engine (churn-triggered cluster-sorted row reordering,
DESIGN.md §Locality) — original-order outputs stay bit-identical, the
kernel sees locality-ordered rows.  Reorder policy knobs (``warmup``,
``churn_threshold``, ``sort_tile``) pass through `get_backend` opts; the
rest go to the inner backend's factory.
"""

from repro.core.backends.base import (Backend, Precision,        # noqa: F401
                                      StepResult, backend_names,
                                      distribute, from_lloyd_ops,
                                      get_backend, instrument,
                                      register_backend)
from repro.core.backends.bounds import BoundStats                # noqa: F401
from repro.core.backends.dense import (blocked_backend,          # noqa: F401
                                       dense_backend)
from repro.core.backends.elkan import elkan_backend              # noqa: F401
from repro.core.backends.hamerly import hamerly_backend          # noqa: F401
from repro.core.backends.pallas import (fused_backend,           # noqa: F401
                                        fused_bounds_backend,
                                        pallas_backend)
from repro.core.backends.yinyang import yinyang_backend          # noqa: F401

register_backend("dense", dense_backend)
register_backend("blocked", blocked_backend)
register_backend("pallas", pallas_backend)
register_backend("fused", fused_backend)
register_backend("hamerly", hamerly_backend)
register_backend("elkan", elkan_backend)
register_backend("yinyang", yinyang_backend)
register_backend("fused_bounds", fused_bounds_backend)


def _reorder_factory(inner_name):
    def factory(*, warmup=None, churn_threshold=None, sort_tile=None,
                **inner_opts):
        from repro.core.locality import ReorderConfig, reorder_backend
        cfg = ReorderConfig()
        if warmup is not None:
            cfg = _dc.replace(cfg, warmup=warmup)
        if churn_threshold is not None:
            cfg = _dc.replace(cfg, churn_threshold=churn_threshold)
        if sort_tile is not None:
            cfg = _dc.replace(cfg, sort_tile=sort_tile)
        return reorder_backend(get_backend(inner_name, **inner_opts), cfg)
    return factory


import dataclasses as _dc  # noqa: E402

for _name in ("hamerly", "elkan", "yinyang", "fused_bounds"):
    register_backend(f"{_name}_reorder", _reorder_factory(_name))
del _name
