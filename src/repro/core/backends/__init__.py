"""Composable backend engine for Algorithm 1 (DESIGN.md §Backends).

    from repro.core.backends import get_backend, distribute, Precision

    backend = get_backend("fused")                       # local compute
    backend = get_backend("dense",
                          precision=Precision(jnp.bfloat16))  # precision
    ops = distribute(backend, ("pod", "data"))           # any mesh

Step slots per backend: ``step`` (one pass over X), ``batched_step``
(R restarts at once), ``minibatch_step`` (weighted chunk pass for the
streaming solver; DESIGN.md §Streaming).  tests/test_conformance.py pins
every registered backend x slot x precision against the kernels/ref.py
oracle.

Registered backends:

    dense        — jnp reference semantics (the oracle; legacy DENSE_OPS)
    blocked      — row-blocked distances, bounded (block_n, K) intermediate
    pallas       — separate tiled assignment/update kernels (decomposed)
    fused        — single-pass Pallas kernel: one X read per accepted
                   iteration at arbitrary K (k-tiled; DESIGN.md §Kernels-v2)
    hamerly      — scalar second-closest bound carried across iterations
    elkan        — per-(row, k-group) lower bounds + centre-centre gate
                   (groups sized like the fused kernel's k-tiles)
    yinyang      — pure group filtering, no K x K term (t = K/10 groups)
    fused_bounds — the fused kernel consuming the group bounds to SKIP
                   whole centroid tiles via a tile-level predicate
                   (DESIGN.md §Bounds)

All three Pallas engines fill every step slot natively: batched steps
run R restarts as the kernels' leading grid axis, minibatch steps fold
row weights into the stats in-pass.  The bound family threads its carry
— (labels, upper, lower, c_last, BoundStats) — through the solver loop;
`distribute()` keeps the bounds shard-local and pmean's the stats.
"""

from repro.core.backends.base import (Backend, Precision,        # noqa: F401
                                      StepResult, backend_names,
                                      distribute, from_lloyd_ops,
                                      get_backend, instrument,
                                      register_backend)
from repro.core.backends.bounds import BoundStats                # noqa: F401
from repro.core.backends.dense import (blocked_backend,          # noqa: F401
                                       dense_backend)
from repro.core.backends.elkan import elkan_backend              # noqa: F401
from repro.core.backends.hamerly import hamerly_backend          # noqa: F401
from repro.core.backends.pallas import (fused_backend,           # noqa: F401
                                        fused_bounds_backend,
                                        pallas_backend)
from repro.core.backends.yinyang import yinyang_backend          # noqa: F401

register_backend("dense", dense_backend)
register_backend("blocked", blocked_backend)
register_backend("pallas", pallas_backend)
register_backend("fused", fused_backend)
register_backend("hamerly", hamerly_backend)
register_backend("elkan", elkan_backend)
register_backend("yinyang", yinyang_backend)
register_backend("fused_bounds", fused_bounds_backend)
