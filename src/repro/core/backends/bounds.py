"""Shared carry contract for the bound-based distance-elimination backends
(DESIGN.md §Bounds).

Three backends maintain triangle-inequality bounds across step calls —
``hamerly`` (scalar second-closest bound), ``elkan`` (per-row x per-group
lower bounds plus the centre-centre gate) and ``yinyang`` (pure group
filtering) — and the ``fused_bounds`` Pallas engine consumes the same
bounds to skip whole centroid tiles inside the kernel.  This module is the
one place the bound algebra lives:

    carry = (labels, upper, lower, c_last, BoundStats)

    labels : (N,)    int32  assignment the bounds are valid for
    upper  : (N,)    f32    u_i >= d(x_i, c_{labels_i})       (Euclidean)
    lower  : (N, G)  f32    l_{i,g} <= min_{j in group g} d(x_i, c_j)
             — or (N,) for hamerly, where l_i bounds the SECOND-closest
    c_last : (K, d)  f32    centroids the step last saw (drift anchor)
    stats  : BoundStats     work-elimination observability (below)

The lower bounds here are *inclusive*: l_{i,g} bounds the min over ALL
centroids of group g, including the assigned one.  The owner group then
always satisfies l_g <= d(x, c_a) <= u, so the scan/tile-skip predicate
``l_g <= u`` can never skip a row's own group — which is what makes the
masked scan (and the kernel's tile skip) *exact*: every centroid in a
skipped group has d(x, c_j) >= l_g > u >= d(x, c_a), strictly above the
running min, so it can neither win nor tie the argmin.

Drift maintenance (valid for ARBITRARY centroid moves — Lloyd updates,
accepted Anderson jumps, and fallback reverts alike, by the triangle
inequality against the move c_last -> c):

    u_i  += |c_new[a_i] - c_old[a_i]|
    l_g  -= max_{j in g} |c_new[j] - c_old[j]|

Groups are contiguous index ranges of ``gs`` centroids — group g covers
[g*gs, (g+1)*gs) — so a group IS a k-tile of the fused kernel when
gs == tk, and the kernel's per-(row-tile, k-tile) skip predicate consumes
these bounds directly.

Under ``distribute()`` the carry stays shard-local except the BoundStats
scalars, which are pmean'd so every shard reports the global elimination
fractions (the drift itself is shard-invariant: C is replicated).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lloyd
from repro.core.backends.base import (Backend, Precision, StepResult,
                                      DEFAULT_PRECISION)
from repro.core.lloyd import pairwise_sqdist
from repro.kernels import tiles


class BoundStats(NamedTuple):
    """Per-step work-elimination fractions, carried so the traced driver
    (and `distribute()`) can observe bound efficacy without extra passes.

    eliminated_frac : () f32 — fraction of rows whose assignment was
        settled without scanning any group beyond the owner's (for the
        kernel engine, where row granularity is lost, this equals
        skipped_frac).
    skipped_frac    : () f32 — fraction of (row, group) scan units —
        (row-tile, k-tile) cells for the kernel — that were skipped.
    """
    eliminated_frac: jax.Array
    skipped_frac: jax.Array

    @classmethod
    def zeros(cls) -> "BoundStats":
        z = jnp.zeros((), jnp.float32)
        return cls(z, z)


def extract_stats(carry) -> Optional[BoundStats]:
    """The BoundStats node of a backend carry, or None for stateless /
    non-bound backends.  Works on any pytree nesting of the carry."""
    found = []

    def visit(node):
        if isinstance(node, BoundStats):
            found.append(node)
        return node

    jax.tree_util.tree_map(visit, carry,
                           is_leaf=lambda n: isinstance(n, BoundStats))
    return found[0] if found else None


# ---------------------------------------------------------------------------
# Group layout
# ---------------------------------------------------------------------------

def resolve_group_size(k: int, group_size: Optional[int],
                       policy: str = "tile") -> int:
    """Centroids per group.  An explicit ``group_size`` wins; otherwise
    "tile" sizes groups like the fused kernel's default k-tile (so CPU
    bounds and kernel tiles agree — one group per k-tile), and "yinyang"
    uses the classic t = ceil(K/10) groups."""
    if group_size is not None:
        return max(1, min(int(group_size), k))
    if policy == "tile":
        return min(tiles.MAX_TILE, tiles.round_up(k, tiles.sublane(4)))
    if policy == "yinyang":
        g = max(1, -(-k // 10))
        return -(-k // g)
    raise ValueError(f"unknown group-size policy {policy!r}")


def group_layout(k: int, gs: int) -> Tuple[int, int]:
    """(n_groups, group_size) for contiguous groups of ``gs`` centroids."""
    return -(-k // gs), gs


def group_ids(k: int, gs: int) -> jax.Array:
    return (jnp.arange(k) // gs).astype(jnp.int32)


def group_max(v: jax.Array, g: int, gs: int) -> jax.Array:
    """(K,) -> (G,) max over each contiguous group (pad with 0: padding
    never raises a drift max, since drifts are >= 0)."""
    vp = jnp.pad(v, (0, g * gs - v.shape[0]))
    return jnp.max(vp.reshape(g, gs), axis=1)


def group_min(d: jax.Array, g: int, gs: int) -> jax.Array:
    """(N, K) -> (N, G) min over each contiguous group (pad with +inf)."""
    n, k = d.shape
    dp = jnp.pad(d, ((0, 0), (0, g * gs - k)), constant_values=jnp.inf)
    return jnp.min(dp.reshape(n, g, gs), axis=2)


# ---------------------------------------------------------------------------
# Bound maintenance
# ---------------------------------------------------------------------------

def centroid_drift(c_new: jax.Array, c_old: jax.Array) -> jax.Array:
    """Per-centroid Euclidean move |c_new[j] - c_old[j]| — the only input
    the bound update needs, so it is agnostic to HOW C moved (Lloyd,
    accepted AA jump, or revert)."""
    return jnp.sqrt(jnp.sum((c_new - c_old) ** 2, axis=-1))


def drift_update(labels, upper, lower, drift, g: int, gs: int):
    """Triangle-inequality bound update for an arbitrary centroid move."""
    upper = upper + drift[labels]
    lower = lower - group_max(drift, g, gs)[None, :]
    return upper, lower


def init_carry(x, c, k: int, gs: int):
    """upper = +inf forces a full scan on the first step (no valid bounds
    yet); lower = 0 is trivially valid (distances are non-negative)."""
    n = x.shape[0]
    g, _ = group_layout(k, gs)
    return (jnp.zeros((n,), jnp.int32),
            jnp.full((n,), jnp.inf, jnp.float32),
            jnp.zeros((n, g), jnp.float32),
            c.astype(jnp.float32),
            BoundStats.zeros())


# ---------------------------------------------------------------------------
# Shared group-filtered step (elkan / yinyang)
# ---------------------------------------------------------------------------

def make_group_bound_backend(name: str, precision: Precision,
                             group_size: Optional[int], policy: str,
                             center_gate: bool) -> Backend:
    """The group-filtered bound step shared by elkan and yinyang.

    Both maintain the carry above and scan only the groups whose lower
    bound could beat the exact distance to the assigned centroid; elkan
    additionally prices the K x K centre-centre matrix for the classic
    global gate (u <= s(a) = half the distance from c_a to its nearest
    other centroid => no centroid can beat a, skip everything), while
    yinyang stays O(K d) per step outside the masked scan.

    Like the hamerly backend this is a vectorised-masked formulation: the
    distance matrix is computed densely and applied under the need mask —
    but the carry/bound algebra is exactly what a sparse executor (or the
    fused_bounds kernel, which shares this module) uses to *actually*
    skip the work, and the per-group bounds written back for skipped
    groups are the drift-updated ones, never the dense recomputation, so
    trajectories match a genuinely skipping implementation bit-for-bit.
    """

    def gs_of(k):
        return resolve_group_size(k, group_size, policy)

    def init_carry_fn(x, c, k):
        return init_carry(x, c, k, gs_of(k))

    def step_fn(x, c, k, carry):
        labels0, upper, lower, c_last, _ = carry
        g, gs = group_layout(k, gs_of(k))
        # Compute policy as in hamerly: inputs rounded to the compute
        # dtype, bound/distance arithmetic in f32 (bounds must stay
        # monotone under the drift updates).
        xf = precision.compute_cast(x).astype(jnp.float32)
        cf = precision.compute_cast(c).astype(jnp.float32)
        n = xf.shape[0]

        drift = centroid_drift(cf, c_last)
        upper, lower = drift_update(labels0, upper, lower, drift, g, gs)

        # Exact distance to the assigned centroid — O(N d), recomputed
        # every step: it tightens u, decides the group filter, and keeps
        # min_sqdist/energy exact for the driver's accept test.
        d = jnp.sqrt(pairwise_sqdist(xf, cf))                 # (N, K)
        d_a = jnp.take_along_axis(d, labels0[:, None], axis=1)[:, 0]

        need_g = lower <= d_a[:, None]                        # (N, G)
        if center_gate:
            cc = jnp.sqrt(pairwise_sqdist(cf, cf))
            cc = jnp.where(jnp.eye(k, dtype=bool), jnp.inf, cc)
            s_half = 0.5 * jnp.min(cc, axis=1)                # (K,)
            # u <= s(a): no other centroid can be closer — skip even the
            # owner group (the assignment provably stands).
            safe = d_a <= s_half[labels0]
            need_g = jnp.logical_and(need_g, ~safe[:, None])

        gid = group_ids(k, gs)                                # (K,)
        owner_col = jnp.arange(k)[None, :] == labels0[:, None]
        cand = jnp.logical_or(need_g[:, gid], owner_col)
        dm = jnp.where(cand, d, jnp.inf)
        labels = jnp.argmin(dm, axis=1).astype(jnp.int32)
        u_new = jnp.min(dm, axis=1)                           # exact d(x, c_label)

        # Scanned groups get the exact (inclusive) group min; skipped
        # groups keep the drift-updated bound.
        gmin = group_min(d, g, gs)
        lower_new = jnp.where(need_g, gmin, lower)

        owner_g = (labels0 // gs).astype(jnp.int32)
        nonowner = jnp.arange(g)[None, :] != owner_g[:, None]
        eliminated = ~jnp.any(jnp.logical_and(need_g, nonowner), axis=1)
        stats = BoundStats(jnp.mean(eliminated.astype(jnp.float32)),
                           1.0 - jnp.mean(need_g.astype(jnp.float32)))

        mind = (u_new * u_new).astype(precision.accum_dtype)
        sums, counts = lloyd.cluster_sums(x.astype(precision.accum_dtype),
                                          labels, k)
        res = StepResult(labels, mind, sums, counts, jnp.sum(mind))
        return res, (labels, u_new, lower_new, cf, stats)

    def stats_fn(x, labels, k):
        return lloyd.cluster_sums(x.astype(precision.accum_dtype), labels, k)

    return Backend(name=name,
                   step_fn=step_fn,
                   stats_fn=stats_fn,
                   assign_fn=lloyd.assign,
                   init_carry_fn=init_carry_fn,
                   precision=precision)
