"""Pallas-kernel backends: separate-kernel (`pallas`) and single-pass
(`fused`) engines for Algorithm 1 (DESIGN.md §Kernels-v2).

`fused` consumes `fused_lloyd_pallas` v2: distances, argmin, cluster stats
and energy in ONE physical pass over X for *arbitrary* K — the kernel
k-tiles the centroid stream and carries the running argmin in VMEM
scratch, so the old K*d VMEM gate (and its fallback to the two-kernel
path) is gone.  Under the step-driven solver an accepted Algorithm-1
iteration therefore costs exactly one X read — the paper's Sec-2.1 cost
model realised on hardware at any K.

`pallas` drives the tiled assignment and one-hot-matmul update kernels as
two X passes per step — kept as the decomposed engine (predict-style
assignment reuse, per-kernel benchmarking) and as an independent check on
the fused path.

Both backends fill all three step slots natively (v2):

  * ``step``           — one fused pass / assignment+update pair;
  * ``batched_step``   — the kernels' leading-R grid runs R centroid
    sets per launch (multi-restart driver, the minibatch guard's R=2);
  * ``minibatch_step`` — the kernels' native row weights fold chunk
    weights into sums/counts/energy in the same pass, instead of the
    generic step + weighted-segment-sum fallback.

Precision policy (applied identically in both engines): the *compute*
dtype covers the distance math AND the X stream into the stats matmul —
X enters VMEM once per pass, in one dtype — while sums/counts/energy
accumulate in f32 on the MXU (`preferred_element_type`) and are returned
in the policy's accum dtype.  (v1 split the difference: assignment saw
the compute-cast X but the update kernel re-read the uncast original,
so the two engines' stats disagreed at bf16.)

`fused_bounds` is the fused engine carrying the shared bound contract of
`backends/bounds.py` (DESIGN.md §Bounds): squared per-(row, k-group)
lower bounds — one group per k-tile — and a squared upper bound ride into
VMEM next to each X row tile, and the kernel SKIPS whole centroid tiles
whose bound says no row can improve.  The drift maintenance between step
calls is the same triangle-inequality algebra as the elkan/yinyang CPU
backends, so it stays valid across accepted Anderson jumps and reverts.

On non-TPU hosts the kernels execute in interpret mode (correctness
path); the TPU lowering is exercised by the dry-run entrypoints.
``REPRO_PALLAS_INTERPRET=1`` forces interpret mode everywhere — the
``test.sh --interpret`` tier uses it to run the kernel suite through
`pallas_call(interpret=True)` on any host.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.backends import bounds as _bounds
from repro.core.backends.base import (Backend, Precision, StepResult,
                                      DEFAULT_PRECISION)
from repro.core.backends.bounds import BoundStats
from repro.core.lloyd import AssignResult
from repro.kernels import tiles
from repro.kernels.assignment import assignment_pallas
from repro.kernels.fused_lloyd import fused_lloyd_pallas
from repro.kernels.update import update_pallas

# Legacy names: the VMEM budget is no longer a gate (there is no fallback
# path) — it seeds the tile chooser's footprint model (kernels/tiles.py).
FUSED_VMEM_BYTES = tiles.DEFAULT_VMEM_BUDGET
FUSED_MAX_KD = FUSED_VMEM_BYTES // 4


def _interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET", "") not in ("", "0"):
        return True
    return jax.default_backend() != "tpu"


def _assign_fn(x, c):
    labels, mind = assignment_pallas(x, c, interpret=_interpret())
    return AssignResult(labels, mind)


def _stats_fn(x, labels, k):
    return update_pallas(x, labels, k, interpret=_interpret())


def _pack(precision: Precision, labels, mind, sums, counts, energy=None):
    acc = precision.accum_dtype
    mind = mind.astype(acc)
    if energy is None:
        energy = jnp.sum(mind, axis=-1)
    else:
        energy = energy.astype(acc)
    return StepResult(labels, mind, sums.astype(acc), counts.astype(acc),
                      energy)


# ---------------------------------------------------------------------------
# Split two-kernel engine ("pallas")
# ---------------------------------------------------------------------------

def _split_step(precision: Precision):
    def step_fn(x, c, k, carry):
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(c)
        labels, mind = assignment_pallas(xc, cc, interpret=_interpret())
        # policy: the stats matmul reads the same compute-cast X as the
        # distance pass (one X stream, one dtype), accumulating in f32
        sums, counts = update_pallas(xc, labels, k, interpret=_interpret())
        return _pack(precision, labels, mind, sums, counts), carry
    return step_fn


def _split_batched(precision: Precision):
    def batched_step_fn(x, cs, k, carries, w=None):
        if w is not None:
            # the split engine's update kernel takes one (N,) weight
            # vector; per-problem weights route through the vmapped
            # minibatch slot (one launch per problem — the fused engine
            # is the batched-weighted fast path)
            mb = _split_minibatch(precision)
            return jax.vmap(
                lambda xx, cc, ww, cr: mb(xx, cc, k, ww, cr),
                in_axes=(0 if x.ndim == 3 else None, 0, 0, 0))(
                    x, cs, w, carries)
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(cs)
        labels, mind = assignment_pallas(xc, cc, interpret=_interpret())
        sums, counts = update_pallas(xc, labels, k, interpret=_interpret())
        return _pack(precision, labels, mind, sums, counts), carries
    return batched_step_fn


def _split_minibatch(precision: Precision):
    def minibatch_step_fn(x, c, k, w, carry):
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(c)
        labels, mind = assignment_pallas(xc, cc, interpret=_interpret())
        sums, counts = update_pallas(xc, labels, k, w=w,
                                     interpret=_interpret())
        acc = precision.accum_dtype
        energy = jnp.sum(mind.astype(acc) * w.astype(acc))
        return _pack(precision, labels, mind, sums, counts, energy), carry
    return minibatch_step_fn


def pallas_backend(precision: Precision = DEFAULT_PRECISION) -> Backend:
    return Backend(name="pallas",
                   step_fn=_split_step(precision),
                   batched_step_fn=_split_batched(precision),
                   minibatch_step_fn=_split_minibatch(precision),
                   stats_fn=_stats_fn,
                   assign_fn=_assign_fn,
                   precision=precision)


# ---------------------------------------------------------------------------
# Single-pass engine ("fused")
# ---------------------------------------------------------------------------

def _fused_step(precision: Precision):
    def step_fn(x, c, k, carry):
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(c)
        labels, mind, sums, counts, energy = fused_lloyd_pallas(
            xc, cc, interpret=_interpret())
        return _pack(precision, labels, mind, sums, counts, energy), carry
    return step_fn


def _fused_batched(precision: Precision):
    def batched_step_fn(x, cs, k, carries, w=None):
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(cs)
        labels, mind, sums, counts, energy = fused_lloyd_pallas(
            xc, cc, w, interpret=_interpret())
        return _pack(precision, labels, mind, sums, counts, energy), carries
    return batched_step_fn


def _fused_minibatch(precision: Precision):
    def minibatch_step_fn(x, c, k, w, carry):
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(c)
        labels, mind, sums, counts, energy = fused_lloyd_pallas(
            xc, cc, w, interpret=_interpret())
        return _pack(precision, labels, mind, sums, counts, energy), carry
    return minibatch_step_fn


def fused_backend(precision: Precision = DEFAULT_PRECISION) -> Backend:
    return Backend(name="fused",
                   step_fn=_fused_step(precision),
                   batched_step_fn=_fused_batched(precision),
                   minibatch_step_fn=_fused_minibatch(precision),
                   stats_fn=_stats_fn,
                   assign_fn=_assign_fn,
                   precision=precision)


# ---------------------------------------------------------------------------
# Tile-skipping single-pass engine ("fused_bounds")
# ---------------------------------------------------------------------------

def fused_bounds_backend(precision: Precision = DEFAULT_PRECISION,
                         group_size=None) -> Backend:
    """The fused kernel consuming group lower bounds to skip k tiles.

    The carry is the shared contract of `backends/bounds.py` with groups
    sized to the kernel's k tile (one group per tile, gs == tk), so the
    drift-maintained (N, G) lower bounds land in VMEM as exactly the
    per-(row-tile, k-tile) skip predicate.  The bound algebra runs in
    Euclidean space outside the kernel; the kernel works in squared
    space (lb² / ub², with inf² = inf on the first, bound-free step).

    An explicit ``group_size`` is rounded up to the f32 sublane so the
    k tile stays Mosaic-tileable.  Default sizing follows the "tile"
    policy — for K <= MAX_TILE that is ONE group (graceful degradation
    to the plain fused kernel plus bound upkeep); pass a smaller
    ``group_size`` to get real skipping at small K.
    """

    def gs_of(k):
        gs = _bounds.resolve_group_size(k, group_size, "tile")
        return tiles.round_up(gs, tiles.sublane(4))

    def init_carry_fn(x, c, k):
        return _bounds.init_carry(x, c, k, gs_of(k))

    def _prep(labels0, upper, lower, c_last, cf, g, gs):
        drift = _bounds.centroid_drift(cf, c_last)
        upper, lower = _bounds.drift_update(labels0, upper, lower,
                                            drift, g, gs)
        lb_sq = jnp.square(jnp.maximum(lower, 0.0))
        ub_sq = jnp.square(upper)
        return lb_sq, ub_sq

    def _run(x, c, k, carry, w=None, batched=False):
        labels0, upper, lower, c_last, _ = carry
        g, gs = _bounds.group_layout(k, gs_of(k))
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(c)
        cf = cc.astype(jnp.float32)
        prep = jax.vmap(_prep, in_axes=(0, 0, 0, 0, 0, None, None)) \
            if batched else _prep
        lb_sq, ub_sq = prep(labels0, upper, lower, c_last, cf, g, gs)
        labels, mind, sums, counts, energy, gmin_sq, skipped = \
            fused_lloyd_pallas(xc, cc, w, tk=gs, interpret=_interpret(),
                               bounds=(labels0, lb_sq, ub_sq))
        u_new = jnp.sqrt(mind)
        lower_new = jnp.sqrt(gmin_sq)
        stats = BoundStats(skipped, skipped)
        new_carry = (labels, u_new, lower_new, cf, stats)
        return _pack(precision, labels, mind, sums, counts, energy), \
            new_carry

    def step_fn(x, c, k, carry):
        return _run(x, c, k, carry)

    def batched_step_fn(x, cs, k, carries, w=None):
        return _run(x, cs, k, carries, w=w, batched=True)

    def minibatch_step_fn(x, c, k, w, carry):
        return _run(x, c, k, carry, w=w)

    return Backend(name="fused_bounds",
                   step_fn=step_fn,
                   batched_step_fn=batched_step_fn,
                   minibatch_step_fn=minibatch_step_fn,
                   stats_fn=_stats_fn,
                   assign_fn=_assign_fn,
                   init_carry_fn=init_carry_fn,
                   precision=precision)
