"""Pallas-kernel backends: separate-kernel (`pallas`) and single-pass
(`fused`) engines for Algorithm 1.

`pallas` drives the tiled assignment and one-hot-matmul update kernels as
two X passes per step — the path for K*d too large to hold C fully in VMEM.

`fused` consumes `fused_lloyd_pallas`: distances, argmin, cluster stats and
energy in ONE physical pass over X (the kernel holds C in VMEM, valid while
the K*d centroid block fits the FUSED_VMEM_BYTES budget at the compute
dtype's byte width).  Under the step-driven solver an accepted
Algorithm-1 iteration therefore costs exactly one X read — the paper's
Sec-2.1 cost model realised on hardware.  `fused_backend` falls back to the
two-kernel step when K*d exceeds the VMEM budget.

On non-TPU hosts the kernels execute in interpret mode (correctness path);
the TPU lowering is exercised by the dry-run entrypoints.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backends.base import (Backend, Precision, StepResult,
                                      DEFAULT_PRECISION)
from repro.core.lloyd import AssignResult
from repro.kernels.assignment import assignment_pallas
from repro.kernels.fused_lloyd import fused_lloyd_pallas
from repro.kernels.update import update_pallas

# VMEM budget for holding the full centroid block in the fused kernel:
# 8 MB, about half of one core's VMEM.  The gate is in BYTES of the
# *compute* dtype — at bf16 the same budget holds 2x the K*d elements
# (an element-count gate assuming f32 made bf16 fall back to the
# two-kernel path 2x too early).  FUSED_MAX_KD keeps the legacy
# f32-element view of the same budget for existing callers.
FUSED_VMEM_BYTES = 8 * 1024 * 1024
FUSED_MAX_KD = FUSED_VMEM_BYTES // 4


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _assign_fn(x, c):
    labels, mind = assignment_pallas(x, c, interpret=_interpret())
    return AssignResult(labels, mind)


def _stats_fn(x, labels, k):
    return update_pallas(x, labels, k, interpret=_interpret())


def _split_step(precision: Precision):
    def step_fn(x, c, k, carry):
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(c)
        labels, mind = assignment_pallas(xc, cc, interpret=_interpret())
        sums, counts = update_pallas(x, labels, k, interpret=_interpret())
        acc = precision.accum_dtype
        mind = mind.astype(acc)
        return StepResult(labels, mind, sums.astype(acc), counts.astype(acc),
                          jnp.sum(mind)), carry
    return step_fn


def pallas_backend(precision: Precision = DEFAULT_PRECISION) -> Backend:
    return Backend(name="pallas",
                   step_fn=_split_step(precision),
                   stats_fn=_stats_fn,
                   assign_fn=_assign_fn,
                   precision=precision)


def fused_backend(precision: Precision = DEFAULT_PRECISION) -> Backend:
    split = _split_step(precision)

    def step_fn(x, c, k, carry):
        cdtype = jnp.dtype(precision.compute) if precision.compute is not None \
            else x.dtype
        # static shapes: Python branch
        if k * x.shape[1] * cdtype.itemsize > FUSED_VMEM_BYTES:
            return split(x, c, k, carry)
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(c)
        labels, mind, sums, counts, energy = fused_lloyd_pallas(
            xc, cc, interpret=_interpret())
        acc = precision.accum_dtype
        return StepResult(labels, mind.astype(acc), sums.astype(acc),
                          counts.astype(acc), energy.astype(acc)), carry

    return Backend(name="fused",
                   step_fn=step_fn,
                   stats_fn=_stats_fn,
                   assign_fn=_assign_fn,
                   precision=precision)
