"""Yinyang-style bound backend: pure group filtering, no K x K matrix
(Ding et al. 2015, in the spirit of Khandelwal & Awekar's cluster-group
pruning).

Per step each row pays one exact distance to its assigned centroid plus
one comparison per centroid *group*; only groups whose (drift-maintained,
inclusive) lower bound could beat that exact distance are scanned.
Default grouping is the classic t = ceil(K/10) groups, independent of the
kernel tile size — yinyang is the CPU-flavoured group filter, elkan the
kernel-tile-aligned one; pass ``group_size=`` to align them.

Unlike elkan there is no centre-centre gate, so the per-step fixed cost
stays O(K d) (the drift norms) + O(N G) (the filter) — the trade the
yinyang paper makes to scale past the K^2 term.

Carry contract, drift maintenance across AA jumps/reverts, and the
exactness argument live in `backends/bounds.py`.
"""

from __future__ import annotations

from typing import Optional

from repro.core.backends.base import Backend, Precision, DEFAULT_PRECISION
from repro.core.backends.bounds import make_group_bound_backend


def yinyang_backend(precision: Precision = DEFAULT_PRECISION,
                    group_size: Optional[int] = None) -> Backend:
    return make_group_bound_backend("yinyang", precision, group_size,
                                    policy="yinyang", center_gate=False)
