"""Elkan-style bound backend: per-row x per-k-group lower bounds plus the
classic centre-centre gate (Elkan 2003, via the accurate-bound family of
Newling & Fleuret 2016).

Where hamerly keeps ONE lower bound per row (the second-closest centroid),
elkan keeps one per (row, group of centroids) — groups are contiguous
index ranges sized like the fused kernel's k-tiles by default
(`bounds.resolve_group_size`), so the same carry drives the
``fused_bounds`` Pallas engine's tile-skip predicate.  On top of the group
filter, elkan prices the K x K centre-centre distance matrix each step for
the global gate: a row with u <= s(a) — half the distance from its
assigned centroid to that centroid's nearest neighbour — provably keeps
its assignment and skips every group, owner included.

The group filter degrades gracefully: at K below one k-tile (the default
group size) there is a single group, elimination comes only from the
centre gate, and the step is still exact — pass ``group_size=`` to carve
finer groups when K is small but elimination matters (see DESIGN.md
§Bounds).

Carry contract, drift maintenance across AA jumps/reverts, and the
exactness argument for the inclusive group bounds live in
`backends/bounds.py`; this module just binds the policy.
"""

from __future__ import annotations

from typing import Optional

from repro.core.backends.base import Backend, Precision, DEFAULT_PRECISION
from repro.core.backends.bounds import make_group_bound_backend


def elkan_backend(precision: Precision = DEFAULT_PRECISION,
                  group_size: Optional[int] = None) -> Backend:
    return make_group_bound_backend("elkan", precision, group_size,
                                    policy="tile", center_gate=True)
