"""Dense and row-blocked jnp backends (single-device reference semantics).

`dense` is the semantic oracle every other backend is tested against: the
MXU-friendly |x|^2 - 2 x.c + |c|^2 distance expansion plus segment-sum
cluster stats — exactly the arithmetic of the legacy DENSE_OPS path, so the
step-driven solver reproduces the old trajectories bit-for-bit at f32.

`blocked` evaluates the distance rows in fixed-size blocks so the (N, K)
intermediate never materialises — the pure-JAX analogue of the Pallas
kernel's N-tiling, for datasets where N*K exceeds memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lloyd
from repro.core.backends.base import (Backend, Precision, StepResult,
                                      DEFAULT_PRECISION)
from repro.core.lloyd import AssignResult


def _blocked_assign(x, c, block_n: int) -> AssignResult:
    """Row-blocked assignment for arbitrary N: lloyd.assign only engages
    its blocked path when block_n divides N, so handle the remainder as a
    separate tail block (< block_n rows, dense) rather than silently
    materialising the full (N, K) matrix the blocking exists to avoid —
    and without copying X into a padded buffer every step."""
    n = x.shape[0]
    rem = n % block_n if block_n else 0
    if rem and n > block_n:
        main = lloyd.assign(x[:n - rem], c, block_n=block_n)
        tail = lloyd.assign(x[n - rem:], c)
        return AssignResult(
            jnp.concatenate([main.labels, tail.labels]),
            jnp.concatenate([main.min_sqdist, tail.min_sqdist]))
    return lloyd.assign(x, c, block_n=block_n)


def _stats(precision: Precision):
    def stats_fn(x, labels, k):
        return lloyd.cluster_sums(x.astype(precision.accum_dtype), labels, k)
    return stats_fn


def _step(precision: Precision, block_n: int = 0):
    def step_fn(x, c, k, carry):
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(c)
        res = _blocked_assign(xc, cc, block_n)
        mind = res.min_sqdist.astype(precision.accum_dtype)
        sums, counts = lloyd.cluster_sums(x.astype(precision.accum_dtype),
                                          res.labels, k)
        return StepResult(res.labels, mind, sums, counts,
                          jnp.sum(mind)), carry
    return step_fn


def _minibatch_step(precision: Precision, block_n: int = 0):
    """Natively-weighted step for streaming chunks: one pass computes the
    assignment and folds the row weights straight into sums/counts/energy
    — the generic fallback pays a second segment-sum for the reweighting."""
    def minibatch_step_fn(x, c, k, w, carry):
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(c)
        res = _blocked_assign(xc, cc, block_n)
        acc = precision.accum_dtype
        wa = w.astype(acc)
        mind = res.min_sqdist.astype(acc)
        sums, counts = lloyd.weighted_cluster_sums(x.astype(acc), res.labels,
                                                   wa, k)
        return StepResult(res.labels, mind, sums, counts,
                          jnp.sum(mind * wa)), carry
    return minibatch_step_fn


def _batched_step(precision: Precision):
    """Natively-batched dense step for the multi-restart driver.

    Semantics match ``_step`` per restart row; the formulation differs in
    two performance-critical ways: (1) the distance cross-terms for ALL
    R centroid sets come from one einsum that reads the shared X stream
    once, and (2) cluster stats use a one-hot matmul instead of R vmapped
    segment-sums — the scatter path serialises badly when batched.  Sums
    therefore accumulate in matmul reduction order (last-ulp differences
    vs the sequential scatter; same class as psum reordering).

    Memory contract: peak footprint is two (R, N, K) buffers (distances
    and the one-hot) — R times the sequential path's single (N, K).  When
    R*N*K approaches device memory, use the blocked backend: its vmapped
    fallback bounds the distance intermediate at (R, block_n, K) per
    step and never materialises a one-hot (DESIGN.md §Batching)."""
    def batched_step_fn(x, cs, k, carries, w=None):
        # x: (N, d) shared or (R, N, d); cs: (R, K, d); w: None or (R, N)
        xc = precision.compute_cast(x)
        cc = precision.compute_cast(cs)
        c_sq = jnp.sum(cc * cc, axis=-1)                       # (R, K)
        x_sq = jnp.sum(xc * xc, axis=-1)                       # (N,)|(R,N)
        if x.ndim == 2:
            cross = jnp.einsum("nd,rkd->rnk", xc, cc)
            x_term = x_sq[None, :, None]
        else:
            cross = jnp.einsum("rnd,rkd->rnk", xc, cc)
            x_term = x_sq[:, :, None]
        d2 = jnp.maximum(x_term - 2.0 * cross + c_sq[:, None, :], 0.0)
        labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)     # (R, N)
        mind = jnp.min(d2, axis=-1).astype(precision.accum_dtype)
        onehot = jax.nn.one_hot(labels, k, dtype=precision.accum_dtype)
        if w is not None:
            # per-problem row weights scale the one-hot, so sums/counts/
            # energy weight in the same contraction; labels/mind stay
            # unweighted (the minibatch contract on the restart axis)
            onehot = onehot * w.astype(precision.accum_dtype)[:, :, None]
        xa = x.astype(precision.accum_dtype)
        if x.ndim == 2:
            sums = jnp.einsum("rnk,nd->rkd", onehot, xa)
        else:
            sums = jnp.einsum("rnk,rnd->rkd", onehot, xa)
        counts = jnp.sum(onehot, axis=1)                       # (R, K)
        if w is None:
            energy = jnp.sum(mind, axis=-1)
        else:
            energy = jnp.sum(mind * w.astype(mind.dtype), axis=-1)
        return StepResult(labels, mind, sums, counts, energy), carries
    return batched_step_fn


def dense_backend(precision: Precision = DEFAULT_PRECISION) -> Backend:
    return Backend(name="dense",
                   step_fn=_step(precision),
                   batched_step_fn=_batched_step(precision),
                   minibatch_step_fn=_minibatch_step(precision),
                   stats_fn=_stats(precision),
                   assign_fn=lloyd.assign,
                   precision=precision)


def blocked_backend(block_n: int = 4096,
                    precision: Precision = DEFAULT_PRECISION) -> Backend:
    def assign_fn(x, c):
        return _blocked_assign(x, c, block_n)

    return Backend(name=f"blocked{block_n}",
                   step_fn=_step(precision, block_n=block_n),
                   minibatch_step_fn=_minibatch_step(precision,
                                                     block_n=block_n),
                   stats_fn=_stats(precision),
                   assign_fn=assign_fn,
                   precision=precision)
