"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060; unverified].

64L d_model=2560, attention-free, vocab=50280, ssm_state=128,
expand=2 (d_inner=5120), head_dim=64 (80 ssm heads), conv width 4.
O(1)-state decode -> runs long_500k natively.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    vocab=50280,
    d_ff=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    source="arXiv:2405.21060; unverified",
)
