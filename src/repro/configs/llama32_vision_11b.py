"""llama-3.2-vision-11b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; a gated
cross-attention layer every 5th layer (8 total) attends over stub image
patch embeddings — input_specs() provides precomputed (B, 1600, d_model)
patch embeddings (the vision tower is the stubbed modality frontend).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    cross_attn_every=5,
    n_img_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
