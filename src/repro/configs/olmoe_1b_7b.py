"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (MHA kv=16) d_ff=1024 per expert, vocab=50304,
MoE 64e top-8.  Expert-parallel sharding is natural here (64 experts over
a model axis of 16 -> 4 experts/chip); rules.moe_ep enables it.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    source="arXiv:2409.02060; hf",
)
