"""zamba2-2.7b — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

54L d_model=2560 Mamba2 blocks (ssm_state=64) with ONE weight-shared
attention+MLP block (32H MHA, d_ff=10240) applied every 6 mamba layers
(9 invocations), each with its own LoRA adapter on Q/K/V, taking
concat(hidden, embedding) as input (2*d_model), zamba-style.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=256,
    shared_attn_every=6,
    shared_lora_rank=128,
    source="arXiv:2411.15242; hf",
)
