"""Architecture registry: --arch <id> resolution + reduced smoke configs."""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

from repro.configs import (h2o_danube_18b, llama32_vision_11b, mamba2_27b,
                           minitron_8b, mixtral_8x7b, musicgen_medium,
                           olmoe_1b_7b, qwen15_110b, smollm_135m, zamba2_27b)

ARCHS = {
    "musicgen-medium": musicgen_medium.CONFIG,
    "minitron-8b": minitron_8b.CONFIG,
    "qwen1.5-110b": qwen15_110b.CONFIG,
    "smollm-135m": smollm_135m.CONFIG,
    "h2o-danube-1.8b": h2o_danube_18b.CONFIG,
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "mixtral-8x7b": mixtral_8x7b.CONFIG,
    "mamba2-2.7b": mamba2_27b.CONFIG,
    "zamba2-2.7b": zamba2_27b.CONFIG,
    "llama-3.2-vision-11b": llama32_vision_11b.CONFIG,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    return ARCHS[arch]


def reduced_config(arch: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: few layers, narrow
    widths, small vocab — same structural features (GQA ratio, SWA, MoE
    top-k, shared-attn cadence, cross-attn cadence) as the full config."""
    cfg = get_config(arch)
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=max(2, (cfg.shared_attn_every or cfg.cross_attn_every or 2)
                     * 2) if (cfg.family in ("hybrid", "vlm")) else 2,
        d_model=64,
        vocab=128,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads
                                            // max(cfg.n_heads, 1)),
                  head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=128)
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=min(cfg.top_k, 4))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    if cfg.shared_attn_every:
        kw.update(shared_attn_every=cfg.shared_attn_every // 3,
                  shared_lora_rank=8)
        kw.update(n_layers=2 * (cfg.shared_attn_every // 3))
    if cfg.cross_attn_every:
        kw.update(cross_attn_every=cfg.cross_attn_every,
                  n_img_tokens=24)
        kw.update(n_layers=2 * cfg.cross_attn_every)
    return dataclasses.replace(cfg, **kw)
