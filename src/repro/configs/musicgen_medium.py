"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=1536 24H (MHA: kv=24) d_ff=6144 vocab=2048.  The EnCodec
frontend is a STUB: input_specs() provides precomputed frame embeddings
(B, S, d_model); the backbone is a standard decoder with logits over the
codec vocabulary.  MusicGen uses full (not sliding-window) attention, so
the long_500k shape is skipped (DESIGN.md §Shapes).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    act="gelu",
    embed_stub=True,
    source="arXiv:2306.05284; hf",
)
