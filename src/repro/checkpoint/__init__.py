"""Checkpointing layers.

``checkpointer``/``reshard`` — the generic async, atomic, mesh-aware
training checkpointer (directory-per-step format; used by the LM launch
stack).  ``kmeans`` — the K-Means solver/estimator persistence facade
over `repro.core.serialize` (single-artifact snapshots, segment-loop
resume, elastic re-mesh; DESIGN.md §Persistence).
"""

from repro.checkpoint.kmeans import (latest_snapshot,     # noqa: F401
                                     load_estimator, resume_point,
                                     save_estimator)
