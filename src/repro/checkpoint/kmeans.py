"""K-Means solver & model persistence facade (DESIGN.md §Persistence).

The mechanics live one layer down so they stay import-cycle-free:

  * `repro.core.serialize`   — the version-tagged npz/msgpack artifact;
  * `repro.core.kmeans`      — segmented drivers (``checkpoint_every=`` /
    ``resume_from=`` on `aa_kmeans`, `aa_kmeans_batched`,
    `aa_kmeans_minibatch`) that write one ``it_<t>.npz`` per boundary;
  * `repro.core.distributed` — shard_map'd segments +
    `restore_distributed_loop_state` (elastic re-mesh on device_put);
  * `repro.core.api`         — ``AAKMeans.save/load``,
    ``MiniBatchAAKMeans.save/load`` (incl. a mid-``partial_fit`` stream).

This module adds the operational conveniences a preemptible job actually
calls: find the newest snapshot in a run directory, resolve the
"fresh start or resume" decision in one line, and (re-)hydrate estimator
artifacts without knowing which estimator class wrote them.

    ckpt_dir = "gs://.../run7"      # any filesystem path
    res = aa_kmeans(x, c0, cfg, checkpoint_every=50,
                    checkpoint_dir=ckpt_dir,
                    resume_from=latest_snapshot(ckpt_dir))   # None on 1st run
"""

from __future__ import annotations

import os
import re
from pathlib import Path
from typing import Optional

from repro.core import serialize
from repro.core.api import AAKMeans, MiniBatchAAKMeans
from repro.runtime.writer import read_manifest


def latest_snapshot(ckpt_dir) -> Optional[Path]:
    """Newest solver snapshot in a segmented run's checkpoint directory,
    or None when there is none yet (first run / clean directory) — the
    value to pass straight to ``resume_from=``.

    Reads the directory's ``manifest.json`` (atomically rewritten at
    every boundary by the runtime writer) rather than listing the
    directory; a legacy/partial directory without a usable manifest falls
    back to the old glob scan.  Either way the newest complete artifact
    is always valid: snapshots are atomically renamed into place, and a
    stray ``.tmp`` from a crash mid-write is ignored (and swept by the
    writer on the next start)."""
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    m = read_manifest(d)
    if m is not None and m.get("latest"):
        p = d / m["latest"]
        if p.exists():
            return p
        # manifest referencing a missing file means external deletion —
        # fall through to the scan rather than failing the resume
    # Scan fallback: only canonical ``it_<int>.npz`` names qualify — the
    # regex is what actually excludes a crashed writer's ``*.npz.tmp``
    # orphans (the old ``endswith(".tmp")`` filter was dead code: a path
    # matching the ``it_*.npz`` glob can never end in ".tmp") — and the
    # newest snapshot is picked by the PARSED step, since lexicographic
    # order mis-ranks any non-zero-padded legacy name (it_9 > it_10).
    snaps = []
    for p in d.glob("it_*.npz*"):
        m = re.fullmatch(r"it_(\d+)\.npz", p.name)
        if m:
            snaps.append((int(m.group(1)), p))
    return max(snaps, key=lambda sp: sp[0])[1] if snaps else None


def resume_point(ckpt_dir) -> tuple[Optional[Path], Optional[dict]]:
    """(path, meta) of the newest snapshot, or (None, None).  The meta
    block carries what a scheduler wants to log on restart: the iteration
    / trip / epoch counter ``t``, ``k``, the backend identity, and (for
    distributed runs) the mesh the checkpoint was taken under — which is
    informational only, since artifacts are mesh-free (DESIGN.md
    §Persistence, elastic restore)."""
    p = latest_snapshot(ckpt_dir)
    if p is None:
        return None, None
    meta, _ = serialize.load(p)
    return p, meta


_ESTIMATORS = {
    serialize.KIND_ESTIMATOR_AA: AAKMeans,
    serialize.KIND_ESTIMATOR_MB: MiniBatchAAKMeans,
}


def save_estimator(model, path) -> Path:
    """``model.save(path)`` for either estimator (symmetry with
    `load_estimator`)."""
    return model.save(path)


def load_estimator(path):
    """Load an estimator artifact without knowing which class wrote it:
    the artifact's ``kind`` tag picks AAKMeans vs MiniBatchAAKMeans — the
    serving-process entry point."""
    meta, _ = serialize.load(path)
    cls = _ESTIMATORS.get(meta.get("kind"))
    if cls is None:
        raise ValueError(
            f"{os.fspath(path)}: kind {meta.get('kind')!r} is not an "
            f"estimator artifact (expected one of {sorted(_ESTIMATORS)})")
    return cls.load(path)
