"""Checkpointing: async, atomic, mesh-aware.

Format: one directory per step containing
  * `tree.msgpack`  — pytree structure + per-leaf metadata (shape, dtype,
    logical axes) serialised with msgpack,
  * `arrays.npz`    — the leaf buffers (gathered to host),
  * `meta.json`     — step, mesh shape/axes, data-pipeline cursor, wall time.

Writes go to `<dir>.tmp` and are atomically renamed — a crash mid-write
never corrupts the latest checkpoint (restore scans for the newest COMPLETE
directory).  `AsyncCheckpointer` snapshots the (host) arrays synchronously
— cheap next to a training step — and performs serialisation + fsync on a
background thread, overlapping I/O with subsequent steps; `wait()` joins
the in-flight write (called before exit and before starting a save for the
same path).

Elastic restores (different mesh / shard counts) go through
checkpoint/reshard.py: arrays are stored UNSHARDED (gathered), so loading
onto any mesh is a device_put with the new sharding — the simple, robust
choice at this repo's scale; sharded-per-host formats drop in behind the
same interface.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

import msgpack

PyTree = Any


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(path: str | os.PathLike, tree: PyTree, *, step: int,
         extra: Optional[dict] = None):
    """Synchronous atomic save."""
    path = Path(path)
    tmp = path.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    paths, leaves, _ = _flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    np.savez(tmp / "arrays.npz", **{f"a{i}": a for i, a in enumerate(host)})
    meta_leaves = [{"path": p, "shape": list(a.shape), "dtype": str(a.dtype)}
                   for p, a in zip(paths, host)]
    (tmp / "tree.msgpack").write_bytes(msgpack.packb(meta_leaves))
    meta = {"step": int(step), "time": time.time(), **(extra or {})}
    (tmp / "meta.json").write_text(json.dumps(meta, indent=1))
    if path.exists():
        shutil.rmtree(path)
    os.replace(tmp, path)


def restore_arrays(path: str | os.PathLike):
    """Load (paths, host arrays, meta) from a checkpoint directory."""
    path = Path(path)
    meta = json.loads((path / "meta.json").read_text())
    leaf_meta = msgpack.unpackb((path / "tree.msgpack").read_bytes())
    with np.load(path / "arrays.npz") as z:
        arrays = [z[f"a{i}"] for i in range(len(leaf_meta))]
    return [m["path"] for m in leaf_meta], arrays, meta


def restore(path, like: PyTree, shardings: Optional[PyTree] = None):
    """Restore into the structure of `like`; device_put with `shardings`
    when given (elastic re-mesh path)."""
    paths, arrays, meta = restore_arrays(path)
    want_paths, want_leaves, treedef = _flatten_with_paths(like)
    by_path = dict(zip(paths, arrays))
    missing = [p for p in want_paths if p not in by_path]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]} "
                         f"({len(missing)} total)")
    out = []
    for p, w in zip(want_paths, want_leaves):
        a = by_path[p]
        if tuple(a.shape) != tuple(w.shape):
            raise ValueError(f"shape mismatch at {p}: ckpt {a.shape} "
                             f"vs expected {tuple(w.shape)}")
        out.append(a.astype(w.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, meta


def latest_step_dir(root: str | os.PathLike) -> Optional[Path]:
    root = Path(root)
    if not root.exists():
        return None
    steps = sorted((int(p.name.split("_")[-1]), p)
                   for p in root.glob("step_*")
                   if (p / "meta.json").exists())
    return steps[-1][1] if steps else None


class AsyncCheckpointer:
    """Background-thread checkpoint writer with a bounded queue of one.

    save() snapshots arrays to host synchronously, then returns; the
    serialise+write happens on the worker thread.  A second save() while
    one is in flight blocks until the previous finishes (bounded memory).
    """

    def __init__(self, root: str | os.PathLike, keep: int = 3):
        self.root = Path(root)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def save(self, tree: PyTree, *, step: int, extra: Optional[dict] = None):
        self.wait()
        # snapshot to host NOW (device buffers may be donated next step)
        paths, leaves, treedef = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        snap = jax.tree_util.tree_unflatten(treedef, host)

        def work():
            try:
                save(self.root / f"step_{step:08d}", snap, step=step,
                     extra=extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(self.root.glob("step_*"))
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    def restore_latest(self, like: PyTree, shardings=None):
        d = latest_step_dir(self.root)
        if d is None:
            return None, None
        return restore(d, like, shardings)
