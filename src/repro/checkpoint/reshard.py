"""Elastic re-meshing of checkpoints.

A checkpoint saved while training on mesh A (say 2 pods, 512 chips) can be
restored onto mesh B (say 1 pod, 256 chips after losing a pod, or a larger
fleet after scale-up).  Because checkpoints store *unsharded* host arrays
plus the model's logical-axes spec tree, resharding is: rebuild the
sharding tree from the same rules on the NEW mesh, then device_put.

The data-pipeline cursor stored in meta.json plus the index-based token
stream (data/tokens.py) make the resume exact even when the data-parallel
degree changes: batch `t` is a pure function of (seed, step, shard-of-B),
so re-slicing the global batch among a different host count is safe.
"""

from __future__ import annotations

from typing import Any, Optional

import jax

from repro.checkpoint import checkpointer as ckpt
from repro.models import params as pr
from repro.sharding.rules import ShardingRules

PyTree = Any


def reshard_restore(path, specs: PyTree, mesh, rules: ShardingRules,
                    dtype=None):
    """Restore a checkpointed param tree onto `mesh` with `rules`.

    `specs` is the ParamSpec tree (the single source of truth for shapes and
    logical axes); dtype defaults to each leaf's checkpointed dtype.
    """
    import jax.numpy as jnp
    like = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or jnp.float32),
        specs, is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "shape"))
    shardings = pr.sharding_tree(specs, mesh, rules)
    tree, meta = ckpt.restore(path, like, shardings)
    return tree, meta


def reshard_state(state: PyTree, new_mesh, sharding_fn):
    """Live re-mesh (no disk round-trip): gather to host, re-place.

    sharding_fn(leaf_path_free) -> Sharding for the new mesh; used by the
    elastic controller when shrinking/growing within a session."""
    host = jax.tree.map(lambda x: jax.device_get(x), state)
    shardings = sharding_fn(host)
    return jax.device_put(host, shardings)
