"""Overlapped host→device chunk ingestion (DESIGN.md §Runtime).

The streaming solver's host loop used to be strictly serial: gather chunk
t on the host, ``device_put`` it, run the chunk step, repeat — the
transfer of chunk t+1 waits for step t even though the device (and XLA's
async dispatch on every backend) could hide it entirely.

``prefetch_to_device`` turns any host-chunk iterator into a
double-buffered device iterator: it keeps up to ``size`` chunks in
flight, issuing each ``jax.device_put`` as soon as a slot frees — because
device_put and jit dispatch are both asynchronous, the copy of chunk t+1
proceeds while the consumer's compute on chunk t runs.  The yielded
sequence is exactly the input sequence (same order, same values); only
the *timing* of the transfers changes, so a prefetched run is
numerically identical to a synchronous one.

Mesh runs pass a ``sharding`` (e.g. ``NamedSharding(mesh, P(axes))``):
each chunk lands already sharded over the data axes, preserving the
chunk contract of `repro.data.streaming`.

``IngestMeter`` rides along to account achieved ingest bandwidth — the
number `benchmarks/streaming_sweep.py --big` reports as GB/s.
"""

from __future__ import annotations

import collections
import time
from typing import Iterable, Iterator, Optional

import jax
import numpy as np


def tree_nbytes(tree) -> int:
    """Total payload bytes of a pytree of arrays (host or device)."""
    return sum(int(np.asarray(leaf).nbytes) if not hasattr(leaf, "nbytes")
               else int(leaf.nbytes)
               for leaf in jax.tree_util.tree_leaves(tree))


class IngestMeter:
    """Byte/wall-clock accounting for a chunk stream.

    ``add(nbytes)`` per chunk; ``gbps`` is achieved ingest over the
    meter's lifetime (or between ``start()`` and the last ``add``).
    """

    def __init__(self):
        self.bytes = 0
        self.chunks = 0
        self._t0 = time.perf_counter()
        self._t_last = self._t0

    def start(self) -> "IngestMeter":
        self._t0 = time.perf_counter()
        self._t_last = self._t0
        self.bytes = 0
        self.chunks = 0
        return self

    def add(self, nbytes: int) -> None:
        self.bytes += int(nbytes)
        self.chunks += 1
        self._t_last = time.perf_counter()

    @property
    def seconds(self) -> float:
        return max(self._t_last - self._t0, 1e-12)

    @property
    def gbps(self) -> float:
        return self.bytes / self.seconds / 1e9

    def scalars(self) -> dict:
        return {"ingest_bytes": float(self.bytes),
                "ingest_chunks": float(self.chunks),
                "ingest_gbps": self.gbps}


def prefetch_to_device(iterator: Iterable, size: int = 2, *,
                       sharding: Optional[jax.sharding.Sharding] = None,
                       meter: Optional[IngestMeter] = None) -> Iterator:
    """Iterate ``iterator``'s chunks (any pytree of host arrays) with up
    to ``size`` host→device transfers in flight.

    ``size=2`` is classic double buffering: while the consumer computes
    on the chunk just yielded, the next chunk's copy is already issued.
    ``size=1`` degenerates to the synchronous behaviour (one transfer,
    then yield) and ``size=0`` is rejected.  With ``sharding`` set, every
    leaf is placed with it (rows sharded over the mesh's data axes);
    otherwise the default device placement applies.

    The generator holds references to at most ``size`` device chunks, so
    the peak device footprint is bounded by ``size * chunk_bytes`` on top
    of the consumer's own state.
    """
    if size < 1:
        raise ValueError(f"prefetch size must be >= 1; got {size}")

    def _put(host_tree):
        if meter is not None:
            meter.add(tree_nbytes(host_tree))
        if sharding is not None:
            return jax.tree_util.tree_map(
                lambda a: jax.device_put(a, sharding), host_tree)
        return jax.tree_util.tree_map(jax.device_put, host_tree)

    buf = collections.deque()
    for item in iterator:
        buf.append(_put(item))
        if len(buf) > size - 1:
            yield buf.popleft()
    while buf:
        yield buf.popleft()
