"""Elastic scaling controller: re-mesh on host loss / gain.

State machine consumed by the launcher:

    RUN -> (host lost / straggler evicted) -> CHECKPOINT -> RESHAPE ->
    RESTORE(new mesh) -> RUN

Supported transitions on the production topology:
  * lose a pod:   (pod=2, data=16, model=16) -> (data=16, model=16)
  * lose hosts within a pod: shrink the data axis to the largest divisor
    (model-parallel groups are a failure unit: losing one chip of a TP
    group evicts the group's host row),
  * gain capacity back: any registered mesh shape upward.

The controller only *decides*; mechanics live in checkpoint/reshard.py and
the index-based data pipeline (both degree-independent).  The decision
logic is pure and unit-tested.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


# Preference-ordered fallback ladder for the production topology.
LADDER = (
    MeshPlan((2, 16, 16), ("pod", "data", "model")),
    MeshPlan((16, 16), ("data", "model")),
    MeshPlan((8, 16), ("data", "model")),
    MeshPlan((4, 16), ("data", "model")),
)


def plan_for(available_devices: int,
             ladder: Tuple[MeshPlan, ...] = LADDER) -> Optional[MeshPlan]:
    """Largest plan that fits the surviving device count."""
    for plan in ladder:
        if plan.n_devices <= available_devices:
            return plan
    return None


@dataclasses.dataclass
class ElasticEvent:
    kind: str            # SHRINK | GROW | NOOP
    plan: Optional[MeshPlan]
    reason: str = ""


class ElasticController:
    def __init__(self, initial: MeshPlan = LADDER[0],
                 ladder: Tuple[MeshPlan, ...] = LADDER):
        self.current = initial
        self.ladder = ladder

    def on_membership_change(self, available_devices: int) -> ElasticEvent:
        plan = plan_for(available_devices, self.ladder)
        if plan is None:
            return ElasticEvent("NOOP", None,
                                f"only {available_devices} devices left — "
                                "below the smallest runnable mesh")
        if plan == self.current:
            return ElasticEvent("NOOP", plan, "mesh unchanged")
        kind = "SHRINK" if plan.n_devices < self.current.n_devices else "GROW"
        prev = self.current
        self.current = plan
        return ElasticEvent(kind, plan,
                            f"{prev.shape}->{plan.shape} with "
                            f"{available_devices} devices")


def global_batch_plan(global_batch: int, plan: MeshPlan) -> int:
    """Per-shard batch after a re-mesh; global batch is preserved as long
    as the data-axis product divides it (guaranteed on the ladder above for
    the assigned shapes)."""
    data = 1
    for s, a in zip(plan.shape, plan.axes):
        if a in ("pod", "data"):
            data *= s
    assert global_batch % data == 0, (global_batch, data)
    return global_batch // data
