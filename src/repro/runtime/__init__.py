"""Host-side execution runtime (DESIGN.md §Runtime).

Three cooperating pieces behind the solver drivers' host loops:

  * `repro.runtime.prefetch` — overlapped host→device chunk ingestion
    (double-buffered ``device_put``; ingest accounting);
  * `repro.runtime.writer`   — background checkpoint writer thread with a
    drain/error lifecycle, snapshot manifest, retention, orphan cleanup;
  * `repro.runtime.metrics`  — the pluggable ``log_scalars`` sink
    protocol (null/stdout/jsonl/tee/collect).
"""

from repro.runtime.metrics import (CollectMetrics, JsonlMetrics,  # noqa: F401
                                   MetricsLogger, NullMetrics,
                                   StdoutMetrics, TeeMetrics, as_metrics,
                                   close_metrics)
from repro.runtime.prefetch import (IngestMeter, prefetch_to_device,  # noqa: F401,E501
                                    tree_nbytes)
from repro.runtime.writer import (CheckpointWriter, cleanup_orphans,  # noqa: F401,E501
                                  read_manifest, snapshot_name,
                                  write_snapshot)
