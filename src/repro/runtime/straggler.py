"""Straggler detection & mitigation policy.

At multi-thousand-chip scale the dominant availability hazards are slow
hosts (thermal throttling, failing HBM, flaky ICI links) and dead hosts.
The *policy* layer here is transport-agnostic and fully unit-testable on
one host; the launcher wires it to whatever signal source exists (per-host
step-duration reports in a real deployment; synthetic timings in tests).

Policy (EWMA + robust z-score):
  * track an exponentially-weighted mean/variance of each host's step time,
  * a host whose EWMA exceeds `threshold` x the fleet median for
    `patience` consecutive reports is flagged STRAGGLER,
  * a host silent for `dead_after_s` is flagged DEAD,
  * flagged hosts produce an action: first REBALANCE (shrink its data
    shard — supported by the index-based pipeline), then EVICT (trigger the
    elastic controller to re-mesh without it; see runtime/elastic.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    alpha: float = 0.2           # EWMA weight of the newest sample
    threshold: float = 1.5       # x fleet median EWMA
    patience: int = 3            # consecutive slow reports before flagging
    dead_after_s: float = 120.0  # silence -> DEAD
    rebalance_first: bool = True


@dataclasses.dataclass
class HostState:
    ewma: Optional[float] = None
    slow_count: int = 0
    last_seen: float = 0.0
    status: str = "OK"           # OK | STRAGGLER | DEAD | EVICTED


class StragglerMonitor:
    def __init__(self, hosts: List[str],
                 cfg: StragglerConfig = StragglerConfig(),
                 clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.hosts: Dict[str, HostState] = {
            h: HostState(last_seen=clock()) for h in hosts}

    def report(self, host: str, step_time_s: float):
        st = self.hosts[host]
        st.last_seen = self.clock()
        a = self.cfg.alpha
        st.ewma = step_time_s if st.ewma is None else \
            a * step_time_s + (1 - a) * st.ewma

    def _median_ewma(self) -> Optional[float]:
        vals = sorted(s.ewma for s in self.hosts.values()
                      if s.ewma is not None and s.status == "OK")
        if not vals:
            return None
        return vals[len(vals) // 2]

    def evaluate(self) -> List[dict]:
        """Returns mitigation actions: {host, action: REBALANCE|EVICT}."""
        actions = []
        med = self._median_ewma()
        now = self.clock()
        for h, st in self.hosts.items():
            if st.status == "EVICTED":
                continue
            if now - st.last_seen > self.cfg.dead_after_s:
                st.status = "DEAD"
                actions.append({"host": h, "action": "EVICT",
                                "reason": "dead"})
                st.status = "EVICTED"
                continue
            if med is None or st.ewma is None:
                continue
            if st.ewma > self.cfg.threshold * med:
                st.slow_count += 1
                if st.slow_count >= self.cfg.patience:
                    if self.cfg.rebalance_first and st.status == "OK":
                        st.status = "STRAGGLER"
                        actions.append({"host": h, "action": "REBALANCE",
                                        "reason": f"ewma {st.ewma:.2f}s > "
                                        f"{self.cfg.threshold}x median "
                                        f"{med:.2f}s"})
                    else:
                        actions.append({"host": h, "action": "EVICT",
                                        "reason": "persistent straggler"})
                        st.status = "EVICTED"
            else:
                st.slow_count = 0
                if st.status == "STRAGGLER":
                    st.status = "OK"    # recovered after rebalance
        return actions

    def healthy_hosts(self) -> List[str]:
        return [h for h, s in self.hosts.items()
                if s.status in ("OK", "STRAGGLER")]
