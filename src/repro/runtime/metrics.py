"""Pluggable metrics pipeline for the host-side runtime (DESIGN.md
§Runtime).

Every host loop in this repo — the segmented solver drivers, the
minibatch epoch driver, the traced benchmark driver, the estimator's
``partial_fit`` stream, the background checkpoint writer — emits its
per-boundary diagnostics through one tiny protocol:

    logger.log_scalars(step, {"energy": 1.2e6, "segment_s": 0.41, ...})

in the spirit of HomebrewNLP-Jax's ``wandblog.py``: the producer never
knows (or imports) the consumer, so the same driver feeds a no-op sink in
production, stdout while debugging, a JSONL file for offline analysis, or
a user-supplied wandb/TensorBoard adapter — anything with a
``log_scalars`` method qualifies; subclassing is never required.

Sinks must tolerate being called from more than one thread: the
checkpoint writer reports its write latency from the writer thread while
the driver logs segment metrics from the main thread (`JsonlMetrics`
locks around its file; the others are trivially safe).

Values may be Python numbers or device scalars; sinks coerce through
``float()``, which *synchronises* on a device scalar — drivers therefore
only log at host boundaries where the value is already materialised
(segment ends, epoch ends), never inside a jit trace.
"""

from __future__ import annotations

import json
import sys
import threading
from typing import IO, Mapping, Optional, Protocol, runtime_checkable


@runtime_checkable
class MetricsLogger(Protocol):
    """Anything with ``log_scalars(step, scalars)`` is a metrics sink."""

    def log_scalars(self, step: int, scalars: Mapping[str, float]) -> None:
        ...


def _to_float(v) -> float:
    """Coerce a Python / numpy / jax scalar to float (bool -> 0.0/1.0)."""
    return float(v)


class NullMetrics:
    """The default sink: drops everything, costs nothing."""

    def log_scalars(self, step, scalars) -> None:
        pass

    def close(self) -> None:
        pass


class StdoutMetrics:
    """Human-readable one-line-per-call sink (debugging / smoke runs)."""

    def __init__(self, prefix: str = "metrics", stream: Optional[IO] = None):
        self.prefix = prefix
        self.stream = stream if stream is not None else sys.stdout

    def log_scalars(self, step, scalars) -> None:
        body = " ".join(f"{k}={_to_float(v):.6g}"
                        for k, v in sorted(scalars.items()))
        print(f"{self.prefix} step={int(step)} {body}",
              file=self.stream, flush=True)

    def close(self) -> None:
        pass


class JsonlMetrics:
    """Append-only JSON-lines sink: one ``{"step": t, ...}`` object per
    call, flushed per line so a killed run loses at most the line in
    flight.  Thread-safe (writer thread + driver thread share it)."""

    def __init__(self, path):
        self.path = path
        self._lock = threading.Lock()
        self._f = open(path, "a", encoding="utf-8")

    def log_scalars(self, step, scalars) -> None:
        rec = {"step": int(step)}
        rec.update({k: _to_float(v) for k, v in scalars.items()})
        line = json.dumps(rec, sort_keys=True)
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()


class TeeMetrics:
    """Fan one stream of scalars out to several sinks."""

    def __init__(self, *sinks: MetricsLogger):
        self.sinks = tuple(as_metrics(s) for s in sinks)

    def log_scalars(self, step, scalars) -> None:
        for s in self.sinks:
            s.log_scalars(step, scalars)

    def close(self) -> None:
        for s in self.sinks:
            close_metrics(s)


class CollectMetrics:
    """In-memory sink: ``records`` is a list of (step, dict) — unit tests
    and notebook inspection."""

    def __init__(self):
        self.records = []
        self._lock = threading.Lock()

    def log_scalars(self, step, scalars) -> None:
        rec = {k: _to_float(v) for k, v in scalars.items()}
        with self._lock:
            self.records.append((int(step), rec))

    def close(self) -> None:
        pass


def as_metrics(obj) -> MetricsLogger:
    """Normalise the ``metrics=`` argument every driver accepts: None ->
    the null sink; a string -> a named built-in ("null" | "stdout");
    anything with ``log_scalars`` passes through."""
    if obj is None:
        return NullMetrics()
    if isinstance(obj, str):
        if obj == "null":
            return NullMetrics()
        if obj == "stdout":
            return StdoutMetrics()
        raise ValueError(f"unknown metrics sink name {obj!r}; expected "
                         f"'null' | 'stdout', a sink object, or None")
    if not hasattr(obj, "log_scalars"):
        raise TypeError(
            f"metrics= expects an object with log_scalars(step, scalars); "
            f"got {type(obj).__name__}")
    return obj


def close_metrics(obj) -> None:
    """Close a sink if it supports closing (the protocol does not require
    it, so user adapters without close() are fine)."""
    close = getattr(obj, "close", None)
    if close is not None:
        close()
