"""Pluggable metrics pipeline for the host-side runtime (DESIGN.md
§Runtime).

Every host loop in this repo — the segmented solver drivers, the
minibatch epoch driver, the traced benchmark driver, the estimator's
``partial_fit`` stream, the background checkpoint writer — emits its
per-boundary diagnostics through one tiny protocol:

    logger.log_scalars(step, {"energy": 1.2e6, "segment_s": 0.41, ...})

in the spirit of HomebrewNLP-Jax's ``wandblog.py``: the producer never
knows (or imports) the consumer, so the same driver feeds a no-op sink in
production, stdout while debugging, a JSONL file for offline analysis, or
a user-supplied wandb/TensorBoard adapter — anything with a
``log_scalars`` method qualifies; subclassing is never required.

Sinks must tolerate being called from more than one thread: the
checkpoint writer reports its write latency from the writer thread while
the driver logs segment metrics from the main thread (`JsonlMetrics`
locks around its file; the others are trivially safe).

Values may be Python numbers or device scalars; sinks coerce through
``float()``, which *synchronises* on a device scalar — drivers therefore
only log at host boundaries where the value is already materialised
(segment ends, epoch ends), never inside a jit trace.
"""

from __future__ import annotations

import json
import sys
import threading
from typing import IO, Mapping, Optional, Protocol, runtime_checkable


@runtime_checkable
class MetricsLogger(Protocol):
    """Anything with ``log_scalars(step, scalars)`` is a metrics sink."""

    def log_scalars(self, step: int, scalars: Mapping[str, float]) -> None:
        ...


def _to_float(v) -> float:
    """Coerce a Python / numpy / jax scalar to float (bool -> 0.0/1.0)."""
    return float(v)


class NullMetrics:
    """The default sink: drops everything, costs nothing."""

    def log_scalars(self, step, scalars) -> None:
        pass

    def close(self) -> None:
        pass


class StdoutMetrics:
    """Human-readable one-line-per-call sink (debugging / smoke runs)."""

    def __init__(self, prefix: str = "metrics", stream: Optional[IO] = None):
        self.prefix = prefix
        self.stream = stream if stream is not None else sys.stdout

    def log_scalars(self, step, scalars) -> None:
        body = " ".join(f"{k}={_to_float(v):.6g}"
                        for k, v in sorted(scalars.items()))
        print(f"{self.prefix} step={int(step)} {body}",
              file=self.stream, flush=True)

    def close(self) -> None:
        pass


class JsonlMetrics:
    """Append-only JSON-lines sink: one ``{"step": t, ...}`` object per
    call, flushed per line so a killed run loses at most the line in
    flight.  Thread-safe (writer thread + driver thread share it)."""

    def __init__(self, path):
        self.path = path
        self._lock = threading.Lock()
        self._f = open(path, "a", encoding="utf-8")

    def log_scalars(self, step, scalars) -> None:
        rec = {"step": int(step)}
        rec.update({k: _to_float(v) for k, v in scalars.items()})
        line = json.dumps(rec, sort_keys=True)
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()


class TeeMetrics:
    """Fan one stream of scalars out to several sinks."""

    def __init__(self, *sinks: MetricsLogger):
        self.sinks = tuple(as_metrics(s) for s in sinks)

    def log_scalars(self, step, scalars) -> None:
        for s in self.sinks:
            s.log_scalars(step, scalars)

    def close(self) -> None:
        for s in self.sinks:
            close_metrics(s)


class CollectMetrics:
    """In-memory sink: ``records`` is a list of (step, dict) — unit tests
    and notebook inspection."""

    def __init__(self):
        self.records = []
        self._lock = threading.Lock()

    def log_scalars(self, step, scalars) -> None:
        rec = {k: _to_float(v) for k, v in scalars.items()}
        with self._lock:
            self.records.append((int(step), rec))

    def close(self) -> None:
        pass


class EarlyStopHook(CollectMetrics):
    """Metrics-driven early stop: a collect sink that watches the energy
    stream and raises ``should_stop`` when per-segment improvement stalls
    (ROADMAP runtime follow-up).

    Passed as the ``metrics=`` sink of any segmented driver (the solver
    drivers check ``should_stop`` after each boundary's ``log_scalars``
    and exit the host loop early), it needs no driver-specific wiring —
    it rides the same one-method protocol every sink uses, and keeps
    `CollectMetrics`' ``records`` for inspection of the decision.

    ``metric`` names the scalar(s) to watch, first match wins per call —
    the default covers the segmented drivers' spellings ("energy" for the
    single solve, "energy_best" batched, "e_val" minibatch, "energy"
    again for hierarchy rounds).  A stall is a boundary whose best-so-far
    value improves by a RELATIVE margin below ``rel_tol``;
    ``patience`` consecutive stalls (after ``min_records`` boundaries)
    trip the stop.  Non-finite and metric-free records are ignored.
    Thread-safe like its base; ``should_stop`` is monotone (never reset).
    """

    def __init__(self, metric=("energy", "energy_best", "e_val"),
                 rel_tol: float = 1e-3, patience: int = 2,
                 min_records: int = 1):
        super().__init__()
        self.metric = (metric,) if isinstance(metric, str) else tuple(metric)
        self.rel_tol = float(rel_tol)
        self.patience = int(patience)
        self.min_records = int(min_records)
        self.should_stop = False
        self.stopped_at: Optional[int] = None
        self._best: Optional[float] = None
        self._stall = 0
        self._seen = 0

    def log_scalars(self, step, scalars) -> None:
        super().log_scalars(step, scalars)
        val = next((scalars[m] for m in self.metric if m in scalars), None)
        if val is None:
            return
        v = _to_float(val)
        if v != v or v in (float("inf"), float("-inf")):
            return
        with self._lock:
            self._seen += 1
            if self._best is None:
                self._best = v
                return
            denom = max(abs(self._best), 1e-30)
            if (self._best - v) / denom > self.rel_tol:
                self._best, self._stall = v, 0
                return
            self._best = min(self._best, v)
            self._stall += 1
            if self._stall >= self.patience and self._seen > self.min_records:
                if not self.should_stop:
                    self.stopped_at = int(step)
                self.should_stop = True


def should_stop(metrics) -> bool:
    """Driver-side probe: True when the sink requests an early exit.
    Any sink exposing a truthy ``should_stop`` attribute qualifies —
    plain sinks (no such attribute) never stop a driver — and a
    `TeeMetrics` fan-out is searched recursively, so a hook composes
    with a JSONL log."""
    if bool(getattr(metrics, "should_stop", False)):
        return True
    sinks = getattr(metrics, "sinks", None)
    if sinks:
        return any(should_stop(s) for s in sinks)
    return False


def as_metrics(obj) -> MetricsLogger:
    """Normalise the ``metrics=`` argument every driver accepts: None ->
    the null sink; a string -> a named built-in ("null" | "stdout");
    anything with ``log_scalars`` passes through."""
    if obj is None:
        return NullMetrics()
    if isinstance(obj, str):
        if obj == "null":
            return NullMetrics()
        if obj == "stdout":
            return StdoutMetrics()
        raise ValueError(f"unknown metrics sink name {obj!r}; expected "
                         f"'null' | 'stdout', a sink object, or None")
    if not hasattr(obj, "log_scalars"):
        raise TypeError(
            f"metrics= expects an object with log_scalars(step, scalars); "
            f"got {type(obj).__name__}")
    return obj


def close_metrics(obj) -> None:
    """Close a sink if it supports closing (the protocol does not require
    it, so user adapters without close() are fine)."""
    close = getattr(obj, "close", None)
    if close is not None:
        close()
