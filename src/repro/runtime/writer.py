"""Background checkpoint writer + snapshot manifest (DESIGN.md §Runtime).

The segmented drivers (core/kmeans.py) used to block ~15 ms per boundary
on the synchronous ``device_get`` + atomic npz write
(BENCH_checkpoint.json) — pure host time the solve cannot hide.  This
module moves the *write* off the critical path while keeping every bit of
the resume guarantee:

  * the **snapshot is taken synchronously**: the driver calls
    ``jax.device_get(state)`` at the segment boundary and hands the
    writer a host tree.  The artifact content is therefore exactly what
    the synchronous path would have written — bit-identical resume does
    not depend on writer timing at all; only the file I/O is deferred.
  * the **writer is a single daemon thread** over a bounded queue
    (default depth 2), so a driver that outruns the disk back-pressures
    instead of buffering unboundedly.
  * **errors propagate**: the first write failure is recorded and
    re-raised on the next ``submit``/``drain``/``close`` — the drivers
    close the writer in a ``finally``, so a failed write still fails the
    run instead of silently dropping snapshots.
  * **drain on exit**: ``close()`` processes everything queued, joins the
    thread, then surfaces any error; after the driver returns, every
    snapshot it reported is durable on disk.

Checkpoint lifecycle (ROADMAP item) lives here too:

  * ``write_snapshot`` — the shared synchronous primitive (the writer
    thread and the distributed driver's snapshot path both use it):
    atomic tmp+rename ``serialize.save``, then an atomically rewritten
    ``manifest.json``, then retention deletions.  The ordering is what
    makes deletion crash-safe: the manifest never references a file that
    is about to be deleted, so a crash between the manifest rewrite and
    the ``unlink`` leaves at worst an orphaned-but-complete artifact —
    never a manifest pointing at nothing.
  * retention — ``keep_last_n`` (sliding window) and ``keep_every_m``
    (every m-th boundary kept forever, for post-hoc trajectory analysis);
    the newest snapshot is always retained.
  * ``cleanup_orphans`` — startup sweep removing ``*.tmp`` files a killed
    writer left behind (the atomic-rename protocol guarantees they are
    never valid artifacts).
"""

from __future__ import annotations

import json
import os
import queue
import threading
import time
from pathlib import Path
from typing import Optional

from repro.runtime.metrics import as_metrics

# NOTE: repro.core.serialize is imported inside `write_snapshot`, not at
# module scope — core/kmeans.py imports this module, and importing the
# repro.core package from here would close an import cycle.

MANIFEST_NAME = "manifest.json"
MANIFEST_SCHEMA = "ckpt_manifest/v1"

_STOP = object()


def snapshot_name(step: int) -> str:
    """Canonical artifact file name for a boundary snapshot."""
    return f"it_{int(step):08d}.npz"


def manifest_path(ckpt_dir) -> Path:
    return Path(ckpt_dir) / MANIFEST_NAME


def read_manifest(ckpt_dir) -> Optional[dict]:
    """The run directory's manifest, or None (no manifest yet / legacy
    directory / unreadable file — callers fall back to a directory
    scan)."""
    p = manifest_path(ckpt_dir)
    try:
        with open(p, "r", encoding="utf-8") as f:
            m = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if not isinstance(m, dict) or m.get("schema") != MANIFEST_SCHEMA:
        return None
    return m


def _write_manifest(ckpt_dir, manifest: dict) -> None:
    """Atomic tmp+rename rewrite — a reader never sees a torn manifest."""
    p = manifest_path(ckpt_dir)
    tmp = p.with_name(p.name + ".tmp")
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    os.replace(tmp, p)


def cleanup_orphans(ckpt_dir) -> list:
    """Remove ``*.tmp`` files left by a killed writer (both artifact and
    manifest temps).  Atomic-rename writing means a ``.tmp`` is never a
    complete artifact, so deletion is always safe.  Returns the removed
    paths."""
    d = Path(ckpt_dir)
    if not d.exists():
        return []
    removed = []
    for p in d.glob("*.tmp"):
        try:
            p.unlink()
            removed.append(p)
        except OSError:
            pass
    return removed


def _json_safe(v):
    try:
        json.dumps(v)
        return v
    except (TypeError, ValueError):
        return str(v)


def _apply_retention(snaps: list, keep_last_n: int, keep_every_m: int):
    """(retained, dropped) over step-sorted manifest entries.  With both
    knobs 0 everything is retained; otherwise an entry survives when it
    is among the newest ``keep_last_n``, on a ``keep_every_m`` boundary
    (step % m == 0), or the newest overall (always kept: it is the resume
    point)."""
    if not snaps or (keep_last_n <= 0 and keep_every_m <= 0):
        return snaps, []
    last = {e["file"] for e in snaps[-max(keep_last_n, 1):]} \
        if keep_last_n > 0 else {snaps[-1]["file"]}
    retained, dropped = [], []
    for e in snaps:
        keep = e["file"] in last or e is snaps[-1] or \
            (keep_every_m > 0 and e["step"] % keep_every_m == 0)
        (retained if keep else dropped).append(e)
    return retained, dropped


def write_snapshot(ckpt_dir, state, *, kind: str, step: int,
                   extra: Optional[dict] = None,
                   keep_last_n: int = 0, keep_every_m: int = 0) -> Path:
    """Synchronous snapshot primitive: artifact, manifest, retention —
    in that order (see the module docstring for why the order is the
    crash-safety argument).  ``state`` may be device or host arrays;
    `serialize.save` gathers either."""
    from repro.core import serialize
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    name = snapshot_name(step)
    path = serialize.save(d / name, state, kind=kind, extra=extra)
    entry = {"file": path.name, "step": int(step),
             "meta": {k: _json_safe(v) for k, v in (extra or {}).items()}}
    manifest = read_manifest(d)
    if manifest is None:
        manifest = {"schema": MANIFEST_SCHEMA, "snapshots": []}
    snaps = [e for e in manifest.get("snapshots", [])
             if e.get("file") != entry["file"]]
    snaps.append(entry)
    snaps.sort(key=lambda e: e["step"])
    retained, dropped = _apply_retention(snaps, int(keep_last_n),
                                         int(keep_every_m))
    manifest.update(kind=kind, latest=retained[-1]["file"],
                    snapshots=retained)
    _write_manifest(d, manifest)
    for e in dropped:
        try:
            (d / e["file"]).unlink()
        except FileNotFoundError:
            pass
    return path


class CheckpointWriter:
    """Single-thread background writer over `write_snapshot`.

    Usage (exactly what the segmented drivers do)::

        writer = CheckpointWriter(ckpt_dir, kind=serialize.KIND_LOOP,
                                  keep_last_n=3, metrics=sink)
        try:
            for segment in run:
                writer.submit(jax.device_get(state), t, extra_meta)
        finally:
            writer.close()      # drain + join; re-raises a failed write

    ``submit`` blocks only when ``queue_size`` writes are already
    pending (disk back-pressure), and re-raises any earlier write error
    immediately so failures surface at the next boundary rather than at
    the end of a long run.  The write latency of every snapshot is
    emitted to ``metrics`` as ``checkpoint_write_s`` (from the writer
    thread — sinks are thread-safe by contract).
    """

    def __init__(self, ckpt_dir, *, kind: str,
                 keep_last_n: int = 0, keep_every_m: int = 0,
                 metrics=None, queue_size: int = 2):
        self.dir = Path(ckpt_dir)
        self.kind = kind
        self.keep_last_n = int(keep_last_n)
        self.keep_every_m = int(keep_every_m)
        self.metrics = as_metrics(metrics)
        self.last_write_s: Optional[float] = None
        self.n_written = 0
        self._q: queue.Queue = queue.Queue(maxsize=max(1, int(queue_size)))
        self._error: Optional[BaseException] = None
        self._closed = False
        self.dir.mkdir(parents=True, exist_ok=True)
        cleanup_orphans(self.dir)
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="repro-ckpt-writer")
        self._thread.start()

    # -- driver-facing API -------------------------------------------------

    def submit(self, state_host, step: int,
               extra: Optional[dict] = None) -> None:
        """Queue one snapshot.  ``state_host`` must already be the
        boundary state (the caller's ``jax.device_get`` IS the snapshot
        point; the writer only persists it)."""
        self._check()
        if self._closed:
            raise RuntimeError("CheckpointWriter is closed")
        self._q.put((state_host, int(step), extra))

    def drain(self) -> None:
        """Block until every queued snapshot is on disk; then surface any
        write error."""
        self._q.join()
        self._check()

    def close(self) -> None:
        """Drain, stop the thread, surface any write error.  Idempotent."""
        if not self._closed:
            self._closed = True
            self._q.put(_STOP)
            self._thread.join()
        self._check()

    def __enter__(self) -> "CheckpointWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # on a clean exit surface writer errors; if the body already
        # raised, still drain/join but let the body's error win
        try:
            self.close()
        except BaseException:
            if exc_type is None:
                raise

    # -- worker ------------------------------------------------------------

    def _check(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                if self._error is not None:
                    continue    # stop persisting after the first failure
                state, step, extra = item
                t0 = time.perf_counter()
                write_snapshot(self.dir, state, kind=self.kind, step=step,
                               extra=extra, keep_last_n=self.keep_last_n,
                               keep_every_m=self.keep_every_m)
                self.last_write_s = time.perf_counter() - t0
                self.n_written += 1
                try:
                    self.metrics.log_scalars(
                        step, {"checkpoint_write_s": self.last_write_s})
                except Exception:
                    pass    # a broken sink must not poison the run
            except BaseException as e:   # noqa: BLE001 — propagated later
                self._error = e
            finally:
                self._q.task_done()
