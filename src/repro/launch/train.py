"""Training launcher: the end-to-end driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --smoke --steps 50           # reduced config, host mesh, CPU-sized

On real hardware the same driver runs the full config on the production
mesh (--mesh single|multi).  Integrates: deterministic data pipeline,
AdamW (+ optional int8-EF gradient compression), async checkpointing with
resume, straggler monitoring hooks, and elastic re-mesh on device loss.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.checkpointer import AsyncCheckpointer
from repro.configs.registry import get_config, reduced_config
from repro.data.tokens import DataConfig, TokenStream
from repro.launch import steps as ST
from repro.launch.mesh import (data_axes_of, make_host_mesh,
                               make_production_mesh)
from repro.models import params as pr
from repro.models.config import ShapeSpec
from repro.models.model import Model, RunFlags, make_constrain
from repro.optim import adamw
from repro.runtime.straggler import StragglerMonitor


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + small shape (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=0)
    ap.add_argument("--global-batch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--remat", default="full",
                    choices=["none", "dots", "full"])
    return ap.parse_args(argv)


def run(args) -> dict:
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    seq = args.seq_len or (128 if args.smoke else 4096)
    gbs = args.global_batch or (8 if args.smoke else 256)
    shape = ShapeSpec("train_cli", seq, gbs, "train")
    flags = RunFlags(remat=args.remat,
                     block_q=min(512, seq), block_kv=min(1024, seq))

    rules = ST.rules_for(mesh, cfg, shape)
    model = Model(cfg, flags)
    constrain = make_constrain(mesh, rules)
    specs = model.param_specs()
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=20,
                                decay_steps=max(args.steps, 100),
                                compression=args.compression)

    params = pr.init_tree(specs, jax.random.PRNGKey(0))
    params = jax.device_put(params, pr.sharding_tree(specs, mesh, rules))
    opt_state = adamw.init_state(params, opt_cfg)
    train_step = jax.jit(ST.make_train_step(model, opt_cfg, constrain),
                         donate_argnums=(0, 1))

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=gbs)
    stream = TokenStream(data_cfg)
    start_step = 0

    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(Path(args.ckpt_dir))
        if args.resume:
            restored, meta = ckpt.restore_latest(
                {"params": params, "m": opt_state.m, "v": opt_state.v})
            if restored is not None:
                params = restored["params"]
                opt_state = opt_state._replace(
                    m=restored["m"], v=restored["v"],
                    step=jax.numpy.asarray(meta["step"], jax.numpy.int32))
                start_step = int(meta["step"])
                stream = TokenStream(data_cfg, start_step=start_step)
                print(f"[resume] from step {start_step}")

    monitor = StragglerMonitor([f"host{i}" for i in
                                range(max(jax.process_count(), 1))])

    # emergency checkpoint on SIGTERM/SIGINT (preemption notice): finish
    # the current step, save, exit cleanly — restart resumes exactly.
    import signal
    stop_requested = {"flag": False}

    def _on_signal(signum, frame):
        stop_requested["flag"] = True
        print(f"[signal] {signal.Signals(signum).name} received — will "
              f"checkpoint and exit after this step", flush=True)

    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            signal.signal(sig, _on_signal)
        except ValueError:
            pass    # non-main thread (tests)

    losses = []
    t_start = time.perf_counter()
    for step in range(start_step, args.steps):
        host_batch = stream.batch_at(step)
        batch = {k: jax.numpy.asarray(v) for k, v in host_batch.items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        monitor.report("host0", dt)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms", flush=True)
        for action in monitor.evaluate():
            print(f"[straggler] {action}", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save({"params": params, "m": opt_state.m,
                       "v": opt_state.v}, step=step + 1,
                      extra={"data": stream.state()})
        if stop_requested["flag"]:
            if ckpt:
                ckpt.save({"params": params, "m": opt_state.m,
                           "v": opt_state.v}, step=step + 1,
                          extra={"data": stream.state(),
                                 "emergency": True})
                ckpt.wait()
            print(f"[signal] emergency checkpoint at step {step + 1}; "
                  f"exiting", flush=True)
            break
    if ckpt:
        ckpt.save({"params": params, "m": opt_state.m, "v": opt_state.v},
                  step=args.steps, extra={"data": stream.state()})
        ckpt.wait()
    wall = time.perf_counter() - t_start
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "steps": len(losses), "wall_s": wall}


def main():
    out = run(parse_args())
    print(f"[done] {out}")


if __name__ == "__main__":
    main()
