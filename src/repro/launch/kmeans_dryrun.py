import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")
# Must run before any other import (jax locks device count on first init).

"""Dry-run of the paper's solver itself at pod scale (the
"most representative of the paper's technique" roofline rows).

Workload: one AA-KMeans iteration over N = 2^27 (134M) samples, d = 64,
K = 1000, samples sharded over ("pod","data").  One iteration = assignment
+ psum'd update + energy + the replicated AA solve — the steady-state body
of Algorithm 1 (cost_analysis is exact here: no layer scans).

Variants (§Perf ladder for the K-Means hillclimb):
  split        — dense (N,K) distance matrix materialised, separate passes
  blocked      — assignment evaluated in row blocks (no (N,K) buffer)
  blocked_bf16 — + bf16 sample storage (halves the X stream)
  (fused Pallas single-pass terms are analytic — kernels_bench.py — since
   interpret-mode HLO does not reflect the TPU kernel's memory behaviour)

    PYTHONPATH=src python -m repro.launch.kmeans_dryrun [--mesh both]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import anderson, lloyd
from repro.core.anderson import AAConfig
from repro.launch.dryrun import (ARTIFACTS, memory_dict, parse_collectives,
                                 parse_dot_flops)
from repro.launch.mesh import data_axes_of, make_production_mesh

N, D, K = 2 ** 27, 64, 1000


def one_iteration(x_local, c, aa_state, e_prev, e_prev2, axes,
                  block_n: int = 0):
    """Steady-state Algorithm-1 body under shard_map (accept path)."""
    cfg = AAConfig()
    res = lloyd.assign(x_local, c.astype(x_local.dtype), block_n=block_n,
                       block_unroll=block_n > 0)
    e_t = jax.lax.psum(lloyd.energy(x_local, c.astype(x_local.dtype),
                                    res.labels), axes)
    aa_state = anderson.adjust_m(aa_state, e_t, e_prev, e_prev2, cfg)
    sums, counts = lloyd.cluster_sums(x_local.astype(jnp.float32),
                                      res.labels, K)
    sums = jax.lax.psum(sums, axes)
    counts = jax.lax.psum(counts, axes)
    c_au = lloyd.update_from_sums(sums, counts, c)
    g = c_au.reshape(-1)
    f = g - c.reshape(-1)
    aa_state, c_next, _, _ = anderson.aa_push_and_solve(aa_state, f, g, cfg)
    return (c_next.reshape(c.shape), aa_state, e_t, e_prev,
            res.labels)


def build_full_solver(mesh):
    """The complete Algorithm-1 solver (lax.while_loop incl. convergence
    psums and the dynamic-m logic) on the production mesh — proves the
    whole program lowers/compiles, complementing the per-iteration
    variants whose costs are loop-free and therefore exactly countable."""
    from repro.core.distributed import make_distributed_kmeans
    from repro.core.kmeans import KMeansConfig
    axes = tuple(mesh.axis_names)
    fit = make_distributed_kmeans(mesh, KMeansConfig(k=K, max_iter=200),
                                  axes)
    x = jax.ShapeDtypeStruct((N, D), jnp.float32,
                             sharding=NamedSharding(mesh, P(axes)))
    c0 = jax.ShapeDtypeStruct((K, D), jnp.float32,
                              sharding=NamedSharding(mesh, P()))
    return fit, (x, c0)


def build(mesh, variant: str):
    # K-Means has no model-parallel dimension: every mesh axis is a data
    # axis (the 256/512 chips all hold sample shards; C is replicated).
    axes = tuple(mesh.axis_names)
    block_n = 0
    dtype = jnp.float32
    if variant.startswith("blocked"):
        block_n = 65536
    if variant.endswith("bf16"):
        dtype = jnp.bfloat16
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    cfg = AAConfig()
    x_spec = P(axes)
    rep = P()

    def step(x_local, c, dF, dG, f_prev, g_prev, ncols, head, m,
             e_prev, e_prev2):
        aa_state = anderson.AAState(dF, dG, f_prev, g_prev, ncols, head, m)
        c2, aa2, e_t, e_p, labels = one_iteration(
            x_local, c, aa_state, e_prev, e_prev2, axes, block_n)
        return (c2, aa2.dF, aa2.dG, aa2.f_prev, aa2.g_prev, aa2.ncols,
                aa2.head, aa2.m, e_t, e_p, labels)

    smapped = jax.shard_map(
        step, mesh=mesh,
        in_specs=(x_spec,) + (rep,) * 10,
        out_specs=(rep,) * 10 + (x_spec,))

    def sds(shape, dt, spec):
        return jax.ShapeDtypeStruct(shape, dt,
                                    sharding=NamedSharding(mesh, spec))

    kd = K * D
    args = (
        sds((N, D), dtype, x_spec),
        sds((K, D), jnp.float32, rep),
        sds((cfg.mbar, kd), jnp.float32, rep),
        sds((cfg.mbar, kd), jnp.float32, rep),
        sds((kd,), jnp.float32, rep),
        sds((kd,), jnp.float32, rep),
        sds((), jnp.int32, rep), sds((), jnp.int32, rep),
        sds((), jnp.int32, rep),
        sds((), jnp.float32, rep), sds((), jnp.float32, rep),
    )
    return jax.jit(smapped), args


def model_flops_kmeans() -> float:
    # useful work: distance cross-term + the segment-sum adds + energy
    return 2.0 * N * K * D + N * D + N * D


def run_variant(mesh_kind: str, variant: str, save=True):
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rec = {"arch": "aa-kmeans-134m-d64-k1000", "shape": f"iter_{variant}",
           "mesh": mesh_kind, "devices": 512 if multi else 256,
           "flags": {"variant": variant}, "tag": "", "ok": False}
    t0 = time.perf_counter()
    try:
        if variant == "full_solver":
            fn, args = build_full_solver(mesh)
        else:
            fn, args = build(mesh, variant)
        lowered = fn.lower(*args)
        rec["time_lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["time_compile_s"] = round(time.perf_counter() - t1, 2)
        ca = compat.cost_analysis(compiled)
        rec["hlo_flops_per_device"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
        rec["memory"] = memory_dict(compiled)
        hlo = compiled.as_text()
        rec["hlo_dot_flops_per_device"] = parse_dot_flops(hlo)
        operand, wire, counts = parse_collectives(hlo)
        rec["collective_operand_bytes_per_device"] = operand
        rec["collective_wire_bytes_per_device"] = wire
        rec["collective_counts"] = counts
        rec["collective_total_per_device"] = float(sum(wire.values()))
        rec["model_flops"] = model_flops_kmeans()
        rec["n_params"] = K * D
        rec["n_active_params"] = K * D
        # no scans/loops anywhere (blocked variants unroll the row blocks):
        # cost_analysis is exact for this workload.
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["time_total_s"] = round(time.perf_counter() - t0, 2)
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        path = ARTIFACTS / f"aa-kmeans__iter_{variant}__{mesh_kind}.json"
        path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variants", default="split,blocked,blocked_bf16")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mk in meshes:
        for v in args.variants.split(","):
            rec = run_variant(mk, v)
            if rec["ok"]:
                print(f"[ok] kmeans {v} {mk}: "
                      f"flops/dev {rec['hlo_flops_per_device']:.3e} "
                      f"bytes/dev {rec['hlo_bytes_per_device']:.3e} "
                      f"coll/dev {rec['collective_total_per_device']:.3e} "
                      f"temp {rec['memory'].get('temp_size_in_bytes',0)/2**30:.2f}GiB",
                      flush=True)
            else:
                print(f"[FAIL] kmeans {v} {mk}: {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
