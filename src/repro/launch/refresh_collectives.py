import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")
# Must precede any jax import.

"""Refresh pass: re-extract collective bytes (fixed tuple-all-reduce
parser) and dot-flops from a cheap scanned-only recompile of every
existing artifact, updating the JSON in place.

Calibrated per-unit metrics (flops/bytes) are untouched; the calibrated
wire total is rescaled by new_raw/old_raw per collective kind (collectives
inside the layer scan appear once in both old and new raw parses, so the
ratio transfers to the calibrated totals).  Artifacts re-generated after
the parser fix are skipped via the `parser_v2` marker.
"""

import dataclasses
import json
import sys
import time
from pathlib import Path

from repro.launch import steps as ST
from repro.launch.dryrun import (ARTIFACTS, parse_collectives,
                                 parse_dot_flops)
from repro.launch.mesh import make_production_mesh
from repro.models.model import RunFlags


def refresh(path: Path):
    rec = json.loads(path.read_text())
    if rec.get("skipped") or not rec.get("ok") or rec.get("parser_v2"):
        return "skip"
    mesh = make_production_mesh(multi_pod=rec["mesh"] == "multi")
    fl = {k: v for k, v in rec["flags"].items()
          if k in RunFlags.__dataclass_fields__}
    flags = RunFlags(**fl)
    t0 = time.perf_counter()
    bundle = ST.build(rec["arch"], rec["shape"], mesh, flags=flags)
    compiled = bundle.lower().compile()
    hlo = compiled.as_text()
    operand, wire, counts = parse_collectives(hlo)
    old_wire = rec.get("collective_wire_bytes_per_device", {})
    cal = rec.get("calib")
    if cal and "wire_corrected" in cal:
        new_corr = {}
        for k, v in cal["wire_corrected"].items():
            old_raw = old_wire.get(k, 0.0)
            new_raw = wire.get(k, 0.0)
            if old_raw > 0:
                new_corr[k] = v * (new_raw / old_raw)
            else:
                # previously invisible kind: calibrated ~= raw (in-scan
                # collectives appear once; scale by unit count as an upper
                # bound is NOT safe -> record raw and flag)
                new_corr[k] = new_raw
        cal["wire_corrected"] = new_corr
        cal["wire_corrected_total"] = float(sum(new_corr.values()))
        cal["wire_rescaled_by_parser_v2"] = True
    rec["collective_operand_bytes_per_device"] = operand
    rec["collective_wire_bytes_per_device"] = wire
    rec["collective_counts"] = counts
    rec["collective_total_per_device"] = float(sum(wire.values()))
    rec["hlo_dot_flops_per_device"] = parse_dot_flops(hlo)
    rec["parser_v2"] = True
    rec["refresh_time_s"] = round(time.perf_counter() - t0, 2)
    path.write_text(json.dumps(rec, indent=1))
    return f"ok {rec['refresh_time_s']}s"


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else ""
    for p in sorted(ARTIFACTS.glob("*.json")):
        if only and only not in p.name:
            continue
        if p.name.startswith("aa-kmeans"):
            continue
        try:
            status = refresh(p)
        except Exception as e:
            status = f"FAIL {type(e).__name__}: {e}"
        print(f"{p.name}: {status}", flush=True)


if __name__ == "__main__":
    main()
