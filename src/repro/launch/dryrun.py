import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count on first initialisation).  Everything below may import jax.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
        --shape train_4k --mesh multi
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json and
feed benchmarks/roofline.py and EXPERIMENTS.md §Dry-run/§Roofline.
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro import compat
from repro.configs.registry import ARCHS, get_config
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES
from repro.models.model import RunFlags

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def parse_collectives(hlo_text: str):
    """Per-device collective operand bytes by op kind, from optimized HLO.

    Operand shapes appear inline in the op's argument list; we sum operand
    sizes (start/done pairs are counted once via the -start form; plain
    forms counted directly)."""
    operand = {k: 0 for k in _COLLECTIVES}
    wire = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result type may be a tuple — variadic all-reduces are common:
        #   %ar = (f32[1000,64]{1,0}, f32[1000]{0}) all-reduce(%a, %b), ...
        m = re.search(r"=\s+(.+?)\s+(" +
                      "|".join(_COLLECTIVES) + r")(-start|-done)?\(", s)
        if not m:
            continue
        if m.group(3) == "-done":
            continue        # counted at the -start form
        kind = m.group(2)
        grp = re.search(r"replica_groups=\[(\d+),(\d+)\]", s)
        gsize = int(grp.group(2)) if grp else 0
        if not gsize:
            grp2 = re.search(r"replica_groups=\{\{([\d,]+)\}", s)
            gsize = len(grp2.group(1).split(",")) if grp2 else 2
        # result shape(s) sit between '=' and the op name
        res = sum(_shape_bytes(sm) for sm in _SHAPE_RE.finditer(m.group(1)))
        g = max(gsize, 1)
        ring = (g - 1) / g
        # per-device operand bytes (spec proxy) and ring wire-traffic bytes
        if kind == "all-gather":
            op_b, wire_b = res // g, res * ring
        elif kind == "all-reduce":
            op_b, wire_b = res, 2 * res * ring
        elif kind == "reduce-scatter":
            op_b, wire_b = res * g, res * g * ring
        elif kind == "all-to-all":
            op_b, wire_b = res, res * ring
        else:  # collective-permute: one hop
            op_b, wire_b = res, res
        operand[kind] += op_b
        wire[kind] += wire_b
        counts[kind] += 1
    return operand, wire, counts


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_DOT_RE = re.compile(
    r"=\s*\w+\[([\d,]*)\][^ ]*\s+dot\(\s*%([\w.\-]+)",)
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def parse_dot_flops(hlo_text: str) -> float:
    """Sum 2 * prod(result_shape) * prod(contracted lhs dims) over every
    `dot` op, INCLUDING dots inside fusion computations.

    Needed because XLA:CPU's HloCostAnalysis does not attribute the flops
    of a dot that was wrapped into a fusion computation (verified: a
    (8.4M x 64) @ (64 x 1000) dot fused with its elementwise consumers
    reports ~0 of its 1.07e15 flops).  While bodies still count once —
    handled by the same unrolled calibration as the rest."""
    shapes = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, _, dims = m.groups()
            shapes[name] = [int(d) for d in dims.split(",")] if dims else []
    total = 0.0
    for line in hlo_text.splitlines():
        if " dot(" not in line:
            continue
        m = _DOT_RE.search(line)
        if not m:
            continue
        res_dims, lhs_name = m.groups()
        res = 1
        for d in (res_dims.split(",") if res_dims else []):
            res *= int(d)
        lhs = shapes.get(lhs_name)
        mc = _LHS_C_RE.search(line)
        contract = 1
        if lhs is not None and mc and mc.group(1):
            for i in mc.group(1).split(","):
                contract *= lhs[int(i)]
        total += 2.0 * res * contract
    return total


def memory_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes", "host_generated_code_size_in_bytes",
            "host_argument_size_in_bytes", "host_output_size_in_bytes",
            "host_temp_size_in_bytes", "peak_memory_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def n_units(cfg) -> int:
    """Number of outer scanned units (layers, or groups for hybrid/vlm)."""
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.shared_attn_every
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_attn_every
    return cfg.n_layers


def with_units(cfg, n: int):
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=n * cfg.shared_attn_every)
    if cfg.family == "vlm":
        return dataclasses.replace(cfg, n_layers=n * cfg.cross_attn_every)
    return dataclasses.replace(cfg, n_layers=n)


_CAL_METRICS = ("flops", "bytes", "dot_flops")


def _collect_costs(compiled):
    ca = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    _, wire, _ = parse_collectives(hlo)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "dot_flops": parse_dot_flops(hlo),
            "wire": wire}


def calibrate_cell(arch, shape_name, mesh, flags: RunFlags, cfg):
    """XLA counts while/scan bodies ONCE in cost_analysis (verified; see
    EXPERIMENTS.md §Dry-run methodology).  To recover true per-step costs we
    compile two small fully-unrolled variants (1 and 2 outer layer units,
    attention/block loops unrolled, identical widths and block sizes) and
    scale:  total(L) = base + L * per_unit."""
    calib_flags = dataclasses.replace(flags, scan_layers=False,
                                      attn_unroll=True)
    costs = {}
    for n in (1, 2):
        cfg_n = with_units(cfg, n)
        bundle = ST.build(arch, shape_name, mesh, flags=calib_flags,
                          cfg=cfg_n)
        t0 = time.perf_counter()
        compiled = bundle.lower().compile()
        costs[n] = _collect_costs(compiled)
        costs[n]["compile_s"] = round(time.perf_counter() - t0, 2)

    units = n_units(cfg)
    out = {"calib_units": units,
           "calib_compile_s": [costs[1]["compile_s"], costs[2]["compile_s"]]}
    for m in _CAL_METRICS:
        per = costs[2][m] - costs[1][m]
        base = costs[1][m] - per
        out[f"{m}_per_unit"] = per
        out[f"{m}_base"] = base
        out[f"{m}_corrected"] = base + units * per
    wire_tot = {}
    for k in costs[1]["wire"]:
        per = costs[2]["wire"][k] - costs[1]["wire"][k]
        base = costs[1]["wire"][k] - per
        wire_tot[k] = max(base + units * per, 0.0)
    out["wire_corrected"] = wire_tot
    out["wire_corrected_total"] = float(sum(wire_tot.values()))
    return out


def model_flops(cfg, shape) -> float:
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 tok/seq


def cell_supported(arch: str, shape_name: str):
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return False, ("full-attention architecture: 500k dense attention "
                       "is out of scope by assignment (DESIGN.md §Shapes)")
    return True, ""


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             flags: RunFlags, tag: str = "", save: bool = True,
             calibrate: bool = True) -> dict:
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    n_dev = 512 if multi else 256
    mesh = make_production_mesh(multi_pod=multi)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": n_dev, "flags": dataclasses.asdict(flags),
           "tag": tag, "ok": False, "parser_v2": True}
    t0 = time.perf_counter()
    try:
        bundle = ST.build(arch, shape_name, mesh, flags=flags)
        lowered = bundle.lower()
        rec["time_lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["time_compile_s"] = round(time.perf_counter() - t1, 2)

        ca = compat.cost_analysis(compiled)
        rec["hlo_flops_per_device"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
        rec["memory"] = memory_dict(compiled)

        hlo = compiled.as_text()
        rec["hlo_dot_flops_per_device"] = parse_dot_flops(hlo)
        operand, wire, counts = parse_collectives(hlo)
        rec["collective_operand_bytes_per_device"] = operand
        rec["collective_wire_bytes_per_device"] = wire
        rec["collective_counts"] = counts
        rec["collective_total_per_device"] = float(sum(wire.values()))

        cfg = bundle.cfg
        rec["n_params"] = cfg.n_params()
        rec["n_active_params"] = cfg.n_active_params()
        rec["model_flops"] = model_flops(cfg, shape)
        if calibrate:
            rec["calib"] = calibrate_cell(arch, shape_name, mesh, flags, cfg)
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["time_total_s"] = round(time.perf_counter() - t0, 2)
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        sfx = f"__{tag}" if tag else ""
        path = ARTIFACTS / f"{arch}__{shape_name}__{mesh_kind}{sfx}.json"
        path.write_text(json.dumps(rec, indent=1))
        rec["artifact"] = str(path)
    return rec


def flags_from_args(args, shape_name: str = "") -> RunFlags:
    block_q, block_kv = args.block_q, args.block_kv
    if shape_name == "prefill_32k" and (block_q, block_kv) == (512, 1024):
        # default blocking for the 32k prompt: bigger tiles, fewer blocks
        block_q = block_kv = 2048
    return RunFlags(remat=args.remat, block_q=block_q,
                    block_kv=block_kv, skip_blocks=args.skip_blocks,
                    loss_chunk=args.loss_chunk, fold_heads=args.fold_heads,
                    cache_seq_model=args.cache_seq_model,
                    seq_shard_acts=args.seq_shard_acts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--remat", default="full",
                    choices=["none", "dots", "full"])
    ap.add_argument("--block-q", type=int, default=512, dest="block_q")
    ap.add_argument("--block-kv", type=int, default=1024, dest="block_kv")
    ap.add_argument("--skip-blocks", action="store_true", dest="skip_blocks")
    ap.add_argument("--loss-chunk", type=int, default=0, dest="loss_chunk")
    ap.add_argument("--fold-heads", action="store_true", dest="fold_heads")
    ap.add_argument("--cache-seq-model", action="store_true",
                    dest="cache_seq_model")
    ap.add_argument("--seq-shard-acts", action="store_true",
                    dest="seq_shard_acts")
    args = ap.parse_args()

    cells = []
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            ok, why = cell_supported(a, s)
            for m in meshes:
                cells.append((a, s, m, ok, why))

    if args.list:
        for a, s, m, ok, why in cells:
            print(f"{a:22s} {s:12s} {m:7s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    failures = 0
    for a, s, m, ok, why in cells:
        flags = flags_from_args(args, s)
        if not ok:
            print(f"[skip] {a} {s} {m}: {why}", flush=True)
            if not args.tag:
                ARTIFACTS.mkdir(parents=True, exist_ok=True)
                (ARTIFACTS / f"{a}__{s}__{m}.json").write_text(json.dumps(
                    {"arch": a, "shape": s, "mesh": m, "ok": True,
                     "skipped": True, "skip_reason": why}, indent=1))
            continue
        rec = run_cell(a, s, m, flags, tag=args.tag)
        if rec["ok"]:
            mem = rec.get("memory", {})
            print(f"[ok]   {a} {s} {m}: lower {rec['time_lower_s']}s "
                  f"compile {rec['time_compile_s']}s "
                  f"flops/dev {rec['hlo_flops_per_device']:.3e} "
                  f"coll/dev {rec['collective_total_per_device']:.3e}B "
                  f"args/dev {mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp/dev {mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB",
                  flush=True)
        else:
            failures += 1
            print(f"[FAIL] {a} {s} {m}: {rec['error']}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
