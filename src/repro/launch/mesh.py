"""Production mesh construction.

Single pod: (data=16, model=16) — one v5e pod, 256 chips.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the "pod" axis extends
data parallelism so only gradient/FSDP reductions cross pods.

`make_production_mesh` is a FUNCTION (not module-level state) so importing
this module never touches jax device state; the dry-run entrypoint sets
XLA_FLAGS before any jax import to get 512 host placeholder devices.
"""

from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
