"""Step builders: train / prefill / decode entry points + abstract inputs.

`build(arch, shape, mesh, ...)` returns everything the launcher, the
dry-run and the tests need:

  * the jit'd step with explicit in/out shardings,
  * abstract (ShapeDtypeStruct, sharding-annotated) arguments for
    .lower().compile() — no allocation,
  * real-initialisation helpers for smoke tests and the example drivers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.registry import get_config
from repro.launch.mesh import data_axes_of
from repro.models import params as pr
from repro.models.config import SHAPES, ModelConfig, ShapeSpec
from repro.models.model import Model, RunFlags, make_constrain, no_constrain
from repro.optim import adamw
from repro.sharding.rules import ShardingRules, make_rules

PyTree = Any


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, shape: ShapeSpec, mesh, rules,
                 dtype=jnp.bfloat16):
    """ShapeDtypeStructs (with shardings) for the model inputs of a cell."""
    b, s = shape.global_batch, shape.seq_len

    def sds(shp, dt, axes):
        return jax.ShapeDtypeStruct(
            shp, dt, sharding=rules.shape_sharding(mesh, axes, shp))

    if shape.kind in ("train", "prefill"):
        batch = {}
        if cfg.embed_stub:
            batch["frames"] = sds((b, s, cfg.d_model), dtype,
                                  ("batch", "seq", None))
        else:
            batch["tokens"] = sds((b, s), jnp.int32, ("batch", "seq"))
        if cfg.family == "vlm":
            batch["img_embeds"] = sds((b, cfg.n_img_tokens, cfg.d_model),
                                      dtype, ("batch", None, None))
        if shape.kind == "train":
            batch["labels"] = sds((b, s), jnp.int32, ("batch", "seq"))
        return batch

    # decode
    batch = {}
    if cfg.embed_stub:
        batch["frame"] = sds((b, cfg.d_model), dtype, ("batch", None))
    else:
        batch["token"] = sds((b,), jnp.int32, ("batch",))
    return batch


def real_batch(cfg: ModelConfig, shape: ShapeSpec, key,
               dtype=jnp.bfloat16):
    """Concrete random batch matching batch_struct (smoke tests)."""
    b, s = shape.global_batch, shape.seq_len
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {}
    if shape.kind in ("train", "prefill"):
        if cfg.embed_stub:
            batch["frames"] = jax.random.normal(k1, (b, s, cfg.d_model),
                                                jnp.float32).astype(dtype)
        else:
            batch["tokens"] = jax.random.randint(k1, (b, s), 0, cfg.vocab)
        if cfg.family == "vlm":
            batch["img_embeds"] = jax.random.normal(
                k2, (b, cfg.n_img_tokens, cfg.d_model),
                jnp.float32).astype(dtype)
        if shape.kind == "train":
            batch["labels"] = jax.random.randint(k3, (b, s), 0, cfg.vocab)
        return batch
    if cfg.embed_stub:
        batch["frame"] = jax.random.normal(k1, (b, cfg.d_model),
                                           jnp.float32).astype(dtype)
    else:
        batch["token"] = jax.random.randint(k1, (b,), 0, cfg.vocab)
    return batch


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig, constrain):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, aux = model.loss(p, batch, constrain)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, metrics = adamw.apply_updates(params, grads,
                                                         opt_state, opt_cfg)
        metrics = dict(metrics, loss=loss, **aux)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model, constrain):
    def prefill_step(params, batch):
        return model.prefill(params, batch, constrain)
    return prefill_step


def make_decode_step(model: Model, constrain):
    def decode_step(params, batch, cache):
        return model.decode_step(params, batch, cache, constrain)
    return decode_step


# ---------------------------------------------------------------------------
# Bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepBundle:
    cfg: ModelConfig
    shape: ShapeSpec
    model: Model
    rules: ShardingRules
    mesh: Any
    step_fn: Callable          # jit'd; signature depends on kind
    abstract_args: tuple       # ShapeDtypeStruct args for .lower()
    param_specs: PyTree

    def lower(self):
        return self.step_fn.lower(*self.abstract_args)


def rules_for(mesh, cfg: ModelConfig, shape: ShapeSpec,
              flags: RunFlags = RunFlags()) -> ShardingRules:
    seq_sharded = shape.kind == "decode" and shape.global_batch == 1
    moe_ep = (cfg.family == "moe"
              and cfg.n_experts >= mesh.shape.get("model", 1))
    cache_seq_model = (flags.cache_seq_model and shape.kind == "decode"
                       and not seq_sharded)
    return make_rules(mesh, seq_sharded=seq_sharded, moe_ep=moe_ep,
                      cache_seq_model=cache_seq_model,
                      seq_shard_acts=(flags.seq_shard_acts
                                      and shape.kind != "decode"))


def opt_abstract(param_specs, mesh, rules, opt_cfg):
    """Abstract AdamW state matching the param tree (m, v fp32)."""
    p_abs = pr.abstract_tree(param_specs, mesh, rules, jnp.float32)

    def like(t):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                           sharding=s.sharding), t)

    ef = like(p_abs) if opt_cfg.compression != "none" else None
    return adamw.AdamWState(
        jax.ShapeDtypeStruct((), jnp.int32,
                             sharding=NamedSharding(
                                 mesh, jax.sharding.PartitionSpec())),
        like(p_abs), like(p_abs), ef)


def build(arch: str, shape_name: str, mesh, *,
          flags: RunFlags = RunFlags(),
          opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
          cfg: Optional[ModelConfig] = None,
          donate: bool = True) -> StepBundle:
    """Assemble the jit'd step + abstract args for one (arch x shape) cell."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules_for(mesh, cfg, shape, flags)
    model = Model(cfg, flags)
    constrain = make_constrain(mesh, rules)
    specs = model.param_specs()

    p_abs = pr.abstract_tree(specs, mesh, rules, jnp.float32)
    p_shard = pr.sharding_tree(specs, mesh, rules)
    batch_abs = batch_struct(cfg, shape, mesh, rules)

    if shape.kind == "train":
        step = make_train_step(model, opt_cfg, constrain)
        o_abs = opt_abstract(specs, mesh, rules, opt_cfg)
        o_shard = jax.tree.map(lambda s: s.sharding, o_abs)
        jit = jax.jit(step,
                      in_shardings=(p_shard, o_shard, None),
                      out_shardings=(p_shard, o_shard, None),
                      donate_argnums=(0, 1) if donate else ())
        args = (p_abs, o_abs, batch_abs)
    elif shape.kind == "prefill":
        step = make_prefill_step(model, constrain)
        cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        c_shard = pr.sharding_tree(cache_specs, mesh, rules)
        jit = jax.jit(step, in_shardings=(p_shard, None),
                      out_shardings=(None, dict(c_shard)))
        args = (p_abs, batch_abs)
    else:  # decode
        step = make_decode_step(model, constrain)
        cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_abs = pr.abstract_tree(cache_specs, mesh, rules, jnp.bfloat16)
        # 'len' must be int32 regardless of the cache dtype
        cache_abs = {k: (jax.ShapeDtypeStruct(v.shape, jnp.int32,
                                              sharding=v.sharding)
                         if k == "len" else v)
                     for k, v in cache_abs.items()}
        c_shard = jax.tree.map(lambda s: s.sharding, cache_abs)
        jit = jax.jit(step,
                      in_shardings=(p_shard, None, c_shard),
                      out_shardings=(None, c_shard),
                      donate_argnums=(2,) if donate else ())
        args = (p_abs, batch_abs, cache_abs)

    return StepBundle(cfg, shape, model, rules, mesh, jit, args, specs)
