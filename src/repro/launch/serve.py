"""Serving launcher: prefill + batched decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        --prompt-len 64 --new-tokens 32 --batch 4

Demonstrates the full serving path on any arch: prefill with decode
headroom, greedy batched decode against ring/linear caches, and (optional)
K-Means KV-cache codebook compression from the paper's solver
(--kv-codebook), reporting the reconstruction error.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced_config
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import params as pr
from repro.models.config import ShapeSpec
from repro.models.model import Model, RunFlags, make_constrain


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--kv-codebook", type=int, default=0,
                    help="K: compress the prefill KV cache with AA-KMeans "
                         "codebooks of K entries per layer")
    return ap.parse_args(argv)


def run(args) -> dict:
    mesh = make_host_mesh() if args.mesh == "host" else \
        make_production_mesh(multi_pod=args.mesh == "multi")
    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec("serve_cli", args.prompt_len, args.batch, "prefill")
    flags = RunFlags(block_q=min(512, args.prompt_len),
                     block_kv=min(1024, args.prompt_len))
    rules = ST.rules_for(mesh, cfg, shape)
    model = Model(cfg, flags)
    constrain = make_constrain(mesh, rules)
    specs = model.param_specs()
    params = pr.init_tree(specs, jax.random.PRNGKey(0))
    params = jax.device_put(params, pr.sharding_tree(specs, mesh, rules))

    batch = ST.real_batch(cfg, shape, jax.random.PRNGKey(1))
    total = args.prompt_len + args.new_tokens

    prefill = jax.jit(lambda p, b: model.prefill(p, b, constrain,
                                                 max_len=total))
    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.perf_counter() - t0

    if args.kv_codebook and "k" in cache:
        from repro.core.applications import compress_kv_cache
        cache, err = compress_kv_cache(cache, k=args.kv_codebook,
                                       valid_len=args.prompt_len)
        print(f"[kv-codebook] K={args.kv_codebook} relative "
              f"reconstruction error {err:.4f}")

    decode = jax.jit(ST.make_decode_step(model, constrain))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        dbatch = ({"token": tok} if not cfg.embed_stub else
                  {"frame": jax.random.normal(jax.random.PRNGKey(int(tok[0])),
                                              (args.batch, cfg.d_model),
                                              jnp.float32)})
        logits, cache = decode(params, dbatch, cache)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    toks = np.stack(out_tokens, 1)
    per_tok = t_decode / max(args.new_tokens - 1, 1) / args.batch
    return {"prefill_s": t_prefill, "decode_s": t_decode,
            "tokens": toks.shape, "s_per_token_per_seq": per_tok,
            "sample": toks[0, :8].tolist()}


def main():
    out = run(parse_args())
    print(f"[done] {out}")


if __name__ == "__main__":
    main()
