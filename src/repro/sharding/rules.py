"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes to
physical mesh axes.

Every parameter / activation carries a tuple of logical axis names; a rule
table (chosen per mesh and workload) maps each name to a mesh axis (or None
for replication).  The production meshes are:

    single-pod : (data=16, model=16)            — 256 chips (one v5e pod)
    multi-pod  : (pod=2, data=16, model=16)     — 512 chips

The "pod" axis extends data parallelism across pods: batch and FSDP weight
shards span ("pod", "data") so the only cross-pod traffic is the gradient /
FSDP all-reduce family, which tolerates the thinner inter-pod links (DCN or
optical) — the standard multi-pod layout.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat  # noqa: F401  (jax version shims: AxisType et al.)

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    table: Tuple[Tuple[str, Axis], ...]

    def as_dict(self) -> Dict[str, Axis]:
        return dict(self.table)

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        t = self.as_dict()
        out = []
        for name in logical_axes:
            if name is None:
                out.append(None)
            else:
                if name not in t:
                    raise KeyError(f"no sharding rule for logical axis "
                                   f"{name!r}")
                out.append(t[name])
        return P(*out)

    def sharding(self, mesh: Mesh,
                 logical_axes: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(mesh, self.spec(logical_axes))

    def shape_spec(self, mesh: Mesh, logical_axes, shape) -> P:
        """Divisibility-aware spec: a dimension whose size does not divide
        by its mesh-axis extent falls back to replication.  This happens for
        e.g. 3/8/9/24 (kv-)head counts against model=16; the resulting
        replicated compute is deliberate baseline behaviour and is surfaced
        by the roofline (HLO_FLOPs > MODEL_FLOPS)."""
        base = self.spec(logical_axes)
        out = []
        for dim, entry in zip(shape, tuple(base) + (None,) * len(shape)):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            # fall back to suffixes of a multi-axis spec before replicating:
            # e.g. fold_bh = 768 over (pod,data,model)=512 fails, but
            # (data,model)=256 divides — shard there, replicate over pod.
            chosen = None
            for start in range(len(axes)):
                cand = axes[start:]
                size = 1
                for a in cand:
                    size *= mesh.shape[a]
                if dim % size == 0:
                    chosen = cand if len(cand) > 1 else cand[0]
                    break
            out.append(chosen)
        return P(*out)

    def shape_sharding(self, mesh: Mesh, logical_axes,
                       shape) -> NamedSharding:
        return NamedSharding(mesh, self.shape_spec(mesh, logical_axes, shape))


def _filter(mesh_axes: Sequence[str], want: Sequence[str]) -> Axis:
    got = tuple(a for a in want if a in mesh_axes)
    if not got:
        return None
    return got if len(got) > 1 else got[0]


def make_rules(mesh: Mesh, *, seq_sharded: bool = False,
               fsdp: bool = True, moe_ep: bool = False,
               cache_seq_model: bool = False,
               seq_shard_acts: bool = False) -> ShardingRules:
    """Build the rule table for a mesh.

    seq_sharded — shard the sequence/cache axis over the data axes
                  (sequence parallelism; used for long_500k where batch=1).
    fsdp        — shard the parameter "embed" axis over data (ZeRO-3 style).
    moe_ep      — shard the expert axis over "model" (expert parallelism)
                  instead of sharding each expert's d_ff (tensor parallel).
    cache_seq_model — decode: shard the KV-cache sequence dim over "model"
                  (flash-decode layout; §Perf lever for collective-bound
                  decode with replicated GQA kv heads).
    """
    axes = mesh.axis_names
    data_axes = _filter(axes, ("pod", "data"))
    model = _filter(axes, ("model",))
    fsdp_axis = data_axes if fsdp else None
    all_axes = _filter(axes, ("pod", "data", "model"))

    cache_seq = model if cache_seq_model else \
        (data_axes if seq_sharded else None)
    table = (
        # --- activations ---
        ("batch", None if seq_sharded else data_axes),
        ("seq", data_axes if seq_sharded else None),
        # residual-stream sequence axis: Megatron-style sequence parallelism
        # over "model" when enabled (train §Perf lever); follows "seq"
        # otherwise.
        ("seq_res", model if seq_shard_acts else
         (data_axes if seq_sharded else None)),
        ("fold_bh", all_axes),
        ("act_embed", None),
        ("act_heads", model),
        ("act_kv_heads", model),
        ("act_mlp", model),
        ("act_vocab", model),
        ("act_experts", model if moe_ep else None),
        ("act_cap", None),
        ("cache_seq", cache_seq),
        ("cache_batch", None if seq_sharded else data_axes),
        ("ssm_heads_act", model),
        # --- parameters ---
        ("layers", None),
        ("embed", fsdp_axis),
        ("vocab", model),
        ("heads", model),
        ("kv_heads", model),
        ("mlp", model),
        ("experts", model if moe_ep else None),
        ("expert_mlp", None if moe_ep else model),
        ("ssm_inner", model),
        ("ssm_state", None),
        ("ssm_heads", model),
        ("conv", None),
        ("lora", None),
        ("img", None),
        ("norm", None),
    )
    return ShardingRules(table)


def tree_spec(rules: ShardingRules, axes_tree):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(lambda ax: rules.spec(ax), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(a, (str, type(None))) for a in x))


def tree_sharding(mesh: Mesh, rules: ShardingRules, axes_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_spec(rules, axes_tree),
                        is_leaf=lambda x: isinstance(x, P))
