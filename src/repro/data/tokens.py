"""Deterministic, shard-aware synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — an index-based PRNG
stream with no filesystem state.  This is the property fault tolerance
leans on: after checkpoint restore (or an elastic re-mesh with a different
data-parallel degree) the pipeline resumes exactly, because batch `t` never
depends on how many hosts produced batches `< t`.

The stream mimics language-model token statistics (Zipfian unigram draw
with short-range repetition) so CE losses move like real training rather
than uniform noise.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2        # Zipf exponent for the unigram distribution
    repeat_p: float = 0.3      # short-range repetition probability


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return (p / p.sum()).astype(np.float64)


class TokenStream:
    """Host-side batch generator for one data shard.

    shard_index / shard_count describe this host's slice of the global
    batch; resume is `TokenStream(cfg, shard, count, start_step=t)`.
    """

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 shard_count: int = 1, start_step: int = 0):
        assert cfg.global_batch % shard_count == 0, \
            (cfg.global_batch, shard_count)
        self.cfg = cfg
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.step = start_step
        self._probs = _zipf_probs(cfg.vocab, cfg.zipf_a)
        self._cum = np.cumsum(self._probs)

    def _row(self, step: int, global_row: int) -> np.ndarray:
        """One sequence: a pure function of (seed, step, GLOBAL row index).
        Keying on the global row (not the shard) makes the stream invariant
        to the data-parallel degree — the property elastic re-meshing
        relies on (tests/test_data.py asserts it)."""
        cfg = self.cfg
        ss = np.random.SeedSequence(entropy=cfg.seed,
                                    spawn_key=(step, global_row))
        rng = np.random.default_rng(ss)
        u = rng.random(cfg.seq_len + 1)
        toks = np.searchsorted(self._cum, u).astype(np.int32)
        toks = np.minimum(toks, cfg.vocab - 1)
        # short-range repetition: with prob repeat_p copy a recent token
        rep = rng.random(cfg.seq_len + 1) < cfg.repeat_p
        back = rng.integers(1, 32, cfg.seq_len + 1)
        idx = np.maximum(np.arange(cfg.seq_len + 1) - back, 0)
        return np.where(rep, toks[idx], toks)

    def batch_at(self, step: int) -> dict:
        """This shard's slice of the global batch for `step`."""
        cfg = self.cfg
        b = cfg.global_batch // self.shard_count
        rows = np.stack([self._row(step, self.shard_index * b + i)
                         for i in range(b)])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step, "shard_index": self.shard_index,
                "shard_count": self.shard_count, "seed": self.cfg.seed}


def global_batch_at(cfg: DataConfig, step: int, shard_count: int = 1):
    """Assemble the full global batch (tests / single-host examples)."""
    shards = [TokenStream(cfg, i, shard_count).batch_at(step)
              for i in range(shard_count)]
    return {k: np.concatenate([s[k] for s in shards], axis=0)
            for k in shards[0]}
