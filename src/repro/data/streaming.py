"""Chunk pipeline for the streaming mini-batch solver (DESIGN.md §Streaming).

Two regimes, one chunk contract:

  * **Device-resident** (`chunk_dataset`): X fits on device (or on the
    mesh); it is reshaped once into fixed-size chunks with a row-weight
    mask for the padded tail, and the epoch driver gathers chunks in a
    per-epoch shuffled order — no copy of X per epoch.
  * **Host-streamed** (`host_chunk_stream`): X lives in host memory only;
    a generator yields one shuffled numpy chunk at a time, so the peak
    device footprint is O(chunk + validation chunk) — the estimator's
    `partial_fit` loop consumes this directly.

The chunk contract shared by both: every chunk has exactly ``chunk_size``
rows; rows past the true N carry weight 0 (they replicate the final sample
but vanish from every weighted reduction); under a mesh, chunk rows are
sharded over the data axes so each host/shard streams only its slice.

`stream_chunks` unifies the regimes behind one iterator: it yields
device-resident chunks whether the source is a `DeviceChunks`, a host
array, or a raw chunk generator, prefetching host→device transfers
through `repro.runtime.prefetch` so copies overlap compute.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


class DeviceChunks(NamedTuple):
    """Device-resident chunked dataset.

    chunks  : (n_chunks, chunk_size, d) — padded rows replicate the last
              real sample (any finite value works; the mask removes them).
    weights : (n_chunks, chunk_size) — 1.0 for real rows, 0.0 for padding.
    n       : the true (unpadded) row count.
    """
    chunks: jax.Array
    weights: jax.Array
    n: int


def shard_count(mesh: jax.sharding.Mesh, data_axes: Sequence[str]) -> int:
    """Total shards of the given mesh data axes — the divisor every
    row-sharded chunk dimension must respect."""
    count = 1
    for a in data_axes:
        count *= mesh.shape[a]
    return count


def chunk_dataset(x, chunk_size: int,
                  mesh: Optional[jax.sharding.Mesh] = None,
                  data_axes: Sequence[str] = ("data",)) -> DeviceChunks:
    """Reshape X (N, d) into masked fixed-size chunks, optionally sharded.

    The tail chunk is padded to ``chunk_size`` with copies of the last row
    at weight 0.  With ``mesh`` set, chunk rows are sharded over
    ``data_axes`` (spec `P(None, axes)`), so each shard owns
    ``chunk_size / n_shards`` rows of every chunk and the solver's
    per-chunk psum reduces over exactly those axes; ``chunk_size`` must be
    divisible by the total shard count.
    """
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1; got {chunk_size}")
    x = jnp.asarray(x)
    n, d = x.shape
    if mesh is not None:
        shards = shard_count(mesh, data_axes)
        if chunk_size % shards:
            raise ValueError(
                f"chunk_size={chunk_size} must be divisible by the "
                f"{shards} shards of mesh axes {tuple(data_axes)}")
    pad = (-n) % chunk_size
    if pad:
        x = jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)])
    w = jnp.concatenate([jnp.ones((n,), jnp.float32),
                         jnp.zeros((pad,), jnp.float32)])
    chunks = x.reshape(-1, chunk_size, d)
    weights = w.reshape(-1, chunk_size)
    if mesh is not None:
        spec = NamedSharding(mesh, P(None, tuple(data_axes)))
        chunks = jax.device_put(chunks, spec)
        weights = jax.device_put(weights, spec)
    return DeviceChunks(chunks, weights, n)


def split_validation(x, val_size: int, key) -> Tuple[jax.Array, jax.Array]:
    """Hold out ``val_size`` uniformly-sampled rows as the guard's
    validation chunk.  Returns (x_train, x_val); the split permutes rows,
    so downstream chunking sees an already-shuffled train set."""
    x = jnp.asarray(x)
    n = x.shape[0]
    if not 0 < val_size < n:
        raise ValueError(f"val_size must be in (0, N={n}); got {val_size}")
    perm = jax.random.permutation(key, n)
    return x[perm[val_size:]], x[perm[:val_size]]


def host_chunk_stream(x, chunk_size: int, epochs: int = 1, seed: int = 0,
                      drop_remainder: bool = False, start_chunk: int = 0):
    """Generator over host-memory chunks, reshuffled per epoch.

    ``x`` stays a host (numpy) array; each yield materialises only one
    (chunk_size, d) gather, so X never needs to fit on device — the
    out-of-device-memory path the streaming solver exists for.  The tail
    chunk of each epoch is shorter than ``chunk_size`` unless
    ``drop_remainder``; pair with `partial_fit`, which accepts any chunk
    length (uniform lengths avoid re-jitting the step).

    The stream is a pure function of (x, chunk_size, epochs, seed): chunk
    ``i`` is identical on every construction.  ``start_chunk`` skips the
    first ``i`` chunks without touching X's rows, so a restarted process
    resumes a persisted ``partial_fit`` stream (the estimator's
    ``n_steps_`` counts consumed chunks) on exactly the chunk the dead
    process would have seen next — the data half of the resume guarantee
    (DESIGN.md §Persistence).
    """
    x = np.asarray(x)
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    skip = int(start_chunk)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n, chunk_size):
            idx = order[i:i + chunk_size]
            if drop_remainder and idx.shape[0] < chunk_size:
                break
            if skip > 0:
                skip -= 1
                continue
            yield x[idx]


def _sorted_chunk_iter(host_iter, sort_by):
    """Stably sort each host chunk's rows by nearest centroid before the
    host→device copy (DESIGN.md §Locality).

    ``sort_by`` is a (K, d) host array of centroids, or a zero-arg callable
    returning one — the streamed epoch driver passes a callable reading its
    *current* iterate, so chunks assembled ``prefetch`` steps ahead sort by
    slightly stale centroids.  That staleness is harmless: chunk ordering
    only shapes locality (tile-skipping inside the weighted backend pass),
    never the numbers — the minibatch stats are row-weighted sums.  The
    sort runs on host, off the device hot path, so np.argsort is fine here
    (the no-argsort rule guards the in-loop device sort in core/locality)."""
    provider = sort_by if callable(sort_by) else (lambda: sort_by)
    for chunk in host_iter:
        rows = np.asarray(chunk)
        c = np.asarray(provider())
        d2 = (np.square(rows).sum(-1)[:, None]
              - 2.0 * rows @ c.T + np.square(c).sum(-1)[None, :])
        labels = np.argmin(d2, axis=1)
        yield rows[np.argsort(labels, kind="stable")]


def stream_chunks(source, chunk_size: Optional[int] = None, *,
                  epochs: int = 1, seed: int = 0, start_chunk: int = 0,
                  drop_remainder: bool = False, prefetch: int = 2,
                  mesh: Optional[jax.sharding.Mesh] = None,
                  data_axes: Sequence[str] = ("data",),
                  meter=None, sort_by=None):
    """One iterator contract over both chunk regimes.

    Yields device-resident chunk arrays regardless of where ``source``
    lives:

      * a `DeviceChunks` — chunks are already on device (and already
        mesh-sharded if built that way); they are yielded in storage
        order with zero copies.  ``chunk_size``/``epochs``/``seed`` must
        be left at their defaults — shuffling device-resident chunks is
        the epoch driver's job.
      * a host array — wrapped in `host_chunk_stream` (per-epoch
        shuffle, ``start_chunk`` resume skipping) and pushed through
        `repro.runtime.prefetch.prefetch_to_device`, so chunk t+1's
        host→device copy overlaps the consumer's compute on chunk t.
      * any iterator/generator of host chunks — prefetched as-is (the
        caller owns ordering); ``chunk_size`` is ignored.

    With ``mesh`` set, each transferred chunk lands sharded over
    ``data_axes`` (rows split, spec `P(axes)` for 2-D chunks), matching
    `chunk_dataset`'s placement.  ``prefetch`` bounds the in-flight
    transfers (2 = double buffering; 1 = synchronous).  ``meter`` is an
    optional `repro.runtime.prefetch.IngestMeter` accumulating achieved
    ingest bytes/bandwidth.

    ``sort_by`` (a (K, d) centroid array, or a zero-arg callable returning
    one) stably sorts each host chunk's rows by nearest centroid before
    transfer, so device chunks arrive locality-ordered for the bound
    engines' tile-skipping (DESIGN.md §Locality).  Host-path only — a
    `DeviceChunks` source is already resident and cannot be re-ordered
    here.
    """
    from repro.runtime.prefetch import prefetch_to_device

    if isinstance(source, DeviceChunks):
        # enforce the WHOLE documented contract: seed/drop_remainder used
        # to slip through this check and be silently ignored, which reads
        # as "my shuffle seed works" when it does nothing
        if chunk_size is not None or epochs != 1 or start_chunk \
                or seed != 0 or drop_remainder or sort_by is not None:
            raise ValueError(
                "stream_chunks(DeviceChunks) yields storage order; "
                "chunk_size/epochs/seed/start_chunk/drop_remainder/"
                "sort_by do not apply")

        def _device_iter():
            for i in range(source.chunks.shape[0]):
                yield source.chunks[i]
        return _device_iter()

    if hasattr(source, "__next__") or not hasattr(source, "shape"):
        host_iter = iter(source)
    else:
        if chunk_size is None:
            raise ValueError("chunk_size is required for a host array")
        host_iter = host_chunk_stream(source, chunk_size, epochs=epochs,
                                      seed=seed, start_chunk=start_chunk,
                                      drop_remainder=drop_remainder)
    if sort_by is not None:
        host_iter = _sorted_chunk_iter(host_iter, sort_by)
    sharding = None
    if mesh is not None:
        sharding = NamedSharding(mesh, P(tuple(data_axes)))
    return prefetch_to_device(host_iter, size=max(1, int(prefetch)),
                              sharding=sharding, meter=meter)
