"""Synthetic stand-ins for the paper's 20 benchmark datasets.

The paper evaluates on 19 UCI datasets plus the synthetic Birch grid
(Table 1).  The UCI files are not available in this offline container, so
each dataset is replaced by a synthetic generator with the *same N and d*
and a cluster structure chosen to span the regimes that matter for the
algorithm's behaviour (well-separated, overlapping, heavy-tailed,
low-dimensional dense, high-dimensional sparse-ish).  EXPERIMENTS.md states
this substitution explicitly; the claims we validate (iteration-count
reduction, acceptance rate, MSE parity with Lloyd) are properties of the
solver dynamics, not of the exact data values.

Generators are deterministic given the seed.  ``scale`` shrinks N for CI
(full sizes reproduce Table 1's N exactly at scale=1.0).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    no: int
    name: str
    n: int
    d: int
    kind: str          # gaussian | birch_grid | heavy_tail | uniform_mix


# Table 1 of the paper (No., name, N, d) with a generator regime each.
_TABLE1 = [
    (1, "UCIHARDATAXtrain", 7352, 561, "gaussian"),
    (2, "Slicelocalization", 53500, 385, "gaussian"),
    (3, "RelationNetwork", 53413, 22, "heavy_tail"),
    (4, "Letterrecognition", 20000, 16, "uniform_mix"),
    (5, "HTRU2", 17898, 8, "heavy_tail"),
    (6, "Household", 2049280, 6, "gaussian"),
    (7, "FrogsMFCCs", 7195, 21, "gaussian"),
    (8, "Eb", 45781, 2, "uniform_mix"),
    (9, "AllUsers", 78095, 8, "gaussian"),
    (10, "MiniBoone", 130064, 50, "heavy_tail"),
    (11, "Colorment", 68040, 9, "uniform_mix"),
    (12, "Conflongdemo", 164860, 3, "gaussian"),
    (13, "Birch", 100000, 2, "birch_grid"),
    (14, "Shuttle", 43500, 9, "heavy_tail"),
    (15, "Covtype", 581012, 55, "gaussian"),
    (16, "SkinNonSkin", 245057, 4, "uniform_mix"),
    (17, "Finalgeneral", 10104, 72, "gaussian"),
    (18, "ColorHistogram", 68040, 32, "heavy_tail"),
    (19, "USCensus1990", 2458285, 69, "gaussian"),
    (20, "Kddcup99", 4898431, 37, "heavy_tail"),
]

DATASETS: Dict[str, DatasetSpec] = {
    name: DatasetSpec(no, name, n, d, kind)
    for no, name, n, d, kind in _TABLE1
}


def _gaussian_mixture(rng, n, d, n_comp, spread=1.5):
    """Heavily-overlapping mixture: the slow-convergence regime for Lloyd
    (the surrogate loses accuracy whenever moving centroids re-assign
    samples, which happens constantly when clusters overlap — Sec. 2)."""
    centers = rng.standard_normal((n_comp, d)) * spread
    comp = rng.integers(0, n_comp, n)
    scales = rng.uniform(0.6, 1.8, (n_comp, 1))
    x = centers[comp] + rng.standard_normal((n, d)) * scales[comp]
    return x


def _birch_grid(rng, n, d, grid=10):
    """BIRCH1-style regular grid of Gaussian clusters (Zhang et al. 1997)."""
    axes = [np.arange(grid) * 10.0 for _ in range(min(d, 2))]
    mesh = np.stack(np.meshgrid(*axes), -1).reshape(-1, min(d, 2))
    if d > 2:
        mesh = np.concatenate(
            [mesh, np.zeros((mesh.shape[0], d - 2))], axis=1)
    comp = rng.integers(0, mesh.shape[0], n)
    return mesh[comp] + rng.standard_normal((n, d))


def _heavy_tail(rng, n, d, n_comp=20):
    centers = rng.standard_normal((n_comp, d)) * 1.5
    comp = rng.integers(0, n_comp, n)
    # Student-t-ish tails: normal / sqrt(chi2/df)
    df = 2.5
    z = rng.standard_normal((n, d))
    chi = rng.chisquare(df, (n, 1)) / df
    return centers[comp] + z / np.sqrt(chi)


def _uniform_mix(rng, n, d, n_comp=15):
    """Half uniform background + overlapping boxes: near-unstructured data,
    the classically slow case for Lloyd."""
    centers = rng.uniform(-3, 3, (n_comp, d))
    widths = rng.uniform(1.0, 4.0, (n_comp, d))
    comp = rng.integers(0, n_comp, n)
    x = centers[comp] + rng.uniform(-1, 1, (n, d)) * widths[comp]
    n_bg = n // 2
    x[:n_bg] = rng.uniform(-5, 5, (n_bg, d))
    return x


_GEN = {
    "gaussian": _gaussian_mixture,
    "birch_grid": _birch_grid,
    "heavy_tail": _heavy_tail,
    "uniform_mix": _uniform_mix,
}


def make_dataset(name: str, *, scale: float = 1.0, seed: int = 0,
                 dtype=np.float32) -> np.ndarray:
    """Generate dataset ``name`` at ``scale`` of its Table-1 size."""
    spec = DATASETS[name]
    n = max(64, int(spec.n * scale))
    rng = np.random.default_rng(seed + spec.no * 1000)
    if spec.kind == "gaussian":
        x = _gaussian_mixture(rng, n, spec.d, n_comp=25)
    else:
        x = _GEN[spec.kind](rng, n, spec.d)
    # Match the paper's preprocessing style: features roughly standardised.
    x = (x - x.mean(0)) / np.maximum(x.std(0), 1e-6)
    return x.astype(dtype)


def make_blobs(n: int, d: int, k: int, *, seed: int = 0, spread: float = 5.0,
               dtype=np.float32) -> np.ndarray:
    """Simple separated blobs — used by unit tests."""
    rng = np.random.default_rng(seed)
    return _gaussian_mixture(rng, n, d, k, spread).astype(dtype)
