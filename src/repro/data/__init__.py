from repro.data.streaming import (DeviceChunks, chunk_dataset,  # noqa: F401
                                  host_chunk_stream, shard_count,
                                  split_validation)
from repro.data.synthetic import DATASETS, make_dataset  # noqa: F401
