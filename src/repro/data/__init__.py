from repro.data.synthetic import DATASETS, make_dataset  # noqa: F401
