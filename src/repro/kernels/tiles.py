"""VMEM-aware tile sizing for the Pallas kernel engine (DESIGN.md
§Kernels-v2).

Every kernel in this package streams X in (TN x d) row tiles and C in
(TK x d) centroid tiles.  The v1 kernels hardcoded TN = TK = 512, which
(a) wasted VMEM at small d and (b) said nothing about whether a tile
actually fits — the fused kernel instead *gated* on K*d and fell back to
a two-kernel path.  v2 replaces both with `choose_tiles`: given the
problem shape and the compute dtype's byte width, pick the largest
(TN, TK) whose working set fits the VMEM budget, shrinking the k tile
first (k-tiling is the lever that removed the fused kernel's VMEM
cliff; see fused_lloyd.py).

The budget is ``DEFAULT_VMEM_BUDGET`` (8 MB, about half of one core's
VMEM — the other half is slack for Mosaic's own temporaries and the
double-buffering head-room the model below only approximates).  The
footprint model counts, per kernel kind:

  * double-buffered input tiles (X, C, |c|², row weights, labels),
  * the distance / one-hot compute blocks (TN x TK f32),
  * the *resident* accumulators: the fused kernel accumulates the full
    (K, d) f32 cluster stats in VMEM across the whole grid, so K·d·4
    bytes is a fixed term no tile size can shrink.  For K·d beyond the
    budget the chooser bottoms out at the minimum tile and the kernel
    still compiles — the accumulator is then the compiler's (spilling)
    problem, not a Python-level fallback.  The cross-over sits far
    above the paper's K <= 1000 regime.

`dimension_semantics` builds the Mosaic compiler hint (parallel over
the restart/sample grid axes, arbitrary over the sequential k axis) in
a form that degrades gracefully across jax versions.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

LANE = 128                       # minor-dim tile width on TPU
MAX_TILE = 512                   # largest row tile the chooser will pick
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024


def round_up(v: int, m: int) -> int:
    return v + (-v) % m


def sublane(itemsize: int) -> int:
    """Minimum second-to-minor tile extent for a dtype's byte width."""
    return {4: 8, 2: 16, 1: 32}.get(itemsize, 8)


def pad_to(a: jax.Array, axis: int, multiple: int, value=0.0):
    """Pad ``axis`` of ``a`` up to a multiple of ``multiple``."""
    size = a.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis % a.ndim] = (0, rem)
    return jnp.pad(a, widths, constant_values=value)


def _resident(kind: str, kp: int, dp: int) -> int:
    """Grid-resident bytes that no tile size can shrink (the fused
    kernels' f32 stats accumulators; fused_bounds adds its skipped-tile
    counter)."""
    if kind == "fused":
        return kp * dp * 4 + kp * 4 + 8
    if kind == "fused_bounds":
        return kp * dp * 4 + kp * 4 + 8 + 8      # + skip counter block
    return 0


def _tile_cost(kind: str, tn: int, tk: int, dp: int, itemsize: int,
               kp: int = 0) -> int:
    """Tile-dependent VMEM bytes of one grid cell's working set.  ``kp``
    (the padded K) only matters for fused_bounds, whose per-row-tile
    bound buffers have one lane per k-tile group (G = kp / tk)."""
    x_tile = 2 * tn * dp * itemsize          # double-buffered X tile
    c_tile = 2 * tk * dp * itemsize          # double-buffered C tile
    csq_tile = 2 * tk * 4
    w_tile = 2 * tn * 4
    lab_tiles = 2 * tn * (4 + 4)             # labels + min-dist tiles
    dist = tn * tk * 4                       # distance / one-hot block
    if kind in ("fused", "fused_bounds"):
        scratch = tn * (4 + 4)               # running min / argmin
        cost = (x_tile + c_tile + csq_tile + w_tile + lab_tiles
                + 2 * dist + scratch)
        if kind == "fused_bounds":
            g = max(1, -(-max(kp, 1) // tk))
            # lower-bound tile in + group-min tile out (f32, double-
            # buffered) + squared-upper-bound and previous-label tiles
            cost += 2 * 2 * tn * g * 4 + 2 * tn * 4 + 2 * tn * 4
        return cost
    if kind == "assignment":
        return x_tile + c_tile + csq_tile + lab_tiles + dist
    if kind == "update":
        out_tiles = 2 * (tk * dp * 4 + tk * 4)   # sums + counts blocks
        return x_tile + w_tile + 2 * tn * 4 + out_tiles + dist
    raise ValueError(f"unknown kernel kind {kind!r}")


def _footprint(kind: str, tn: int, tk: int, kp: int, dp: int,
               itemsize: int) -> int:
    """Approximate VMEM bytes of one grid cell's working set."""
    return _tile_cost(kind, tn, tk, dp, itemsize, kp) + \
        _resident(kind, kp, dp)


def choose_tiles(n: int, k: int, d: int, itemsize: int, *,
                 kind: str = "fused",
                 vmem_bytes: Optional[int] = None) -> Tuple[int, int]:
    """Pick (tn, tk) for a kernel of ``kind`` so its working set fits.

    Starts from MAX_TILE and halves the larger of the two tiles (k tile
    on ties — k-tiling is the v2 lever) until the `_footprint` model
    fits ``vmem_bytes`` (default: the module's ``DEFAULT_VMEM_BUDGET``,
    read at call time so tests can monkeypatch it).  Tiles are kept at
    multiples of the dtype's sublane and never exceed the padded
    problem extent.

    The fused kernel's grid-resident stats accumulator is charged only
    up to *half* the budget: once K·d is irreducibly past that, further
    tile shrinking cannot buy the accumulator back — it would only
    multiply the C re-stream traffic — so the tiles keep the remaining
    half to size against and the accumulator becomes the compiler's
    (spilling) problem, as documented in DESIGN.md §Kernels-v2.
    """
    budget = DEFAULT_VMEM_BUDGET if vmem_bytes is None else vmem_bytes
    sl = sublane(itemsize)
    dp = round_up(max(d, 1), LANE)
    tn = min(MAX_TILE, round_up(max(n, 1), sl))
    tk = min(MAX_TILE, round_up(max(k, 1), sl))

    def cost(a, b):
        kp = round_up(max(k, 1), b)
        resident = _resident(kind, kp, dp)
        return _tile_cost(kind, a, b, dp, itemsize, kp) + \
            min(resident, budget // 2)

    while cost(tn, tk) > budget and (tn > sl or tk > sl):
        if tk >= tn and tk > sl:
            tk = max(sl, round_up(tk // 2, sl))
        else:
            tn = max(sl, round_up(tn // 2, sl))
    return tn, tk


def dimension_semantics(*sems: str):
    """kwargs for pl.pallas_call carrying the Mosaic dimension-semantics
    hint ("parallel" | "arbitrary" per grid axis), or {} when the
    installed jax has no TPU compiler-params spelling (the hint is an
    optimisation, never a correctness requirement)."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        params = getattr(pltpu, "CompilerParams", None) or \
            getattr(pltpu, "TPUCompilerParams", None)
        if params is None:
            return {}
        return {"compiler_params": params(dimension_semantics=tuple(sems))}
    except ImportError:                      # pragma: no cover
        return {}
