"""Pallas TPU kernel for the K-Means update step (Eq. 4): segment-sum.

Scatter-add is hostile to the TPU's vector units; the TPU-native analogue is
a one-hot matmul on the MXU:

    sums[k, :]  = sum_i w_i * 1[labels_i == k] * x_i   =  (w*onehot)^T @ X
    counts[k]   = sum_i w_i * 1[labels_i == k]

tiled over samples (grid minor axis, sequential accumulation into the
(TK x d) output block) and over centroid tiles, with a leading R axis for
batched label sets (v2).  Row weights are native — the weighted one-hot
costs nothing extra on the MXU, which is what lets the `pallas` backend's
minibatch step skip the separate weighted segment-sum pass the generic
fallback pays.  Restart and centroid tiles own independent output blocks
(`parallel`); only the sample sweep accumulates (`arbitrary`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tiles
from repro.kernels.tiles import pad_to


def _update_kernel(labels_ref, x_ref, w_ref, sums_ref, counts_ref, *,
                   tk: int):
    jk = pl.program_id(1)         # centroid tile (owns the output block)
    i = pl.program_id(2)          # sample tile (minor, sequential)

    labels = labels_ref[...].reshape(-1)               # (TN,)
    x = x_ref[...]
    x = x.reshape(x.shape[-2], x.shape[-1]).astype(jnp.float32)
    w = w_ref[...]                                     # (TN,) f32

    local = labels - jk * tk              # position within this tile
    ks = jax.lax.broadcasted_iota(jnp.int32, (labels.shape[0], tk), 1)
    onehot = jnp.where(local[:, None] == ks, w[:, None],
                       jnp.float32(0.0))               # weighted (TN, TK)

    psum = jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (TK, d) on the MXU
    pcount = jnp.sum(onehot, axis=0)                   # (TK,)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = psum.reshape(sums_ref.shape)
        counts_ref[...] = pcount.reshape(counts_ref.shape)

    @pl.when(i > 0)
    def _accum():
        sums_ref[...] += psum.reshape(sums_ref.shape)
        counts_ref[...] += pcount.reshape(counts_ref.shape)


@functools.partial(jax.jit, static_argnames=("k", "tn", "tk", "interpret"))
def _update_call(x, labels, w, *, k: int, tn: int, tk: int, interpret: bool):
    r = labels.shape[0]
    n = x.shape[-2]
    x_batched = x.ndim == 3

    xp = pad_to(pad_to(x, -2, tn), -1, tiles.LANE)
    lp = pad_to(labels.astype(jnp.int32), -1, tn, value=-1)
    wp = pad_to(w, 0, tn)         # padded rows also weigh 0

    np_, dp = xp.shape[-2], xp.shape[-1]
    kp = tiles.round_up(k, tk)
    grid = (r, kp // tk, np_ // tn)

    if x_batched:
        x_spec = pl.BlockSpec((1, tn, dp), lambda rr, jk, i: (rr, i, 0))
    else:
        x_spec = pl.BlockSpec((tn, dp), lambda rr, jk, i: (i, 0))

    sums, counts = pl.pallas_call(
        functools.partial(_update_kernel, tk=tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tn), lambda rr, jk, i: (rr, i)),
            x_spec,
            pl.BlockSpec((tn,), lambda rr, jk, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, tk, dp), lambda rr, jk, i: (rr, jk, 0)),
            pl.BlockSpec((1, tk), lambda rr, jk, i: (rr, jk)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, kp, dp), jnp.float32),
            jax.ShapeDtypeStruct((r, kp), jnp.float32),
        ],
        **tiles.dimension_semantics("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(lp, xp, wp)
    return sums[:, :k, :x.shape[-1]], counts[:, :k]


def update_pallas(x: jax.Array, labels: jax.Array, k: int, *,
                  w=None, tn=None, tk=None, interpret: bool = False,
                  vmem_bytes=None):
    """Per-cluster sums (K,d) f32 and counts (K,) f32 via the Pallas kernel.

    labels (N,) — or (R, N) for R label sets over shared (N, d) or
    per-problem (R, N, d) samples, adding a leading R axis to the outputs.
    w: optional (N,) row weights scaling each row's contribution (the
    weighted segment-sum of the minibatch step).  Tile-padded sample rows
    get label -1 *and* weight 0, so they land in no cluster.
    """
    batched = labels.ndim == 2
    if x.ndim == 3 and not batched:
        raise ValueError(
            f"per-problem x {x.shape} needs per-problem labels (R, N); "
            f"got {labels.shape}")
    ls = labels if batched else labels[None]
    n, d = x.shape[-2], x.shape[-1]
    if w is None:
        w = jnp.ones((n,), jnp.float32)
    else:
        w = w.astype(jnp.float32)
    if tn is None or tk is None:
        ct, ck = tiles.choose_tiles(n, k, d, jnp.dtype(x.dtype).itemsize,
                                    kind="update", vmem_bytes=vmem_bytes)
        tn = ct if tn is None else tn
        tk = ck if tk is None else tk
    sums, counts = _update_call(x, ls, w, k=k, tn=tn, tk=tk,
                                interpret=interpret)
    if not batched:
        return sums[0], counts[0]
    return sums, counts
