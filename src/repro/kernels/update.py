"""Pallas TPU kernel for the K-Means update step (Eq. 4): segment-sum.

Scatter-add is hostile to the TPU's vector units; the TPU-native analogue is
a one-hot matmul on the MXU:

    sums[k, :]  = sum_i 1[labels_i == k] * x_i   =  onehot^T @ X
    counts[k]   = sum_i 1[labels_i == k]

tiled over samples (grid minor axis, sequential accumulation into the
(TK x d) output block) and over centroid tiles (grid major axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.assignment import _pad_to

DEFAULT_TN = 1024
DEFAULT_TK = 1024


def _update_kernel(labels_ref, x_ref, sums_ref, counts_ref, *, tk: int):
    i = pl.program_id(1)          # sample tile (minor, sequential)
    j = pl.program_id(0)          # centroid tile (major)

    labels = labels_ref[...]                       # (TN,)
    x = x_ref[...].astype(jnp.float32)             # (TN, d)

    local = labels - j * tk                        # position within this tile
    ks = jax.lax.broadcasted_iota(jnp.int32, (labels.shape[0], tk), 1)
    onehot = (local[:, None] == ks).astype(jnp.float32)   # (TN, TK)

    psum = jax.lax.dot_general(
        onehot, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (TK, d) on the MXU
    pcount = jnp.sum(onehot, axis=0)               # (TK,)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = psum
        counts_ref[...] = pcount

    @pl.when(i > 0)
    def _accum():
        sums_ref[...] += psum
        counts_ref[...] += pcount


@functools.partial(jax.jit, static_argnames=("k", "tn", "tk", "interpret"))
def update_pallas(x: jax.Array, labels: jax.Array, k: int, *,
                  tn: int = DEFAULT_TN, tk: int = DEFAULT_TK,
                  interpret: bool = False):
    """Per-cluster sums (K,d) f32 and counts (K,) f32 via the Pallas kernel.

    Padded sample rows are given label -1 so they land in no tile.
    """
    n, d = x.shape
    tn = min(tn, max(8, n))
    tk = min(tk, max(8, k))

    xp = _pad_to(x, 0, tn)
    xp = _pad_to(xp, 1, 128)
    lp = _pad_to(labels.astype(jnp.int32), 0, tn, value=-1)

    np_, dp = xp.shape
    kp = k + ((-k) % tk)
    grid = (kp // tk, np_ // tn)

    sums, counts = pl.pallas_call(
        functools.partial(_update_kernel, tk=tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn,), lambda j, i: (i,)),
            pl.BlockSpec((tn, dp), lambda j, i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tk, dp), lambda j, i: (j, 0)),
            pl.BlockSpec((tk,), lambda j, i: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, dp), jnp.float32),
            jax.ShapeDtypeStruct((kp,), jnp.float32),
        ],
        interpret=interpret,
    )(lp, xp)
    return sums[:k, :d], counts[:k]
