"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic specification its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and assert_allclose's).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def assignment_ref(x: jax.Array, c: jax.Array):
    """Nearest-centroid assignment.  x (N,d), c (K,d) ->
    (labels (N,) int32, min_sqdist (N,) f32)."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x_sq = jnp.sum(x * x, axis=-1, keepdims=True)
    c_sq = jnp.sum(c * c, axis=-1)
    d = jnp.maximum(x_sq - 2.0 * (x @ c.T) + c_sq[None, :], 0.0)
    return jnp.argmin(d, axis=-1).astype(jnp.int32), jnp.min(d, axis=-1)


def update_ref(x: jax.Array, labels: jax.Array, k: int, w=None):
    """Per-cluster sums and counts, optionally row-weighted by w (N,).
    -> (sums (K,d) f32, counts (K,) f32)."""
    x = x.astype(jnp.float32)
    w = jnp.ones((x.shape[0],), jnp.float32) if w is None \
        else w.astype(jnp.float32)
    sums = jax.ops.segment_sum(x * w[:, None], labels, num_segments=k)
    counts = jax.ops.segment_sum(w, labels, num_segments=k)
    return sums, counts


def fused_lloyd_ref(x: jax.Array, c: jax.Array):
    """One fused Lloyd pass: assignment + cluster sums + counts + energy,
    reading X exactly once.  -> (labels, min_sqdist, sums, counts, energy)."""
    labels, mind = assignment_ref(x, c)
    sums, counts = update_ref(x, labels, c.shape[0])
    return labels, mind, sums, counts, jnp.sum(mind)


def minibatch_ref(x: jax.Array, c: jax.Array, w: jax.Array):
    """Weighted chunk pass (the `Backend.minibatch_step` oracle): row
    weights w (N,) scale each row's contribution to sums/counts/energy;
    labels and min_sqdist stay per-row and unweighted.
    -> (labels, min_sqdist, sums, counts, energy)."""
    labels, mind = assignment_ref(x, c)
    w = w.astype(jnp.float32)
    k = c.shape[0]
    sums = jax.ops.segment_sum(x.astype(jnp.float32) * w[:, None], labels,
                               num_segments=k)
    counts = jax.ops.segment_sum(w, labels, num_segments=k)
    return labels, mind, sums, counts, jnp.sum(mind * w)
