"""Fused Pallas TPU kernel: one full Lloyd pass reading X exactly once.

Beyond-paper TPU optimisation (see EXPERIMENTS.md §Perf).  A Lloyd iteration
as separate assignment + update + energy passes streams X from HBM two to
three times; since the per-iteration work is memory-bound for small/medium K
(arithmetic intensity ~ K flops/byte for assignment), fusing the three into
a single pass halves the dominant roofline term.

For each (TN x d) sample tile held in VMEM:
    1. distances to ALL centroids (C held fully in VMEM — valid for
       K*d <= ~2 MSamples, which covers the paper's K <= 1000 regime;
       larger K falls back to the two-kernel path),
    2. per-row argmin -> labels tile,
    3. one-hot^T @ X accumulation into (K, d) sums + counts,
    4. energy accumulation sum(min_dist).

Outputs: labels (N,), sums (K,d), counts (K,), energy (1,1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.assignment import _pad_to

DEFAULT_TN = 512


def _fused_kernel(x_ref, c_ref, csq_ref, labels_ref, mind_ref, sums_ref,
                  counts_ref, energy_ref):
    i = pl.program_id(0)

    x = x_ref[...]                                   # (TN, d)
    c = c_ref[...]                                   # (K, d)
    csq = csq_ref[...]                               # (1, K)

    xf = x.astype(jnp.float32)
    xsq = jnp.sum(xf * xf, axis=-1, keepdims=True)
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (TN, K) MXU pass 1
    dist = jnp.maximum(xsq - 2.0 * cross + csq, 0.0)

    labels = jnp.argmin(dist, axis=-1).astype(jnp.int32)
    mind = jnp.min(dist, axis=-1)
    labels_ref[...] = labels
    mind_ref[...] = mind

    ks = jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    onehot = (labels[:, None] == ks).astype(jnp.float32)
    psum = jax.lax.dot_general(
        onehot, xf, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (K, d) MXU pass 2
    pcount = jnp.sum(onehot, axis=0)
    penergy = jnp.sum(mind)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = psum
        counts_ref[...] = pcount
        energy_ref[0, 0] = penergy

    @pl.when(i > 0)
    def _accum():
        sums_ref[...] += psum
        counts_ref[...] += pcount
        energy_ref[0, 0] += penergy


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def fused_lloyd_pallas(x: jax.Array, c: jax.Array, *,
                       tn: int = DEFAULT_TN, interpret: bool = False):
    """Fused assignment+update+energy.  x (N,d), c (K,d) ->
    (labels (N,) i32, min_sqdist (N,) f32, sums (K,d) f32, counts (K,) f32,
    energy () f32).

    Requires K*d to fit in VMEM (checked by the ops.py dispatcher).
    Padded sample rows carry +0 contribution: their distances are computed
    against real centroids but their one-hot row is forced to zero and their
    min-dist excluded from the energy.
    """
    n, d = x.shape
    k = c.shape[0]
    tn = min(tn, max(8, n))

    xp = _pad_to(x, 0, tn)
    xp = _pad_to(xp, 1, 128)
    cp = _pad_to(c, 0, 8)
    cp = _pad_to(cp, 1, 128)

    cpf = cp.astype(jnp.float32)
    csq = jnp.sum(cpf * cpf, axis=-1)
    if cp.shape[0] != k:
        mask = jnp.arange(cp.shape[0]) >= k
        csq = jnp.where(mask, jnp.float32(jnp.finfo(jnp.float32).max), csq)
    csq = csq[None, :]                                # (1, Kp)

    np_, dp = xp.shape
    kp = cp.shape[0]
    # Zero padded sample rows so their sum/count/energy contribution is a
    # clean zero in exactly one cluster... instead: set their x to the first
    # centroid and subtract?  Simpler and exact: mask via a validity column.
    # We pass padded rows as all-zero and post-subtract their contribution.
    n_pad = np_ - n

    labels, mind, sums, counts, energy = pl.pallas_call(
        _fused_kernel,
        grid=(np_ // tn,),
        in_specs=[
            pl.BlockSpec((tn, dp), lambda i: (i, 0)),
            pl.BlockSpec((kp, dp), lambda i: (0, 0)),
            pl.BlockSpec((1, kp), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((tn,), lambda i: (i,)),
            pl.BlockSpec((kp, dp), lambda i: (0, 0)),
            pl.BlockSpec((kp,), lambda i: (0,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((kp, dp), jnp.float32),
            jax.ShapeDtypeStruct((kp,), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, csq)

    if n_pad:
        # Padded rows are all-zero samples: they were assigned to the
        # centroid nearest the origin.  Remove their contribution exactly.
        zlab, zmind = labels[n], jnp.min(csq)  # identical for every pad row
        sums = sums  # zero rows add nothing to sums
        counts = counts.at[zlab].add(-jnp.float32(n_pad))
        energy = energy - jnp.float32(n_pad) * zmind
    return (labels[:n], mind[:n], sums[:k, :d], counts[:k],
            energy[0, 0])
