"""Fused Pallas TPU kernel v2: one full Lloyd pass reading X exactly once,
for arbitrary K (DESIGN.md §Kernels-v2).

A Lloyd iteration as separate assignment + update + energy passes streams X
from HBM two to three times; the per-iteration work is memory-bound for
small/medium K (arithmetic intensity ~ K flops/byte for assignment), so
fusing the three into a single pass halves the dominant roofline term.

v1 of this kernel held the full (K, d) centroid block in VMEM and fell
back to the two-kernel path past an 8 MB gate.  v2 k-tiles instead: the
grid is (R, n_tiles, k_tiles) with k minor, and each X row tile is
resident in VMEM for the whole k sweep —

    1. distances of the (TN x d) X tile against one (TK x d) centroid
       tile per grid step (MXU), folding a running (min, argmin) held in
       VMEM *scratch* across the k tiles;
    2. at the final k tile the assignment of the X tile is complete:
       emit labels/min-dist and accumulate the weighted one-hot cluster
       stats and energy — while the X block is still resident, so X is
       read from HBM exactly once regardless of K.

The (K, d) f32 stats accumulator stays VMEM-resident across the grid
(k-tiling the *inputs* is what removed the old cliff; the accumulator's
K·d·4 bytes is the remaining — much later — limit, priced by the
`tiles.choose_tiles` footprint model).

Row weights are native: every row's contribution to sums/counts/energy is
scaled by its weight, which (a) makes this kernel the streaming
`minibatch_step` (padding rows carry weight 0 and vanish exactly — no
post-hoc subtraction) and (b) is how the wrapper handles its own
tile-padding rows.  labels/min_sqdist stay per-row and unweighted.

The leading R grid axis batches restarts: c of shape (R, K, d) runs R
centroid sets against shared (N, d) or per-problem (R, N, d) samples in
one kernel launch — the native `batched_step` for the multi-restart
driver and the minibatch validation guard's R = 2 step.

Outputs: labels (N,), min_sqdist (N,), sums (K,d), counts (K,), energy ()
— with a leading R axis when c is (R, K, d).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tiles
from repro.kernels.tiles import pad_to


def _fused_kernel(x_ref, c_ref, csq_ref, w_ref,
                  labels_ref, mind_ref, sums_ref, counts_ref, energy_ref,
                  mind_s, amin_s, *, tk: int):
    i = pl.program_id(1)          # X row tile (sequential: stats accumulate)
    j = pl.program_id(2)          # centroid tile (minor: argmin sweep)
    nk = pl.num_programs(2)

    x = x_ref[...]
    x = x.reshape(x.shape[-2], x.shape[-1])            # (TN, d)
    c = c_ref[...].reshape(c_ref.shape[-2], c_ref.shape[-1])   # (TK, d)
    csq = csq_ref[...].reshape(1, -1)                  # (1, TK)

    xf = x.astype(jnp.float32)
    xsq = jnp.sum(xf * xf, axis=-1, keepdims=True)
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (TN, TK) on the MXU
    dist = jnp.maximum(xsq - 2.0 * cross + csq, 0.0)

    local_min = jnp.min(dist, axis=-1)                 # (TN,)
    local_arg = jnp.argmin(dist, axis=-1).astype(jnp.int32) + j * tk

    @pl.when(j == 0)
    def _seed():
        mind_s[...] = local_min
        amin_s[...] = local_arg

    @pl.when(j > 0)
    def _sweep():
        better = local_min < mind_s[...]     # strict: ties keep the low tile
        amin_s[...] = jnp.where(better, local_arg, amin_s[...])
        mind_s[...] = jnp.where(better, local_min, mind_s[...])

    # Final k tile: the X tile's assignment is complete and the block is
    # still resident — emit everything the step needs in the same pass.
    @pl.when(j == nk - 1)
    def _emit():
        labels = amin_s[...]
        mind = mind_s[...]
        w = w_ref[...].reshape(-1)                     # (TN,) f32
        labels_ref[...] = labels.reshape(labels_ref.shape)
        mind_ref[...] = mind.reshape(mind_ref.shape)

        @pl.when(i == 0)
        def _init():
            sums_ref[...] = jnp.zeros(sums_ref.shape, sums_ref.dtype)
            counts_ref[...] = jnp.zeros(counts_ref.shape, counts_ref.dtype)
            energy_ref[...] = jnp.zeros(energy_ref.shape, energy_ref.dtype)

        tn = labels.shape[0]

        def _accum_tile(jj, carry):
            # Weighted one-hot restricted to centroid tile jj keeps the
            # intermediate at (TN, TK) — never (TN, K).
            ks = jax.lax.broadcasted_iota(jnp.int32, (tn, tk), 1) + jj * tk
            onehot = jnp.where(labels[:, None] == ks, w[:, None],
                               jnp.float32(0.0))
            psum = jax.lax.dot_general(
                onehot, xf, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)    # (TK, d) on the MXU
            sums_ref[0, pl.ds(jj * tk, tk), :] += psum
            counts_ref[0, pl.ds(jj * tk, tk)] += jnp.sum(onehot, axis=0)
            return carry

        jax.lax.fori_loop(0, nk, _accum_tile, 0)
        energy_ref[0, 0] += jnp.sum(mind * w)


def _fused_bounds_kernel(x_ref, c_ref, csq_ref, w_ref, lb_ref, ub_ref,
                         lab0_ref, labels_ref, mind_ref, sums_ref,
                         counts_ref, energy_ref, gmin_ref, skip_ref,
                         mind_s, amin_s, *, tk: int):
    """The fused kernel with a per-(row-tile, k-tile) skip predicate.

    Extra inputs per X row tile: the squared inclusive group lower bounds
    lb (TN, G) — one lane per k-tile, G = num k tiles — the squared upper
    bound ub (TN,), and the previous labels (TN,).  A k tile j is
    computed only when ANY row of the tile has lb[:, j] <= ub (the
    non-strict predicate is what guarantees a row's owner tile is always
    computed: lb_owner <= d(x, c_a)^2 <= ub); otherwise the whole
    distance block, and the C tile's use, are skipped under `pl.when`
    and the drift-maintained bound is passed through as the new group
    min.  The running min is *seeded* with (ub, previous label), so a
    row all of whose non-owner tiles are skipped still emits its exact
    min-dist: the computed owner tile can only tighten the seed, and if
    it does not, ub was already exactly d(x, c_a)^2.

    Emits the fused kernel's five outputs plus the updated squared group
    mins (TN, G) and a skipped-tile counter (one per restart), which the
    wrapper normalises to a fraction of the (row-tile x k-tile) grid.
    """
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    lb = lb_ref[0, :, pl.ds(j, 1)].reshape(-1)                 # (TN,)
    ub = ub_ref[...].reshape(-1)                               # (TN,)
    pred = jnp.any(lb <= ub)

    @pl.when(j == 0)
    def _seed():
        mind_s[...] = ub
        amin_s[...] = lab0_ref[...].reshape(-1)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _zero_skip():
        skip_ref[...] = jnp.zeros(skip_ref.shape, skip_ref.dtype)

    @pl.when(pred)
    def _compute():
        x = x_ref[...]
        x = x.reshape(x.shape[-2], x.shape[-1])
        c = c_ref[...].reshape(c_ref.shape[-2], c_ref.shape[-1])
        csq = csq_ref[...].reshape(1, -1)
        xf = x.astype(jnp.float32)
        xsq = jnp.sum(xf * xf, axis=-1, keepdims=True)
        cross = jax.lax.dot_general(
            x, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        dist = jnp.maximum(xsq - 2.0 * cross + csq, 0.0)

        local_min = jnp.min(dist, axis=-1)
        local_arg = jnp.argmin(dist, axis=-1).astype(jnp.int32) + j * tk
        # strict <: a tie keeps the seed (the row's standing assignment)
        better = local_min < mind_s[...]
        amin_s[...] = jnp.where(better, local_arg, amin_s[...])
        mind_s[...] = jnp.where(better, local_min, mind_s[...])
        gmin_ref[0, :, pl.ds(j, 1)] = local_min[:, None]

    @pl.when(jnp.logical_not(pred))
    def _skip():
        skip_ref[0, 0] += 1.0
        # the drift-maintained bound stays the best known group min
        gmin_ref[0, :, pl.ds(j, 1)] = lb[:, None]

    @pl.when(j == nk - 1)
    def _emit():
        labels = amin_s[...]
        mind = mind_s[...]
        w = w_ref[...].reshape(-1)
        labels_ref[...] = labels.reshape(labels_ref.shape)
        mind_ref[...] = mind.reshape(mind_ref.shape)

        @pl.when(i == 0)
        def _init():
            sums_ref[...] = jnp.zeros(sums_ref.shape, sums_ref.dtype)
            counts_ref[...] = jnp.zeros(counts_ref.shape, counts_ref.dtype)
            energy_ref[...] = jnp.zeros(energy_ref.shape, energy_ref.dtype)

        x = x_ref[...]
        xf = x.reshape(x.shape[-2], x.shape[-1]).astype(jnp.float32)
        tn = labels.shape[0]

        def _accum_tile(jj, carry):
            ks = jax.lax.broadcasted_iota(jnp.int32, (tn, tk), 1) + jj * tk
            onehot = jnp.where(labels[:, None] == ks, w[:, None],
                               jnp.float32(0.0))
            psum = jax.lax.dot_general(
                onehot, xf, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            sums_ref[0, pl.ds(jj * tk, tk), :] += psum
            counts_ref[0, pl.ds(jj * tk, tk)] += jnp.sum(onehot, axis=0)
            return carry

        jax.lax.fori_loop(0, nk, _accum_tile, 0)
        energy_ref[0, 0] += jnp.sum(mind * w)


@functools.partial(jax.jit, static_argnames=("tn", "tk", "interpret"))
def _fused_bounds_call(x, cs, w, lab0, lb_sq, ub_sq, *, tn: int, tk: int,
                       interpret: bool):
    r, k, d = cs.shape
    n = x.shape[-2]
    x_batched = x.ndim == 3

    xp = pad_to(pad_to(x, -2, tn), -1, tiles.LANE)
    cp = pad_to(pad_to(cs, -2, tk), -1, tiles.LANE)
    wp = pad_to(w, -1, tn)
    w_batched = w.ndim == 2
    fmax = jnp.float32(jnp.finfo(jnp.float32).max)
    # padding rows must never force a tile's computation: their lower
    # bound is +max and their upper bound 0, so lb <= ub is always false
    lab0p = pad_to(lab0, -1, tn)
    lbp = pad_to(lb_sq, -2, tn, value=fmax)
    ubp = pad_to(ub_sq, -1, tn, value=0.0)

    cpf = cp.astype(jnp.float32)
    csq = jnp.sum(cpf * cpf, axis=-1)
    if cp.shape[-2] != k:
        mask = jnp.arange(cp.shape[-2]) >= k
        csq = jnp.where(mask[None, :], fmax, csq)

    np_, dp = xp.shape[-2], xp.shape[-1]
    kp = cp.shape[-2]
    g = kp // tk
    assert lbp.shape[-1] == g, (lbp.shape, g)
    grid = (r, np_ // tn, kp // tk)

    if x_batched:
        x_spec = pl.BlockSpec((1, tn, dp), lambda rr, i, j: (rr, i, 0))
    else:
        x_spec = pl.BlockSpec((tn, dp), lambda rr, i, j: (i, 0))
    if w_batched:
        w_spec = pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i))
    else:
        w_spec = pl.BlockSpec((tn,), lambda rr, i, j: (i,))

    return pl.pallas_call(
        functools.partial(_fused_bounds_kernel, tk=tk),
        grid=grid,
        in_specs=[
            x_spec,
            pl.BlockSpec((1, tk, dp), lambda rr, i, j: (rr, j, 0)),
            pl.BlockSpec((1, tk), lambda rr, i, j: (rr, j)),
            w_spec,
            pl.BlockSpec((1, tn, g), lambda rr, i, j: (rr, i, 0)),
            pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i)),
            pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i)),
            pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i)),
            pl.BlockSpec((1, kp, dp), lambda rr, i, j: (rr, 0, 0)),
            pl.BlockSpec((1, kp), lambda rr, i, j: (rr, 0)),
            pl.BlockSpec((1, 1), lambda rr, i, j: (rr, 0)),
            pl.BlockSpec((1, tn, g), lambda rr, i, j: (rr, i, 0)),
            pl.BlockSpec((1, 1), lambda rr, i, j: (rr, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, np_), jnp.int32),
            jax.ShapeDtypeStruct((r, np_), jnp.float32),
            jax.ShapeDtypeStruct((r, kp, dp), jnp.float32),
            jax.ShapeDtypeStruct((r, kp), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, np_, g), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tn,), jnp.float32),
            pltpu.VMEM((tn,), jnp.int32),
        ],
        **tiles.dimension_semantics("parallel", "arbitrary", "arbitrary"),
        interpret=interpret,
    )(xp, cp, csq, wp, lbp, ubp, lab0p)


@functools.partial(jax.jit, static_argnames=("tn", "tk", "interpret"))
def _fused_call(x, cs, w, *, tn: int, tk: int, interpret: bool):
    r, k, d = cs.shape
    n = x.shape[-2]
    x_batched = x.ndim == 3

    xp = pad_to(pad_to(x, -2, tn), -1, tiles.LANE)
    cp = pad_to(pad_to(cs, -2, tk), -1, tiles.LANE)
    wp = pad_to(w, -1, tn)           # tile-padding rows weigh 0 -> inert
    w_batched = w.ndim == 2

    cpf = cp.astype(jnp.float32)
    csq = jnp.sum(cpf * cpf, axis=-1)                  # (R, Kp)
    if cp.shape[-2] != k:
        # padded centroid rows must never win the argmin
        mask = jnp.arange(cp.shape[-2]) >= k
        csq = jnp.where(mask[None, :],
                        jnp.float32(jnp.finfo(jnp.float32).max), csq)

    np_, dp = xp.shape[-2], xp.shape[-1]
    kp = cp.shape[-2]
    grid = (r, np_ // tn, kp // tk)

    if x_batched:
        x_spec = pl.BlockSpec((1, tn, dp), lambda rr, i, j: (rr, i, 0))
    else:
        x_spec = pl.BlockSpec((tn, dp), lambda rr, i, j: (i, 0))
    if w_batched:
        w_spec = pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i))
    else:
        w_spec = pl.BlockSpec((tn,), lambda rr, i, j: (i,))

    return pl.pallas_call(
        functools.partial(_fused_kernel, tk=tk),
        grid=grid,
        in_specs=[
            x_spec,
            pl.BlockSpec((1, tk, dp), lambda rr, i, j: (rr, j, 0)),
            pl.BlockSpec((1, tk), lambda rr, i, j: (rr, j)),
            w_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i)),
            pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i)),
            pl.BlockSpec((1, kp, dp), lambda rr, i, j: (rr, 0, 0)),
            pl.BlockSpec((1, kp), lambda rr, i, j: (rr, 0)),
            pl.BlockSpec((1, 1), lambda rr, i, j: (rr, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, np_), jnp.int32),
            jax.ShapeDtypeStruct((r, np_), jnp.float32),
            jax.ShapeDtypeStruct((r, kp, dp), jnp.float32),
            jax.ShapeDtypeStruct((r, kp), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tn,), jnp.float32),            # running min
            pltpu.VMEM((tn,), jnp.int32),              # running argmin
        ],
        # restarts are independent; stats accumulate across i; the k
        # sweep folds scratch sequentially
        **tiles.dimension_semantics("parallel", "arbitrary", "arbitrary"),
        interpret=interpret,
    )(xp, cp, csq, wp)


def fused_lloyd_pallas(x: jax.Array, c: jax.Array, w=None, *,
                       tn=None, tk=None, interpret: bool = False,
                       vmem_bytes=None, bounds=None):
    """Fused assignment+update+energy in ONE physical pass over x.

    x: (N, d) — or (R, N, d) for per-problem batches; c: (K, d) — or
    (R, K, d) to run R centroid sets in one launch (the batched slot).
    w: optional (N,) row weights folded into sums/counts/energy (the
    minibatch slot; labels/min_sqdist stay unweighted) — or (R, N)
    per-problem weights in the batched case, the masking column of the
    hierarchy engine's padded segments (DESIGN.md §Hierarchy).

    Returns (labels i32, min_sqdist f32, sums (K,d) f32, counts (K,) f32,
    energy () f32), each gaining a leading R axis when c is (R, K, d).

    Tile sizes default to `tiles.choose_tiles` (VMEM-budget-aware; k is
    tiled, so arbitrary K takes this path — there is no fallback).

    ``bounds=(labels0, lb_sq, ub_sq)`` switches to the tile-skipping
    variant (DESIGN.md §Bounds): labels0 (N,) i32 is the standing
    assignment, lb_sq (N, G) the SQUARED inclusive group lower bounds
    with one group per k-tile (G = ceil(K/tk) — pass a matching ``tk``),
    and ub_sq (N,) the squared upper bound on the assigned distance.  A
    whole centroid tile is skipped when no row of the X tile can beat
    its bound; two extra outputs are appended: the updated squared group
    mins (N, G) and the skipped-tile fraction () of the (row-tile x
    k-tile) grid.  Each bound input gains a leading R axis when c does.
    """
    batched = c.ndim == 3
    if x.ndim == 3 and not batched:
        raise ValueError(
            f"per-problem x {x.shape} needs a per-problem c (R, K, d); "
            f"got {c.shape} — broadcast c yourself if the sets are shared")
    cs = c if batched else c[None]
    k, d = cs.shape[-2], cs.shape[-1]
    n = x.shape[-2]
    if w is None:
        w = jnp.ones((n,), jnp.float32)
    else:
        w = w.astype(jnp.float32)
    if w.ndim == 2 and not batched:
        raise ValueError(
            f"per-problem w {w.shape} needs a per-problem c (R, K, d); "
            f"got {c.shape}")
    kind = "fused" if bounds is None else "fused_bounds"
    if tn is None or tk is None:
        ct, ck = tiles.choose_tiles(n, k, d, jnp.dtype(x.dtype).itemsize,
                                    kind=kind, vmem_bytes=vmem_bytes)
        tn = ct if tn is None else tn
        tk = ck if tk is None else tk

    if bounds is None:
        labels, mind, sums, counts, energy = _fused_call(
            x, cs, w, tn=tn, tk=tk, interpret=interpret)
    else:
        lab0, lb_sq, ub_sq = bounds
        if not batched:
            lab0, lb_sq, ub_sq = lab0[None], lb_sq[None], ub_sq[None]
        g = -(-tiles.round_up(k, tk) // tk)
        if lb_sq.shape[-1] != g:
            raise ValueError(
                f"lb_sq has {lb_sq.shape[-1]} groups but tk={tk} tiles "
                f"K={k} into {g} — group size and k tile must agree")
        labels, mind, sums, counts, energy, gmin, skipped = \
            _fused_bounds_call(x, cs, w, lab0, lb_sq.astype(jnp.float32),
                               ub_sq.astype(jnp.float32),
                               tn=tn, tk=tk, interpret=interpret)
        n_cells = (gmin.shape[-2] // tn) * g
        skipped_frac = skipped[:, 0] / jnp.float32(n_cells)
        gmin = gmin[:, :n, :]

    labels, mind = labels[:, :n], mind[:, :n]
    sums, counts, energy = sums[:, :k, :d], counts[:, :k], energy[:, 0]
    if bounds is not None:
        if not batched:
            return (labels[0], mind[0], sums[0], counts[0], energy[0],
                    gmin[0], skipped_frac[0])
        return labels, mind, sums, counts, energy, gmin, skipped_frac
    if not batched:
        return labels[0], mind[0], sums[0], counts[0], energy[0]
    return labels, mind, sums, counts, energy
