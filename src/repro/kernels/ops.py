"""Jit'd dispatch layer over the Pallas kernels.

`use_pallas` resolution:
  * on TPU backends the compiled kernels run natively;
  * on CPU (this container) `interpret=True` executes the kernel bodies in
    Python for correctness validation — the TPU lowering is exercised by the
    dry-run path.

The solver-facing integration lives in `repro.core.backends`
(`get_backend("pallas" | "fused")`): the fused single-pass kernel is
consumed through the step primitive, so Algorithm 1 reads X exactly once
per accepted iteration — at arbitrary K, since the v2 kernel k-tiles the
centroid stream (DESIGN.md §Kernels-v2; there is no VMEM fallback path).
Row weights and the leading-R batch axis of the kernels are exposed here
as optional arguments.  `pallas_lloyd_ops()` remains as the deprecated
LloydOps adapter for code still injecting assign/update separately.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backends import fused_backend, pallas_backend  # noqa: F401
from repro.core.backends.pallas import (FUSED_MAX_KD,          # noqa: F401
                                        FUSED_VMEM_BYTES)
from repro.core.lloyd import AssignResult, LloydOps, update_from_sums
from repro.kernels import ref
from repro.kernels.assignment import assignment_pallas
from repro.kernels.fused_lloyd import fused_lloyd_pallas
from repro.kernels.update import update_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def assignment(x: jax.Array, c: jax.Array, *, use_pallas: bool = True):
    """(labels, min_sqdist) — Pallas kernel or jnp oracle.  c may carry a
    leading R axis (R centroid sets in one launch)."""
    if use_pallas:
        return assignment_pallas(x, c, interpret=_interpret())
    if c.ndim == 3:
        return jax.vmap(ref.assignment_ref, in_axes=(None, 0))(x, c)
    return ref.assignment_ref(x, c)


def cluster_update(x: jax.Array, labels: jax.Array, k: int, *,
                   w: jax.Array | None = None, use_pallas: bool = True):
    """(sums, counts) — Pallas kernel or jnp oracle; optional row
    weights w scale each row's contribution (the minibatch stats)."""
    if use_pallas:
        return update_pallas(x, labels, k, w=w, interpret=_interpret())
    return ref.update_ref(x, labels, k, w=w)


def fused_lloyd_step(x: jax.Array, c: jax.Array, *,
                     w: jax.Array | None = None, use_pallas: bool = True):
    """(labels, min_sqdist, sums, counts, energy) in one X pass; optional
    row weights fold into the stats/energy, and a (R, K, d) centroid
    batch adds a leading R axis to every output."""
    if use_pallas:
        return fused_lloyd_pallas(x, c, w, interpret=_interpret())
    if c.ndim == 3:
        fn = (lambda cc: ref.fused_lloyd_ref(x, cc)) if w is None else \
            (lambda cc: ref.minibatch_ref(x, cc, w))
        return jax.vmap(fn)(c)
    if w is None:
        return ref.fused_lloyd_ref(x, c)
    return ref.minibatch_ref(x, c, w)


def fused_step(x: jax.Array, c: jax.Array, *, use_pallas: bool = True):
    """One full Lloyd iteration via the fused kernel:
    returns (c_next, labels, energy)."""
    labels, _, sums, counts, energy = fused_lloyd_step(
        x, c, use_pallas=use_pallas)
    c_next = update_from_sums(sums, counts, c.astype(sums.dtype))
    return c_next.astype(c.dtype), labels, energy


# ---------------------------------------------------------------------------
# Deprecated LloydOps adapter — prefer get_backend("pallas"/"fused")
# ---------------------------------------------------------------------------

def pallas_lloyd_ops() -> LloydOps:
    """Algorithm-1 ops backed by the separate assignment/update kernels.

    Deprecated: the step-driven solver consumes `pallas_backend()` /
    `fused_backend()` directly (one pass per accepted iteration); this
    container remains for callers injecting assign/update separately."""

    def assign_fn(x, c):
        labels, mind = assignment(x, c)
        return AssignResult(labels, mind)

    def update_fn(x, labels, k, c_prev):
        sums, counts = cluster_update(x, labels, k)
        return update_from_sums(sums, counts,
                                c_prev.astype(sums.dtype)).astype(c_prev.dtype)

    def energy_fn(x, c, labels):
        diff = x.astype(jnp.float32) - c.astype(jnp.float32)[labels]
        return jnp.sum(diff * diff)

    return LloydOps(assign_fn=assign_fn, update_fn=update_fn,
                    energy_fn=energy_fn)
