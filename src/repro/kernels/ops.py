"""Jit'd dispatch layer over the Pallas kernels.

`use_pallas` resolution:
  * on TPU backends the compiled kernels run natively;
  * on CPU (this container) `interpret=True` executes the kernel bodies in
    Python for correctness validation — the TPU lowering is exercised by the
    dry-run path.

`pallas_lloyd_ops()` adapts the kernels to the `LloydOps` interface so
Algorithm 1 (repro.core.kmeans) runs unchanged on top of them, and
`fused_ops()` wires the fused single-pass kernel in as the beyond-paper
optimised backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lloyd import AssignResult, LloydOps, update_from_sums
from repro.kernels import ref
from repro.kernels.assignment import assignment_pallas
from repro.kernels.fused_lloyd import fused_lloyd_pallas
from repro.kernels.update import update_pallas

# VMEM budget for holding the full centroid block in the fused kernel
# (elements of C, f32): 2M elements = 8 MB, about half of one core's VMEM.
FUSED_MAX_KD = 2 * 1024 * 1024


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def assignment(x: jax.Array, c: jax.Array, *, use_pallas: bool = True):
    """(labels, min_sqdist) — Pallas kernel or jnp oracle."""
    if use_pallas:
        return assignment_pallas(x, c, interpret=_interpret())
    return ref.assignment_ref(x, c)


def cluster_update(x: jax.Array, labels: jax.Array, k: int, *,
                   use_pallas: bool = True):
    """(sums, counts) — Pallas kernel or jnp oracle."""
    if use_pallas:
        return update_pallas(x, labels, k, interpret=_interpret())
    return ref.update_ref(x, labels, k)


def fused_lloyd_step(x: jax.Array, c: jax.Array, *, use_pallas: bool = True):
    """(labels, sums, counts, energy) in one X pass."""
    if use_pallas:
        return fused_lloyd_pallas(x, c, interpret=_interpret())
    return ref.fused_lloyd_ref(x, c)


# ---------------------------------------------------------------------------
# LloydOps adapters
# ---------------------------------------------------------------------------

def pallas_lloyd_ops() -> LloydOps:
    """Algorithm-1 ops backed by the separate assignment/update kernels."""

    def assign_fn(x, c):
        labels, mind = assignment(x, c)
        return AssignResult(labels, mind)

    def update_fn(x, labels, k, c_prev):
        sums, counts = cluster_update(x, labels, k)
        return update_from_sums(sums, counts,
                                c_prev.astype(sums.dtype)).astype(c_prev.dtype)

    def energy_fn(x, c, labels):
        diff = x.astype(jnp.float32) - c.astype(jnp.float32)[labels]
        return jnp.sum(diff * diff)

    return LloydOps(assign_fn=assign_fn, update_fn=update_fn,
                    energy_fn=energy_fn)


class FusedGCache:
    """The fused kernel computes assignment AND update in one pass; the
    Algorithm-1 driver however consumes them at two separate call sites
    (assign at line 3, update at line 16 after a possible revert).  The
    driver stays kernel-agnostic; this thin cache lets the fused backend
    reuse the pass when the accelerated iterate was accepted — exactly the
    reuse argument of the paper's overhead analysis (Sec. 2.1 part ii)."""

    def __init__(self):
        self._key = None
        self._val = None

    def get(self, c):
        if self._key is not None and self._key is c:
            return self._val
        return None

    def put(self, c, val):
        self._key, self._val = c, val


def fused_step(x: jax.Array, c: jax.Array, *, use_pallas: bool = True):
    """One full Lloyd iteration via the fused kernel:
    returns (c_next, labels, energy)."""
    labels, sums, counts, energy = fused_lloyd_step(x, c,
                                                    use_pallas=use_pallas)
    c_next = update_from_sums(sums, counts, c.astype(sums.dtype))
    return c_next.astype(c.dtype), labels, energy
