"""Pallas TPU kernel engine v2 (DESIGN.md §Kernels-v2).

    tiles.py       — VMEM-budget tile chooser + Mosaic dimension hints
    assignment.py  — tiled argmin-distance kernel (Eq. 3)
    update.py      — weighted one-hot segment-sum kernel (Eq. 4)
    fused_lloyd.py — single-pass fused step: one X read per iteration,
                     arbitrary K (k-tiled), native weights + R batching
    ops.py         — jit'd dispatch (pallas vs jnp oracle)
    ref.py         — pure-jnp semantic oracles for every kernel

All kernels accept an optional leading R axis on the centroid (and label)
inputs — one launch runs R problems.  The stats-producing kernels
(fused_lloyd, update) additionally take optional per-row weights that
fold into the cluster statistics and the energy; assignment is
weight-free (labels/min-dist are per-row by definition).
"""
