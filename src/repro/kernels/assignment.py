"""Pallas TPU kernel for the K-Means assignment step (Eq. 3).

This is the paper's stated per-iteration bottleneck: O(N*K) distance
evaluations.  The paper's CPU implementation avoids work with Hamerly's
bounds; on TPU the same insight does not transfer (data-dependent branching
starves the MXU — see DESIGN.md §Hardware-adaptation), so the TPU-native
formulation is a dense blocked computation

    dist^2(i, k) = |x_i|^2 - 2 <x_i, c_k> + |c_k|^2

where the cross term is an MXU matmul, tiled so each (TN x d) sample block
and (TK x d) centroid block live in VMEM, with a running (min, argmin)
reduction across centroid tiles.

Grid layout: (n_tiles, k_tiles); the k dimension is the minor (sequential)
axis so the running min/argmin accumulation into the output block (indexed
by the n tile only) touches consecutive grid steps — the legal accumulation
pattern on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TN = 512   # sample rows per tile
DEFAULT_TK = 512   # centroid rows per tile


def _assignment_kernel(x_ref, c_ref, csq_ref, labels_ref, mind_ref, *,
                       tk: int):
    """One (n_tile, k_tile) cell: distances + running min/argmin."""
    j = pl.program_id(1)

    x = x_ref[...]                                  # (TN, d)
    c = c_ref[...]                                  # (TK, d)
    csq = csq_ref[...]                              # (1, TK)

    xf = x.astype(jnp.float32)
    xsq = jnp.sum(xf * xf, axis=-1, keepdims=True)  # (TN, 1)
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (TN, TK) on the MXU
    dist = jnp.maximum(xsq - 2.0 * cross + csq, 0.0)

    local_arg = jnp.argmin(dist, axis=-1).astype(jnp.int32)   # (TN,)
    local_min = jnp.min(dist, axis=-1)                        # (TN,)
    local_arg_global = local_arg + j * tk

    @pl.when(j == 0)
    def _init():
        labels_ref[...] = local_arg_global
        mind_ref[...] = local_min

    @pl.when(j > 0)
    def _accum():
        prev_min = mind_ref[...]
        prev_lab = labels_ref[...]
        better = local_min < prev_min                # strict: ties keep the
        labels_ref[...] = jnp.where(better, local_arg_global, prev_lab)
        mind_ref[...] = jnp.where(better, local_min, prev_min)


def _pad_to(a: jax.Array, axis: int, multiple: int, value=0.0):
    size = a.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(jax.jit,
                   static_argnames=("tn", "tk", "interpret"))
def assignment_pallas(x: jax.Array, c: jax.Array, *,
                      tn: int = DEFAULT_TN, tk: int = DEFAULT_TK,
                      interpret: bool = False):
    """Nearest-centroid assignment via the Pallas kernel.

    x: (N, d) f32/bf16; c: (K, d).  Returns (labels (N,) i32, mind (N,) f32).
    Arbitrary N, K, d — inputs are padded to tile multiples; padded centroid
    rows get +inf squared norms so they are never selected.
    """
    n, d = x.shape
    k = c.shape[0]
    tn = min(tn, max(8, n))
    tk = min(tk, max(8, k))

    xp = _pad_to(x, 0, tn)
    cp = _pad_to(c, 0, tk)
    # Pad feature dim to the 128-lane boundary for MXU alignment.
    xp = _pad_to(xp, 1, 128)
    cp = _pad_to(cp, 1, 128)

    cpf = cp.astype(jnp.float32)
    csq = jnp.sum(cpf * cpf, axis=-1)
    # Padded centroids must never win the argmin.
    if cp.shape[0] != k:
        mask = jnp.arange(cp.shape[0]) >= k
        csq = jnp.where(mask, jnp.float32(jnp.finfo(jnp.float32).max), csq)
    csq2 = csq[None, :]                              # (1, Kp)

    np_, dp = xp.shape
    kp = cp.shape[0]
    grid = (np_ // tn, kp // tk)

    labels, mind = pl.pallas_call(
        functools.partial(_assignment_kernel, tk=tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, dp), lambda i, j: (i, 0)),
            pl.BlockSpec((tk, dp), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tk), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tn,), lambda i, j: (i,)),
            pl.BlockSpec((tn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, cp, csq2)
    return labels[:n], mind[:n]
