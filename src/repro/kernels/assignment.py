"""Pallas TPU kernel for the K-Means assignment step (Eq. 3).

This is the paper's stated per-iteration bottleneck: O(N*K) distance
evaluations.  The paper's CPU implementation avoids work with Hamerly's
bounds; on TPU the same insight does not transfer (data-dependent branching
starves the MXU — see DESIGN.md §Hardware-adaptation), so the TPU-native
formulation is a dense blocked computation

    dist^2(i, k) = |x_i|^2 - 2 <x_i, c_k> + |c_k|^2

where the cross term is an MXU matmul, tiled so each (TN x d) sample block
and (TK x d) centroid block live in VMEM, with a running (min, argmin)
reduction across centroid tiles.

Grid layout (v2): (R, n_tiles, k_tiles); the k dimension is the minor
(sequential) axis so the running min/argmin accumulation into the output
block (indexed by the restart and n tile only) touches consecutive grid
steps — the legal accumulation pattern on TPU.  The leading R axis runs
R centroid sets against shared or per-problem samples in one launch (the
batched slot); restart and sample tiles are independent, so both are
hinted `parallel` for Mosaic, with `arbitrary` only on the k sweep.
Tile sizes come from the VMEM-budget chooser in `tiles.py`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tiles
from repro.kernels.tiles import pad_to


def _assignment_kernel(x_ref, c_ref, csq_ref, labels_ref, mind_ref, *,
                       tk: int):
    """One (r, n_tile, k_tile) cell: distances + running min/argmin."""
    j = pl.program_id(2)

    x = x_ref[...]
    x = x.reshape(x.shape[-2], x.shape[-1])            # (TN, d)
    c = c_ref[...].reshape(c_ref.shape[-2], c_ref.shape[-1])   # (TK, d)
    csq = csq_ref[...].reshape(1, -1)                  # (1, TK)

    xf = x.astype(jnp.float32)
    xsq = jnp.sum(xf * xf, axis=-1, keepdims=True)     # (TN, 1)
    cross = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (TN, TK) on the MXU
    dist = jnp.maximum(xsq - 2.0 * cross + csq, 0.0)

    local_arg = (jnp.argmin(dist, axis=-1).astype(jnp.int32)
                 + j * tk).reshape(labels_ref.shape)
    local_min = jnp.min(dist, axis=-1).reshape(mind_ref.shape)

    @pl.when(j == 0)
    def _init():
        labels_ref[...] = local_arg
        mind_ref[...] = local_min

    @pl.when(j > 0)
    def _accum():
        prev_min = mind_ref[...]
        prev_lab = labels_ref[...]
        better = local_min < prev_min                # strict: ties keep the
        labels_ref[...] = jnp.where(better, local_arg, prev_lab)
        mind_ref[...] = jnp.where(better, local_min, prev_min)


@functools.partial(jax.jit, static_argnames=("tn", "tk", "interpret"))
def _assignment_call(x, cs, *, tn: int, tk: int, interpret: bool):
    r, k = cs.shape[0], cs.shape[-2]
    n = x.shape[-2]
    x_batched = x.ndim == 3

    xp = pad_to(pad_to(x, -2, tn), -1, tiles.LANE)
    cp = pad_to(pad_to(cs, -2, tk), -1, tiles.LANE)

    cpf = cp.astype(jnp.float32)
    csq = jnp.sum(cpf * cpf, axis=-1)                  # (R, Kp)
    if cp.shape[-2] != k:
        # padded centroids must never win the argmin
        mask = jnp.arange(cp.shape[-2]) >= k
        csq = jnp.where(mask[None, :],
                        jnp.float32(jnp.finfo(jnp.float32).max), csq)

    np_, dp = xp.shape[-2], xp.shape[-1]
    kp = cp.shape[-2]
    grid = (r, np_ // tn, kp // tk)

    if x_batched:
        x_spec = pl.BlockSpec((1, tn, dp), lambda rr, i, j: (rr, i, 0))
    else:
        x_spec = pl.BlockSpec((tn, dp), lambda rr, i, j: (i, 0))

    labels, mind = pl.pallas_call(
        functools.partial(_assignment_kernel, tk=tk),
        grid=grid,
        in_specs=[
            x_spec,
            pl.BlockSpec((1, tk, dp), lambda rr, i, j: (rr, j, 0)),
            pl.BlockSpec((1, tk), lambda rr, i, j: (rr, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i)),
            pl.BlockSpec((1, tn), lambda rr, i, j: (rr, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, np_), jnp.int32),
            jax.ShapeDtypeStruct((r, np_), jnp.float32),
        ],
        **tiles.dimension_semantics("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(xp, cp, csq)
    return labels[:, :n], mind[:, :n]


def assignment_pallas(x: jax.Array, c: jax.Array, *,
                      tn=None, tk=None, interpret: bool = False,
                      vmem_bytes=None):
    """Nearest-centroid assignment via the Pallas kernel.

    x: (N, d) f32/bf16 — or (R, N, d) per-problem; c: (K, d) — or
    (R, K, d) for R centroid sets in one launch.  Returns (labels i32,
    mind f32), each with a leading R axis when c is (R, K, d).

    Arbitrary N, K, d — inputs are padded to tile multiples; padded
    centroid rows get +inf squared norms so they are never selected.
    Tile sizes default to the VMEM-budget chooser (`tiles.choose_tiles`).
    """
    batched = c.ndim == 3
    if x.ndim == 3 and not batched:
        raise ValueError(
            f"per-problem x {x.shape} needs a per-problem c (R, K, d); "
            f"got {c.shape} — broadcast c yourself if the sets are shared")
    cs = c if batched else c[None]
    k, d = cs.shape[-2], cs.shape[-1]
    n = x.shape[-2]
    if tn is None or tk is None:
        ct, ck = tiles.choose_tiles(n, k, d, jnp.dtype(x.dtype).itemsize,
                                    kind="assignment", vmem_bytes=vmem_bytes)
        tn = ct if tn is None else tn
        tk = ck if tk is None else tk
    labels, mind = _assignment_call(x, cs, tn=tn, tk=tk, interpret=interpret)
    if not batched:
        return labels[0], mind[0]
    return labels, mind
