"""Version shims for jax APIs that moved between 0.4.x and 0.5+.

This repo targets the newer spellings (`jax.shard_map`,
`jax.sharding.AxisType`, `jax.make_mesh(..., axis_types=...)`); on the
jax 0.4.x line those either live under `jax.experimental` or do not exist.
Importing this module resolves each symbol once and — where the canonical
location is missing — installs the shim *at* the canonical location, so
call sites (including tests and examples that use `jax.sharding.AxisType`
or `jax.shard_map` directly) work on either version.

Shimmed surface:

    AxisType   — `jax.sharding.AxisType`; on 0.4.x a stand-in enum with the
                 same member names (Auto / Explicit / Manual).  0.4.x meshes
                 have no axis-type machinery, so the values are inert tags.
    shard_map  — `jax.shard_map`, falling back to
                 `jax.experimental.shard_map.shard_map` (same call
                 convention for the subset used here: f positional,
                 mesh/in_specs/out_specs keywords).
    make_mesh  — `jax.make_mesh` accepting and discarding `axis_types`
                 when the installed version's signature lacks it.

Import this module (for the side effects) from any module that touches
mesh construction or shard_map: launch/mesh.py, core/distributed.py,
sharding/rules.py.
"""

from __future__ import annotations

import enum
import inspect

import jax
import jax.sharding


# --- jax.sharding.AxisType ------------------------------------------------

try:
    AxisType = jax.sharding.AxisType
except AttributeError:
    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType on jax 0.4.x (inert tags)."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


# --- jax.shard_map --------------------------------------------------------

try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _experimental_sm

    def shard_map(f, /, **kwargs):
        # The 0.4.x replication checker has no rule for lax.while_loop (the
        # solver's main loop); out_specs still declare the replication
        # contract, so disable the static check rather than the feature.
        kwargs.setdefault("check_rep", False)
        return _experimental_sm(f, **kwargs)

    jax.shard_map = shard_map


# --- jax.make_mesh(..., axis_types=...) -----------------------------------

def cost_analysis(compiled) -> dict:
    """`Compiled.cost_analysis()` as a flat dict on every jax version
    (0.4.x returns a per-device *list* of dicts; newer versions a dict)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


_native_make_mesh = jax.make_mesh

if "axis_types" in inspect.signature(_native_make_mesh).parameters:
    make_mesh = _native_make_mesh
else:
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
        del axis_types  # no axis-type machinery on this jax version
        return _native_make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh
