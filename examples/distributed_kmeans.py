"""The paper's solver on a multi-device mesh (shard_map data parallelism).

    PYTHONPATH=src python examples/distributed_kmeans.py [--devices 8]

Forces N virtual host devices (must run as its own process), builds a
(pod, data) mesh, shards a 200k-sample dataset across it, and runs
Algorithm 1 end-to-end with psum-reduced update/energy/convergence —
verifying bit-level agreement of the solver trajectory with the
single-device run (same iterations, acceptance count, energy).

This is the mechanism the 256/512-chip production dry-run uses; here it
executes for real on virtual devices.
"""

import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--backend", default="dense",
                    help="per-shard engine composed with the mesh via "
                         "distribute() (any repro.core.backends registry "
                         "name; validated after jax init)")
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices}")
    import jax
    import jax.numpy as jnp
    from repro.core.backends import backend_names
    from repro.core.distributed import (make_distributed_kmeans,
                                        shard_dataset)
    from repro.core.init_schemes import kmeanspp_init
    from repro.core.kmeans import KMeansConfig, aa_kmeans
    from repro.data.synthetic import make_blobs

    if args.backend not in backend_names():
        ap.error(f"--backend {args.backend!r}: unknown backend "
                 f"(registered: {', '.join(backend_names())})")

    assert len(jax.devices()) == args.devices
    pods = 2 if args.devices % 2 == 0 else 1
    mesh = jax.make_mesh((pods, args.devices // pods), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    print(f"mesh: {dict(mesh.shape)}")

    k = 12
    x_host = make_blobs(args.n, 16, k, seed=3, spread=1.5)
    x, pad = shard_dataset(x_host, mesh, ("pod", "data"))
    c0 = kmeanspp_init(jax.random.PRNGKey(1), jnp.asarray(x_host), k)

    cfg = KMeansConfig(k=k, max_iter=500)
    fit = make_distributed_kmeans(mesh, cfg, ("pod", "data"),
                                  backend=args.backend)
    res = jax.block_until_ready(fit(x, c0))
    print(f"distributed ({args.devices} devices, {args.backend}): "
          f"{int(res.n_accepted)}/{int(res.n_iter)} iterations, "
          f"MSE {float(res.energy)/args.n:.4f}, "
          f"converged={bool(res.converged)}")

    res1 = jax.jit(lambda a, b: aa_kmeans(a, b, cfg))(
        jnp.asarray(x_host), c0)
    print(f"single-device reference:  "
          f"{int(res1.n_accepted)}/{int(res1.n_iter)} iterations, "
          f"MSE {float(res1.energy)/args.n:.4f}")
    # psum reduction order can nudge fp trajectories on overlapping data;
    # the guaranteed invariant is equal-quality convergence.
    assert bool(res.converged) and bool(res1.converged)
    assert abs(float(res.energy) - float(res1.energy)) / float(res1.energy) \
        < 0.02
    print("OK: distributed solver converges to the single-device optimum.")


if __name__ == "__main__":
    sys.exit(main())
