"""Serving + the paper's technique: K-Means KV-cache codebooks.

    PYTHONPATH=src python examples/kv_codebook_serving.py

Prefills a prompt through a (reduced) h2o-danube model, compresses the
KV cache with AA-KMeans codebooks (one clustering problem per K/V tensor —
exactly Eq. (1) of the paper over the cached head vectors), then decodes
from both the raw and the compressed cache and compares outputs.

Also demonstrates `embedding_codebook` (product quantisation of the
embedding table with the AA solver) and prints solver statistics
(iterations, acceptance rate) on these real — not synthetic — vector sets.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import reduced_config
from repro.core.applications import (compress_kv_cache, embedding_codebook,
                                     kv_codebook)
from repro.launch import steps as ST
from repro.models import params as pr
from repro.models.config import ShapeSpec
from repro.models.model import Model, RunFlags, make_constrain


def main():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = reduced_config("h2o-danube-1.8b")
    flags = RunFlags(block_q=16, block_kv=16)
    model = Model(cfg, flags)
    shape = ShapeSpec("serve", 32, 4, "prefill")
    rules = ST.rules_for(mesh, cfg, shape)
    constrain = make_constrain(mesh, rules)
    params = pr.init_tree(model.param_specs(), jax.random.PRNGKey(0))
    batch = ST.real_batch(cfg, shape, jax.random.PRNGKey(1))

    logits, cache = model.prefill(params, batch, constrain, max_len=48)
    print(f"prefilled {shape.seq_len} tokens, cache K shape "
          f"{tuple(cache['k'].shape)}")

    # --- solver stats on real cached vectors (paper-style a/b report) ---
    vecs = cache["k"][:, :, :shape.seq_len].reshape(-1, cfg.head_dim)
    cb, codes, res = kv_codebook(vecs, k=16)
    print(f"KV clustering: N={vecs.shape[0]} d={cfg.head_dim} K=16 -> "
          f"{int(res.n_accepted)}/{int(res.n_iter)} iterations accepted, "
          f"MSE {float(res.energy)/vecs.shape[0]:.5f}")

    # --- decode parity raw vs compressed ---
    comp_cache, err = compress_kv_cache(
        {k: v for k, v in cache.items()}, k=32, valid_len=shape.seq_len)
    print(f"cache codebook (K=32) relative reconstruction error: {err:.4f}")

    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    out_raw, out_cmp = [], []
    c_raw, c_cmp = cache, comp_cache
    t_raw = t_cmp = tok
    for _ in range(8):
        lo_r, c_raw = model.decode_step(params, {"token": t_raw}, c_raw,
                                        constrain)
        lo_c, c_cmp = model.decode_step(params, {"token": t_cmp}, c_cmp,
                                        constrain)
        t_raw = jnp.argmax(lo_r[:, -1], -1).astype(jnp.int32)
        t_cmp = jnp.argmax(lo_c[:, -1], -1).astype(jnp.int32)
        out_raw.append(np.asarray(t_raw))
        out_cmp.append(np.asarray(t_cmp))
    agree = float(np.mean(np.stack(out_raw) == np.stack(out_cmp)))
    print(f"greedy-token agreement over 8 decode steps "
          f"(raw vs compressed cache): {agree:.2f}")

    # --- embedding-table product quantisation ---
    table = params["head"]["embed"]
    cbs, codes, rel = embedding_codebook(table, k=32, n_subspaces=4)
    ratio = table.size * 4 / (codes.size * 1 + cbs.size * 4)
    print(f"embedding PQ: table {tuple(table.shape)} -> rel err {rel:.4f}, "
          f"~{ratio:.1f}x compression")


if __name__ == "__main__":
    main()
