"""Quickstart: Anderson-accelerated K-Means vs Lloyd in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py [--backend fused]

Generates an overlapping Gaussian mixture (the slow-convergence regime the
paper targets), seeds with K-Means++, runs classical Lloyd and Algorithm 1
from the same centroids, and prints the head-to-head — the paper's
headline result (fewer iterations, same MSE) in miniature.  ``--backend``
selects the solver engine (repro.core.backends): the ``fused`` Pallas
backend reads X exactly once per accepted iteration.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.backends import backend_names
from repro.core.init_schemes import batched_init, kmeanspp_init
from repro.core.kmeans import (KMeansConfig, aa_kmeans, aa_kmeans_batched,
                               aa_kmeans_traced, select_best)
from repro.core.lloyd import lloyd_kmeans
from repro.data.synthetic import make_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="dense",
                    choices=sorted(backend_names()))
    args = ap.parse_args()

    k = 10
    x = jnp.asarray(make_dataset("Colorment", scale=0.2, seed=0))
    print(f"dataset: Colorment stand-in, N={x.shape[0]}, d={x.shape[1]}, "
          f"K={k}, backend={args.backend}")
    c0 = kmeanspp_init(jax.random.PRNGKey(0), x, k)

    lloyd = jax.jit(lambda a, b: lloyd_kmeans(a, b, k, 1000))
    jax.block_until_ready(lloyd(x, c0))            # compile
    t0 = time.perf_counter()
    _, _, e_l, it_l = jax.block_until_ready(lloyd(x, c0))
    t_l = time.perf_counter() - t0

    cfg = KMeansConfig(k=k, max_iter=1000)
    aa = jax.jit(lambda a, b: aa_kmeans(a, b, cfg, backend=args.backend))
    jax.block_until_ready(aa(x, c0))
    t0 = time.perf_counter()
    res = jax.block_until_ready(aa(x, c0))
    t_a = time.perf_counter() - t0

    print(f"\nLloyd      : {int(it_l):4d} iterations  "
          f"{t_l*1e3:7.1f} ms  MSE {float(e_l)/x.shape[0]:.4f}")
    print(f"AA (ours)  : {int(res.n_iter):4d} iterations "
          f"({int(res.n_accepted)} accelerated accepted)  "
          f"{t_a*1e3:7.1f} ms  MSE {float(res.energy)/x.shape[0]:.4f}")
    print(f"iteration reduction: "
          f"{100*(1 - int(res.n_iter)/int(it_l)):.0f}%   "
          f"time reduction: {100*(1 - t_a/t_l):.0f}%")

    # peek at the dynamic window in action (warmup=True -> the reported
    # wall time is steady-state execution, not jit compilation)
    tr = aa_kmeans_traced(x, c0, cfg, backend=args.backend, warmup=True)
    print(f"\ndynamic m trace (first 20): {tr.m_values[:20]}")
    print(f"accepted pattern (first 20): "
          f"{''.join('Y' if a else '.' for a in tr.accepted[:20])}")
    print(f"traced wall time (steady-state): {tr.wall_time_s*1e3:.1f} ms")

    # batched multi-restart: R seedings solved in ONE device program with
    # on-device best-of-R selection — what AAKMeans(n_init=R).fit runs.
    restarts = 8
    keys = jax.random.split(jax.random.PRNGKey(1), restarts)
    c0s = batched_init("kmeans++", keys, x, k)
    batched = jax.jit(lambda a, b: select_best(
        aa_kmeans_batched(a, b, cfg, backend=args.backend)))
    jax.block_until_ready(batched(x, c0s))
    t0 = time.perf_counter()
    best = jax.block_until_ready(batched(x, c0s))
    t_b = time.perf_counter() - t0
    print(f"\nbatched best-of-{restarts}: {t_b*1e3:7.1f} ms for all "
          f"restarts  MSE {float(best.energy)/x.shape[0]:.4f}  "
          f"(winner: {int(best.n_iter)} iterations)")


if __name__ == "__main__":
    main()
