"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps on CPU and show the loss falling.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch ...]

Uses the real launcher (repro.launch.train): deterministic data pipeline,
AdamW, remat, async checkpointing with resume.  Default arch is
smollm-135m at reduced sequence length so a few hundred steps complete on
this container; on TPU the same command with --mesh single trains the full
config on a 256-chip pod.
"""

import argparse

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--full-width", action="store_true",
                    help="use the real config widths (slower on CPU); "
                         "default uses the reduced smoke config")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--seq-len", str(args.seq_len),
            "--global-batch", str(args.global_batch),
            "--ckpt-dir", "/tmp/repro_train_lm_ckpt", "--ckpt-every", "100",
            "--log-every", "20"]
    if not args.full_width:
        argv.append("--smoke")
    out = T.run(T.parse_args(argv))
    drop = out["first_loss"] - out["final_loss"]
    print(f"\nloss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"(drop {drop:.3f}) over {out['steps']} steps "
          f"[{out['wall_s']:.0f}s]")
    assert drop > 0.3, "training should clearly reduce the loss"
    print("OK: loss decreased as expected.")


if __name__ == "__main__":
    main()
