"""Segmentation-overhead benchmark for the persistence engine
(DESIGN.md §Persistence).

A checkpointed solve is a host loop over jit'd `while_loop` segments, so
its cost over the monolithic solve decomposes into (a) host/dispatch
overhead per segment boundary and (b) the `device_get` + npz write per
snapshot.  This module times the same fixed-seed solve four ways —
monolithic, segmented with no snapshot writes (``checkpoint_cb`` only),
segmented with synchronous artifact writes (``sync_writes=True``), and
segmented with the default background `repro.runtime.writer` — and
reports the per-boundary overheads.  The async arm shows how much of the
sync write cost the writer thread hides (the remaining overhead is the
unavoidable ``device_get`` snapshot plus queue handoff); the perf
trajectory catches a regression that would make "resumable" cost more
than it must.

    PYTHONPATH=src python -m benchmarks.checkpoint_bench [--json [PATH]]
        [--checkpoint-every S] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

from benchmarks.common import csv_row

# full run: long enough that per-boundary cost is resolvable over noise;
# smoke: just proves the segmented path runs end to end (CI)
FULL = dict(n=20000, d=16, k=32, max_iter=60)
SMOKE = dict(n=512, d=8, k=8, max_iter=12)


def _solve_time(fn, reps=3):
    """Median wall time of a solve, compile excluded (one warm-up call;
    the segmented drivers block on every segment, so block_until_ready on
    the result is enough)."""
    import jax
    jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def run(checkpoint_every: int = 10, smoke: bool = False) -> dict:
    import jax.numpy as jnp
    import jax

    from repro.core.init_schemes import kmeanspp_init
    from repro.core.kmeans import KMeansConfig, aa_kmeans
    from repro.data.synthetic import make_blobs

    p = SMOKE if smoke else FULL
    x = jnp.asarray(make_blobs(p["n"], p["d"], p["k"], seed=0, spread=1.0))
    c0 = kmeanspp_init(jax.random.PRNGKey(0), x, p["k"])
    cfg = KMeansConfig(k=p["k"], max_iter=p["max_iter"])
    every = max(1, int(checkpoint_every))

    # the monolithic baseline is the jitted whole-solve program, like any
    # production caller would run it (aa_kmeans_jit idiom)
    mono = jax.jit(lambda xx, cc: aa_kmeans(xx, cc, cfg))
    ref = mono(x, c0)
    t_mono = _solve_time(lambda: mono(x, c0))
    t_seg = _solve_time(lambda: aa_kmeans(
        x, c0, cfg, checkpoint_every=every, checkpoint_cb=lambda st, t: None))
    with tempfile.TemporaryDirectory() as d:
        t_sync = _solve_time(lambda: aa_kmeans(
            x, c0, cfg, checkpoint_every=every, checkpoint_dir=d,
            sync_writes=True))
    with tempfile.TemporaryDirectory() as d:
        # default path: background CheckpointWriter (drained before the
        # driver returns, so every snapshot is on disk when timing stops)
        t_async = _solve_time(lambda: aa_kmeans(
            x, c0, cfg, checkpoint_every=every, checkpoint_dir=d))
        n_snaps = len(list(Path(d).glob("it_*.npz")))
        # roundtrip correctness rides along: resume the final artifact
        res = aa_kmeans(x, c0, cfg,
                        resume_from=max(Path(d).glob("it_*.npz")))
    assert float(res.energy) == float(ref.energy), \
        "resumed solve diverged from the monolithic result"
    n_bounds = max(1, n_snaps)

    # Direct per-boundary cost, free of solve-time noise (the end-to-end
    # deltas above bury a ~ms write under ~60 ms segments): what the
    # DRIVER pays at a boundary is device_get + npz write on the sync
    # path vs device_get + queue handoff on the async path — the write
    # itself runs on the writer thread, off the critical path.
    from repro.core import serialize
    from repro.runtime.writer import CheckpointWriter, write_snapshot
    holder = {}
    aa_kmeans(x, c0, cfg, checkpoint_every=every,
              checkpoint_cb=lambda st, t: holder.update(state=st))
    state = holder["state"]
    reps = 8 if smoke else 20

    def _boundary_time(fn, between=None):
        ts = []
        for i in range(reps):
            t0 = time.perf_counter()
            fn(i, jax.device_get(state))    # the snapshot point itself
            ts.append(time.perf_counter() - t0)
            if between is not None:
                between()
        ts.sort()
        return ts[len(ts) // 2] * 1e6

    with tempfile.TemporaryDirectory() as d:
        sync_us = _boundary_time(lambda i, st: write_snapshot(
            d, st, kind=serialize.KIND_LOOP, step=i))
    with tempfile.TemporaryDirectory() as d:
        with CheckpointWriter(d, kind=serialize.KIND_LOOP) as w:
            # drain OUTSIDE the timer: in a real run the next segment's
            # compute gives the writer its slack, so the driver pays only
            # the handoff; a tight rep loop would instead measure queue
            # back-pressure (disk saturation) that checkpoint_every
            # boundaries never reach
            async_us = _boundary_time(lambda i, st: w.submit(st, i),
                                      between=w.drain)

    return {
        "n": p["n"], "d": p["d"], "k": p["k"],
        "n_iter": int(ref.n_iter), "checkpoint_every": every,
        "segments": n_bounds, "snapshots": n_snaps,
        "t_monolithic_s": t_mono, "t_segmented_s": t_seg,
        "t_checkpointed_s": t_sync, "t_checkpointed_async_s": t_async,
        "seg_overhead_us_per_boundary": (t_seg - t_mono) / n_bounds * 1e6,
        "snap_overhead_us_per_snapshot": (t_sync - t_seg) / n_bounds * 1e6,
        "async_overhead_us_per_snapshot": (t_async - t_seg) / n_bounds * 1e6,
        "sync_boundary_us": sync_us,
        "async_boundary_us": async_us,
        "async_to_sync_overhead_ratio": async_us / sync_us,
    }


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--checkpoint-every", type=int, default=10,
                        metavar="S", help="segment length in iterations")
    parser.add_argument("--json", nargs="?", const="BENCH_checkpoint.json",
                        default=None, metavar="PATH",
                        help="write the record to PATH (default "
                             "BENCH_checkpoint.json in the repo root)")
    parser.add_argument("--smoke", action="store_true",
                        help="tiny problem; proves the segmented path (CI)")
    args = parser.parse_args(argv)

    import jax
    rec = run(checkpoint_every=args.checkpoint_every, smoke=args.smoke)
    tag = f"n{rec['n']}_k{rec['k']}_s{rec['checkpoint_every']}"
    print(csv_row(f"checkpoint.monolithic.{tag}",
                  rec["t_monolithic_s"] * 1e6))
    print(csv_row(f"checkpoint.segmented.{tag}", rec["t_segmented_s"] * 1e6,
                  f"boundary_us={rec['seg_overhead_us_per_boundary']:.1f}"))
    print(csv_row(f"checkpoint.snapshotted.{tag}",
                  rec["t_checkpointed_s"] * 1e6,
                  f"snapshot_us={rec['snap_overhead_us_per_snapshot']:.1f};"
                  f"snapshots={rec['snapshots']}"))
    print(csv_row(f"checkpoint.snapshotted_async.{tag}",
                  rec["t_checkpointed_async_s"] * 1e6,
                  f"snapshot_us={rec['async_overhead_us_per_snapshot']:.1f}"))
    print(csv_row(f"checkpoint.boundary_sync.{tag}",
                  rec["sync_boundary_us"]))
    print(csv_row(f"checkpoint.boundary_async.{tag}",
                  rec["async_boundary_us"],
                  f"ratio_vs_sync="
                  f"{rec['async_to_sync_overhead_ratio']:.3f}"))
    if args.json:
        path = Path(args.json)
        if not path.is_absolute():
            path = Path(__file__).resolve().parents[1] / path
        path.write_text(json.dumps(
            {"schema": "checkpoint_bench/v2",
             "backend": jax.default_backend(),
             "smoke": args.smoke, "record": rec}, indent=2))
        print(f"wrote {path}")
    return rec


if __name__ == "__main__":
    main()
