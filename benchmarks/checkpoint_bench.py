"""Segmentation-overhead benchmark for the persistence engine
(DESIGN.md §Persistence).

A checkpointed solve is a host loop over jit'd `while_loop` segments, so
its cost over the monolithic solve decomposes into (a) host/dispatch
overhead per segment boundary and (b) the `device_get` + atomic npz write
per snapshot.  This module times the same fixed-seed solve three ways —
monolithic, segmented with no snapshot writes (``checkpoint_cb`` only),
and segmented with real artifacts to a temp dir — and reports the
per-boundary overheads, so the perf trajectory catches a regression that
would make "resumable" cost more than it must.

    PYTHONPATH=src python -m benchmarks.checkpoint_bench [--json [PATH]]
        [--checkpoint-every S] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

from benchmarks.common import csv_row

# full run: long enough that per-boundary cost is resolvable over noise;
# smoke: just proves the segmented path runs end to end (CI)
FULL = dict(n=20000, d=16, k=32, max_iter=60)
SMOKE = dict(n=512, d=8, k=8, max_iter=12)


def _solve_time(fn, reps=3):
    """Median wall time of a solve, compile excluded (one warm-up call;
    the segmented drivers block on every segment, so block_until_ready on
    the result is enough)."""
    import jax
    jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def run(checkpoint_every: int = 10, smoke: bool = False) -> dict:
    import jax.numpy as jnp
    import jax

    from repro.core.init_schemes import kmeanspp_init
    from repro.core.kmeans import KMeansConfig, aa_kmeans
    from repro.data.synthetic import make_blobs

    p = SMOKE if smoke else FULL
    x = jnp.asarray(make_blobs(p["n"], p["d"], p["k"], seed=0, spread=1.0))
    c0 = kmeanspp_init(jax.random.PRNGKey(0), x, p["k"])
    cfg = KMeansConfig(k=p["k"], max_iter=p["max_iter"])
    every = max(1, int(checkpoint_every))

    # the monolithic baseline is the jitted whole-solve program, like any
    # production caller would run it (aa_kmeans_jit idiom)
    mono = jax.jit(lambda xx, cc: aa_kmeans(xx, cc, cfg))
    ref = mono(x, c0)
    t_mono = _solve_time(lambda: mono(x, c0))
    t_seg = _solve_time(lambda: aa_kmeans(
        x, c0, cfg, checkpoint_every=every, checkpoint_cb=lambda st, t: None))
    with tempfile.TemporaryDirectory() as d:
        t_ckpt = _solve_time(lambda: aa_kmeans(
            x, c0, cfg, checkpoint_every=every, checkpoint_dir=d))
        n_snaps = len(list(Path(d).glob("it_*.npz")))
        # roundtrip correctness rides along: resume the final artifact
        res = aa_kmeans(x, c0, cfg,
                        resume_from=max(Path(d).glob("it_*.npz")))
    assert float(res.energy) == float(ref.energy), \
        "resumed solve diverged from the monolithic result"
    n_bounds = max(1, n_snaps)
    return {
        "n": p["n"], "d": p["d"], "k": p["k"],
        "n_iter": int(ref.n_iter), "checkpoint_every": every,
        "segments": n_bounds, "snapshots": n_snaps,
        "t_monolithic_s": t_mono, "t_segmented_s": t_seg,
        "t_checkpointed_s": t_ckpt,
        "seg_overhead_us_per_boundary": (t_seg - t_mono) / n_bounds * 1e6,
        "snap_overhead_us_per_snapshot": (t_ckpt - t_seg) / n_bounds * 1e6,
    }


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--checkpoint-every", type=int, default=10,
                        metavar="S", help="segment length in iterations")
    parser.add_argument("--json", nargs="?", const="BENCH_checkpoint.json",
                        default=None, metavar="PATH",
                        help="write the record to PATH (default "
                             "BENCH_checkpoint.json in the repo root)")
    parser.add_argument("--smoke", action="store_true",
                        help="tiny problem; proves the segmented path (CI)")
    args = parser.parse_args(argv)

    import jax
    rec = run(checkpoint_every=args.checkpoint_every, smoke=args.smoke)
    tag = f"n{rec['n']}_k{rec['k']}_s{rec['checkpoint_every']}"
    print(csv_row(f"checkpoint.monolithic.{tag}",
                  rec["t_monolithic_s"] * 1e6))
    print(csv_row(f"checkpoint.segmented.{tag}", rec["t_segmented_s"] * 1e6,
                  f"boundary_us={rec['seg_overhead_us_per_boundary']:.1f}"))
    print(csv_row(f"checkpoint.snapshotted.{tag}",
                  rec["t_checkpointed_s"] * 1e6,
                  f"snapshot_us={rec['snap_overhead_us_per_snapshot']:.1f};"
                  f"snapshots={rec['snapshots']}"))
    if args.json:
        path = Path(args.json)
        if not path.is_absolute():
            path = Path(__file__).resolve().parents[1] / path
        path.write_text(json.dumps(
            {"schema": "checkpoint_bench/v1",
             "backend": jax.default_backend(),
             "smoke": args.smoke, "record": rec}, indent=2))
        print(f"wrote {path}")
    return rec


if __name__ == "__main__":
    main()
