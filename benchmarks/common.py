"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, reps: int = 3):
    """Median wall time of jitted fn (compile excluded via warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return out, ts[len(ts) // 2]


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
