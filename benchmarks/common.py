"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, reps: int = 3, reduce=None):
    """Wall time of jitted fn (compile excluded via warmup).

    ``reduce`` folds the per-rep times: default median; pass ``min`` for
    comparisons on a contended box, where the minimum tracks the true
    cost while medians wander with load."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    if reduce is not None:
        return out, reduce(ts)
    ts.sort()
    return out, ts[len(ts) // 2]


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def traced_run(x, c0, cfg, backend="dense", warmup=True, **kwargs):
    """`aa_kmeans_traced` for benchmark code, warm by default: the
    warm-up pass compiles the init/iteration programs before the timer
    starts, so the trace's ``wall_time_s`` is a Table-3-comparable
    execution time rather than (compile + execute).  Pass warmup=False
    when only the per-iteration statistics matter (m trace, acceptance
    pattern) — they are timing-independent and the extra solve is then
    wasted work."""
    from repro.core.kmeans import aa_kmeans_traced
    return aa_kmeans_traced(x, c0, cfg, backend=backend, warmup=warmup,
                            **kwargs)
