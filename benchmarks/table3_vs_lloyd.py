"""Paper Table 3: AA-KMeans vs Lloyd across seedings and cluster counts.

Protocol (scaled): for each dataset and each init scheme in {k-means++,
afk-mc2, bf, clarans} at K=10, plus CLARANS at K in {10, 100}, run Lloyd
and Algorithm 1 from the SAME initial centroids to convergence.  Report
iterations, warm wall time and MSE.

Claims validated (paper Sec. 3.2): our method wins the majority of cases,
mean computational-time decrease > 25-33%, MSE parity with Lloyd.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timed
from repro.core.init_schemes import (afkmc2_init, bf_init, clarans_init,
                                     kmeanspp_init)
from repro.core.kmeans import KMeansConfig, aa_kmeans
from repro.core.lloyd import lloyd_kmeans
from repro.data.synthetic import DATASETS, make_dataset

INITS = {"kmeans++": kmeanspp_init, "afk-mc2": afkmc2_init,
         "bf": bf_init, "clarans": clarans_init}


def one_case(x, c0, k, backend="dense"):
    lf = jax.jit(lambda a, b: lloyd_kmeans(a, b, k, 1000))
    (c, lab, e_l, it_l), t_l = timed(lf, x, c0)
    cfg = KMeansConfig(k=k, max_iter=1000)
    af = jax.jit(lambda a, b: aa_kmeans(a, b, cfg, backend=backend))
    res, t_a = timed(af, x, c0)
    return {"lloyd_iter": int(it_l), "lloyd_time_s": t_l,
            "lloyd_mse": float(e_l) / x.shape[0],
            "aa_a": int(res.n_accepted), "aa_b": int(res.n_iter),
            "aa_time_s": t_a, "aa_mse": float(res.energy) / x.shape[0]}


def run(scale=0.05, datasets=None, seed=0, ks=(10,), clarans_ks=(10, 100),
        verbose=True, backend="dense"):
    rows, cases = [], []
    for name in (datasets or list(DATASETS)):
        x = jnp.asarray(make_dataset(name, scale=scale, seed=seed))
        for init_name, init_fn in INITS.items():
            key = jax.random.PRNGKey(seed)
            ks_here = clarans_ks if init_name == "clarans" else ks
            for k in ks_here:
                if k >= x.shape[0] // 4:
                    continue
                c0 = init_fn(key, x, k)
                c0 = jnp.asarray(c0)
                case = one_case(x, c0, k, backend=backend)
                case.update(dataset=name, init=init_name, k=k)
                cases.append(case)
                if verbose:
                    print(f"{name:18s} {init_name:9s} K={k:4d} | "
                          f"lloyd {case['lloyd_iter']:4d}it "
                          f"{case['lloyd_time_s']*1e3:8.1f}ms "
                          f"mse {case['lloyd_mse']:8.4f} | "
                          f"aa {case['aa_a']}/{case['aa_b']} "
                          f"{case['aa_time_s']*1e3:8.1f}ms "
                          f"mse {case['aa_mse']:8.4f}", flush=True)
    wins = sum(1 for c in cases if c["aa_time_s"] < c["lloyd_time_s"])
    iter_wins = sum(1 for c in cases if c["aa_b"] < c["lloyd_iter"])
    mean_dec = sum(1 - c["aa_time_s"] / c["lloyd_time_s"]
                   for c in cases) / max(len(cases), 1)
    mse_ok = sum(1 for c in cases
                 if c["aa_mse"] <= c["lloyd_mse"] * 1.01)
    return {"cases": cases, "wins": wins, "iter_wins": iter_wins,
            "total": len(cases), "mean_time_decrease": mean_dec,
            "mse_parity": mse_ok}


def main(scale=0.05, backend="dense"):
    s = run(scale=scale, backend=backend)
    print(csv_row("table3.aa_vs_lloyd", 0.0,
                  f"wins={s['wins']}/{s['total']} "
                  f"iter_wins={s['iter_wins']}/{s['total']} "
                  f"mean_time_decrease={s['mean_time_decrease']:.1%} "
                  f"mse_parity={s['mse_parity']}/{s['total']}"))
    return s


if __name__ == "__main__":
    main()
