"""Serving-path benchmark: closure-index recall vs latency
(DESIGN.md §Serving).

For each (K, d) case the query set is labelled once by the exact full-K
scan, then by the cluster-closure candidate path over a sweep of
candidate counts (`repro.serving.closure`).  Each record prices one
sweep point: label agreement with the exact path ("recall" — the
candidate restriction is the only approximation) against the measured
per-query wall cost of both paths.  The curve is the serving tier's
tuning surface: pick the smallest candidate count whose recall clears
the product's bar.

``--json [PATH]`` writes ``BENCH_serving.json`` (schema
``serving_bench/v1``); ``--smoke`` runs a tiny case for CI
(tests/test_perf_smoke.py pins the schema).  The full run includes the
K=4096 case the ISSUE-8 acceptance names.

    PYTHONPATH=src python -m benchmarks.serving_bench --json
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

# (k, d, n_queries, candidate sweep)
CASES = [
    (512, 32, 4096, (8, 16, 32, 64, 128)),
    (4096, 64, 4096, (32, 64, 128, 256, 512)),
]
SMOKE_CASES = [
    (64, 8, 512, (4, 16, 64)),
]


def _timed(fn, *args, reps: int = 5) -> float:
    """Median wall seconds per call; compile excluded (one warmup)."""
    import jax
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _make_case(k: int, d: int, n_queries: int, seed: int):
    """Synthetic serving workload: centroids on a low-intrinsic-dimension
    manifold (8-D latent embedded in d), queries scattered around them.
    Real fitted codebooks have exactly this structure — neighbouring
    centroids exist, so a closure index has something to exploit.  An
    isotropic d=64 Gaussian would not (concentration of measure makes
    every centroid nearly equidistant, which no candidate index — or
    product — can serve).  Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    dim_lat = max(2, min(d, 8))
    basis = rng.normal(size=(dim_lat, d)).astype(np.float32) / \
        np.sqrt(dim_lat)
    centroids = (rng.normal(size=(k, dim_lat)) * 8.0
                 ).astype(np.float32) @ basis
    owner = rng.integers(0, k, size=n_queries)
    queries = (centroids[owner]
               + 0.5 * rng.normal(size=(n_queries, d)).astype(np.float32))
    return centroids, queries


def case_records(k: int, d: int, n_queries: int, sweep, *,
                 seed: int = 0, reps: int = 5) -> list:
    import jax
    import jax.numpy as jnp

    from repro.core.lloyd import pairwise_sqdist
    from repro.serving.closure import (build_closure_index,
                                       candidate_table, closure_assign)

    centroids_h, queries_h = _make_case(k, d, n_queries, seed)
    c = jnp.asarray(centroids_h)
    x = jnp.asarray(queries_h)

    exact_fn = jax.jit(lambda xq, cq: jnp.argmin(
        pairwise_sqdist(xq, cq), axis=1).astype(jnp.int32))
    t_exact = _timed(exact_fn, x, c, reps=reps)
    exact_labels = np.asarray(exact_fn(x, c))

    approx_fn = jax.jit(
        lambda xq, cq, r, cd, t: closure_assign(xq, cq, r, cd, t)[0])
    # one build at the largest sweep point; prefixes ARE the smaller
    # closures (candidate lists are sorted nearest-first).  The candidate
    # table is per-model-version state (ServingModel builds it at load),
    # so it is precomputed here too and excluded from the per-query cost.
    index = build_closure_index(c, n_candidates=max(sweep), seed=seed)
    table = candidate_table(c, index.candidates)
    records = []
    for n_cand in sorted(sweep):
        idx = index.shrink(n_cand)
        tab = table[:, :n_cand]
        t_approx = _timed(approx_fn, x, c, idx.routers, idx.candidates,
                          tab, reps=reps)
        labels = np.asarray(approx_fn(x, c, idx.routers, idx.candidates,
                                      tab))
        records.append({
            "k": k, "d": d, "n_queries": n_queries,
            "n_groups": int(idx.n_groups),
            "n_candidates": int(n_cand),
            "scan_frac": (idx.n_groups + n_cand) / k,
            "recall": float(np.mean(labels == exact_labels)),
            "exact_us_per_query": t_exact / n_queries * 1e6,
            "approx_us_per_query": t_approx / n_queries * 1e6,
            "speedup": t_exact / t_approx,
        })
    return records


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_serving.json",
                        default=None, metavar="PATH",
                        help="write records to PATH (default "
                             "BENCH_serving.json in the repo root)")
    parser.add_argument("--smoke", action="store_true",
                        help="tiny case for CI (schema smoke)")
    args = parser.parse_args(argv)

    import jax

    cases = SMOKE_CASES if args.smoke else CASES
    records = []
    for k, d, n_queries, sweep in cases:
        records += case_records(k, d, n_queries, sweep,
                                reps=3 if args.smoke else 5)
    records.sort(key=lambda r: (r["k"], r["n_candidates"]))
    for r in records:
        print(f"serving.closure.k{r['k']}_d{r['d']}_c{r['n_candidates']},"
              f"{r['approx_us_per_query']:.3f},"
              f"recall={r['recall']:.4f};speedup={r['speedup']:.2f};"
              f"exact_us={r['exact_us_per_query']:.3f}")
    if args.json:
        path = Path(args.json)
        if not path.is_absolute():
            path = Path(__file__).resolve().parents[1] / path
        path.write_text(json.dumps(
            {"schema": "serving_bench/v1",
             "backend": jax.default_backend(),
             "smoke": args.smoke, "records": records},
            indent=2, sort_keys=True))
        print(f"wrote {path}")
    return records


if __name__ == "__main__":
    main()
