"""Streaming sweep: mini-batch AA vs full-batch AA vs mini-batch Lloyd.

    PYTHONPATH=src python -m benchmarks.streaming_sweep            # quality
    PYTHONPATH=src python -m benchmarks.streaming_sweep --big      # + OOM demo

Two measurements:

1. quality — synthetic Gaussians that fit on device.  Full-batch AA
   (same seed centroids) establishes the reference final energy and its
   samples-read budget: ``(2t − n_acc)·N`` by the pass-count model the
   instrumented backend test pins (one pass per accepted iteration, two
   per revert).  Each mini-batch arm (AA and plain Lloyd, identical
   chunking/guard protocol) then runs epoch by epoch; after every epoch
   the current guard-picked centroids are priced on the FULL dataset (a
   measurement pass, not counted as samples read), and we record the
   samples read — chunk rows plus the validation rows the guard touches —
   when the arm first comes within ``--target`` (default 2%) of the
   full-batch final energy.  Acceptance: mini-batch AA reaches 2% with
   <= 50% of full-batch AA's samples.

2. --big — an N where the full-batch solver cannot allocate X on a
   device with ``--device-mem-mb`` of memory (the X buffer alone plus
   the (N, K) distance intermediate overflow it).  X is generated in
   host memory and streamed chunk by chunk (`stream_chunks` -> one
   jit'd chunk step per chunk), so the peak device footprint stays at
   O(chunk + val); the full-batch arm is reported infeasible rather
   than run.  The demo runs the identical chunk sequence twice — once
   with synchronous per-chunk ``device_put`` and once through the
   prefetching pipeline (`repro.runtime.prefetch`, chunk t+1's copy
   overlapping chunk t's compute) — and reports both achieved ingest
   bandwidths (GB/s).

The module is import-safe at small sizes; tests/test_minibatch.py runs
``main(smoke=True)`` under the slow marker.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.backends import backend_names
from repro.core.init_schemes import kmeanspp_init
from repro.core.kmeans import KMeansConfig, aa_kmeans, resolve_backend
from repro.core.minibatch import (MiniBatchConfig, guard_pick,
                                  minibatch_init, minibatch_iteration,
                                  run_epoch)
from repro.data.streaming import (chunk_dataset, host_chunk_stream,
                                  split_validation, stream_chunks)
from repro.data.synthetic import make_blobs
from repro.runtime.prefetch import IngestMeter


def _full_energy_fn(x, k, backend):
    bk = resolve_backend(backend)
    # init_carry, not (): carry-bearing backends (hamerly) unpack it
    return jax.jit(
        lambda c: bk.step(x, c, k, bk.init_carry(x, c, k))[0].energy)


def _samples_to_target(x_train, x_price, x_val, c0, cfg, target_energy,
                       rel_target, backend, max_epochs, seed, label,
                       verbose):
    """Run one mini-batch arm epoch by epoch until its guard-picked
    centroids price within target on ``x_price`` — the SAME full dataset
    the target energy was computed on (pricing on the train split alone
    would deflate the energy sum by the held-out fraction and flatter
    the arm).  Returns (samples_read, full_energy, epochs_used) —
    epochs_used = max_epochs+1 marks a miss."""
    bk = resolve_backend(backend)
    dc = chunk_dataset(x_train, cfg.chunk_size)
    n_chunks, b = dc.weights.shape
    v = x_val.shape[0]
    epoch_fn = jax.jit(run_epoch, static_argnames=("cfg", "backend"))
    pick_fn = jax.jit(guard_pick, static_argnames=("cfg", "backend"))
    e_full_fn = _full_energy_fn(x_price, cfg.k, backend)

    state = minibatch_init(c0, cfg, bk)
    key = jax.random.PRNGKey(seed)
    samples = 0
    e_now = float("inf")
    for epoch in range(1, max_epochs + 1):
        key, sub = jax.random.split(key)
        state, _ = epoch_fn(dc.chunks, dc.weights, x_val, state,
                            cfg=cfg, backend=bk, key=sub)
        # every chunk step reads its B chunk rows plus the V validation
        # rows the guard prices both candidates on (one shared-X pass)
        samples += n_chunks * (b + v)
        c_now, _, _, _ = pick_fn(x_val, state, cfg=cfg, backend=bk)
        e_now = float(e_full_fn(c_now))
        if verbose:
            print(f"  {label} epoch {epoch}: full-X E {e_now:12.1f} "
                  f"({e_now / target_energy - 1:+.2%} vs target base), "
                  f"samples {samples}", flush=True)
        if e_now <= target_energy * (1.0 + rel_target):
            return samples, e_now, epoch
    return samples, e_now, max_epochs + 1


def quality_comparison(n=100_000, d=16, k=20, chunk=8192, val=2048,
                       decay=0.9, seed=0, backend="dense", max_epochs=12,
                       rel_target=0.02, verbose=True):
    """Samples-read-to-quality: full-batch AA vs mini-batch AA vs
    mini-batch Lloyd, all from the same seed centroids and all priced on
    the same full dataset.  (Full-batch trains on all N rows; the
    mini-batch arms train on N - val of them, holding ``val`` rows out
    for the guard — the small training handicap goes against the
    mini-batch arms, so the criterion is conservative.)"""
    x = jnp.asarray(make_blobs(n, d, k, seed=seed, spread=3.0))
    x_train, x_val = split_validation(x, val, jax.random.PRNGKey(seed))
    c0 = kmeanspp_init(jax.random.PRNGKey(seed + 1), x[:4 * chunk], k)

    full = jax.jit(lambda a, b: aa_kmeans(
        a, b, KMeansConfig(k=k, max_iter=500), backend=backend))(x, c0)
    t, n_acc = int(full.n_iter), int(full.n_accepted)
    full_samples = (2 * t - n_acc) * n          # pass-count model
    e_full = float(full.energy)
    if verbose:
        print(f"full-batch AA: E {e_full:12.1f}  iters {t} "
              f"(acc {n_acc})  samples {full_samples}", flush=True)

    out = {"full": {"energy": e_full, "samples": full_samples,
                    "n_iter": t}}
    for label, accelerated in (("minibatch-aa", True),
                               ("minibatch-lloyd", False)):
        cfg = MiniBatchConfig(k=k, chunk_size=chunk, decay=decay,
                              accelerated=accelerated)
        s, e, ep = _samples_to_target(x_train, x, x_val, c0, cfg, e_full,
                                      rel_target, backend, max_epochs,
                                      seed + 2, label, verbose)
        out[label] = {"energy": e, "samples": s, "epochs": ep,
                      "ratio": s / full_samples,
                      "reached": ep <= max_epochs}
        if verbose:
            flag = "OK" if ep <= max_epochs else "MISS"
            print(f"{label}: within {rel_target:.0%} after {s} samples "
                  f"({s / full_samples:.2f}x full-batch) [{flag}]",
                  flush=True)
    return out


def big_streaming_demo(n=4_000_000, d=16, k=20, chunk=65_536, val=8192,
                       device_mem_mb=192, epochs=2, seed=0,
                       backend="dense", verbose=True):
    """Stream an X that cannot sit on a --device-mem-mb device.

    Full-batch needs the (N, d) buffer plus the (N, K) distance
    intermediate resident at once; streaming needs one chunk plus the
    validation chunk.  X itself is generated into host memory and only
    ever touched one chunk at a time.
    """
    full_bytes = n * d * 4 + n * k * 4
    budget = device_mem_mb * 2**20
    stream_bytes = (chunk + val) * d * 4 + chunk * k * 4
    assert full_bytes > budget, (
        f"--big demo expects full-batch ({full_bytes >> 20} MB) to "
        f"overflow the {device_mem_mb} MB budget; raise N")
    assert stream_bytes < budget
    if verbose:
        print(f"--big: N={n} d={d} K={k} | full-batch needs "
              f"{full_bytes >> 20} MB > {device_mem_mb} MB budget -> "
              f"infeasible; streaming peaks at {stream_bytes >> 20} MB",
              flush=True)

    x = make_blobs(n, d, k, seed=seed, spread=3.0)      # host memory only
    bk = resolve_backend(backend)
    cfg = MiniBatchConfig(k=k, chunk_size=chunk)
    x_val = jnp.asarray(x[:val])
    c0 = kmeanspp_init(jax.random.PRNGKey(seed), x_val, k)
    step_fn = jax.jit(minibatch_iteration,
                      static_argnames=("cfg", "backend"))
    # compile outside the timed arms: both arms then measure steady-state
    # streaming, not who pays the jit trace
    warm = jnp.asarray(x[val:val + chunk])
    jax.block_until_ready(step_fn(
        warm, jnp.ones((chunk,), jnp.float32), x_val,
        minibatch_init(c0, cfg, bk), cfg=cfg, backend=bk)[0].c_au)

    def _stream_arm(prefetch):
        """One full streaming pass; prefetch=1 is the synchronous
        baseline (transfer, then compute), prefetch=2 double-buffers."""
        meter = IngestMeter()
        state = minibatch_init(c0, cfg, bk)
        steps = 0
        trace = None
        meter.start()
        t0 = time.perf_counter()
        for xc in stream_chunks(
                host_chunk_stream(x[val:], chunk, epochs=epochs,
                                  seed=seed, drop_remainder=True),
                prefetch=prefetch, meter=meter):
            w = jnp.ones((xc.shape[0],), jnp.float32)
            state, trace = step_fn(xc, w, x_val, state, cfg=cfg,
                                   backend=bk)
            steps += 1
            if verbose and steps % 16 == 0:
                print(f"  step {steps}: val E "
                      f"{float(trace.e_val):12.1f}", flush=True)
        jax.block_until_ready(state.c_au)
        wall = time.perf_counter() - t0
        return state, steps, meter, wall

    # synchronous baseline first (prefetch=1 degenerates to put-then-step)
    _, steps_sync, meter_sync, wall_sync = _stream_arm(prefetch=1)
    gbps_sync = meter_sync.bytes / wall_sync / 1e9
    state, steps, meter, wall_pre = _stream_arm(prefetch=2)
    gbps_pre = meter.bytes / wall_pre / 1e9
    assert steps == steps_sync
    c_fin, e_fin, _, _ = guard_pick(x_val, state, cfg, bk)
    if verbose:
        print(f"--big: {steps} chunk steps, final val E {float(e_fin):.1f} "
              f"(per-val-sample {float(e_fin) / val:.3f})", flush=True)
        print(f"--big ingest: synchronous {gbps_sync:.3f} GB/s "
              f"({wall_sync:.2f} s) vs prefetched {gbps_pre:.3f} GB/s "
              f"({wall_pre:.2f} s) — {wall_sync / wall_pre:.2f}x", flush=True)
    return {"steps": steps, "val_energy": float(e_fin),
            "full_bytes": full_bytes, "stream_bytes": stream_bytes,
            "ingest_bytes": meter.bytes,
            "ingest_gbps_sync": gbps_sync, "ingest_gbps_prefetch": gbps_pre,
            "wall_sync_s": wall_sync, "wall_prefetch_s": wall_pre,
            "speedup": wall_sync / wall_pre}


def main(smoke=False, big=False, backend="dense", rel_target=0.02,
         verbose=True, **kwargs):
    if smoke:
        kwargs = dict(n=20_000, d=8, k=8, chunk=2048, val=1024,
                      max_epochs=10, **kwargs)
    q = quality_comparison(backend=backend, rel_target=rel_target,
                           verbose=verbose, **kwargs)
    print(csv_row("streaming_sweep.full_samples", q["full"]["samples"]))
    print(csv_row("streaming_sweep.minibatch_aa_samples",
                  q["minibatch-aa"]["samples"],
                  f"ratio={q['minibatch-aa']['ratio']:.2f}x"))
    print(csv_row("streaming_sweep.minibatch_lloyd_samples",
                  q["minibatch-lloyd"]["samples"],
                  f"ratio={q['minibatch-lloyd']['ratio']:.2f}x"))
    out = {"quality": q}
    if big:
        out["big"] = big_streaming_demo(backend=backend, verbose=verbose)
        print(csv_row("streaming_sweep.big_steps", out["big"]["steps"],
                      f"val_energy={out['big']['val_energy']:.1f}"))
        print(csv_row("streaming_sweep.big_ingest_gbps",
                      out["big"]["ingest_gbps_prefetch"],
                      f"sync={out['big']['ingest_gbps_sync']:.3f};"
                      f"speedup={out['big']['speedup']:.2f}x"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="dense",
                    choices=sorted(backend_names()))
    ap.add_argument("--target", type=float, default=0.02,
                    help="relative energy target vs full-batch final")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--big", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke, big=args.big, backend=args.backend,
         rel_target=args.target)
