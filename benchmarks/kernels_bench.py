"""Kernel micro-benchmarks: fused vs split Lloyd pass + arithmetic-intensity
derivation for the kernel roofline (EXPERIMENTS.md §Roofline, K-Means rows).

On this CPU container the Pallas kernels run in interpret mode (not
representative); wall times here benchmark the jnp reference path that XLA
compiles, while the DERIVED columns give the analytic TPU roofline of each
kernel variant: X passes per iteration, bytes moved, flops, arithmetic
intensity, and the predicted HBM-bound iteration time on v5e (819 GB/s,
197 TFLOP/s).  The v2 fused kernel is priced with its k-tiled traffic
model: X once, C re-streamed per X row tile.

``--json [PATH]`` emits the full table as ``BENCH_kernels.json`` — the
machine-readable seed of the perf trajectory (one record per kernel
variant x shape: x_passes_per_iter, bytes_per_iter, flops_per_iter, wall
time where measured).  ``--smoke`` shrinks the shapes and additionally
drives the real Pallas kernels in interpret mode, so CI can assert the
benchmark harness end-to-end without a TPU (test.sh --slow).

Schema v3 adds the tile-skip dimension (DESIGN.md §Bounds): every record
carries ``skipped_tile_frac`` (None for the bound-free kernels) and
``phase``, and `bounds_records` drives the ``fused_bounds`` engine
through an "early" (first step — no valid bounds, zero skip, the worst
case) and a "converged" (post-refinement — the plateau the solver
spends most iterations in) phase, reporting the measured skipped-tile
fraction and the traffic model it implies.  X passes stay at 1.0:
skipping removes C re-streams and distance flops, never the single X
read.

Schema v4 adds the row-layout dimension (DESIGN.md §Locality): every
record carries ``layout`` (None off the bounds arms) and the bounds
phases run over three layouts — "ordered" (rows laid out cluster by
cluster: the best case the tile predicate was designed for),
"interleaved" (the same rows deterministically shuffled — the make_blobs
regime, where a converged row tile still spans many clusters and the
tile-level ANY predicate never fires), and "interleaved+reorder" (the
interleaved rows driven through the ``fused_bounds_reorder`` locality
engine, which sorts rows by current label on-device and should recover
the ordered layout's converged skip).  `solver_records` adds end-to-end
``aa_kmeans_traced`` wall-time rows on the interleaved workload with and
without reordering, reporting the post-accept-phase skip
(`split_bound_phases` — the flat average would dilute it with warm-up
iterations).  Records are emitted in a deterministic order with fixed
seeds and sorted JSON keys, so two runs differ only in wall times.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timed
from repro.core.backends import get_backend
from repro.kernels import ref, tiles

HBM_BW = 819e9
PEAK = 197e12

SHAPES = [(100_000, 9, 10), (100_000, 9, 100),
          (53_500, 385, 10), (131_072, 64, 1000),
          (131_072, 64, 65_536)]          # beyond the old fused VMEM gate
SMOKE_SHAPES = [(512, 9, 10), (384, 17, 33)]

# Deliberately a curated subset of backends.backend_names(): the backends
# whose CPU wall clock is meaningful (Pallas engines join on real TPUs —
# see step_bench).
STEP_BACKENDS = ("dense", "blocked", "hamerly", "elkan", "yinyang")


def analyze(n, d, k, variant: str):
    """Per-Lloyd-iteration X passes / bytes / flops on TPU (bf16 X, f32
    accum).  Pipeline variants: "split" (assignment pass + update pass),
    "fused_v1" (whole C resident — the old gated kernel, for reference),
    "fused" (v2 k-tiled: X once, C re-streamed per X row tile).
    Single-kernel variants (one X pass each, their own byte/flop terms):
    "assignment" (distances + labels/mind out), "update" (labels in,
    one-hot matmul, stats out)."""
    itemsize = 2
    x_bytes = n * d * itemsize
    c_bytes = k * d * itemsize
    out_bytes = n * 4 + k * d * 4                  # labels+mind, f32 stats
    dist_flops = 2 * n * k * d     # distance cross-term
    onehot_flops = 2 * n * k * d   # one-hot matmul for the update
    flops = dist_flops + onehot_flops
    if variant == "split":
        x_passes = 2.0
        bytes_moved = 2 * x_bytes + 2 * c_bytes + 2 * n * 4 + k * d * 4
    elif variant == "fused_v1":
        x_passes = 1.0
        bytes_moved = x_bytes + c_bytes + out_bytes
    elif variant == "fused":
        x_passes = 1.0
        tn, _ = tiles.choose_tiles(n, k, d, itemsize, kind="fused")
        n_tiles = max(1, -(-n // tn))
        bytes_moved = x_bytes + n_tiles * c_bytes + out_bytes
    elif variant == "assignment":
        x_passes = 1.0
        tn, _ = tiles.choose_tiles(n, k, d, itemsize, kind="assignment")
        n_tiles = max(1, -(-n // tn))
        bytes_moved = x_bytes + n_tiles * c_bytes + 2 * n * 4
        flops = dist_flops
    elif variant == "update":
        x_passes = 1.0
        bytes_moved = x_bytes + n * 4 + k * d * 4 + k * 4
        flops = onehot_flops
    else:
        raise ValueError(variant)
    ai = flops / bytes_moved
    t_mem = bytes_moved / HBM_BW
    t_comp = flops / PEAK
    return {"x_passes_per_iter": x_passes, "bytes_per_iter": bytes_moved,
            "flops_per_iter": flops, "ai": ai,
            "t_mem_us": t_mem * 1e6, "t_comp_us": t_comp * 1e6,
            "bound": "compute" if t_comp > t_mem else "memory"}


def kernel_records(shapes, smoke: bool = False):
    """One record per kernel variant x shape: analytic roofline columns
    plus a wall time where this host can measure one meaningfully (the
    XLA-compiled jnp path always; the Pallas kernels themselves only in
    --smoke interpret mode, flagged as such)."""
    rng = np.random.default_rng(0)
    records = []
    for (n, d, k) in shapes:
        x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)

        if n * k <= 200e6:
            split = jax.jit(lambda a, b, kk=k: (
                ref.update_ref(a, ref.assignment_ref(a, b)[0], kk)))
            fused = jax.jit(lambda a, b: ref.fused_lloyd_ref(a, b))
            _, t_split = timed(split, x, c)
            _, t_fused = timed(fused, x, c)
        else:
            # the (N, K) distance matrix of the jnp path would not fit
            # host memory — analytic roofline rows only for this shape
            t_split = t_fused = None

        for variant, t in (("split", t_split), ("fused", t_fused),
                           ("fused_v1", None)):
            rec = {"variant": variant, "n": n, "d": d, "k": k,
                   "wall_us": None if t is None else t * 1e6,
                   "wall_path": None if t is None else "xla_ref",
                   "skipped_tile_frac": None, "phase": None,
                   "layout": None,
                   **analyze(n, d, k, variant)}
            records.append(rec)

        if smoke:
            # exercise the actual Pallas kernels (interpret mode)
            from repro.kernels.assignment import assignment_pallas
            from repro.kernels.fused_lloyd import fused_lloyd_pallas
            from repro.kernels.update import update_pallas
            w = jnp.ones((n,), jnp.float32)
            for variant, fn in (
                    ("pallas.fused", lambda: fused_lloyd_pallas(
                        x, c, interpret=True)),
                    ("pallas.fused_weighted", lambda: fused_lloyd_pallas(
                        x, c, w, interpret=True)),
                    ("pallas.assignment", lambda: assignment_pallas(
                        x, c, interpret=True)),
                    ("pallas.update", lambda: update_pallas(
                        x, jnp.zeros((n,), jnp.int32), k, w=w,
                        interpret=True))):
                _, t = timed(lambda fn=fn: fn(), warmup=1, reps=1)
                base = variant.split(".", 1)[1].replace("_weighted", "")
                records.append({"variant": variant, "n": n, "d": d, "k": k,
                                "wall_us": t * 1e6,
                                "wall_path": "pallas_interpret",
                                "skipped_tile_frac": None, "phase": None,
                                "layout": None,
                                **analyze(n, d, k, base)})
    return records


def bounds_workload(k=32, d=16, per=64, seed=7, layout="ordered"):
    """Synthetic tile-skip workloads in two row layouts.

    ``layout="ordered"`` lays rows out cluster by cluster (the favourable
    locality a sorted / sharded ingest provides), with the centroid order
    matching, so a converged row tile needs only the k tiles its own
    clusters live in.  ``layout="interleaved"`` deterministically shuffles
    those same rows — the `make_blobs` regime, where consecutive rows land
    in unrelated clusters, an X row *tile* always spans many groups, and
    the tile-level predicate (ANY row needs the k tile) never fires even
    when per-row elimination is near total.  The interleaved layout is the
    workload the locality engine (DESIGN.md §Locality) exists to fix."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((k, d)).astype(np.float32) * 20.0
    x = np.concatenate([centers[j] + rng.standard_normal((per, d))
                        .astype(np.float32) for j in range(k)])
    if layout == "interleaved":
        x = x[np.random.default_rng(seed + 1).permutation(x.shape[0])]
    elif layout != "ordered":
        raise ValueError(f"unknown layout {layout!r}")
    c0 = centers + 0.5 * rng.standard_normal((k, d)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(c0)


BOUNDS_LAYOUTS = ("ordered", "interleaved", "interleaved+reorder")


def bounds_records(group_size=8, refine_steps=4):
    """Early- vs converged-phase records for the ``fused_bounds`` engine
    across the three row layouts.

    Drives real steps (interpret mode off-TPU) and reports the MEASURED
    skipped-tile fraction per (layout, phase): "early" is the first step
    from the init carry (upper = +inf — no valid bounds, full scan, skip
    0 by construction), "converged" is the step after ``refine_steps``
    Lloyd refinements, where the bounds have tightened onto the stable
    assignment.  The "interleaved+reorder" arm wraps the engine in the
    locality engine (``fused_bounds_reorder``, warmup=1) so the kernel
    sees cluster-sorted rows from step 1 on — its converged skip should
    match the ordered layout's, against the raw interleaved arm's ~0.
    The analytic columns price the skip against the fused kernel's
    traffic model: the skipped fraction removes C re-streams and distance
    flops but never the single X read, so x_passes stays 1.0 and AI
    *drops* as bytes shrink slower than flops."""
    from repro.core.backends.bounds import extract_stats

    wall_path = ("pallas_interpret" if jax.default_backend() != "tpu"
                 else "pallas_tpu")
    records = []
    for layout in BOUNDS_LAYOUTS:
        reorder = layout.endswith("+reorder")
        x, c = bounds_workload(layout=layout.split("+")[0])
        n, d = x.shape
        k = c.shape[0]
        bk = get_backend("fused_bounds_reorder", warmup=1,
                         group_size=group_size) if reorder \
            else get_backend("fused_bounds", group_size=group_size)

        skips, walls = {}, {}
        carry = bk.init_carry(x, c, k)
        step = jax.jit(lambda a, b, cr, bk=bk: bk.step(a, b, k, cr))
        for i in range(refine_steps + 1):
            (res, carry), t = timed(step, x, c, carry, warmup=0, reps=1)
            skip = float(extract_stats(carry).skipped_frac)
            if i == 0:
                skips["early"], walls["early"] = skip, t
            c = bk.centroids_from_step(x, res, k, c)
        skips["converged"], walls["converged"] = skip, t

        for phase in sorted(skips):
            skip = skips[phase]
            base = analyze(n, d, k, "fused")
            itemsize = 2
            tn, _ = tiles.choose_tiles(n, k, d, itemsize,
                                       kind="fused_bounds")
            n_tiles = max(1, -(-n // tn))
            c_stream = n_tiles * k * d * itemsize
            base["bytes_per_iter"] = int(
                base["bytes_per_iter"] - skip * c_stream)
            base["flops_per_iter"] = int(base["flops_per_iter"]
                                         - skip * 2 * n * k * d)
            base["ai"] = base["flops_per_iter"] / base["bytes_per_iter"]
            base["t_mem_us"] = base["bytes_per_iter"] / HBM_BW * 1e6
            base["t_comp_us"] = base["flops_per_iter"] / PEAK * 1e6
            base["bound"] = ("compute"
                             if base["t_comp_us"] > base["t_mem_us"]
                             else "memory")
            records.append({"variant": "pallas.fused_bounds",
                            "n": n, "d": d, "k": k,
                            "wall_us": walls[phase] * 1e6,
                            "wall_path": wall_path,
                            "skipped_tile_frac": skip, "phase": phase,
                            "layout": layout,
                            **base})
    return records


def solver_records(max_iter=12):
    """End-to-end traced-solver rows: `aa_kmeans_traced` on the
    INTERLEAVED workload with and without the locality engine.

    Per-step micro-benchmarks can overstate a reordering win (they never
    pay the sort); these rows time the whole solve — warm-up iterations,
    churn-triggered sorts, gathers and all — and report the post-accept
    phase's mean skipped-tile fraction (`split_bound_phases`: the flat
    average would dilute any converged plateau with the boundless warm-up
    steps).  Expect that fraction to sit near 0 in BOTH arms on a
    from-scratch solve: the driver exits the moment labels stabilise, and
    tile-skipping only pays once drift ≈ 0 for consecutive steps — i.e.
    exactly the post-convergence plateau the driver never executes.  The
    converged-phase `bounds_records` arms isolate that plateau (the
    regime the segmented epoch drivers and serving-side refinement
    actually occupy); these rows price what reordering costs a cold solve
    that never reaches it.  Off-TPU the wall number is interpret
    overhead, not kernel time — it becomes meaningful on a real TPU."""
    from repro.core.kmeans import KMeansConfig, aa_kmeans_traced

    x, c_near = bounds_workload(layout="interleaved")
    n, d = x.shape
    k = c_near.shape[0]
    # random-row init: the near-solution init the per-step bench uses
    # converges in one iteration, leaving no post-accept phase to measure
    c0 = x[np.random.default_rng(11).choice(n, k, replace=False)]
    cfg = KMeansConfig(k=k, max_iter=max_iter)
    wall_path = ("pallas_interpret" if jax.default_backend() != "tpu"
                 else "pallas_tpu")
    records = []
    for layout, reorder in (("interleaved", False),
                            ("interleaved+reorder", True)):
        tr = aa_kmeans_traced(x, c0, cfg, backend="fused_bounds",
                              warmup=True, reorder=reorder)
        post = (tr.bound_phases or {}).get("post_accept", {})
        records.append({"variant": "solver.fused_bounds_traced",
                        "n": n, "d": d, "k": k,
                        "wall_us": tr.wall_time_s * 1e6,
                        "wall_path": wall_path,
                        "skipped_tile_frac": post.get("skipped_frac"),
                        "phase": "post_accept", "layout": layout,
                        "n_iters": len(tr.energies)})
    return records


def step_bench(backends=None, n=100_000, d=9, k=100):
    """Wall time of one step() — the solver's per-iteration unit — per
    backend.  The Pallas backends ("pallas"/"fused") are only timed on a
    real TPU: in CPU interpret mode their wall numbers would be pure
    Python-emulation overhead and read as the opposite of the TPU story
    (which the analytic roofline in `analyze` covers)."""
    if backends is None:
        backends = STEP_BACKENDS + (("pallas", "fused")
                                    if jax.default_backend() == "tpu"
                                    else ())
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    rows = []
    for name in backends:
        # block size must divide N for the row-blocked path to engage
        bk = get_backend(name, block_n=n // 8) if name == "blocked" \
            else get_backend(name)
        carry = bk.init_carry(x, c, k)
        fn = jax.jit(lambda a, b, cr, bk=bk: bk.step(a, b, k, cr)[0])
        res, t = timed(fn, x, c, carry)
        rows.append(csv_row(f"backend.step.{name}.n{n}_d{d}_k{k}", t * 1e6,
                            f"energy={float(res.energy):.3e}"))
    return rows


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_kernels.json",
                        default=None, metavar="PATH",
                        help="write records to PATH (default "
                             "BENCH_kernels.json in the repo root)")
    parser.add_argument("--smoke", action="store_true",
                        help="tiny shapes + drive the real Pallas kernels "
                             "in interpret mode (CI smoke)")
    args = parser.parse_args(argv)

    shapes = SMOKE_SHAPES if args.smoke else SHAPES
    records = kernel_records(shapes, smoke=args.smoke)
    records += bounds_records()
    records += solver_records()
    records.sort(key=lambda r: (r["variant"], r["n"], r["d"], r["k"],
                                r["layout"] or "", r["phase"] or ""))
    for r in records:
        phase = f".{r['phase']}" if r["phase"] else ""
        layout = f".{r['layout']}" if r["layout"] else ""
        skip = "" if r["skipped_tile_frac"] is None else \
            f";skip={r['skipped_tile_frac']:.3f}"
        detail = (f"x_passes={r['x_passes_per_iter']:g};"
                  f"tpu_bytes={r['bytes_per_iter']:.2e};ai={r['ai']:.1f};"
                  f"tpu_{r['bound']}_us="
                  f"{max(r['t_mem_us'], r['t_comp_us']):.1f}"
                  if "ai" in r else f"n_iters={r['n_iters']}")
        print(csv_row(
            f"kernel.{r['variant']}.n{r['n']}_d{r['d']}_k{r['k']}"
            f"{layout}{phase}",
            r["wall_us"] or 0.0, f"{detail}{skip}"))
    if not args.smoke:
        for row in step_bench():
            print(row)

    if args.json:
        path = Path(args.json)
        if not path.is_absolute():
            path = Path(__file__).resolve().parents[1] / path
        path.write_text(json.dumps(
            {"schema": "kernels_bench/v4",
             "backend": jax.default_backend(),
             "smoke": args.smoke, "records": records},
            indent=2, sort_keys=True))
        print(f"wrote {path}")
    return records


if __name__ == "__main__":
    main()
