"""Kernel micro-benchmarks: fused vs split Lloyd pass + arithmetic-intensity
derivation for the kernel roofline (EXPERIMENTS.md §Roofline, K-Means rows).

On this CPU container the Pallas kernels run in interpret mode (not
representative); wall times here benchmark the jnp reference path that XLA
compiles, while the DERIVED columns give the analytic TPU roofline of each
kernel variant: bytes moved per iteration, flops, arithmetic intensity, and
the predicted HBM-bound iteration time on v5e (819 GB/s, 197 TFLOP/s).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, timed
from repro.core.backends import get_backend
from repro.kernels import ref

HBM_BW = 819e9
PEAK = 197e12

# Deliberately a curated subset of backends.backend_names(): the backends
# whose CPU wall clock is meaningful (Pallas engines join on real TPUs —
# see step_bench).
STEP_BACKENDS = ("dense", "blocked", "hamerly")


def analyze(n, d, k, fused: bool):
    """Per-Lloyd-iteration bytes/flops on TPU (bf16 X, f32 accum)."""
    x_bytes = n * d * 2
    c_bytes = k * d * 4
    flops = 2 * n * k * d          # distance cross-term (dominant)
    flops += 2 * n * k * d         # one-hot matmul for the update
    if fused:
        bytes_moved = x_bytes + c_bytes + n * 4 + k * d * 4
    else:
        # assignment pass reads X, writes labels; update pass re-reads X;
        # energy pass gathers (reuses labels/mindist)
        bytes_moved = 2 * x_bytes + 2 * c_bytes + 2 * n * 4 + k * d * 4
    ai = flops / bytes_moved
    t_mem = bytes_moved / HBM_BW
    t_comp = flops / PEAK
    return {"bytes": bytes_moved, "flops": flops, "ai": ai,
            "t_mem_us": t_mem * 1e6, "t_comp_us": t_comp * 1e6,
            "bound": "compute" if t_comp > t_mem else "memory"}


def step_bench(backends=None, n=100_000, d=9, k=100):
    """Wall time of one step() — the solver's per-iteration unit — per
    backend.  The Pallas backends ("pallas"/"fused") are only timed on a
    real TPU: in CPU interpret mode their wall numbers would be pure
    Python-emulation overhead and read as the opposite of the TPU story
    (which the analytic roofline in `analyze` covers)."""
    if backends is None:
        backends = STEP_BACKENDS + (("pallas", "fused")
                                    if jax.default_backend() == "tpu"
                                    else ())
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    rows = []
    for name in backends:
        # block size must divide N for the row-blocked path to engage
        bk = get_backend(name, block_n=n // 8) if name == "blocked" \
            else get_backend(name)
        carry = bk.init_carry(x, c, k)
        fn = jax.jit(lambda a, b, cr, bk=bk: bk.step(a, b, k, cr)[0])
        res, t = timed(fn, x, c, carry)
        rows.append(csv_row(f"backend.step.{name}.n{n}_d{d}_k{k}", t * 1e6,
                            f"energy={float(res.energy):.3e}"))
    return rows


def main():
    rng = np.random.default_rng(0)
    rows = []
    for (n, d, k) in [(100_000, 9, 10), (100_000, 9, 100),
                      (53_500, 385, 10), (131_072, 64, 1000)]:
        x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)

        split = jax.jit(lambda a, b, kk=k: (
            ref.update_ref(a, ref.assignment_ref(a, b)[0], kk)))
        fused = jax.jit(lambda a, b: ref.fused_lloyd_ref(a, b))
        _, t_split = timed(split, x, c)
        _, t_fused = timed(fused, x, c)

        a_s = analyze(n, d, k, fused=False)
        a_f = analyze(n, d, k, fused=True)
        rows.append(csv_row(
            f"kernel.split.n{n}_d{d}_k{k}", t_split * 1e6,
            f"tpu_bytes={a_s['bytes']:.2e};ai={a_s['ai']:.1f};"
            f"tpu_{a_s['bound']}_us={max(a_s['t_mem_us'], a_s['t_comp_us']):.1f}"))
        rows.append(csv_row(
            f"kernel.fused.n{n}_d{d}_k{k}", t_fused * 1e6,
            f"tpu_bytes={a_f['bytes']:.2e};ai={a_f['ai']:.1f};"
            f"tpu_{a_f['bound']}_us={max(a_f['t_mem_us'], a_f['t_comp_us']):.1f};"
            f"mem_term_speedup={a_s['bytes']/a_f['bytes']:.2f}x"))
    rows += step_bench()
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    main()
