"""Flat vs hierarchical solve at large K (DESIGN.md §Hierarchy).

Each case fits the same synthetic dataset twice: the flat batched solver
(`aa_kmeans_batched`, one K-cluster program) and the two-level
divide-and-conquer engine (`aa_kmeans_hierarchical`, G ≈ √K
super-clusters, all K/G-sub-problems one batched program).  Both arms are
END-TO-END fits — seeding included — because that is the cost a codebook
refresh actually pays; the record carries wall seconds and final energy
for both plus their ratios.  The million-cluster arm runs the hierarchy
only (its flat arm would price an N×K distance matrix no host here can
hold — ``flat_wall_s: null`` is the honest record, not a timeout).

Data is a low-intrinsic-dimension manifold plus noise (the
`serving_bench` generator family): smooth density is the k²-means
operating regime — on well-separated discrete blobs the uniform K/G
split must merge blobs in overfull super-clusters and the energy ratio
degrades, which `tests/test_hierarchy.py` documents instead of hiding.

``--json [PATH]`` writes ``BENCH_hierarchy.json`` (schema
``hierarchy_bench/v1``); ``--smoke`` runs a tiny case for CI
(tests/test_perf_smoke.py pins the schema, and pins the committed
K=65536 record's wall ratio < 1).

    PYTHONPATH=src python -m benchmarks.hierarchy_bench --json
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

# name, k, n, d, n_groups, max_iter, mbar, flat block_n (0 = no flat arm),
# super_max_iter, n_reassign, n_init.
#
# n_groups is the quality knob: G ≈ √K minimises per-row work (G + K/G)
# but pins the most centroids per group; smaller G trades wall back for
# energy (fewer, larger sub-problems ≈ closer to flat).  The flat-armed
# cases pick the G meeting the ≤5% energy bar with wall to spare — the
# measured ladder at K=65536 (n_init=2): G=256 → 9.3% over flat,
# G=64 → 5.7%, G=16 → 4.2%.  The million-cluster arm runs G = √K: it
# has no flat arm to chase, and √K is the throughput-optimal point.
CASES = [
    ("k4096", 4096, 65536, 16, 16, 10, 30, 8192, 30, 2, 2),
    ("k65536", 65536, 131072, 16, 16, 8, 30, 4096, 30, 2, 2),
    ("k1m", 2 ** 20, 2 ** 21, 4, 1024, 3, 5, 0, 5, 1, 1),
]
SMOKE_CASES = [
    ("smoke", 256, 4096, 8, 16, 10, 10, 2048, 20, 1, 1),
]


def _make_case(n: int, d: int, seed: int):
    """Smooth-density workload: latent gaussian through a tanh embedding
    plus noise (see module docstring for why not discrete blobs)."""
    rng = np.random.default_rng(seed)
    dim_lat = max(2, min(d, 6))
    z = rng.normal(size=(n, dim_lat))
    basis = rng.normal(size=(dim_lat, d)) / np.sqrt(dim_lat)
    x = np.tanh(z @ basis) + 0.05 * rng.normal(size=(n, d))
    return x.astype(np.float32)


def _fit_flat(x, k, max_iter, mbar, block_n, seed):
    import jax
    import jax.numpy as jnp

    from repro.core.anderson import AAConfig
    from repro.core.init_schemes import batched_init
    from repro.core.kmeans import (KMeansConfig, aa_kmeans_batched,
                                   select_best)
    cfg = KMeansConfig(k=k, max_iter=max_iter, aa=AAConfig(mbar=mbar),
                       block_n=block_n)
    t0 = time.perf_counter()
    keys = jax.random.split(jax.random.PRNGKey(seed), 1)
    c0s = batched_init("kmeans++", keys, x, k)
    best = select_best(aa_kmeans_batched(x, c0s, cfg, backend="blocked"))
    jax.block_until_ready(best.centroids)
    return time.perf_counter() - t0, float(best.energy)


def _fit_hier(x, k, g, max_iter, mbar, super_max_iter, block_n, seed,
              n_reassign, n_init):
    import jax

    from repro.core.anderson import AAConfig
    from repro.core.hierarchy import aa_kmeans_hierarchical
    from repro.core.kmeans import KMeansConfig
    cfg = KMeansConfig(k=k, max_iter=max_iter, aa=AAConfig(mbar=mbar),
                       block_n=block_n)
    backend = "blocked" if block_n else "dense"
    t0 = time.perf_counter()
    res = aa_kmeans_hierarchical(x, k, cfg, backend=backend, n_groups=g,
                                 n_reassign=n_reassign, n_init=n_init,
                                 seed=seed,
                                 super_max_iter=super_max_iter)
    jax.block_until_ready(res.centroids)
    return time.perf_counter() - t0, float(res.energy), int(res.n_rounds)


def case_record(name, k, n, d, g, max_iter, mbar, flat_block_n,
                super_max_iter, n_reassign, n_init, *,
                seed: int = 0) -> dict:
    import jax.numpy as jnp
    x = jnp.asarray(_make_case(n, d, seed))
    # a dense sub-assignment prices a (G·n_init, N_max, K/G) distance
    # transient — gigabytes at these shapes — so the hierarchy arm runs
    # blocked everywhere: the flat arm's block size where there is one
    # (same engine both arms), a small block on the million-cluster arm
    hier_block_n = 256 if flat_block_n == 0 else flat_block_n
    hier_s, hier_e, n_rounds = _fit_hier(x, k, g, max_iter, mbar,
                                         super_max_iter, hier_block_n,
                                         seed, n_reassign, n_init)
    rec = {
        "case": name, "k": k, "n": n, "d": d,
        "n_groups": g, "k_sub": k // g,
        "max_iter": max_iter, "mbar": mbar,
        "n_reassign": n_reassign, "n_init": n_init,
        "hier_wall_s": hier_s, "hier_energy": hier_e,
        "n_rounds": n_rounds,
        "flat_wall_s": None, "flat_energy": None,
        "wall_ratio": None, "energy_ratio": None,
    }
    if flat_block_n:
        flat_s, flat_e = _fit_flat(x, k, max_iter, mbar, flat_block_n,
                                   seed)
        rec.update(flat_wall_s=flat_s, flat_energy=flat_e,
                   wall_ratio=hier_s / flat_s,
                   energy_ratio=hier_e / flat_e)
    return rec


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_hierarchy.json",
                        default=None, metavar="PATH",
                        help="write records to PATH (default "
                             "BENCH_hierarchy.json in the repo root)")
    parser.add_argument("--smoke", action="store_true",
                        help="tiny case for CI (schema smoke)")
    args = parser.parse_args(argv)

    import jax

    cases = SMOKE_CASES if args.smoke else CASES
    records = []
    for case in cases:
        rec = case_record(*case)
        records.append(rec)
        flat = "flat=skipped" if rec["flat_wall_s"] is None else (
            f"flat={rec['flat_wall_s']:.2f}s;"
            f"wall_ratio={rec['wall_ratio']:.3f};"
            f"energy_ratio={rec['energy_ratio']:.4f}")
        print(f"hierarchy.{rec['case']},{rec['hier_wall_s']:.2f},"
              f"E={rec['hier_energy']:.4g};rounds={rec['n_rounds']};"
              f"{flat}", flush=True)
    if args.json:
        path = Path(args.json)
        if not path.is_absolute():
            path = Path(__file__).resolve().parents[1] / path
        path.write_text(json.dumps(
            {"schema": "hierarchy_bench/v1",
             "backend": jax.default_backend(),
             "smoke": args.smoke, "records": records},
            indent=2, sort_keys=True))
        print(f"wrote {path}")
    return records


if __name__ == "__main__":
    main()
